//! TAG expansion — the paper's Algorithm 1 (§4.2).
//!
//! `Expand(J)` walks the roles of a job spec and builds one
//! [`WorkerConfig`] per physical worker:
//!
//! * **data consumers** (line 14-22): one worker per dataset; the worker's
//!   compute comes from realm matching ([`crate::registry`]) and its channel
//!   groups from the `groupAssociation` entry matching the dataset's group,
//! * **other roles** (line 24-30): one worker per `groupAssociation` entry,
//!   times `replica`, placed round-robin.
//!
//! Roles are self-contained, so expansion order doesn't matter (§4.2); we
//! iterate in spec order for deterministic worker ids. `PreCheck` /
//! `PostCheck` live in [`super::validate`].

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::json::Json;
use crate::registry::Registry;

use super::validate::{post_check, pre_check};
use super::{JobSpec, Role};

/// The physical instantiation of one role instance — everything an agent
/// needs to start a worker (§5.2 "task configuration").
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerConfig {
    /// Globally unique worker id: `<job>-<role>-<n>`.
    pub id: String,
    pub role: String,
    /// Compute cluster this worker is placed on.
    pub compute: String,
    /// `channel name -> group` memberships for this worker.
    pub channels: BTreeMap<String, String>,
    /// Dataset bound to this worker (data consumers only).
    pub dataset: Option<String>,
    /// Which replica of its groupAssociation entry this worker is.
    pub replica_idx: usize,
}

impl WorkerConfig {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("id", self.id.as_str());
        o.insert("role", self.role.as_str());
        o.insert("compute", self.compute.as_str());
        let mut ch = Json::obj();
        for (k, v) in &self.channels {
            ch.insert(k.as_str(), v.as_str());
        }
        o.insert("channels", ch);
        match &self.dataset {
            Some(d) => o.insert("dataset", d.as_str()),
            None => o.insert("dataset", Json::Null),
        }
        o.insert("replicaIdx", self.replica_idx);
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut channels = BTreeMap::new();
        if let Some(o) = j.get("channels").as_obj() {
            for (k, v) in o.iter() {
                channels.insert(
                    k.clone(),
                    v.as_str().context("channel group must be string")?.to_string(),
                );
            }
        }
        Ok(WorkerConfig {
            id: j.get("id").as_str().context("missing id")?.to_string(),
            role: j.get("role").as_str().context("missing role")?.to_string(),
            compute: j.get("compute").as_str().unwrap_or("box").to_string(),
            channels,
            dataset: j.get("dataset").as_str().map(str::to_string),
            replica_idx: j.get("replicaIdx").as_usize().unwrap_or(0),
        })
    }
}

/// Algorithm 1, `Expand(J)`: returns the full worker list, or an error when
/// pre/post validation fails.
pub fn expand(spec: &JobSpec, registry: &Registry) -> Result<Vec<WorkerConfig>> {
    pre_check(spec)?;
    registry.reset_load();
    let mut workers = Vec::new();
    for role in &spec.roles {
        let xs = build_workers(role, spec, registry)
            .with_context(|| format!("expanding role '{}'", role.name))?;
        workers.extend(xs);
    }
    post_check(spec, &workers)?;
    Ok(workers)
}

/// Resolve a role's `groupAssociation` entry to concrete channel groups,
/// filling in `"default"` for channels of the role not named by the entry.
fn resolve_channels(role: &Role, entry: &BTreeMap<String, String>, spec: &JobSpec) -> BTreeMap<String, String> {
    let mut channels = BTreeMap::new();
    for c in spec.channels_of(&role.name) {
        let group = entry
            .get(&c.name)
            .cloned()
            .unwrap_or_else(|| "default".to_string());
        channels.insert(c.name.clone(), group);
    }
    channels
}

/// Algorithm 1, `BuildWorkers(r, J)`.
fn build_workers(role: &Role, spec: &JobSpec, registry: &Registry) -> Result<Vec<WorkerConfig>> {
    let mut out = Vec::new();
    let mut n = 0usize;
    if role.is_data_consumer {
        // lines 14-22: iterate dataset groups, one worker per dataset.
        for group in spec.dataset_groups() {
            let assoc = group_assoc_by_group_name(role, &group).with_context(|| {
                format!(
                    "role '{}' has no groupAssociation entry for dataset group '{group}'",
                    role.name
                )
            })?;
            for d in spec.datasets.iter().filter(|d| d.group == group) {
                let compute = registry.compute_for_realm(&d.realm)?;
                out.push(WorkerConfig {
                    id: format!("{}-{}-{}", spec.name, role.name, n),
                    role: role.name.clone(),
                    compute,
                    channels: resolve_channels(role, assoc, spec),
                    dataset: Some(d.name.clone()),
                    replica_idx: 0,
                });
                n += 1;
            }
        }
    } else {
        // lines 24-30: one worker per association entry, times replica.
        for assoc in &role.group_association {
            for i in 0..role.replica {
                let compute = registry.decide_compute()?;
                out.push(WorkerConfig {
                    id: format!("{}-{}-{}", spec.name, role.name, n),
                    role: role.name.clone(),
                    compute,
                    channels: resolve_channels(role, assoc, spec),
                    dataset: None,
                    replica_idx: i,
                });
                n += 1;
            }
        }
    }
    Ok(out)
}

/// Algorithm 1's `GetGroupAssocByGroupName(r, g)`: the association entry
/// that places the worker in group `g` on some channel.
fn group_assoc_by_group_name<'a>(
    role: &'a Role,
    group: &str,
) -> Result<&'a BTreeMap<String, String>> {
    let hit = role
        .group_association
        .iter()
        .find(|m| m.values().any(|v| v == group));
    match hit {
        Some(m) => Ok(m),
        None => {
            // Convention: a lone empty entry means "default everywhere".
            if group == "default"
                && role.group_association.len() == 1
                && role.group_association[0].is_empty()
            {
                Ok(&role.group_association[0])
            } else {
                bail!("no entry for group '{group}'")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::Backend;
    use crate::registry::{ComputeSpec, Registry};
    use crate::topo;

    fn single_box() -> Registry {
        Registry::single_box()
    }

    #[test]
    fn expands_paper_figure3_example() {
        // Fig 3: H-FL with datasets A,B in "west" and C,D in "east" ->
        // 4 trainers, 2 aggregators (one per group), 1 global aggregator.
        let spec = topo::hierarchical(4, 2, Backend::Broker).build();
        let w = expand(&spec, &single_box()).unwrap();
        let trainers: Vec<_> = w.iter().filter(|x| x.role == "trainer").collect();
        let aggs: Vec<_> = w.iter().filter(|x| x.role == "aggregator").collect();
        let globals: Vec<_> = w.iter().filter(|x| x.role == "global-aggregator").collect();
        assert_eq!(trainers.len(), 4);
        assert_eq!(aggs.len(), 2);
        assert_eq!(globals.len(), 1);
        // trainers' param-channel groups follow their dataset groups
        let g0 = &trainers[0].channels["param-channel"];
        assert_eq!(g0, "group0");
        // both aggregators share the default agg-channel group
        assert!(aggs.iter().all(|a| a.channels["agg-channel"] == "default"));
        // and sit in different param-channel groups
        assert_ne!(
            aggs[0].channels["param-channel"],
            aggs[1].channels["param-channel"]
        );
    }

    #[test]
    fn replica_creates_copies_sharing_properties() {
        // CO-FL-style: aggregator role with replica=3 in a single group.
        let spec = topo::coordinated(10, 3, Backend::Broker).build();
        let w = expand(&spec, &single_box()).unwrap();
        let aggs: Vec<_> = w.iter().filter(|x| x.role == "aggregator").collect();
        assert_eq!(aggs.len(), 3);
        // replicas share channel groups (paper: copies share properties)
        assert!(aggs
            .windows(2)
            .all(|p| p[0].channels == p[1].channels));
        let idx: Vec<_> = aggs.iter().map(|a| a.replica_idx).collect();
        assert_eq!(idx, vec![0, 1, 2]);
    }

    #[test]
    fn worker_ids_unique_and_deterministic() {
        let spec = topo::classical(5, Backend::Broker).build();
        let a = expand(&spec, &single_box()).unwrap();
        let b = expand(&spec, &single_box()).unwrap();
        assert_eq!(a, b);
        let mut ids: Vec<_> = a.iter().map(|w| w.id.clone()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), a.len());
    }

    #[test]
    fn data_consumer_gets_one_worker_per_dataset() {
        let spec = topo::classical(7, Backend::P2p).build();
        let w = expand(&spec, &single_box()).unwrap();
        let trainers: Vec<_> = w.iter().filter(|x| x.role == "trainer").collect();
        assert_eq!(trainers.len(), 7);
        let mut ds: Vec<_> = trainers.iter().map(|t| t.dataset.clone().unwrap()).collect();
        ds.sort();
        ds.dedup();
        assert_eq!(ds.len(), 7, "each trainer bound to a distinct dataset");
    }

    #[test]
    fn realm_constraints_drive_placement() {
        let mut spec = topo::classical(2, Backend::P2p).build();
        spec.datasets[0].realm = "eu/west".into();
        spec.datasets[1].realm = "us/east".into();
        let mut reg = Registry::new();
        reg.register_compute(ComputeSpec::new("eu-dc", "eu", 10));
        reg.register_compute(ComputeSpec::new("us-dc", "us", 10));
        let w = expand(&spec, &reg).unwrap();
        let t: Vec<_> = w.iter().filter(|x| x.role == "trainer").collect();
        assert_eq!(t[0].compute, "eu-dc");
        assert_eq!(t[1].compute, "us-dc");
    }

    #[test]
    fn unmatchable_realm_fails_expansion() {
        let mut spec = topo::classical(1, Backend::P2p).build();
        spec.datasets[0].realm = "mars".into();
        let mut reg = Registry::new();
        reg.register_compute(ComputeSpec::new("earth", "eu", 10));
        assert!(expand(&spec, &reg).is_err());
    }

    #[test]
    fn missing_group_association_for_dataset_group_fails() {
        let mut spec = topo::hierarchical(4, 2, Backend::Broker).build();
        // orphan a dataset group not covered by trainer's associations
        spec.datasets.push(crate::tag::DatasetRef {
            name: "orphan".into(),
            group: "nowhere".into(),
            realm: "*".into(),
            url: "synth://x".into(),
        });
        assert!(expand(&spec, &single_box()).is_err());
    }

    #[test]
    fn worker_config_json_roundtrip() {
        let spec = topo::hierarchical(4, 2, Backend::Broker).build();
        let w = expand(&spec, &single_box()).unwrap();
        for cfg in &w {
            let back = WorkerConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(&back, cfg);
        }
    }
}
