//! TAG validation: the `PreCheck` / `PostCheck` of Algorithm 1, plus the
//! flavour resolution that feeds the role↔program binding.
//!
//! `PreCheck` validates the logical graph before expansion (structural
//! sanity of roles/channels/attributes, flavour consistency); `PostCheck`
//! validates the expanded physical deployment (connectivity of every
//! channel group, id uniqueness, dataset binding). [`infer_flavor`]
//! derives a default [`Flavor`] for specs that do not declare one —
//! binding decisions happen *here*, at validate time, never by sniffing
//! channel names at dispatch time — and [`lint`] surfaces the non-fatal
//! findings the control plane streams as
//! [`EventKind::SpecLint`](crate::notify::EventKind::SpecLint) events.

use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};

use anyhow::{bail, Result};

use super::{Flavor, JobSpec, WorkerConfig};

/// Infer the topology flavour from the TAG's shape. These are exactly the
/// legacy dispatch-time heuristics of the old `roles::build_program`,
/// relocated to validate time so the spec's binding is fixed before any
/// worker exists:
///
/// * a `coordinator` role ⇒ [`Flavor::Coordinated`] (CO-FL, §6.1),
/// * a `ring-channel` next to a `global-aggregator` ⇒ [`Flavor::Hybrid`]
///   (cluster rings + delegate uploads, §6.2),
/// * a single (self-paired) role ⇒ [`Flavor::Distributed`],
/// * `hyper.aggregation: fedbuff` ⇒ [`Flavor::Async`],
/// * anything else ⇒ [`Flavor::Sync`].
pub fn infer_flavor(spec: &JobSpec) -> Flavor {
    let aggregation = spec.hyper.get("aggregation").as_str();
    if spec.role("coordinator").is_some() {
        Flavor::Coordinated
    } else if spec.channel("ring-channel").is_some() && spec.role("global-aggregator").is_some() {
        Flavor::Hybrid
    } else if spec.roles.len() == 1 {
        Flavor::Distributed
    } else if matches!(aggregation, Some("fedbuff") | Some("async")) {
        Flavor::Async
    } else {
        Flavor::Sync
    }
}

/// Non-fatal spec findings. The control plane emits one
/// [`EventKind::SpecLint`](crate::notify::EventKind::SpecLint) event per
/// entry at submit.
pub fn lint(spec: &JobSpec) -> Vec<String> {
    let mut warnings = Vec::new();
    if spec.flavor.is_none() {
        warnings.push(format!(
            "spec '{}' declares no tag.flavor; inferred '{}' from the TAG shape — \
             declare it explicitly to pin the role\u{2194}program binding",
            spec.name,
            infer_flavor(spec).name()
        ));
    }
    warnings
}

/// Structural validation of the logical TAG (Algorithm 1 line 3).
pub fn pre_check(spec: &JobSpec) -> Result<()> {
    if spec.roles.is_empty() {
        bail!("TAG has no roles");
    }
    // unique names
    let mut seen = HashSet::new();
    for r in &spec.roles {
        if !seen.insert(&r.name) {
            bail!("duplicate role '{}'", r.name);
        }
    }
    let mut seen = HashSet::new();
    for c in &spec.channels {
        if !seen.insert(&c.name) {
            bail!("duplicate channel '{}'", c.name);
        }
    }
    // channel endpoints must exist
    for c in &spec.channels {
        for endpoint in [&c.pair.0, &c.pair.1] {
            if spec.role(endpoint).is_none() {
                bail!("channel '{}' references unknown role '{endpoint}'", c.name);
            }
        }
    }
    // every role must sit on at least one channel
    for r in &spec.roles {
        if spec.channels_of(&r.name).is_empty() {
            bail!("role '{}' is not connected to any channel", r.name);
        }
    }
    // groupAssociation keys must be channels of the role; group values must
    // be allowed by the channel's groupBy (when declared)
    for r in &spec.roles {
        let my_channels: BTreeSet<&str> = spec
            .channels_of(&r.name)
            .iter()
            .map(|c| c.name.as_str())
            .collect();
        for (i, entry) in r.group_association.iter().enumerate() {
            for (ch, group) in entry {
                if !my_channels.contains(ch.as_str()) {
                    bail!(
                        "role '{}' groupAssociation[{i}] names channel '{ch}' the role is not an endpoint of",
                        r.name
                    );
                }
                let chan = spec.channel(ch).unwrap();
                if !chan.group_by.is_empty() && !chan.group_by.contains(group) {
                    bail!(
                        "role '{}' groupAssociation[{i}]: group '{group}' not in channel '{ch}' groupBy {:?}",
                        r.name,
                        chan.group_by
                    );
                }
            }
        }
        // replica only meaningful for non-consumers (consumers scale by datasets)
        if r.is_data_consumer && r.replica != 1 {
            bail!(
                "role '{}' is a data consumer; scale it with datasets, not replica",
                r.name
            );
        }
    }
    // Flavour consistency. Declaration-vs-spec checks apply only when the
    // spec declares a flavour; the program-precondition (shape) checks run
    // on the *resolved* flavour — declared or inferred — so a binding
    // whose channels can't exist fails here, at submit, never in pods.
    if let Some(declared) = spec.flavor {
        if declared == Flavor::Coordinated && spec.role("coordinator").is_none() {
            bail!("flavor 'coordinated' requires a 'coordinator' role");
        }
        if declared != Flavor::Coordinated && spec.role("coordinator").is_some() {
            bail!(
                "TAG has a 'coordinator' role but declares flavor '{}'; \
                 coordinated specs must declare (or infer) flavor 'coordinated'",
                declared.name()
            );
        }
        // the declared flavour must agree with the aggregation policy:
        // execution keys off hyper.aggregation, so a contradiction would
        // silently run the other protocol
        let async_hyper = matches!(
            spec.hyper.get("aggregation").as_str(),
            Some("fedbuff") | Some("async")
        );
        if declared == Flavor::Async && !async_hyper {
            bail!("flavor 'async' requires hyper.aggregation \"fedbuff\"");
        }
        if async_hyper && declared != Flavor::Async {
            bail!(
                "hyper.aggregation \"fedbuff\" contradicts declared flavor '{}'; \
                 declare flavor 'async' (or omit it and let inference pick)",
                declared.name()
            );
        }
    }
    let resolved = spec.resolved_flavor();
    if matches!(resolved, Flavor::Hybrid | Flavor::Distributed) {
        // the built-in ring programs join the channel by this exact
        // name, so a looser check would pass submit and fail pods
        let ring_ok = spec
            .channel("ring-channel")
            .map(|c| c.pair.0 == c.pair.1)
            .unwrap_or(false);
        if !ring_ok {
            bail!(
                "flavor '{}' requires a self-paired channel named 'ring-channel' \
                 (the ring the built-in programs join)",
                resolved.name()
            );
        }
    }
    if resolved == Flavor::Hybrid
        && (spec.role("global-aggregator").is_none()
            || spec.channel("param-channel").is_none())
    {
        // the hybrid trainer uploads to the global over this channel;
        // without them every trainer pod would fail at its first fetch
        bail!(
            "flavor 'hybrid' requires a 'global-aggregator' role and a \
             'param-channel' upload channel"
        );
    }
    if resolved == Flavor::Distributed && spec.roles.len() != 1 {
        bail!(
            "flavor 'distributed' requires a single self-paired role \
             (no aggregator tier; other roles would run unrelated protocols)"
        );
    }
    // a data consumer must exist iff datasets are declared
    let has_consumer = spec.roles.iter().any(|r| r.is_data_consumer);
    if has_consumer && spec.datasets.is_empty() {
        bail!("TAG has a data-consumer role but the job declares no datasets");
    }
    // dataset names unique
    let mut seen = HashSet::new();
    for d in &spec.datasets {
        if !seen.insert(&d.name) {
            bail!("duplicate dataset '{}'", d.name);
        }
    }
    Ok(())
}

/// Validation of the expanded physical topology (Algorithm 1 line 9).
pub fn post_check(spec: &JobSpec, workers: &[WorkerConfig]) -> Result<()> {
    if workers.is_empty() {
        bail!("expansion produced no workers");
    }
    // unique ids
    let mut ids = HashSet::new();
    for w in workers {
        if !ids.insert(&w.id) {
            bail!("duplicate worker id '{}'", w.id);
        }
    }
    // data consumers carry datasets; others don't
    for w in workers {
        let role = spec.role(&w.role).unwrap();
        if role.is_data_consumer && w.dataset.is_none() {
            bail!("data-consumer worker '{}' has no dataset", w.id);
        }
        if !role.is_data_consumer && w.dataset.is_some() {
            bail!("worker '{}' of non-consumer role carries a dataset", w.id);
        }
    }
    // channel-group connectivity: every (channel, group) that has members
    // must include both endpoint roles (or >=2 members for self-pairs).
    let mut membership: HashMap<(String, String), BTreeMap<String, usize>> = HashMap::new();
    for w in workers {
        for (ch, group) in &w.channels {
            *membership
                .entry((ch.clone(), group.clone()))
                .or_default()
                .entry(w.role.clone())
                .or_insert(0) += 1;
        }
    }
    for ((ch, group), roles) in &membership {
        let chan = spec
            .channel(ch)
            .ok_or_else(|| anyhow::anyhow!("worker references unknown channel '{ch}'"))?;
        let (a, b) = (&chan.pair.0, &chan.pair.1);
        if a == b {
            let n = roles.get(a).copied().unwrap_or(0);
            if n < 2 {
                bail!(
                    "channel '{ch}' group '{group}' is a self-pair of '{a}' but has {n} member(s); need >= 2"
                );
            }
        } else {
            for endpoint in [a, b] {
                if roles.get(endpoint).copied().unwrap_or(0) == 0 {
                    bail!(
                        "channel '{ch}' group '{group}' has no worker of endpoint role '{endpoint}'"
                    );
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::Backend;
    use crate::registry::Registry;
    use crate::tag::expand;
    use crate::topo;

    #[test]
    fn valid_templates_pass_both_checks() {
        for spec in [
            topo::classical(5, Backend::Broker).build(),
            topo::hierarchical(6, 2, Backend::Broker).build(),
            topo::coordinated(10, 2, Backend::Broker).build(),
            topo::hybrid(10, 5, Backend::Broker, Backend::P2p).build(),
            topo::distributed(4, Backend::P2p).build(),
        ] {
            let w = expand(&spec, &Registry::single_box())
                .unwrap_or_else(|e| panic!("{}: {e:#}", spec.name));
            assert!(!w.is_empty());
        }
    }

    #[test]
    fn flavor_inference_matches_template_shapes() {
        use crate::json::Json;
        assert_eq!(
            infer_flavor(&topo::classical(4, Backend::P2p).build()),
            Flavor::Sync
        );
        assert_eq!(
            infer_flavor(&topo::hierarchical(4, 2, Backend::P2p).build()),
            Flavor::Sync
        );
        assert_eq!(
            infer_flavor(&topo::coordinated(10, 2, Backend::P2p).build()),
            Flavor::Coordinated
        );
        assert_eq!(
            infer_flavor(&topo::hybrid(10, 5, Backend::Broker, Backend::P2p).build()),
            Flavor::Hybrid
        );
        assert_eq!(
            infer_flavor(&topo::distributed(4, Backend::P2p).build()),
            Flavor::Distributed
        );
        let async_spec = topo::classical(3, Backend::P2p)
            .set("aggregation", "fedbuff")
            .set("buffer_k", Json::from(2usize))
            .build();
        assert_eq!(infer_flavor(&async_spec), Flavor::Async);
    }

    #[test]
    fn declared_flavor_mismatches_rejected() {
        // coordinated without a coordinator role
        let mut spec = topo::classical(2, Backend::P2p).build();
        spec.flavor = Some(Flavor::Coordinated);
        assert!(pre_check(&spec).is_err());
        // a coordinator role with a non-coordinated declaration
        let mut spec = topo::coordinated(4, 2, Backend::P2p).build();
        spec.flavor = Some(Flavor::Sync);
        assert!(pre_check(&spec).is_err());
        // hybrid/distributed need a ring
        for f in [Flavor::Hybrid, Flavor::Distributed] {
            let mut spec = topo::classical(2, Backend::P2p).build();
            spec.flavor = Some(f);
            assert!(pre_check(&spec).is_err(), "{f:?}");
        }
        // ...and specifically one NAMED 'ring-channel': the built-in ring
        // programs join it by name, so a renamed ring must fail at submit
        let mut spec = topo::hybrid(10, 5, Backend::Broker, Backend::P2p).build();
        spec.flavor = Some(Flavor::Hybrid);
        let ring = spec
            .channels
            .iter_mut()
            .find(|c| c.name == "ring-channel")
            .unwrap();
        ring.name = "cluster-ring".into();
        for r in &mut spec.roles {
            for ga in &mut r.group_association {
                if let Some(g) = ga.remove("ring-channel") {
                    ga.insert("cluster-ring".into(), g);
                }
            }
        }
        assert!(pre_check(&spec).is_err());
        // distributed on a multi-role TAG deploys workers the ring
        // protocol never talks to — rejected
        let mut spec = topo::hybrid(10, 5, Backend::Broker, Backend::P2p).build();
        spec.flavor = Some(Flavor::Distributed);
        assert!(pre_check(&spec).is_err());
        // async must agree with hyper.aggregation, both ways
        let mut spec = topo::classical(2, Backend::P2p).build();
        spec.flavor = Some(Flavor::Async);
        assert!(pre_check(&spec).is_err(), "async without fedbuff");
        let mut spec = topo::classical(2, Backend::P2p)
            .set("aggregation", "fedbuff")
            .build();
        spec.flavor = Some(Flavor::Sync);
        assert!(pre_check(&spec).is_err(), "fedbuff declared sync");
        // consistent declarations pass
        let mut spec = topo::hybrid(10, 5, Backend::Broker, Backend::P2p).build();
        spec.flavor = Some(Flavor::Hybrid);
        pre_check(&spec).unwrap();
        let mut spec = topo::distributed(4, Backend::P2p).build();
        spec.flavor = Some(Flavor::Distributed);
        pre_check(&spec).unwrap();
        let mut spec = topo::classical(2, Backend::P2p)
            .set("aggregation", "fedbuff")
            .build();
        spec.flavor = Some(Flavor::Async);
        pre_check(&spec).unwrap();
    }

    #[test]
    fn inferred_flavor_shape_checks_fail_at_submit_not_in_pods() {
        // a single-role spec whose self-pair channel is NOT named
        // 'ring-channel': inference still picks Distributed, and the
        // distributed trainer would fail joining the missing ring in
        // every pod — pre_check must reject it up front
        let mut spec = topo::distributed(4, Backend::P2p).build();
        for c in &mut spec.channels {
            c.name = "mesh".into();
        }
        for r in &mut spec.roles {
            for ga in &mut r.group_association {
                if let Some(g) = ga.remove("ring-channel") {
                    ga.insert("mesh".into(), g);
                }
            }
        }
        assert_eq!(infer_flavor(&spec), Flavor::Distributed);
        assert!(pre_check(&spec).is_err());
        // the properly-named template still passes
        pre_check(&topo::distributed(4, Backend::P2p).build()).unwrap();
    }

    #[test]
    fn lint_flags_missing_flavor_only() {
        let spec = topo::classical(2, Backend::P2p).build();
        let warnings = lint(&spec);
        assert_eq!(warnings.len(), 1);
        assert!(warnings[0].contains("tag.flavor"), "{warnings:?}");
        assert!(warnings[0].contains("sync"), "{warnings:?}");
        let mut spec = topo::classical(2, Backend::P2p).build();
        spec.flavor = Some(Flavor::Sync);
        assert!(lint(&spec).is_empty());
    }

    #[test]
    fn duplicate_role_rejected() {
        let mut spec = topo::classical(2, Backend::P2p).build();
        let dup = spec.roles[0].clone();
        spec.roles.push(dup);
        assert!(pre_check(&spec).is_err());
    }

    #[test]
    fn unknown_channel_endpoint_rejected() {
        let mut spec = topo::classical(2, Backend::P2p).build();
        spec.channels[0].pair.1 = "ghost".into();
        assert!(pre_check(&spec).is_err());
    }

    #[test]
    fn disconnected_role_rejected() {
        let mut spec = topo::classical(2, Backend::P2p).build();
        spec.channels.clear();
        assert!(pre_check(&spec).is_err());
    }

    #[test]
    fn group_outside_groupby_rejected() {
        let mut spec = topo::hierarchical(4, 2, Backend::Broker).build();
        // channel declares groupBy [group0, group1]; claim "group9"
        spec.roles[0].group_association[0]
            .insert("param-channel".into(), "group9".into());
        assert!(pre_check(&spec).is_err());
    }

    #[test]
    fn consumer_with_replica_rejected() {
        let mut spec = topo::classical(2, Backend::P2p).build();
        spec.roles
            .iter_mut()
            .find(|r| r.is_data_consumer)
            .unwrap()
            .replica = 3;
        assert!(pre_check(&spec).is_err());
    }

    #[test]
    fn consumer_without_datasets_rejected() {
        let mut spec = topo::classical(2, Backend::P2p).build();
        spec.datasets.clear();
        assert!(pre_check(&spec).is_err());
    }

    #[test]
    fn post_check_catches_empty_group() {
        let spec = topo::hierarchical(4, 2, Backend::Broker).build();
        let mut w = expand(&spec, &Registry::single_box()).unwrap();
        // delete all trainers of group1 -> aggregator of group1 is orphaned
        w.retain(|x| {
            !(x.role == "trainer" && x.channels["param-channel"] == "group1")
        });
        assert!(post_check(&spec, &w).is_err());
    }

    #[test]
    fn post_check_catches_duplicate_ids() {
        let spec = topo::classical(2, Backend::P2p).build();
        let w = expand(&spec, &Registry::single_box()).unwrap();
        let mut dup = w.clone();
        dup.push(w[0].clone());
        assert!(post_check(&spec, &dup).is_err());
    }

    #[test]
    fn post_check_self_pair_needs_two() {
        let spec = topo::distributed(1, Backend::P2p).build();
        // one trainer on a trainer-trainer channel cannot form a topology
        assert!(expand(&spec, &Registry::single_box()).is_err());
    }
}
