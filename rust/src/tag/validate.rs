//! TAG validation: the `PreCheck` / `PostCheck` of Algorithm 1.
//!
//! `PreCheck` validates the logical graph before expansion (structural
//! sanity of roles/channels/attributes); `PostCheck` validates the expanded
//! physical deployment (connectivity of every channel group, id uniqueness,
//! dataset binding).

use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};

use anyhow::{bail, Result};

use super::{JobSpec, WorkerConfig};

/// Structural validation of the logical TAG (Algorithm 1 line 3).
pub fn pre_check(spec: &JobSpec) -> Result<()> {
    if spec.roles.is_empty() {
        bail!("TAG has no roles");
    }
    // unique names
    let mut seen = HashSet::new();
    for r in &spec.roles {
        if !seen.insert(&r.name) {
            bail!("duplicate role '{}'", r.name);
        }
    }
    let mut seen = HashSet::new();
    for c in &spec.channels {
        if !seen.insert(&c.name) {
            bail!("duplicate channel '{}'", c.name);
        }
    }
    // channel endpoints must exist
    for c in &spec.channels {
        for endpoint in [&c.pair.0, &c.pair.1] {
            if spec.role(endpoint).is_none() {
                bail!("channel '{}' references unknown role '{endpoint}'", c.name);
            }
        }
    }
    // every role must sit on at least one channel
    for r in &spec.roles {
        if spec.channels_of(&r.name).is_empty() {
            bail!("role '{}' is not connected to any channel", r.name);
        }
    }
    // groupAssociation keys must be channels of the role; group values must
    // be allowed by the channel's groupBy (when declared)
    for r in &spec.roles {
        let my_channels: BTreeSet<&str> = spec
            .channels_of(&r.name)
            .iter()
            .map(|c| c.name.as_str())
            .collect();
        for (i, entry) in r.group_association.iter().enumerate() {
            for (ch, group) in entry {
                if !my_channels.contains(ch.as_str()) {
                    bail!(
                        "role '{}' groupAssociation[{i}] names channel '{ch}' the role is not an endpoint of",
                        r.name
                    );
                }
                let chan = spec.channel(ch).unwrap();
                if !chan.group_by.is_empty() && !chan.group_by.contains(group) {
                    bail!(
                        "role '{}' groupAssociation[{i}]: group '{group}' not in channel '{ch}' groupBy {:?}",
                        r.name,
                        chan.group_by
                    );
                }
            }
        }
        // replica only meaningful for non-consumers (consumers scale by datasets)
        if r.is_data_consumer && r.replica != 1 {
            bail!(
                "role '{}' is a data consumer; scale it with datasets, not replica",
                r.name
            );
        }
    }
    // a data consumer must exist iff datasets are declared
    let has_consumer = spec.roles.iter().any(|r| r.is_data_consumer);
    if has_consumer && spec.datasets.is_empty() {
        bail!("TAG has a data-consumer role but the job declares no datasets");
    }
    // dataset names unique
    let mut seen = HashSet::new();
    for d in &spec.datasets {
        if !seen.insert(&d.name) {
            bail!("duplicate dataset '{}'", d.name);
        }
    }
    Ok(())
}

/// Validation of the expanded physical topology (Algorithm 1 line 9).
pub fn post_check(spec: &JobSpec, workers: &[WorkerConfig]) -> Result<()> {
    if workers.is_empty() {
        bail!("expansion produced no workers");
    }
    // unique ids
    let mut ids = HashSet::new();
    for w in workers {
        if !ids.insert(&w.id) {
            bail!("duplicate worker id '{}'", w.id);
        }
    }
    // data consumers carry datasets; others don't
    for w in workers {
        let role = spec.role(&w.role).unwrap();
        if role.is_data_consumer && w.dataset.is_none() {
            bail!("data-consumer worker '{}' has no dataset", w.id);
        }
        if !role.is_data_consumer && w.dataset.is_some() {
            bail!("worker '{}' of non-consumer role carries a dataset", w.id);
        }
    }
    // channel-group connectivity: every (channel, group) that has members
    // must include both endpoint roles (or >=2 members for self-pairs).
    let mut membership: HashMap<(String, String), BTreeMap<String, usize>> = HashMap::new();
    for w in workers {
        for (ch, group) in &w.channels {
            *membership
                .entry((ch.clone(), group.clone()))
                .or_default()
                .entry(w.role.clone())
                .or_insert(0) += 1;
        }
    }
    for ((ch, group), roles) in &membership {
        let chan = spec
            .channel(ch)
            .ok_or_else(|| anyhow::anyhow!("worker references unknown channel '{ch}'"))?;
        let (a, b) = (&chan.pair.0, &chan.pair.1);
        if a == b {
            let n = roles.get(a).copied().unwrap_or(0);
            if n < 2 {
                bail!(
                    "channel '{ch}' group '{group}' is a self-pair of '{a}' but has {n} member(s); need >= 2"
                );
            }
        } else {
            for endpoint in [a, b] {
                if roles.get(endpoint).copied().unwrap_or(0) == 0 {
                    bail!(
                        "channel '{ch}' group '{group}' has no worker of endpoint role '{endpoint}'"
                    );
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::Backend;
    use crate::registry::Registry;
    use crate::tag::expand;
    use crate::topo;

    #[test]
    fn valid_templates_pass_both_checks() {
        for spec in [
            topo::classical(5, Backend::Broker).build(),
            topo::hierarchical(6, 2, Backend::Broker).build(),
            topo::coordinated(10, 2, Backend::Broker).build(),
            topo::hybrid(10, 5, Backend::Broker, Backend::P2p).build(),
            topo::distributed(4, Backend::P2p).build(),
        ] {
            let w = expand(&spec, &Registry::single_box())
                .unwrap_or_else(|e| panic!("{}: {e:#}", spec.name));
            assert!(!w.is_empty());
        }
    }

    #[test]
    fn duplicate_role_rejected() {
        let mut spec = topo::classical(2, Backend::P2p).build();
        let dup = spec.roles[0].clone();
        spec.roles.push(dup);
        assert!(pre_check(&spec).is_err());
    }

    #[test]
    fn unknown_channel_endpoint_rejected() {
        let mut spec = topo::classical(2, Backend::P2p).build();
        spec.channels[0].pair.1 = "ghost".into();
        assert!(pre_check(&spec).is_err());
    }

    #[test]
    fn disconnected_role_rejected() {
        let mut spec = topo::classical(2, Backend::P2p).build();
        spec.channels.clear();
        assert!(pre_check(&spec).is_err());
    }

    #[test]
    fn group_outside_groupby_rejected() {
        let mut spec = topo::hierarchical(4, 2, Backend::Broker).build();
        // channel declares groupBy [group0, group1]; claim "group9"
        spec.roles[0].group_association[0]
            .insert("param-channel".into(), "group9".into());
        assert!(pre_check(&spec).is_err());
    }

    #[test]
    fn consumer_with_replica_rejected() {
        let mut spec = topo::classical(2, Backend::P2p).build();
        spec.roles
            .iter_mut()
            .find(|r| r.is_data_consumer)
            .unwrap()
            .replica = 3;
        assert!(pre_check(&spec).is_err());
    }

    #[test]
    fn consumer_without_datasets_rejected() {
        let mut spec = topo::classical(2, Backend::P2p).build();
        spec.datasets.clear();
        assert!(pre_check(&spec).is_err());
    }

    #[test]
    fn post_check_catches_empty_group() {
        let spec = topo::hierarchical(4, 2, Backend::Broker).build();
        let mut w = expand(&spec, &Registry::single_box()).unwrap();
        // delete all trainers of group1 -> aggregator of group1 is orphaned
        w.retain(|x| {
            !(x.role == "trainer" && x.channels["param-channel"] == "group1")
        });
        assert!(post_check(&spec, &w).is_err());
    }

    #[test]
    fn post_check_catches_duplicate_ids() {
        let spec = topo::classical(2, Backend::P2p).build();
        let w = expand(&spec, &Registry::single_box()).unwrap();
        let mut dup = w.clone();
        dup.push(w[0].clone());
        assert!(post_check(&spec, &dup).is_err());
    }

    #[test]
    fn post_check_self_pair_needs_two() {
        let spec = topo::distributed(1, Backend::P2p).build();
        // one trainer on a trainer-trainer channel cannot form a topology
        assert!(expand(&spec, &Registry::single_box()).is_err());
    }
}
