//! TAG deltas — live topology extension (the paper's title claim, §6).
//!
//! A static reproduction expands a TAG once and freezes the worker set;
//! this module makes the *extension* part of "Simplifying Topology
//! Extension" executable. Two layers:
//!
//! * [`TagDelta`] — a **spec-level** edit: roles/channels/datasets to add
//!   or remove. `delta.apply(spec)` produces the extended [`JobSpec`]
//!   (re-validated by `PreCheck`), and [`TagDelta::diff`] recovers the
//!   delta between two specs. Deltas are what a [`TopologyEvent`] carries
//!   through a job's event timeline.
//! * [`WorkerDelta`] — a **worker-level** patch between two expansions:
//!   `diff_workers(expand(a), expand(b))` lists exactly the
//!   [`WorkerConfig`]s to deploy and the worker ids to retire, and
//!   [`apply_workers`] reconstructs `expand(b)` from `expand(a)` plus the
//!   patch (property-tested in `rust/tests/properties.rs`). The
//!   controller resolves each timeline event into such a patch at submit
//!   time, so mid-run extension never re-runs Algorithm 1 on the fabric's
//!   critical path.
//!
//! The patch identity `expand(b) == apply_workers(expand(a), diff)` holds
//! because Algorithm 1 is deterministic and role-major: workers common to
//! both expansions (identical id, placement, channel groups, dataset)
//! keep their relative order, so a positional insert/remove patch is
//! exact.
//!
//! # Event timeline JSON
//!
//! Job specs may carry an `events` array (see [`TopologyEvent`]): each
//! entry fires at a virtual timestamp `at_us` once the running job's
//! clock passes it. Supported kinds:
//!
//! ```json
//! {"kind": "extend", "at_us": 2000000, "delta": {
//!     "addRoles": [...], "addChannels": [...], "addDatasets": [...],
//!     "removeRoles": [...], "removeChannels": [...], "removeDatasets": [...]
//! }}
//! {"kind": "leave", "at_us": 3500000, "workers": ["job-trainer-3"]}
//! ```
//!
//! A *join* (growing the trainer population) is an `extend` whose delta
//! adds datasets: Algorithm 1 expands one data-consumer worker per
//! dataset, so new datasets become new trainers.
//!
//! ```
//! use flame::tag::delta::TopologyEvent;
//! let ev = TopologyEvent::from_json(
//!     &flame::json::Json::parse(
//!         r#"{"kind": "leave", "at_us": 1500, "workers": ["j-trainer-0"]}"#,
//!     )
//!     .unwrap(),
//! )
//! .unwrap();
//! assert_eq!(ev.at_us(), 1500);
//! let back = TopologyEvent::from_json(&ev.to_json()).unwrap();
//! assert_eq!(back.at_us(), 1500);
//! ```

use anyhow::{bail, Context, Result};

use crate::json::Json;
use crate::net::VTime;
use crate::registry::Registry;

use super::expand::{expand, WorkerConfig};
use super::{
    channel_to_json, dataset_to_json, parse_channel, parse_dataset, parse_role, role_to_json,
    Channel, DatasetRef, JobSpec, Role,
};

// ----------------------------------------------------------- spec deltas

/// A spec-level TAG edit: the difference between two [`JobSpec`]s, or a
/// set of add/remove directives to apply to one. Removals are by name and
/// run before additions, so replacing a role or channel is expressed as
/// `remove_*` + `add_*` of the same name.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TagDelta {
    pub add_roles: Vec<Role>,
    pub add_channels: Vec<Channel>,
    pub add_datasets: Vec<DatasetRef>,
    pub remove_roles: Vec<String>,
    pub remove_channels: Vec<String>,
    pub remove_datasets: Vec<String>,
}

impl TagDelta {
    pub fn is_empty(&self) -> bool {
        self.add_roles.is_empty()
            && self.add_channels.is_empty()
            && self.add_datasets.is_empty()
            && self.remove_roles.is_empty()
            && self.remove_channels.is_empty()
            && self.remove_datasets.is_empty()
    }

    /// Apply this delta to `spec`, producing the extended spec. The result
    /// is re-validated with Algorithm 1's `PreCheck`; an edit that leaves
    /// the TAG inconsistent (dangling endpoint, orphaned role) is an
    /// error, not a deployable spec.
    pub fn apply(&self, spec: &JobSpec) -> Result<JobSpec> {
        let mut out = spec.clone();
        out.roles.retain(|r| !self.remove_roles.contains(&r.name));
        out.channels
            .retain(|c| !self.remove_channels.contains(&c.name));
        out.datasets
            .retain(|d| !self.remove_datasets.contains(&d.name));
        out.roles.extend(self.add_roles.iter().cloned());
        out.channels.extend(self.add_channels.iter().cloned());
        out.datasets.extend(self.add_datasets.iter().cloned());
        // the derived spec is a plain TAG; it does not inherit the timeline
        out.events.clear();
        super::validate::pre_check(&out).context("delta produces an invalid TAG")?;
        Ok(out)
    }

    /// The delta turning `a` into `b`: entries of `a` missing from (or
    /// changed in) `b` are removals; entries of `b` not identically in `a`
    /// are additions. `diff(a, b).apply(a)` reproduces `b` up to ordering
    /// of replaced entries.
    pub fn diff(a: &JobSpec, b: &JobSpec) -> TagDelta {
        let mut d = TagDelta::default();
        for r in &a.roles {
            if b.role(&r.name) != Some(r) {
                d.remove_roles.push(r.name.clone());
            }
        }
        for r in &b.roles {
            if a.role(&r.name) != Some(r) {
                d.add_roles.push(r.clone());
            }
        }
        for c in &a.channels {
            if b.channel(&c.name) != Some(c) {
                d.remove_channels.push(c.name.clone());
            }
        }
        for c in &b.channels {
            if a.channel(&c.name) != Some(c) {
                d.add_channels.push(c.clone());
            }
        }
        let find = |spec: &JobSpec, name: &str| -> Option<DatasetRef> {
            spec.datasets.iter().find(|d| d.name == name).cloned()
        };
        for ds in &a.datasets {
            if find(b, &ds.name).as_ref() != Some(ds) {
                d.remove_datasets.push(ds.name.clone());
            }
        }
        for ds in &b.datasets {
            if find(a, &ds.name).as_ref() != Some(ds) {
                d.add_datasets.push(ds.clone());
            }
        }
        d
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        if !self.add_roles.is_empty() {
            o.insert(
                "addRoles",
                Json::Arr(self.add_roles.iter().map(role_to_json).collect()),
            );
        }
        if !self.add_channels.is_empty() {
            o.insert(
                "addChannels",
                Json::Arr(self.add_channels.iter().map(channel_to_json).collect()),
            );
        }
        if !self.add_datasets.is_empty() {
            o.insert(
                "addDatasets",
                Json::Arr(self.add_datasets.iter().map(dataset_to_json).collect()),
            );
        }
        let names = |xs: &[String]| Json::Arr(xs.iter().map(|n| Json::Str(n.clone())).collect());
        if !self.remove_roles.is_empty() {
            o.insert("removeRoles", names(&self.remove_roles));
        }
        if !self.remove_channels.is_empty() {
            o.insert("removeChannels", names(&self.remove_channels));
        }
        if !self.remove_datasets.is_empty() {
            o.insert("removeDatasets", names(&self.remove_datasets));
        }
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut d = TagDelta::default();
        if let Some(arr) = j.get("addRoles").as_arr() {
            for (i, r) in arr.iter().enumerate() {
                d.add_roles
                    .push(parse_role(r).with_context(|| format!("delta addRoles[{i}]"))?);
            }
        }
        if let Some(arr) = j.get("addChannels").as_arr() {
            for (i, c) in arr.iter().enumerate() {
                d.add_channels
                    .push(parse_channel(c).with_context(|| format!("delta addChannels[{i}]"))?);
            }
        }
        if let Some(arr) = j.get("addDatasets").as_arr() {
            for (i, ds) in arr.iter().enumerate() {
                d.add_datasets
                    .push(parse_dataset(ds).with_context(|| format!("delta addDatasets[{i}]"))?);
            }
        }
        let names = |key: &str| -> Vec<String> {
            j.get(key)
                .as_arr()
                .map(|a| a.iter().filter_map(|n| n.as_str().map(str::to_string)).collect())
                .unwrap_or_default()
        };
        d.remove_roles = names("removeRoles");
        d.remove_channels = names("removeChannels");
        d.remove_datasets = names("removeDatasets");
        Ok(d)
    }
}

// --------------------------------------------------------- event timeline

/// One scheduled topology change on a running job, firing when the job's
/// virtual clock reaches `at_us`. Events are applied at round boundaries
/// by the round-driving aggregator (see `roles::global`), which keeps
/// membership changes synchronous with the round structure and therefore
/// deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum TopologyEvent {
    /// Extend (or shrink) the TAG by a delta: new roles/channels deploy as
    /// fresh workers on the running fabric; removed entries retire theirs.
    Extend { at_us: VTime, delta: TagDelta },
    /// Named workers depart (device dropout / churn). The spec is
    /// unchanged — this is physical-membership churn, not a TAG edit.
    Leave { at_us: VTime, workers: Vec<String> },
}

impl TopologyEvent {
    pub fn at_us(&self) -> VTime {
        match self {
            TopologyEvent::Extend { at_us, .. } | TopologyEvent::Leave { at_us, .. } => *at_us,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        match self {
            TopologyEvent::Extend { at_us, delta } => {
                o.insert("kind", "extend");
                o.insert("at_us", *at_us);
                o.insert("delta", delta.to_json());
            }
            TopologyEvent::Leave { at_us, workers } => {
                o.insert("kind", "leave");
                o.insert("at_us", *at_us);
                o.insert(
                    "workers",
                    Json::Arr(workers.iter().map(|w| Json::Str(w.clone())).collect()),
                );
            }
        }
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let at_us = j.get("at_us").as_i64().context("event missing 'at_us'")? as VTime;
        match j.get("kind").as_str().context("event missing 'kind'")? {
            "extend" => Ok(TopologyEvent::Extend {
                at_us,
                delta: TagDelta::from_json(j.get("delta")).context("extend event delta")?,
            }),
            "leave" => {
                let workers: Vec<String> = j
                    .get("workers")
                    .as_arr()
                    .context("leave event missing 'workers'")?
                    .iter()
                    .filter_map(|w| w.as_str().map(str::to_string))
                    .collect();
                if workers.is_empty() {
                    bail!("leave event names no workers");
                }
                Ok(TopologyEvent::Leave { at_us, workers })
            }
            other => bail!("unknown event kind '{other}' (extend|leave)"),
        }
    }
}

// --------------------------------------------------------- worker deltas

/// Worker-level patch between two expansions: configs to deploy (with
/// their positions in the target expansion) and worker ids to retire.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WorkerDelta {
    /// `(position in the target expansion, config)`, ascending by position.
    pub add: Vec<(usize, WorkerConfig)>,
    /// Ids present in the source expansion but not (identically) in the
    /// target.
    pub remove: Vec<String>,
}

/// Patch turning worker list `a` into worker list `b`. Workers are
/// matched by full config identity; a worker whose config changed appears
/// in both `remove` (old id) and `add` (new config). Linear in
/// `|a| + |b|` — ids are unique within an expansion, so an identical
/// config can only sit under the same id, and the match indexes by id.
pub fn diff_workers(a: &[WorkerConfig], b: &[WorkerConfig]) -> WorkerDelta {
    let a_by_id: std::collections::HashMap<&str, &WorkerConfig> =
        a.iter().map(|w| (w.id.as_str(), w)).collect();
    let b_by_id: std::collections::HashMap<&str, &WorkerConfig> =
        b.iter().map(|w| (w.id.as_str(), w)).collect();
    let mut d = WorkerDelta::default();
    for w in a {
        if b_by_id.get(w.id.as_str()) != Some(&w) {
            d.remove.push(w.id.clone());
        }
    }
    for (i, w) in b.iter().enumerate() {
        if a_by_id.get(w.id.as_str()) != Some(&w) {
            d.add.push((i, w.clone()));
        }
    }
    d
}

/// Apply a [`diff_workers`] patch: `apply_workers(a, &diff_workers(a, b))
/// == b` whenever the common workers keep their relative order — which
/// every [`TagDelta`]-induced pair does, because Algorithm 1 expands
/// role-major in stable order.
pub fn apply_workers(a: &[WorkerConfig], d: &WorkerDelta) -> Vec<WorkerConfig> {
    let removed: std::collections::HashSet<&str> =
        d.remove.iter().map(String::as_str).collect();
    let mut out: Vec<WorkerConfig> = a
        .iter()
        .filter(|w| !removed.contains(w.id.as_str()))
        .cloned()
        .collect();
    for (i, w) in &d.add {
        out.insert((*i).min(out.len()), w.clone());
    }
    out
}

/// Expand both specs against `registry` and diff the expansions: the
/// incremental-deploy work list for extending a running `before` job into
/// `after`.
pub fn delta_workers(
    before: &JobSpec,
    after: &JobSpec,
    registry: &Registry,
) -> Result<WorkerDelta> {
    let a = expand(before, registry).context("expanding pre-extension spec")?;
    let b = expand(after, registry).context("expanding post-extension spec")?;
    Ok(diff_workers(&a, &b))
}

// ------------------------------------------------- canned extension moves

/// The §6 "add a middle aggregator tier" story as a delta: turns a 2-tier
/// `trainer ↔ global-aggregator` TAG (the [`crate::topo::classical`]
/// shape) into a 3-tier H-FL TAG by inserting an `aggregator` role with
/// `replica` copies between the tiers. The trainer-facing channel keeps
/// its name and groups, so live trainers need no re-join — they pick up
/// their new parent from the next round's weight distribution.
pub fn add_tier_delta(spec: &JobSpec, n_aggregators: usize) -> Result<TagDelta> {
    if n_aggregators == 0 {
        bail!("add_tier_delta needs at least one aggregator");
    }
    if spec.role("aggregator").is_some() {
        bail!("spec already has an 'aggregator' role");
    }
    let param = spec
        .channel("param-channel")
        .context("add_tier_delta expects a 'param-channel'")?;
    let trainer = spec
        .roles
        .iter()
        .find(|r| r.is_data_consumer)
        .context("add_tier_delta expects a data-consumer role")?
        .name
        .clone();
    let global = if param.pair.0 == trainer {
        param.pair.1.clone()
    } else {
        param.pair.0.clone()
    };
    let mut ft = std::collections::BTreeMap::new();
    ft.insert(trainer.clone(), vec!["fetch".to_string(), "upload".into()]);
    ft.insert(
        "aggregator".to_string(),
        vec!["distribute".to_string(), "aggregate".into()],
    );
    let new_param = Channel {
        name: "param-channel".into(),
        pair: (trainer, "aggregator".into()),
        group_by: param.group_by.clone(),
        func_tags: ft,
        backend: param.backend,
        substrate: param.substrate.clone(),
    };
    let mut ft = std::collections::BTreeMap::new();
    ft.insert(
        "aggregator".to_string(),
        vec!["fetch".to_string(), "upload".into()],
    );
    ft.insert(
        global.clone(),
        vec!["distribute".to_string(), "aggregate".into()],
    );
    let agg_channel = Channel {
        name: "agg-channel".into(),
        pair: ("aggregator".into(), global.clone()),
        group_by: vec!["default".to_string()],
        func_tags: ft,
        backend: param.backend,
        substrate: param.substrate.clone(),
    };
    let global_role = spec.role(&global).context("param-channel upper endpoint role")?;
    let mut new_global = global_role.clone();
    new_global.group_association = vec![[("agg-channel".to_string(), "default".to_string())]
        .into_iter()
        .collect()];
    let agg_role = Role {
        name: "aggregator".into(),
        replica: n_aggregators,
        is_data_consumer: false,
        group_association: vec![[
            ("param-channel".to_string(), "default".to_string()),
            ("agg-channel".to_string(), "default".to_string()),
        ]
        .into_iter()
        .collect()],
        program: None,
    };
    Ok(TagDelta {
        add_roles: vec![new_global, agg_role],
        add_channels: vec![new_param, agg_channel],
        add_datasets: Vec::new(),
        remove_roles: vec![global],
        remove_channels: vec!["param-channel".into()],
        remove_datasets: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::Backend;
    use crate::topo;

    #[test]
    fn diff_apply_roundtrips_spec() {
        let a = topo::classical(4, Backend::P2p).build();
        let b = {
            let mut b = a.clone();
            b.datasets.push(DatasetRef {
                name: "extra".into(),
                group: "default".into(),
                realm: "*".into(),
                url: "synth://extra".into(),
            });
            b
        };
        let d = TagDelta::diff(&a, &b);
        assert_eq!(d.add_datasets.len(), 1);
        assert!(d.remove_datasets.is_empty() && d.add_roles.is_empty());
        let b2 = d.apply(&a).unwrap();
        assert_eq!(b2.datasets.len(), b.datasets.len());
        assert_eq!(TagDelta::diff(&b2, &b), TagDelta::default());
    }

    #[test]
    fn add_tier_delta_builds_valid_three_tier_spec() {
        let a = topo::classical(6, Backend::P2p).build();
        let d = add_tier_delta(&a, 2).unwrap();
        let b = d.apply(&a).unwrap();
        assert!(b.role("aggregator").is_some());
        assert!(b.channel("agg-channel").is_some());
        assert_eq!(
            b.channel("param-channel").unwrap().pair,
            ("trainer".to_string(), "aggregator".to_string())
        );
        let reg = Registry::single_box();
        let wa = expand(&a, &reg).unwrap();
        let wb = expand(&b, &reg).unwrap();
        // trainers are untouched; the tier shows up as new workers
        assert_eq!(wb.iter().filter(|w| w.role == "aggregator").count(), 2);
        let wd = diff_workers(&wa, &wb);
        assert_eq!(apply_workers(&wa, &wd), wb);
        // the global's config changes (its channel set moved to agg-channel)
        assert!(wd.remove.iter().any(|id| id.contains("global-aggregator")));
    }

    #[test]
    fn worker_patch_handles_removals() {
        let reg = Registry::single_box();
        let a = topo::classical(5, Backend::P2p).build();
        let mut b = a.clone();
        b.datasets.remove(1); // drop one trainer's dataset
        let wa = expand(&a, &reg).unwrap();
        let wb = expand(&b, &reg).unwrap();
        let d = diff_workers(&wa, &wb);
        assert_eq!(apply_workers(&wa, &d), wb);
        assert!(!d.remove.is_empty());
    }

    #[test]
    fn delta_json_roundtrip() {
        let a = topo::classical(3, Backend::Broker).build();
        let d = add_tier_delta(&a, 3).unwrap();
        let back = TagDelta::from_json(&d.to_json()).unwrap();
        assert_eq!(back, d);
    }

    #[test]
    fn event_json_roundtrip_and_validation() {
        let a = topo::classical(3, Backend::P2p).build();
        let ev = TopologyEvent::Extend {
            at_us: 42,
            delta: add_tier_delta(&a, 1).unwrap(),
        };
        assert_eq!(TopologyEvent::from_json(&ev.to_json()).unwrap(), ev);
        let ev = TopologyEvent::Leave {
            at_us: 7,
            workers: vec!["cfl-trainer-0".into()],
        };
        assert_eq!(TopologyEvent::from_json(&ev.to_json()).unwrap(), ev);
        assert!(TopologyEvent::from_json(
            &Json::parse(r#"{"kind":"leave","at_us":1,"workers":[]}"#).unwrap()
        )
        .is_err());
        assert!(TopologyEvent::from_json(
            &Json::parse(r#"{"kind":"teleport","at_us":1}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn invalid_delta_rejected_by_precheck() {
        let a = topo::classical(3, Backend::P2p).build();
        // removing the only channel orphans both roles
        let d = TagDelta {
            remove_channels: vec!["param-channel".into()],
            ..Default::default()
        };
        assert!(d.apply(&a).is_err());
    }
}
