//! Topology Abstraction Graph — the paper's central abstraction (§4.1).
//!
//! A TAG is a logical graph: **roles** are vertices (worker behaviour),
//! **channels** are undirected edges (communication backends). Role
//! attributes `replica`, `isDataConsumer` and `groupAssociation`, plus
//! channel attributes `groupBy`, `funcTags` and `backend`, drive the
//! expansion of the condensed logical graph into the physical deployment
//! topology (Algorithm 1, [`expand`]).
//!
//! Specs are JSON (the paper uses YAML; semantics are identical — see
//! DESIGN.md substitutions). [`JobSpec::parse`] accepts the schema shown in
//! `examples/specs/hfl.json`, which mirrors the paper's Figure 3a.
//!
//! # Spec JSON schema
//!
//! ```json
//! {
//!   "name":   "<job name>",            // required
//!   "model":  "mlp",                   // optional, default "mlp"
//!   "rounds": 10,                      // optional, default 10
//!   "tag": {
//!     "flavor": "sync",                // optional program-binding hint:
//!                                      // sync|coordinated|hybrid|async|distributed
//!                                      // (inferred from the TAG shape when absent,
//!                                      // with a spec-lint warning)
//!     "roles": [{
//!       "name": "trainer",             // required
//!       "replica": 1,                  // optional; workers per association entry
//!       "isDataConsumer": true,        // optional; one worker per dataset
//!       "groupAssociation": [          // optional; {channel -> group} entries
//!         {"param-channel": "group0"}
//!       ],
//!       "program": "fedprox-trainer"   // optional; binds the role to a program
//!                                      // registered in the job's RoleRegistry
//!                                      // (default: the registry's (role, flavor)
//!                                      // binding)
//!     }],
//!     "channels": [{
//!       "name": "param-channel",       // required
//!       "pair": ["trainer", "aggregator"],  // required, exactly 2 roles
//!       "groupBy": ["group0", "group1"],    // optional; default single group
//!       "funcTags": {"trainer": ["fetch", "upload"]},  // optional
//!       "backend": "p2p"               // p2p | broker | inproc (+aliases)
//!     }]
//!   },
//!   "datasets": [{
//!     "name": "d0", "group": "group0", "realm": "*", "url": "synth://0"
//!   }],
//!   "hyper": {"lr": 0.1, "quorum": 0.8},   // forwarded to role programs;
//!                                      // also: "codec" (f32|int8|topk, upload
//!                                      // compression + encoded-byte virtual-time
//!                                      // accounting), "topk_frac" (top-k keep
//!                                      // fraction), "simd" (off|auto|scalar|
//!                                      // portable|avx2 aggregation kernels,
//!                                      // FLAME_SIMD env overrides)
//!   "events": [                        // optional live-extension timeline
//!     {"kind": "extend", "at_us": 2000000, "delta": {"addRoles": [], "addChannels": [], "addDatasets": []}},
//!     {"kind": "leave",  "at_us": 3000000, "workers": ["job-trainer-3"]}
//!   ]
//! }
//! ```
//!
//! The `events` array is the **live topology extension timeline** (see
//! [`delta`]): each entry fires once the running job's virtual clock
//! passes `at_us`, growing or shrinking the deployed topology mid-run.
//!
//! ```
//! let spec = flame::tag::JobSpec::parse(r#"{
//!     "name": "tiny",
//!     "tag": {
//!         "roles": [
//!             {"name": "trainer", "isDataConsumer": true},
//!             {"name": "global-aggregator"}
//!         ],
//!         "channels": [{
//!             "name": "param-channel",
//!             "pair": ["trainer", "global-aggregator"],
//!             "backend": "p2p"
//!         }]
//!     },
//!     "datasets": [{"name": "d0"}],
//!     "events": [{"kind": "leave", "at_us": 90, "workers": ["tiny-trainer-0"]}]
//! }"#).unwrap();
//! assert_eq!(spec.roles.len(), 2);
//! assert_eq!(spec.events.len(), 1);
//! assert_eq!(spec.events[0].at_us(), 90);
//! ```

pub mod delta;
pub mod expand;
pub mod validate;

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::channel::Backend;
use crate::json::Json;

pub use delta::{TagDelta, TopologyEvent, WorkerDelta};
pub use expand::{expand, WorkerConfig};

/// Topology flavour — the spec-level hint (`tag.flavor`) that drives the
/// default role↔program binding in the
/// [`RoleRegistry`](crate::roles::RoleRegistry).
///
/// A spec that omits it keeps working: the flavour is inferred from the
/// TAG's shape at validate time ([`validate::infer_flavor`]) and surfaced
/// as a spec-lint warning, so binding is always declared-or-derived in one
/// place rather than sniffed from magic channel names at dispatch time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Flavor {
    /// Plain synchronous FL (classical or hierarchical).
    Sync,
    /// CO-FL (§6.1): a coordinator assigns work and owns termination.
    Coordinated,
    /// Hybrid FL (§6.2): cluster rings plus delegate uploads.
    Hybrid,
    /// Asynchronous (FedBuff) aggregation.
    Async,
    /// Distributed all-reduce: one self-paired role, no aggregator.
    Distributed,
}

impl Flavor {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "sync" | "synchronous" => Flavor::Sync,
            "coordinated" => Flavor::Coordinated,
            "hybrid" => Flavor::Hybrid,
            "async" | "asynchronous" => Flavor::Async,
            "distributed" => Flavor::Distributed,
            other => bail!(
                "unknown flavor '{other}' (sync|coordinated|hybrid|async|distributed)"
            ),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Flavor::Sync => "sync",
            Flavor::Coordinated => "coordinated",
            Flavor::Hybrid => "hybrid",
            Flavor::Async => "async",
            Flavor::Distributed => "distributed",
        }
    }
}

/// One vertex of the TAG: an executable worker unit bound to a program.
#[derive(Debug, Clone, PartialEq)]
pub struct Role {
    pub name: String,
    /// Number of replicated workers per groupAssociation entry (§4.1); used
    /// e.g. to build the CO-FL bipartite aggregator tier (§6.1).
    pub replica: usize,
    /// Does this role consume a dataset? Data consumers are expanded one
    /// worker per dataset (Algorithm 1 lines 14-22).
    pub is_data_consumer: bool,
    /// List of `{channel -> group}` sets; one worker (times `replica`) is
    /// created per entry for non-consumers, and entries are matched by
    /// dataset group for consumers.
    pub group_association: Vec<BTreeMap<String, String>>,
    /// The §4.1 role↔program binding, declared in the spec: the name of a
    /// program registered in the job's
    /// [`RoleRegistry`](crate::roles::RoleRegistry). `None` selects the
    /// registry's default binding for `(role name, flavor)`.
    pub program: Option<String>,
}

/// One edge of the TAG: links a pair of roles over a communication backend.
#[derive(Debug, Clone, PartialEq)]
pub struct Channel {
    pub name: String,
    /// The two roles this channel links (may be the same role for
    /// distributed/p2p topologies).
    pub pair: (String, String),
    /// Label-based grouping (§4.1): the allowed group labels on this
    /// channel. Empty means the single implicit group `"default"`.
    pub group_by: Vec<String>,
    /// Maps each endpoint role to the function tags it serves on this
    /// channel — used by roles to dispatch, and by validation.
    pub func_tags: BTreeMap<String, Vec<String>>,
    /// Per-channel communication backend (§6.2 flexibility).
    pub backend: Backend,
    /// The substrate name the spec actually requested (`"mqtt"`,
    /// `"grpc"`, ...), preserved verbatim even when it aliases onto an
    /// implemented transport — what `flame roles` and job events report.
    pub substrate: String,
}

/// A dataset registration (metadata only — the system never holds raw data;
/// §4.3). `group` realizes the paper's `datasetGroups` attribute.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetRef {
    pub name: String,
    pub group: String,
    pub realm: String,
    pub url: String,
}

/// A complete job specification: TAG + datasets + job-level settings.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    pub model: String,
    pub rounds: u64,
    pub roles: Vec<Role>,
    pub channels: Vec<Channel>,
    pub datasets: Vec<DatasetRef>,
    /// Hyper-parameters forwarded verbatim to role programs.
    pub hyper: Json,
    /// Live topology extension timeline (optional): scheduled mid-run
    /// joins/leaves/tier extensions, fired once the job's virtual clock
    /// passes each event's `at_us`. See [`delta::TopologyEvent`].
    pub events: Vec<TopologyEvent>,
    /// Declared topology flavour (`tag.flavor`); `None` defers to
    /// validate-time inference ([`validate::infer_flavor`]).
    pub flavor: Option<Flavor>,
}

impl JobSpec {
    /// Parse a JSON job spec (see `examples/specs/*.json`).
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("job spec is not valid JSON")?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let name = j
            .get("name")
            .as_str()
            .context("job spec missing 'name'")?
            .to_string();
        let model = j
            .get("model")
            .as_str()
            .unwrap_or("mlp")
            .to_string();
        let rounds = j.get("rounds").as_i64().unwrap_or(10) as u64;

        let tag = j.get("tag");
        let flavor_j = tag.get("flavor");
        let flavor = if flavor_j.is_null() {
            None
        } else {
            // present but non-string must be a hard error, not a silent
            // fall-through to inference
            let s = flavor_j.as_str().context("tag.flavor must be a string")?;
            Some(Flavor::parse(s)?)
        };
        let mut roles = Vec::new();
        for (i, r) in tag
            .get("roles")
            .as_arr()
            .context("tag missing 'roles' array")?
            .iter()
            .enumerate()
        {
            roles.push(parse_role(r).with_context(|| format!("role #{i}"))?);
        }
        let mut channels = Vec::new();
        for (i, c) in tag
            .get("channels")
            .as_arr()
            .context("tag missing 'channels' array")?
            .iter()
            .enumerate()
        {
            channels.push(parse_channel(c).with_context(|| format!("channel #{i}"))?);
        }

        let mut datasets = Vec::new();
        if let Some(arr) = j.get("datasets").as_arr() {
            for (i, d) in arr.iter().enumerate() {
                datasets.push(parse_dataset(d).with_context(|| format!("dataset #{i}"))?);
            }
        }

        let mut events = Vec::new();
        if let Some(arr) = j.get("events").as_arr() {
            for (i, e) in arr.iter().enumerate() {
                events.push(TopologyEvent::from_json(e).with_context(|| format!("event #{i}"))?);
            }
        }

        Ok(JobSpec {
            name,
            model,
            rounds,
            roles,
            channels,
            datasets,
            hyper: j.get("hyper").clone(),
            events,
            flavor,
        })
    }

    /// The spec's topology flavour: the declared `tag.flavor`, or — when
    /// the spec omits it — the shape-derived default
    /// ([`validate::infer_flavor`]).
    pub fn resolved_flavor(&self) -> Flavor {
        self.flavor.unwrap_or_else(|| validate::infer_flavor(self))
    }

    pub fn role(&self, name: &str) -> Option<&Role> {
        self.roles.iter().find(|r| r.name == name)
    }

    pub fn channel(&self, name: &str) -> Option<&Channel> {
        self.channels.iter().find(|c| c.name == name)
    }

    /// Channels that `role` participates in.
    pub fn channels_of(&self, role: &str) -> Vec<&Channel> {
        self.channels
            .iter()
            .filter(|c| c.pair.0 == role || c.pair.1 == role)
            .collect()
    }

    /// Dataset groups in first-appearance order (the paper's datasetGroups).
    pub fn dataset_groups(&self) -> Vec<String> {
        let mut groups = Vec::new();
        for d in &self.datasets {
            if !groups.contains(&d.group) {
                groups.push(d.group.clone());
            }
        }
        groups
    }

    /// Serialize back to JSON (used by the store and the transform demos).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("name", self.name.as_str());
        o.insert("model", self.model.as_str());
        o.insert("rounds", self.rounds);
        let mut tag = Json::obj();
        if let Some(f) = self.flavor {
            tag.insert("flavor", f.name());
        }
        tag.insert(
            "roles",
            Json::Arr(self.roles.iter().map(role_to_json).collect()),
        );
        tag.insert(
            "channels",
            Json::Arr(self.channels.iter().map(channel_to_json).collect()),
        );
        o.insert("tag", tag);
        o.insert(
            "datasets",
            Json::Arr(self.datasets.iter().map(dataset_to_json).collect()),
        );
        if !self.hyper.is_null() {
            o.insert("hyper", self.hyper.clone());
        }
        if !self.events.is_empty() {
            o.insert(
                "events",
                Json::Arr(self.events.iter().map(TopologyEvent::to_json).collect()),
            );
        }
        Json::Obj(o)
    }
}

pub(crate) fn parse_role(j: &Json) -> Result<Role> {
    let name = j
        .get("name")
        .as_str()
        .context("role missing 'name'")?
        .to_string();
    let replica = j.get("replica").as_usize().unwrap_or(1);
    if replica == 0 {
        bail!("role '{name}': replica must be >= 1");
    }
    let is_data_consumer = j.get("isDataConsumer").as_bool().unwrap_or(false);
    let mut group_association = Vec::new();
    if let Some(arr) = j.get("groupAssociation").as_arr() {
        for entry in arr {
            let o = entry
                .as_obj()
                .context("groupAssociation entries must be objects")?;
            let mut m = BTreeMap::new();
            for (k, v) in o.iter() {
                m.insert(
                    k.clone(),
                    v.as_str()
                        .context("groupAssociation values must be strings")?
                        .to_string(),
                );
            }
            group_association.push(m);
        }
    }
    if group_association.is_empty() {
        // Convention: a role with no explicit association gets one worker in
        // the "default" group of each of its channels (resolved later).
        group_association.push(BTreeMap::new());
    }
    let program_j = j.get("program");
    let program = if program_j.is_null() {
        None
    } else {
        let p = program_j
            .as_str()
            .with_context(|| format!("role '{name}': 'program' must be a string"))?
            .to_string();
        if p.is_empty() {
            bail!("role '{name}': program name must be non-empty");
        }
        Some(p)
    };
    Ok(Role {
        name,
        replica,
        is_data_consumer,
        group_association,
        program,
    })
}

pub(crate) fn parse_channel(j: &Json) -> Result<Channel> {
    let name = j
        .get("name")
        .as_str()
        .context("channel missing 'name'")?
        .to_string();
    let pair = j.get("pair").as_arr().context("channel missing 'pair'")?;
    if pair.len() != 2 {
        bail!("channel '{name}': pair must have exactly 2 roles");
    }
    let pair = (
        pair[0].as_str().context("pair[0] must be a string")?.to_string(),
        pair[1].as_str().context("pair[1] must be a string")?.to_string(),
    );
    let group_by = j
        .get("groupBy")
        .as_arr()
        .map(|a| {
            a.iter()
                .filter_map(|g| g.as_str().map(str::to_string))
                .collect()
        })
        .unwrap_or_default();
    let mut func_tags = BTreeMap::new();
    if let Some(o) = j.get("funcTags").as_obj() {
        for (role, tags) in o.iter() {
            let tags = tags
                .as_arr()
                .context("funcTags values must be arrays")?
                .iter()
                .filter_map(|t| t.as_str().map(str::to_string))
                .collect();
            func_tags.insert(role.clone(), tags);
        }
    }
    let substrate = j.get("backend").as_str().unwrap_or("p2p").to_string();
    let backend =
        Backend::parse(&substrate).with_context(|| format!("channel '{name}'"))?;
    Ok(Channel {
        name,
        pair,
        group_by,
        func_tags,
        backend,
        substrate,
    })
}

pub(crate) fn parse_dataset(j: &Json) -> Result<DatasetRef> {
    Ok(DatasetRef {
        name: j
            .get("name")
            .as_str()
            .context("dataset missing 'name'")?
            .to_string(),
        group: j.get("group").as_str().unwrap_or("default").to_string(),
        realm: j.get("realm").as_str().unwrap_or("*").to_string(),
        url: j.get("url").as_str().unwrap_or("synth://default").to_string(),
    })
}

pub(crate) fn role_to_json(r: &Role) -> Json {
    let mut o = Json::obj();
    o.insert("name", r.name.as_str());
    if r.replica != 1 {
        o.insert("replica", r.replica);
    }
    if r.is_data_consumer {
        o.insert("isDataConsumer", true);
    }
    let ga: Vec<Json> = r
        .group_association
        .iter()
        .map(|m| {
            let mut o = Json::obj();
            for (k, v) in m {
                o.insert(k.as_str(), v.as_str());
            }
            Json::Obj(o)
        })
        .collect();
    o.insert("groupAssociation", Json::Arr(ga));
    if let Some(p) = &r.program {
        o.insert("program", p.as_str());
    }
    Json::Obj(o)
}

pub(crate) fn channel_to_json(c: &Channel) -> Json {
    let mut o = Json::obj();
    o.insert("name", c.name.as_str());
    o.insert(
        "pair",
        Json::Arr(vec![
            Json::Str(c.pair.0.clone()),
            Json::Str(c.pair.1.clone()),
        ]),
    );
    if !c.group_by.is_empty() {
        o.insert(
            "groupBy",
            Json::Arr(c.group_by.iter().map(|g| Json::Str(g.clone())).collect()),
        );
    }
    if !c.func_tags.is_empty() {
        let mut ft = Json::obj();
        for (role, tags) in &c.func_tags {
            ft.insert(
                role.as_str(),
                Json::Arr(tags.iter().map(|t| Json::Str(t.clone())).collect()),
            );
        }
        o.insert("funcTags", ft);
    }
    // the requested substrate round-trips verbatim (it may be an alias of
    // the implementing transport, e.g. "mqtt" riding the broker)
    o.insert("backend", c.substrate.as_str());
    Json::Obj(o)
}

pub(crate) fn dataset_to_json(d: &DatasetRef) -> Json {
    let mut o = Json::obj();
    o.insert("name", d.name.as_str());
    o.insert("group", d.group.as_str());
    o.insert("realm", d.realm.as_str());
    o.insert("url", d.url.as_str());
    Json::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo;

    #[test]
    fn parses_hfl_spec() {
        let spec = topo::hierarchical(4, 2, Backend::Broker).build();
        assert_eq!(spec.roles.len(), 3);
        assert_eq!(spec.channels.len(), 2);
        let trainer = spec.role("trainer").unwrap();
        assert!(trainer.is_data_consumer);
        let agg = spec.role("aggregator").unwrap();
        assert_eq!(agg.group_association.len(), 2);
    }

    #[test]
    fn roundtrips_via_json() {
        let spec = topo::hierarchical(4, 2, Backend::Broker).build();
        let text = spec.to_json().pretty();
        let back = JobSpec::parse(&text).unwrap();
        assert_eq!(back.roles.len(), spec.roles.len());
        assert_eq!(back.channels.len(), spec.channels.len());
        assert_eq!(back.datasets.len(), spec.datasets.len());
        assert_eq!(
            back.role("aggregator").unwrap().group_association,
            spec.role("aggregator").unwrap().group_association
        );
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(JobSpec::parse("{").is_err());
        assert!(JobSpec::parse("{}").is_err()); // no name
        assert!(JobSpec::parse(r#"{"name":"x"}"#).is_err()); // no tag
        assert!(JobSpec::parse(
            r#"{"name":"x","tag":{"roles":[{"name":"r","replica":0}],"channels":[]}}"#
        )
        .is_err()); // replica 0
        assert!(JobSpec::parse(
            r#"{"name":"x","tag":{"roles":[],"channels":[{"name":"c","pair":["a"]}]}}"#
        )
        .is_err()); // pair len 1
    }

    #[test]
    fn flavor_and_program_roundtrip_via_json() {
        let mut spec = topo::classical(2, Backend::P2p).build();
        spec.flavor = Some(Flavor::Sync);
        spec.roles[0].program = Some("fedprox-trainer".into());
        let back = JobSpec::parse(&spec.to_json().pretty()).unwrap();
        assert_eq!(back.flavor, Some(Flavor::Sync));
        assert_eq!(back.roles[0].program.as_deref(), Some("fedprox-trainer"));
        // absent fields stay absent
        let plain = topo::classical(2, Backend::P2p).build();
        let back = JobSpec::parse(&plain.to_json().pretty()).unwrap();
        assert_eq!(back.flavor, None);
        assert!(back.roles.iter().all(|r| r.program.is_none()));
    }

    #[test]
    fn bad_flavor_and_empty_program_rejected() {
        assert!(JobSpec::parse(
            r#"{"name":"x","tag":{"flavor":"quantum","roles":[{"name":"r"}],"channels":[]}}"#
        )
        .is_err());
        assert!(JobSpec::parse(
            r#"{"name":"x","tag":{"roles":[{"name":"r","program":""}],"channels":[]}}"#
        )
        .is_err());
        // present-but-wrong-typed values are hard errors, not silent skips
        assert!(JobSpec::parse(
            r#"{"name":"x","tag":{"flavor":5,"roles":[{"name":"r"}],"channels":[]}}"#
        )
        .is_err());
        assert!(JobSpec::parse(
            r#"{"name":"x","tag":{"roles":[{"name":"r","program":5}],"channels":[]}}"#
        )
        .is_err());
        assert!(Flavor::parse("hybrid").is_ok());
        assert_eq!(Flavor::parse("coordinated").unwrap().name(), "coordinated");
    }

    #[test]
    fn dataset_groups_in_order() {
        let spec = topo::hierarchical(6, 3, Backend::Broker).build();
        assert_eq!(spec.dataset_groups().len(), 3);
    }

    #[test]
    fn channels_of_role() {
        let spec = topo::hierarchical(4, 2, Backend::Broker).build();
        let chans = spec.channels_of("aggregator");
        assert_eq!(chans.len(), 2);
        assert_eq!(spec.channels_of("trainer").len(), 1);
    }
}

#[cfg(test)]
mod spec_file_tests {
    use super::*;
    use crate::registry::Registry;

    /// The shipped example specs (examples/specs/*.json) must stay valid.
    #[test]
    fn example_spec_files_parse_and_expand() {
        let dir = std::path::Path::new("examples/specs");
        if !dir.exists() {
            eprintln!("skipping: examples/specs not present");
            return;
        }
        let mut checked = 0;
        for entry in std::fs::read_dir(dir).unwrap() {
            let path = entry.unwrap().path();
            if path.extension().and_then(|e| e.to_str()) != Some("json") {
                continue;
            }
            let text = std::fs::read_to_string(&path).unwrap();
            let spec = JobSpec::parse(&text)
                .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
            let workers = expand(&spec, &Registry::single_box())
                .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
            assert!(!workers.is_empty());
            checked += 1;
        }
        assert!(checked >= 4, "expected >=4 example specs, found {checked}");
    }
}
