//! Agent — the per-worker thin client (paper §5.1).
//!
//! Each pod runs one agent. The agent fetches the worker's task
//! configuration (here: the [`WorkerConfig`] the deployer hands it), builds
//! the role's program over a fresh [`crate::roles::WorkerEnv`], executes it
//! as a supervised task, and reports status transitions to the management
//! plane through the notifier. It also provides the paper's sandbox
//! boundary: a panicking or erroring worker is contained and surfaced as a
//! `Failed` status instead of taking the plane down.

use std::panic::AssertUnwindSafe;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::json::Json;
use crate::notify::{EventKind, Notifier};
use crate::roles::{build_program, WorkerEnv};

fn status_event(notifier: &Notifier, job: &str, worker: &str, state: &str, detail: &str) {
    let mut payload = Json::obj();
    payload.insert("worker", worker);
    payload.insert("state", state);
    if !detail.is_empty() {
        payload.insert("detail", detail);
    }
    notifier.emit(EventKind::WorkerStatus, job, Json::Obj(payload));
}

/// Run one worker to completion under agent supervision.
///
/// The environment (channel joins) is built by the controller *before* any
/// worker starts, so every role observes complete channel membership — the
/// deployment equivalent of the paper's step-7/8 ordering (agents fetch
/// their full task configuration before the worker process starts).
pub fn run_worker(env: WorkerEnv, notifier: Arc<Notifier>) -> Result<()> {
    let job_name = env.job.spec.name.clone();
    let worker_id = env.cfg.id.clone();
    status_event(&notifier, &job_name, &worker_id, "starting", "");

    let result: Result<()> = (|| {
        let mut program = build_program(env)?;
        // sandbox: contain panics from role code
        match std::panic::catch_unwind(AssertUnwindSafe(|| program.run())) {
            Ok(r) => r,
            Err(panic) => {
                let msg = panic
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "worker panicked".into());
                Err(anyhow!("worker panic: {msg}"))
            }
        }
    })();

    match &result {
        Ok(()) => status_event(&notifier, &job_name, &worker_id, "completed", ""),
        Err(e) => status_event(&notifier, &job_name, &worker_id, "failed", &format!("{e:#}")),
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roles::tests_support::tiny_job_runtime;

    #[test]
    fn bad_role_fails_cleanly_with_status_events() {
        let (job, cfgs) = tiny_job_runtime();
        let notifier = Arc::new(Notifier::new());
        let rx = notifier.subscribe(Some(EventKind::WorkerStatus), None);
        let mut bad = cfgs[0].clone();
        bad.role = "bogus".into();
        let env = WorkerEnv::new(bad, job).unwrap();
        let res = run_worker(env, notifier);
        assert!(res.is_err());
        let events: Vec<_> = rx.try_iter().collect();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].payload.get("state").as_str(), Some("starting"));
        assert_eq!(events[1].payload.get("state").as_str(), Some("failed"));
    }

    #[test]
    fn unknown_channel_in_config_fails_at_env_build() {
        let (job, cfgs) = tiny_job_runtime();
        let mut bad = cfgs[0].clone();
        bad.channels.insert("ghost-channel".into(), "default".into());
        assert!(WorkerEnv::new(bad, job).is_err());
    }
}
