//! Agent — the per-worker thin client (paper §5.1).
//!
//! Each pod runs one agent. The agent fetches the worker's task
//! configuration (here: the [`WorkerConfig`](crate::tag::WorkerConfig) the
//! deployer hands it), builds the role's program over a fresh
//! [`crate::roles::WorkerEnv`], executes it as a supervised task, and
//! reports status transitions to the management plane through the
//! notifier. It also provides the paper's sandbox boundary: a panicking or
//! erroring worker is contained and surfaced as a `Failed` status instead
//! of taking the plane down.
//!
//! Two execution shapes share the same supervision logic:
//!
//! * [`run_worker`] — the blocking form: one OS thread drives the worker
//!   to completion (thread-per-worker deployment, direct tests).
//! * [`WorkerTask`] — the cooperative form: a [`crate::sched::RunnableTask`]
//!   the worker fabric polls; each poll drives the program until it
//!   completes or yields at a blocking receive.

use std::panic::AssertUnwindSafe;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::deploy::{PodStatus, StatusCell};
use crate::json::Json;
use crate::net::{VClock, VTime};
use crate::notify::{EventKind, Notifier};
use crate::roles::{JobRuntime, Program, WorkerEnv};
use crate::sched::{is_pending, PollOutcome, RunnableTask};
use crate::workflow::StepStatus;

/// Emit a worker status transition, stamped with the worker's virtual
/// time so the status stream is orderable against trace spans.
fn status_event(
    notifier: &Notifier,
    job: &str,
    worker: &str,
    at: VTime,
    state: &str,
    detail: &str,
) {
    let mut payload = Json::obj();
    payload.insert("worker", worker);
    payload.insert("state", state);
    if !detail.is_empty() {
        payload.insert("detail", detail);
    }
    notifier.emit_at(EventKind::WorkerStatus, job, at, Json::Obj(payload));
}

fn panic_msg(panic: Box<dyn std::any::Any + Send>) -> String {
    panic
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| panic.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "worker panicked".into())
}

/// Run one worker to completion under agent supervision (blocking mode).
///
/// The environment (channel joins) is built by the controller *before* any
/// worker starts, so every role observes complete channel membership — the
/// deployment equivalent of the paper's step-7/8 ordering (agents fetch
/// their full task configuration before the worker process starts).
pub fn run_worker(env: WorkerEnv, notifier: Arc<Notifier>) -> Result<()> {
    let job_name = env.job.spec.name.clone();
    let worker_id = env.cfg.id.clone();
    let clock = env.clock.clone();
    let now = || clock.lock().unwrap().now();
    status_event(&notifier, &job_name, &worker_id, now(), "starting", "");

    let result: Result<()> = (|| {
        // Role SDK dispatch: the job's registry resolves this worker's
        // role↔program binding from spec data (no role-name matching).
        let programs = env.job.programs.clone();
        let mut program = programs.build(env)?;
        // sandbox: contain panics from role code
        match std::panic::catch_unwind(AssertUnwindSafe(|| program.run())) {
            Ok(r) => r,
            Err(panic) => Err(anyhow!("worker panic: {}", panic_msg(panic))),
        }
    })();
    // membership revocation is clean retirement, not failure — journal it
    // as "departed" (then "completed"), exactly like the cooperative path
    let result = match result {
        Err(e) if crate::channel::is_departed(&e) => {
            status_event(&notifier, &job_name, &worker_id, now(), "departed", "");
            Ok(())
        }
        other => other,
    };

    match &result {
        Ok(()) => status_event(&notifier, &job_name, &worker_id, now(), "completed", ""),
        Err(e) => status_event(
            &notifier,
            &job_name,
            &worker_id,
            now(),
            "failed",
            &format!("{e:#}"),
        ),
    }
    result
}

/// The cooperative agent: one worker as a schedulable task.
///
/// The program is built lazily on the first poll (so build errors surface
/// through the same status pipeline as runtime errors), then stepped; a
/// step that yields parks the task until the channel fabric wakes it.
pub struct WorkerTask {
    job: String,
    worker: String,
    env: Option<WorkerEnv>,
    program: Option<Box<dyn Program>>,
    notifier: Arc<Notifier>,
    status: Arc<StatusCell>,
    /// Kept past the env→program handoff: the deadlock post-mortem
    /// ([`RunnableTask::stall_context`]) queries the job's channel fabric
    /// and trace hub after the program owns the env.
    rt: Arc<JobRuntime>,
    clock: Arc<Mutex<VClock>>,
}

impl WorkerTask {
    pub fn new(env: WorkerEnv, notifier: Arc<Notifier>, status: Arc<StatusCell>) -> Self {
        Self {
            job: env.job.spec.name.clone(),
            worker: env.cfg.id.clone(),
            rt: env.job.clone(),
            clock: env.clock.clone(),
            env: Some(env),
            program: None,
            notifier,
            status,
        }
    }

    fn now(&self) -> VTime {
        self.clock.lock().unwrap().now()
    }

    fn finish(&mut self, result: Result<()>) -> PollOutcome {
        match result {
            Ok(()) => {
                self.status.set(PodStatus::Completed);
                status_event(
                    &self.notifier,
                    &self.job,
                    &self.worker,
                    self.now(),
                    "completed",
                    "",
                );
            }
            Err(e) => {
                let detail = format!("{e:#}");
                self.status.set(PodStatus::Failed(detail.clone()));
                status_event(
                    &self.notifier,
                    &self.job,
                    &self.worker,
                    self.now(),
                    "failed",
                    &detail,
                );
            }
        }
        self.program = None; // release role state eagerly
        PollOutcome::Done
    }
}

impl RunnableTask for WorkerTask {
    fn name(&self) -> &str {
        &self.worker
    }

    fn poll(&mut self) -> PollOutcome {
        if let Some(env) = self.env.take() {
            self.status.set(PodStatus::Running);
            status_event(
                &self.notifier,
                &self.job,
                &self.worker,
                self.now(),
                "starting",
                "",
            );
            let programs = env.job.programs.clone();
            match std::panic::catch_unwind(AssertUnwindSafe(|| programs.build(env))) {
                Ok(Ok(p)) => self.program = Some(p),
                Ok(Err(e)) => return self.finish(Err(e)),
                Err(panic) => {
                    return self.finish(Err(anyhow!("worker panic: {}", panic_msg(panic))))
                }
            }
        }
        let program = self.program.as_mut().expect("program built on first poll");
        match std::panic::catch_unwind(AssertUnwindSafe(|| program.step())) {
            Ok(Ok(StepStatus::Pending)) => PollOutcome::Parked,
            Ok(Ok(StepStatus::Done)) => self.finish(Ok(())),
            // A raw Pending escaping as Err means the chain executor lost
            // its resume cursor; parking would restart the chain from the
            // top on resume (duplicating sends). Fail loudly instead.
            Ok(Err(e)) if is_pending(&e) => self.finish(Err(anyhow!(
                "pending signal escaped the chain executor (lost resume cursor)"
            ))),
            // Retired by a `leave` event: the membership revocation is the
            // worker's termination signal, not a failure.
            Ok(Err(e)) if crate::channel::is_departed(&e) => {
                status_event(
                    &self.notifier,
                    &self.job,
                    &self.worker,
                    self.now(),
                    "departed",
                    "",
                );
                self.finish(Ok(()))
            }
            Ok(Err(e)) => self.finish(Err(e)),
            Err(panic) => self.finish(Err(anyhow!("worker panic: {}", panic_msg(panic)))),
        }
    }

    fn fail(&mut self, reason: &str) {
        self.status.set(PodStatus::Failed(reason.to_string()));
        status_event(
            &self.notifier,
            &self.job,
            &self.worker,
            self.now(),
            "failed",
            reason,
        );
        self.program = None;
    }

    /// Deadlock post-mortem body: every cooperative wait this worker has
    /// registered on the job's channels, plus the last trace span it
    /// recorded (when tracing is on) — enough to see *what* it was waiting
    /// for and *where* in the round it stalled.
    fn stall_context(&self) -> Option<String> {
        let mut parts = self.rt.chan_mgr.stall_notes(&self.worker);
        if let Some(last) = self.rt.trace.last_span_of(&self.worker) {
            parts.push(format!("last span {last}"));
        }
        if parts.is_empty() {
            None
        } else {
            Some(parts.join("; "))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roles::tests_support::tiny_job_runtime;

    #[test]
    fn bad_role_fails_cleanly_with_status_events() {
        let (job, cfgs) = tiny_job_runtime();
        let notifier = Arc::new(Notifier::new());
        let rx = notifier.subscribe(Some(EventKind::WorkerStatus), None);
        let mut bad = cfgs[0].clone();
        bad.role = "bogus".into();
        let env = WorkerEnv::new(bad, job).unwrap();
        let res = run_worker(env, notifier);
        assert!(res.is_err());
        let events: Vec<_> = rx.try_iter().collect();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].payload.get("state").as_str(), Some("starting"));
        assert_eq!(events[1].payload.get("state").as_str(), Some("failed"));
    }

    #[test]
    fn unknown_channel_in_config_fails_at_env_build() {
        let (job, cfgs) = tiny_job_runtime();
        let mut bad = cfgs[0].clone();
        bad.channels.insert("ghost-channel".into(), "default".into());
        assert!(WorkerEnv::new(bad, job).is_err());
    }

    #[test]
    fn worker_task_surfaces_build_failure_as_failed_status() {
        let (job, cfgs) = tiny_job_runtime();
        let notifier = Arc::new(Notifier::new());
        let rx = notifier.subscribe(Some(EventKind::WorkerStatus), None);
        let mut bad = cfgs[0].clone();
        bad.role = "bogus".into();
        let env = WorkerEnv::new(bad, job).unwrap();
        let status = StatusCell::new();
        let mut task = WorkerTask::new(env, notifier, status.clone());
        assert!(matches!(task.poll(), PollOutcome::Done));
        assert!(matches!(status.get(), PodStatus::Failed(_)));
        assert_eq!(rx.try_iter().count(), 2); // starting + failed
    }
}
