//! The `channel` primitive + Channel API (paper §4.1, Table 2).
//!
//! A channel links a pair of roles and abstracts the communication backend;
//! workers use the same API regardless of backend. This module provides:
//!
//! * [`Message`] / [`Payload`] — what roles exchange (model vectors ride as
//!   shared `Arc<Vec<f32>>` so fan-out broadcasts don't copy weights;
//!   kinds are interned `Arc<str>` atoms and metadata rides behind an
//!   `Arc<Json>`, so *cloning a message is three pointer bumps*),
//! * [`Backend`] — per-channel backend selection (the paper's headline
//!   flexibility, §6.2): `P2p` direct links, `Broker` store-and-forward via
//!   a hub (MQTT-like), `InProc` zero-cost local (tests),
//! * [`ChannelManager`] — membership per `(channel, group)` pair as created
//!   by TAG expansion's `groupBy`. The membership map is **sharded** so a
//!   10k-worker fabric does not serialise on one global mutex; delivery
//!   touches only the target mailbox's own lock.
//! * [`ChannelHandle`] — the worker-side **Table 2 API**: `join`, `leave`,
//!   `send`, `recv`, `recv_fifo`, `peek`, `broadcast`, `ends`, `empty`.
//!
//! ## Hot-path memory discipline
//!
//! The steady-state round loop is allocation-free (measured by
//! `rust/benches/fabric.rs`, pinned by `rust/tests/alloc_regression.rs`):
//!
//! * channel identity is a packed-`u64` [`crate::intern::Route`] — the old
//!   per-call `(String, String, String)` key tuple is gone;
//! * a handle resolves its route **once at `join`** and caches an `Arc`
//!   to the channel state, so `send`/`recv`/`broadcast` never touch the
//!   shard map again;
//! * peer lists are cached per handle and stamped with the channel's
//!   membership **epoch**; joins, leaves and evictions bump the epoch, so
//!   live topology extension invalidates exactly the caches it must;
//! * broker hub node names are precomputed at channel creation (the old
//!   code `format!`-ed one per delivery);
//! * sender names travel as interned `Arc<str>` atoms — enqueueing an
//!   envelope clones pointers, never strings.
//!
//! Transfers account virtual time through [`crate::net::VirtualNet`]; each
//! worker's [`VClock`] merges message arrival times on receive, so critical
//! -path round times fall out of normal channel use (see `net` docs).
//!
//! ## Blocking vs cooperative receives
//!
//! Every handle carries its worker's [`WorkerPark`]. In blocking mode
//! (direct use, thread-per-worker execution) an unsatisfied receive waits
//! on the mailbox condvar up to the park's timeout. In cooperative mode
//! (the [`crate::sched`] worker fabric) the receive registers its wait
//! condition on the mailbox and yields [`crate::sched::Pending`]; delivery
//! of a matching message wakes the parked worker through its
//! [`crate::sched::Waker`] at the message's virtual arrival time.
//!
//! Message selection is deterministic in both modes: the earliest match by
//! `(virtual arrival, sender, sequence)` wins, so the same job produces
//! bit-identical results under threaded and cooperative execution.
//!
//! ## Churn: departures and eviction
//!
//! Live topology extension (see [`crate::tag::delta`]) makes membership
//! dynamic, which channels support with two mechanisms:
//!
//! * **Departure notices.** [`ChannelHandle::leave`] and
//!   [`ChannelManager::evict`] record the departed worker on every
//!   remaining member's mailbox and *cancel* parked waits that can no
//!   longer be satisfied: a `recv` waiting on the leaver, or a
//!   `recv_fifo` barrier still missing the leaver's message, wakes and
//!   fails promptly with a "peer left" error instead of stranding until
//!   the deadlock detector (cooperative) or the wall-clock timeout
//!   (blocking) fires. Mail the leaver sent *before* departing stays
//!   consumable.
//! * **Eviction.** [`ChannelManager::evict`] retires a worker from every
//!   channel it joined: its own mailboxes close (its next receive raises
//!   the [`Departed`] signal, which the agent treats as clean
//!   retirement), and every parked peer in the affected groups is woken
//!   conservatively so quorum-style collects re-evaluate membership.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, RwLock};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::intern::{atom, route, Route};
use crate::json::Json;
use crate::net::{VClock, VTime, VirtualNet};
use crate::sched::{pending_err, Waker, WorkerPark};

/// Default wall-clock stall guard for *blocking* receives. Deployments
/// override it via `JobOptions::recv_timeout` (auto-scaled with worker
/// count); cooperative execution needs no timeout at all — stalls are
/// detected instantly as virtual-time deadlocks.
pub const RECV_TIMEOUT: Duration = Duration::from_secs(60);

/// Membership shards: keyed by the mixed route hash so join/lookup load
/// spreads instead of serialising on a single map lock.
const N_SHARDS: usize = 64;

/// Communication backend for one channel (TAG `backend` attribute).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Zero-virtual-cost local queue (unit tests, intra-process glue).
    InProc,
    /// Direct point-to-point link: one hop on the virtual net.
    P2p,
    /// MQTT-like pub/sub broker: two hops via the channel's hub node. Works
    /// when peers can't reach each other directly (NAT/firewall), at the
    /// price of WAN traffic through the broker — exactly the §6.2 trade-off.
    Broker,
    /// Real length-prefixed TCP streams between OS processes (see
    /// [`crate::wire`]). Virtual-time cost is one direct hop, identical to
    /// [`Backend::P2p`] — which is what makes the in-process run of a
    /// `backend: "tcp"` job the byte-parity oracle for the multi-process
    /// deployment.
    Tcp,
}

/// Marker error: this worker was retired from the deployment (evicted by
/// a `leave` event). Raised by receives on a closed mailbox; the agent
/// recognises it and completes the worker cleanly instead of failing it.
#[derive(Debug, Clone, Copy)]
pub struct Departed;

impl std::fmt::Display for Departed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "worker departed the deployment (membership revoked)")
    }
}

impl std::error::Error for Departed {}

/// Build the departure signal as an `anyhow` error.
pub fn departed_err() -> anyhow::Error {
    anyhow::Error::new(Departed)
}

/// Is this error the departure signal (possibly wrapped in context)?
pub fn is_departed(err: &anyhow::Error) -> bool {
    err.downcast_ref::<Departed>().is_some()
}

impl Backend {
    /// Every substrate name [`Self::parse`] accepts, with the transport it
    /// maps onto. Aliases are real-world substrates whose delivery shape
    /// matches an implemented transport (gRPC is a direct link; MQTT and
    /// Kafka are store-and-forward hubs); the requested name is preserved
    /// through the job spec as [`crate::tag::Channel::substrate`].
    pub const SUBSTRATES: &'static [(&'static str, Backend)] = &[
        ("broker", Backend::Broker),
        ("grpc", Backend::P2p),
        ("inproc", Backend::InProc),
        ("kafka", Backend::Broker),
        ("local", Backend::InProc),
        ("mqtt", Backend::Broker),
        ("p2p", Backend::P2p),
        ("tcp", Backend::Tcp),
    ];

    pub fn parse(s: &str) -> Result<Self> {
        match Self::SUBSTRATES.iter().find(|(n, _)| *n == s) {
            Some((_, b)) => Ok(*b),
            None => {
                let valid: Vec<&str> = Self::SUBSTRATES.iter().map(|(n, _)| *n).collect();
                bail!(
                    "unknown backend '{s}' (valid backends: {})",
                    valid.join(", ")
                )
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::InProc => "inproc",
            Backend::P2p => "p2p",
            Backend::Broker => "broker",
            Backend::Tcp => "tcp",
        }
    }
}

/// Message payload. Model weights/updates are `Arc`-shared: broadcast to N
/// peers moves a pointer, not N vector copies.
#[derive(Debug, Clone)]
pub enum Payload {
    Empty,
    Floats(Arc<Vec<f32>>),
    Json(Json),
    /// A codec-compressed model update (see [`crate::runtime::codec`]).
    /// Its wire size is the **encoded** byte count, so virtual-time
    /// transfer charges reflect compression, not the dense f32 length.
    Encoded(Arc<crate::runtime::EncodedUpdate>),
}

impl Payload {
    /// Wire size used for virtual-time accounting.
    pub fn size_bytes(&self) -> u64 {
        match self {
            Payload::Empty => 0,
            Payload::Floats(v) => (v.len() * 4) as u64,
            Payload::Json(j) => j.dump().len() as u64,
            Payload::Encoded(e) => e.wire_bytes() as u64,
        }
    }
}

/// The shared null-metadata value: control messages carry it without
/// allocating.
fn null_meta() -> Arc<Json> {
    static NULL: OnceLock<Arc<Json>> = OnceLock::new();
    NULL.get_or_init(|| Arc::new(Json::Null)).clone()
}

/// A typed message between roles. `kind` disambiguates the function the
/// receiver dispatches to (the paper's `funcTags`); it is an interned
/// atom, so constructing a message with a known kind allocates nothing
/// and fan-out clones are pointer-sized.
#[derive(Debug, Clone)]
pub struct Message {
    pub kind: Arc<str>,
    pub round: u64,
    pub payload: Payload,
    /// Private: set through [`Self::with_meta`] only, which also caches
    /// the serialized size — a public field could silently desynchronize
    /// the wire accounting.
    meta: Arc<Json>,
    /// Serialized metadata size, cached at construction so per-delivery
    /// wire accounting never re-dumps the JSON.
    meta_bytes: u64,
}

impl Message {
    pub fn new(kind: impl AsRef<str>, round: u64, payload: Payload) -> Self {
        Self {
            kind: atom(kind.as_ref()),
            round,
            payload,
            meta: null_meta(),
            meta_bytes: 0,
        }
    }

    pub fn with_meta(mut self, meta: Json) -> Self {
        self.meta_bytes = if meta.is_null() { 0 } else { meta.dump().len() as u64 };
        self.meta = Arc::new(meta);
        self
    }

    /// The message metadata (shared; `Json::Null` when none was attached).
    pub fn meta(&self) -> &Json {
        &self.meta
    }

    pub fn floats(kind: impl AsRef<str>, round: u64, data: Arc<Vec<f32>>) -> Self {
        Self::new(kind, round, Payload::Floats(data))
    }

    pub fn control(kind: impl AsRef<str>, round: u64) -> Self {
        Self::new(kind, round, Payload::Empty)
    }

    /// A codec-compressed update message; wire accounting uses the
    /// encoded size (see [`Payload::Encoded`]).
    pub fn encoded(
        kind: impl AsRef<str>,
        round: u64,
        enc: Arc<crate::runtime::EncodedUpdate>,
    ) -> Self {
        Self::new(kind, round, Payload::Encoded(enc))
    }

    pub fn size_bytes(&self) -> u64 {
        // kind/round/meta overhead + payload
        64 + self.payload.size_bytes() + self.meta_bytes
    }
}

#[derive(Debug)]
struct Envelope {
    from: Arc<str>,
    msg: Message,
    arrival: VTime,
    seq: u64,
}

/// What a parked receive is waiting for. Sender/kind patterns are interned
/// atoms — building a spec never copies string contents.
#[derive(Debug, Clone)]
enum MatchSpec {
    /// Any message from this sender.
    From(Arc<str>),
    /// A message from this sender with this kind.
    FromKind(Arc<str>, Arc<str>),
    /// Any message at all.
    Any,
    /// Any message with this kind.
    AnyKind(Arc<str>),
}

impl MatchSpec {
    fn matches_parts(&self, from: &str, kind: &str) -> bool {
        match self {
            MatchSpec::From(f) => &**f == from,
            MatchSpec::FromKind(f, k) => &**f == from && &**k == kind,
            MatchSpec::Any => true,
            MatchSpec::AnyKind(k) => &**k == kind,
        }
    }

    fn matches(&self, e: &Envelope) -> bool {
        self.matches_parts(&e.from, &e.msg.kind)
    }

    /// Does this wait depend on a specific sender? (`Any*` waits can be
    /// satisfied by whoever remains, so a single departure never dooms
    /// them.)
    fn depends_on(&self, worker: &str) -> bool {
        match self {
            MatchSpec::From(f) | MatchSpec::FromKind(f, _) => &**f == worker,
            MatchSpec::Any | MatchSpec::AnyKind(_) => false,
        }
    }
}

/// Wait condition parked on a mailbox by a cooperative receive.
#[derive(Debug)]
enum WaitSpec {
    /// Wake as soon as one matching envelope is delivered.
    Match(MatchSpec),
    /// Wake once mail from *every* listed sender is present (`recv_fifo`'s
    /// aggregation barrier). Delivery removes senders in place, so the
    /// check is O(1) per message instead of a queue scan.
    AllOf(Vec<Arc<str>>),
}

struct MailboxInner {
    queue: VecDeque<Envelope>,
    waiting: Option<(WaitSpec, Waker)>,
    /// Peers that left this (channel, group) while we were a member —
    /// consulted by strict waits so a departure cannot strand us.
    departed: Vec<Arc<str>>,
    /// Set when this member itself was evicted: further receives raise
    /// [`Departed`].
    closed: bool,
}

struct MailboxCore {
    inner: Mutex<MailboxInner>,
    cv: Condvar,
}

impl MailboxCore {
    fn new() -> Arc<Self> {
        Arc::new(Self {
            inner: Mutex::new(MailboxInner {
                queue: VecDeque::new(),
                waiting: None,
                departed: Vec::new(),
                closed: false,
            }),
            cv: Condvar::new(),
        })
    }
}

type Mailbox = Arc<MailboxCore>;

/// Earliest matching envelope by `(arrival, sender, seq)` — deterministic
/// across executors (the global `seq` counter only breaks exact ties from
/// the *same* sender, where it reflects the sender's program order).
fn best_index(q: &VecDeque<Envelope>, spec: &MatchSpec) -> Option<usize> {
    q.iter()
        .enumerate()
        .filter(|(_, e)| spec.matches(e))
        .min_by(|(_, a), (_, b)| (a.arrival, &a.from, a.seq).cmp(&(b.arrival, &b.from, b.seq)))
        .map(|(i, _)| i)
}

struct Member {
    mailbox: Mailbox,
    role: Arc<str>,
    /// A shadow member hosted on another OS process ([`ChannelManager::
    /// join_remote`]): counted by `ends()`/quorum exactly like a local
    /// member, but deliveries to it ship through the bound [`Transport`]
    /// instead of its (unused) local mailbox.
    remote: bool,
}

/// Membership of one `(scope, channel, group)` route. Lives behind an
/// `Arc` in the shard map so handles resolve it once at join; the `epoch`
/// counter versions membership for the handles' peer-list caches.
struct ChannelShared {
    backend: Backend,
    /// The packed route this membership lives under — what remote
    /// deliveries carry as their wire key.
    route: Route,
    /// Precomputed broker hub node name (`hub:<scope::>channel`).
    hub: Arc<str>,
    members: RwLock<HashMap<Arc<str>, Member>>,
    /// Bumped on every membership change (join / leave / evict).
    epoch: AtomicU64,
}

impl ChannelShared {
    fn bump(&self) {
        self.epoch.fetch_add(1, Ordering::AcqRel);
    }
}

type ShardMap = HashMap<Route, Arc<ChannelShared>>;

/// A real inter-process message carrier bound behind the [`Backend`]
/// abstraction (implemented by [`crate::wire::TcpBackend`]). The channel
/// layer computes the virtual arrival time exactly as it does for local
/// members — the transfer functions are pure, so sender and receiver
/// agree on it — then hands the framed message to the transport; the
/// receiving process re-enqueues it through
/// [`ChannelManager::deliver_remote`].
pub trait Transport: Send + Sync {
    /// Ship `msg` to the process hosting `to`. Implementations must
    /// preserve per-sender FIFO order (message selection breaks exact
    /// `(arrival, sender)` ties by sequence number, which on the receiver
    /// reflects reception order — FIFO streams keep that equal to the
    /// sender's program order, preserving byte-determinism). Delivery to a
    /// dead peer is not an error: peer death surfaces through the
    /// [`Departed`]/evict machinery, not through send failures.
    fn ship(
        &self,
        route: Route,
        from: &Arc<str>,
        to: &str,
        arrival: VTime,
        msg: &Message,
    ) -> Result<()>;

    /// Substrate name for diagnostics.
    fn name(&self) -> &'static str;
}

/// The shared mailbox/membership substrate: membership shards, the global
/// delivery sequence counter, and the virtual network. One fabric can be
/// shared by **many jobs** (the multi-job control plane), each seeing it
/// through its own scoped [`ChannelManager`] view.
struct Fabric {
    net: Arc<VirtualNet>,
    shards: Vec<RwLock<ShardMap>>,
    seq: AtomicU64,
    /// Bound once by a multi-process deployment; local-only fabrics never
    /// set it and pay one `OnceLock` load per delivery to a remote member
    /// (i.e. never — remote members only exist once a transport is bound).
    transport: OnceLock<Arc<dyn Transport>>,
}

impl Fabric {
    fn shard(&self, r: Route) -> &RwLock<ShardMap> {
        &self.shards[(r.mix() as usize) % self.shards.len()]
    }

    fn lookup(&self, r: Route) -> Option<Arc<ChannelShared>> {
        self.shard(r).read().unwrap().get(&r).cloned()
    }
}

/// Channel fabric view. A standalone job owns an unscoped manager
/// ([`ChannelManager::new`]); concurrent jobs on one shared fabric each
/// hold a **scoped** view ([`ChannelManager::scoped`]) that namespaces
/// every channel key by the job id — two jobs with identical worker and
/// channel names (e.g. two `cfl` submissions) can never see each other's
/// mailboxes or memberships. Handles are created per worker+channel by
/// `join`.
pub struct ChannelManager {
    fabric: Arc<Fabric>,
    /// This view's namespace: one component of the packed
    /// `(scope, channel, group)` route. Empty for standalone jobs.
    scope: Arc<str>,
    /// The scope's interned symbol — what `evict` filters routes by.
    scope_sym: crate::intern::Symbol,
    /// Per-job trace hub, bound only for jobs with tracing enabled
    /// ([`Self::set_trace`]). Deliveries record one `upload-xfer` span per
    /// message; unset (the default) costs a single atomic load on the
    /// delivery hot path and nothing else.
    trace: OnceLock<Arc<crate::trace::TraceHub>>,
}

impl ChannelManager {
    pub fn new(net: Arc<VirtualNet>) -> Arc<Self> {
        Arc::new(Self {
            fabric: Arc::new(Fabric {
                net,
                shards: (0..N_SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
                seq: AtomicU64::new(0),
                transport: OnceLock::new(),
            }),
            scope: atom(""),
            scope_sym: crate::intern::sym(""),
            trace: OnceLock::new(),
        })
    }

    /// Bind this view's trace hub (idempotent). Only called for jobs with
    /// tracing enabled; each scoped view binds its own job's hub.
    pub fn set_trace(&self, hub: Arc<crate::trace::TraceHub>) {
        let _ = self.trace.set(hub);
    }

    /// A per-job view over this manager's shared fabric: same shards, same
    /// sequence counter, same virtual network, but every membership route
    /// carries `scope` as a distinct component (and broker hub nodes are
    /// scope-prefixed), isolating the job's membership and mail from
    /// every other scope.
    pub fn scoped(self: &Arc<Self>, scope: &str) -> Arc<ChannelManager> {
        Arc::new(Self {
            fabric: self.fabric.clone(),
            scope: atom(scope),
            scope_sym: crate::intern::sym(scope),
            trace: OnceLock::new(),
        })
    }

    /// This view's namespace (empty for standalone jobs).
    pub fn scope(&self) -> &str {
        &self.scope
    }

    pub fn net(&self) -> &Arc<VirtualNet> {
        &self.fabric.net
    }

    /// The scope-qualified broker hub node name for a channel — per-job
    /// hubs on a shared fabric are distinct virtual-net nodes.
    fn qualified(&self, channel: &str) -> String {
        if self.scope.is_empty() {
            channel.to_string()
        } else {
            format!("{}::{channel}", self.scope)
        }
    }

    /// The fabric-level membership route: channel identity is the packed
    /// `(scope, channel, group)` symbol triple — no string-prefix
    /// conventions, so channel names (or scopes) containing any separator
    /// can never alias another scope's routes. `None` once the global
    /// symbol space is exhausted (join surfaces it as a clean error).
    fn route_of(&self, channel: &str, group: &str) -> Option<Route> {
        route(&self.scope, channel, group)
    }

    /// Join `(channel, group)` as `worker` acting as `role` in blocking
    /// mode (direct use / thread-per-worker execution). See
    /// [`Self::join_with_park`] for the full form.
    pub fn join(
        self: &Arc<Self>,
        channel: &str,
        group: &str,
        worker: &str,
        role: &str,
        backend: Backend,
        clock: Arc<Mutex<VClock>>,
    ) -> Result<ChannelHandle> {
        self.join_with_park(
            channel,
            group,
            worker,
            role,
            backend,
            clock,
            WorkerPark::blocking(RECV_TIMEOUT),
        )
    }

    /// Join `(channel, group)` as `worker` acting as `role`, sharing the
    /// worker's virtual clock and execution mode across all its channels.
    /// Returns the worker-side handle — which has its route resolved once,
    /// here: the handle's sends and receives never touch the shard map
    /// again. `role` determines what `ends()` yields: peers of the *other*
    /// endpoint role (or all other members on self-pair channels like the
    /// distributed trainer ring).
    #[allow(clippy::too_many_arguments)]
    pub fn join_with_park(
        self: &Arc<Self>,
        channel: &str,
        group: &str,
        worker: &str,
        role: &str,
        backend: Backend,
        clock: Arc<Mutex<VClock>>,
        park: Arc<WorkerPark>,
    ) -> Result<ChannelHandle> {
        let r = self.route_of(channel, group).ok_or_else(|| {
            anyhow!(
                "fabric symbol space exhausted (> 2^21 distinct names): \
                 rejecting join of '{worker}' on '{channel}/{group}'"
            )
        })?;
        let shared = self.shared_for(r, channel, backend)?;
        let me = atom(worker);
        let mailbox: Mailbox = {
            let mut members = shared.members.write().unwrap();
            let mailbox = match members.get(worker) {
                Some(m) => m.mailbox.clone(), // re-join keeps pending mail
                None => MailboxCore::new(),
            };
            members.insert(
                me.clone(),
                Member {
                    mailbox: mailbox.clone(),
                    role: atom(role),
                    remote: false,
                },
            );
            // a (re)join supersedes any earlier departure: reopen the
            // member's own mailbox and clear its name from peers'
            // departure notices so strict receives on the returned worker
            // work again
            mailbox.inner.lock().unwrap().closed = false;
            for (k, m) in members.iter() {
                if &**k != worker {
                    m.mailbox.inner.lock().unwrap().departed.retain(|d| &**d != worker);
                }
            }
            mailbox
        };
        shared.bump();
        Ok(ChannelHandle {
            mgr: self.clone(),
            shared,
            channel: atom(channel),
            group: atom(group),
            me,
            role: atom(role),
            backend,
            mailbox,
            clock,
            park,
            peers: Mutex::new(PeerCache {
                epoch: u64::MAX,
                ends: Arc::new(Vec::new()),
                roles: HashMap::new(),
            }),
        })
    }

    /// Resolve (or create) the membership record of route `r`, checking
    /// backend consistency — shared by local joins and remote shadow
    /// joins.
    fn shared_for(&self, r: Route, channel: &str, backend: Backend) -> Result<Arc<ChannelShared>> {
        let shared = {
            let mut g = self.fabric.shard(r).write().unwrap();
            g.entry(r)
                .or_insert_with(|| {
                    Arc::new(ChannelShared {
                        backend,
                        route: r,
                        hub: atom(&format!("hub:{}", self.qualified(channel))),
                        members: RwLock::new(HashMap::new()),
                        epoch: AtomicU64::new(0),
                    })
                })
                .clone()
        };
        if shared.backend != backend {
            bail!(
                "channel '{channel}' already uses backend {:?}",
                shared.backend
            );
        }
        Ok(shared)
    }

    /// Bind the inter-process transport (idempotent; first bind wins).
    /// Deliveries addressed to members registered via
    /// [`Self::join_remote`] ship through it instead of a local mailbox.
    pub fn bind_transport(&self, t: Arc<dyn Transport>) {
        let _ = self.fabric.transport.set(t);
    }

    /// Register `worker` as a **shadow member** of `(channel, group)`: a
    /// worker hosted on another OS process. It counts toward `ends()`,
    /// role membership and quorum targets exactly like a local member —
    /// which is what keeps every process's membership view (and therefore
    /// collect barriers and broadcast fan-outs) identical — but mail
    /// addressed to it is handed to the bound [`Transport`]. The
    /// multi-process deployer registers every non-local worker of the
    /// expanded job before any worker starts, mirroring the two-phase
    /// deploy ordering.
    pub fn join_remote(
        &self,
        channel: &str,
        group: &str,
        worker: &str,
        role: &str,
        backend: Backend,
    ) -> Result<()> {
        let r = self.route_of(channel, group).ok_or_else(|| {
            anyhow!(
                "fabric symbol space exhausted (> 2^21 distinct names): \
                 rejecting remote join of '{worker}' on '{channel}/{group}'"
            )
        })?;
        let shared = self.shared_for(r, channel, backend)?;
        {
            let mut members = shared.members.write().unwrap();
            if let Some(m) = members.get(worker) {
                if !m.remote {
                    bail!(
                        "worker '{worker}' is already a local member of '{channel}/{group}' \
                         — it cannot also be remote"
                    );
                }
                return Ok(()); // idempotent remote re-join
            }
            members.insert(
                atom(worker),
                Member {
                    mailbox: MailboxCore::new(),
                    role: atom(role),
                    remote: true,
                },
            );
        }
        shared.bump();
        Ok(())
    }

    /// Enqueue a message that arrived over the wire from another process
    /// into the local target's mailbox — the receiving half of
    /// [`Transport::ship`]. The arrival time was computed on the sender
    /// (the virtual-net transfer functions are pure, so both sides agree);
    /// the sequence number is assigned here, in reception order, which a
    /// FIFO per-sender stream keeps equal to the sender's program order —
    /// the only property `(arrival, sender, seq)` selection needs.
    pub fn deliver_remote(
        &self,
        route: Route,
        from: &Arc<str>,
        to: &str,
        arrival: VTime,
        msg: Message,
    ) -> Result<()> {
        let shared = self
            .fabric
            .lookup(route)
            .with_context(|| format!("wire delivery on unknown route {route:?}"))?;
        let seq = self.fabric.seq.fetch_add(1, Ordering::Relaxed);
        let mailbox = {
            let members = shared.members.read().unwrap();
            let member = members.get(to).with_context(|| {
                format!("wire delivery for '{to}', which is not joined on this process")
            })?;
            if member.remote {
                bail!("wire delivery for '{to}', which is remote here too (bad roster)");
            }
            member.mailbox.clone()
        };
        Self::enqueue(&mailbox, from, msg, arrival, seq);
        Ok(())
    }

    /// Retire `worker` from every channel group it joined (a `leave`
    /// event / device dropout). Its own mailboxes close — the worker's
    /// next receive raises [`Departed`] and the agent completes it — and
    /// every parked peer in the affected groups is woken conservatively so
    /// membership-aware collects re-evaluate their quorum target. Returns
    /// the number of memberships revoked.
    pub fn evict(&self, worker: &str, at: VTime) -> usize {
        let mut revoked = 0;
        let worker_a = atom(worker);
        for shard in &self.fabric.shards {
            let mut own: Vec<Mailbox> = Vec::new();
            let mut peers: Vec<Mailbox> = Vec::new();
            {
                let g = shard.read().unwrap();
                for (r, shared) in g.iter() {
                    // scope isolation: an eviction through this view must
                    // never touch another job's identically-named worker
                    if r.scope_sym() != self.scope_sym {
                        continue;
                    }
                    let mut members = shared.members.write().unwrap();
                    if let Some(evictee) = members.remove(worker) {
                        revoked += 1;
                        own.push(evictee.mailbox);
                        peers.extend(members.values().map(|m| m.mailbox.clone()));
                        shared.bump();
                    }
                }
            }
            for mb in own {
                let waker = {
                    let mut mg = mb.inner.lock().unwrap();
                    mg.closed = true;
                    mg.waiting.take().map(|(_, w)| w)
                };
                mb.cv.notify_all();
                if let Some(w) = waker {
                    w.wake(at);
                }
            }
            for mb in peers {
                Self::post_departure(&mb, &worker_a, at, true);
            }
        }
        revoked
    }

    /// Every cooperative wait `worker` has registered across this scope's
    /// channels — one line per parked receive, the wait-spec body of the
    /// scheduler's deadlock post-mortem. Peer sets mirror
    /// [`ChannelHandle::ends`] (other-role members, or all other members
    /// on self-pair channels). Sorted for deterministic output.
    pub fn stall_notes(&self, worker: &str) -> Vec<String> {
        let mut notes = Vec::new();
        for shard in &self.fabric.shards {
            let g = shard.read().unwrap();
            for (r, shared) in g.iter() {
                if r.scope_sym() != self.scope_sym {
                    continue;
                }
                let members = shared.members.read().unwrap();
                let Some(me) = members.get(worker) else {
                    continue;
                };
                // members-read → mailbox-inner matches the join path's
                // nesting; the mailbox guard drops before formatting.
                let desc = {
                    let mb = me.mailbox.inner.lock().unwrap();
                    match &mb.waiting {
                        None => continue,
                        Some((w, _)) => describe_wait(w),
                    }
                };
                let mut peers: Vec<&str> = members
                    .iter()
                    .filter(|(k, m)| &***k != worker && m.role != me.role)
                    .map(|(k, _)| &***k)
                    .collect();
                if peers.is_empty() {
                    peers = members
                        .keys()
                        .filter(|k| &***k != worker)
                        .map(|k| &***k)
                        .collect();
                }
                peers.sort_unstable();
                let shown: Vec<&str> = peers.iter().take(6).copied().collect();
                notes.push(format!(
                    "waiting on channel '{}' for {} (peers: [{}{}])",
                    crate::intern::name(r.channel_sym()),
                    desc,
                    shown.join(", "),
                    if peers.len() > 6 { ", ..." } else { "" }
                ));
            }
        }
        notes.sort_unstable();
        notes
    }

    /// Record `worker`'s departure on a peer mailbox; wake its parked wait
    /// if the wait depends on the leaver, or unconditionally when
    /// `conservative` (membership changed under a quorum collect).
    fn post_departure(mb: &Mailbox, worker: &Arc<str>, at: VTime, conservative: bool) {
        let waker = {
            let mut mg = mb.inner.lock().unwrap();
            if !mg.departed.iter().any(|d| d == worker) {
                mg.departed.push(worker.clone());
            }
            let depends = match &mg.waiting {
                Some((WaitSpec::Match(spec), _)) => spec.depends_on(worker),
                Some((WaitSpec::AllOf(missing), _)) => missing.iter().any(|m| m == worker),
                None => false,
            };
            if depends || (conservative && mg.waiting.is_some()) {
                mg.waiting.take().map(|(_, w)| w)
            } else {
                None
            }
        };
        mb.cv.notify_all();
        if let Some(w) = waker {
            w.wake(at);
        }
    }

    /// Members of `(channel, group)` acting as `role`, excluding
    /// `exclude`, sorted. The membership view quorum-style collects use:
    /// "the trainers currently on this channel", robust to other roles
    /// (e.g. a legacy parent) sharing the group after a live extension.
    pub fn members_of_role(
        &self,
        channel: &str,
        group: &str,
        exclude: &str,
        role: &str,
    ) -> Vec<String> {
        match self.route_of(channel, group).and_then(|r| self.fabric.lookup(r)) {
            None => Vec::new(),
            Some(shared) => {
                let members = shared.members.read().unwrap();
                let mut m: Vec<String> = members
                    .iter()
                    .filter(|(k, mem)| &***k != exclude && &*mem.role == role)
                    .map(|(k, _)| k.to_string())
                    .collect();
                m.sort();
                m
            }
        }
    }

    /// All members of `(channel, group)` (sorted), regardless of role.
    pub fn members(&self, channel: &str, group: &str) -> Vec<String> {
        match self.route_of(channel, group).and_then(|r| self.fabric.lookup(r)) {
            None => Vec::new(),
            Some(shared) => {
                let members = shared.members.read().unwrap();
                let mut m: Vec<String> = members.keys().map(|k| k.to_string()).collect();
                m.sort();
                m
            }
        }
    }

    /// Deliver `msg` from `from` to `to` on the resolved channel; computes
    /// the virtual arrival time from the backend's route. `queue_delay`
    /// models store-and-forward serialisation at the broker (fan-out
    /// copies leave the hub one after another).
    ///
    /// Only the membership read lock is held long enough to resolve the
    /// target mailbox; the enqueue takes the mailbox's own lock, so
    /// concurrent deliveries on different channels (or different workers
    /// of one channel) do not serialise. Nothing here allocates.
    #[allow(clippy::too_many_arguments)]
    fn deliver(
        &self,
        shared: &ChannelShared,
        diag: (&str, &str),
        backend: Backend,
        from: &Arc<str>,
        from_clock: VTime,
        to: &str,
        msg: Message,
        queue_delay: VTime,
    ) -> Result<VTime> {
        let bytes = msg.size_bytes();
        let arrival = match backend {
            Backend::InProc => from_clock,
            // Tcp charges exactly one direct hop, same as P2p: identical
            // virtual-time arithmetic is what makes the in-process run of
            // a `backend: "tcp"` job the multi-process byte-parity oracle.
            Backend::P2p | Backend::Tcp => {
                from_clock + self.fabric.net.transfer_at_us(from, to, bytes, from_clock)
            }
            Backend::Broker => {
                from_clock
                    + queue_delay
                    + self
                        .fabric
                        .net
                        .transfer_via_at_us(from, &shared.hub, to, bytes, from_clock)
            }
        };
        // transfer span charged exactly as the net model charged the
        // message: send clock -> computed arrival. No-op (one atomic
        // load) for untraced jobs.
        if let Some(t) = self.trace.get() {
            t.transfer(from, to, msg.round, from_clock, arrival, bytes);
        }
        let (mailbox, remote) = {
            let members = shared.members.read().unwrap();
            let member = members.get(to).with_context(|| {
                format!("peer '{to}' not joined on '{}/{}'", diag.0, diag.1)
            })?;
            (member.mailbox.clone(), member.remote)
        };
        if remote {
            // the target lives on another OS process: hand the framed
            // message (with its already-computed arrival) to the wire.
            // Best-effort: a dead peer surfaces through evict/Departed,
            // not through send failures.
            self.fabric
                .transport
                .get()
                .with_context(|| {
                    format!("remote member '{to}' on '{}/{}' but no transport bound", diag.0, diag.1)
                })?
                .ship(shared.route, from, to, arrival, &msg)?;
            return Ok(arrival);
        }
        let seq = self.fabric.seq.fetch_add(1, Ordering::Relaxed);
        Self::enqueue(&mailbox, from, msg, arrival, seq);
        Ok(arrival)
    }

    /// The delivery tail shared by local sends and wire receptions: check
    /// the parked wait-spec, push the envelope, wake. Only the target
    /// mailbox's own lock is taken; nothing here allocates.
    fn enqueue(mailbox: &Mailbox, from: &Arc<str>, msg: Message, arrival: VTime, seq: u64) {
        let waker = {
            let mut g = mailbox.inner.lock().unwrap();
            let satisfied = match &mut g.waiting {
                Some((WaitSpec::Match(spec), _)) => spec.matches_parts(from, &msg.kind),
                Some((WaitSpec::AllOf(missing), _)) => {
                    missing.retain(|m| m != from);
                    missing.is_empty()
                }
                None => false,
            };
            g.queue.push_back(Envelope {
                from: from.clone(),
                msg,
                arrival,
                seq,
            });
            if satisfied {
                g.waiting.take().map(|(_, w)| w)
            } else {
                None
            }
        };
        mailbox.cv.notify_all();
        if let Some(w) = waker {
            w.wake(arrival);
        }
    }
}

/// Epoch-stamped peer-list cache (one per handle): `ends()` and
/// `ends_of_role()` are O(1) pointer clones until membership actually
/// changes.
struct PeerCache {
    epoch: u64,
    ends: Arc<Vec<String>>,
    roles: HashMap<String, Arc<Vec<String>>>,
}

/// Worker-side endpoint implementing the paper's Table 2 API.
pub struct ChannelHandle {
    mgr: Arc<ChannelManager>,
    /// Route resolved once at join: the hot path never re-keys the shard
    /// map.
    shared: Arc<ChannelShared>,
    channel: Arc<str>,
    group: Arc<str>,
    me: Arc<str>,
    role: Arc<str>,
    backend: Backend,
    mailbox: Mailbox,
    clock: Arc<Mutex<VClock>>,
    park: Arc<WorkerPark>,
    peers: Mutex<PeerCache>,
}

impl ChannelHandle {
    pub fn name(&self) -> &str {
        &self.channel
    }

    pub fn group(&self) -> &str {
        &self.group
    }

    pub fn backend(&self) -> Backend {
        self.backend
    }

    pub fn worker_id(&self) -> &str {
        &self.me
    }

    /// Current virtual time at this worker.
    pub fn now(&self) -> VTime {
        self.clock.lock().unwrap().now()
    }

    /// Leave the channel and deallocate its resources (Table 2 `leave`).
    /// Remaining members get a departure notice, and any peer parked on
    /// mail only this worker could send is cancelled promptly (it errors
    /// instead of stranding until a timeout or the deadlock detector).
    pub fn leave(self) {
        let at = self.now();
        let peers: Vec<Mailbox> = {
            let mut members = self.shared.members.write().unwrap();
            match members.remove(&*self.me) {
                Some(_) => members.values().map(|m| m.mailbox.clone()).collect(),
                None => return,
            }
        };
        self.shared.bump();
        for mb in peers {
            ChannelManager::post_departure(&mb, &self.me, at, false);
        }
    }

    /// Rebuild this handle's other-end peer list from current membership:
    /// members of a different role, or — when every member shares one role
    /// (self-pair channel) — all other members. Sorted for determinism.
    fn compute_ends(&self) -> Vec<String> {
        let members = self.shared.members.read().unwrap();
        let other_role: Vec<String> = members
            .iter()
            .filter(|(k, m)| ***k != *self.me && m.role != self.role)
            .map(|(k, _)| k.to_string())
            .collect();
        let mut peers = if other_role.is_empty() {
            members
                .keys()
                .filter(|k| ***k != *self.me)
                .map(|k| k.to_string())
                .collect()
        } else {
            other_role
        };
        peers.sort();
        peers
    }

    /// Lock the peer cache, refreshing it first if membership moved past
    /// the stamped epoch — the single invalidation point for both `ends`
    /// and `ends_of_role`.
    fn refreshed_peers(&self) -> std::sync::MutexGuard<'_, PeerCache> {
        let cur = self.shared.epoch.load(Ordering::Acquire);
        let mut c = self.peers.lock().unwrap();
        if c.epoch != cur {
            c.ends = Arc::new(self.compute_ends());
            c.roles.clear();
            c.epoch = cur;
        }
        c
    }

    /// Peers at the other end of the channel (Table 2 `ends`), sorted for
    /// determinism. Group-scoped: only members of this worker's group, and
    /// role-scoped: only the *other* endpoint role (all other members on
    /// self-pair channels). Served from the epoch-stamped cache: steady
    /// state costs one atomic load and an `Arc` clone.
    pub fn ends(&self) -> Arc<Vec<String>> {
        self.refreshed_peers().ends.clone()
    }

    /// Check if peers exist at the other end (Table 2 `empty`).
    pub fn empty(&self) -> bool {
        self.ends().is_empty()
    }

    /// Current members of this worker's group acting as `role` (excluding
    /// this worker), sorted. Unlike [`Self::ends`], which yields *all*
    /// other-role peers, this scopes to one role — the membership view
    /// churn-safe collects intersect their peer list against. Cached per
    /// role under the same membership epoch as `ends`.
    pub fn ends_of_role(&self, role: &str) -> Arc<Vec<String>> {
        let mut c = self.refreshed_peers();
        if let Some(v) = c.roles.get(role) {
            return v.clone();
        }
        let v = Arc::new(self.compute_role_members(role));
        c.roles.insert(role.to_string(), v.clone());
        v
    }

    /// Rebuild one role's member list from the handle's cached channel
    /// state — no shard-map or interner traffic (the route stays resolved
    /// once, at join).
    fn compute_role_members(&self, role: &str) -> Vec<String> {
        let members = self.shared.members.read().unwrap();
        let mut m: Vec<String> = members
            .iter()
            .filter(|(k, mem)| ***k != *self.me && &*mem.role == role)
            .map(|(k, _)| k.to_string())
            .collect();
        m.sort();
        m
    }

    /// Send `msg` to `end` (Table 2 `send`).
    pub fn send(&self, end: &str, msg: Message) -> Result<()> {
        let now = self.clock.lock().unwrap().now();
        self.mgr.deliver(
            &self.shared,
            (&*self.channel, &*self.group),
            self.backend,
            &self.me,
            now,
            end,
            msg,
            0,
        )?;
        Ok(())
    }

    /// The shared fan-out core: deliver one copy per `(peer, message)` at
    /// send time `now`. On broker channels the copies serialise through
    /// the hub (store-and-forward): message `i` queues behind the hub
    /// legs of all earlier ones — the broker contention that makes
    /// broadcast-heavy rounds expensive in the paper's §6.2 MQTT setup.
    fn fanout_iter<S: AsRef<str>>(
        &self,
        now: VTime,
        items: impl Iterator<Item = (S, Message)>,
    ) -> Result<usize> {
        let mut queued: VTime = 0;
        let mut n = 0;
        for (to, msg) in items {
            let to = to.as_ref();
            let extra = queued;
            if self.backend == Backend::Broker {
                queued += self
                    .mgr
                    .fabric
                    .net
                    .transfer_at_us(&self.shared.hub, to, msg.size_bytes(), now);
            }
            self.mgr.deliver(
                &self.shared,
                (&*self.channel, &*self.group),
                self.backend,
                &self.me,
                now,
                to,
                msg,
                extra,
            )?;
            n += 1;
        }
        Ok(n)
    }

    /// Fan a batch of per-peer messages out in one shot (see
    /// [`Self::fanout_iter`] for the broker serialisation model).
    pub fn send_fanout(&self, items: Vec<(String, Message)>) -> Result<usize> {
        let now = self.clock.lock().unwrap().now();
        self.fanout_iter(now, items.into_iter())
    }

    /// Broadcast `msg` to all peers (Table 2 `broadcast`). Fan-out walks
    /// the cached peer list and clones the message per peer — payload,
    /// kind and metadata are all `Arc`-shared, so each copy is three
    /// pointer bumps; broker fan-out serialises at the hub (see
    /// [`Self::fanout_iter`]).
    pub fn broadcast(&self, msg: Message) -> Result<usize> {
        let peers = self.ends();
        let now = self.clock.lock().unwrap().now();
        self.fanout_iter(now, peers.iter().map(|p| (p.as_str(), msg.clone())))
    }

    /// Receive the earliest message from `end` (Table 2 `recv`). Blocks in
    /// blocking mode; yields [`crate::sched::Pending`] in cooperative mode.
    /// Merges the worker clock with the message's virtual arrival time.
    pub fn recv(&self, end: &str) -> Result<Message> {
        Ok(self.take_match(&MatchSpec::From(atom(end)))?.msg)
    }

    /// Receive the earliest message from `end` with the given kind.
    pub fn recv_kind(&self, end: &str, kind: &str) -> Result<Message> {
        Ok(self
            .take_match(&MatchSpec::FromKind(atom(end), atom(kind)))?
            .msg)
    }

    /// Receive the earliest message from *any* peer; returns `(from, msg)`.
    pub fn recv_any(&self) -> Result<(Arc<str>, Message)> {
        let e = self.take_match(&MatchSpec::Any)?;
        Ok((e.from, e.msg))
    }

    /// Receive the earliest message of `kind` from any peer.
    pub fn recv_any_kind(&self, kind: &str) -> Result<(Arc<str>, Message)> {
        let e = self.take_match(&MatchSpec::AnyKind(atom(kind)))?;
        Ok((e.from, e.msg))
    }

    /// Like [`Self::recv_any_kind`] but also returns the message's virtual
    /// arrival time (needed when the receiver must attribute per-sender
    /// timing independent of its own merged clock, e.g. CO-FL acks).
    pub fn recv_any_kind_timed(&self, kind: &str) -> Result<(Arc<str>, Message, VTime)> {
        let e = self.take_match(&MatchSpec::AnyKind(atom(kind)))?;
        Ok((e.from, e.msg, e.arrival))
    }

    /// Consume the earliest envelope matching `spec`, or park. Cooperative
    /// parking registers `spec` on the mailbox *under the mailbox lock*, so
    /// a concurrent delivery either sees the registration (and wakes us) or
    /// happened before it (and is found by the scan) — no lost wakeups.
    fn take_match(&self, spec: &MatchSpec) -> Result<Envelope> {
        let core = &*self.mailbox;
        let mut g = core.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(departed_err());
            }
            if let Some(i) = best_index(&g.queue, spec) {
                let env = g.queue.remove(i).unwrap();
                drop(g);
                self.clock.lock().unwrap().merge(env.arrival);
                return Ok(env);
            }
            // no mail, and the only peer that could send it has left:
            // fail promptly rather than strand
            if let Some(gone) = g.departed.iter().find(|d| spec.depends_on(d)) {
                bail!(
                    "peer '{gone}' left channel '{}' group '{}' while '{}' was waiting for its mail",
                    self.channel,
                    self.group,
                    self.me
                );
            }
            if self.park.is_cooperative() {
                let waker = self.park.waker().ok_or_else(|| {
                    anyhow!("cooperative worker '{}' has no scheduler waker", self.me)
                })?;
                g.waiting = Some((WaitSpec::Match(spec.clone()), waker));
                return Err(pending_err());
            }
            let (ng, timeout) = core.cv.wait_timeout(g, self.park.timeout()).unwrap();
            g = ng;
            if timeout.timed_out() {
                bail!(
                    "recv timeout on channel '{}' group '{}' at worker '{}'",
                    self.channel,
                    self.group,
                    self.me
                );
            }
        }
    }

    /// Receive one message from each of `ends`, yielded in FIFO order of
    /// virtual arrival (Table 2 `recv_fifo`). Waits until all have arrived
    /// (the aggregation barrier) and only then consumes — an atomic
    /// all-or-nothing take, so a cooperative yield leaves the mailbox
    /// untouched and the calling tasklet safely re-runnable. The worker
    /// clock ends at the latest arrival.
    pub fn recv_fifo(&self, ends: &[String]) -> Result<Vec<(String, Message)>> {
        // one message per *unique* end (duplicate entries collapse, as in
        // the pending-set semantics of the original implementation)
        let mut unique: Vec<&String> = Vec::with_capacity(ends.len());
        for end in ends {
            if !unique.contains(&end) {
                unique.push(end);
            }
        }
        let core = &*self.mailbox;
        let mut g = core.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(departed_err());
            }
            let missing: Vec<Arc<str>> = unique
                .iter()
                .filter(|end| !g.queue.iter().any(|e| &*e.from == end.as_str()))
                .map(|e| atom(e.as_str()))
                .collect();
            if missing.is_empty() {
                break;
            }
            // a still-missing sender has left: the barrier can never close
            if let Some(gone) = missing.iter().find(|m| g.departed.contains(*m)) {
                bail!(
                    "peer '{gone}' left channel '{}' group '{}' during a recv_fifo barrier at '{}'",
                    self.channel,
                    self.group,
                    self.me
                );
            }
            if self.park.is_cooperative() {
                let waker = self.park.waker().ok_or_else(|| {
                    anyhow!("cooperative worker '{}' has no scheduler waker", self.me)
                })?;
                g.waiting = Some((WaitSpec::AllOf(missing), waker));
                return Err(pending_err());
            }
            let (ng, timeout) = core.cv.wait_timeout(g, self.park.timeout()).unwrap();
            g = ng;
            if timeout.timed_out() {
                bail!(
                    "recv_fifo timeout on channel '{}' group '{}' at worker '{}' \
                     (missing {} of {} peers)",
                    self.channel,
                    self.group,
                    self.me,
                    missing.len(),
                    unique.len()
                );
            }
        }
        let mut got: Vec<Envelope> = Vec::with_capacity(unique.len());
        for end in &unique {
            let spec = MatchSpec::From(atom(end));
            let i = best_index(&g.queue, &spec).expect("presence checked above");
            got.push(g.queue.remove(i).unwrap());
        }
        drop(g);
        {
            let mut clk = self.clock.lock().unwrap();
            for e in &got {
                clk.merge(e.arrival);
            }
        }
        got.sort_by(|a, b| (a.arrival, &a.from).cmp(&(b.arrival, &b.from)));
        Ok(got.into_iter().map(|e| (e.from.to_string(), e.msg)).collect())
    }

    /// Peek (without consuming) the earliest message from `end`
    /// (Table 2 `peek`). Does not advance the clock.
    pub fn peek(&self, end: &str) -> Option<Message> {
        let g = self.mailbox.inner.lock().unwrap();
        best_index(&g.queue, &MatchSpec::From(atom(end))).map(|i| g.queue[i].msg.clone())
    }

    /// Non-blocking: is any message from `end` available?
    pub fn has_message(&self, end: &str) -> bool {
        self.peek(end).is_some()
    }

    /// Advance this worker's virtual clock (compute time accounting).
    pub fn advance_clock(&self, dt: VTime) {
        self.clock.lock().unwrap().advance(dt);
    }

    /// What this handle's worker is parked on, if anything — the wait-spec
    /// and peer set line of the scheduler's deadlock post-mortem. `None`
    /// when no cooperative wait is registered on the mailbox.
    ///
    /// The wait description is copied out under the mailbox lock and the
    /// peer set is gathered *after* dropping it: the join path nests
    /// mailbox locks inside the membership lock, so taking membership
    /// locks while holding a mailbox lock could cycle.
    pub fn stall_note(&self) -> Option<String> {
        let desc = {
            let g = self.mailbox.inner.lock().unwrap();
            match &g.waiting {
                None => return None,
                Some((w, _)) => describe_wait(w),
            }
        };
        let peers = self.ends();
        let shown: Vec<&str> = peers.iter().take(6).map(|s| s.as_str()).collect();
        Some(format!(
            "waiting on channel '{}' for {} (peers: [{}{}])",
            self.channel,
            desc,
            shown.join(", "),
            if peers.len() > 6 { ", ..." } else { "" }
        ))
    }
}

/// Human-readable form of a registered cooperative wait — the wait-spec
/// half of a deadlock post-mortem line.
fn describe_wait(w: &WaitSpec) -> String {
    match w {
        WaitSpec::Match(spec) => match spec {
            MatchSpec::From(f) => format!("a message from '{f}'"),
            MatchSpec::FromKind(f, k) => format!("a '{k}' message from '{f}'"),
            MatchSpec::Any => "any message".to_string(),
            MatchSpec::AnyKind(k) => format!("any '{k}' message"),
        },
        WaitSpec::AllOf(missing) => {
            let shown: Vec<&str> = missing.iter().take(4).map(|m| &**m).collect();
            format!(
                "a barrier with {} outstanding sender(s) [{}{}]",
                missing.len(),
                shown.join(", "),
                if missing.len() > 4 { ", ..." } else { "" }
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkSpec;

    fn setup(backend: Backend) -> (Arc<ChannelManager>, ChannelHandle, ChannelHandle) {
        let net = Arc::new(VirtualNet::new(LinkSpec::mbps(8.0, 100)));
        let mgr = ChannelManager::new(net);
        let ca = Arc::new(Mutex::new(VClock::default()));
        let cb = Arc::new(Mutex::new(VClock::default()));
        let a = mgr.join("param", "default", "a", "trainer", backend, ca).unwrap();
        let b = mgr.join("param", "default", "b", "aggregator", backend, cb).unwrap();
        (mgr, a, b)
    }

    #[test]
    fn send_recv_roundtrip() {
        let (_m, a, b) = setup(Backend::P2p);
        a.send("b", Message::control("hello", 1)).unwrap();
        let msg = b.recv("a").unwrap();
        assert_eq!(&*msg.kind, "hello");
        assert_eq!(msg.round, 1);
    }

    #[test]
    fn virtual_time_advances_on_recv() {
        let (_m, a, b) = setup(Backend::P2p);
        // 1 MB over 8 Mbps = 1s + 100us
        let w = Arc::new(vec![0f32; 250_000]);
        a.send("b", Message::floats("weights", 0, w)).unwrap();
        b.recv("a").unwrap();
        assert!(b.now() >= 1_000_000, "clock={}", b.now());
        assert_eq!(a.now(), 0, "sender clock unaffected by send");
    }

    #[test]
    fn broker_costs_two_hops() {
        let (_m, a, b) = setup(Backend::Broker);
        let w = Arc::new(vec![0f32; 250_000]);
        a.send("b", Message::floats("weights", 0, w.clone())).unwrap();
        b.recv("a").unwrap();
        let broker_t = b.now();

        let (_m2, a2, b2) = setup(Backend::P2p);
        a2.send("b", Message::floats("weights", 0, w)).unwrap();
        b2.recv("a").unwrap();
        assert!(
            broker_t > b2.now() && broker_t <= 2 * b2.now() + 1000,
            "broker {} vs p2p {}",
            broker_t,
            b2.now()
        );
    }

    #[test]
    fn inproc_is_free() {
        let (_m, a, b) = setup(Backend::InProc);
        a.send("b", Message::floats("w", 0, Arc::new(vec![0f32; 1_000_000])))
            .unwrap();
        b.recv("a").unwrap();
        assert_eq!(b.now(), 0);
    }

    #[test]
    fn ends_and_empty() {
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let mk = |id: &str, role: &str| {
            mgr.join(
                "c",
                "g",
                id,
                role,
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
            )
            .unwrap()
        };
        let a = mk("agg", "aggregator");
        assert!(a.empty());
        let _t1 = mk("t1", "trainer");
        let _t2 = mk("t2", "trainer");
        assert_eq!(*a.ends(), vec!["t1".to_string(), "t2".into()]);
        assert!(!a.empty());
    }

    #[test]
    fn ends_cache_tracks_membership_epoch() {
        // the epoch-stamped cache must serve identical Arcs while
        // membership is stable and refresh exactly when it changes
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let mk = |id: &str, role: &str| {
            mgr.join(
                "c",
                "g",
                id,
                role,
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
            )
            .unwrap()
        };
        let a = mk("agg", "aggregator");
        let _t1 = mk("t1", "trainer");
        let e1 = a.ends();
        let e2 = a.ends();
        assert!(Arc::ptr_eq(&e1, &e2), "stable membership must reuse the cache");
        let t2 = mk("t2", "trainer");
        assert_eq!(*a.ends(), vec!["t1".to_string(), "t2".into()]);
        t2.leave();
        assert_eq!(*a.ends(), vec!["t1".to_string()]);
        mgr.evict("t1", 0);
        assert!(a.ends().is_empty());
    }

    #[test]
    fn message_clones_share_payload_kind_and_meta() {
        let mut meta = Json::obj();
        meta.insert("samples", 64usize);
        let msg = Message::floats("update", 3, Arc::new(vec![1.0; 16])).with_meta(Json::Obj(meta));
        let copy = msg.clone();
        assert!(Arc::ptr_eq(&msg.kind, &copy.kind), "kind must be shared");
        assert!(Arc::ptr_eq(&msg.meta, &copy.meta), "meta must be shared");
        let (Payload::Floats(a), Payload::Floats(b)) = (&msg.payload, &copy.payload) else {
            panic!("floats payload expected");
        };
        assert!(Arc::ptr_eq(a, b), "payload must be shared");
        assert_eq!(msg.size_bytes(), copy.size_bytes());
        // interning: two messages with the same kind share one atom
        let other = Message::control("update", 9);
        assert!(Arc::ptr_eq(&msg.kind, &other.kind));
    }

    #[test]
    fn group_isolation() {
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let mk = |id: &str, g: &str, role: &str| {
            mgr.join(
                "param",
                g,
                id,
                role,
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
            )
            .unwrap()
        };
        let w = mk("west-agg", "west", "aggregator");
        let _w1 = mk("w1", "west", "trainer");
        let _e1 = mk("e1", "east", "trainer");
        assert_eq!(*w.ends(), vec!["w1".to_string()]);
    }

    #[test]
    fn leave_removes_membership() {
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let mk = |id: &str, role: &str| {
            mgr.join(
                "c",
                "g",
                id,
                role,
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
            )
            .unwrap()
        };
        let a = mk("a", "trainer");
        let b = mk("b", "aggregator");
        b.leave();
        assert!(a.empty());
    }

    #[test]
    fn broadcast_reaches_all_peers() {
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let mk = |id: &str, role: &str| {
            mgr.join(
                "c",
                "g",
                id,
                role,
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
            )
            .unwrap()
        };
        let agg = mk("agg", "aggregator");
        let t1 = mk("t1", "trainer");
        let t2 = mk("t2", "trainer");
        let n = agg.broadcast(Message::control("start", 3)).unwrap();
        assert_eq!(n, 2);
        assert_eq!(t1.recv("agg").unwrap().round, 3);
        assert_eq!(t2.recv("agg").unwrap().round, 3);
    }

    #[test]
    fn recv_fifo_orders_by_virtual_arrival() {
        let net = Arc::new(VirtualNet::new(LinkSpec::mbps(100.0, 0)));
        net.set_uplink("slow", LinkSpec::mbps(1.0, 0));
        let mgr = ChannelManager::new(net);
        let mk = |id: &str, role: &str| {
            mgr.join(
                "c",
                "g",
                id,
                role,
                Backend::P2p,
                Arc::new(Mutex::new(VClock::default())),
            )
            .unwrap()
        };
        let agg = mk("agg", "aggregator");
        let slow = mk("slow", "trainer");
        let fast = mk("fast", "trainer");
        let w = Arc::new(vec![0f32; 100_000]);
        // slow sends FIRST in real time, but arrives later in virtual time.
        slow.send("agg", Message::floats("u", 0, w.clone())).unwrap();
        fast.send("agg", Message::floats("u", 0, w)).unwrap();
        let got = agg
            .recv_fifo(&["slow".to_string(), "fast".to_string()])
            .unwrap();
        assert_eq!(got[0].0, "fast");
        assert_eq!(got[1].0, "slow");
        // barrier clock = slowest arrival
        assert!(agg.now() >= 3_000_000, "clock={}", agg.now());
    }

    #[test]
    fn peek_does_not_consume_or_advance_clock() {
        let (_m, a, b) = setup(Backend::P2p);
        a.send("b", Message::control("x", 7)).unwrap();
        // wait for delivery (delivery is synchronous in-process)
        assert!(b.peek("a").is_some());
        assert_eq!(b.now(), 0);
        assert_eq!(b.recv("a").unwrap().round, 7);
        assert!(b.peek("a").is_none());
    }

    #[test]
    fn stall_note_reports_the_registered_wait() {
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let sched = crate::sched::Scheduler::new();
        let park = WorkerPark::cooperative();
        let t = mgr
            .join_with_park(
                "param",
                "default",
                "t0",
                "trainer",
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
                park.clone(),
            )
            .unwrap();
        let _agg = mgr.join(
            "param",
            "default",
            "agg",
            "aggregator",
            Backend::InProc,
            Arc::new(Mutex::new(VClock::default())),
        );
        assert!(t.stall_note().is_none(), "no wait registered yet");
        // a cooperative receive with no mail registers its wait and yields
        park.set_waker(sched.waker(sched.spawn_parked(Box::new(NoopTask))));
        let err = t.recv("agg").unwrap_err();
        assert!(crate::sched::is_pending(&err));
        let note = t.stall_note().expect("wait must be registered");
        assert!(note.contains("channel 'param'"), "{note}");
        assert!(note.contains("a message from 'agg'"), "{note}");
        assert!(note.contains("peers: [agg]"), "{note}");
    }

    struct NoopTask;
    impl crate::sched::RunnableTask for NoopTask {
        fn name(&self) -> &str {
            "noop"
        }
        fn poll(&mut self) -> crate::sched::PollOutcome {
            crate::sched::PollOutcome::Done
        }
        fn fail(&mut self, _reason: &str) {}
    }

    #[test]
    fn recv_kind_filters() {
        let (_m, a, b) = setup(Backend::InProc);
        a.send("b", Message::control("report", 1)).unwrap();
        a.send("b", Message::control("weights", 2)).unwrap();
        let m = b.recv_kind("a", "weights").unwrap();
        assert_eq!(m.round, 2);
        let m = b.recv("a").unwrap();
        assert_eq!(&*m.kind, "report");
    }

    #[test]
    fn send_to_unjoined_peer_errors() {
        let (_m, a, _b) = setup(Backend::InProc);
        assert!(a.send("ghost", Message::control("x", 0)).is_err());
    }

    #[test]
    fn cross_thread_send_recv() {
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let agg = mgr
            .join(
                "c",
                "g",
                "agg",
                "aggregator",
                Backend::P2p,
                Arc::new(Mutex::new(VClock::default())),
            )
            .unwrap();
        let mut handles = vec![];
        for i in 0..4 {
            let mgr = mgr.clone();
            handles.push(std::thread::spawn(move || {
                let t = mgr
                    .join(
                        "c",
                        "g",
                        &format!("t{i}"),
                        "trainer",
                        Backend::P2p,
                        Arc::new(Mutex::new(VClock::default())),
                    )
                    .unwrap();
                t.send("agg", Message::control("u", i)).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let ends: Vec<String> = (0..4).map(|i| format!("t{i}")).collect();
        let got = agg.recv_fifo(&ends).unwrap();
        assert_eq!(got.len(), 4);
    }

    #[test]
    fn backend_mismatch_on_join_errors() {
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let c = Arc::new(Mutex::new(VClock::default()));
        mgr.join("c", "g", "a", "trainer", Backend::P2p, c.clone()).unwrap();
        assert!(mgr.join("c", "g", "b", "aggregator", Backend::Broker, c).is_err());
    }

    #[test]
    fn recv_fifo_collapses_duplicate_ends() {
        let (_m, a, b) = setup(Backend::InProc);
        a.send("b", Message::control("u", 1)).unwrap();
        let got = b
            .recv_fifo(&["a".to_string(), "a".to_string()])
            .unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].0, "a");
    }

    #[test]
    fn backend_parse_roundtrips_and_aliases() {
        for b in [Backend::InProc, Backend::P2p, Backend::Broker, Backend::Tcp] {
            assert_eq!(Backend::parse(b.name()).unwrap(), b);
        }
        assert_eq!(Backend::parse("local").unwrap(), Backend::InProc);
        assert_eq!(Backend::parse("grpc").unwrap(), Backend::P2p);
        assert_eq!(Backend::parse("mqtt").unwrap(), Backend::Broker);
        assert_eq!(Backend::parse("kafka").unwrap(), Backend::Broker);
        let err = Backend::parse("carrier-pigeon").unwrap_err().to_string();
        // unknown substrates must name the full valid list
        for (n, _) in Backend::SUBSTRATES {
            assert!(err.contains(n), "error '{err}' missing substrate '{n}'");
        }
    }

    #[test]
    fn rejoin_keeps_pending_mail() {
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let clock = Arc::new(Mutex::new(VClock::default()));
        let a = mgr
            .join("c", "g", "a", "trainer", Backend::InProc, clock.clone())
            .unwrap();
        let _b = mgr
            .join("c", "g", "b", "aggregator", Backend::InProc, clock.clone())
            .unwrap();
        a.send("b", Message::control("kept", 9)).unwrap();
        // b re-joins (e.g. worker restart): its mailbox must survive
        let b2 = mgr
            .join("c", "g", "b", "aggregator", Backend::InProc, clock)
            .unwrap();
        assert_eq!(&*b2.recv("a").unwrap().kind, "kept");
    }

    #[test]
    fn self_pair_channel_peers_are_all_other_members() {
        // a distributed ring: every member has the same role, so ends()
        // must yield all *other* members, per member.
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let mk = |id: &str| {
            mgr.join(
                "ring",
                "g",
                id,
                "trainer",
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
            )
            .unwrap()
        };
        let t0 = mk("t0");
        let t1 = mk("t1");
        let t2 = mk("t2");
        assert_eq!(*t0.ends(), vec!["t1".to_string(), "t2".into()]);
        assert_eq!(*t1.ends(), vec!["t0".to_string(), "t2".into()]);
        assert_eq!(*t2.ends(), vec!["t0".to_string(), "t1".into()]);
        assert_eq!(mgr.members("ring", "g").len(), 3);
        // single member: no peers, still a valid (empty) channel end set
        let solo = mgr
            .join(
                "ring2",
                "g",
                "solo",
                "trainer",
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
            )
            .unwrap();
        assert!(solo.ends().is_empty());
        assert!(solo.empty());
    }

    #[test]
    fn leave_cancels_dependent_blocking_recv() {
        // regression: a parked recv waiting on a leaver must be cancelled
        // promptly — not strand until the wall-clock timeout fires.
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let mk = |id: &str, role: &str| {
            mgr.join(
                "c",
                "g",
                id,
                role,
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
            )
            .unwrap()
        };
        let a = mk("a", "trainer");
        let b = mk("b", "aggregator");
        let t0 = std::time::Instant::now();
        let waiter = std::thread::spawn(move || a.recv("b"));
        std::thread::sleep(Duration::from_millis(50));
        b.leave();
        let err = waiter.join().unwrap().unwrap_err();
        assert!(format!("{err:#}").contains("left channel"), "{err:#}");
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "recv stranded for {:?} instead of being cancelled",
            t0.elapsed()
        );
    }

    #[test]
    fn leave_fails_cooperative_wait_without_stranding() {
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let mk = |id: &str, role: &str| {
            mgr.join_with_park(
                "c",
                "g",
                id,
                role,
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
                WorkerPark::cooperative(),
            )
            .unwrap()
        };
        let a = mk("a", "trainer");
        let b = mk("b", "aggregator");
        b.leave();
        // the departure notice fires before the park, so no waker is needed
        let err = a.recv("b").unwrap_err();
        assert!(!crate::sched::is_pending(&err));
        assert!(format!("{err:#}").contains("left channel"), "{err:#}");
        // barriers fail the same way
        let err = a.recv_fifo(&["b".to_string()]).unwrap_err();
        assert!(format!("{err:#}").contains("recv_fifo barrier"), "{err:#}");
    }

    #[test]
    fn mail_sent_before_leave_stays_consumable() {
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let mk = |id: &str, role: &str| {
            mgr.join_with_park(
                "c",
                "g",
                id,
                role,
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
                WorkerPark::cooperative(),
            )
            .unwrap()
        };
        let a = mk("a", "trainer");
        let b = mk("b", "aggregator");
        b.send("a", Message::control("parting-gift", 3)).unwrap();
        b.leave();
        assert_eq!(a.recv("b").unwrap().round, 3);
        assert!(a.recv("b").is_err());
    }

    #[test]
    fn rejoin_supersedes_departure_notice() {
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let mk = |id: &str, role: &str| {
            mgr.join_with_park(
                "c",
                "g",
                id,
                role,
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
                WorkerPark::cooperative(),
            )
            .unwrap()
        };
        let a = mk("a", "trainer");
        let b = mk("b", "aggregator");
        b.leave();
        assert!(a.recv("b").is_err());
        // b comes back: strict receives on it must work again
        let b2 = mk("b", "aggregator");
        b2.send("a", Message::control("back", 4)).unwrap();
        assert_eq!(a.recv("b").unwrap().round, 4);
        // and an evicted-then-rejoined worker's mailbox reopens
        mgr.evict("b", 1);
        let b3 = mk("b", "aggregator");
        a.send("b", Message::control("hi", 5)).unwrap();
        assert_eq!(b3.recv("a").unwrap().round, 5);
    }

    #[test]
    fn evict_closes_worker_and_notifies_peers() {
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let mk = |id: &str, role: &str| {
            mgr.join_with_park(
                "c",
                "g",
                id,
                role,
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
                WorkerPark::cooperative(),
            )
            .unwrap()
        };
        let a = mk("agg", "aggregator");
        let b = mk("t1", "trainer");
        let _c = mk("t2", "trainer");
        assert_eq!(mgr.evict("t1", 5), 1);
        // the evictee's own receive raises the clean-retirement signal
        let err = b.recv("agg").unwrap_err();
        assert!(is_departed(&err), "{err:#}");
        // peers see the departure and updated membership
        let err = a.recv("t1").unwrap_err();
        assert!(format!("{err:#}").contains("left channel"), "{err:#}");
        assert_eq!(*a.ends(), vec!["t2".to_string()]);
        // evicting an unknown worker is a no-op
        assert_eq!(mgr.evict("ghost", 5), 0);
    }

    #[test]
    fn ends_of_role_scopes_membership() {
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let mk = |id: &str, role: &str| {
            mgr.join(
                "c",
                "g",
                id,
                role,
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
            )
            .unwrap()
        };
        let agg = mk("agg", "aggregator");
        let _t1 = mk("t1", "trainer");
        let _t2 = mk("t2", "trainer");
        let _g = mk("global", "global-aggregator");
        // ends() mixes every other role; ends_of_role scopes to one
        assert_eq!(agg.ends().len(), 3);
        assert_eq!(*agg.ends_of_role("trainer"), vec!["t1".to_string(), "t2".into()]);
        assert_eq!(
            *agg.ends_of_role("global-aggregator"),
            vec!["global".to_string()]
        );
        assert!(agg.ends_of_role("coordinator").is_empty());
        // role caches refresh on membership change too
        let _t3 = mk("t3", "trainer");
        assert_eq!(agg.ends_of_role("trainer").len(), 3);
    }

    #[test]
    fn deterministic_tie_break_orders_by_sender() {
        // two same-arrival-time messages (InProc, clocks at 0) must come
        // out ordered by sender name regardless of send interleaving
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let mk = |id: &str, role: &str| {
            mgr.join(
                "c",
                "g",
                id,
                role,
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
            )
            .unwrap()
        };
        let agg = mk("agg", "aggregator");
        let z = mk("z", "trainer");
        let a = mk("a", "trainer");
        z.send("agg", Message::control("u", 0)).unwrap();
        a.send("agg", Message::control("u", 0)).unwrap();
        let (from1, _) = agg.recv_any().unwrap();
        let (from2, _) = agg.recv_any().unwrap();
        assert_eq!(&*from1, "a");
        assert_eq!(&*from2, "z");
    }

    #[test]
    fn scoped_views_isolate_identical_names_on_one_fabric() {
        // two jobs with byte-identical channel, group, worker and role
        // names share one fabric — the multi-job control plane setup
        let root = ChannelManager::new(Arc::new(VirtualNet::default()));
        let j1 = root.scoped("cfl-1");
        let j2 = root.scoped("cfl-2");
        let mk = |mgr: &Arc<ChannelManager>, id: &str, role: &str| {
            mgr.join(
                "param-channel",
                "default",
                id,
                role,
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
            )
            .unwrap()
        };
        let a1 = mk(&j1, "agg", "aggregator");
        let t1 = mk(&j1, "t0", "trainer");
        let a2 = mk(&j2, "agg", "aggregator");
        let t2 = mk(&j2, "t0", "trainer");
        // membership is per scope, not per fabric
        assert_eq!(*a1.ends(), vec!["t0".to_string()]);
        assert_eq!(j1.members("param-channel", "default").len(), 2);
        assert_eq!(j2.members("param-channel", "default").len(), 2);
        // mail never crosses scopes: each aggregator sees only its own
        // trainer's message
        t1.send("agg", Message::control("u", 1)).unwrap();
        t2.send("agg", Message::control("u", 2)).unwrap();
        assert_eq!(a1.recv("t0").unwrap().round, 1);
        assert_eq!(a2.recv("t0").unwrap().round, 2);
        assert!(a1.peek("t0").is_none());
        assert!(a2.peek("t0").is_none());
    }

    #[test]
    fn scoped_evict_never_touches_other_scopes() {
        let root = ChannelManager::new(Arc::new(VirtualNet::default()));
        let j1 = root.scoped("job-1");
        let j2 = root.scoped("job-2");
        let mk = |mgr: &Arc<ChannelManager>, id: &str, role: &str| {
            mgr.join(
                "c",
                "g",
                id,
                role,
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
            )
            .unwrap()
        };
        let _a1 = mk(&j1, "agg", "aggregator");
        let _t1 = mk(&j1, "t0", "trainer");
        let a2 = mk(&j2, "agg", "aggregator");
        let t2 = mk(&j2, "t0", "trainer");
        // evicting "t0" through job-1's view revokes exactly one membership
        assert_eq!(j1.evict("t0", 1), 1);
        assert!(j1.members("c", "g") == vec!["agg".to_string()]);
        // job-2's identically named worker is untouched and still works
        assert_eq!(*a2.ends(), vec!["t0".to_string()]);
        t2.send("agg", Message::control("alive", 3)).unwrap();
        assert_eq!(a2.recv("t0").unwrap().round, 3);
        // an unscoped view on the same fabric cannot evict scoped members
        assert_eq!(root.evict("t0", 1), 0);
    }

    #[test]
    fn separator_in_channel_or_scope_names_cannot_alias_scopes() {
        // membership routes are packed symbol triples, not joined strings:
        // a channel literally named with the hub separator works in an
        // unscoped manager (including evict)...
        let root = ChannelManager::new(Arc::new(VirtualNet::default()));
        let mk = |mgr: &Arc<ChannelManager>, ch: &str, id: &str, role: &str| {
            mgr.join(
                ch,
                "g",
                id,
                role,
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
            )
            .unwrap()
        };
        let a = mk(&root, "fl::param", "agg", "aggregator");
        let _t = mk(&root, "fl::param", "t0", "trainer");
        assert_eq!(root.evict("t0", 1), 1, "unscoped evict must see '::' names");
        assert!(a.empty());
        // ...and a scope that happens to be a prefix+separator of another
        // never matches the other's keys
        let j1 = root.scoped("a-1");
        let j2 = root.scoped("a-1::b-2");
        let _w1 = mk(&j1, "c", "w", "trainer");
        let _w2 = mk(&j2, "c", "w", "trainer");
        assert_eq!(j1.evict("w", 1), 1);
        assert_eq!(j2.members("c", "g"), vec!["w".to_string()]);
    }

    #[test]
    fn scoped_broker_hubs_are_distinct_net_nodes() {
        use crate::net::LinkSpec;
        // shaping one job's hub must not slow the other job's broker path
        let net = Arc::new(VirtualNet::new(LinkSpec::mbps(100.0, 0)));
        net.set_pair("t0", "hub:slow::param", LinkSpec::mbps(0.1, 0));
        let root = ChannelManager::new(net);
        let slow = root.scoped("slow");
        let fast = root.scoped("fast");
        let mk = |mgr: &Arc<ChannelManager>, id: &str, role: &str| {
            mgr.join(
                "param",
                "g",
                id,
                role,
                Backend::Broker,
                Arc::new(Mutex::new(VClock::default())),
            )
            .unwrap()
        };
        let sa = mk(&slow, "agg", "aggregator");
        let st = mk(&slow, "t0", "trainer");
        let fa = mk(&fast, "agg", "aggregator");
        let ft = mk(&fast, "t0", "trainer");
        let w = Arc::new(vec![0f32; 100_000]);
        st.send("agg", Message::floats("u", 0, w.clone())).unwrap();
        ft.send("agg", Message::floats("u", 0, w)).unwrap();
        sa.recv("t0").unwrap();
        fa.recv("t0").unwrap();
        assert!(
            sa.now() > 10 * fa.now(),
            "slow hub {} vs fast hub {}",
            sa.now(),
            fa.now()
        );
    }
}
