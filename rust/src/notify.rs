//! Notifier — the management plane's event service (paper §5.1).
//!
//! The controller pushes event signals; agents and deployers subscribe and
//! react (e.g. fetch job info on a deploy event, stop workers on revoke).
//! Implemented as a fan-out pub/sub bus over std mpsc channels with
//! per-subscriber topic filters.

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Mutex;

use crate::json::Json;
use crate::net::VTime;

/// Event kinds the management plane emits (§5.2 workflow).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A compute-creation request for deployers (step 5/6).
    Deploy,
    /// Tear a job's resources down (revoke deploy).
    Revoke,
    /// A worker reported a status change.
    WorkerStatus,
    /// A job's control-plane lifecycle state changed (payload: the new
    /// state string — `queued`, `deploying`, `running`, `completed`,
    /// `failed`). Streamed by the multi-job [`crate::controlplane`].
    JobState,
    /// A non-fatal spec finding raised at submit (payload: the warning
    /// string) — e.g. a spec that omits `tag.flavor` and relies on
    /// validate-time inference for its role↔program binding.
    SpecLint,
    /// Job finished (success or failure).
    JobDone,
    /// A round boundary's trace summary (payload: the per-phase µs
    /// breakdown object emitted by [`crate::trace::TraceHub`]). Only
    /// emitted for jobs with tracing enabled.
    Trace,
}

/// One event on the bus.
#[derive(Debug, Clone)]
pub struct Event {
    pub kind: EventKind,
    pub job: String,
    /// Emitting virtual time (µs). Events published from inside a running
    /// job carry the emitter's vclock so the stream is orderable against
    /// trace spans; management-plane events outside any virtual timeline
    /// (submit, revoke) carry 0.
    pub at: VTime,
    pub payload: Json,
}

struct Subscriber {
    kind: Option<EventKind>,
    job: Option<String>,
    tx: Sender<Event>,
}

/// The notification service.
#[derive(Default)]
pub struct Notifier {
    subs: Mutex<Vec<Subscriber>>,
}

impl Notifier {
    pub fn new() -> Self {
        Self::default()
    }

    /// Subscribe with optional kind/job filters (None = wildcard).
    pub fn subscribe(&self, kind: Option<EventKind>, job: Option<&str>) -> Receiver<Event> {
        let (tx, rx) = mpsc::channel();
        self.subs.lock().unwrap().push(Subscriber {
            kind,
            job: job.map(str::to_string),
            tx,
        });
        rx
    }

    /// Publish an event; returns how many subscribers received it. Dead
    /// subscribers (dropped receivers) are pruned.
    pub fn publish(&self, event: Event) -> usize {
        let mut subs = self.subs.lock().unwrap();
        let mut delivered = 0;
        subs.retain(|s| {
            let matches = s.kind.map_or(true, |k| k == event.kind)
                && s.job.as_deref().map_or(true, |j| j == event.job);
            if !matches {
                return true;
            }
            match s.tx.send(event.clone()) {
                Ok(()) => {
                    delivered += 1;
                    true
                }
                Err(_) => false, // receiver dropped: prune
            }
        });
        delivered
    }

    /// Emit outside any virtual timeline (management-plane events): the
    /// stamp is 0.
    pub fn emit(&self, kind: EventKind, job: &str, payload: Json) -> usize {
        self.emit_at(kind, job, 0, payload)
    }

    /// Emit from inside a job at virtual time `at` (the emitter's vclock
    /// or a message arrival time), so subscribers can order the event
    /// against trace spans.
    pub fn emit_at(&self, kind: EventKind, job: &str, at: VTime, payload: Json) -> usize {
        self.publish(Event {
            kind,
            job: job.to_string(),
            at,
            payload,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wildcard_subscriber_sees_everything() {
        let n = Notifier::new();
        let rx = n.subscribe(None, None);
        n.emit(EventKind::Deploy, "j1", Json::Null);
        n.emit(EventKind::JobDone, "j2", Json::Null);
        assert_eq!(rx.try_iter().count(), 2);
    }

    #[test]
    fn kind_filter() {
        let n = Notifier::new();
        let rx = n.subscribe(Some(EventKind::Revoke), None);
        n.emit(EventKind::Deploy, "j1", Json::Null);
        assert_eq!(n.emit(EventKind::Revoke, "j1", Json::Null), 1);
        let events: Vec<Event> = rx.try_iter().collect();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].kind, EventKind::Revoke);
    }

    #[test]
    fn job_filter() {
        let n = Notifier::new();
        let rx = n.subscribe(None, Some("j2"));
        n.emit(EventKind::Deploy, "j1", Json::Null);
        n.emit(EventKind::Deploy, "j2", Json::from("payload"));
        let events: Vec<Event> = rx.try_iter().collect();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].payload.as_str(), Some("payload"));
    }

    #[test]
    fn dead_subscribers_are_pruned() {
        let n = Notifier::new();
        let rx = n.subscribe(None, None);
        drop(rx);
        assert_eq!(n.emit(EventKind::Deploy, "j", Json::Null), 0);
        // second publish confirms the dead sub was removed
        assert_eq!(n.emit(EventKind::Deploy, "j", Json::Null), 0);
    }

    #[test]
    fn job_state_stream_preserves_transition_order() {
        let n = Notifier::new();
        let rx = n.subscribe(Some(EventKind::JobState), Some("cfl-1"));
        for s in ["queued", "deploying", "running", "completed"] {
            n.emit(EventKind::JobState, "cfl-1", Json::from(s));
            n.emit(EventKind::JobState, "other-2", Json::from(s));
        }
        let states: Vec<String> = rx
            .try_iter()
            .map(|e| e.payload.as_str().unwrap().to_string())
            .collect();
        assert_eq!(states, vec!["queued", "deploying", "running", "completed"]);
    }

    #[test]
    fn events_carry_the_emitting_virtual_time() {
        let n = Notifier::new();
        let rx = n.subscribe(None, None);
        n.emit(EventKind::Deploy, "j1", Json::Null);
        n.emit_at(EventKind::Trace, "j1", 42_000, Json::Null);
        let events: Vec<Event> = rx.try_iter().collect();
        assert_eq!(events[0].at, 0);
        assert_eq!(events[1].at, 42_000);
        assert_eq!(events[1].kind, EventKind::Trace);
    }

    #[test]
    fn cross_thread_delivery() {
        use std::sync::Arc;
        let n = Arc::new(Notifier::new());
        let rx = n.subscribe(Some(EventKind::WorkerStatus), None);
        let n2 = n.clone();
        let h = std::thread::spawn(move || {
            for i in 0..10 {
                n2.emit(EventKind::WorkerStatus, &format!("j{i}"), Json::Null);
            }
        });
        h.join().unwrap();
        assert_eq!(rx.try_iter().count(), 10);
    }
}
