//! Compute & dataset registries with realm-scoped matching (paper §4.3).
//!
//! Flame decouples infrastructure from learning jobs: cluster admins
//! register compute independently of data owners registering dataset
//! metadata, and the controller couples them **at deployment time**. The
//! `realm` attribute defines hierarchical accessibility boundaries (e.g.
//! GDPR regions): a dataset with realm `eu/west` may only be trained on
//! compute whose realm lies inside (or above) `eu/west`.
//!
//! Realms are `/`-separated paths; `*` is the wildcard. Compatibility is
//! prefix containment in either direction: `eu` compute can host `eu/west`
//! data (the cluster spans the region) and `eu/west/dc1` compute can host
//! `eu/west` data (the cluster lies inside the boundary).

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::json::Json;
use crate::tag::DatasetRef;

/// A registered compute cluster (the deployer registers these — §5.2 step 1).
#[derive(Debug, Clone)]
pub struct ComputeSpec {
    pub name: String,
    pub realm: String,
    /// Advisory worker capacity used for least-loaded placement.
    pub capacity: usize,
    /// Which orchestrator backs this cluster ("sim", "k8s", ...); resolved
    /// by the deployer layer.
    pub orchestrator: String,
}

impl ComputeSpec {
    pub fn new(name: impl Into<String>, realm: impl Into<String>, capacity: usize) -> Self {
        Self {
            name: name.into(),
            realm: realm.into(),
            capacity,
            orchestrator: "sim".into(),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("name", self.name.as_str());
        o.insert("realm", self.realm.as_str());
        o.insert("capacity", self.capacity);
        o.insert("orchestrator", self.orchestrator.as_str());
        Json::Obj(o)
    }
}

/// Are two realms mutually accessible (prefix containment either way)?
pub fn realm_compatible(a: &str, b: &str) -> bool {
    if a == "*" || b == "*" {
        return true;
    }
    let ap: Vec<&str> = a.split('/').collect();
    let bp: Vec<&str> = b.split('/').collect();
    let n = ap.len().min(bp.len());
    ap[..n] == bp[..n]
}

#[derive(Default)]
struct Load {
    assigned: HashMap<String, usize>,
    rr: usize,
}

/// The management-plane registry of computes and datasets.
#[derive(Default)]
pub struct Registry {
    computes: Vec<ComputeSpec>,
    datasets: Vec<DatasetRef>,
    load: Mutex<Load>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// A registry with one unconstrained compute — the fiab-style single-box
    /// emulation default.
    pub fn single_box() -> Self {
        let mut r = Self::new();
        r.register_compute(ComputeSpec::new("box", "*", usize::MAX));
        r
    }

    pub fn register_compute(&mut self, c: ComputeSpec) {
        self.computes.push(c);
    }

    pub fn register_dataset(&mut self, d: DatasetRef) {
        self.datasets.push(d);
    }

    pub fn computes(&self) -> &[ComputeSpec] {
        &self.computes
    }

    /// The registered compute named `name`.
    pub fn compute(&self, name: &str) -> Option<&ComputeSpec> {
        self.computes.iter().find(|c| c.name == name)
    }

    /// Advisory worker capacity of `name` (admission control reads this;
    /// `None` for unknown computes).
    pub fn capacity_of(&self, name: &str) -> Option<usize> {
        self.compute(name).map(|c| c.capacity)
    }

    /// Total advisory capacity across every registered compute (saturating
    /// — the single-box registry advertises `usize::MAX`).
    pub fn total_capacity(&self) -> usize {
        self.computes
            .iter()
            .fold(0usize, |acc, c| acc.saturating_add(c.capacity))
    }

    pub fn datasets(&self) -> &[DatasetRef] {
        &self.datasets
    }

    pub fn dataset(&self, name: &str) -> Option<&DatasetRef> {
        self.datasets.iter().find(|d| d.name == name)
    }

    /// Algorithm 1's `GetComputeId(d)`: least-loaded compute whose realm is
    /// compatible with the dataset's realm.
    pub fn compute_for_realm(&self, realm: &str) -> Result<String> {
        let mut load = self.load.lock().unwrap();
        let candidate = self
            .computes
            .iter()
            .filter(|c| realm_compatible(&c.realm, realm))
            .min_by_key(|c| load.assigned.get(&c.name).copied().unwrap_or(0));
        match candidate {
            Some(c) => {
                *load.assigned.entry(c.name.clone()).or_insert(0) += 1;
                Ok(c.name.clone())
            }
            None => bail!("no registered compute matches realm '{realm}'"),
        }
    }

    /// Algorithm 1's `DecideComputeId(a)`: round-robin placement for
    /// non-data-consumer workers (no realm constraint).
    pub fn decide_compute(&self) -> Result<String> {
        if self.computes.is_empty() {
            bail!("no compute registered");
        }
        let mut load = self.load.lock().unwrap();
        let i = load.rr % self.computes.len();
        load.rr += 1;
        let name = self.computes[i].name.clone();
        *load.assigned.entry(name.clone()).or_insert(0) += 1;
        Ok(name)
    }

    /// Reset placement counters (between expansions).
    pub fn reset_load(&self) {
        let mut load = self.load.lock().unwrap();
        load.assigned.clear();
        load.rr = 0;
    }

    pub fn assigned(&self, compute: &str) -> usize {
        self.load
            .lock()
            .unwrap()
            .assigned
            .get(compute)
            .copied()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn realm_prefix_containment() {
        assert!(realm_compatible("eu", "eu/west"));
        assert!(realm_compatible("eu/west/dc1", "eu/west"));
        assert!(realm_compatible("eu", "eu"));
        assert!(!realm_compatible("eu/west", "eu/east"));
        assert!(!realm_compatible("us", "eu"));
        assert!(realm_compatible("*", "eu/west"));
        assert!(realm_compatible("eu/west", "*"));
    }

    #[test]
    fn realm_tokens_are_whole_segments_not_string_prefixes() {
        // "eu" must NOT contain "europe": containment is per `/`-segment
        assert!(!realm_compatible("eu", "europe"));
        assert!(!realm_compatible("europe/west", "eu/west"));
        // deep nesting works in both directions
        assert!(realm_compatible("a/b/c/d", "a/b"));
        assert!(realm_compatible("a/b", "a/b/c/d"));
        assert!(!realm_compatible("a/b/c", "a/x/c"));
        // both wildcards
        assert!(realm_compatible("*", "*"));
    }

    #[test]
    fn capacity_lookups() {
        let mut r = Registry::new();
        r.register_compute(ComputeSpec::new("edge", "eu", 4));
        r.register_compute(ComputeSpec::new("dc", "eu", 100));
        assert_eq!(r.capacity_of("edge"), Some(4));
        assert_eq!(r.capacity_of("dc"), Some(100));
        assert_eq!(r.capacity_of("nope"), None);
        assert_eq!(r.total_capacity(), 104);
        assert_eq!(r.compute("edge").unwrap().realm, "eu");
        // the single-box registry advertises effectively infinite capacity
        assert_eq!(Registry::single_box().total_capacity(), usize::MAX);
    }

    #[test]
    fn compute_for_realm_respects_boundary() {
        let mut r = Registry::new();
        r.register_compute(ComputeSpec::new("eu-dc", "eu/west", 100));
        r.register_compute(ComputeSpec::new("us-dc", "us/east", 100));
        assert_eq!(r.compute_for_realm("eu/west").unwrap(), "eu-dc");
        assert_eq!(r.compute_for_realm("us/east/zone1").unwrap(), "us-dc");
        assert!(r.compute_for_realm("ap/south").is_err());
    }

    #[test]
    fn least_loaded_placement() {
        let mut r = Registry::new();
        r.register_compute(ComputeSpec::new("a", "*", 100));
        r.register_compute(ComputeSpec::new("b", "*", 100));
        for _ in 0..10 {
            r.compute_for_realm("*").unwrap();
        }
        assert_eq!(r.assigned("a"), 5);
        assert_eq!(r.assigned("b"), 5);
    }

    #[test]
    fn round_robin_decide() {
        let mut r = Registry::new();
        r.register_compute(ComputeSpec::new("a", "*", 100));
        r.register_compute(ComputeSpec::new("b", "*", 100));
        let seq: Vec<String> = (0..4).map(|_| r.decide_compute().unwrap()).collect();
        assert_eq!(seq, vec!["a", "b", "a", "b"]);
    }

    #[test]
    fn empty_registry_errors() {
        let r = Registry::new();
        assert!(r.decide_compute().is_err());
        assert!(r.compute_for_realm("*").is_err());
    }

    #[test]
    fn reset_load_clears_counters() {
        let mut r = Registry::new();
        r.register_compute(ComputeSpec::new("a", "*", 100));
        r.decide_compute().unwrap();
        r.reset_load();
        assert_eq!(r.assigned("a"), 0);
    }
}
