//! Journaling state store (the paper's MongoDB stand-in).
//!
//! The controller persists job specs, expanded worker configurations and
//! status transitions here. The store is an append-only JSON-lines journal
//! with an in-memory collection index — enough durability machinery that the
//! "DB write" column of the paper's Table 6 measures a real serialization +
//! write path, while staying embeddable and dependency-free.
//!
//! Layout: each record is one line `{"c": <collection>, "k": <key>,
//! "v": <value|null>}`; a `null` value is a tombstone. Recovery replays the
//! journal in order. `Store::in_memory()` skips the file for tests/benches
//! that only need the index (Table 6 reports both modes).

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::json::{Json, Obj};

struct Inner {
    /// collection -> key -> value
    index: HashMap<String, HashMap<String, Json>>,
    writer: Option<BufWriter<File>>,
    path: Option<PathBuf>,
    writes: u64,
}

/// Embedded journaling document store.
pub struct Store {
    inner: Mutex<Inner>,
}

impl Store {
    /// Open (or create) a journal-backed store at `path`, replaying any
    /// existing journal into the index.
    ///
    /// Crash tolerance: a process killed mid-append leaves a **torn final
    /// line** — bytes with no terminating newline. If the torn bytes do
    /// not parse, the record never committed: recovery drops them and
    /// truncates them away so the next append starts on a clean boundary.
    /// If they *do* parse (the crash landed exactly between the record
    /// bytes and its newline), the record is kept and the missing newline
    /// is written, so the next append cannot merge onto it. Corruption
    /// anywhere else — including an unparseable line that *is*
    /// newline-terminated — is real damage and stays a hard error.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let mut index: HashMap<String, HashMap<String, Json>> = HashMap::new();
        let mut truncate_to: Option<u64> = None;
        let mut needs_newline = false;
        if path.exists() {
            // read BYTES, not a String: a tear can land mid-way through a
            // multi-byte UTF-8 character, and the whole-file read must not
            // reject the journal before the tail repair gets to run
            let raw = std::fs::read(&path).context("open journal")?;
            let complete = raw.ends_with(b"\n");
            let mut lines: Vec<&[u8]> = raw.split(|b| *b == b'\n').collect();
            if complete {
                lines.pop(); // drop the empty chunk after the final newline
            }
            let n_lines = lines.len();
            for (lineno, line) in lines.iter().enumerate() {
                if line.iter().all(|b| b.is_ascii_whitespace()) {
                    continue;
                }
                let replayed: Result<()> = match std::str::from_utf8(line) {
                    Ok(text) => match Json::parse(text) {
                        Ok(rec) => Self::apply(&mut index, &rec),
                        Err(e) => Err(anyhow::Error::new(e)),
                    },
                    Err(e) => Err(anyhow::Error::new(e)),
                };
                if let Err(e) = replayed {
                    let is_torn_tail = lineno + 1 == n_lines && !complete;
                    if is_torn_tail {
                        truncate_to = Some((raw.len() - line.len()) as u64);
                        break;
                    }
                    return Err(e)
                        .with_context(|| format!("corrupt journal line {}", lineno + 1));
                }
            }
            // a crash between the record bytes and their newline leaves a
            // fully-parseable unterminated tail: keep it, terminate it
            needs_newline = !complete && truncate_to.is_none() && !raw.is_empty();
        }
        if let Some(len) = truncate_to {
            // repair: cut the torn bytes so appends don't merge into them
            OpenOptions::new()
                .write(true)
                .open(&path)
                .context("repair torn journal tail")?
                .set_len(len)?;
        }
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        if needs_newline {
            (&file).write_all(b"\n").context("repair unterminated journal tail")?;
        }
        Ok(Self {
            inner: Mutex::new(Inner {
                index,
                writer: Some(BufWriter::new(file)),
                path: Some(path),
                writes: 0,
            }),
        })
    }

    /// Index-only store (no journal file); used by tests and to separate
    /// expansion cost from write cost in the Table 6 bench.
    pub fn in_memory() -> Self {
        Self {
            inner: Mutex::new(Inner {
                index: HashMap::new(),
                writer: None,
                path: None,
                writes: 0,
            }),
        }
    }

    fn apply(
        index: &mut HashMap<String, HashMap<String, Json>>,
        rec: &Json,
    ) -> Result<()> {
        let c = rec
            .get("c")
            .as_str()
            .context("journal record missing collection")?
            .to_string();
        let k = rec
            .get("k")
            .as_str()
            .context("journal record missing key")?
            .to_string();
        let v = rec.get("v");
        let coll = index.entry(c).or_default();
        if v.is_null() {
            coll.remove(&k);
        } else {
            coll.insert(k, v.clone());
        }
        Ok(())
    }

    /// Insert or replace `collection/key`.
    pub fn put(&self, collection: &str, key: &str, value: Json) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        if g.writer.is_some() {
            let mut rec = Obj::new();
            rec.insert("c", collection);
            rec.insert("k", key);
            rec.insert("v", value.clone());
            let line = Json::Obj(rec).dump();
            let w = g.writer.as_mut().unwrap();
            w.write_all(line.as_bytes())?;
            w.write_all(b"\n")?;
        }
        g.index
            .entry(collection.to_string())
            .or_default()
            .insert(key.to_string(), value);
        g.writes += 1;
        Ok(())
    }

    /// Batched put: one journal flush for `items` records. This is the path
    /// the controller uses to persist an expansion result (Table 6).
    pub fn put_batch(
        &self,
        collection: &str,
        items: impl IntoIterator<Item = (String, Json)>,
    ) -> Result<usize> {
        let mut g = self.inner.lock().unwrap();
        let mut n = 0;
        for (key, value) in items {
            if g.writer.is_some() {
                let mut rec = Obj::new();
                rec.insert("c", collection);
                rec.insert("k", key.as_str());
                rec.insert("v", value.clone());
                let line = Json::Obj(rec).dump();
                let w = g.writer.as_mut().unwrap();
                w.write_all(line.as_bytes())?;
                w.write_all(b"\n")?;
            }
            g.index
                .entry(collection.to_string())
                .or_default()
                .insert(key, value);
            n += 1;
        }
        g.writes += n as u64;
        if let Some(w) = g.writer.as_mut() {
            w.flush()?;
        }
        Ok(n)
    }

    pub fn get(&self, collection: &str, key: &str) -> Option<Json> {
        let g = self.inner.lock().unwrap();
        g.index.get(collection).and_then(|c| c.get(key)).cloned()
    }

    pub fn delete(&self, collection: &str, key: &str) -> Result<bool> {
        let mut g = self.inner.lock().unwrap();
        if g.writer.is_some() {
            let mut rec = Obj::new();
            rec.insert("c", collection);
            rec.insert("k", key);
            rec.insert("v", Json::Null);
            let line = Json::Obj(rec).dump();
            let w = g.writer.as_mut().unwrap();
            w.write_all(line.as_bytes())?;
            w.write_all(b"\n")?;
        }
        let existed = g
            .index
            .get_mut(collection)
            .map(|c| c.remove(key).is_some())
            .unwrap_or(false);
        Ok(existed)
    }

    /// All keys in a collection (unordered).
    pub fn keys(&self, collection: &str) -> Vec<String> {
        let g = self.inner.lock().unwrap();
        g.index
            .get(collection)
            .map(|c| c.keys().cloned().collect())
            .unwrap_or_default()
    }

    pub fn count(&self, collection: &str) -> usize {
        let g = self.inner.lock().unwrap();
        g.index.get(collection).map(|c| c.len()).unwrap_or(0)
    }

    /// Flush buffered journal writes to the OS.
    pub fn flush(&self) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        if let Some(w) = g.writer.as_mut() {
            w.flush()?;
        }
        Ok(())
    }

    /// fsync the journal (full durability point).
    pub fn sync(&self) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        if let Some(w) = g.writer.as_mut() {
            w.flush()?;
            w.get_ref().sync_data()?;
        }
        Ok(())
    }

    pub fn total_writes(&self) -> u64 {
        self.inner.lock().unwrap().writes
    }

    pub fn journal_path(&self) -> Option<PathBuf> {
        self.inner.lock().unwrap().path.clone()
    }

    /// Compact the journal: rewrite it as exactly the live index (drops
    /// overwritten versions and tombstones). Atomic via rename. Returns the
    /// number of live records written; no-op for in-memory stores.
    pub fn compact(&self) -> Result<usize> {
        let mut g = self.inner.lock().unwrap();
        let Some(path) = g.path.clone() else {
            return Ok(0);
        };
        if let Some(w) = g.writer.as_mut() {
            w.flush()?;
        }
        let tmp = path.with_extension("compact");
        let mut n = 0;
        {
            let mut w = BufWriter::new(File::create(&tmp)?);
            for (c, coll) in &g.index {
                for (k, v) in coll {
                    let mut rec = Obj::new();
                    rec.insert("c", c.as_str());
                    rec.insert("k", k.as_str());
                    rec.insert("v", v.clone());
                    w.write_all(Json::Obj(rec).dump().as_bytes())?;
                    w.write_all(b"\n")?;
                    n += 1;
                }
            }
            w.flush()?;
            w.get_ref().sync_data()?;
        }
        std::fs::rename(&tmp, &path)?;
        let file = OpenOptions::new().append(true).open(&path)?;
        g.writer = Some(BufWriter::new(file));
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("flame-store-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("{name}-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn put_get_roundtrip() {
        let s = Store::in_memory();
        s.put("jobs", "j1", Json::from("spec")).unwrap();
        assert_eq!(s.get("jobs", "j1").unwrap().as_str(), Some("spec"));
        assert!(s.get("jobs", "nope").is_none());
        assert!(s.get("other", "j1").is_none());
    }

    #[test]
    fn delete_and_tombstone() {
        let s = Store::in_memory();
        s.put("c", "k", Json::from(1i64)).unwrap();
        assert!(s.delete("c", "k").unwrap());
        assert!(!s.delete("c", "k").unwrap());
        assert!(s.get("c", "k").is_none());
    }

    #[test]
    fn journal_recovery_replays_state() {
        let p = tmp("recovery");
        {
            let s = Store::open(&p).unwrap();
            s.put("jobs", "a", Json::from(1i64)).unwrap();
            s.put("jobs", "b", Json::from(2i64)).unwrap();
            s.put("jobs", "a", Json::from(3i64)).unwrap(); // overwrite
            s.delete("jobs", "b").unwrap(); // tombstone
            s.flush().unwrap();
        }
        let s = Store::open(&p).unwrap();
        assert_eq!(s.get("jobs", "a").unwrap().as_i64(), Some(3));
        assert!(s.get("jobs", "b").is_none());
        assert_eq!(s.count("jobs"), 1);
    }

    #[test]
    fn batch_put_counts() {
        let p = tmp("batch");
        let s = Store::open(&p).unwrap();
        let n = s
            .put_batch(
                "workers",
                (0..100).map(|i| (format!("w{i}"), Json::from(i as i64))),
            )
            .unwrap();
        assert_eq!(n, 100);
        assert_eq!(s.count("workers"), 100);
        drop(s);
        let s = Store::open(&p).unwrap();
        assert_eq!(s.count("workers"), 100);
        assert_eq!(s.get("workers", "w42").unwrap().as_i64(), Some(42));
    }

    #[test]
    fn corrupt_journal_is_an_error() {
        let p = tmp("corrupt");
        std::fs::write(&p, "{\"c\":\"x\",\"k\":\"k\",\"v\":1}\nnot-json\n").unwrap();
        assert!(Store::open(&p).is_err());
    }

    #[test]
    fn corrupt_mid_journal_line_is_an_error_even_with_torn_tail() {
        // a torn tail is forgivable; damage BEFORE it is not
        let p = tmp("mid-corrupt");
        std::fs::write(
            &p,
            "{\"c\":\"x\",\"k\":\"a\",\"v\":1}\nnot-json\n{\"c\":\"x\",\"k\":\"b\"",
        )
        .unwrap();
        let err = Store::open(&p).unwrap_err();
        assert!(format!("{err:#}").contains("line 2"), "{err:#}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn torn_final_line_is_dropped_and_repaired() {
        // simulate a crash mid-append: committed records, then a partial
        // line with no terminating newline
        let p = tmp("torn");
        {
            let s = Store::open(&p).unwrap();
            s.put("jobs", "a", Json::from(1i64)).unwrap();
            s.put("jobs", "b", Json::from(2i64)).unwrap();
            s.flush().unwrap();
        }
        {
            let mut f = OpenOptions::new().append(true).open(&p).unwrap();
            f.write_all(b"{\"c\":\"jobs\",\"k\":\"c\",\"v\"").unwrap();
        }
        // recovery keeps every committed record and drops the torn one
        let s = Store::open(&p).unwrap();
        assert_eq!(s.count("jobs"), 2);
        assert_eq!(s.get("jobs", "a").unwrap().as_i64(), Some(1));
        assert!(s.get("jobs", "c").is_none());
        // the torn bytes were truncated away: new appends land on a clean
        // line boundary and survive the next recovery
        s.put("jobs", "c", Json::from(3i64)).unwrap();
        s.flush().unwrap();
        drop(s);
        let s = Store::open(&p).unwrap();
        assert_eq!(s.count("jobs"), 3);
        assert_eq!(s.get("jobs", "c").unwrap().as_i64(), Some(3));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn torn_tail_inside_a_multibyte_character_is_repaired() {
        // the tear can split a UTF-8 sequence: recovery must still open
        // the store (bytes, not read_to_string) and drop the torn line
        let p = tmp("torn-utf8");
        {
            let s = Store::open(&p).unwrap();
            s.put("jobs", "caf\u{e9}", Json::from(1i64)).unwrap();
            s.flush().unwrap();
        }
        {
            let mut f = OpenOptions::new().append(true).open(&p).unwrap();
            // "caf" + the FIRST byte of a two-byte 'é' only, no newline
            f.write_all(b"{\"c\":\"jobs\",\"k\":\"caf\xc3").unwrap();
        }
        let s = Store::open(&p).unwrap();
        assert_eq!(s.count("jobs"), 1);
        assert_eq!(s.get("jobs", "caf\u{e9}").unwrap().as_i64(), Some(1));
        s.put("jobs", "next", Json::from(2i64)).unwrap();
        s.flush().unwrap();
        drop(s);
        let s = Store::open(&p).unwrap();
        assert_eq!(s.count("jobs"), 2);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn complete_record_missing_only_its_newline_is_kept_and_repaired() {
        // crash exactly between the record bytes and the b"\n" write: the
        // record is committed, the line just lacks its terminator — it
        // must be kept AND terminated so the next append cannot merge
        let p = tmp("newline-torn");
        {
            let s = Store::open(&p).unwrap();
            s.put("jobs", "a", Json::from(1i64)).unwrap();
            s.flush().unwrap();
        }
        {
            let mut f = OpenOptions::new().append(true).open(&p).unwrap();
            f.write_all(b"{\"c\":\"jobs\",\"k\":\"b\",\"v\":2}").unwrap(); // no \n
        }
        let s = Store::open(&p).unwrap();
        assert_eq!(s.get("jobs", "b").unwrap().as_i64(), Some(2));
        s.put("jobs", "c", Json::from(3i64)).unwrap();
        s.flush().unwrap();
        drop(s);
        // the merge-corruption hazard: without the newline repair, record
        // c would have been appended onto b's line
        let s = Store::open(&p).unwrap();
        assert_eq!(s.count("jobs"), 3);
        assert_eq!(s.get("jobs", "b").unwrap().as_i64(), Some(2));
        assert_eq!(s.get("jobs", "c").unwrap().as_i64(), Some(3));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn tombstones_delete_across_restart_despite_torn_tail() {
        let p = tmp("tomb-torn");
        {
            let s = Store::open(&p).unwrap();
            s.put("jobs", "keep", Json::from(1i64)).unwrap();
            s.put("jobs", "gone", Json::from(2i64)).unwrap();
            s.delete("jobs", "gone").unwrap();
            s.flush().unwrap();
        }
        {
            let mut f = OpenOptions::new().append(true).open(&p).unwrap();
            // torn resurrection attempt for the deleted key: must not apply
            f.write_all(b"{\"c\":\"jobs\",\"k\":\"gone\",\"v\":9").unwrap();
        }
        let s = Store::open(&p).unwrap();
        assert_eq!(s.get("jobs", "keep").unwrap().as_i64(), Some(1));
        assert!(s.get("jobs", "gone").is_none(), "tombstone must survive");
        assert_eq!(s.count("jobs"), 1);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn keys_and_counts() {
        let s = Store::in_memory();
        for i in 0..5 {
            s.put("c", &format!("k{i}"), Json::from(i as i64)).unwrap();
        }
        let mut ks = s.keys("c");
        ks.sort();
        assert_eq!(ks, vec!["k0", "k1", "k2", "k3", "k4"]);
        assert_eq!(s.count("c"), 5);
        assert_eq!(s.total_writes(), 5);
    }

    #[test]
    fn compaction_shrinks_journal_and_preserves_state() {
        let p = tmp("compact");
        let s = Store::open(&p).unwrap();
        for i in 0..50 {
            s.put("c", "hot", Json::from(i as i64)).unwrap(); // 50 versions
        }
        s.put("c", "dead", Json::from(1i64)).unwrap();
        s.delete("c", "dead").unwrap();
        s.put("c", "live", Json::from(7i64)).unwrap();
        s.flush().unwrap();
        let before = std::fs::metadata(&p).unwrap().len();
        let n = s.compact().unwrap();
        assert_eq!(n, 2); // hot + live
        let after = std::fs::metadata(&p).unwrap().len();
        assert!(after < before / 5, "{before} -> {after}");
        // state intact, and the store still accepts writes after compaction
        assert_eq!(s.get("c", "hot").unwrap().as_i64(), Some(49));
        assert!(s.get("c", "dead").is_none());
        s.put("c", "post", Json::from(2i64)).unwrap();
        s.flush().unwrap();
        drop(s);
        let s = Store::open(&p).unwrap();
        assert_eq!(s.get("c", "hot").unwrap().as_i64(), Some(49));
        assert_eq!(s.get("c", "post").unwrap().as_i64(), Some(2));
        assert_eq!(s.count("c"), 3);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn compact_on_memory_store_is_noop() {
        let s = Store::in_memory();
        s.put("c", "k", Json::from(1i64)).unwrap();
        assert_eq!(s.compact().unwrap(), 0);
        assert_eq!(s.get("c", "k").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn concurrent_writers_do_not_lose_records() {
        use std::sync::Arc;
        let s = Arc::new(Store::in_memory());
        let mut handles = vec![];
        for t in 0..8 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100 {
                    s.put("c", &format!("t{t}-{i}"), Json::from(i as i64)).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.count("c"), 800);
    }

    #[test]
    fn checkpoint_epochs_gc_through_compaction_across_reopen() {
        use crate::controlplane::checkpoint::{
            load_latest, CkptPolicy, CkptSink, CKPT_COLLECTION,
        };
        use std::sync::Arc;
        let p = tmp("ckpt-gc");
        {
            let store = Arc::new(Store::open(&p).unwrap());
            let sink = CkptSink::new("j", CkptPolicy::every_round(), true);
            sink.bind_store(store.clone());
            // cursor 0 throughout: a nonzero cursor's 16-hex encoding
            // would collide with the stale-epoch substring probe below
            for round in 1..=3u64 {
                sink.publish("w0", Json::from(round as i64));
                sink.commit(round, 0, Json::from("g"), Json::Null, Json::Null, &[])
                    .unwrap();
            }
            // the sink's GC tombstoned epochs 1-2; compaction drops their
            // journal records (and the tombstones) physically
            store.compact().unwrap();
        }
        let raw = std::fs::read_to_string(&p).unwrap();
        for stale in 1..=2u64 {
            assert!(
                !raw.contains(&format!("{stale:016x}")),
                "stale epoch {stale} survived compaction on disk"
            );
        }
        // restart over the compacted journal: the head epoch is intact
        let store = Arc::new(Store::open(&p).unwrap());
        let ck = load_latest(&store, "j").unwrap().unwrap();
        assert_eq!((ck.round, ck.cursor), (3, 0));
        assert_eq!(ck.workers["w0"], Json::from(3i64));
        assert_eq!(store.keys(CKPT_COLLECTION).len(), 5); // head,meta,global,metrics,w/w0
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn put_batch_writes_in_order_so_a_head_last_commit_is_atomic() {
        // the checkpoint protocol's crash-atomicity rests on two store
        // facts: put_batch journals records in iteration order, and a
        // restart that lost the tail loses a *suffix* only. So a batch
        // whose final record is the head key either commits fully or not
        // at all, as observed through the head.
        let p = tmp("batch-head");
        {
            let s = Store::open(&p).unwrap();
            s.put_batch(
                "job_ckpt",
                [
                    ("e/meta".to_string(), Json::from(1i64)),
                    ("e/global".to_string(), Json::from(2i64)),
                    ("head".to_string(), Json::from("e")),
                ],
            )
            .unwrap();
            s.flush().unwrap();
        }
        let raw = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = raw.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[2].contains("\"head\""), "head record must journal last");
        // crash before the head record reached disk: the epoch is
        // invisible through the head pointer
        std::fs::write(&p, format!("{}\n{}\n", lines[0], lines[1])).unwrap();
        let s = Store::open(&p).unwrap();
        assert!(s.get("job_ckpt", "head").is_none());
        assert_eq!(s.get("job_ckpt", "e/meta").unwrap().as_i64(), Some(1));
        let _ = std::fs::remove_file(&p);
    }
}
