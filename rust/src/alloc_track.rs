//! Counting wrapper around the system allocator (bench/test instrumentation).
//!
//! The zero-allocation fabric claim is only worth something if it is
//! *measured*: `rust/benches/fabric.rs` reports allocations/round and
//! `rust/tests/alloc_regression.rs` turns the steady-state bound into a
//! regression test. Both install [`CountingAlloc`] as their binary's
//! `#[global_allocator]`:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: flame::alloc_track::CountingAlloc = flame::alloc_track::CountingAlloc;
//! ```
//!
//! The library itself never installs it — normal builds pay two relaxed
//! atomic adds only in binaries that opt in.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

/// Pass-through allocator that counts allocation events and bytes.
/// Deallocations are not subtracted: the counters measure allocator
/// *traffic*, which is what a recycling fabric must drive to zero.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// A point-in-time reading of the counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AllocSnapshot {
    pub allocs: u64,
    pub bytes: u64,
}

/// Current counter values (zeros unless [`CountingAlloc`] is installed as
/// the global allocator of the running binary).
pub fn snapshot() -> AllocSnapshot {
    AllocSnapshot {
        allocs: ALLOCS.load(Ordering::Relaxed),
        bytes: BYTES.load(Ordering::Relaxed),
    }
}

/// Allocator traffic between two snapshots.
pub fn delta(before: AllocSnapshot, after: AllocSnapshot) -> AllocSnapshot {
    AllocSnapshot {
        allocs: after.allocs.saturating_sub(before.allocs),
        bytes: after.bytes.saturating_sub(before.bytes),
    }
}

/// Bench smoke mode — the single definition every `rust/benches/*` binary
/// consults: `cargo bench --benches -- --test` (or `--smoke`, or
/// `BENCH_SMOKE=1`; `BENCH_SMOKE=0`/empty means off) shrinks each bench's
/// sweep to a seconds-long cell so CI keeps `benches/` green without
/// paying full bench time.
pub fn bench_smoke() -> bool {
    std::env::args().any(|a| a == "--test" || a == "--smoke")
        || std::env::var("BENCH_SMOKE")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_delta_arithmetic() {
        let a = AllocSnapshot { allocs: 10, bytes: 100 };
        let b = AllocSnapshot { allocs: 25, bytes: 180 };
        assert_eq!(delta(a, b), AllocSnapshot { allocs: 15, bytes: 80 });
        // saturating: never underflows if counters were reset between
        assert_eq!(delta(b, a), AllocSnapshot { allocs: 0, bytes: 0 });
    }
}
