//! Intermediate aggregator role — the H-FL middle tier (paper Fig 3).
//!
//! This is exactly the role FedML's client/server dichotomy cannot express
//! (§2.3): it acts as a server toward its trainers and a client toward the
//! global aggregator. Base chain:
//! `Loop(recv_global >> distribute >> collect >> aggregate >> upload)`.
//!
//! CO-FL variant via surgery (§6.1): `get_assignment` before `recv_global`
//! (per-round trainer set + active flag from the coordinator) and `report`
//! after `upload` (upload-delay feedback that drives the coordinator's
//! load-balancing scheme).
//!
//! **Streaming aggregation**: the collect is a quorum loop that folds each
//! update into a [`crate::runtime::Accumulator`] *as it arrives* — steady
//! -state memory is one O(d) buffer plus transient staging (out-of-order
//! arrivals stage as `Arc` clones until their fold slot is reached), and
//! folded update buffers return to the job's `TensorPool` immediately.
//! The fold order is the sorted expected-sender order (see the runtime
//! module docs), so results stay byte-identical across executors and
//! runner-pool sizes.
//!
//! **Churn safety** (live topology extension): the aggregator never
//! freezes a peer list. Distribution and collection run against the
//! *currently alive* intersection of its trainer set with channel
//! membership, and `collect` is a quorum loop (`ceil(quorum * alive)`
//! current-round updates, re-entrant across cooperative yields) rather
//! than a `recv_fifo` barrier — so a trainer that departs mid-job can
//! never deadlock a round. Aggregators deployed by a mid-run tier
//! extension receive their trainer partition as an `assign` message from
//! the global sequencer before their first weights.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::channel::{Message, Payload};
use crate::json::{self, Json};
use crate::runtime::Accumulator;
use crate::workflow::{Composer, Tasklet};

use super::{chain_program, Program, WorkerEnv};

pub struct AggregatorCtx {
    pub env: WorkerEnv,
    weights: Arc<Vec<f32>>,
    round: u64,
    /// CO-FL: trainers assigned this round (None = use channel ends).
    assigned: Option<Vec<String>>,
    /// CO-FL: excluded aggregators sit out the round.
    active: bool,
    /// Set when the global skipped this aggregator for a round (selection).
    skip: bool,
    total_samples: f64,
    /// Mean trainer loss this round (forwarded upstream).
    mean_loss: f64,
    /// Virtual send time of the last upload (for delay reporting).
    upload_sent_at: u64,
    /// Trainers this round's weights were distributed to — the expected
    /// upload universe the streaming collect folds over.
    round_targets: Vec<String>,
    /// In-flight streaming fold (re-entrancy across cooperative yields of
    /// the quorum collect). O(d), not O(trainers·d).
    acc: Option<Accumulator>,
    /// Virtual time the streaming collect opened (transient trace state —
    /// never checkpointed; a resumed round restarts its wait span).
    collect_t0: Option<u64>,
    /// Per-update losses collected this round (sender, loss) — summed in
    /// sorted sender order at round end for a deterministic mean.
    losses: Vec<(Arc<str>, f64)>,
    /// The trainer-side role on `param-channel` (the other endpoint).
    data_role: String,
    pub done: bool,
}

impl AggregatorCtx {
    /// Build the context for an aggregator program over `env` (public for
    /// Role-SDK derivations of [`base_chain`]).
    pub fn new(env: WorkerEnv) -> Self {
        let data_role = env
            .job
            .spec
            .channel("param-channel")
            .map(|ch| {
                if ch.pair.0 == env.cfg.role {
                    ch.pair.1.clone()
                } else {
                    ch.pair.0.clone()
                }
            })
            .unwrap_or_else(|| "trainer".to_string());
        Self {
            env,
            weights: Arc::new(Vec::new()),
            round: 0,
            assigned: None,
            active: true,
            skip: false,
            total_samples: 0.0,
            mean_loss: f64::NAN,
            upload_sent_at: 0,
            round_targets: Vec::new(),
            acc: None,
            collect_t0: None,
            losses: Vec::new(),
            data_role,
            done: false,
        }
    }

    fn trainers(&self) -> Result<Vec<String>> {
        match &self.assigned {
            Some(t) => Ok(t.clone()),
            // role-scoped, not ends(): after a live extension the default
            // group also holds the legacy parent and sibling aggregators
            None => Ok((*self.env.chan("param-channel")?.ends_of_role(&self.data_role)).clone()),
        }
    }

    /// This aggregator's trainers that are still members of the channel —
    /// the churn-safe view every distribute/collect runs against.
    fn alive_trainers(&self) -> Result<Vec<String>> {
        let mine = self.trainers()?;
        let members = self.env.chan("param-channel")?.ends_of_role(&self.data_role);
        Ok(mine.into_iter().filter(|t| members.contains(t)).collect())
    }

    fn global_parent(&self) -> Result<String> {
        self.env
            .chan("agg-channel")?
            .ends()
            .first()
            .cloned()
            .context("no global aggregator on agg-channel")
    }

    /// Completed rounds as seen by this aggregator (custom-program test
    /// tasklets gate failure injection on it).
    pub fn round(&self) -> u64 {
        self.round
    }

    /// Boundary snapshot: the assigned trainer partition plus the round
    /// counter. Weights are deliberately absent — the next `recv_global`
    /// replaces them wholesale, and per-round stats are recomputed.
    pub fn snapshot_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("round", json::from_u64_hex(self.round));
        if let Some(t) = &self.assigned {
            o.insert(
                "assigned",
                Json::Arr(t.iter().map(|s| Json::Str(s.clone())).collect()),
            );
        }
        Json::Obj(o)
    }

    /// Rehydrate from a [`Self::snapshot_json`] snapshot — used both on
    /// resume-from-checkpoint and to seed a failover replacement pod.
    pub fn restore_from(&mut self, snap: &Json) -> Result<()> {
        if let Some(t) = snap.get("assigned").as_arr() {
            self.assigned = Some(
                t.iter()
                    .filter_map(|x| x.as_str().map(str::to_string))
                    .collect(),
            );
        }
        self.round = json::as_u64_hex(snap.get("round"))
            .context("aggregator checkpoint missing round")?;
        Ok(())
    }
}

// ------------------------------------------------------------- tasklets

fn recv_global(c: &mut AggregatorCtx) -> Result<()> {
    if c.done || !c.active {
        return Ok(());
    }
    c.skip = false;
    let parent = c.global_parent()?;
    loop {
        let msg = c.env.chan("agg-channel")?.recv(&parent)?;
        match &*msg.kind {
            "assign" => {
                // live extension: the sequencer's trainer partition for
                // this aggregator; precedes the round's weights. Consuming
                // it is idempotent across re-entries (set-and-continue).
                c.assigned = msg.meta().get("trainers").as_arr().map(|a| {
                    a.iter()
                        .filter_map(|t| t.as_str().map(str::to_string))
                        .collect()
                });
                continue;
            }
            "weights" => {
                let Payload::Floats(w) = msg.payload else {
                    bail!("weights without floats");
                };
                // recycle the superseded model (the mean installed by last
                // round's collect): by now every upstream/downstream
                // reference has been consumed, so it returns to the pool
                let old = std::mem::replace(&mut c.weights, w);
                c.env.job.pool.reclaim(old);
                c.round = msg.round;
            }
            "skip" => {
                // not selected this round: idle, and idle our trainers too
                c.skip = true;
                c.round = msg.round;
                let param = c.env.chan("param-channel")?;
                for t in c.alive_trainers()? {
                    param.send(&t, Message::control("skip", msg.round))?;
                }
            }
            "done" => {
                // H-FL: propagate termination downstream — to this
                // aggregator's own (still-present) trainers, so a shared
                // post-extension group never sees duplicate `done`s.
                let param = c.env.chan("param-channel")?;
                for t in c.alive_trainers()? {
                    param.send(&t, Message::control("done", msg.round))?;
                }
                c.done = true;
            }
            other => bail!("aggregator got unexpected '{other}' from global"),
        }
        return Ok(());
    }
}

fn distribute(c: &mut AggregatorCtx) -> Result<()> {
    if c.done || !c.active || c.skip {
        return Ok(());
    }
    let trainers = c.alive_trainers()?;
    let param = c.env.chan("param-channel")?;
    let msg = Message::floats("weights", c.round, c.weights.clone());
    let mut items = Vec::with_capacity(trainers.len());
    for t in &trainers {
        c.env.job.metrics.add_traffic(msg.size_bytes());
        items.push((t.clone(), msg.clone()));
    }
    param.send_fanout(items)?;
    // sends never advance the sender clock, so the span is zero-length
    let v = c.env.now();
    c.env
        .job
        .trace
        .span(&c.env.cfg.id, crate::trace::phase::DISTRIBUTE, c.round, v, v);
    // the streaming collect's expected upload universe: exactly the
    // trainers that received this round's weights
    c.round_targets = trainers;
    Ok(())
}

fn collect_and_aggregate(c: &mut AggregatorCtx) -> Result<()> {
    if c.done || !c.active || c.skip {
        return Ok(());
    }
    let elastic = c.env.job.timeline.is_elastic();
    // Quorum collect against *current* membership (not a frozen peer
    // list): the target re-computes on every re-entry, so departures
    // shrink it instead of blocking the round. Partial progress lives in
    // the streaming accumulator in `c.acc` (re-entrant across
    // cooperative yields); each update is folded — and its buffer
    // recycled — the moment its fold slot is reached.
    // The quorum target is computed per tasklet (re-)entry, not per
    // message: a mid-round departure wakes the parked collect, which
    // yields and re-enters here to re-count — the fold path itself stays
    // free of O(k) membership scans.
    let alive = c.alive_trainers()?;
    if alive.is_empty() && !elastic {
        bail!("aggregator '{}' has no trainers", c.env.cfg.id);
    }
    let target = super::quorum_target(alive.len(), c.env.job.tcfg.quorum);
    if c.acc.is_none() {
        c.collect_t0 = Some(c.env.now());
        c.acc = Some(Accumulator::new(
            c.env.job.compute.clone(),
            c.env.job.pool.clone(),
            c.round_targets.clone(),
        ));
        c.losses.clear();
    }
    while c.acc.as_ref().map(|a| a.len()).unwrap_or(0) < target {
        let (from, msg, _arrival) = c
            .env
            .chan("param-channel")?
            .recv_any_kind_timed("update")?;
        if msg.round != c.round {
            // straggler update from a past round: drop (recycling its
            // buffer if this was the last reference). Encoded payloads
            // are plain heap allocations, not pool-sized tensors — they
            // free on drop.
            if let Payload::Floats(w) = msg.payload {
                c.env.job.pool.reclaim(w);
            }
            continue;
        }
        let samples = msg.meta().get("samples").as_f64().unwrap_or(1.0);
        let loss = msg.meta().get("loss").as_f64().unwrap_or(0.0);
        let w = match msg.payload {
            Payload::Floats(w) => w,
            Payload::Encoded(enc) => {
                // codec path: the trainer uploaded an encoded *delta*;
                // reconstruct its model by decode-adding onto this round's
                // distributed weights (`c.weights` still holds them during
                // collect), so the fold below is codec-agnostic.
                let codec = c
                    .env
                    .job
                    .codec
                    .clone()
                    .context("encoded update received but no codec configured")?;
                let mut buf = c.env.job.pool.take_copy(&c.weights);
                codec.decode_add(
                    &enc,
                    Arc::get_mut(&mut buf).expect("pooled buffers are uniquely owned"),
                )?;
                buf
            }
            _ => bail!("update without floats"),
        };
        c.acc
            .as_mut()
            .expect("accumulator created above")
            .push(&from, w, samples)?;
        c.losses.push((from, loss));
    }
    let wait_t0 = c.collect_t0.take().unwrap_or_else(|| c.env.now());
    c.env.job.trace.span(
        &c.env.cfg.id,
        crate::trace::phase::WAIT,
        c.round,
        wait_t0,
        c.env.now(),
    );
    let acc = c.acc.take().expect("accumulator created above");
    let mut losses = std::mem::take(&mut c.losses);
    if losses.is_empty() {
        // all trainers departed: keep the model, contribute zero weight
        let _ = acc.finish()?;
        c.total_samples = 0.0;
        c.mean_loss = 0.0;
        return Ok(());
    }
    // deterministic loss mean: sum in sorted sender order, independent of
    // the (interleaving-dependent) consumption order
    losses.sort_by(|a, b| a.0.cmp(&b.0));
    c.mean_loss = losses.iter().map(|(_, l)| *l).sum::<f64>() / losses.len() as f64;
    let t0 = std::time::Instant::now();
    let out = acc.finish()?;
    c.total_samples = out.total_weight;
    if let Some(mean) = out.mean {
        let old = std::mem::replace(&mut c.weights, mean);
        // the superseded model goes back to the pool once every sibling
        // reference (global broadcast, in-flight mail) is gone
        c.env.job.pool.reclaim(old);
    }
    let dv = c.env.charge(t0);
    let v1 = c.env.now();
    c.env.job.trace.span(
        &c.env.cfg.id,
        crate::trace::phase::AGGREGATE,
        c.round,
        v1 - dv,
        v1,
    );
    Ok(())
}

fn upload(c: &mut AggregatorCtx) -> Result<()> {
    if c.done || !c.active || c.skip {
        return Ok(());
    }
    let parent = c.global_parent()?;
    let chan = c.env.chan("agg-channel")?;
    let mut meta = Json::obj();
    meta.insert("samples", Json::Num(c.total_samples));
    meta.insert("loss", Json::Num(c.mean_loss));
    meta.insert("worker", c.env.cfg.id.as_str());
    let msg =
        Message::floats("update", c.round, c.weights.clone()).with_meta(Json::Obj(meta));
    c.env.job.metrics.add_traffic(msg.size_bytes());
    c.upload_sent_at = chan.now();
    // publish-before-send: by the time the sequencer's collect returns,
    // this boundary snapshot is already in the checkpoint hub (it also
    // seeds the replacement pod if this aggregator later fails over)
    if let Some(sink) = &c.env.job.ckpt {
        sink.publish(&c.env.cfg.id, c.snapshot_json());
    }
    chan.send(&parent, msg)?;
    Ok(())
}

/// CO-FL only: coordinator's per-round assignment (trainer set + active).
fn get_assignment(c: &mut AggregatorCtx) -> Result<()> {
    if c.done {
        return Ok(());
    }
    let chan = c.env.chan("coord-a-channel")?;
    let coord = chan
        .ends()
        .first()
        .cloned()
        .context("no coordinator on coord-a-channel")?;
    let msg = chan.recv(&coord)?;
    match &*msg.kind {
        "assign" => {
            c.active = msg.meta().get("active").as_bool().unwrap_or(true);
            c.assigned = msg.meta().get("trainers").as_arr().map(|a| {
                a.iter()
                    .filter_map(|t| t.as_str().map(str::to_string))
                    .collect()
            });
            c.round = msg.round;
        }
        "done" => c.done = true,
        other => bail!("unexpected coordinator message '{other}'"),
    }
    Ok(())
}

/// CO-FL only: wait for the global's ack and report the observed upload
/// delay to the coordinator (feeds the load-balancing scheme of §6.1).
fn report(c: &mut AggregatorCtx) -> Result<()> {
    if c.done || !c.active || c.skip {
        return Ok(());
    }
    let agg_chan = c.env.chan("agg-channel")?;
    let parent = c.global_parent()?;
    let ack = agg_chan.recv_kind(&parent, "ack")?;
    // delay = when the global saw OUR upload, minus when we sent it
    let seen_at = ack.meta().get("arrival_us").as_f64().unwrap_or(0.0) as u64;
    let delay = seen_at.saturating_sub(c.upload_sent_at);
    let coord_chan = c.env.chan("coord-a-channel")?;
    let coord = coord_chan
        .ends()
        .first()
        .cloned()
        .context("no coordinator")?;
    let mut meta = Json::obj();
    meta.insert("delay_us", delay);
    meta.insert("worker", c.env.cfg.id.as_str());
    coord_chan.send(
        &coord,
        Message::control("report", c.round).with_meta(Json::Obj(meta)),
    )?;
    Ok(())
}

/// The base (H-FL) aggregator chain.
pub fn base_chain() -> Composer<AggregatorCtx> {
    Composer::new().loop_until(
        |c: &AggregatorCtx| c.done,
        Composer::new()
            .task("recv_global", recv_global)
            .task("distribute", distribute)
            .task("collect", collect_and_aggregate)
            .task("upload", upload),
    )
}

pub fn build(env: WorkerEnv, coordinated: bool) -> Result<Box<dyn Program>> {
    let mut ctx = AggregatorCtx::new(env);
    // Rehydrate before the chain starts (this chain has no init tasklet):
    // from the job checkpoint on resume, or from the sink's staged seed
    // when this pod is a failover replacement for a dead aggregator.
    if let Some(ck) = ctx.env.job.restore.clone() {
        if let Some(snap) = ck.workers.get(&ctx.env.cfg.id) {
            ctx.restore_from(snap)?;
        }
    }
    if let Some(sink) = ctx.env.job.ckpt.clone() {
        if let Some(seed) = sink.take_seed(&ctx.env.cfg.id) {
            ctx.restore_from(&seed)?;
        }
    }
    let mut chain = base_chain();
    if coordinated {
        chain.insert_before("recv_global", Tasklet::new("get_assignment", get_assignment))?;
        chain.insert_after("upload", Tasklet::new("report", report))?;
    }
    Ok(chain_program(chain, ctx))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_chain_shape() {
        assert_eq!(
            base_chain().aliases(),
            vec!["recv_global", "distribute", "collect", "upload"]
        );
    }

    #[test]
    fn cofl_surgery_shape() {
        let mut c = base_chain();
        c.insert_before("recv_global", Tasklet::new("get_assignment", get_assignment))
            .unwrap();
        c.insert_after("upload", Tasklet::new("report", report)).unwrap();
        assert_eq!(
            c.aliases(),
            vec![
                "get_assignment",
                "recv_global",
                "distribute",
                "collect",
                "upload",
                "report"
            ]
        );
    }
}
