//! Distributed-learning trainer (paper Fig 1a/2b): no aggregator at all.
//!
//! Trainers share model weights among themselves directly via ring
//! all-reduce every round — the "distributed" end of the paper's topology
//! spectrum, used by the C-FL→Distributed transformation of Table 4.
//! From the user's perspective this is the base-class swap the paper
//! describes: same `load/init/train` core functions, different chain.
//!
//! **Crash resilience** (checkpoint-armed jobs): there is no aggregator to
//! act as the committing worker, so the ring's *delegate* (lexically-first
//! member) plays controller. At each due boundary every member publishes
//! its snapshot; non-delegates then send a collective-op `"epoch"` marker
//! to the delegate. A member only reaches the marker send after its
//! all-reduce completed, and the full collective completing means every
//! chunk was consumed — so once the delegate has drained one marker per
//! peer, no ring message is in flight anywhere and every published
//! snapshot is ordered before the commit ([`checkpoint`]).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::channel::Message;
use crate::json::Json;
use crate::workflow::{Composer, Tasklet};

use super::collective::{is_delegate, RingAllReduce};
use super::{chain_program, Program, WorkerEnv};

pub struct DistributedCtx {
    pub env: WorkerEnv,
    data: Arc<crate::data::Dataset>,
    flat: Vec<f32>,
    batches: Vec<Vec<usize>>,
    plan: Vec<usize>,
    batch_pos: usize,
    round: u64,
    last_loss: f64,
    /// In-flight ring all-reduce; persisted so `allreduce` is re-entrant
    /// across cooperative yields.
    ring_op: Option<RingAllReduce>,
    /// Boundary this member was rehydrated at (0 = fresh run); the
    /// checkpoint tasklet skips boundaries `<=` this.
    resumed_at: u64,
    /// Delegate only: epoch markers drained so far at the in-progress
    /// boundary (re-entrant across cooperative yields).
    epoch_seen: usize,
    done: bool,
}

impl DistributedCtx {
    /// Boundary snapshot of a ring member's resumable state: model, RNG
    /// stream, epoch plan position, round counter and virtual clock.
    pub fn snapshot_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("round", crate::json::from_u64_hex(self.round));
        o.insert("clock", crate::json::from_u64_hex(self.env.now()));
        o.insert("rng", self.env.rng.to_json());
        o.insert("flat", super::floats_to_json(&self.flat));
        o.insert(
            "plan",
            Json::Arr(self.plan.iter().map(|i| Json::Num(*i as f64)).collect()),
        );
        o.insert("batch_pos", Json::Num(self.batch_pos as f64));
        Json::Obj(o)
    }

    /// Rehydrate from a [`Self::snapshot_json`] checkpoint and merge the
    /// saved boundary clock so virtual time continues from the kill point.
    pub fn restore_from(&mut self, snap: &Json) -> Result<()> {
        self.env.rng = crate::prng::Rng::from_json(snap.get("rng"))
            .context("ring checkpoint missing rng state")?;
        let flat = super::floats_from_json(snap.get("flat"));
        if flat.len() != self.flat.len() {
            bail!(
                "ring checkpoint model has {} params, job expects {}",
                flat.len(),
                self.flat.len()
            );
        }
        self.flat = flat;
        self.plan = snap
            .get("plan")
            .as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|v| v as usize).collect())
            .unwrap_or_default();
        self.batch_pos = snap.get("batch_pos").as_f64().unwrap_or(0.0) as usize;
        self.round =
            crate::json::as_u64_hex(snap.get("round")).context("ring checkpoint missing round")?;
        self.resumed_at = self.round;
        if let Some(t) = crate::json::as_u64_hex(snap.get("clock")) {
            self.env.clock.lock().unwrap().merge(t);
        }
        Ok(())
    }
}

fn load(c: &mut DistributedCtx) -> Result<()> {
    let b = c.env.job.compute.batch();
    c.batches = crate::data::batch_plan(&mut c.env.rng, c.data.len(), b);
    Ok(())
}

fn init(c: &mut DistributedCtx) -> Result<()> {
    // All members start from the shared init (same seed via job runtime).
    c.flat = c.env.job.init_flat.as_ref().clone();
    if let Some(ck) = c.env.job.restore.clone() {
        if let Some(snap) = ck.workers.get(&c.env.cfg.id) {
            c.restore_from(snap)?;
        }
    }
    Ok(())
}

/// Ring crash resilience (see module docs): runs at the top of the round
/// loop, where `c.round` counts completed rounds. Non-delegates publish
/// and send their epoch marker in one pass (sends never yield); the
/// delegate drains one marker per peer — re-entrant via `epoch_seen` —
/// publishes its own snapshot *after* the drain (the marker merges advance
/// its clock), then commits the epoch and runs the fault script.
fn checkpoint(c: &mut DistributedCtx) -> Result<()> {
    if c.done {
        return Ok(());
    }
    let Some(sink) = c.env.job.ckpt.clone() else {
        return Ok(());
    };
    if !sink.is_live() || c.round <= c.resumed_at || !sink.due(c.round) {
        return Ok(());
    }
    let (peers, delegate, members) = {
        let ring = c.env.chan("ring-channel")?;
        let mut members: Vec<String> = (*ring.ends()).clone();
        members.push(ring.worker_id().to_string());
        members.sort();
        (ring.ends().len(), is_delegate(ring), members)
    };
    if !delegate {
        sink.publish(&c.env.cfg.id, c.snapshot_json());
        let to = members.first().cloned().context("empty ring membership")?;
        let ring = c.env.chan("ring-channel")?;
        ring.send(&to, Message::control("epoch", c.round))?;
        return Ok(());
    }
    while c.epoch_seen < peers {
        {
            let ring = c.env.chan("ring-channel")?;
            let _ = ring.recv_any_kind_timed("epoch")?;
        }
        c.epoch_seen += 1;
    }
    c.epoch_seen = 0;
    sink.publish(&c.env.cfg.id, c.snapshot_json());
    sink.commit(
        c.round,
        c.env.job.timeline.cursor(),
        c.snapshot_json(),
        c.env.job.metrics.snapshot(),
        c.env.job.trace.snapshot(),
        &members,
    )?;
    let prev_due = c.round.saturating_sub(sink.policy().every.max(1));
    if sink.policy().faults.controller_kill_between(prev_due, c.round) {
        bail!("injected controller kill at round boundary {}", c.round);
    }
    Ok(())
}

fn train(c: &mut DistributedCtx) -> Result<()> {
    let tcfg = c.env.job.tcfg.clone();
    let compute = c.env.job.compute.clone();
    let b = compute.batch();
    let mut loss_sum = 0.0;
    for _ in 0..tcfg.local_steps {
        if c.plan.is_empty() || c.batch_pos >= c.plan.len() {
            let mut p: Vec<usize> = (0..c.batches.len()).collect();
            c.env.rng.shuffle(&mut p);
            c.plan = p;
            c.batch_pos = 0;
        }
        let bi = c.plan[c.batch_pos];
        c.batch_pos += 1;
        let (x, y) = c.data.gather_batch(&c.batches[bi], b);
        let t0 = Instant::now();
        let (nf, loss) = compute.train_step(&c.flat, &x, &y, tcfg.lr)?;
        c.env.charge(t0);
        c.flat = nf;
        loss_sum += loss as f64;
    }
    c.last_loss = loss_sum / tcfg.local_steps as f64;
    Ok(())
}

fn allreduce(c: &mut DistributedCtx) -> Result<()> {
    let samples = c.data.len() as f32;
    if c.ring_op.is_none() {
        let ring = c.env.chan("ring-channel")?;
        c.ring_op = Some(RingAllReduce::mean(ring, &c.flat, samples));
    }
    {
        let ring = c.env.chan("ring-channel")?;
        c.ring_op.as_mut().unwrap().poll(ring)?; // Pending propagates, op retained
    }
    let op = c.ring_op.take().unwrap();
    c.flat = op.into_mean()?;
    let ring = c.env.chan("ring-channel")?;
    // one member records the job-level series
    if is_delegate(ring) {
        let now = c.env.now();
        let m = &c.env.job.metrics;
        m.record(&c.env.cfg.id, "loss", c.round, c.last_loss);
        m.record(&c.env.cfg.id, "vtime_s", c.round, now as f64 / 1e6);
    }
    c.round += 1;
    if c.round >= c.env.job.rounds() {
        c.done = true;
    }
    Ok(())
}

pub fn chain() -> Composer<DistributedCtx> {
    Composer::new()
        .task("load", load)
        .task("init", init)
        .loop_until(
            |c: &DistributedCtx| c.done,
            Composer::new().task("train", train).task("allreduce", allreduce),
        )
}

impl DistributedCtx {
    /// Build the context for a distributed-trainer program over `env`
    /// (public for Role-SDK derivations of [`chain`]).
    pub fn new(env: WorkerEnv) -> Result<Self> {
        Ok(Self {
            data: env.shard()?,
            env,
            flat: Vec::new(),
            batches: Vec::new(),
            plan: Vec::new(),
            batch_pos: 0,
            round: 0,
            last_loss: f64::NAN,
            ring_op: None,
            resumed_at: 0,
            epoch_seen: 0,
            done: false,
        })
    }
}

pub fn build(env: WorkerEnv) -> Result<Box<dyn Program>> {
    let armed = env.job.ckpt.as_ref().is_some_and(|s| s.is_live());
    let mut chain = chain();
    if armed {
        // crash resilience: the boundary protocol runs at the top of the
        // round loop, mirroring the global aggregator's chain surgery
        chain.insert_before("train", Tasklet::new("checkpoint", checkpoint))?;
    }
    Ok(chain_program(chain, DistributedCtx::new(env)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_shape() {
        assert_eq!(
            chain().aliases(),
            vec!["load", "init", "train", "allreduce"]
        );
    }

    #[test]
    fn ckpt_surgery_inserts_boundary_protocol() {
        let mut c = chain();
        c.insert_before("train", Tasklet::new("checkpoint", checkpoint))
            .unwrap();
        assert_eq!(
            c.aliases(),
            vec!["load", "init", "checkpoint", "train", "allreduce"]
        );
    }
}
