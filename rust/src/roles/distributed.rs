//! Distributed-learning trainer (paper Fig 1a/2b): no aggregator at all.
//!
//! Trainers share model weights among themselves directly via ring
//! all-reduce every round — the "distributed" end of the paper's topology
//! spectrum, used by the C-FL→Distributed transformation of Table 4.
//! From the user's perspective this is the base-class swap the paper
//! describes: same `load/init/train` core functions, different chain.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::workflow::Composer;

use super::collective::{is_delegate, RingAllReduce};
use super::{chain_program, Program, WorkerEnv};

pub struct DistributedCtx {
    pub env: WorkerEnv,
    data: Arc<crate::data::Dataset>,
    flat: Vec<f32>,
    batches: Vec<Vec<usize>>,
    plan: Vec<usize>,
    batch_pos: usize,
    round: u64,
    last_loss: f64,
    /// In-flight ring all-reduce; persisted so `allreduce` is re-entrant
    /// across cooperative yields.
    ring_op: Option<RingAllReduce>,
    done: bool,
}

fn load(c: &mut DistributedCtx) -> Result<()> {
    let b = c.env.job.compute.batch();
    c.batches = crate::data::batch_plan(&mut c.env.rng, c.data.len(), b);
    Ok(())
}

fn init(c: &mut DistributedCtx) -> Result<()> {
    // All members start from the shared init (same seed via job runtime).
    c.flat = c.env.job.init_flat.as_ref().clone();
    Ok(())
}

fn train(c: &mut DistributedCtx) -> Result<()> {
    let tcfg = c.env.job.tcfg.clone();
    let compute = c.env.job.compute.clone();
    let b = compute.batch();
    let mut loss_sum = 0.0;
    for _ in 0..tcfg.local_steps {
        if c.plan.is_empty() || c.batch_pos >= c.plan.len() {
            let mut p: Vec<usize> = (0..c.batches.len()).collect();
            c.env.rng.shuffle(&mut p);
            c.plan = p;
            c.batch_pos = 0;
        }
        let bi = c.plan[c.batch_pos];
        c.batch_pos += 1;
        let (x, y) = c.data.gather_batch(&c.batches[bi], b);
        let t0 = Instant::now();
        let (nf, loss) = compute.train_step(&c.flat, &x, &y, tcfg.lr)?;
        c.env.charge(t0);
        c.flat = nf;
        loss_sum += loss as f64;
    }
    c.last_loss = loss_sum / tcfg.local_steps as f64;
    Ok(())
}

fn allreduce(c: &mut DistributedCtx) -> Result<()> {
    let samples = c.data.len() as f32;
    if c.ring_op.is_none() {
        let ring = c.env.chan("ring-channel")?;
        c.ring_op = Some(RingAllReduce::mean(ring, &c.flat, samples));
    }
    {
        let ring = c.env.chan("ring-channel")?;
        c.ring_op.as_mut().unwrap().poll(ring)?; // Pending propagates, op retained
    }
    let op = c.ring_op.take().unwrap();
    c.flat = op.into_mean()?;
    let ring = c.env.chan("ring-channel")?;
    // one member records the job-level series
    if is_delegate(ring) {
        let now = c.env.now();
        let m = &c.env.job.metrics;
        m.record(&c.env.cfg.id, "loss", c.round, c.last_loss);
        m.record(&c.env.cfg.id, "vtime_s", c.round, now as f64 / 1e6);
    }
    c.round += 1;
    if c.round >= c.env.job.rounds() {
        c.done = true;
    }
    Ok(())
}

pub fn chain() -> Composer<DistributedCtx> {
    Composer::new()
        .task("load", load)
        .task("init", init)
        .loop_until(
            |c: &DistributedCtx| c.done,
            Composer::new().task("train", train).task("allreduce", allreduce),
        )
}

impl DistributedCtx {
    /// Build the context for a distributed-trainer program over `env`
    /// (public for Role-SDK derivations of [`chain`]).
    pub fn new(env: WorkerEnv) -> Result<Self> {
        Ok(Self {
            data: env.shard()?,
            env,
            flat: Vec::new(),
            batches: Vec::new(),
            plan: Vec::new(),
            batch_pos: 0,
            round: 0,
            last_loss: f64::NAN,
            ring_op: None,
            done: false,
        })
    }
}

pub fn build(env: WorkerEnv) -> Result<Box<dyn Program>> {
    Ok(chain_program(chain(), DistributedCtx::new(env)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_shape() {
        assert_eq!(
            chain().aliases(),
            vec!["load", "init", "train", "allreduce"]
        );
    }
}
