//! Hybrid-FL trainer (paper §6.2, Fig 1e/2e).
//!
//! Co-located trainers form a cluster on the fast `ring-channel` (p2p
//! backend); each round every trainer trains locally, the cluster
//! ring-allreduces a weighted cluster model, and the **delegate** (one
//! member) uploads a single copy over the slow `param-channel` (broker
//! backend). This is what cuts per-round upload from `N×model` to
//! `clusters×model` (250 MB -> 25 MB in the paper's Fig 11 setup).
//!
//! The chain reuses the base trainer's fetch tasklet alias scheme:
//! `load >> init >> Loop(fetch >> train >> cluster_agg >> upload)` — from a
//! user's perspective, switching C-FL -> Hybrid is a base-class swap plus
//! TAG changes (Table 4 column "C-FL→Hybrid").

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::channel::{Message, Payload};
use crate::json::Json;
use crate::workflow::{Composer, Tasklet};

use super::collective::{is_delegate, RingAllReduce};
use super::{chain_program, Program, WorkerEnv};

pub struct HybridCtx {
    pub env: WorkerEnv,
    data: Arc<crate::data::Dataset>,
    flat: Vec<f32>,
    global: Vec<f32>,
    batches: Vec<Vec<usize>>,
    plan: Vec<usize>,
    batch_pos: usize,
    parent: Option<String>,
    round: u64,
    cluster_samples: f32,
    last_loss: f64,
    /// In-flight ring all-reduce; persisted so `cluster_agg` is re-entrant
    /// across cooperative yields.
    ring_op: Option<RingAllReduce>,
    /// Codec error-feedback residual (lossy schemes bank what they drop
    /// here and fold it into the next round's delta). Only the delegate
    /// ever touches it.
    residual: Vec<f32>,
    done: bool,
}

impl HybridCtx {
    /// Boundary snapshot of the resumable state (mirrors the trainer's).
    /// Published before the delegate's upload send — and, for
    /// non-delegates, before their "epoch" marker send — so the global's
    /// boundary drain orders every cluster member's snapshot ahead of the
    /// commit that references it.
    pub fn snapshot_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("round", crate::json::from_u64_hex(self.round));
        o.insert("rng", self.env.rng.to_json());
        o.insert(
            "plan",
            Json::Arr(self.plan.iter().map(|i| Json::Num(*i as f64)).collect()),
        );
        o.insert("batch_pos", Json::Num(self.batch_pos as f64));
        if !self.residual.is_empty() {
            o.insert("residual", super::floats_to_json(&self.residual));
        }
        if let Some(p) = &self.parent {
            o.insert("parent", Json::Str(p.clone()));
        }
        Json::Obj(o)
    }

    /// Rehydrate from a [`Self::snapshot_json`] snapshot.
    pub fn restore_from(&mut self, snap: &Json) -> Result<()> {
        self.env.rng = crate::prng::Rng::from_json(snap.get("rng"))
            .context("hybrid checkpoint missing rng state")?;
        self.plan = snap
            .get("plan")
            .as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|v| v as usize).collect())
            .unwrap_or_default();
        self.batch_pos = snap.get("batch_pos").as_f64().unwrap_or(0.0) as usize;
        let residual = super::floats_from_json(snap.get("residual"));
        if !residual.is_empty() {
            self.residual = residual;
        }
        if let Some(p) = snap.get("parent").as_str() {
            self.parent = Some(p.to_string());
        }
        self.round = crate::json::as_u64_hex(snap.get("round"))
            .context("hybrid checkpoint missing round")?;
        Ok(())
    }
}

fn load(c: &mut HybridCtx) -> Result<()> {
    let b = c.env.job.compute.batch();
    c.batches = crate::data::batch_plan(&mut c.env.rng, c.data.len(), b);
    Ok(())
}

fn init(c: &mut HybridCtx) -> Result<()> {
    let d = c.env.job.compute.d_pad();
    c.flat = vec![0.0; d];
    c.global = vec![0.0; d];
    if let Some(ck) = c.env.job.restore.clone() {
        if let Some(snap) = ck.workers.get(&c.env.cfg.id) {
            c.restore_from(snap)?;
        }
    }
    Ok(())
}

/// Boundary bookkeeping shared by both upload variants: publish this
/// member's snapshot, then — non-delegates only, at due boundaries — send
/// the collective-op "epoch" marker the global's checkpoint drain counts.
/// Delegates need no marker: their update send is the happens-before edge.
/// A scripted [`crate::controlplane::FaultPlan`] worker kill fires here,
/// after the publish (failover seed) and before any send.
fn boundary_ckpt(c: &HybridCtx, delegate: bool) -> Result<()> {
    let Some(sink) = c.env.job.ckpt.clone() else {
        return Ok(());
    };
    sink.publish(&c.env.cfg.id, c.snapshot_json());
    let boundary = c.round + 1;
    if sink.policy().faults.kills_worker_at(&c.env.cfg.id, boundary) {
        bail!("injected worker kill at round boundary {boundary}");
    }
    if !delegate && sink.is_live() && sink.due(boundary) {
        let parent = c.parent.clone().context("no parent for epoch marker")?;
        let param = c.env.chan("param-channel")?;
        param.send(&parent, Message::control("epoch", c.round))?;
    }
    Ok(())
}

fn fetch(c: &mut HybridCtx) -> Result<()> {
    if c.done {
        return Ok(());
    }
    let param = c.env.chan("param-channel")?;
    if c.parent.is_none() {
        c.parent = param.ends().first().cloned();
    }
    let parent = c.parent.clone().context("no global aggregator visible")?;
    let msg = param.recv(&parent)?;
    match &*msg.kind {
        "weights" => {
            let Payload::Floats(w) = &msg.payload else {
                bail!("weights without floats");
            };
            c.global.copy_from_slice(w);
            c.flat.copy_from_slice(w);
            c.round = msg.round;
        }
        "done" => c.done = true,
        other => bail!("hybrid trainer got '{other}'"),
    }
    // last consumer of the broadcast returns the buffer to the pool
    if let Payload::Floats(w) = msg.payload {
        c.env.job.pool.reclaim(w);
    }
    Ok(())
}

fn train(c: &mut HybridCtx) -> Result<()> {
    if c.done {
        return Ok(());
    }
    let tcfg = c.env.job.tcfg.clone();
    let compute = c.env.job.compute.clone();
    let b = compute.batch();
    let mut loss_sum = 0.0;
    for _ in 0..tcfg.local_steps {
        if c.plan.is_empty() || c.batch_pos >= c.plan.len() {
            c.plan = {
                let mut p: Vec<usize> = (0..c.batches.len()).collect();
                c.env.rng.shuffle(&mut p);
                p
            };
            c.batch_pos = 0;
        }
        let bi = c.plan[c.batch_pos];
        c.batch_pos += 1;
        let (x, y) = c.data.gather_batch(&c.batches[bi], b);
        let t0 = Instant::now();
        let (nf, loss) = compute.train_step(&c.flat, &x, &y, tcfg.lr)?;
        c.env.charge(t0);
        c.flat = nf;
        loss_sum += loss as f64;
    }
    c.last_loss = loss_sum / tcfg.local_steps as f64;
    Ok(())
}

/// Ring-allreduce the cluster model over the fast p2p channel. The
/// collective's state machine lives in the context, so a cooperative yield
/// mid-ring resumes the protocol instead of restarting (and duplicating
/// sends).
fn cluster_agg(c: &mut HybridCtx) -> Result<()> {
    if c.done {
        return Ok(());
    }
    let my_samples = c.data.len() as f32;
    if c.ring_op.is_none() {
        let ring = c.env.chan("ring-channel")?;
        c.ring_op = Some(RingAllReduce::mean(ring, &c.flat, my_samples));
    }
    {
        let ring = c.env.chan("ring-channel")?;
        c.ring_op.as_mut().unwrap().poll(ring)?; // Pending propagates, op retained
    }
    let op = c.ring_op.take().unwrap();
    c.flat = op.into_mean()?;
    // cluster sample total for upstream weighting
    let ring = c.env.chan("ring-channel")?;
    let k = ring.ends().len() + 1;
    c.cluster_samples = my_samples * k as f32; // shards are equal-sized by construction
    Ok(())
}

/// Only the cluster delegate uploads — the bandwidth saving of Hybrid FL.
fn upload(c: &mut HybridCtx) -> Result<()> {
    if c.done {
        return Ok(());
    }
    let ring = c.env.chan("ring-channel")?;
    if !is_delegate(ring) {
        return boundary_ckpt(c, false);
    }
    let parent = c.parent.clone().context("no parent")?;
    let mut meta = Json::obj();
    meta.insert("samples", Json::Num(c.cluster_samples as f64));
    meta.insert("loss", Json::Num(c.last_loss));
    meta.insert("cluster", ring.group());
    let msg = Message::floats("update", c.round, c.env.job.pool.take_copy(&c.flat))
        .with_meta(Json::Obj(meta));
    let param = c.env.chan("param-channel")?;
    c.env.job.metrics.add_traffic(msg.size_bytes());
    c.env
        .job
        .metrics
        .record(&c.env.cfg.id, "upload_bytes", c.round, msg.size_bytes() as f64);
    boundary_ckpt(c, true)?;
    param.send(&parent, msg)?;
    Ok(())
}

/// Codec variant of [`upload`] (same chain-surgery mechanism as the base
/// trainer): the delegate encodes the cluster *delta* against this
/// round's distributed model and ships the compressed form — the
/// `VirtualNet` then charges encoded bytes, stacking the codec's saving
/// on top of Hybrid's clusters×model reduction. The global's hybrid
/// collect decode-adds onto its own copy of the round base.
fn upload_encoded(c: &mut HybridCtx) -> Result<()> {
    if c.done {
        return Ok(());
    }
    let ring = c.env.chan("ring-channel")?;
    if !is_delegate(ring) {
        return boundary_ckpt(c, false);
    }
    let codec = c
        .env
        .job
        .codec
        .clone()
        .context("upload_encoded requires a codec on the job")?;
    let parent = c.parent.clone().context("no parent")?;
    let delta = crate::model::sub(&c.flat, &c.global);
    let enc = Arc::new(codec.encode(&delta, &mut c.residual));
    let mut meta = Json::obj();
    meta.insert("samples", Json::Num(c.cluster_samples as f64));
    meta.insert("loss", Json::Num(c.last_loss));
    meta.insert("cluster", ring.group());
    let msg = Message::encoded("update", c.round, enc).with_meta(Json::Obj(meta));
    let param = c.env.chan("param-channel")?;
    c.env.job.metrics.add_traffic(msg.size_bytes());
    c.env
        .job
        .metrics
        .record(&c.env.cfg.id, "upload_bytes", c.round, msg.size_bytes() as f64);
    boundary_ckpt(c, true)?;
    param.send(&parent, msg)?;
    Ok(())
}

pub fn chain() -> Composer<HybridCtx> {
    Composer::new()
        .task("load", load)
        .task("init", init)
        .loop_until(
            |c: &HybridCtx| c.done,
            Composer::new()
                .task("fetch", fetch)
                .task("train", train)
                .task("cluster_agg", cluster_agg)
                .task("upload", upload),
        )
}

impl HybridCtx {
    /// Build the context for a hybrid-trainer program over `env` (public
    /// for Role-SDK derivations of [`chain`]).
    pub fn new(env: WorkerEnv) -> Result<Self> {
        Ok(Self {
            data: env.shard()?,
            env,
            flat: Vec::new(),
            global: Vec::new(),
            batches: Vec::new(),
            plan: Vec::new(),
            batch_pos: 0,
            parent: None,
            round: 0,
            cluster_samples: 0.0,
            last_loss: f64::NAN,
            ring_op: None,
            residual: Vec::new(),
            done: false,
        })
    }
}

pub fn build(env: WorkerEnv) -> Result<Box<dyn Program>> {
    let mut chain = chain();
    if env.job.codec.is_some() {
        // codec-enabled jobs swap the upload tasklet for the encoding one
        // — same Table 1 surgery mechanism as every other derivation
        chain.replace_with("upload", Tasklet::new("upload_encoded", upload_encoded))?;
    }
    Ok(chain_program(chain, HybridCtx::new(env)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_shape() {
        assert_eq!(
            chain().aliases(),
            vec!["load", "init", "fetch", "train", "cluster_agg", "upload"]
        );
    }

    #[test]
    fn codec_surgery_takes_over_the_upload_slot() {
        let mut c = chain();
        c.replace_with("upload", Tasklet::new("upload_encoded", upload_encoded))
            .unwrap();
        assert_eq!(
            c.aliases(),
            vec!["load", "init", "fetch", "train", "cluster_agg", "upload_encoded"]
        );
    }
}
