//! Coordinator role — the CO-FL extension of §6.1.
//!
//! Each round the coordinator (1) assigns every trainer to an active
//! aggregator (bipartite rebalancing over the replica-expanded aggregator
//! tier), (2) tells the global aggregator which aggregators participate,
//! (3) collects per-aggregator upload-delay reports, and (4) runs the
//! paper's **load-balancing scheme**: an aggregator whose upload delay is a
//! large multiple of the round's median for three consecutive rounds is
//! excluded with *binary backoff* (1, 2, 4, 8, 16 rounds), with a one-round
//! probe between exclusions — reproducing the round-6→round-28 timeline of
//! the paper's Fig 10.
//!
//! The coordinator also owns termination: after the last round it
//! broadcasts `done` on all coordinator channels (which is why CO-FL
//! removes the global aggregator's `end_of_train`, Fig 9).

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::channel::Message;
use crate::json::Json;
use crate::workflow::Composer;

use super::{chain_program, Program, WorkerEnv};

/// Straggler-tracking state per aggregator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AggState {
    /// Healthy; counts consecutive slow rounds.
    Normal { consecutive_slow: u32 },
    /// Sitting out `remaining` rounds; next exclusion will last
    /// `next_backoff`.
    Excluded { remaining: u64, next_backoff: u64 },
    /// One-round probe after an exclusion window.
    Probing { next_backoff: u64 },
}

/// The detection + binary-backoff policy (paper §6.1), isolated from
/// channel plumbing so it is unit-testable round by round.
pub struct LoadBalancer {
    state: HashMap<String, AggState>,
    /// "slow" means delay > `factor` x median of this round's delays.
    pub factor: f64,
    /// consecutive slow rounds before the first exclusion.
    pub patience: u32,
}

impl LoadBalancer {
    pub fn new() -> Self {
        Self {
            state: HashMap::new(),
            factor: 3.0,
            patience: 3,
        }
    }

    /// Aggregators that participate this round (excluded ones sit out),
    /// advancing exclusion windows.
    pub fn active(&mut self, aggregators: &[String]) -> Vec<String> {
        let mut active = Vec::new();
        for a in aggregators {
            let st = self
                .state
                .entry(a.clone())
                .or_insert(AggState::Normal { consecutive_slow: 0 });
            match st {
                AggState::Excluded {
                    remaining,
                    next_backoff,
                } => {
                    *remaining -= 1;
                    if *remaining == 0 {
                        *st = AggState::Probing {
                            next_backoff: *next_backoff,
                        };
                    }
                    // sits out this round
                }
                _ => active.push(a.clone()),
            }
        }
        // never exclude everyone
        if active.is_empty() {
            active.push(aggregators[0].clone());
        }
        active
    }

    /// Feed this round's upload delays (active aggregators only); updates
    /// detection state.
    pub fn observe(&mut self, delays: &HashMap<String, u64>) {
        if delays.is_empty() {
            return;
        }
        let mut ds: Vec<u64> = delays.values().copied().collect();
        ds.sort();
        // lower median: with one straggler among k reporters the median
        // must land on a healthy sample (k=2 included).
        let median = ds[(ds.len() - 1) / 2] as f64;
        for (agg, &delay) in delays {
            let slow = ds.len() >= 2 && delay as f64 > self.factor * median.max(1.0);
            let st = self
                .state
                .entry(agg.clone())
                .or_insert(AggState::Normal { consecutive_slow: 0 });
            *st = match st.clone() {
                AggState::Normal { consecutive_slow } => {
                    let n = if slow { consecutive_slow + 1 } else { 0 };
                    if n >= self.patience {
                        AggState::Excluded {
                            remaining: 1,
                            next_backoff: 2,
                        }
                    } else {
                        AggState::Normal { consecutive_slow: n }
                    }
                }
                AggState::Probing { next_backoff } => {
                    if slow {
                        AggState::Excluded {
                            remaining: next_backoff,
                            next_backoff: next_backoff * 2,
                        }
                    } else {
                        AggState::Normal { consecutive_slow: 0 }
                    }
                }
                // an excluded aggregator shouldn't report; keep state
                s @ AggState::Excluded { .. } => s,
            };
        }
    }

    pub fn state_of(&self, agg: &str) -> Option<&AggState> {
        self.state.get(agg)
    }
}

impl Default for LoadBalancer {
    fn default() -> Self {
        Self::new()
    }
}

pub struct CoordinatorCtx {
    pub env: WorkerEnv,
    lb: LoadBalancer,
    round: u64,
    active: Vec<String>,
    pub done: bool,
}

impl CoordinatorCtx {
    /// Build the context for a coordinator program over `env` (public for
    /// Role-SDK derivations of [`chain`]).
    pub fn new(env: WorkerEnv) -> Self {
        Self {
            env,
            lb: LoadBalancer::new(),
            round: 0,
            active: Vec::new(),
            done: false,
        }
    }
}

// ------------------------------------------------------------- tasklets

fn assign(c: &mut CoordinatorCtx) -> Result<()> {
    if c.done {
        return Ok(());
    }
    let aggs = c.env.chan("coord-a-channel")?.ends();
    let trainers = c.env.chan("coord-t-channel")?.ends();
    if aggs.is_empty() || trainers.is_empty() {
        bail!("coordinator sees no aggregators or trainers");
    }
    c.active = c.lb.active(&aggs);

    // trainer -> aggregator round-robin over the active set
    let mut assignment: HashMap<String, Vec<String>> =
        c.active.iter().map(|a| (a.clone(), Vec::new())).collect();
    let tchan = c.env.chan("coord-t-channel")?;
    for (i, t) in trainers.iter().enumerate() {
        let agg = &c.active[i % c.active.len()];
        assignment.get_mut(agg).unwrap().push(t.clone());
        let mut meta = Json::obj();
        meta.insert("parent", agg.as_str());
        tchan.send(t, Message::control("assign", c.round).with_meta(Json::Obj(meta)))?;
    }

    // aggregators: trainer set + active flag
    let achan = c.env.chan("coord-a-channel")?;
    for a in aggs.iter() {
        let mut meta = Json::obj();
        let is_active = c.active.contains(a);
        meta.insert("active", is_active);
        let ts = assignment.get(a).cloned().unwrap_or_default();
        meta.insert(
            "trainers",
            Json::Arr(ts.into_iter().map(Json::Str).collect()),
        );
        achan.send(a, Message::control("assign", c.round).with_meta(Json::Obj(meta)))?;
    }

    // global: the active aggregator list
    let gchan = c.env.chan("coord-g-channel")?;
    let global = gchan.ends();
    let mut meta = Json::obj();
    meta.insert(
        "aggregators",
        Json::Arr(c.active.iter().cloned().map(Json::Str).collect()),
    );
    for g in global.iter() {
        gchan.send(
            g,
            Message::control("assign", c.round).with_meta(Json::Obj(meta.clone())),
        )?;
    }
    Ok(())
}

fn collect_reports(c: &mut CoordinatorCtx) -> Result<()> {
    if c.done {
        return Ok(());
    }
    let achan = c.env.chan("coord-a-channel")?;
    let got = achan.recv_fifo(&c.active)?;
    let mut delays = HashMap::new();
    for (from, msg) in got {
        if &*msg.kind != "report" {
            bail!("coordinator expected 'report', got '{}'", msg.kind);
        }
        let delay = msg.meta().get("delay_us").as_f64().unwrap_or(0.0) as u64;
        c.env
            .job
            .metrics
            .record(&from, "upload_delay_s", c.round, delay as f64 / 1e6);
        delays.insert(from, delay);
    }
    c.lb.observe(&delays);
    c.env.job.metrics.record(
        &c.env.cfg.id,
        "active_aggregators",
        c.round,
        c.active.len() as f64,
    );
    c.round += 1;
    if c.round >= c.env.job.rounds() {
        c.done = true;
    }
    Ok(())
}

fn end_of_train(c: &mut CoordinatorCtx) -> Result<()> {
    // The coordinator owns termination in CO-FL.
    for ch in ["coord-t-channel", "coord-a-channel", "coord-g-channel"] {
        c.env.chan(ch)?.broadcast(Message::control("done", c.round))?;
    }
    Ok(())
}

pub fn chain() -> Composer<CoordinatorCtx> {
    Composer::new()
        .loop_until(
            |c: &CoordinatorCtx| c.done,
            Composer::new()
                .task("assign", assign)
                .task("collect_reports", collect_reports),
        )
        .task("end_of_train", end_of_train)
}

pub fn build(env: WorkerEnv) -> Result<Box<dyn Program>> {
    Ok(chain_program(chain(), CoordinatorCtx::new(env)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn aggs() -> Vec<String> {
        vec!["a0".to_string(), "a1".to_string()]
    }

    fn round(lb: &mut LoadBalancer, slow_delay: u64) -> Vec<String> {
        let active = lb.active(&aggs());
        let mut delays = HashMap::new();
        for a in &active {
            delays.insert(a.clone(), if a == "a1" { slow_delay } else { 1_000 });
        }
        lb.observe(&delays);
        active
    }

    #[test]
    fn no_exclusion_when_healthy() {
        let mut lb = LoadBalancer::new();
        for _ in 0..10 {
            assert_eq!(round(&mut lb, 1_000).len(), 2);
        }
    }

    #[test]
    fn paper_fig10_backoff_timeline() {
        // Straggler from "round 6" on; detection after 3 consecutive slow
        // rounds; then exclusions of 1, 2, 4, 8 rounds with probes between.
        let mut lb = LoadBalancer::new();
        let mut excluded_rounds = Vec::new();
        for r in 0..30u64 {
            let slow = r >= 6; // congestion starts at round 6
            let active = round(&mut lb, if slow { 100_000 } else { 1_000 });
            if !active.contains(&"a1".to_string()) {
                excluded_rounds.push(r);
            }
        }
        // slow observed at 6,7,8 -> excluded at 9; probe 10 (slow);
        // excluded 11-12; probe 13; excluded 14-17; probe 18; excluded 19-26;
        // probe 27; excluded 28... (16 rounds)
        assert_eq!(
            excluded_rounds,
            vec![9, 11, 12, 14, 15, 16, 17, 19, 20, 21, 22, 23, 24, 25, 26, 28, 29]
        );
    }

    #[test]
    fn recovery_resets_state() {
        let mut lb = LoadBalancer::new();
        for _ in 0..6 {
            round(&mut lb, 100_000); // slow: rounds 0,1,2 detect; 3 excluded; 4 probe(slow); 5.. excluded
        }
        // congestion clears; after the current exclusion + probe the
        // aggregator must return to Normal and stay active.
        let mut consecutive_active = 0;
        for _ in 0..12 {
            let active = round(&mut lb, 1_000);
            if active.len() == 2 {
                consecutive_active += 1;
            } else {
                consecutive_active = 0;
            }
        }
        assert!(consecutive_active >= 6, "straggler did not recover");
        assert_eq!(
            lb.state_of("a1"),
            Some(&AggState::Normal { consecutive_slow: 0 })
        );
    }

    #[test]
    fn never_excludes_everyone() {
        let mut lb = LoadBalancer::new();
        lb.state.insert(
            "a0".into(),
            AggState::Excluded { remaining: 5, next_backoff: 2 },
        );
        lb.state.insert(
            "a1".into(),
            AggState::Excluded { remaining: 5, next_backoff: 2 },
        );
        let active = lb.active(&aggs());
        assert!(!active.is_empty());
    }

    #[test]
    fn single_aggregator_is_never_slow() {
        // With one reporter there is no discrepancy to detect.
        let mut lb = LoadBalancer::new();
        let one = vec!["a0".to_string()];
        for _ in 0..10 {
            let active = lb.active(&one);
            let mut d = HashMap::new();
            d.insert("a0".to_string(), 1_000_000u64);
            lb.observe(&d);
            assert_eq!(active, one);
        }
    }

    #[test]
    fn chain_shape() {
        assert_eq!(
            chain().aliases(),
            vec!["assign", "collect_reports", "end_of_train"]
        );
    }
}
