//! The Role SDK's registry: the public, data-driven role↔program binding
//! (paper §4.1 — "the flexible binding between role and program").
//!
//! A [`RoleRegistry`] maps **program names** to [`ProgramFactory`]
//! closures. Which program a worker runs is decided entirely by data the
//! spec controls:
//!
//! 1. the role's explicit `program:` field, when declared, else
//! 2. the registry's default binding for `(role name, flavor)`, where the
//!    flavour is the spec's `tag.flavor` (or the validate-time inference,
//!    [`crate::tag::validate::infer_flavor`]).
//!
//! All built-in programs are registered through the same public API any
//! downstream mechanism uses ([`RoleRegistry::builtin`]), and each one is
//! assembled from its role's **exported base chain** via the Table-1
//! surgery API — a custom program does exactly what the built-ins do, from
//! outside the crate. The old `build_program` role-name `match` (and its
//! `"ring-channel"` magic-name sniffing) is gone; nothing in `roles/`
//! needs editing to add a mechanism.
//!
//! # Registering a custom program end-to-end
//!
//! ```
//! use std::sync::Arc;
//!
//! use flame::channel::Backend;
//! use flame::control::{Controller, JobOptions};
//! use flame::roles::sdk::{chain_program, trainer_chain, Tasklet, TrainerCtx};
//! use flame::store::Store;
//!
//! // Derive a custom trainer from the exported base chain by Table-1
//! // surgery (paper Fig 9 style): add a bookkeeping tasklet after train.
//! let mut spec = flame::topo::classical(2, Backend::P2p).rounds(1).build();
//! spec.flavor = Some(flame::tag::Flavor::Sync);
//! spec.roles
//!     .iter_mut()
//!     .find(|r| r.name == "trainer")
//!     .unwrap()
//!     .program = Some("audited-trainer".into());
//!
//! let opts = JobOptions::mock().with_program(
//!     "audited-trainer",
//!     Arc::new(|env, _binding| {
//!         let ctx = TrainerCtx::new(env)?;
//!         let mut chain = trainer_chain();
//!         chain.insert_after(
//!             "train",
//!             Tasklet::new("audit", |c: &mut TrainerCtx| {
//!                 let _round = c.round; // custom logic goes here
//!                 Ok(())
//!             }),
//!         )?;
//!         Ok(chain_program(chain, ctx))
//!     }),
//! );
//!
//! let mut ctl = Controller::new(Arc::new(Store::in_memory()));
//! let report = ctl.submit(spec, opts).unwrap();
//! assert_eq!(report.workers, 3);
//! ```

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::tag::{Flavor, JobSpec};

use super::{aggregator, coordinator, distributed, global, hybrid, trainer};
use super::{Program, WorkerEnv};

/// Builds one worker's program from its environment and resolved binding.
///
/// Factories are `Arc`-shared closures so a registry can be cloned per job
/// (base registry + `JobOptions::with_program` overrides) without cloning
/// any program logic.
pub type ProgramFactory =
    Arc<dyn Fn(WorkerEnv, &RoleBinding) -> Result<Box<dyn Program>> + Send + Sync>;

/// The resolved role↔program binding handed to a [`ProgramFactory`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RoleBinding {
    /// The TAG role this worker instantiates.
    pub role: String,
    /// The registered program it runs.
    pub program: String,
    /// The job's topology flavour (declared or inferred).
    pub flavor: Flavor,
}

/// One row of the program catalog (`flame roles`,
/// [`RoleRegistry::catalog`]): a registered program plus the default
/// rules binding it. Derived from the authoritative rule list at call
/// time, so it can never desync from dispatch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProgramInfo {
    pub name: String,
    /// Default `(role, flavor)` rules binding this program (`None` =
    /// the role's any-flavour fallback); empty for programs reachable
    /// only via an explicit spec `program:` field.
    pub bindings: Vec<(String, Option<Flavor>)>,
}

/// A default-binding rule: `(role, flavor)` → program name. `flavor:
/// None` is the role's any-flavour fallback.
#[derive(Debug, Clone)]
struct BindingRule {
    role: String,
    flavor: Option<Flavor>,
    program: String,
}

/// Registry of role programs (see module docs).
#[derive(Clone, Default)]
pub struct RoleRegistry {
    programs: BTreeMap<String, ProgramFactory>,
    defaults: Vec<BindingRule>,
}

impl RoleRegistry {
    /// An empty registry (no programs, no default bindings).
    pub fn new() -> Self {
        Self::default()
    }

    /// The registry every controller starts from: the six built-in roles'
    /// programs (plus their CO-FL variants), registered through the same
    /// public API custom code uses, with the default `(role, flavor)`
    /// bindings that reproduce the paper's §4.4 role set.
    pub fn builtin() -> Self {
        let mut r = Self::new();
        r.register("trainer", Arc::new(|env, _b| trainer::build(env, false)));
        r.register(
            "coordinated-trainer",
            Arc::new(|env, _b| trainer::build(env, true)),
        );
        r.register("hybrid-trainer", Arc::new(|env, _b| hybrid::build(env)));
        r.register(
            "distributed-trainer",
            Arc::new(|env, _b| distributed::build(env)),
        );
        r.register("aggregator", Arc::new(|env, _b| aggregator::build(env, false)));
        r.register(
            "coordinated-aggregator",
            Arc::new(|env, _b| aggregator::build(env, true)),
        );
        r.register(
            "global-aggregator",
            Arc::new(|env, _b| global::build(env, false)),
        );
        r.register(
            "coordinated-global-aggregator",
            Arc::new(|env, _b| global::build(env, true)),
        );
        r.register("coordinator", Arc::new(|env, _b| coordinator::build(env)));

        // default bindings: (role, flavor) → program; None = any flavour
        let rules = [
            ("trainer", None, "trainer"),
            ("trainer", Some(Flavor::Coordinated), "coordinated-trainer"),
            ("trainer", Some(Flavor::Hybrid), "hybrid-trainer"),
            ("trainer", Some(Flavor::Distributed), "distributed-trainer"),
            ("aggregator", None, "aggregator"),
            (
                "aggregator",
                Some(Flavor::Coordinated),
                "coordinated-aggregator",
            ),
            ("global-aggregator", None, "global-aggregator"),
            (
                "global-aggregator",
                Some(Flavor::Coordinated),
                "coordinated-global-aggregator",
            ),
            ("coordinator", None, "coordinator"),
        ];
        for (role, flavor, program) in rules {
            r.bind_default(role, flavor, program)
                .expect("built-in binding must resolve");
        }
        r
    }

    /// Register (or replace) a program under `name`. The program carries
    /// no default binding until [`Self::bind_default`] names it; specs
    /// reach it through their `program:` field.
    pub fn register(&mut self, name: impl Into<String>, factory: ProgramFactory) {
        self.programs.insert(name.into(), factory);
    }

    /// Make `program` the default binding of `role` under `flavor`
    /// (`None` = the role's any-flavour fallback). Replaces an existing
    /// rule for the same `(role, flavor)`; fails if the program is not
    /// registered.
    pub fn bind_default(
        &mut self,
        role: &str,
        flavor: Option<Flavor>,
        program: &str,
    ) -> Result<()> {
        if !self.contains(program) {
            bail!("cannot bind unregistered program '{program}'");
        }
        self.defaults
            .retain(|d| !(d.role == role && d.flavor == flavor));
        self.defaults.push(BindingRule {
            role: role.to_string(),
            flavor,
            program: program.to_string(),
        });
        Ok(())
    }

    /// The effective registry for one job: `base` plus per-job factory
    /// overlays (`JobOptions::with_program`). Returns `base` untouched
    /// when there is nothing to overlay; factories are `Arc`s, so the
    /// clone is cheap.
    pub fn overlaid(base: &Arc<Self>, extra: &[(String, ProgramFactory)]) -> Arc<Self> {
        if extra.is_empty() {
            return base.clone();
        }
        let mut r = (**base).clone();
        for (name, factory) in extra {
            r.register(name.clone(), factory.clone());
        }
        Arc::new(r)
    }

    /// Resolve every role of `spec` under `flavor` — the shared
    /// submission gate of `Controller::submit` and
    /// `JobManager::submit`: an unknown program must fail the
    /// submission, never a pod.
    pub fn resolve_all(&self, spec: &JobSpec, flavor: Flavor) -> Result<()> {
        for role in &spec.roles {
            self.resolve(spec, flavor, &role.name)
                .with_context(|| format!("binding role '{}'", role.name))?;
        }
        Ok(())
    }

    /// Is a program registered under `name`?
    pub fn contains(&self, name: &str) -> bool {
        self.programs.contains_key(name)
    }

    /// Registered program names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.programs.keys().cloned().collect()
    }

    /// The program catalog, sorted by name: every registered program with
    /// the default rules binding it (the `flame roles` listing), derived
    /// from the live rule list.
    pub fn catalog(&self) -> Vec<ProgramInfo> {
        self.programs
            .keys()
            .map(|name| ProgramInfo {
                name: name.clone(),
                bindings: self
                    .defaults
                    .iter()
                    .filter(|d| &d.program == name)
                    .map(|d| (d.role.clone(), d.flavor))
                    .collect(),
            })
            .collect()
    }

    fn default_program(&self, role: &str, flavor: Flavor) -> Option<&str> {
        self.defaults
            .iter()
            .find(|d| d.role == role && d.flavor == Some(flavor))
            .or_else(|| {
                self.defaults
                    .iter()
                    .find(|d| d.role == role && d.flavor.is_none())
            })
            .map(|d| d.program.as_str())
    }

    /// Resolve the binding for `role_name` under `flavor`: the role's
    /// declared `program:` when present, else the registry's default for
    /// `(role, flavor)` (falling back to the role's any-flavour rule).
    /// Errors when the role is unknown, nothing binds it, or the bound
    /// program is not registered.
    pub fn resolve(&self, spec: &JobSpec, flavor: Flavor, role_name: &str) -> Result<RoleBinding> {
        let role = spec
            .role(role_name)
            .with_context(|| format!("spec has no role '{role_name}'"))?;
        let program = match &role.program {
            Some(p) => p.clone(),
            None => self
                .default_program(role_name, flavor)
                .map(str::to_string)
                .with_context(|| {
                    format!(
                        "no program bound for role '{role_name}' (flavor '{}'): \
                         declare `program:` in the spec or register a default binding",
                        flavor.name()
                    )
                })?,
        };
        if !self.contains(&program) {
            bail!(
                "role '{role_name}' binds program '{program}', which is not registered \
                 (registered: {})",
                self.names().join(", ")
            );
        }
        Ok(RoleBinding {
            role: role_name.to_string(),
            program,
            flavor,
        })
    }

    /// Build the program for one worker: resolve its binding against the
    /// job's spec and flavour, then invoke the factory. This is the §4.1
    /// role↔program binding — the replacement for the old hardcoded
    /// `build_program` dispatch.
    pub fn build(&self, env: WorkerEnv) -> Result<Box<dyn Program>> {
        let binding = self.resolve(&env.job.spec, env.job.flavor, &env.cfg.role)?;
        let factory = self
            .programs
            .get(&binding.program)
            .expect("resolve checked registration")
            .clone();
        factory(env, &binding)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::Backend;
    use crate::topo;

    #[test]
    fn builtin_registry_lists_all_programs() {
        let r = RoleRegistry::builtin();
        for name in [
            "trainer",
            "coordinated-trainer",
            "hybrid-trainer",
            "distributed-trainer",
            "aggregator",
            "coordinated-aggregator",
            "global-aggregator",
            "coordinated-global-aggregator",
            "coordinator",
        ] {
            assert!(r.contains(name), "missing '{name}'");
        }
        assert_eq!(r.names().len(), 9);
        // every built-in appears in the catalog with >= 1 default rule
        let catalog = r.catalog();
        assert_eq!(catalog.len(), 9);
        assert!(catalog.iter().all(|p| !p.bindings.is_empty()));
        // the catalog is derived from the live rules, so a re-bind is
        // reflected immediately (no desyncable labels)
        let mut r = r;
        r.bind_default("trainer", None, "coordinated-trainer").unwrap();
        let info = |r: &RoleRegistry, name: &str| {
            r.catalog().into_iter().find(|p| p.name == name).unwrap()
        };
        assert!(info(&r, "coordinated-trainer")
            .bindings
            .contains(&("trainer".to_string(), None)));
        assert!(!info(&r, "trainer")
            .bindings
            .contains(&("trainer".to_string(), None)));
    }

    #[test]
    fn default_bindings_follow_flavor() {
        let r = RoleRegistry::builtin();
        let spec = topo::hierarchical(4, 2, Backend::P2p).build();
        for (flavor, role, program) in [
            (Flavor::Sync, "trainer", "trainer"),
            (Flavor::Async, "trainer", "trainer"), // any-flavour fallback
            (Flavor::Coordinated, "trainer", "coordinated-trainer"),
            (Flavor::Hybrid, "trainer", "hybrid-trainer"),
            (Flavor::Distributed, "trainer", "distributed-trainer"),
            (Flavor::Sync, "aggregator", "aggregator"),
            (Flavor::Coordinated, "aggregator", "coordinated-aggregator"),
            (Flavor::Sync, "global-aggregator", "global-aggregator"),
            (
                Flavor::Coordinated,
                "global-aggregator",
                "coordinated-global-aggregator",
            ),
        ] {
            let b = r.resolve(&spec, flavor, role).unwrap();
            assert_eq!(b.program, program, "({role}, {flavor:?})");
            assert_eq!(b.flavor, flavor);
        }
    }

    #[test]
    fn explicit_program_field_wins_over_defaults() {
        let mut r = RoleRegistry::builtin();
        r.register("my-trainer", Arc::new(|env, _b| trainer::build(env, false)));
        let mut spec = topo::classical(2, Backend::P2p).build();
        spec.roles[0].program = Some("my-trainer".into());
        let b = r.resolve(&spec, Flavor::Sync, "trainer").unwrap();
        assert_eq!(b.program, "my-trainer");
    }

    #[test]
    fn unknown_bindings_error_with_context() {
        let r = RoleRegistry::builtin();
        // an unregistered explicit program
        let mut spec = topo::classical(2, Backend::P2p).build();
        spec.roles[0].program = Some("ghost".into());
        let err = r.resolve(&spec, Flavor::Sync, "trainer").unwrap_err();
        assert!(format!("{err:#}").contains("not registered"), "{err:#}");
        // a role nothing binds
        let mut spec = topo::classical(2, Backend::P2p).build();
        spec.roles[0].name = "mystery".into();
        spec.channels[0].pair.0 = "mystery".into();
        let err = r.resolve(&spec, Flavor::Sync, "mystery").unwrap_err();
        assert!(format!("{err:#}").contains("no program bound"), "{err:#}");
    }

    #[test]
    fn bind_default_requires_registered_program() {
        let mut r = RoleRegistry::new();
        assert!(r.bind_default("trainer", None, "nope").is_err());
        r.register("p", Arc::new(|env, _b| trainer::build(env, false)));
        r.bind_default("trainer", None, "p").unwrap();
        let spec = topo::classical(2, Backend::P2p).build();
        assert_eq!(
            r.resolve(&spec, Flavor::Sync, "trainer").unwrap().program,
            "p"
        );
    }
}
