//! Global aggregator role: owns the global model, drives rounds, evaluates.
//!
//! Base (synchronous) chain:
//! `init >> Loop(select >> distribute >> collect >> optimize >> eval) >>
//! end_of_train`.
//!
//! * **Selection** plugs any [`crate::select::Selector`] (Select-All /
//!   Random / Oort) over this node's direct children.
//! * **optimize** applies the configured server optimizer (FedAvg /
//!   FedAdam / FedAdagrad / FedYogi / FedDyn server state).
//! * With `aggregation: "fedbuff"` the loop body is replaced by the
//!   asynchronous buffered path (one chain, different tasklets — the
//!   composer makes the swap explicit and inspectable).
//!
//! **Streaming collect**: the synchronous path folds every child update
//! into a [`crate::runtime::Accumulator`] as it is received — one O(d)
//! fold buffer (plus transient staging for out-of-order arrivals)
//! instead of unconditionally retaining all O(children·d) updates, with
//! folded buffers recycled through the job's `TensorPool`. Fold order is
//! the sorted expected-sender order, which is interleaving-independent,
//! so executor parity stays byte-exact. Only per-update *metadata*
//! (sender, loss, arrival) is kept to round end, for acks and selector
//! feedback. The hybrid path (one update per cluster, senders unknown in
//! advance) streams too: its accumulator starts with an *empty* expected
//! set, so every update takes the spill path and folds in sorted sender
//! order at round end — interleaving-independent like the main path, one
//! O(d) buffer instead of O(clusters·d). (This replaced the old buffered
//! hybrid collect and its legacy uniform-mean fallback: a
//! zero-total-weight hybrid round now keeps the model, like every other
//! collect.)
//!
//! **Update codecs**: when the job carries a [`crate::runtime::Codec`],
//! uploads arrive as [`Payload::Encoded`] *deltas*. The synchronous and
//! hybrid collects reconstruct each sender's model by decode-adding onto
//! this round's distributed base (`c.flat`, unchanged until the
//! post-collect `optimize`), so the fold downstream is codec-agnostic;
//! the async FedBuff path consumes deltas directly and decodes into a
//! zeroed buffer with no base re-add.
//!
//! CO-FL variant (paper Fig 9, §6.1): `get_coord_ends` inserted before
//! `distribute` (the coordinator decides which aggregators participate) and
//! `end_of_train` **removed** — the coordinator owns termination.
//!
//! **Elastic variant** (live topology extension): when the job carries a
//! [`crate::deploy::TopologyTimeline`], an `apply_events` tasklet is
//! inserted at the top of the round loop. The global aggregator is the
//! round sequencer, so draining due events there — deploying joiners,
//! evicting leavers, joining freshly created channels, re-partitioning
//! trainers across the (possibly new) middle tier — keeps every
//! membership change aligned with a round boundary, which is what makes a
//! scripted timeline deterministic. Collects run against *current*
//! membership with the configured quorum fraction, so a departed worker
//! can never block a round.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::algos::{AggregationPolicy, FedBuff, ServerOpt};
use crate::channel::{Message, Payload};
use crate::json::{self, Json};
use crate::net::VTime;
use crate::runtime::Accumulator;
use crate::select::{make_selector, ClientStats, Selector};
use crate::workflow::{Composer, Tasklet};

use super::{chain_program, Program, WorkerEnv};

pub struct GlobalCtx {
    pub env: WorkerEnv,
    pub flat: Vec<f32>,
    opt: ServerOpt,
    selector: Box<dyn Selector>,
    fedbuff: Option<FedBuff>,
    /// CO-FL: aggregator set for this round (None = all channel ends).
    active_children: Option<Vec<String>>,
    selected: Vec<String>,
    /// Per-child stats fed back to the selector.
    child_stats: HashMap<String, ClientStats>,
    round: u64,
    round_start: u64,
    /// Send acks on collect (CO-FL delay reporting).
    ack_updates: bool,
    /// Hybrid FL: number of clusters expected to upload (delegates only);
    /// None for non-hybrid topologies.
    hybrid_clusters: Option<usize>,
    /// In-flight streaming fold for the synchronous collect (re-entrant
    /// across cooperative yields). O(d), not O(children·d).
    acc: Option<Accumulator>,
    /// Virtual time the in-flight collect entered its wait (set with
    /// `acc`, consumed at quorum): the `collect-wait` span start. Purely
    /// transient — never checkpointed.
    collect_t0: Option<VTime>,
    /// Per-update metadata kept to round end: `(sender, loss, arrival)` —
    /// pointer-sized, feeds acks and selector stats (both the synchronous
    /// and the hybrid collect use it; only one runs per job).
    col: Vec<(Arc<str>, f64, VTime)>,
    /// Live topology extension enabled (the job carries a timeline).
    elastic: bool,
    /// Membership changed since the last trainer partition was sent to the
    /// middle tier.
    assign_dirty: bool,
    /// The data-consumer role's name (trainer membership queries).
    data_role: Option<String>,
    /// Boundary this deployment was rehydrated at (0 = fresh run). The
    /// checkpoint tasklet skips boundaries `<=` this: at the resume
    /// boundary the worker snapshot hub is still empty, so re-committing
    /// there would overwrite the good epoch with a torn one.
    resumed_at: u64,
    /// Outstanding-upload census for partial quorum: selected sender →
    /// expected in-flight uploads not yet consumed (counted *or* stale).
    /// The boundary drain in [`checkpoint`] blocks on these, so a commit
    /// never races an in-flight upload and every published worker snapshot
    /// is ordered before the epoch that references it. Full-quorum rounds
    /// leave this empty and the drain is a no-op.
    outstanding: BTreeMap<String, usize>,
    /// Senders whose updates were counted in the last completed collect —
    /// the landed census committed with the boundary's head record.
    landed: Vec<String>,
    /// Hybrid: epoch markers drained so far at the in-progress boundary
    /// (re-entrant across cooperative yields in the drain loop).
    epoch_seen: usize,
    /// Async barrier: members we sent weights to whose next update has not
    /// arrived yet. A due version boundary withholds replies until this
    /// drains empty — a true barrier with no update in flight anywhere.
    async_outstanding: BTreeSet<String>,
    /// Async: highest version whose barrier commit already happened
    /// (restored to the checkpoint version on resume).
    last_barrier: u64,
    pub done: bool,
}

impl GlobalCtx {
    /// Build the context for a global-aggregator program over `env`
    /// (public for Role-SDK derivations of [`base_chain`] /
    /// [`async_chain`]). `coordinated` enables CO-FL ack reporting.
    pub fn new(env: WorkerEnv, coordinated: bool) -> Self {
        let tcfg = &env.job.tcfg;
        let d = env.job.compute.d_pad();
        let opt = ServerOpt::new(tcfg.server, d)
            .with_eta(tcfg.eta)
            .with_alpha(tcfg.alpha);
        let selector = make_selector(&tcfg.selection, tcfg.select_frac, tcfg.seed ^ 0x5E1);
        let fedbuff = match tcfg.aggregation {
            AggregationPolicy::Asynchronous { buffer_k } => {
                Some(FedBuff::new(buffer_k, tcfg.eta))
            }
            AggregationPolicy::Synchronous => None,
        };
        // Hybrid: a trainer ring channel the global is not part of means
        // only cluster delegates upload.
        let hybrid_clusters = env
            .job
            .spec
            .channel("ring-channel")
            .filter(|ch| ch.pair.0 != "global-aggregator" && ch.pair.1 != "global-aggregator")
            .filter(|_| env.job.spec.role("global-aggregator").is_some())
            .map(|ch| ch.group_by.len().max(1));
        let elastic = env.job.timeline.is_elastic();
        let data_role = env
            .job
            .spec
            .roles
            .iter()
            .find(|r| r.is_data_consumer)
            .map(|r| r.name.clone());
        Self {
            env,
            flat: Vec::new(),
            opt,
            selector,
            fedbuff,
            active_children: None,
            selected: Vec::new(),
            child_stats: HashMap::new(),
            round: 0,
            round_start: 0,
            ack_updates: coordinated,
            hybrid_clusters,
            acc: None,
            collect_t0: None,
            col: Vec::new(),
            elastic,
            assign_dirty: false,
            data_role,
            resumed_at: 0,
            outstanding: BTreeMap::new(),
            landed: Vec::new(),
            epoch_seen: 0,
            async_outstanding: BTreeSet::new(),
            last_barrier: 0,
            done: false,
        }
    }

    /// Round-boundary snapshot of everything the round sequencer needs to
    /// resume: model, server-optimizer moments, selector stream, FedBuff
    /// window, round counter and virtual clock. Field order is fixed and
    /// floats dump shortest-roundtrip, so the encoding is deterministic.
    pub fn snapshot_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("round", json::from_u64_hex(self.round));
        o.insert("clock", json::from_u64_hex(self.env.now()));
        o.insert("flat", super::floats_to_json(&self.flat));
        let (m, v, h) = self.opt.state();
        o.insert("opt_m", super::floats_to_json(m));
        o.insert("opt_v", super::floats_to_json(v));
        o.insert("opt_h", super::floats_to_json(h));
        if let Some(sel) = self.selector.snapshot() {
            o.insert("selector", sel);
        }
        if let Some(fb) = &self.fedbuff {
            let (acc, wsum, pending, version) = fb.state();
            let mut f = Json::obj();
            f.insert("acc", super::floats_to_json(acc));
            f.insert("wsum", Json::Num(wsum as f64));
            f.insert("pending", Json::Num(pending as f64));
            f.insert("version", json::from_u64_hex(version));
            o.insert("fedbuff", Json::Obj(f));
        }
        Json::Obj(o)
    }

    /// Rehydrate from a [`Self::snapshot_json`] checkpoint: overwrite the
    /// freshly initialised state and merge the saved boundary clock (the
    /// `round_time_s`/`vtime_s` series must continue from the killed run's
    /// virtual time, not restart at zero).
    pub fn restore_from(&mut self, snap: &Json) -> Result<()> {
        let flat = super::floats_from_json(snap.get("flat"));
        if flat.len() != self.flat.len() {
            bail!(
                "checkpoint model has {} params, job expects {}",
                flat.len(),
                self.flat.len()
            );
        }
        self.flat = flat;
        self.opt.restore_state(
            super::floats_from_json(snap.get("opt_m")),
            super::floats_from_json(snap.get("opt_v")),
            super::floats_from_json(snap.get("opt_h")),
        );
        let sel = snap.get("selector");
        if !matches!(*sel, Json::Null) {
            self.selector.restore(sel);
        }
        if let Some(fb) = self.fedbuff.as_mut() {
            let fbj = snap.get("fedbuff");
            if !matches!(*fbj, Json::Null) {
                fb.restore_state(
                    super::floats_from_json(fbj.get("acc")),
                    fbj.get("wsum").as_f64().unwrap_or(0.0) as f32,
                    fbj.get("pending").as_f64().unwrap_or(0.0) as usize,
                    json::as_u64_hex(fbj.get("version")).unwrap_or(0),
                );
            }
        }
        self.round = json::as_u64_hex(snap.get("round")).context("checkpoint missing round")?;
        self.resumed_at = self.round;
        // async: the resume boundary's barrier already committed — don't
        // re-trigger it at the restored version
        self.last_barrier = self.round;
        if let Some(t) = json::as_u64_hex(snap.get("clock")) {
            self.env.clock.lock().unwrap().merge(t);
        }
        Ok(())
    }

    fn children_channel(&self) -> &'static str {
        // C-FL/Hybrid: trainers sit on param-channel; H-FL/CO-FL: the
        // aggregator tier sits on agg-channel. The tier channel only wins
        // while it has peers, so an elastic job keeps talking to its
        // trainers directly until the middle tier actually deploys.
        if let Some(h) = self.env.chans.get("agg-channel") {
            if !h.ends().is_empty() {
                return "agg-channel";
            }
        }
        "param-channel"
    }

    fn children(&self) -> Result<Vec<String>> {
        match &self.active_children {
            Some(c) => Ok(c.clone()),
            None => Ok((*self.env.chan(self.children_channel())?.ends()).clone()),
        }
    }
}

// ------------------------------------------------------------- tasklets

fn init(c: &mut GlobalCtx) -> Result<()> {
    c.flat = c.env.job.init_flat.as_ref().clone();
    assert_eq!(c.flat.len(), c.env.job.compute.d_pad());
    if let Some(ck) = c.env.job.restore.clone() {
        c.restore_from(&ck.global)?;
    }
    Ok(())
}

/// Crash resilience: commit a round-boundary checkpoint through the job's
/// sink. Runs at the top of the round loop — by then `eval` has bumped
/// `c.round` to the completed-round count, and every uploading worker's
/// boundary snapshot is in the hub (publish happens-before the upload
/// send, and the boundary *drain* below consumes every upload a partial
/// quorum left in flight — so consumption, not luck, orders each publish
/// before the commit that references it). Committing *before*
/// `apply_events` means the saved timeline cursor names the event-replay
/// point exactly: this boundary's events are still pending.
fn checkpoint(c: &mut GlobalCtx) -> Result<()> {
    if c.done {
        return Ok(());
    }
    let Some(sink) = c.env.job.ckpt.clone() else {
        return Ok(());
    };
    if !sink.is_live() || c.round <= c.resumed_at || !sink.due(c.round) {
        return Ok(());
    }
    let chan_name = c.children_channel();
    if let Some(clusters) = c.hybrid_clusters {
        // Hybrid barrier: only delegates upload, so non-delegate cluster
        // members send an "epoch" marker after publishing their boundary
        // snapshot. Draining one marker per non-delegate closes the
        // happens-before gap the delegate uploads leave open. Kind-selective
        // recv keeps markers and next-round updates from crossing.
        let expected = {
            let members = c.env.chan(chan_name)?.ends();
            members.len().saturating_sub(clusters)
        };
        while c.epoch_seen < expected {
            let chan = c.env.chan(chan_name)?;
            let _ = chan.recv_any_kind_timed("epoch")?;
            c.epoch_seen += 1;
        }
        c.epoch_seen = 0;
    } else if c.env.job.tcfg.quorum < 1.0 {
        // Partial-quorum boundary drain: consume the stale uploads the
        // quorum cut loose before committing. Re-entrant — the census
        // lives in the ctx, so a yield inside recv resumes the drain.
        // Full-quorum jobs skip it: every counted round consumed every
        // member's upload already, and draining a departed straggler's
        // in-flight bytes here would merge its arrival clock one round
        // earlier than an unarmed run does — checkpointing must stay
        // pure observation.
        loop {
            let members = c.env.chan(chan_name)?.ends();
            let pending: usize = c
                .outstanding
                .iter()
                .filter(|(s, _)| members.contains(s))
                .map(|(_, n)| *n)
                .sum();
            if pending == 0 {
                break;
            }
            let (from, msg, _arrival) = {
                let chan = c.env.chan(chan_name)?;
                chan.recv_any_kind_timed("update")?
            };
            if let Payload::Floats(w) = msg.payload {
                c.env.job.pool.reclaim(w);
            }
            if let Some(n) = c.outstanding.get_mut(&*from) {
                *n -= 1;
                if *n == 0 {
                    c.outstanding.remove(&*from);
                }
            }
        }
    }
    // the span goes in BEFORE the commit so it rides its own snapshot: a
    // resumed run skips re-committing this boundary (`resumed_at` guard),
    // so a span recorded after the commit could never be replayed. The
    // commit does not advance the virtual clock, so the span is
    // zero-length either way.
    let v0 = c.env.now();
    c.env
        .job
        .trace
        .span(&c.env.cfg.id, crate::trace::phase::CHECKPOINT, c.round, v0, v0);
    sink.commit(
        c.round,
        c.env.job.timeline.cursor(),
        c.snapshot_json(),
        c.env.job.metrics.snapshot(),
        c.env.job.trace.snapshot(),
        &c.landed,
    )?;
    let prev_due = c.round.saturating_sub(sink.policy().every.max(1));
    if sink.policy().faults.controller_kill_between(prev_due, c.round) {
        bail!("injected controller kill at round boundary {}", c.round);
    }
    Ok(())
}

/// Elastic only: drain the topology timeline at the round boundary. The
/// global is the round sequencer, so applying joins/leaves/extensions
/// here — before selection and distribution — keeps membership stable
/// within a round and makes the scripted timeline deterministic.
///
/// Never receives, so it cannot yield: safe to re-enter trivially.
fn apply_events(c: &mut GlobalCtx) -> Result<()> {
    if c.done || !c.elastic {
        return Ok(());
    }
    let now = c.env.now();
    let due = c.env.job.timeline.due(now);
    for entry in due {
        match entry.action {
            crate::deploy::ScheduledAction::Deploy(cfgs) => {
                // join any channel the extended spec gives this role (the
                // new tier's uplink) *before* spawning its members, so
                // joiners observe the sequencer from their first poll
                let missing: Vec<String> = c
                    .env
                    .job
                    .spec
                    .channels_of(&c.env.cfg.role)
                    .iter()
                    .filter(|ch| !c.env.chans.contains_key(&ch.name))
                    .map(|ch| ch.name.clone())
                    .collect();
                for name in missing {
                    c.env.join_channel(&name, "default")?;
                }
                let job = c.env.job.clone();
                for cfg in cfgs {
                    job.timeline.live_deploy(cfg, &job, entry.at)?;
                }
                c.assign_dirty = true;
            }
            crate::deploy::ScheduledAction::Evict(ids) => {
                for id in &ids {
                    c.env.job.chan_mgr.evict(id, entry.at);
                }
                c.assign_dirty = true;
            }
        }
    }
    // (re)partition trainers across the middle tier whenever membership
    // moved: each aggregator gets a disjoint slice of the current trainer
    // population, round-robin over the sorted lists (deterministic).
    if c.assign_dirty {
        if let Some(data_role) = c.data_role.clone() {
            // re-partitioning needs both views: the tier (agg-channel) and
            // the trainer population (param-channel). A topology where the
            // sequencer cannot see the trainers (static H-FL groups) keeps
            // per-group membership instead — alive_trainers() handles it.
            if c.env.chans.contains_key("agg-channel") && c.env.chans.contains_key("param-channel")
            {
                let aggs = c.env.chan("agg-channel")?.ends();
                if !aggs.is_empty() {
                    let trainers = c.env.chan("param-channel")?.ends_of_role(&data_role);
                    let mut parts: Vec<Vec<Json>> = vec![Vec::new(); aggs.len()];
                    for (i, t) in trainers.iter().enumerate() {
                        parts[i % aggs.len()].push(Json::Str(t.clone()));
                    }
                    let agg = c.env.chan("agg-channel")?;
                    for (a, part) in aggs.iter().zip(parts) {
                        let mut meta = Json::obj();
                        meta.insert("trainers", Json::Arr(part));
                        agg.send(
                            a,
                            Message::control("assign", c.round).with_meta(Json::Obj(meta)),
                        )?;
                    }
                }
            }
        }
        c.assign_dirty = false;
    }
    Ok(())
}

fn select(c: &mut GlobalCtx) -> Result<()> {
    if c.done {
        return Ok(());
    }
    let children = c.children()?;
    if children.is_empty() {
        bail!("global aggregator has no children");
    }
    c.selected = c.selector.select(c.round, &children);
    Ok(())
}

fn distribute(c: &mut GlobalCtx) -> Result<()> {
    if c.done {
        return Ok(());
    }
    let chan_name = c.children_channel();
    let chan = c.env.chan(chan_name)?;
    c.round_start = chan.now();
    // the round's model snapshot comes from the pool — steady-state
    // rounds reuse the buffer the previous round's receivers released
    let w = c.env.job.pool.take_copy(&c.flat);
    let all = c.children()?;
    let mut items = Vec::with_capacity(all.len());
    for child in all {
        let msg = if c.selected.contains(&child) {
            // census: one upload expected back from every selected child
            // (hybrid excepted — there only delegates upload, and the
            // collect barrier is full over clusters already)
            if c.hybrid_clusters.is_none() {
                *c.outstanding.entry(child.clone()).or_insert(0) += 1;
            }
            Message::floats("weights", c.round, w.clone())
        } else {
            Message::control("skip", c.round)
        };
        c.env.job.metrics.add_traffic(msg.size_bytes());
        items.push((child, msg));
    }
    chan.send_fanout(items)?;
    // sends never advance the sender's clock, so this span is zero-length
    // at the round boundary — it marks where the round starts in the trace
    c.env.job.trace.span(
        &c.env.cfg.id,
        crate::trace::phase::DISTRIBUTE,
        c.round,
        c.round_start,
        chan.now(),
    );
    Ok(())
}

/// Reconstruct a full-model update from an upload payload: plain floats
/// pass through untouched; an encoded *delta* decode-adds onto `base`
/// (this round's distributed model) in a pooled buffer, so the fold
/// downstream never sees the codec.
fn decode_update(
    job: &super::JobRuntime,
    base: &[f32],
    payload: Payload,
) -> Result<Arc<Vec<f32>>> {
    match payload {
        Payload::Floats(w) => Ok(w),
        Payload::Encoded(enc) => {
            let codec = job
                .codec
                .clone()
                .context("encoded update received but no codec configured")?;
            let mut buf = job.pool.take_copy(base);
            codec.decode_add(
                &enc,
                Arc::get_mut(&mut buf).expect("pooled buffers are uniquely owned"),
            )?;
            Ok(buf)
        }
        _ => bail!("update without floats"),
    }
}

/// Synchronous collect: stream every update into the accumulator as it
/// arrives, then apply the server optimizer once the quorum target is met.
fn collect_and_optimize(c: &mut GlobalCtx) -> Result<()> {
    if c.done {
        return Ok(());
    }
    if c.hybrid_clusters.is_some() {
        return collect_hybrid(c);
    }
    let chan_name = c.children_channel();
    if c.acc.is_none() {
        // the fold universe is this round's selected set; quorum decides
        // how many of them we wait for
        c.acc = Some(Accumulator::new(
            c.env.job.compute.clone(),
            c.env.job.pool.clone(),
            c.selected.clone(),
        ));
        c.collect_t0 = Some(c.env.now());
        c.col.clear();
    }
    // The target is quorum- and membership-aware: `ceil(quorum * alive)`
    // over the *currently joined* selected children, recomputed on every
    // tasklet (re-)entry — a child that departs mid-round wakes this
    // collect, which yields and re-enters to re-count, so departures
    // shrink the target instead of blocking the round while the fold
    // path itself stays free of O(k) membership scans.
    let target = {
        let members = c.env.chan(chan_name)?.ends();
        let alive = c.selected.iter().filter(|s| members.contains(*s)).count();
        super::quorum_target(alive, c.env.job.tcfg.quorum)
    };
    while c.acc.as_ref().map(|a| a.len()).unwrap_or(0) < target {
        let (from, msg, arrival) = {
            let chan = c.env.chan(chan_name)?;
            chan.recv_any_kind_timed("update")?
        };
        // census: consumed, whether it counts below or not
        if let Some(n) = c.outstanding.get_mut(&*from) {
            *n -= 1;
            if *n == 0 {
                c.outstanding.remove(&*from);
            }
        }
        if msg.round != c.round {
            // quorum fractions leave slow updates of past rounds queued;
            // they are stale by the time they arrive and must not count
            if let Payload::Floats(w) = msg.payload {
                c.env.job.pool.reclaim(w);
            }
            continue;
        }
        if !c.selected.iter().any(|s| s.as_str() == &*from) {
            if c.elastic {
                // e.g. a retired child's in-flight update: drop it, but
                // recycle its buffer like the stale-round path above
                if let Payload::Floats(w) = msg.payload {
                    c.env.job.pool.reclaim(w);
                }
                continue;
            }
            bail!("unexpected update from unselected child '{from}'");
        }
        let samples = msg.meta().get("samples").as_f64().unwrap_or(1.0);
        let loss = msg.meta().get("loss").as_f64().unwrap_or(0.0);
        let w = decode_update(&c.env.job, &c.flat, msg.payload)?;
        c.acc
            .as_mut()
            .expect("accumulator created above")
            .push(&from, w, samples)?;
        c.col.push((from, loss, arrival));
    }
    // quorum met: the clock now holds the last counted arrival — close
    // the wait span (it started when the accumulator was created)
    let wait_t0 = c.collect_t0.take().unwrap_or(c.round_start);
    let wait_end = c.env.now();
    let me = c.env.cfg.id.clone();
    let t = &c.env.job.trace;
    t.span(&me, crate::trace::phase::WAIT, c.round, wait_t0, wait_end);
    t.counter(&me, "quorum", wait_end, c.col.len() as f64);
    let acc = c.acc.take().expect("accumulator created above");
    let mut col = std::mem::take(&mut c.col);
    if col.is_empty() {
        // every selected child departed this round: keep the model
        c.landed.clear();
        let _ = acc.finish()?;
        return Ok(());
    }
    // Metadata in virtual-arrival order with a deterministic sender
    // tie-break — the same order the buffered collect used, so ack send
    // order and selector feedback stay bit-identical across executors.
    col.sort_by(|a, b| (a.2, &a.0).cmp(&(b.2, &b.0)));
    c.landed = col.iter().map(|(f, _, _)| f.to_string()).collect();
    if c.ack_updates {
        // Acks go out after the collection barrier (send time = the
        // round's merged clock, independent of consumption order — the
        // same on every executor). Each ack carries the update's own
        // virtual arrival time, so the sender's delay measurement is
        // independent of this node's (straggler-merged) clock.
        let chan = c.env.chan(chan_name)?;
        for (from, _, arrival) in &col {
            let mut meta = Json::obj();
            meta.insert("arrival_us", *arrival);
            chan.send(from, Message::control("ack", c.round).with_meta(Json::Obj(meta)))?;
        }
    }
    let now = c.env.now();
    for (from, loss, _) in &col {
        c.child_stats.insert(
            from.to_string(),
            ClientStats {
                loss: *loss,
                round_time: now.saturating_sub(c.round_start),
                participation: 0,
            },
        );
    }
    let t0 = Instant::now();
    let out = acc.finish()?;
    if let Some(mean) = out.mean {
        c.opt.apply(&mut c.flat, &mean);
        c.env.job.pool.reclaim(mean);
    }
    // zero total weight (every contributor lost its trainers to churn and
    // relayed its stale model) keeps the model as-is
    let dv = c.env.charge(t0);
    let v1 = c.env.now();
    c.env
        .job
        .trace
        .span(&me, crate::trace::phase::AGGREGATE, c.round, v1 - dv, v1);
    for (client, stats) in c.child_stats.drain() {
        c.selector.report(&client, stats);
    }
    Ok(())
}

/// Hybrid collect: one update per cluster from whichever delegate, so the
/// sender set is unknown in advance. Streams through an [`Accumulator`]
/// with an *empty* expected set — every update takes the spill path and
/// folds in sorted sender order at round end, which is
/// interleaving-independent like the main path while keeping one O(d)
/// buffer instead of O(clusters·d).
fn collect_hybrid(c: &mut GlobalCtx) -> Result<()> {
    let chan_name = c.children_channel();
    let expected = c.hybrid_clusters.expect("hybrid path requires cluster count");
    if c.acc.is_none() {
        c.acc = Some(Accumulator::new(
            c.env.job.compute.clone(),
            c.env.job.pool.clone(),
            Vec::new(),
        ));
        c.collect_t0 = Some(c.env.now());
        c.col.clear();
    }
    while c.acc.as_ref().map(|a| a.len()).unwrap_or(0) < expected {
        let (from, msg, arrival) = {
            let chan = c.env.chan(chan_name)?;
            chan.recv_any_kind_timed("update")?
        };
        let samples = msg.meta().get("samples").as_f64().unwrap_or(1.0);
        let loss = msg.meta().get("loss").as_f64().unwrap_or(0.0);
        let w = decode_update(&c.env.job, &c.flat, msg.payload)?;
        c.acc
            .as_mut()
            .expect("accumulator created above")
            .push(&from, w, samples)?;
        c.col.push((from, loss, arrival));
    }
    let wait_t0 = c.collect_t0.take().unwrap_or(c.round_start);
    let wait_end = c.env.now();
    let me = c.env.cfg.id.clone();
    let t = &c.env.job.trace;
    t.span(&me, crate::trace::phase::WAIT, c.round, wait_t0, wait_end);
    t.counter(&me, "quorum", wait_end, c.col.len() as f64);
    let acc = c.acc.take().expect("accumulator created above");
    let mut col = std::mem::take(&mut c.col);
    // Acks and selector feedback in virtual-arrival order with a
    // deterministic sender tie-break — the same order the buffered
    // collect used.
    col.sort_by(|a, b| (a.2, &a.0).cmp(&(b.2, &b.0)));
    c.landed = col.iter().map(|(f, _, _)| f.to_string()).collect();
    if c.ack_updates {
        let chan = c.env.chan(chan_name)?;
        for (from, _, arrival) in &col {
            let mut meta = Json::obj();
            meta.insert("arrival_us", *arrival);
            chan.send(from, Message::control("ack", c.round).with_meta(Json::Obj(meta)))?;
        }
    }
    let now = c.env.now();
    for (from, loss, _) in &col {
        c.child_stats.insert(
            from.to_string(),
            ClientStats {
                loss: *loss,
                round_time: now.saturating_sub(c.round_start),
                participation: 0,
            },
        );
    }
    let t0 = Instant::now();
    let out = acc.finish()?;
    // zero total weight keeps the model as-is (the buffered collect's
    // legacy uniform-mean fallback is gone — all collects agree now)
    if let Some(mean) = out.mean {
        c.opt.apply(&mut c.flat, &mean);
        c.env.job.pool.reclaim(mean);
    }
    let dv = c.env.charge(t0);
    let v1 = c.env.now();
    c.env
        .job
        .trace
        .span(&me, crate::trace::phase::AGGREGATE, c.round, v1 - dv, v1);
    for (client, stats) in c.child_stats.drain() {
        c.selector.report(&client, stats);
    }
    Ok(())
}

fn eval(c: &mut GlobalCtx) -> Result<()> {
    if c.done {
        return Ok(());
    }
    let t0 = Instant::now();
    let (loss, acc) =
        crate::runtime::evaluate(c.env.job.compute.as_ref(), &c.flat, &c.env.job.test_set)?;
    let dv = c.env.charge(t0);
    let me = c.env.cfg.id.clone();
    let now = c.env.now();
    let round_time = now.saturating_sub(c.round_start);
    let m = &c.env.job.metrics;
    m.record(&me, "loss", c.round, loss);
    m.record(&me, "acc", c.round, acc);
    m.record(&me, "round_time_s", c.round, round_time as f64 / 1e6);
    m.record(&me, "vtime_s", c.round, now as f64 / 1e6);
    m.record(&me, "bytes_total", c.round, m.total_bytes() as f64);
    if c.elastic {
        // live-extension observability: population per tier, per round
        if let Some(data_role) = &c.data_role {
            if let Ok(param) = c.env.chan("param-channel") {
                m.record(
                    &me,
                    "trainers_alive",
                    c.round,
                    param.ends_of_role(data_role).len() as f64,
                );
            }
        }
        let aggs = c
            .env
            .chans
            .get("agg-channel")
            .map(|h| h.ends().len())
            .unwrap_or(0);
        m.record(&me, "aggregators_alive", c.round, aggs as f64);
    }
    let t = &c.env.job.trace;
    t.span(&me, crate::trace::phase::EVAL, c.round, now - dv, now);
    // round boundary: fold this round's spans into phase.*_us series,
    // sample scheduler stats, emit the Trace event
    t.round_boundary(m, &me, c.round, c.round_start, now);
    c.round += 1;
    if c.round >= c.env.job.rounds() {
        c.done = true;
    }
    Ok(())
}

fn end_of_train(c: &mut GlobalCtx) -> Result<()> {
    let chan = c.env.chan(c.children_channel())?;
    chan.broadcast(Message::control("done", c.round))?;
    Ok(())
}

/// CO-FL only: the coordinator names the aggregators for this round (or
/// signals termination — `end_of_train` is removed in CO-FL).
fn get_coord_ends(c: &mut GlobalCtx) -> Result<()> {
    if c.done {
        return Ok(());
    }
    let chan = c.env.chan("coord-g-channel")?;
    let coord = chan
        .ends()
        .first()
        .cloned()
        .context("no coordinator on coord-g-channel")?;
    let msg = chan.recv(&coord)?;
    match &*msg.kind {
        "assign" => {
            c.active_children = msg.meta().get("aggregators").as_arr().map(|a| {
                a.iter()
                    .filter_map(|x| x.as_str().map(str::to_string))
                    .collect()
            });
            // `select` ran against the previous round's membership; the
            // coordinator's word is final for this round.
            if let Some(active) = &c.active_children {
                c.selected.retain(|s| active.contains(s));
                if c.selected.is_empty() {
                    c.selected = active.clone();
                }
            }
        }
        "done" => c.done = true,
        other => bail!("unexpected coordinator message '{other}'"),
    }
    Ok(())
}

// --------------------------------------------------- async (FedBuff) path

fn async_serve(c: &mut GlobalCtx) -> Result<()> {
    if c.done {
        return Ok(());
    }
    let chan_name = c.children_channel();
    let target_versions = c.env.job.rounds();
    let serve_t0 = c.env.now();
    let (from, msg) = {
        let chan = c.env.chan(chan_name)?;
        chan.recv_any()?
    };
    if &*msg.kind != "update" {
        bail!("async global expected 'update', got '{}'", msg.kind);
    }
    // the wait for this update, charged by the arrival merge
    c.env.job.trace.span(
        &c.env.cfg.id,
        crate::trace::phase::WAIT,
        msg.round,
        serve_t0,
        c.env.now(),
    );
    let delta: Arc<Vec<f32>> = match msg.payload {
        Payload::Floats(d) => d,
        Payload::Encoded(enc) => {
            // async codec path: the encoding carries the *delta* itself,
            // which is exactly what FedBuff folds — decode into a zeroed
            // buffer, no base re-add
            let codec = c
                .env
                .job
                .codec
                .clone()
                .context("encoded update received but no codec configured")?;
            let mut buf = c.env.job.pool.take_zeroed();
            codec.decode_add(
                &enc,
                Arc::get_mut(&mut buf).expect("pooled buffers are uniquely owned"),
            )?;
            buf
        }
        _ => bail!("update without floats"),
    };
    let fb = c.fedbuff.as_mut().expect("async path requires fedbuff");
    // streaming fold: the delta is folded into the buffer in place (no
    // O(k·d) retention), so the wire buffer recycles immediately
    let buffered = fb.push(delta.as_slice(), msg.round);
    c.env.job.pool.reclaim(delta);
    c.async_outstanding.remove(&*from);
    if let Some(agg_delta) = buffered {
        crate::model::axpy(&mut c.flat, 1.0, &agg_delta);
        let version = fb.version();
        // evaluate on every version bump
        let t0 = Instant::now();
        let (loss, acc) =
            crate::runtime::evaluate(c.env.job.compute.as_ref(), &c.flat, &c.env.job.test_set)?;
        let dv = c.env.charge(t0);
        let me = c.env.cfg.id.clone();
        let now = c.env.now();
        let m = &c.env.job.metrics;
        m.record(&me, "loss", version, loss);
        m.record(&me, "acc", version, acc);
        m.record(&me, "vtime_s", version, now as f64 / 1e6);
        let t = &c.env.job.trace;
        t.span(&me, crate::trace::phase::EVAL, version, now - dv, now);
        // async "round" = buffer version: the boundary window runs from
        // the previous version bump (kickoff for the first)
        t.round_boundary(m, &me, version, c.round_start, now);
        c.round_start = now;
        if version >= target_versions {
            c.done = true;
            let chan = c.env.chan(chan_name)?;
            chan.broadcast(Message::control("done", version))?;
            return Ok(());
        }
    }
    // Version-boundary barrier (armed jobs only): at a due version, stop
    // replying and drain every outstanding update — when none is in
    // flight anywhere, every client's boundary snapshot is published
    // (publish happens-before each consumed upload) and the commit is
    // safe. The barrier broadcast below is everyone's reply, so a
    // resumed run's kickoff (same weights, same clock) is byte-identical
    // to the oracle continuing past the barrier.
    let version = c.fedbuff.as_ref().unwrap().version();
    if let Some(sink) = c.env.job.ckpt.clone() {
        if sink.is_live() && version > c.last_barrier && sink.due(version) {
            let members = c.env.chan(chan_name)?.ends();
            if c.async_outstanding.iter().any(|s| members.contains(s)) {
                // drain in progress: the sender waits for the barrier
                // broadcast like everyone else
                return Ok(());
            }
            let landed: Vec<String> = (*members).clone();
            let v0 = c.env.now();
            c.env
                .job
                .trace
                .span(&c.env.cfg.id, crate::trace::phase::CHECKPOINT, version, v0, v0);
            sink.commit(
                version,
                c.env.job.timeline.cursor(),
                c.snapshot_json(),
                c.env.job.metrics.snapshot(),
                c.env.job.trace.snapshot(),
                &landed,
            )?;
            if sink
                .policy()
                .faults
                .controller_kill_between(c.last_barrier, version)
            {
                bail!("injected controller kill at version boundary {version}");
            }
            c.last_barrier = version;
            let chan = c.env.chan(chan_name)?;
            let msg = Message::floats("weights", version, c.env.job.pool.take_copy(&c.flat));
            for _ in 0..chan.ends().len() {
                c.env.job.metrics.add_traffic(msg.size_bytes());
            }
            let now = chan.now();
            c.env.job.trace.span(
                &c.env.cfg.id,
                crate::trace::phase::DISTRIBUTE,
                version,
                now,
                now,
            );
            chan.broadcast(msg)?;
            c.async_outstanding = chan.ends().iter().cloned().collect();
            // the next version window starts at the barrier, exactly where
            // a resumed run's kickoff would start it
            c.round_start = now;
            return Ok(());
        }
    }
    // keep the client training on the freshest model
    let chan = c.env.chan(chan_name)?;
    let reply = Message::floats("weights", version, c.env.job.pool.take_copy(&c.flat));
    c.env.job.metrics.add_traffic(reply.size_bytes());
    chan.send(&from, reply)?;
    c.async_outstanding.insert(from.to_string());
    Ok(())
}

fn async_kickoff(c: &mut GlobalCtx) -> Result<()> {
    // seed every client with current-version weights: version 0 on a
    // fresh run, the checkpoint version on resume — where it replays the
    // killed run's barrier broadcast byte-for-byte (same payload, same
    // restored clock)
    let version = c.fedbuff.as_ref().map(|f| f.version()).unwrap_or(0);
    let chan = c.env.chan(c.children_channel())?;
    let msg = Message::floats("weights", version, c.env.job.pool.take_copy(&c.flat));
    for _ in 0..chan.ends().len() {
        c.env.job.metrics.add_traffic(msg.size_bytes());
    }
    c.async_outstanding = chan.ends().iter().cloned().collect();
    chan.broadcast(msg)?;
    c.round_start = chan.now();
    c.env.job.trace.span(
        &c.env.cfg.id,
        crate::trace::phase::DISTRIBUTE,
        version,
        c.round_start,
        c.round_start,
    );
    Ok(())
}

/// The base synchronous chain.
pub fn base_chain() -> Composer<GlobalCtx> {
    Composer::new()
        .task("init", init)
        .loop_until(
            |c: &GlobalCtx| c.done,
            Composer::new()
                .task("select", select)
                .task("distribute", distribute)
                .task("collect", collect_and_optimize)
                .task("eval", eval),
        )
        .task("end_of_train", end_of_train)
}

/// The asynchronous (FedBuff) chain.
pub fn async_chain() -> Composer<GlobalCtx> {
    Composer::new()
        .task("init", init)
        .task("kickoff", async_kickoff)
        .loop_until(|c: &GlobalCtx| c.done, Composer::new().task("serve", async_serve))
}

pub fn build(env: WorkerEnv, coordinated: bool) -> Result<Box<dyn Program>> {
    let asynchronous = matches!(
        env.job.tcfg.aggregation,
        AggregationPolicy::Asynchronous { .. }
    );
    let elastic = env.job.timeline.is_elastic();
    let ckpt_live = env.job.ckpt.as_ref().is_some_and(|s| s.is_live());
    let ctx = GlobalCtx::new(env, coordinated);
    let chain = if asynchronous {
        async_chain()
    } else {
        let mut chain = base_chain();
        if ckpt_live {
            // crash resilience: commit the boundary checkpoint ahead of
            // the event sequencer (inserted next, so it lands between
            // checkpoint and select), keeping the saved cursor aligned
            // with the not-yet-drained timeline
            chain.insert_before("select", Tasklet::new("checkpoint", checkpoint))?;
        }
        if elastic {
            // live topology extension: the round sequencer drains the
            // event timeline at each round boundary (chain surgery, same
            // Table 1 mechanism as the CO-FL derivation)
            chain.insert_before("select", Tasklet::new("apply_events", apply_events))?;
        }
        if coordinated {
            // paper Fig 9: insert get_coord_ends ahead of the distribution
            // path (here: before selection, which feeds distribute), and
            // remove end_of_train (the coordinator owns termination).
            chain.insert_before("select", Tasklet::new("get_coord_ends", get_coord_ends))?;
            chain.remove("end_of_train")?;
        }
        chain
    };
    Ok(chain_program(chain, ctx))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_chain_shape() {
        assert_eq!(
            base_chain().aliases(),
            vec!["init", "select", "distribute", "collect", "eval", "end_of_train"]
        );
    }

    #[test]
    fn cofl_surgery_matches_fig9() {
        let mut c = base_chain();
        c.insert_before("select", Tasklet::new("get_coord_ends", get_coord_ends))
            .unwrap();
        c.remove("end_of_train").unwrap();
        assert_eq!(
            c.aliases(),
            vec!["init", "get_coord_ends", "select", "distribute", "collect", "eval"]
        );
    }

    #[test]
    fn async_chain_shape() {
        assert_eq!(async_chain().aliases(), vec!["init", "kickoff", "serve"]);
    }

    #[test]
    fn elastic_surgery_inserts_event_sequencer() {
        let mut c = base_chain();
        c.insert_before("select", Tasklet::new("apply_events", apply_events))
            .unwrap();
        assert_eq!(
            c.aliases(),
            vec![
                "init",
                "apply_events",
                "select",
                "distribute",
                "collect",
                "eval",
                "end_of_train"
            ]
        );
    }
}
