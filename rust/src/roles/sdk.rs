//! The public Role SDK surface — everything a downstream mechanism needs
//! to implement and register a program *without touching `roles/`*.
//!
//! The paper's extension story (§4.4, Fig 9, Table 1) is: inherit a base
//! role, perform chain surgery, run. This module is that story as one
//! import:
//!
//! * the **exported base chains** of all six built-in roles
//!   ([`trainer_chain`], [`aggregator_chain`], [`global_chain`] /
//!   [`global_async_chain`], [`coordinator_chain`], [`hybrid_chain`],
//!   [`distributed_chain`]) plus their public context types,
//! * the **surgery API** ([`Composer`]: `insert_before` / `insert_after`
//!   / `replace_with` / `remove` / `get_tasklet` — paper Table 1),
//! * [`chain_program`] to bind a finished chain to its context as a
//!   runnable [`Program`],
//! * the **registry** types ([`RoleRegistry`], [`ProgramFactory`],
//!   [`RoleBinding`], [`Flavor`]) that connect the program to a spec.
//!
//! A derived mechanism registers either globally
//! (`Controller::register_program` / `JobManager::register_program`) or
//! per job (`JobOptions::with_program`), and the spec names it via the
//! role's `program:` field (or a `bind_default` rule). See
//! `sim::run_fedprox` for a complete derivation: FedProx is the base
//! trainer chain with `train` replaced by a proximal step — zero edits
//! inside the built-in role builders.

pub use super::registry::{ProgramFactory, ProgramInfo, RoleBinding, RoleRegistry};
pub use super::{chain_program, JobRuntime, Program, WorkerEnv};
pub use crate::tag::Flavor;
pub use crate::workflow::{Composer, StepStatus, Tasklet};

pub use super::aggregator::{base_chain as aggregator_chain, AggregatorCtx};
pub use super::coordinator::{chain as coordinator_chain, CoordinatorCtx};
pub use super::distributed::{chain as distributed_chain, DistributedCtx};
pub use super::global::{async_chain as global_async_chain, base_chain as global_chain, GlobalCtx};
pub use super::hybrid::{chain as hybrid_chain, HybridCtx};
pub use super::trainer::{base_chain as trainer_chain, TrainerCtx};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exported_chains_expose_their_surgery_points() {
        // every base chain is reachable and inspectable through the SDK —
        // the aliases are the public surgery surface of paper Table 1
        assert!(trainer_chain().get_tasklet("train"));
        assert!(aggregator_chain().get_tasklet("collect"));
        assert!(global_chain().get_tasklet("distribute"));
        assert!(global_async_chain().get_tasklet("serve"));
        assert!(coordinator_chain().get_tasklet("assign"));
        assert!(hybrid_chain().get_tasklet("cluster_agg"));
        assert!(distributed_chain().get_tasklet("allreduce"));
    }
}
