//! Trainer role: the data-consuming worker (paper Fig 5's `MNistTrainer`).
//!
//! Base chain (H-FL/C-FL): `load >> init >> Loop(fetch >> train >> upload)`.
//! The CO-FL variant (§6.1) is derived purely by chain surgery: a
//! `get_assignment` tasklet inserted before `fetch` reads the coordinator's
//! per-round aggregator assignment (and the end-of-training signal, since
//! the coordinator owns termination in CO-FL).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::channel::Message;
use crate::algos::ClientAlgo;
use crate::data::batch_plan;
use crate::json::{self, Json};
use crate::select::FedBalancer;
use crate::workflow::{Composer, Tasklet};

use super::{chain_program, Program, WorkerEnv};

/// Trainer state threaded through the tasklet chain.
pub struct TrainerCtx {
    pub env: WorkerEnv,
    data: Arc<crate::data::Dataset>,
    /// Local model (flat).
    flat: Vec<f32>,
    /// Last received global model (FedProx/FedDyn anchor, delta base).
    global: Vec<f32>,
    /// FedDyn drift state.
    h: Vec<f32>,
    batches: Vec<Vec<usize>>,
    /// Current epoch's batch visit order (balancer-driven when enabled).
    plan: Vec<usize>,
    batch_pos: usize,
    balancer: Option<FedBalancer>,
    /// Current upstream aggregator: learned from whoever distributed this
    /// round's weights (so a live tier extension re-parents trainers
    /// without re-deployment), or pinned by the CO-FL coordinator. An
    /// interned atom — per-round re-parenting never copies the name.
    pub parent: Option<Arc<str>>,
    /// CO-FL: the coordinator assigned `parent`; fetch must receive from
    /// exactly that worker rather than from whoever sends first.
    pinned: bool,
    pub round: u64,
    /// True when this round was a non-participation round ("skip").
    skip: bool,
    pub done: bool,
    last_loss: f64,
    /// Error-feedback residual for lossy upload codecs (top-k): the mass
    /// this client has not yet managed to send. Owned here — per client —
    /// so encoding stays a pure function of `(delta, residual)` and the
    /// job's codec object can be shared statelessly.
    residual: Vec<f32>,
}

impl TrainerCtx {
    /// Build the context for a trainer program over `env` (public so
    /// custom programs derived from [`base_chain`] via the Role SDK can
    /// instantiate it — see `sim::run_fedprox`).
    pub fn new(env: WorkerEnv) -> Result<Self> {
        Ok(Self {
            data: env.shard()?,
            env,
            flat: Vec::new(),
            global: Vec::new(),
            h: Vec::new(),
            batches: Vec::new(),
            plan: Vec::new(),
            batch_pos: 0,
            balancer: None,
            parent: None,
            pinned: false,
            round: 0,
            skip: false,
            done: false,
            last_loss: f64::NAN,
            residual: Vec::new(),
        })
    }

    /// Whether the current round actually trains (not terminated, not a
    /// non-participation "skip" round). Custom `train`-slot tasklets must
    /// gate on this exactly like the base `train` does.
    pub fn training_this_round(&self) -> bool {
        !self.done && !self.skip
    }

    /// The local model (flat parameter vector).
    pub fn model(&self) -> &[f32] {
        &self.flat
    }

    /// The round's received global model — the FedProx/FedDyn proximal
    /// anchor and the delta base for uploads.
    pub fn anchor(&self) -> &[f32] {
        &self.global
    }

    /// Replace the local model after a training step.
    pub fn set_model(&mut self, flat: Vec<f32>) {
        debug_assert_eq!(flat.len(), self.global.len());
        self.flat = flat;
    }

    /// Feed one batch's observed loss back to the batch selector
    /// (FedBalancer) when it is enabled; no-op otherwise. Custom
    /// `train`-slot tasklets should call this per batch exactly like
    /// the base `train` does, or loss-guided selection silently stalls
    /// on its initial estimates.
    pub fn record_batch_loss(&mut self, batch_idx: usize, loss: f64) {
        if let Some(fb) = &mut self.balancer {
            fb.record(batch_idx, loss);
        }
    }

    /// Record the round's mean training loss: feeds the `trainer_loss`
    /// series and the metadata `upload` attaches to the update message.
    pub fn finish_train_step(&mut self, mean_loss: f64) {
        self.last_loss = mean_loss;
        self.env
            .job
            .metrics
            .record(&self.env.cfg.id, "trainer_loss", self.round, mean_loss);
    }

    /// The next training batch under the epoch plan (balancer-driven when
    /// FedBalancer is enabled): `(batch index, x, y)`.
    pub fn next_batch(&mut self) -> (usize, Vec<f32>, Vec<i32>) {
        if self.plan.is_empty() || self.batch_pos >= self.plan.len() {
            // new epoch: balancer plan, or a fresh shuffle of all batches
            self.plan = match &mut self.balancer {
                Some(fb) => fb.plan(),
                None => {
                    let mut p: Vec<usize> = (0..self.batches.len()).collect();
                    self.env.rng.shuffle(&mut p);
                    p
                }
            };
            self.batch_pos = 0;
        }
        let b = self.env.job.compute.batch();
        let batch_idx = self.plan[self.batch_pos];
        let (x, y) = self.data.gather_batch(&self.batches[batch_idx], b);
        self.batch_pos += 1;
        (batch_idx, x, y)
    }

    /// Boundary snapshot of the trainer's resumable state: RNG stream,
    /// epoch plan position, FedDyn drift, codec residual, balancer stream
    /// and current parent. The received model is *not* saved — the next
    /// round's distribution refills it.
    pub fn snapshot_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("round", json::from_u64_hex(self.round));
        o.insert("rng", self.env.rng.to_json());
        o.insert(
            "plan",
            Json::Arr(self.plan.iter().map(|i| Json::Num(*i as f64)).collect()),
        );
        o.insert("batch_pos", Json::Num(self.batch_pos as f64));
        if let Some(fb) = &self.balancer {
            o.insert("balancer", fb.snapshot());
        }
        if !self.h.is_empty() {
            o.insert("h", super::floats_to_json(&self.h));
        }
        if !self.residual.is_empty() {
            o.insert("residual", super::floats_to_json(&self.residual));
        }
        if let Some(p) = &self.parent {
            o.insert("parent", Json::Str(p.to_string()));
        }
        Json::Obj(o)
    }

    /// Rehydrate from a [`Self::snapshot_json`] checkpoint (runs in `init`,
    /// after `load` fresh-seeded the RNG — the restore overwrites it, so
    /// the resumed stream continues exactly where the killed run stopped).
    pub fn restore_from(&mut self, snap: &Json) -> Result<()> {
        self.env.rng = crate::prng::Rng::from_json(snap.get("rng"))
            .context("trainer checkpoint missing rng state")?;
        self.plan = snap
            .get("plan")
            .as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|v| v as usize).collect())
            .unwrap_or_default();
        self.batch_pos = snap.get("batch_pos").as_f64().unwrap_or(0.0) as usize;
        if let Some(fb) = self.balancer.as_mut() {
            let bj = snap.get("balancer");
            if !matches!(*bj, Json::Null) {
                fb.restore(bj);
            }
        }
        let h = super::floats_from_json(snap.get("h"));
        if !h.is_empty() {
            self.h = h;
        }
        let residual = super::floats_from_json(snap.get("residual"));
        if !residual.is_empty() {
            self.residual = residual;
        }
        if let Some(p) = snap.get("parent").as_str() {
            self.parent = Some(crate::intern::atom(p));
        }
        self.round = json::as_u64_hex(snap.get("round")).context("trainer checkpoint missing round")?;
        Ok(())
    }
}

/// Publish this trainer's boundary snapshot into the job's checkpoint
/// sink. Called immediately *before* the upload send: the send is what
/// wakes the aggregation path, so by the time the sequencer's full-quorum
/// collect returns (and its checkpoint tasklet can run), every
/// participating trainer's snapshot is already in the hub.
fn publish_ckpt(c: &TrainerCtx) {
    if let Some(sink) = &c.env.job.ckpt {
        sink.publish(&c.env.cfg.id, c.snapshot_json());
    }
}

/// Scripted worker kill ([`crate::controlplane::FaultPlan`]): a plan naming
/// this worker takes its pod down at its own boundary upload — after the
/// snapshot publish (so a failover seed exists) but before the send (the
/// kill models a client dying mid-round, not a half-delivered update).
fn fault_check(c: &TrainerCtx) -> Result<()> {
    if let Some(sink) = &c.env.job.ckpt {
        let boundary = c.round + 1;
        if sink.policy().faults.kills_worker_at(&c.env.cfg.id, boundary) {
            bail!("injected worker kill at round boundary {boundary}");
        }
    }
    Ok(())
}

// ------------------------------------------------------------- tasklets

fn load(c: &mut TrainerCtx) -> Result<()> {
    let b = c.env.job.compute.batch();
    c.batches = batch_plan(&mut c.env.rng, c.data.len(), b);
    if c.env.job.tcfg.fedbalancer {
        let seed = c.env.job.tcfg.seed ^ 0xFB;
        c.balancer = Some(FedBalancer::new(c.batches.len(), 0.5, seed));
    }
    Ok(())
}

fn init(c: &mut TrainerCtx) -> Result<()> {
    let d = c.env.job.compute.d_pad();
    c.flat = vec![0.0; d];
    c.global = vec![0.0; d];
    // FedDyn drift state only when the algorithm needs it: at 10k trainers
    // an unused third model vector per worker is hundreds of MB of RSS.
    c.h = if matches!(c.env.job.tcfg.client, ClientAlgo::Dyn) {
        vec![0.0; d]
    } else {
        Vec::new()
    };
    if let Some(ck) = c.env.job.restore.clone() {
        if let Some(snap) = ck.workers.get(&c.env.cfg.id) {
            c.restore_from(snap)?;
        }
        // no snapshot: this trainer never participated before the kill
        // point (or joined after it), so fresh-init state IS its state
    }
    Ok(())
}

fn fetch(c: &mut TrainerCtx) -> Result<()> {
    if c.done {
        return Ok(());
    }
    let param = c.env.chan("param-channel")?;
    // Unpinned trainers take the round's distribution from whoever sends
    // it: in a static topology that is always the same parent, and after a
    // live tier extension it is the trainer's new group aggregator — the
    // re-parenting needs no control message at all.
    let (from, msg) = if c.pinned {
        let p = c
            .parent
            .clone()
            .context("pinned trainer has no assigned parent")?;
        let m = param.recv(&p)?;
        (p, m)
    } else {
        param.recv_any()?
    };
    match &*msg.kind {
        "weights" => {
            let crate::channel::Payload::Floats(w) = &msg.payload else {
                bail!("weights message without float payload");
            };
            c.global.copy_from_slice(w);
            c.flat.copy_from_slice(w);
            c.round = msg.round;
            c.skip = false;
            c.parent = Some(from);
        }
        "skip" => {
            c.round = msg.round;
            c.skip = true;
        }
        "done" => c.done = true,
        other => bail!("trainer got unexpected message kind '{other}'"),
    }
    // whoever consumes the broadcast last hands the weights buffer back to
    // the pool for next round's distribution
    if let crate::channel::Payload::Floats(w) = msg.payload {
        c.env.job.pool.reclaim(w);
    }
    Ok(())
}

fn train(c: &mut TrainerCtx) -> Result<()> {
    if c.done || c.skip {
        return Ok(());
    }
    let tcfg = c.env.job.tcfg.clone();
    let compute = c.env.job.compute.clone();
    let v0 = c.env.now();
    let mut loss_sum = 0.0;
    for _ in 0..tcfg.local_steps {
        let (batch_idx, x, y) = c.next_batch();
        let t0 = Instant::now();
        let loss = match tcfg.client {
            ClientAlgo::Sgd => {
                let (nf, loss) = compute.train_step(&c.flat, &x, &y, tcfg.lr)?;
                c.flat = nf;
                loss
            }
            ClientAlgo::Prox => {
                let (nf, loss) =
                    compute.train_step_prox(&c.flat, &c.global, &x, &y, tcfg.lr, tcfg.mu)?;
                c.flat = nf;
                loss
            }
            ClientAlgo::Dyn => {
                let (nf, nh, loss) = compute
                    .train_step_dyn(&c.flat, &c.global, &c.h, &x, &y, tcfg.lr, tcfg.alpha)?;
                c.flat = nf;
                c.h = nh;
                loss
            }
        };
        c.env.charge(t0);
        if let Some(fb) = &mut c.balancer {
            fb.record(batch_idx, loss as f64);
        }
        loss_sum += loss as f64;
    }
    c.last_loss = loss_sum / tcfg.local_steps as f64;
    c.env.job.trace.span(
        &c.env.cfg.id,
        crate::trace::phase::TRAIN,
        c.round,
        v0,
        c.env.now(),
    );
    c.env
        .job
        .metrics
        .record(&c.env.cfg.id, "trainer_loss", c.round, c.last_loss);
    Ok(())
}

fn upload(c: &mut TrainerCtx) -> Result<()> {
    if c.done || c.skip {
        return Ok(());
    }
    let tcfg = &c.env.job.tcfg;
    let asynchronous = matches!(
        tcfg.aggregation,
        crate::algos::AggregationPolicy::Asynchronous { .. }
    );
    // DP sanitisation operates on the delta.
    let mut delta = crate::model::sub(&c.flat, &c.global);
    if tcfg.dp_clip > 0.0 {
        crate::algos::dp_sanitize(&mut delta, tcfg.dp_clip, tcfg.dp_sigma, &mut c.env.rng);
    }
    let payload: Arc<Vec<f32>> = if asynchronous {
        Arc::new(delta) // FedBuff consumes deltas
    } else {
        // pooled: the aggregator folds this buffer and recycles it, so
        // steady-state uploads stop touching the allocator
        let mut w = c.env.job.pool.take_copy(&c.global);
        let wb = Arc::get_mut(&mut w).expect("pooled buffers are uniquely owned");
        crate::model::axpy(wb, 1.0, &delta);
        w
    };
    let mut meta = Json::obj();
    meta.insert("samples", c.data.len());
    meta.insert("loss", Json::Num(c.last_loss));
    meta.insert("worker", c.env.cfg.id.as_str());
    let msg = Message::floats("update", c.round, payload).with_meta(Json::Obj(meta));
    let parent = c.parent.clone().context("no parent to upload to")?;
    let param = c.env.chan("param-channel")?;
    c.env.job.metrics.add_traffic(msg.size_bytes());
    c.env
        .job
        .metrics
        .record(&c.env.cfg.id, "upload_bytes", c.round, msg.size_bytes() as f64);
    publish_ckpt(c);
    fault_check(c)?;
    param.send(&parent, msg)?;
    Ok(())
}

/// Codec variant of `upload`, swapped into the `upload` slot by [`build`]
/// when the job configures `hyper.codec`: the (DP-sanitized) delta is
/// encoded through the job codec and travels as `Payload::Encoded`, so
/// virtual-time wire accounting charges the **compressed** bytes. The
/// aggregation point decodes and — for synchronous collects — re-adds the
/// round's distributed base, mirroring the raw path's `base + delta`
/// arithmetic exactly (the `f32` codec is therefore bit-identical to no
/// codec at all). Lossy codecs bank their unsent mass in the per-client
/// error-feedback residual.
fn upload_encoded(c: &mut TrainerCtx) -> Result<()> {
    if c.done || c.skip {
        return Ok(());
    }
    let codec = c
        .env
        .job
        .codec
        .clone()
        .context("upload_encoded scheduled without a job codec")?;
    let tcfg = &c.env.job.tcfg;
    let mut delta = crate::model::sub(&c.flat, &c.global);
    if tcfg.dp_clip > 0.0 {
        crate::algos::dp_sanitize(&mut delta, tcfg.dp_clip, tcfg.dp_sigma, &mut c.env.rng);
    }
    let enc = Arc::new(codec.encode(&delta, &mut c.residual));
    // encode is not charged to the virtual clock (it models codec choice,
    // not compute cost), so the span is a zero-length marker
    let v = c.env.now();
    c.env
        .job
        .trace
        .span(&c.env.cfg.id, crate::trace::phase::ENCODE, c.round, v, v);
    let mut meta = Json::obj();
    meta.insert("samples", c.data.len());
    meta.insert("loss", Json::Num(c.last_loss));
    meta.insert("worker", c.env.cfg.id.as_str());
    let msg = Message::encoded("update", c.round, enc).with_meta(Json::Obj(meta));
    let parent = c.parent.clone().context("no parent to upload to")?;
    let param = c.env.chan("param-channel")?;
    c.env.job.metrics.add_traffic(msg.size_bytes());
    c.env
        .job
        .metrics
        .record(&c.env.cfg.id, "upload_bytes", c.round, msg.size_bytes() as f64);
    publish_ckpt(c);
    fault_check(c)?;
    param.send(&parent, msg)?;
    Ok(())
}

/// CO-FL only (inserted by surgery): per-round assignment from the
/// coordinator — which aggregator to work with, or end-of-training.
fn get_assignment(c: &mut TrainerCtx) -> Result<()> {
    if c.done {
        return Ok(());
    }
    let coord_chan = c.env.chan("coord-t-channel")?;
    let coord = coord_chan
        .ends()
        .first()
        .cloned()
        .context("no coordinator on coord-t-channel")?;
    let msg = coord_chan.recv(&coord)?;
    match &*msg.kind {
        "assign" => {
            c.parent = msg.meta().get("parent").as_str().map(crate::intern::atom);
            c.pinned = c.parent.is_some();
        }
        "done" => c.done = true,
        other => bail!("unexpected coordinator message '{other}'"),
    }
    Ok(())
}

/// The base trainer chain.
pub fn base_chain() -> Composer<TrainerCtx> {
    Composer::new()
        .task("load", load)
        .task("init", init)
        .loop_until(
            |c: &TrainerCtx| c.done,
            Composer::new()
                .task("fetch", fetch)
                .task("train", train)
                .task("upload", upload),
        )
}

/// Build the trainer program; `coordinated` derives the CO-FL variant by
/// chain surgery (paper Fig 9 style).
pub fn build(env: WorkerEnv, coordinated: bool) -> Result<Box<dyn Program>> {
    let ctx = TrainerCtx::new(env)?;
    let mut chain = base_chain();
    if coordinated {
        chain.insert_before("fetch", Tasklet::new("get_assignment", get_assignment))?;
    }
    // Update codec: the encode stage rides the composer chain by taking
    // over the `upload` slot (same Table-1 surgery a custom program uses).
    if ctx.env.job.codec.is_some() {
        chain.replace_with("upload", Tasklet::new("upload_encoded", upload_encoded))?;
    }
    Ok(chain_program(chain, ctx))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_chain_shape() {
        let c = base_chain();
        assert_eq!(
            c.aliases(),
            vec!["load", "init", "fetch", "train", "upload"]
        );
    }

    #[test]
    fn coordinated_surgery_inserts_assignment() {
        let mut c = base_chain();
        c.insert_before("fetch", Tasklet::new("get_assignment", get_assignment))
            .unwrap();
        assert_eq!(
            c.aliases(),
            vec!["load", "init", "get_assignment", "fetch", "train", "upload"]
        );
    }

    #[test]
    fn codec_surgery_takes_over_the_upload_slot() {
        let mut c = base_chain();
        c.replace_with("upload", Tasklet::new("upload_encoded", upload_encoded))
            .unwrap();
        assert_eq!(
            c.aliases(),
            vec!["load", "init", "fetch", "train", "upload_encoded"]
        );
    }
}
