//! Distributed-learning collectives over the Channel API.
//!
//! The paper's distributed topology (Fig 1a) uses mechanisms like
//! all-reduce; Hybrid FL (§6.2) aggregates each co-located cluster with
//! ring-allreduce before one delegate uploads. This module implements the
//! bandwidth-optimal **ring all-reduce** (Patarasuk & Yuan) directly on the
//! Table-2 channel API: k-1 scatter-reduce steps + k-1 all-gather steps of
//! `D/k`-sized chunks, so each member moves `2·(k-1)/k·D` data — the cost
//! the virtual clocks then account for.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::channel::{ChannelHandle, Message, Payload};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RingPhase {
    Scatter,
    Gather,
    Done,
}

/// Resumable ring all-reduce: the collective as an explicit state machine.
///
/// The ring protocol interleaves `k-1` send/receive pairs per phase; under
/// the cooperative worker fabric any of those receives can yield
/// [`crate::sched::Pending`] out of the calling tasklet. Holding the
/// protocol state (phase, step, whether this step's chunk was already
/// sent) in a value the role context owns makes the enclosing tasklet
/// re-entrant: on resume, [`poll`](Self::poll) continues exactly where the
/// collective left off and never duplicates a send.
pub struct RingAllReduce {
    buf: Vec<f32>,
    bounds: Vec<(usize, usize)>,
    left: String,
    right: String,
    my_idx: usize,
    k: usize,
    phase: RingPhase,
    step: usize,
    sent: bool,
    mean: bool,
}

impl RingAllReduce {
    /// Sum all-reduce over `buf`.
    pub fn sum(chan: &ChannelHandle, buf: Vec<f32>) -> Self {
        Self::new(chan, buf, false)
    }

    /// Weighted-mean all-reduce: each member contributes
    /// `(values, weight)`; everyone ends with `Σ w_i·x_i / Σ w_i`.
    pub fn mean(chan: &ChannelHandle, values: &[f32], weight: f32) -> Self {
        let mut buf: Vec<f32> = values.iter().map(|v| v * weight).collect();
        buf.push(weight);
        Self::new(chan, buf, true)
    }

    fn new(chan: &ChannelHandle, buf: Vec<f32>, mean: bool) -> Self {
        let me = chan.worker_id().to_string();
        let mut members: Vec<String> = (*chan.ends()).clone();
        members.push(me.clone());
        members.sort();
        let k = members.len();
        let my_idx = members.iter().position(|m| *m == me).unwrap();
        let right = members[(my_idx + 1) % k].clone();
        let left = members[(my_idx + k - 1) % k].clone();
        // chunk boundaries (first chunks take the remainder)
        let n = buf.len();
        let bounds: Vec<(usize, usize)> = (0..k)
            .map(|c| {
                let base = n / k;
                let extra = n % k;
                let start = c * base + c.min(extra);
                let len = base + usize::from(c < extra);
                (start, start + len)
            })
            .collect();
        Self {
            buf,
            bounds,
            left,
            right,
            my_idx,
            k,
            phase: if k == 1 { RingPhase::Done } else { RingPhase::Scatter },
            step: 0,
            sent: false,
            mean,
        }
    }

    /// Drive the collective to completion. A blocking receive inside waits;
    /// a cooperative one yields [`crate::sched::Pending`] out of this call
    /// with all protocol state retained — call `poll` again on resume.
    pub fn poll(&mut self, chan: &ChannelHandle) -> Result<()> {
        loop {
            let kind = match self.phase {
                RingPhase::Done => return Ok(()),
                RingPhase::Scatter => "ar_sr",
                RingPhase::Gather => "ar_ag",
            };
            let (send_c, recv_c) = match self.phase {
                // scatter-reduce: after step s, chunk (i-s-1) mod k holds partials
                RingPhase::Scatter => (
                    (self.my_idx + self.k - self.step) % self.k,
                    (self.my_idx + self.k - self.step - 1) % self.k,
                ),
                // all-gather: circulate the completed chunks
                RingPhase::Gather => (
                    (self.my_idx + 1 + self.k - self.step) % self.k,
                    (self.my_idx + self.k - self.step) % self.k,
                ),
                RingPhase::Done => unreachable!(),
            };
            if !self.sent {
                let (s0, s1) = self.bounds[send_c];
                let msg =
                    Message::floats(kind, self.step as u64, Arc::new(self.buf[s0..s1].to_vec()));
                chan.send(&self.right, msg)?;
                self.sent = true;
            }
            let got = chan.recv_kind(&self.left, kind)?; // may yield Pending
            let Payload::Floats(chunk) = got.payload else {
                bail!("allreduce chunk without floats");
            };
            let (r0, r1) = self.bounds[recv_c];
            match self.phase {
                RingPhase::Scatter => {
                    for (dst, src) in self.buf[r0..r1].iter_mut().zip(chunk.iter()) {
                        *dst += src;
                    }
                }
                RingPhase::Gather => self.buf[r0..r1].copy_from_slice(&chunk),
                RingPhase::Done => unreachable!(),
            }
            self.sent = false;
            self.step += 1;
            if self.step == self.k - 1 {
                self.step = 0;
                self.phase = match self.phase {
                    RingPhase::Scatter => RingPhase::Gather,
                    RingPhase::Gather => RingPhase::Done,
                    RingPhase::Done => unreachable!(),
                };
            }
        }
    }

    /// Consume a completed sum all-reduce.
    pub fn into_sum(self) -> Result<Vec<f32>> {
        if self.phase != RingPhase::Done {
            bail!("ring allreduce consumed before completion");
        }
        Ok(self.buf)
    }

    /// Consume a completed mean all-reduce (divides by the total weight).
    pub fn into_mean(self) -> Result<Vec<f32>> {
        if self.phase != RingPhase::Done {
            bail!("ring allreduce consumed before completion");
        }
        if !self.mean {
            bail!("into_mean on a sum all-reduce");
        }
        let mut buf = self.buf;
        let wsum = buf.pop().context("mean all-reduce buffer empty")?;
        if wsum <= 0.0 {
            bail!("ring allreduce: total weight is zero");
        }
        for v in buf.iter_mut() {
            *v /= wsum;
        }
        Ok(buf)
    }
}

/// Weighted mean all-reduce over the members of `chan`'s group (blocking
/// convenience over [`RingAllReduce`]).
///
/// Each member contributes `(weights, weight_scalar)`; everyone ends with
/// the identical weighted mean `Σ w_i·x_i / Σ w_i`. Deterministic: the ring
/// order is the sorted member list.
pub fn ring_allreduce_mean(
    chan: &ChannelHandle,
    values: &mut [f32],
    weight: f32,
) -> Result<()> {
    let mut op = RingAllReduce::mean(chan, values, weight);
    op.poll(chan)?;
    let out = op.into_mean()?;
    values.copy_from_slice(&out);
    Ok(())
}

/// In-place sum all-reduce via ring scatter-reduce + all-gather (blocking
/// convenience over [`RingAllReduce`]).
pub fn ring_allreduce_sum(chan: &ChannelHandle, buf: &mut [f32]) -> Result<()> {
    let mut op = RingAllReduce::sum(chan, buf.to_vec());
    op.poll(chan)?;
    let out = op.into_sum()?;
    buf.copy_from_slice(&out);
    Ok(())
}

/// The cluster delegate: the lexically-first member of the group uploads on
/// behalf of the cluster (Hybrid FL's "single copy of the cluster model").
pub fn is_delegate(chan: &ChannelHandle) -> bool {
    let me = chan.worker_id().to_string();
    let mut members: Vec<String> = (*chan.ends()).clone();
    members.push(me.clone());
    members.sort();
    members[0] == me
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{Backend, ChannelManager};
    use crate::net::{LinkSpec, VClock, VirtualNet};
    use std::sync::Mutex;

    fn run_ring(k: usize, n: usize) -> Vec<Vec<f32>> {
        let net = Arc::new(VirtualNet::new(LinkSpec::mbps(100.0, 10)));
        let mgr = ChannelManager::new(net);
        let mut handles = vec![];
        for i in 0..k {
            let mgr = mgr.clone();
            handles.push(std::thread::spawn(move || {
                let chan = mgr
                    .join(
                        "ring",
                        "g",
                        &format!("t{i}"),
                        "trainer",
                        Backend::P2p,
                        Arc::new(Mutex::new(VClock::default())),
                    )
                    .unwrap();
                // wait for all members to join
                while chan.ends().len() < k - 1 {
                    std::thread::yield_now();
                }
                let mut buf: Vec<f32> = (0..n).map(|j| (i * n + j) as f32).collect();
                ring_allreduce_sum(&chan, &mut buf).unwrap();
                buf
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn allreduce_sum_matches_oracle() {
        for (k, n) in [(2, 10), (3, 7), (4, 16), (5, 23)] {
            let results = run_ring(k, n);
            let want: Vec<f32> = (0..n)
                .map(|j| (0..k).map(|i| (i * n + j) as f32).sum())
                .collect();
            for (i, r) in results.iter().enumerate() {
                assert_eq!(r, &want, "member {i} of k={k} n={n}");
            }
        }
    }

    #[test]
    fn allreduce_mean_weighted() {
        let k = 3;
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let mut handles = vec![];
        for i in 0..k {
            let mgr = mgr.clone();
            handles.push(std::thread::spawn(move || {
                let chan = mgr
                    .join(
                        "ring",
                        "g",
                        &format!("t{i}"),
                        "trainer",
                        Backend::InProc,
                        Arc::new(Mutex::new(VClock::default())),
                    )
                    .unwrap();
                while chan.ends().len() < k - 1 {
                    std::thread::yield_now();
                }
                let mut v = vec![(i + 1) as f32; 5];
                // weights 1, 2, 3 -> mean = (1*1+2*2+3*3)/6 = 14/6
                ring_allreduce_mean(&chan, &mut v, (i + 1) as f32).unwrap();
                v
            }));
        }
        for h in handles {
            let v = h.join().unwrap();
            for x in v {
                assert!((x - 14.0 / 6.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn single_member_is_identity() {
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let chan = mgr
            .join(
                "ring",
                "g",
                "solo",
                "trainer",
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
            )
            .unwrap();
        let mut v = vec![1.0, 2.0, 3.0];
        ring_allreduce_sum(&chan, &mut v).unwrap();
        assert_eq!(v, vec![1.0, 2.0, 3.0]);
        assert!(is_delegate(&chan));
    }

    #[test]
    fn virtual_time_reflects_ring_cost() {
        // k members, D floats each at 100 Mbps: ring moves 2*(k-1)/k*D per
        // member; clock must advance accordingly (and far less than k*D).
        let k = 4;
        let n = 100_000;
        let net = Arc::new(VirtualNet::new(LinkSpec::mbps(100.0, 0)));
        let mgr = ChannelManager::new(net);
        let mut handles = vec![];
        for i in 0..k {
            let mgr = mgr.clone();
            handles.push(std::thread::spawn(move || {
                let clock = Arc::new(Mutex::new(VClock::default()));
                let chan = mgr
                    .join(
                        "ring",
                        "g",
                        &format!("t{i}"),
                        "trainer",
                        Backend::P2p,
                        clock.clone(),
                    )
                    .unwrap();
                while chan.ends().len() < k - 1 {
                    std::thread::yield_now();
                }
                let mut buf = vec![1.0f32; n];
                ring_allreduce_sum(&chan, &mut buf).unwrap();
                let now = clock.lock().unwrap().now();
                now
            }));
        }
        let times: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // ideal: 2*(k-1)/k * n * 4 bytes over 100 Mbps
        let ideal_us = 2.0 * (k as f64 - 1.0) / k as f64 * (n * 4) as f64 * 8.0 / 100e6 * 1e6;
        for t in times {
            let t = t as f64;
            assert!(t > 0.8 * ideal_us, "t={t} ideal={ideal_us}");
            // steps serialize: allow pipeline slack but far below k*D cost
            let naive_us = (k as f64 - 1.0) * (n * 4) as f64 * 8.0 / 100e6 * 1e6;
            assert!(t < 1.5 * naive_us, "t={t} naive={naive_us}");
        }
    }
}
