//! Distributed-learning collectives over the Channel API.
//!
//! The paper's distributed topology (Fig 1a) uses mechanisms like
//! all-reduce; Hybrid FL (§6.2) aggregates each co-located cluster with
//! ring-allreduce before one delegate uploads. This module implements the
//! bandwidth-optimal **ring all-reduce** (Patarasuk & Yuan) directly on the
//! Table-2 channel API: k-1 scatter-reduce steps + k-1 all-gather steps of
//! `D/k`-sized chunks, so each member moves `2·(k-1)/k·D` data — the cost
//! the virtual clocks then account for.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::channel::{ChannelHandle, Message, Payload};

/// Weighted mean all-reduce over the members of `chan`'s group.
///
/// Each member contributes `(weights, weight_scalar)`; everyone ends with
/// the identical weighted mean `Σ w_i·x_i / Σ w_i`. Deterministic: the ring
/// order is the sorted member list.
pub fn ring_allreduce_mean(
    chan: &ChannelHandle,
    values: &mut [f32],
    weight: f32,
) -> Result<()> {
    // contribution vector: [x * w ..., w]
    let mut buf: Vec<f32> = values.iter().map(|v| v * weight).collect();
    buf.push(weight);
    ring_allreduce_sum(chan, &mut buf)?;
    let wsum = *buf.last().unwrap();
    if wsum <= 0.0 {
        bail!("ring allreduce: total weight is zero");
    }
    for (dst, src) in values.iter_mut().zip(&buf) {
        *dst = src / wsum;
    }
    Ok(())
}

/// In-place sum all-reduce via ring scatter-reduce + all-gather.
pub fn ring_allreduce_sum(chan: &ChannelHandle, buf: &mut [f32]) -> Result<()> {
    let me = chan.worker_id().to_string();
    let mut members = chan.ends();
    members.push(me.clone());
    members.sort();
    let k = members.len();
    if k == 1 {
        return Ok(());
    }
    let my_idx = members.iter().position(|m| *m == me).unwrap();
    let right = &members[(my_idx + 1) % k];
    let left = &members[(my_idx + k - 1) % k];

    // chunk boundaries (first chunks take the remainder)
    let n = buf.len();
    let bounds: Vec<(usize, usize)> = (0..k)
        .map(|c| {
            let base = n / k;
            let extra = n % k;
            let start = c * base + c.min(extra);
            let len = base + usize::from(c < extra);
            (start, start + len)
        })
        .collect();

    // scatter-reduce: after step s, chunk (i - s - 1) mod k holds partials
    for step in 0..k - 1 {
        let send_c = (my_idx + k - step) % k;
        let recv_c = (my_idx + k - step - 1) % k;
        let (s0, s1) = bounds[send_c];
        let msg = Message::floats("ar_sr", step as u64, Arc::new(buf[s0..s1].to_vec()));
        chan.send(right, msg)?;
        let got = chan.recv_kind(left, "ar_sr")?;
        let Payload::Floats(chunk) = got.payload else {
            bail!("allreduce chunk without floats");
        };
        let (r0, r1) = bounds[recv_c];
        for (dst, src) in buf[r0..r1].iter_mut().zip(chunk.iter()) {
            *dst += src;
        }
    }
    // all-gather: circulate the completed chunks
    for step in 0..k - 1 {
        let send_c = (my_idx + 1 + k - step) % k;
        let recv_c = (my_idx + k - step) % k;
        let (s0, s1) = bounds[send_c];
        let msg = Message::floats("ar_ag", step as u64, Arc::new(buf[s0..s1].to_vec()));
        chan.send(right, msg)?;
        let got = chan.recv_kind(left, "ar_ag")?;
        let Payload::Floats(chunk) = got.payload else {
            bail!("allreduce chunk without floats");
        };
        let (r0, r1) = bounds[recv_c];
        buf[r0..r1].copy_from_slice(&chunk);
    }
    Ok(())
}

/// The cluster delegate: the lexically-first member of the group uploads on
/// behalf of the cluster (Hybrid FL's "single copy of the cluster model").
pub fn is_delegate(chan: &ChannelHandle) -> bool {
    let me = chan.worker_id().to_string();
    let mut members = chan.ends();
    members.push(me.clone());
    members.sort();
    members[0] == me
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{Backend, ChannelManager};
    use crate::net::{LinkSpec, VClock, VirtualNet};
    use std::sync::Mutex;

    fn run_ring(k: usize, n: usize) -> Vec<Vec<f32>> {
        let net = Arc::new(VirtualNet::new(LinkSpec::mbps(100.0, 10)));
        let mgr = ChannelManager::new(net);
        let mut handles = vec![];
        for i in 0..k {
            let mgr = mgr.clone();
            handles.push(std::thread::spawn(move || {
                let chan = mgr
                    .join(
                        "ring",
                        "g",
                        &format!("t{i}"),
                        "trainer",
                        Backend::P2p,
                        Arc::new(Mutex::new(VClock::default())),
                    )
                    .unwrap();
                // wait for all members to join
                while chan.ends().len() < k - 1 {
                    std::thread::yield_now();
                }
                let mut buf: Vec<f32> = (0..n).map(|j| (i * n + j) as f32).collect();
                ring_allreduce_sum(&chan, &mut buf).unwrap();
                buf
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn allreduce_sum_matches_oracle() {
        for (k, n) in [(2, 10), (3, 7), (4, 16), (5, 23)] {
            let results = run_ring(k, n);
            let want: Vec<f32> = (0..n)
                .map(|j| (0..k).map(|i| (i * n + j) as f32).sum())
                .collect();
            for (i, r) in results.iter().enumerate() {
                assert_eq!(r, &want, "member {i} of k={k} n={n}");
            }
        }
    }

    #[test]
    fn allreduce_mean_weighted() {
        let k = 3;
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let mut handles = vec![];
        for i in 0..k {
            let mgr = mgr.clone();
            handles.push(std::thread::spawn(move || {
                let chan = mgr
                    .join(
                        "ring",
                        "g",
                        &format!("t{i}"),
                        "trainer",
                        Backend::InProc,
                        Arc::new(Mutex::new(VClock::default())),
                    )
                    .unwrap();
                while chan.ends().len() < k - 1 {
                    std::thread::yield_now();
                }
                let mut v = vec![(i + 1) as f32; 5];
                // weights 1, 2, 3 -> mean = (1*1+2*2+3*3)/6 = 14/6
                ring_allreduce_mean(&chan, &mut v, (i + 1) as f32).unwrap();
                v
            }));
        }
        for h in handles {
            let v = h.join().unwrap();
            for x in v {
                assert!((x - 14.0 / 6.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn single_member_is_identity() {
        let net = Arc::new(VirtualNet::default());
        let mgr = ChannelManager::new(net);
        let chan = mgr
            .join(
                "ring",
                "g",
                "solo",
                "trainer",
                Backend::InProc,
                Arc::new(Mutex::new(VClock::default())),
            )
            .unwrap();
        let mut v = vec![1.0, 2.0, 3.0];
        ring_allreduce_sum(&chan, &mut v).unwrap();
        assert_eq!(v, vec![1.0, 2.0, 3.0]);
        assert!(is_delegate(&chan));
    }

    #[test]
    fn virtual_time_reflects_ring_cost() {
        // k members, D floats each at 100 Mbps: ring moves 2*(k-1)/k*D per
        // member; clock must advance accordingly (and far less than k*D).
        let k = 4;
        let n = 100_000;
        let net = Arc::new(VirtualNet::new(LinkSpec::mbps(100.0, 0)));
        let mgr = ChannelManager::new(net);
        let mut handles = vec![];
        for i in 0..k {
            let mgr = mgr.clone();
            handles.push(std::thread::spawn(move || {
                let clock = Arc::new(Mutex::new(VClock::default()));
                let chan = mgr
                    .join(
                        "ring",
                        "g",
                        &format!("t{i}"),
                        "trainer",
                        Backend::P2p,
                        clock.clone(),
                    )
                    .unwrap();
                while chan.ends().len() < k - 1 {
                    std::thread::yield_now();
                }
                let mut buf = vec![1.0f32; n];
                ring_allreduce_sum(&chan, &mut buf).unwrap();
                let now = clock.lock().unwrap().now();
                now
            }));
        }
        let times: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // ideal: 2*(k-1)/k * n * 4 bytes over 100 Mbps
        let ideal_us = 2.0 * (k as f64 - 1.0) / k as f64 * (n * 4) as f64 * 8.0 / 100e6 * 1e6;
        for t in times {
            let t = t as f64;
            assert!(t > 0.8 * ideal_us, "t={t} ideal={ideal_us}");
            // steps serialize: allow pipeline slack but far below k*D cost
            let naive_us = (k as f64 - 1.0) * (n * 4) as f64 * 8.0 / 100e6 * 1e6;
            assert!(t < 1.5 * naive_us, "t={t} naive={naive_us}");
        }
    }
}
