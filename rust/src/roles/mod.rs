//! Built-in role programs (paper §4.4) and the worker execution
//! environment.
//!
//! Every role — trainer, aggregator, global aggregator, coordinator, hybrid
//! trainer, distributed trainer — is a [`crate::workflow::Composer`] tasklet
//! chain over a role-specific context, mirroring the Python SDK's base
//! classes. Derived mechanisms (the CO-FL roles of §6.1) are produced by
//! **chain surgery** on the base chains (Table 1 API), exactly like the
//! paper's Fig 9 — not by re-implementation.
//!
//! Which program a worker runs is decided by the **Role SDK** ([`sdk`],
//! [`registry`]): a [`RoleRegistry`] resolves each role's binding from
//! spec data (the role's `program:` field, or the default binding for the
//! job's `tag.flavor`) and invokes the registered factory. There is no
//! role-name dispatch in this module.
//!
//! [`WorkerEnv`] is what the agent hands a role at start: the expanded
//! worker config, joined channel handles (per the TAG), the shared job
//! runtime (compute pool, datasets, metrics, program registry), and the
//! worker's virtual clock.

pub mod aggregator;
pub mod collective;
pub mod coordinator;
pub mod distributed;
pub mod global;
pub mod hybrid;
pub mod registry;
pub mod sdk;
pub mod trainer;

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::algos::TrainingConfig;
use crate::channel::{ChannelHandle, ChannelManager, RECV_TIMEOUT};
use crate::data::Dataset;
use crate::deploy::TopologyTimeline;
use crate::metrics::MetricsHub;
use crate::net::{VClock, VTime};
use crate::prng::{fnv1a64, Rng};
use crate::runtime::{Compute, ComputeTimeModel, TensorPool};
use crate::sched::WorkerPark;
use crate::tag::{Flavor, JobSpec, WorkerConfig};
use crate::workflow::StepStatus;

pub use registry::{ProgramFactory, RoleBinding, RoleRegistry};

/// Everything shared by all workers of one job deployment.
pub struct JobRuntime {
    pub spec: JobSpec,
    pub chan_mgr: Arc<ChannelManager>,
    pub compute: Arc<dyn Compute>,
    pub tcfg: TrainingConfig,
    pub metrics: Arc<MetricsHub>,
    /// dataset name -> shard.
    pub shards: HashMap<String, Arc<Dataset>>,
    /// Held-out set evaluated by the global aggregator.
    pub test_set: Arc<Dataset>,
    pub time_model: ComputeTimeModel,
    /// Initial global model (He-init from the artifact spec, or zeros for
    /// the mock runtime).
    pub init_flat: Arc<Vec<f32>>,
    /// Model-buffer pool: distributed weights, uploaded updates and
    /// aggregation accumulators cycle through it instead of the global
    /// allocator (see `runtime::pool`). One pool per job, sized `d_pad`.
    pub pool: Arc<TensorPool>,
    /// Scripted live-extension timeline (empty for static jobs). The
    /// round-driving global aggregator drains it at round boundaries.
    pub timeline: Arc<TopologyTimeline>,
    /// Role SDK: the program registry this job's workers bind through
    /// (the controller's base registry plus any per-job
    /// `JobOptions::with_program` overrides).
    pub programs: Arc<RoleRegistry>,
    /// The job's resolved topology flavour (declared `tag.flavor`, or the
    /// validate-time inference) — drives default role↔program bindings.
    pub flavor: Flavor,
    /// Upload codec shared by every worker of the job (`hyper.codec`):
    /// uploading roles encode their delta through it, aggregation points
    /// decode. `None` = raw float uploads. Per-client error-feedback
    /// residuals live in the uploading role's context, not here.
    pub codec: Option<Arc<dyn crate::runtime::Codec>>,
    /// Round-boundary checkpoint sink (`None` = crash resilience off for
    /// this job). Uploading workers publish boundary snapshots into it;
    /// the global's checkpoint tasklet commits them through the store.
    pub ckpt: Option<Arc<crate::controlplane::checkpoint::CkptSink>>,
    /// Checkpoint this deployment rehydrates from (`None` = fresh run).
    /// Role contexts pull their saved state out at build time.
    pub restore: Option<Arc<crate::controlplane::checkpoint::JobCheckpoint>>,
    /// Per-job virtual-time span recorder. Always present; jobs without
    /// `hyper.trace = "on"` carry the disabled hub, whose recording
    /// methods reject before touching a lock — the round loop stays
    /// allocation-free.
    pub trace: Arc<crate::trace::TraceHub>,
}

impl JobRuntime {
    pub fn rounds(&self) -> u64 {
        self.spec.rounds
    }
}

/// Per-worker execution environment: config + joined channels + clock.
pub struct WorkerEnv {
    pub cfg: WorkerConfig,
    pub job: Arc<JobRuntime>,
    pub clock: Arc<Mutex<VClock>>,
    pub chans: BTreeMap<String, ChannelHandle>,
    pub rng: Rng,
    /// Execution mode shared by this worker's channel handles — kept so
    /// channels joined *after* startup ([`Self::join_channel`]) wait the
    /// same way as the ones joined at build.
    pub park: Arc<WorkerPark>,
}

impl WorkerEnv {
    /// Join all channels listed in the worker config and build the env in
    /// blocking mode (thread-per-worker execution, direct tests).
    pub fn new(cfg: WorkerConfig, job: Arc<JobRuntime>) -> Result<Self> {
        Self::with_park(cfg, job, WorkerPark::blocking(RECV_TIMEOUT))
    }

    /// Join all channels listed in the worker config and build the env.
    /// The park decides how this worker's receives wait: blocking Condvar
    /// (with a configurable timeout) or cooperative yield to the
    /// [`crate::sched`] worker fabric.
    pub fn with_park(
        cfg: WorkerConfig,
        job: Arc<JobRuntime>,
        park: Arc<WorkerPark>,
    ) -> Result<Self> {
        let clock = Arc::new(Mutex::new(VClock::default()));
        let mut chans = BTreeMap::new();
        for (ch_name, group) in &cfg.channels {
            let chan = job
                .spec
                .channel(ch_name)
                .with_context(|| format!("worker '{}' references unknown channel '{ch_name}'", cfg.id))?;
            let handle = job.chan_mgr.join_with_park(
                ch_name,
                group,
                &cfg.id,
                &cfg.role,
                chan.backend,
                clock.clone(),
                park.clone(),
            )?;
            chans.insert(ch_name.clone(), handle);
        }
        // FNV-1a id mixing: a plain 131-polynomial fold is linear, so
        // distinct ids could fold to the same tag and share a stream (see
        // prng::fnv1a64 and its collision regression test).
        let mut seed_rng = Rng::new(job.tcfg.seed ^ 0x5EED_CAFE);
        let rng = seed_rng.fork(fnv1a64(cfg.id.as_bytes()));
        if let Some(sink) = &job.ckpt {
            sink.register_cfg(cfg.clone());
        }
        Ok(Self {
            cfg,
            job,
            clock,
            chans,
            rng,
            park,
        })
    }

    pub fn chan(&self, name: &str) -> Result<&ChannelHandle> {
        self.chans
            .get(name)
            .with_context(|| format!("worker '{}' has no channel '{name}'", self.cfg.id))
    }

    /// Join an additional channel at runtime — live topology extension:
    /// e.g. the global aggregator joining the freshly created
    /// `agg-channel` when a middle tier grows in mid-job. No-op if the
    /// channel is already joined; the new handle shares this worker's
    /// clock and park.
    pub fn join_channel(&mut self, name: &str, group: &str) -> Result<()> {
        if self.chans.contains_key(name) {
            return Ok(());
        }
        let chan = self
            .job
            .spec
            .channel(name)
            .with_context(|| format!("worker '{}' joining unknown channel '{name}'", self.cfg.id))?;
        let handle = self.job.chan_mgr.join_with_park(
            name,
            group,
            &self.cfg.id,
            &self.cfg.role,
            chan.backend,
            self.clock.clone(),
            self.park.clone(),
        )?;
        self.chans.insert(name.to_string(), handle);
        Ok(())
    }

    pub fn now(&self) -> VTime {
        self.clock.lock().unwrap().now()
    }

    /// Charge local compute against the virtual clock per the job's time
    /// model; returns the charged virtual duration.
    pub fn charge(&self, measured: Instant) -> VTime {
        let dt = self.job.time_model.charge(measured.elapsed().as_micros());
        self.clock.lock().unwrap().advance(dt);
        dt
    }

    /// This worker's dataset shard (data consumers only).
    pub fn shard(&self) -> Result<Arc<Dataset>> {
        let name = self
            .cfg
            .dataset
            .as_ref()
            .with_context(|| format!("worker '{}' has no dataset", self.cfg.id))?;
        self.job
            .shards
            .get(name)
            .cloned()
            .with_context(|| format!("dataset '{name}' not materialised"))
    }
}

/// A runnable role program (a tasklet chain bound to its context).
///
/// Programs are *steppable*: [`step`](Program::step) drives the chain
/// until it completes or suspends at a yielding receive, which is what the
/// cooperative worker fabric polls. [`run`](Program::run) is the blocking
/// convenience (a worker whose receives block never suspends).
pub trait Program: Send {
    /// Drive the program until completion or a cooperative yield.
    fn step(&mut self) -> Result<StepStatus>;

    /// Run to completion (blocking execution mode).
    fn run(&mut self) -> Result<()> {
        match self.step()? {
            StepStatus::Done => Ok(()),
            StepStatus::Pending => {
                bail!("worker program yielded outside a cooperative scheduler")
            }
        }
    }
}

struct ChainProgram<C: Send> {
    composer: crate::workflow::Composer<C>,
    ctx: C,
    /// Resume path of the suspended tasklet (empty = start of chain).
    cursor: Vec<usize>,
}

impl<C: Send> Program for ChainProgram<C> {
    fn step(&mut self) -> Result<StepStatus> {
        let resume = std::mem::take(&mut self.cursor);
        let (status, pend) = self.composer.step_from(&resume, &mut self.ctx)?;
        if status == StepStatus::Pending {
            self.cursor = pend;
        }
        Ok(status)
    }
}

/// Bind a tasklet chain to its context as a runnable [`Program`] — the
/// last step of assembling a role program, built-in or custom (the Role
/// SDK's equivalent of instantiating a derived role class).
pub fn chain_program<C: Send + 'static>(
    composer: crate::workflow::Composer<C>,
    ctx: C,
) -> Box<dyn Program> {
    Box::new(ChainProgram {
        composer,
        ctx,
        cursor: Vec::new(),
    })
}

/// How many of `alive` children an aggregation must hear from before it
/// proceeds: `ceil(quorum * alive)`, clamped to `[1, alive]` (and `0`
/// when nobody is left — the round then skips aggregation rather than
/// blocking forever). Quorum 1.0 (the default) is the classic full
/// barrier; fractions trade straggler latency for deterministic
/// reproducibility (see DESIGN.md "Topology extension lifecycle").
pub(crate) fn quorum_target(alive: usize, quorum: f64) -> usize {
    if alive == 0 {
        return 0;
    }
    ((alive as f64 * quorum).ceil() as usize).clamp(1, alive)
}

/// Checkpoint encoding for a float vector. `f32 → f64` widening is exact
/// and the JSON dump prints shortest-roundtrip `f64`, so every value
/// survives the store round-trip byte-exact.
pub(crate) fn floats_to_json(v: &[f32]) -> crate::json::Json {
    crate::json::Json::Arr(v.iter().map(|x| crate::json::Json::Num(*x as f64)).collect())
}

/// Inverse of [`floats_to_json`]; a missing/malformed value decodes empty,
/// which restore paths reject via length checks.
pub(crate) fn floats_from_json(j: &crate::json::Json) -> Vec<f32> {
    match j.as_arr() {
        Some(a) => a.iter().map(|x| x.as_f64().unwrap_or(0.0) as f32).collect(),
        None => Vec::new(),
    }
}

/// Test fixtures shared by unit tests across modules.
#[cfg(test)]
pub mod tests_support {
    use super::*;
    use crate::channel::Backend;
    use crate::net::VirtualNet;
    use crate::registry::Registry;
    use crate::runtime::MockCompute;
    use crate::tag::expand;
    use crate::topo;

    /// A tiny C-FL job runtime over the mock compute (2 trainers).
    pub fn tiny_job_runtime() -> (Arc<JobRuntime>, Vec<WorkerConfig>) {
        let spec = topo::classical(2, Backend::InProc).build().to_json();
        let spec = JobSpec::from_json(&spec).unwrap();
        let cfgs = expand(&spec, &Registry::single_box()).unwrap();
        let (shards, test) =
            crate::data::make_federated(0, 2, 64, 32, crate::data::Partition::Iid, 0.5);
        let mut shard_map = HashMap::new();
        for (d, s) in spec.datasets.iter().zip(shards) {
            shard_map.insert(d.name.clone(), Arc::new(s));
        }
        let compute: Arc<dyn Compute> = Arc::new(MockCompute::default_mlp());
        let init_flat = Arc::new(vec![0f32; compute.d_pad()]);
        let pool = TensorPool::new(compute.d_pad());
        let flavor = spec.resolved_flavor();
        let job = Arc::new(JobRuntime {
            spec,
            chan_mgr: ChannelManager::new(Arc::new(VirtualNet::default())),
            compute,
            tcfg: TrainingConfig::default(),
            metrics: Arc::new(MetricsHub::new()),
            shards: shard_map,
            test_set: Arc::new(test),
            time_model: ComputeTimeModel::Free,
            init_flat,
            pool,
            timeline: TopologyTimeline::empty(),
            programs: Arc::new(RoleRegistry::builtin()),
            flavor,
            codec: None,
            ckpt: None,
            restore: None,
            trace: crate::trace::TraceHub::disabled(),
        });
        (job, cfgs)
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::tiny_job_runtime as mini_job;
    use super::*;

    #[test]
    fn env_joins_declared_channels() {
        let (job, cfgs) = mini_job();
        let trainer_cfg = cfgs.iter().find(|c| c.role == "trainer").unwrap().clone();
        let env = WorkerEnv::new(trainer_cfg, job).unwrap();
        assert!(env.chan("param-channel").is_ok());
        assert!(env.chan("nope").is_err());
        assert!(env.shard().is_ok());
    }

    #[test]
    fn env_rngs_differ_per_worker() {
        let (job, cfgs) = mini_job();
        let mut a = WorkerEnv::new(cfgs[0].clone(), job.clone()).unwrap();
        let mut b = WorkerEnv::new(cfgs[1].clone(), job).unwrap();
        assert_ne!(a.rng.next_u64(), b.rng.next_u64());
    }

    #[test]
    fn registry_builds_every_expanded_worker() {
        let (job, cfgs) = mini_job();
        for cfg in cfgs {
            let env = WorkerEnv::new(cfg, job.clone()).unwrap();
            assert!(job.programs.build(env).is_ok());
        }
    }

    #[test]
    fn quorum_target_bounds() {
        assert_eq!(quorum_target(0, 1.0), 0);
        assert_eq!(quorum_target(4, 1.0), 4);
        assert_eq!(quorum_target(4, 0.5), 2);
        assert_eq!(quorum_target(3, 0.5), 2); // ceil, not floor
        assert_eq!(quorum_target(5, 0.01), 1); // never waits on nobody
    }

    #[test]
    fn join_channel_is_idempotent_and_validated() {
        let (job, cfgs) = mini_job();
        let trainer_cfg = cfgs.iter().find(|c| c.role == "trainer").unwrap().clone();
        let mut env = WorkerEnv::new(trainer_cfg, job).unwrap();
        // already joined: no-op
        env.join_channel("param-channel", "default").unwrap();
        // unknown channels are rejected
        assert!(env.join_channel("ghost-channel", "default").is_err());
    }

    #[test]
    fn unknown_role_rejected() {
        let (job, cfgs) = mini_job();
        let mut cfg = cfgs[0].clone();
        cfg.role = "mystery".into();
        // need matching channels; reuse trainer's
        let env = WorkerEnv::new(cfg, job.clone()).unwrap();
        assert!(job.programs.build(env).is_err());
    }
}
