//! Real multi-process transport: binary wire format, TCP substrate, and
//! the process-spanning deployer.
//!
//! Everything below this module exists to make one sentence true: *a job
//! running `backend: "tcp"` across several OS processes produces a
//! byte-identical final report to the same job on the in-process virtual
//! fabric.* The pieces:
//!
//! * [`frame`] — the length-prefixed, checksummed binary encoding of a
//!   channel delivery ([`encode_into`] / [`decode_from`]),
//! * [`slab`] — recycled encode buffers ([`BufSlab`]), keeping the
//!   steady-state encode path allocation-free for pooled float payloads,
//! * [`tcp`] — [`TcpBackend`], the [`crate::channel::Transport`]
//!   implementation: per-peer connection registry, stream reassembly,
//!   peer-death → `Departed` mapping,
//! * [`proc`] — [`ProcDeployer`] (parent) and [`worker_main`] (the
//!   `flame worker` child host): worker partitioning, the interning
//!   handshake, and the merged job report.
//!
//! See DESIGN.md §"Wire transport & multi-process deploy" for the frame
//! layout diagram and the determinism argument.

pub mod frame;
pub mod proc;
pub mod slab;
pub mod tcp;

pub use frame::{decode_from, encode_into, WireFrame};
pub use proc::{worker_main, ProcDeployer, ProcOpts, ProcReport};
pub use slab::{BufSlab, SlabStats};
pub use tcp::TcpBackend;
