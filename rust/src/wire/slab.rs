//! Recycled encode buffers — the wire path's answer to per-frame
//! allocation tax.
//!
//! Serializing a message needs a scratch buffer; allocating one per frame
//! would reintroduce exactly the per-message allocation churn PR 5
//! removed from the in-process fabric. A [`BufSlab`] keeps a small pool
//! of retired pages (in the style of timely-dataflow's `bytes` crate):
//! `take` hands out a cleared page with its old capacity intact, so once
//! a page has grown to the deployment's largest frame size, steady-state
//! encodes allocate nothing — pinned by the `alloc_regression` suite.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Pages retained beyond this are dropped instead of pooled: a burst of
/// concurrent encodes must not turn into a permanent high-water mark.
const MAX_POOLED: usize = 64;

/// Counters for slab behaviour (observable from benches and tests).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SlabStats {
    /// `take` calls served from the pool (no allocation).
    pub reused: u64,
    /// `take` calls that had to allocate a fresh page.
    pub fresh: u64,
}

/// A pool of recycled byte pages for frame encoding.
#[derive(Debug, Default)]
pub struct BufSlab {
    pages: Mutex<Vec<Vec<u8>>>,
    reused: AtomicU64,
    fresh: AtomicU64,
}

impl BufSlab {
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty page, recycled when possible. The returned page keeps
    /// whatever capacity it grew to in earlier lives — the warm-up frames
    /// pay the growth, the steady state rides it.
    pub fn take(&self) -> Vec<u8> {
        if let Some(page) = self.pages.lock().unwrap().pop() {
            self.reused.fetch_add(1, Ordering::Relaxed);
            return page;
        }
        self.fresh.fetch_add(1, Ordering::Relaxed);
        Vec::new()
    }

    /// Return a page to the pool. Contents are cleared; capacity is kept.
    /// Pages past the pool cap are dropped (burst protection).
    pub fn recycle(&self, mut page: Vec<u8>) {
        page.clear();
        let mut g = self.pages.lock().unwrap();
        if g.len() < MAX_POOLED {
            g.push(page);
        }
    }

    /// Pool behaviour so far.
    pub fn stats(&self) -> SlabStats {
        SlabStats {
            reused: self.reused.load(Ordering::Relaxed),
            fresh: self.fresh.load(Ordering::Relaxed),
        }
    }

    /// Pages currently pooled.
    pub fn pooled(&self) -> usize {
        self.pages.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycled_pages_keep_capacity() {
        let slab = BufSlab::new();
        let mut p = slab.take();
        p.extend_from_slice(&[0u8; 4096]);
        let cap = p.capacity();
        slab.recycle(p);
        let p2 = slab.take();
        assert!(p2.is_empty());
        assert_eq!(p2.capacity(), cap, "capacity must survive recycling");
        assert_eq!(slab.stats(), SlabStats { reused: 1, fresh: 1 });
    }

    #[test]
    fn pool_is_bounded() {
        let slab = BufSlab::new();
        let pages: Vec<Vec<u8>> = (0..2 * MAX_POOLED).map(|_| slab.take()).collect();
        for p in pages {
            slab.recycle(p);
        }
        assert_eq!(slab.pooled(), MAX_POOLED, "burst must not pin pages forever");
    }
}
