//! Length-delimited binary frame format for [`Message`].
//!
//! One frame carries one channel delivery between OS processes:
//!
//! ```text
//! offset  size  field
//!      0     4  magic  "FLMW" (little-endian u32)
//!      4     1  version (currently 1)
//!      5     1  payload tag (0 empty, 1 floats, 2 json,
//!               3 enc-f32, 4 enc-int8, 5 enc-topk)
//!      6     2  reserved (zero)
//!      8     8  route    — the interner's packed u64 (scope,channel,group)
//!     16     8  arrival  — virtual arrival time, computed on the sender
//!     24     8  round
//!     32     2  len(from)   + that many UTF-8 bytes
//!      .     2  len(to)     + that many UTF-8 bytes
//!      .     2  len(kind)   + that many UTF-8 bytes
//!      .     4  len(meta)   + compact-JSON bytes (0 = null metadata)
//!      .     4  len(body)   + payload bytes (see below)
//!      .     8  checksum — FNV-1a 64 over every preceding byte
//! ```
//!
//! Payload bodies: `Floats` and `Encoded::F32` are raw little-endian f32
//! slabs (bit-exact round-trip — model updates must not change across the
//! wire); `Json` is the compact dump; `Int8` is `u64 d · f32 scale · d
//! quantized bytes`; `TopK` is `u64 d · u32 k · k u32 indices · k f32
//! values`.
//!
//! The route rides as the raw packed word, which is only meaningful
//! because every process in a deployment replays the same interning table
//! at join ([`crate::intern::apply_names`]); the sender and kind names
//! ride as strings since nothing orders by their symbols.
//!
//! [`encode_into`] writes into a caller-supplied buffer (recycled through
//! a [`super::BufSlab`]), so steady-state encodes of pooled float
//! payloads allocate nothing — pinned by the `alloc_regression` suite.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::channel::{Message, Payload};
use crate::intern::{atom, Route};
use crate::json::Json;
use crate::net::VTime;
use crate::prng::fnv1a64;
use crate::runtime::EncodedUpdate;

/// `"FLMW"` as a little-endian word.
pub const MAGIC: u32 = u32::from_le_bytes(*b"FLMW");
/// Current frame format version.
pub const VERSION: u8 = 1;

const TAG_EMPTY: u8 = 0;
const TAG_FLOATS: u8 = 1;
const TAG_JSON: u8 = 2;
const TAG_ENC_F32: u8 = 3;
const TAG_ENC_INT8: u8 = 4;
const TAG_ENC_TOPK: u8 = 5;

/// Smallest well-formed frame: fixed header + four zero-length fields +
/// checksum.
const MIN_FRAME: usize = 4 + 1 + 1 + 2 + 8 + 8 + 8 + 2 + 2 + 4 + 4 + 8;

/// A decoded frame: everything the channel manager's remote-delivery
/// entry point needs to re-enqueue the message locally.
pub struct WireFrame {
    pub route: Route,
    pub from: Arc<str>,
    pub to: Arc<str>,
    pub arrival: VTime,
    pub msg: Message,
}

/// Serialize one delivery into `buf` (cleared first). The buffer keeps
/// its capacity across calls, so encoding into a recycled page allocates
/// nothing once the page has grown to the working frame size — except
/// for non-null metadata, whose compact-JSON dump builds a `String`.
#[allow(clippy::too_many_arguments)]
pub fn encode_into(
    buf: &mut Vec<u8>,
    route: Route,
    from: &str,
    to: &str,
    arrival: VTime,
    msg: &Message,
) -> Result<()> {
    buf.clear();
    let tag = match &msg.payload {
        Payload::Empty => TAG_EMPTY,
        Payload::Floats(_) => TAG_FLOATS,
        Payload::Json(_) => TAG_JSON,
        Payload::Encoded(e) => match &**e {
            EncodedUpdate::F32 { .. } => TAG_ENC_F32,
            EncodedUpdate::Int8 { .. } => TAG_ENC_INT8,
            EncodedUpdate::TopK { .. } => TAG_ENC_TOPK,
        },
    };
    buf.extend_from_slice(&MAGIC.to_le_bytes());
    buf.push(VERSION);
    buf.push(tag);
    buf.extend_from_slice(&0u16.to_le_bytes());
    buf.extend_from_slice(&route.raw().to_le_bytes());
    buf.extend_from_slice(&arrival.to_le_bytes());
    buf.extend_from_slice(&msg.round.to_le_bytes());
    put_str16(buf, from)?;
    put_str16(buf, to)?;
    put_str16(buf, &msg.kind)?;
    if msg.meta().is_null() {
        buf.extend_from_slice(&0u32.to_le_bytes());
    } else {
        let dumped = msg.meta().dump();
        buf.extend_from_slice(&(dumped.len() as u32).to_le_bytes());
        buf.extend_from_slice(dumped.as_bytes());
    }
    let body_len_at = buf.len();
    buf.extend_from_slice(&0u32.to_le_bytes());
    match &msg.payload {
        Payload::Empty => {}
        Payload::Floats(v) => put_f32s(buf, v),
        Payload::Json(j) => buf.extend_from_slice(j.dump().as_bytes()),
        Payload::Encoded(e) => match &**e {
            EncodedUpdate::F32 { data } => put_f32s(buf, data),
            EncodedUpdate::Int8 { d, scale, q } => {
                buf.extend_from_slice(&(*d as u64).to_le_bytes());
                buf.extend_from_slice(&scale.to_le_bytes());
                buf.extend(q.iter().map(|&v| v as u8));
            }
            EncodedUpdate::TopK { d, idx, val } => {
                buf.extend_from_slice(&(*d as u64).to_le_bytes());
                buf.extend_from_slice(&(idx.len() as u32).to_le_bytes());
                for i in idx {
                    buf.extend_from_slice(&i.to_le_bytes());
                }
                put_f32s(buf, val);
            }
        },
    }
    let body_len = (buf.len() - body_len_at - 4) as u32;
    buf[body_len_at..body_len_at + 4].copy_from_slice(&body_len.to_le_bytes());
    let sum = fnv1a64(buf);
    buf.extend_from_slice(&sum.to_le_bytes());
    Ok(())
}

/// Deserialize a frame previously produced by [`encode_into`]. Verifies
/// magic, version and the trailing checksum, and bounds-checks every
/// length field — truncated or corrupted frames are rejected with an
/// error, never a panic.
pub fn decode_from(bytes: &[u8]) -> Result<WireFrame> {
    if bytes.len() < MIN_FRAME {
        bail!("wire frame too short: {} bytes (min {MIN_FRAME})", bytes.len());
    }
    let (head, sum_bytes) = bytes.split_at(bytes.len() - 8);
    let want = u64::from_le_bytes(sum_bytes.try_into().expect("split at 8"));
    let got = fnv1a64(head);
    if want != got {
        bail!("wire frame checksum mismatch (corrupt or truncated frame)");
    }
    let mut rd = Rd { b: head, pos: 0 };
    let magic = rd.u32()?;
    if magic != MAGIC {
        bail!("bad wire magic {magic:#010x} (expected {MAGIC:#010x})");
    }
    let version = rd.u8()?;
    if version != VERSION {
        bail!("unsupported wire version {version} (speak version {VERSION})");
    }
    let tag = rd.u8()?;
    let _reserved = rd.u16()?;
    let route = Route::from_raw(rd.u64()?);
    let arrival = rd.u64()?;
    let round = rd.u64()?;
    let from = atom(rd.str16()?);
    let to = atom(rd.str16()?);
    let kind = rd.str16()?.to_string();
    let meta_len = rd.u32()? as usize;
    let meta = if meta_len == 0 {
        None
    } else {
        let raw = std::str::from_utf8(rd.take(meta_len)?)
            .map_err(|e| anyhow::anyhow!("frame metadata is not UTF-8: {e}"))?;
        Some(Json::parse(raw)?)
    };
    let body_len = rd.u32()? as usize;
    let body = rd.take(body_len)?;
    if rd.pos != head.len() {
        bail!(
            "wire frame has {} trailing byte(s) after the payload body",
            head.len() - rd.pos
        );
    }
    let payload = match tag {
        TAG_EMPTY => {
            if !body.is_empty() {
                bail!("empty-payload frame carries {} body bytes", body.len());
            }
            Payload::Empty
        }
        TAG_FLOATS => Payload::Floats(Arc::new(get_f32s(body)?)),
        TAG_JSON => {
            let raw = std::str::from_utf8(body)
                .map_err(|e| anyhow::anyhow!("json payload is not UTF-8: {e}"))?;
            Payload::Json(Json::parse(raw)?)
        }
        TAG_ENC_F32 => Payload::Encoded(Arc::new(EncodedUpdate::F32 {
            data: get_f32s(body)?,
        })),
        TAG_ENC_INT8 => {
            let mut rd = Rd { b: body, pos: 0 };
            let d = rd.u64()? as usize;
            let scale = f32::from_le_bytes(rd.take(4)?.try_into().expect("4 bytes"));
            let q: Vec<i8> = rd.take(body.len() - rd.pos)?.iter().map(|&b| b as i8).collect();
            Payload::Encoded(Arc::new(EncodedUpdate::Int8 { d, scale, q }))
        }
        TAG_ENC_TOPK => {
            let mut rd = Rd { b: body, pos: 0 };
            let d = rd.u64()? as usize;
            let k = rd.u32()? as usize;
            let mut idx = Vec::with_capacity(k);
            for _ in 0..k {
                idx.push(rd.u32()?);
            }
            let val = get_f32s(rd.take(body.len() - rd.pos)?)?;
            if val.len() != k {
                bail!("top-k frame: {k} indices but {} values", val.len());
            }
            Payload::Encoded(Arc::new(EncodedUpdate::TopK { d, idx, val }))
        }
        other => bail!("unknown wire payload tag {other}"),
    };
    let mut msg = Message::new(kind, round, payload);
    if let Some(m) = meta {
        msg = msg.with_meta(m);
    }
    Ok(WireFrame {
        route,
        from,
        to,
        arrival,
        msg,
    })
}

fn put_str16(buf: &mut Vec<u8>, s: &str) -> Result<()> {
    if s.len() > u16::MAX as usize {
        bail!("wire string field of {} bytes exceeds the u16 length prefix", s.len());
    }
    buf.extend_from_slice(&(s.len() as u16).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
    Ok(())
}

fn put_f32s(buf: &mut Vec<u8>, v: &[f32]) {
    for x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn get_f32s(body: &[u8]) -> Result<Vec<f32>> {
    if body.len() % 4 != 0 {
        bail!("float slab of {} bytes is not a multiple of 4", body.len());
    }
    Ok(body
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().expect("chunks_exact(4)")))
        .collect())
}

/// Bounds-checked little-endian reader over one frame.
struct Rd<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!(
                "wire frame truncated: need {n} byte(s) at offset {}, have {}",
                self.pos,
                self.b.len() - self.pos
            );
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("2 bytes")))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn str16(&mut self) -> Result<&'a str> {
        let n = self.u16()? as usize;
        std::str::from_utf8(self.take(n)?)
            .map_err(|e| anyhow::anyhow!("wire string field is not UTF-8: {e}"))
    }
}
