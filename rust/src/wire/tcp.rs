//! TCP substrate: per-peer connection registry, length-prefixed stream
//! reassembly, and delivery into the receiving process's channel fabric.
//!
//! Topology is a directed mesh over the deployment's OS processes: for
//! every ordered pair `(i, j)` process `i` owns exactly one outbound
//! stream to `j`, opened at startup with a small hello identifying the
//! sender. One stream per ordered pair is what makes determinism cheap:
//! TCP preserves order within a stream, so frames from one sender reach
//! the receiving fabric in the sender's program order — per-sender FIFO,
//! the only property the `(arrival, sender, seq)` message selection needs
//! (see [`Transport::ship`]).
//!
//! Virtual arrival times are computed on the **sending** process (the
//! transfer functions are pure, both sides hold the same network model)
//! and ride inside the frame, so a multi-process run charges exactly the
//! virtual-time arithmetic an in-process `backend: "tcp"` run charges —
//! which is what lets the in-process run serve as the byte-parity oracle.
//!
//! Peer death is not a send error. Shipping to a dead peer silently
//! drops (the frame could equally have died in flight); the **receiving
//! side** of a broken stream maps the disconnect onto the existing
//! [`ChannelManager::evict`] path, so every surviving process sees the
//! dead process's workers leave through the same `Departed`/quorum
//! machinery a graceful leave uses.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::channel::{ChannelManager, Message, Transport};
use crate::intern::Route;
use crate::net::VTime;

use super::frame::{decode_from, encode_into};
use super::slab::{BufSlab, SlabStats};

/// First word of the per-connection hello (`"FLHI"` little-endian).
const HELLO_MAGIC: u32 = u32::from_le_bytes(*b"FLHI");

/// Upper bound on a single frame's length prefix. Anything larger is a
/// corrupt or hostile stream, not a model update (256 MiB ≈ a 64M-param
/// f32 payload with room to spare).
const MAX_FRAME: usize = 256 << 20;

/// The TCP transport: one outbound stream per peer process, a shared
/// encode-buffer slab, and the worker→process placement map.
pub struct TcpBackend {
    self_proc: usize,
    /// Outbound stream per process index; `None` for self and for peers
    /// that died (or were never connected).
    peers: Vec<Mutex<Option<TcpStream>>>,
    /// Worker id → hosting process index, identical on every process.
    proc_of: HashMap<String, usize>,
    slab: BufSlab,
    /// Set on graceful teardown so reader threads stop mapping stream
    /// EOFs onto evictions.
    shutdown: AtomicBool,
}

impl TcpBackend {
    /// A backend for process `self_proc` of `n_procs`, with the shared
    /// placement map. No connections yet — call [`Self::connect_peers`].
    pub fn new(self_proc: usize, n_procs: usize, proc_of: HashMap<String, usize>) -> Arc<Self> {
        Arc::new(Self {
            self_proc,
            peers: (0..n_procs).map(|_| Mutex::new(None)).collect(),
            proc_of,
            slab: BufSlab::new(),
            shutdown: AtomicBool::new(false),
        })
    }

    /// Open the outbound half of the mesh: one connection to every other
    /// process, each greeted with `HELLO_MAGIC` + this process's index.
    pub fn connect_peers(&self, addrs: &[String]) -> Result<()> {
        if addrs.len() != self.peers.len() {
            bail!(
                "peer address list has {} entries for {} processes",
                addrs.len(),
                self.peers.len()
            );
        }
        for (p, addr) in addrs.iter().enumerate() {
            if p == self.self_proc {
                continue;
            }
            let stream = TcpStream::connect(addr)
                .with_context(|| format!("connecting to peer process {p} at {addr}"))?;
            stream.set_nodelay(true).ok();
            let mut hello = [0u8; 8];
            hello[..4].copy_from_slice(&HELLO_MAGIC.to_le_bytes());
            hello[4..].copy_from_slice(&(self.self_proc as u32).to_le_bytes());
            let mut s = stream;
            s.write_all(&hello)
                .with_context(|| format!("greeting peer process {p}"))?;
            *self.peers[p].lock().unwrap() = Some(s);
        }
        Ok(())
    }

    /// Stop mapping stream teardown onto evictions: the deployment is
    /// exiting on purpose.
    pub fn begin_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Encode-buffer pool counters (benches assert steady-state reuse).
    pub fn slab_stats(&self) -> SlabStats {
        self.slab.stats()
    }

    /// Accept inbound streams and pump each into `mgr` on its own
    /// thread. `roster[p]` lists the workers hosted by process `p`; when
    /// a peer's stream breaks before shutdown, its whole roster is
    /// evicted so collects re-quorum and waiters see `Departed`.
    pub fn serve(
        self: &Arc<Self>,
        listener: TcpListener,
        mgr: Arc<ChannelManager>,
        roster: Arc<Vec<Vec<String>>>,
    ) {
        let backend = Arc::clone(self);
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { break };
                stream.set_nodelay(true).ok();
                let backend = Arc::clone(&backend);
                let mgr = Arc::clone(&mgr);
                let roster = Arc::clone(&roster);
                std::thread::spawn(move || {
                    if let Err(e) = backend.pump(stream, &mgr, &roster) {
                        if !backend.shutdown.load(Ordering::SeqCst) {
                            eprintln!("wire: inbound stream ended: {e:#}");
                        }
                    }
                });
                if backend.shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
        });
    }

    /// Reassemble frames off one inbound stream until it breaks, then
    /// (unless shutting down) evict the dead peer's workers.
    fn pump(
        &self,
        mut stream: TcpStream,
        mgr: &Arc<ChannelManager>,
        roster: &Arc<Vec<Vec<String>>>,
    ) -> Result<()> {
        let mut hello = [0u8; 8];
        stream.read_exact(&mut hello).context("reading connection hello")?;
        let magic = u32::from_le_bytes(hello[..4].try_into().expect("4 bytes"));
        if magic != HELLO_MAGIC {
            bail!("inbound stream opened with bad hello magic {magic:#010x}");
        }
        let peer = u32::from_le_bytes(hello[4..].try_into().expect("4 bytes")) as usize;
        if peer >= roster.len() {
            bail!("inbound hello names process {peer}, deployment has {}", roster.len());
        }
        let mut frame: Vec<u8> = Vec::new();
        let result = loop {
            let mut len_bytes = [0u8; 4];
            if let Err(e) = stream.read_exact(&mut len_bytes) {
                break Err(anyhow::Error::from(e).context(format!("stream from process {peer}")));
            }
            let len = u32::from_le_bytes(len_bytes) as usize;
            if len > MAX_FRAME {
                break Err(anyhow::anyhow!(
                    "process {peer} sent a {len}-byte frame (cap {MAX_FRAME})"
                ));
            }
            frame.clear();
            frame.resize(len, 0);
            if let Err(e) = stream.read_exact(&mut frame) {
                break Err(anyhow::Error::from(e).context(format!("stream from process {peer}")));
            }
            match decode_from(&frame) {
                Ok(f) => {
                    if let Err(e) = mgr.deliver_remote(f.route, &f.from, &f.to, f.arrival, f.msg) {
                        eprintln!("wire: dropping undeliverable frame from process {peer}: {e:#}");
                    }
                }
                Err(e) => break Err(e.context(format!("decoding frame from process {peer}"))),
            }
        };
        if !self.shutdown.load(Ordering::SeqCst) {
            for w in &roster[peer] {
                mgr.evict(w, 0);
            }
        }
        result
    }
}

impl Transport for TcpBackend {
    fn ship(
        &self,
        route: Route,
        from: &Arc<str>,
        to: &str,
        arrival: VTime,
        msg: &Message,
    ) -> Result<()> {
        let &proc = self
            .proc_of
            .get(to)
            .with_context(|| format!("wire ship to '{to}', which is in no process's roster"))?;
        if proc == self.self_proc {
            bail!("wire ship to '{to}', which this process hosts locally");
        }
        let mut page = self.slab.take();
        encode_into(&mut page, route, from, to, arrival, msg)?;
        {
            let mut slot = self.peers[proc].lock().unwrap();
            if let Some(stream) = slot.as_mut() {
                let len = (page.len() as u32).to_le_bytes();
                // Dead peers surface through the receive-side evict path,
                // never through send errors (the frame could equally have
                // died in flight after a successful write).
                if stream.write_all(&len).and_then(|()| stream.write_all(&page)).is_err() {
                    *slot = None;
                }
            }
        }
        self.slab.recycle(page);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "tcp"
    }
}
