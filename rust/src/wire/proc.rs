//! Multi-process deployment: partition one job's expanded workers across
//! OS child processes and drive them over the TCP substrate.
//!
//! The parent ([`ProcDeployer`]) expands the TAG, round-robins the
//! workers over `flame worker --listen` child processes, and coordinates
//! them over a line-oriented stdin/stdout control protocol (`WIRE `-
//! prefixed JSON lines from the child; bare JSON command lines from the
//! parent):
//!
//! 1. every child binds its listener and reports its port,
//! 2. the parent ships one **hello** to each child: its process index,
//!    every process's address and worker roster, the full interning
//!    table, the job spec, and the data/time recipe ([`ProcOpts`]),
//! 3. each child replays the name table **before interning anything
//!    else** ([`crate::intern::apply_names`]), prepares the job, shadow-
//!    joins every non-local worker ([`ChannelManager::join_remote`]),
//!    opens its outbound mesh connections, deploys its local workers, and
//!    reports **ready**,
//! 4. on **start** every child runs its cooperative pool to completion
//!    and reports **done** with its metrics snapshot,
//! 5. the parent merges the snapshots and reaps every child.
//!
//! ## Why the merged report is byte-identical to an in-process run
//!
//! Virtual arrival times are computed on the sender with the same pure
//! transfer functions and the same default network model an in-process
//! `backend: "tcp"` run uses, message selection breaks exact ties in
//! per-sender FIFO order (which one TCP stream per ordered process pair
//! preserves), every compared series is written by a single worker (the
//! global aggregator), and traffic counters are incremented on the
//! sending side — so per-process sums add to the in-process totals.
//! Concatenating the children's metrics snapshots therefore reproduces
//! the oracle's series exactly; `tests/tcp_parity.rs` pins this.

use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::channel::ChannelManager;
use crate::control::{prepare_job, JobOptions};
use crate::data::Partition;
use crate::deploy::{Deployer, PodStatus, SimDeployer};
use crate::intern::{apply_names, export_names, sym};
use crate::json::{self, Json};
use crate::metrics::MetricsHub;
use crate::net::{VTime, VirtualNet};
use crate::notify::Notifier;
use crate::registry::Registry;
use crate::roles::RoleRegistry;
use crate::runtime::ComputeTimeModel;
use crate::tag::{expand, JobSpec};

use super::tcp::TcpBackend;

/// Per-step control-protocol timeout (and the child-side job watchdog).
/// `FLAME_WIRE_TIMEOUT_S` overrides the 120 s default — CI sets it so a
/// wedged deployment fails the suite instead of hanging it.
pub fn wire_timeout() -> Duration {
    let secs = std::env::var("FLAME_WIRE_TIMEOUT_S")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(120);
    Duration::from_secs(secs.max(1))
}

/// The serializable slice of [`JobOptions`] a worker process rebuilds —
/// the full options carry closures and trait objects, so the hello ships
/// this recipe instead and both sides call [`ProcOpts::build`]. The
/// parity oracle must run with the **same** recipe-built options.
#[derive(Debug, Clone, PartialEq)]
pub struct ProcOpts {
    pub per_shard: usize,
    pub test_n: usize,
    /// `Some(alpha)` = Dirichlet label skew, `None` = IID.
    pub dirichlet: Option<f64>,
    /// Data-generation seed.
    pub seed: u64,
    /// Fixed virtual cost per training step; `None` keeps the mock
    /// default.
    pub fixed_per_step: Option<VTime>,
}

impl Default for ProcOpts {
    fn default() -> Self {
        Self {
            per_shard: 48,
            test_n: 96,
            dirichlet: None,
            seed: 11,
            fixed_per_step: Some(2_000),
        }
    }
}

impl ProcOpts {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("per_shard", self.per_shard);
        o.insert("test_n", self.test_n);
        match self.dirichlet {
            Some(a) => o.insert("dirichlet", Json::Num(a)),
            None => o.insert("dirichlet", Json::Null),
        }
        o.insert("seed", json::from_u64_hex(self.seed));
        match self.fixed_per_step {
            Some(c) => o.insert("fixed_per_step", json::from_u64_hex(c)),
            None => o.insert("fixed_per_step", Json::Null),
        }
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            per_shard: j.get("per_shard").as_usize().context("opts recipe missing per_shard")?,
            test_n: j.get("test_n").as_usize().context("opts recipe missing test_n")?,
            dirichlet: j.get("dirichlet").as_f64(),
            seed: json::as_u64_hex(j.get("seed")).context("opts recipe missing seed")?,
            fixed_per_step: json::as_u64_hex(j.get("fixed_per_step")),
        })
    }

    /// Materialise the recipe. Deterministic: two processes building from
    /// equal recipes run byte-identical jobs.
    pub fn build(&self) -> JobOptions {
        let partition = match self.dirichlet {
            Some(a) => Partition::Dirichlet(a),
            None => Partition::Iid,
        };
        let mut opts =
            JobOptions::mock().with_data(self.per_shard, self.test_n, partition, self.seed);
        if let Some(cost) = self.fixed_per_step {
            opts = opts.with_time(ComputeTimeModel::FixedPerStep(cost));
        }
        opts
    }
}

/// What a multi-process run returns: the merged metrics and the
/// [`crate::control::JobReport`] fields the parity test byte-compares.
pub struct ProcReport {
    /// Workers in the expansion (across all processes).
    pub workers: usize,
    /// All processes' samples merged (traffic counters summed).
    pub metrics: Arc<MetricsHub>,
    pub total_bytes: u64,
    pub vtime_s: f64,
    /// Process indices killed mid-run (the fault-injection path).
    pub killed: Vec<usize>,
}

/// Deploys one job across OS child processes running `flame worker`.
pub struct ProcDeployer {
    /// Path to the `flame` binary (tests use `env!("CARGO_BIN_EXE_flame")`).
    pub bin: PathBuf,
    /// Child process count (each hosts a worker partition).
    pub procs: usize,
    /// Runner threads per child's cooperative pool.
    pub runners: usize,
}

/// Child processes with kill-on-drop: an early error in the parent can
/// never leak children past the deployer call.
struct Brood {
    children: Vec<Child>,
}

impl Drop for Brood {
    fn drop(&mut self) {
        for c in &mut self.children {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

impl ProcDeployer {
    /// Run `spec` to completion across the child processes and merge
    /// their reports. Fails if any worker fails on any process.
    pub fn run(&self, label: &str, spec: JobSpec, opts: &ProcOpts) -> Result<ProcReport> {
        self.run_inner(label, spec, opts, None)
    }

    /// [`Self::run`] with fault injection: one process whose workers are
    /// all of `victim_role` is `SIGKILL`ed at run start (after the mesh
    /// and memberships are fully established, before its pods execute).
    /// Survivors see its stream break, evict its roster through the
    /// `Departed` path, and finish on quorum — the spec must therefore
    /// set a quorum the survivors can meet.
    pub fn run_killing(
        &self,
        label: &str,
        spec: JobSpec,
        opts: &ProcOpts,
        victim_role: &str,
    ) -> Result<ProcReport> {
        self.run_inner(label, spec, opts, Some(victim_role))
    }

    fn run_inner(
        &self,
        label: &str,
        spec: JobSpec,
        opts: &ProcOpts,
        victim_role: Option<&str>,
    ) -> Result<ProcReport> {
        if self.procs < 2 {
            bail!("multi-process deploy needs at least 2 processes, got {}", self.procs);
        }
        let registry = Registry::single_box();
        let workers = expand(&spec, &registry).context("TAG expansion failed")?;
        if workers.len() < self.procs {
            bail!(
                "cannot partition {} workers across {} processes",
                workers.len(),
                self.procs
            );
        }
        // Placement: round-robin in expansion order. Determinism does not
        // care where a worker runs (arrival arithmetic is placement-
        // independent); round-robin just spreads load.
        let mut roster: Vec<Vec<String>> = vec![Vec::new(); self.procs];
        for (i, w) in workers.iter().enumerate() {
            roster[i % self.procs].push(w.id.clone());
        }
        let victim = match victim_role {
            None => None,
            Some(role) => Some(
                roster
                    .iter()
                    .position(|ws| {
                        !ws.is_empty()
                            && ws.iter().all(|id| {
                                workers.iter().any(|w| w.id == *id && w.role == role)
                            })
                    })
                    .with_context(|| {
                        format!("no process hosts only '{role}' workers; cannot inject its death")
                    })?,
            ),
        };

        // Interning handshake: make sure every route component any child
        // will pack — scope "", channel names, group names — is in the
        // table, in an order fixed by the spec and the expansion, then
        // export. Children replay this table first, so route words agree
        // across the whole deployment.
        sym("");
        for c in &spec.channels {
            sym(&c.name);
        }
        for w in &workers {
            for (ch, group) in &w.channels {
                sym(ch);
                sym(group);
            }
        }
        let names = export_names();

        // Spawn the worker hosts and pump their stdout lines into one
        // event queue.
        let mut brood = Brood {
            children: Vec::with_capacity(self.procs),
        };
        for p in 0..self.procs {
            let child = Command::new(&self.bin)
                .args(["worker", "--listen", "127.0.0.1:0"])
                .stdin(Stdio::piped())
                .stdout(Stdio::piped())
                .stderr(Stdio::inherit())
                .spawn()
                .with_context(|| format!("spawning worker process {p} ({})", self.bin.display()))?;
            brood.children.push(child);
        }
        let (tx, rx) = mpsc::channel::<(usize, Json)>();
        for (p, child) in brood.children.iter_mut().enumerate() {
            let stdout = child.stdout.take().context("child stdout was piped")?;
            let tx = tx.clone();
            std::thread::spawn(move || {
                for line in BufReader::new(stdout).lines() {
                    let Ok(line) = line else { break };
                    if let Some(rest) = line.trim().strip_prefix("WIRE ") {
                        if let Ok(j) = Json::parse(rest) {
                            let _ = tx.send((p, j));
                        }
                    }
                }
                let mut o = Json::obj();
                o.insert("ev", "eof");
                let _ = tx.send((p, Json::Obj(o)));
            });
        }
        drop(tx);
        let step = wire_timeout();

        // 1. ports
        let mut ports = vec![0u16; self.procs];
        let mut seen = 0usize;
        while seen < self.procs {
            let (p, ev) = recv_event(&rx, step, "listener ports")?;
            match ev.get("ev").as_str() {
                Some("port") => {
                    ports[p] = ev.get("port").as_usize().context("port event missing port")? as u16;
                    seen += 1;
                }
                Some("eof") => bail!("worker process {p} exited during startup"),
                other => bail!("unexpected event {other:?} from process {p} awaiting ports"),
            }
        }

        // 2. hello
        for p in 0..self.procs {
            let mut procs_j: Vec<Json> = Vec::with_capacity(self.procs);
            for (q, ws) in roster.iter().enumerate() {
                let mut e = Json::obj();
                e.insert("addr", format!("127.0.0.1:{}", ports[q]).as_str());
                e.insert("workers", Json::Arr(ws.iter().map(|w| Json::Str(w.clone())).collect()));
                procs_j.push(Json::Obj(e));
            }
            let mut hello = Json::obj();
            hello.insert("cmd", "hello");
            hello.insert("proc", p);
            hello.insert("runners", self.runners);
            hello.insert("label", label);
            hello.insert("names", Json::Arr(names.iter().map(|n| Json::Str(n.clone())).collect()));
            hello.insert("spec", spec.to_json());
            hello.insert("opts", opts.to_json());
            hello.insert("procs", Json::Arr(procs_j));
            send_line(&mut brood.children[p], &Json::Obj(hello));
        }

        // 3. ready
        let mut ready = 0usize;
        while ready < self.procs {
            let (p, ev) = recv_event(&rx, step, "readiness")?;
            match ev.get("ev").as_str() {
                Some("ready") => ready += 1,
                Some("eof") => bail!("worker process {p} exited before becoming ready"),
                other => bail!("unexpected event {other:?} from process {p} awaiting readiness"),
            }
        }

        // 4. start (and, for the fault-injection path, kill the victim
        // before it can run a single pod: every surviving process sees a
        // fully-joined peer die at run start, the worst case for the
        // Departed/quorum machinery)
        let start = {
            let mut o = Json::obj();
            o.insert("cmd", "start");
            Json::Obj(o)
        };
        if let Some(v) = victim {
            let _ = brood.children[v].kill();
            let _ = brood.children[v].wait();
        }
        for p in 0..self.procs {
            if Some(p) != victim {
                send_line(&mut brood.children[p], &start);
            }
        }

        // 5. done
        let mut done: Vec<Option<Json>> = (0..self.procs).map(|_| None).collect();
        let want = self.procs - victim.map_or(0, |_| 1);
        let mut have = 0usize;
        while have < want {
            let (p, ev) = recv_event(&rx, step, "job completion")?;
            let kind = ev.get("ev").as_str().unwrap_or("").to_string();
            match kind.as_str() {
                "done" => {
                    if done[p].replace(ev).is_none() {
                        have += 1;
                    }
                }
                "eof" if Some(p) == victim => {}
                "eof" => bail!("worker process {p} died before reporting completion"),
                other => bail!("unexpected event '{other}' from process {p} awaiting completion"),
            }
        }

        // 6. graceful teardown: exit + reap (Brood's drop is then a no-op)
        let exit = {
            let mut o = Json::obj();
            o.insert("cmd", "exit");
            Json::Obj(o)
        };
        for child in &mut brood.children {
            send_line(child, &exit);
        }
        for (p, child) in brood.children.iter_mut().enumerate() {
            let status = child.wait().with_context(|| format!("reaping worker process {p}"))?;
            if Some(p) != victim && !status.success() {
                bail!("worker process {p} exited with {status}");
            }
        }

        // Merge: concatenate samples in process order (each compared
        // series has a single writer, so per-series order is untouched),
        // sum the traffic counters, and restore into one hub.
        let mut failures: Vec<String> = Vec::new();
        let mut samples: Vec<Json> = Vec::new();
        let mut bytes = 0u64;
        let mut messages = 0u64;
        for d in done.iter().flatten() {
            if d.get("ok").as_bool() != Some(true) {
                if let Some(fs) = d.get("failures").as_arr() {
                    failures.extend(fs.iter().filter_map(|f| f.as_str().map(String::from)));
                }
            }
            let m = d.get("metrics");
            if let Some(rows) = m.get("samples").as_arr() {
                samples.extend(rows.iter().cloned());
            }
            bytes += json::as_u64_hex(m.get("bytes")).unwrap_or(0);
            messages += json::as_u64_hex(m.get("messages")).unwrap_or(0);
        }
        if !failures.is_empty() {
            bail!("multi-process job failed:\n  {}", failures.join("\n  "));
        }
        let merged = {
            let mut o = Json::obj();
            o.insert("samples", Json::Arr(samples));
            o.insert("bytes", json::from_u64_hex(bytes));
            o.insert("messages", json::from_u64_hex(messages));
            Json::Obj(o)
        };
        let hub = Arc::new(MetricsHub::for_job(label));
        hub.restore(&merged);
        Ok(ProcReport {
            workers: workers.len(),
            total_bytes: hub.total_bytes(),
            vtime_s: hub.last("vtime_s").unwrap_or(0.0),
            metrics: hub,
            killed: victim.into_iter().collect(),
        })
    }
}

fn recv_event(
    rx: &mpsc::Receiver<(usize, Json)>,
    step: Duration,
    awaiting: &str,
) -> Result<(usize, Json)> {
    rx.recv_timeout(step)
        .map_err(|_| anyhow!("timed out after {step:?} awaiting {awaiting} from worker processes"))
}

fn send_line(child: &mut Child, j: &Json) {
    if let Some(stdin) = child.stdin.as_mut() {
        let _ = writeln!(stdin, "{}", j.dump());
        let _ = stdin.flush();
    }
}

/// Emit one `WIRE `-prefixed protocol line on stdout (flushed — the
/// parent blocks on these).
fn emit(j: &Json) {
    let mut out = std::io::stdout().lock();
    let _ = writeln!(out, "WIRE {}", j.dump());
    let _ = out.flush();
}

fn emit_ev(ev: &str) {
    let mut o = Json::obj();
    o.insert("ev", ev);
    emit(&Json::Obj(o));
}

/// Read control lines until `want` arrives. Any *other* command is a
/// protocol error — the parent drives a strict sequence.
fn next_cmd(lines: &mut impl Iterator<Item = std::io::Result<String>>, want: &str) -> Result<Json> {
    for line in lines.by_ref() {
        let line = line.context("worker host: reading control stdin")?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let j = Json::parse(trimmed)
            .map_err(|e| anyhow!("worker host: unparseable control line: {e}"))?;
        let cmd = j.get("cmd").as_str().unwrap_or("").to_string();
        if cmd == want {
            return Ok(j);
        }
        bail!("worker host: expected control command '{want}', got '{cmd}'");
    }
    bail!("worker host: control stream closed while awaiting '{want}'");
}

/// The `flame worker --listen <addr>` entry point: host one process's
/// partition of a multi-process job, driven by a [`ProcDeployer`] parent
/// over stdin/stdout.
pub fn worker_main(listen: &str) -> Result<()> {
    let listener =
        TcpListener::bind(listen).with_context(|| format!("binding wire listener on {listen}"))?;
    let port = listener.local_addr().context("reading listener address")?.port();
    {
        let mut o = Json::obj();
        o.insert("ev", "port");
        o.insert("port", port as usize);
        emit(&Json::Obj(o));
    }

    let stdin = std::io::stdin();
    let mut lines = stdin.lock().lines();
    let hello = next_cmd(&mut lines, "hello")?;

    // The interning handshake MUST precede every other interning in this
    // process (ChannelManager::new interns the empty scope, prepare_job
    // interns worker and channel names), otherwise the route words
    // diverge and apply_names rejects the join.
    let names: Vec<String> = hello
        .get("names")
        .as_arr()
        .context("hello missing interning table")?
        .iter()
        .map(|n| n.as_str().unwrap_or("").to_string())
        .collect();
    apply_names(&names)?;

    let self_proc = hello.get("proc").as_usize().context("hello missing proc index")?;
    let runners = hello.get("runners").as_usize().unwrap_or(1);
    let label = hello.get("label").as_str().unwrap_or("wire-job").to_string();
    let spec = JobSpec::from_json(hello.get("spec")).context("worker host: parsing job spec")?;
    let opts = ProcOpts::from_json(hello.get("opts")).context("worker host: parsing opts recipe")?;
    let mut addrs: Vec<String> = Vec::new();
    let mut roster: Vec<Vec<String>> = Vec::new();
    for pj in hello.get("procs").as_arr().context("hello missing process list")? {
        addrs.push(pj.get("addr").as_str().context("process entry missing addr")?.to_string());
        roster.push(
            pj.get("workers")
                .as_arr()
                .context("process entry missing workers")?
                .iter()
                .map(|w| w.as_str().unwrap_or("").to_string())
                .collect(),
        );
    }
    if self_proc >= roster.len() {
        bail!("hello names process {self_proc}, deployment has {}", roster.len());
    }

    let chan_mgr = ChannelManager::new(Arc::new(VirtualNet::default()));
    let prepared = prepare_job(
        &label,
        spec,
        opts.build(),
        &Registry::single_box(),
        &Arc::new(RoleRegistry::builtin()),
        chan_mgr.clone(),
    )?;
    if prepared.timeline.is_elastic() {
        bail!("multi-process deploy does not support live topology events yet");
    }

    // Shadow-join every worker hosted elsewhere BEFORE deploying local
    // pods: all processes then observe the complete membership (the same
    // two-phase ordering the in-process deployers guarantee).
    let mine: HashSet<&str> = roster[self_proc].iter().map(|s| s.as_str()).collect();
    for w in &prepared.workers {
        if mine.contains(w.id.as_str()) {
            continue;
        }
        for (ch, group) in &w.channels {
            let backend = prepared
                .job
                .spec
                .channel(ch)
                .with_context(|| format!("worker '{}' references unknown channel '{ch}'", w.id))?
                .backend;
            chan_mgr.join_remote(ch, group, &w.id, &w.role, backend)?;
        }
    }

    let proc_of: HashMap<String, usize> = roster
        .iter()
        .enumerate()
        .flat_map(|(p, ws)| ws.iter().map(move |w| (w.clone(), p)))
        .collect();
    let backend = TcpBackend::new(self_proc, roster.len(), proc_of);
    chan_mgr.bind_transport(backend.clone());
    backend.serve(listener, chan_mgr.clone(), Arc::new(roster.clone()));
    // every peer is already listening (the parent collected all ports
    // before any hello went out), so the outbound mesh connects now
    backend.connect_peers(&addrs)?;

    let sim = SimDeployer::new(runners);
    // remote deliveries arrive from reader threads outside the runner
    // pool: a quiescent pool is waiting for mail, not deadlocked
    sim.sched().set_external_source(true);
    let notifier = Arc::new(Notifier::new());
    let mut pods = Vec::new();
    for w in &prepared.workers {
        if mine.contains(w.id.as_str()) {
            pods.push(sim.deploy(w.clone(), &prepared.job, notifier.clone())?);
        }
    }
    emit_ev("ready");
    next_cmd(&mut lines, "start")?;

    // Watchdog: a deployment wedged on a dead-but-undetected peer exits
    // instead of hanging forever (the parent would block on our done).
    let finished = Arc::new(AtomicBool::new(false));
    {
        let finished = finished.clone();
        std::thread::spawn(move || {
            std::thread::sleep(wire_timeout());
            if !finished.load(Ordering::SeqCst) {
                eprintln!("wire: worker host watchdog fired; aborting");
                std::process::exit(3);
            }
        });
    }
    sim.start()?;
    finished.store(true, Ordering::SeqCst);
    backend.begin_shutdown();

    let mut failures: Vec<String> = Vec::new();
    for pod in &pods {
        if let PodStatus::Failed(e) = pod.wait() {
            failures.push(format!("{}: {e}", pod.worker_id));
        }
    }
    let mut done = Json::obj();
    done.insert("ev", "done");
    done.insert("ok", failures.is_empty());
    done.insert("failures", Json::Arr(failures.into_iter().map(Json::Str).collect()));
    done.insert("metrics", prepared.job.metrics.snapshot());
    emit(&Json::Obj(done));

    // Hold the fabric (and our inbound streams) open until every process
    // is done: the parent's exit is the whole-deployment barrier.
    let _ = next_cmd(&mut lines, "exit");
    Ok(())
}
