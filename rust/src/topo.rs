//! Topology templates (paper §6.3: "topologies introduced in this paper are
//! provided as templates in Flame").
//!
//! Each builder returns the TAG + dataset spec for one of the paper's
//! Figure 1/2 topologies; users pick one, adjust sizes/backends, and submit.
//! The §6.3 transformation walkthrough (Table 4) is reproduced in
//! `examples/topology_transform.rs` by diffing these templates' JSON.

use std::collections::BTreeMap;

use crate::channel::Backend;
use crate::json::Json;
use crate::tag::{Channel, DatasetRef, JobSpec, Role};

/// Fluent builder over a prepared [`JobSpec`].
pub struct TopoBuilder {
    spec: JobSpec,
}

impl TopoBuilder {
    pub fn rounds(mut self, r: u64) -> Self {
        self.spec.rounds = r;
        self
    }

    pub fn model(mut self, m: &str) -> Self {
        self.spec.model = m.to_string();
        self
    }

    pub fn name(mut self, n: &str) -> Self {
        self.spec.name = n.to_string();
        self
    }

    pub fn hyper(mut self, h: Json) -> Self {
        self.spec.hyper = h;
        self
    }

    /// Merge one hyper-parameter into the job's hyper object.
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Self {
        let mut o = match std::mem::replace(&mut self.spec.hyper, Json::Null) {
            Json::Obj(o) => o,
            _ => Json::obj(),
        };
        o.insert(key, value);
        self.spec.hyper = Json::Obj(o);
        self
    }

    pub fn build(self) -> JobSpec {
        self.spec
    }
}

fn ga(entries: &[&[(&str, &str)]]) -> Vec<BTreeMap<String, String>> {
    entries
        .iter()
        .map(|e| {
            e.iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect()
        })
        .collect()
}

fn datasets(n: usize, group_of: impl Fn(usize) -> String) -> Vec<DatasetRef> {
    (0..n)
        .map(|i| DatasetRef {
            name: format!("d{i}"),
            group: group_of(i),
            realm: "*".to_string(),
            url: format!("synth://shard/{i}"),
        })
        .collect()
}

fn channel(
    name: &str,
    pair: (&str, &str),
    group_by: &[String],
    backend: Backend,
    func_tags: &[(&str, &[&str])],
) -> Channel {
    Channel {
        name: name.to_string(),
        pair: (pair.0.to_string(), pair.1.to_string()),
        group_by: group_by.to_vec(),
        func_tags: func_tags
            .iter()
            .map(|(r, ts)| (r.to_string(), ts.iter().map(|t| t.to_string()).collect()))
            .collect(),
        backend,
        substrate: backend.name().to_string(),
    }
}

fn groups(n: usize) -> Vec<String> {
    (0..n).map(|i| format!("group{i}")).collect()
}

/// Classical FL (Fig 1b / 2c): trainers <-> one global aggregator.
pub fn classical(n_trainers: usize, backend: Backend) -> TopoBuilder {
    let spec = JobSpec {
        name: "cfl".into(),
        model: "mlp".into(),
        rounds: 10,
        roles: vec![
            Role {
                name: "trainer".into(),
                replica: 1,
                is_data_consumer: true,
                group_association: ga(&[&[("param-channel", "default")]]),
                program: None,
            },
            Role {
                name: "global-aggregator".into(),
                replica: 1,
                is_data_consumer: false,
                group_association: ga(&[&[("param-channel", "default")]]),
                program: None,
            },
        ],
        channels: vec![channel(
            "param-channel",
            ("trainer", "global-aggregator"),
            &["default".to_string()],
            backend,
            &[
                ("trainer", &["fetch", "upload"]),
                ("global-aggregator", &["distribute", "aggregate"]),
            ],
        )],
        datasets: datasets(n_trainers, |_| "default".into()),
        hyper: Json::Null,
        events: Vec::new(),
        flavor: None,
    };
    TopoBuilder { spec }
}

/// Hierarchical FL (Fig 1c / 2d, and the paper's Fig 3a example):
/// trainers -> per-group aggregators -> global aggregator.
pub fn hierarchical(n_trainers: usize, n_groups: usize, backend: Backend) -> TopoBuilder {
    let gs = groups(n_groups);
    let trainer_ga: Vec<BTreeMap<String, String>> = gs
        .iter()
        .map(|g| {
            [("param-channel".to_string(), g.clone())]
                .into_iter()
                .collect()
        })
        .collect();
    let agg_ga: Vec<BTreeMap<String, String>> = gs
        .iter()
        .map(|g| {
            [
                ("param-channel".to_string(), g.clone()),
                ("agg-channel".to_string(), "default".to_string()),
            ]
            .into_iter()
            .collect()
        })
        .collect();
    let spec = JobSpec {
        name: "hfl".into(),
        model: "mlp".into(),
        rounds: 10,
        roles: vec![
            Role {
                name: "trainer".into(),
                replica: 1,
                is_data_consumer: true,
                group_association: trainer_ga,
                program: None,
            },
            Role {
                name: "aggregator".into(),
                replica: 1,
                is_data_consumer: false,
                group_association: agg_ga,
                program: None,
            },
            Role {
                name: "global-aggregator".into(),
                replica: 1,
                is_data_consumer: false,
                group_association: ga(&[&[("agg-channel", "default")]]),
                program: None,
            },
        ],
        channels: vec![
            channel(
                "param-channel",
                ("trainer", "aggregator"),
                &gs,
                backend,
                &[
                    ("trainer", &["fetch", "upload"]),
                    ("aggregator", &["distribute", "aggregate"]),
                ],
            ),
            channel(
                "agg-channel",
                ("aggregator", "global-aggregator"),
                &["default".to_string()],
                backend,
                &[
                    ("aggregator", &["fetch", "upload"]),
                    ("global-aggregator", &["distribute", "aggregate"]),
                ],
            ),
        ],
        datasets: datasets(n_trainers, |i| format!("group{}", i % n_groups)),
        hyper: Json::Null,
        events: Vec::new(),
        flavor: None,
    };
    TopoBuilder { spec }
}

/// Coordinated FL (Fig 1d, §6.1 "CO-FL"): H-FL with a single trainer group,
/// a replicated aggregator tier (bipartite links via `replica`), and a
/// coordinator connected to every other role.
pub fn coordinated(n_trainers: usize, n_aggregators: usize, backend: Backend) -> TopoBuilder {
    let spec = JobSpec {
        name: "cofl".into(),
        model: "mlp".into(),
        rounds: 10,
        roles: vec![
            Role {
                name: "trainer".into(),
                replica: 1,
                is_data_consumer: true,
                group_association: ga(&[&[
                    ("param-channel", "default"),
                    ("coord-t-channel", "default"),
                ]]),
                program: None,
            },
            Role {
                name: "aggregator".into(),
                replica: n_aggregators,
                is_data_consumer: false,
                group_association: ga(&[&[
                    ("param-channel", "default"),
                    ("agg-channel", "default"),
                    ("coord-a-channel", "default"),
                ]]),
                program: None,
            },
            Role {
                name: "global-aggregator".into(),
                replica: 1,
                is_data_consumer: false,
                group_association: ga(&[&[
                    ("agg-channel", "default"),
                    ("coord-g-channel", "default"),
                ]]),
                program: None,
            },
            Role {
                name: "coordinator".into(),
                replica: 1,
                is_data_consumer: false,
                group_association: ga(&[&[
                    ("coord-t-channel", "default"),
                    ("coord-a-channel", "default"),
                    ("coord-g-channel", "default"),
                ]]),
                program: None,
            },
        ],
        channels: vec![
            channel(
                "param-channel",
                ("trainer", "aggregator"),
                &["default".to_string()],
                backend,
                &[
                    ("trainer", &["fetch", "upload"]),
                    ("aggregator", &["distribute", "aggregate"]),
                ],
            ),
            channel(
                "agg-channel",
                ("aggregator", "global-aggregator"),
                &["default".to_string()],
                backend,
                &[
                    ("aggregator", &["fetch", "upload"]),
                    ("global-aggregator", &["distribute", "aggregate"]),
                ],
            ),
            channel(
                "coord-t-channel",
                ("trainer", "coordinator"),
                &["default".to_string()],
                backend,
                &[("trainer", &["coordinate"]), ("coordinator", &["assign"])],
            ),
            channel(
                "coord-a-channel",
                ("aggregator", "coordinator"),
                &["default".to_string()],
                backend,
                &[("aggregator", &["coordinate"]), ("coordinator", &["assign"])],
            ),
            channel(
                "coord-g-channel",
                ("global-aggregator", "coordinator"),
                &["default".to_string()],
                backend,
                &[
                    ("global-aggregator", &["coordinate"]),
                    ("coordinator", &["assign"]),
                ],
            ),
        ],
        datasets: datasets(n_trainers, |_| "default".into()),
        hyper: Json::Null,
        events: Vec::new(),
        flavor: None,
    };
    TopoBuilder { spec }
}

/// Hybrid FL (Fig 1e / 2e, §6.2): co-located trainer clusters aggregate
/// internally over a fast p2p ring channel; one delegate per cluster
/// uploads to the global aggregator over the (slow) upload backend.
pub fn hybrid(
    n_trainers: usize,
    n_groups: usize,
    upload_backend: Backend,
    ring_backend: Backend,
) -> TopoBuilder {
    let gs = groups(n_groups);
    let trainer_ga: Vec<BTreeMap<String, String>> = gs
        .iter()
        .map(|g| {
            [
                ("param-channel".to_string(), "default".to_string()),
                ("ring-channel".to_string(), g.clone()),
            ]
            .into_iter()
            .collect()
        })
        .collect();
    let spec = JobSpec {
        name: "hybrid".into(),
        model: "mlp".into(),
        rounds: 10,
        roles: vec![
            Role {
                name: "trainer".into(),
                replica: 1,
                is_data_consumer: true,
                group_association: trainer_ga,
                program: None,
            },
            Role {
                name: "global-aggregator".into(),
                replica: 1,
                is_data_consumer: false,
                group_association: ga(&[&[("param-channel", "default")]]),
                program: None,
            },
        ],
        channels: vec![
            channel(
                "param-channel",
                ("trainer", "global-aggregator"),
                &["default".to_string()],
                upload_backend,
                &[
                    ("trainer", &["fetch", "upload"]),
                    ("global-aggregator", &["distribute", "aggregate"]),
                ],
            ),
            channel(
                "ring-channel",
                ("trainer", "trainer"),
                &gs,
                ring_backend,
                &[("trainer", &["allreduce"])],
            ),
        ],
        datasets: datasets(n_trainers, |i| format!("group{}", i % n_groups)),
        hyper: Json::Null,
        events: Vec::new(),
        flavor: None,
    };
    TopoBuilder { spec }
}

/// Distributed learning (Fig 1a / 2b): no aggregator; trainers all-reduce
/// among themselves each round.
pub fn distributed(n_trainers: usize, backend: Backend) -> TopoBuilder {
    let spec = JobSpec {
        name: "distributed".into(),
        model: "mlp".into(),
        rounds: 10,
        roles: vec![Role {
            name: "trainer".into(),
            replica: 1,
            is_data_consumer: true,
            group_association: ga(&[&[("ring-channel", "default")]]),
            program: None,
        }],
        channels: vec![channel(
            "ring-channel",
            ("trainer", "trainer"),
            &["default".to_string()],
            backend,
            &[("trainer", &["allreduce"])],
        )],
        datasets: datasets(n_trainers, |_| "default".into()),
        hyper: Json::Null,
        events: Vec::new(),
        flavor: None,
    };
    TopoBuilder { spec }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::registry::Registry;
    use crate::tag::expand;

    #[test]
    fn classical_sizes() {
        let w = expand(&classical(8, Backend::Broker).build(), &Registry::single_box()).unwrap();
        assert_eq!(w.len(), 9);
    }

    #[test]
    fn hierarchical_sizes() {
        let w = expand(
            &hierarchical(12, 3, Backend::Broker).build(),
            &Registry::single_box(),
        )
        .unwrap();
        // 12 trainers + 3 aggregators + 1 global
        assert_eq!(w.len(), 16);
    }

    #[test]
    fn coordinated_sizes_match_paper_fig10_setup() {
        // §6.1 toy scenario: 10 trainers, 2 aggregators (+global+coordinator)
        let w = expand(
            &coordinated(10, 2, Backend::Broker).build(),
            &Registry::single_box(),
        )
        .unwrap();
        assert_eq!(w.len(), 14);
        assert_eq!(w.iter().filter(|x| x.role == "coordinator").count(), 1);
    }

    #[test]
    fn hybrid_sizes_match_paper_fig11_setup() {
        // §6.2: 50 trainers in 5 groups + 1 aggregator
        let w = expand(
            &hybrid(50, 5, Backend::Broker, Backend::P2p).build(),
            &Registry::single_box(),
        )
        .unwrap();
        assert_eq!(w.len(), 51);
        // ring channel groups hold 10 trainers each
        for g in 0..5 {
            let n = w
                .iter()
                .filter(|x| {
                    x.channels.get("ring-channel").map(String::as_str)
                        == Some(&format!("group{g}"))
                })
                .count();
            assert_eq!(n, 10);
        }
    }

    #[test]
    fn hybrid_channels_use_distinct_backends() {
        let spec = hybrid(10, 2, Backend::Broker, Backend::P2p).build();
        assert_eq!(spec.channel("param-channel").unwrap().backend, Backend::Broker);
        assert_eq!(spec.channel("ring-channel").unwrap().backend, Backend::P2p);
    }

    #[test]
    fn distributed_is_single_role() {
        let spec = distributed(4, Backend::P2p).build();
        assert_eq!(spec.roles.len(), 1);
        let w = expand(&spec, &Registry::single_box()).unwrap();
        assert_eq!(w.len(), 4);
    }

    #[test]
    fn builder_setters() {
        let spec = classical(2, Backend::P2p)
            .rounds(42)
            .model("transformer")
            .name("custom")
            .set("lr", Json::Num(0.05))
            .set("algorithm", "fedprox")
            .build();
        assert_eq!(spec.rounds, 42);
        assert_eq!(spec.model, "transformer");
        assert_eq!(spec.name, "custom");
        assert_eq!(spec.hyper.get("lr").as_f64(), Some(0.05));
        assert_eq!(spec.hyper.get("algorithm").as_str(), Some("fedprox"));
    }
}
