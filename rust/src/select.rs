//! Client & sample selection policies (paper Table 7).
//!
//! Client selection decides which trainers participate each round:
//! `Select All`, `Random` (McMahan et al.) and `Oort` (Lai et al.) —
//! utility-based selection combining statistical utility (root of mean
//! squared loss) with a system-speed penalty over the trainer's observed
//! round latency, plus epsilon-greedy exploration.
//!
//! Sample selection implements a FedBalancer-style policy (Shin et al.): a
//! trainer keeps per-batch loss estimates and preferentially trains on the
//! highest-loss fraction of its data, with a floor of random exploration.

use std::collections::HashMap;

use crate::json::Json;
use crate::net::VTime;
use crate::prng::Rng;

/// Per-client state the selector learns from round reports.
#[derive(Debug, Clone, Default)]
pub struct ClientStats {
    /// Last reported mean training loss.
    pub loss: f64,
    /// Last observed round duration (virtual us).
    pub round_time: VTime,
    /// Rounds participated.
    pub participation: u64,
}

/// Client selection policy.
pub trait Selector: Send {
    /// Choose the participating subset for `round` out of `candidates`
    /// (sorted worker ids). Must return a non-empty subset when
    /// `candidates` is non-empty.
    fn select(&mut self, round: u64, candidates: &[String]) -> Vec<String>;

    /// Feed back a client's round report.
    fn report(&mut self, client: &str, stats: ClientStats);

    /// Internal state for round-boundary checkpoints (`None` = stateless).
    /// The encoding must be deterministic: same state, same JSON.
    fn snapshot(&self) -> Option<Json> {
        None
    }

    /// Restore state captured by [`Selector::snapshot`]; stateless
    /// selectors ignore it.
    fn restore(&mut self, _snap: &Json) {}
}

/// Everyone participates every round.
pub struct SelectAll;

impl Selector for SelectAll {
    fn select(&mut self, _round: u64, candidates: &[String]) -> Vec<String> {
        candidates.to_vec()
    }

    fn report(&mut self, _client: &str, _stats: ClientStats) {}
}

/// Uniformly random fraction per round.
pub struct RandomSelect {
    frac: f64,
    rng: Rng,
}

impl RandomSelect {
    pub fn new(frac: f64, seed: u64) -> Self {
        Self {
            frac: frac.clamp(0.0, 1.0),
            rng: Rng::new(seed),
        }
    }
}

fn target_count(frac: f64, n: usize) -> usize {
    ((frac * n as f64).round() as usize).clamp(1, n)
}

impl Selector for RandomSelect {
    fn select(&mut self, _round: u64, candidates: &[String]) -> Vec<String> {
        if candidates.is_empty() {
            return vec![];
        }
        let k = target_count(self.frac, candidates.len());
        let idx = self.rng.sample_indices(candidates.len(), k);
        let mut out: Vec<String> = idx.into_iter().map(|i| candidates[i].clone()).collect();
        out.sort();
        out
    }

    fn report(&mut self, _client: &str, _stats: ClientStats) {}

    fn snapshot(&self) -> Option<Json> {
        let mut o = Json::obj();
        o.insert("rng", self.rng.to_json());
        Some(Json::Obj(o))
    }

    fn restore(&mut self, snap: &Json) {
        if let Some(rng) = Rng::from_json(snap.get("rng")) {
            self.rng = rng;
        }
    }
}

/// Oort-style utility selection.
///
/// Utility of client i: `stat_i * sys_i` with `stat_i = sqrt(mean loss^2)`
/// (we use reported mean loss as the proxy) and
/// `sys_i = (T/t_i)^alpha if t_i > T else 1` — a penalty for clients slower
/// than the round-time target `T` (set adaptively to the median observed).
/// An epsilon fraction of each cohort is random exploration of unseen
/// clients.
pub struct OortSelect {
    frac: f64,
    epsilon: f64,
    alpha: f64,
    stats: HashMap<String, ClientStats>,
    rng: Rng,
}

impl OortSelect {
    pub fn new(frac: f64, seed: u64) -> Self {
        Self {
            frac: frac.clamp(0.0, 1.0),
            epsilon: 0.2,
            alpha: 2.0,
            stats: HashMap::new(),
            rng: Rng::new(seed),
        }
    }

    fn utility(&self, client: &str, median_t: f64) -> f64 {
        match self.stats.get(client) {
            None => 0.0,
            Some(s) => {
                let stat = s.loss.max(1e-6);
                let sys = if median_t > 0.0 && (s.round_time as f64) > median_t {
                    (median_t / s.round_time as f64).powf(self.alpha)
                } else {
                    1.0
                };
                stat * sys
            }
        }
    }
}

impl Selector for OortSelect {
    fn select(&mut self, _round: u64, candidates: &[String]) -> Vec<String> {
        if candidates.is_empty() {
            return vec![];
        }
        let k = target_count(self.frac, candidates.len());
        // adaptive round-time target: median of observed times
        let mut times: Vec<f64> = candidates
            .iter()
            .filter_map(|c| self.stats.get(c))
            .filter(|s| s.round_time > 0)
            .map(|s| s.round_time as f64)
            .collect();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median_t = if times.is_empty() {
            0.0
        } else {
            times[times.len() / 2]
        };

        let n_explore = ((k as f64 * self.epsilon).ceil() as usize).min(k);
        let n_exploit = k - n_explore;

        // exploit: top-utility explored clients
        let mut scored: Vec<(&String, f64)> = candidates
            .iter()
            .filter(|c| self.stats.contains_key(*c))
            .map(|c| (c, self.utility(c, median_t)))
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(b.0)));
        let mut chosen: Vec<String> = scored
            .iter()
            .take(n_exploit)
            .map(|(c, _)| (*c).clone())
            .collect();

        // explore: random among the rest (prefer never-seen clients)
        let mut rest: Vec<&String> = candidates.iter().filter(|c| !chosen.contains(c)).collect();
        rest.sort_by_key(|c| self.stats.contains_key(*c) as u8); // unseen first
        let unseen = rest.iter().filter(|c| !self.stats.contains_key(**c)).count();
        let pool = unseen.max(rest.len().min(k));
        while chosen.len() < k && !rest.is_empty() {
            let j = self.rng.below(pool.min(rest.len()) as u64) as usize;
            chosen.push(rest.remove(j).clone());
        }
        chosen.sort();
        chosen
    }

    fn report(&mut self, client: &str, stats: ClientStats) {
        let e = self.stats.entry(client.to_string()).or_default();
        e.loss = stats.loss;
        e.round_time = stats.round_time;
        e.participation += 1;
    }

    fn snapshot(&self) -> Option<Json> {
        let mut o = Json::obj();
        o.insert("rng", self.rng.to_json());
        let mut stats = Json::obj();
        let mut ids: Vec<&String> = self.stats.keys().collect();
        ids.sort(); // HashMap order is not deterministic; the snapshot must be
        for id in ids {
            let s = &self.stats[id];
            let mut e = Json::obj();
            e.insert("loss", Json::Num(s.loss));
            e.insert("round_time", s.round_time);
            e.insert("participation", s.participation);
            stats.insert(id.clone(), Json::Obj(e));
        }
        o.insert("stats", Json::Obj(stats));
        Some(Json::Obj(o))
    }

    fn restore(&mut self, snap: &Json) {
        if let Some(rng) = Rng::from_json(snap.get("rng")) {
            self.rng = rng;
        }
        self.stats.clear();
        if let Some(stats) = snap.get("stats").as_obj() {
            for (id, e) in stats.iter() {
                self.stats.insert(
                    id.clone(),
                    ClientStats {
                        loss: e.get("loss").as_f64().unwrap_or(0.0),
                        round_time: e.get("round_time").as_f64().unwrap_or(0.0) as VTime,
                        participation: e.get("participation").as_f64().unwrap_or(0.0) as u64,
                    },
                );
            }
        }
    }
}

/// Build a selector from the config string ("all" | "random" | "oort").
pub fn make_selector(name: &str, frac: f64, seed: u64) -> Box<dyn Selector> {
    match name {
        "random" => Box::new(RandomSelect::new(frac, seed)),
        "oort" => Box::new(OortSelect::new(frac, seed)),
        _ => Box::new(SelectAll),
    }
}

// ------------------------------------------------------------------------
// Sample selection (FedBalancer-style)
// ------------------------------------------------------------------------

/// Trainer-side batch-granular loss-based sample selection.
///
/// Tracks an exponential moving average of each batch's loss; `plan` keeps
/// the top `keep_frac` loss batches plus an exploration floor so estimates
/// stay fresh. (The original FedBalancer works per-sample with deadline
/// control; batch granularity preserves the mechanism under our fixed-shape
/// artifacts — see DESIGN.md.)
pub struct FedBalancer {
    keep_frac: f64,
    explore: f64,
    ema: Vec<f64>,
    rng: Rng,
}

impl FedBalancer {
    pub fn new(n_batches: usize, keep_frac: f64, seed: u64) -> Self {
        Self {
            keep_frac: keep_frac.clamp(0.1, 1.0),
            explore: 0.2,
            ema: vec![f64::MAX; n_batches], // unseen batches = max priority
            rng: Rng::new(seed),
        }
    }

    pub fn record(&mut self, batch: usize, loss: f64) {
        let e = &mut self.ema[batch];
        *e = if *e == f64::MAX { loss } else { 0.7 * *e + 0.3 * loss };
    }

    /// Checkpoint state: the per-batch loss EMAs (`f64::MAX` "unseen"
    /// sentinels travel as `null`) plus the exploration RNG position.
    pub fn snapshot(&self) -> Json {
        let mut o = Json::obj();
        o.insert("rng", self.rng.to_json());
        let ema: Vec<Json> = self
            .ema
            .iter()
            .map(|e| if *e == f64::MAX { Json::Null } else { Json::Num(*e) })
            .collect();
        o.insert("ema", Json::Arr(ema));
        Json::Obj(o)
    }

    /// Restore state captured by [`FedBalancer::snapshot`].
    pub fn restore(&mut self, snap: &Json) {
        if let Some(rng) = Rng::from_json(snap.get("rng")) {
            self.rng = rng;
        }
        if let Some(ema) = snap.get("ema").as_arr() {
            self.ema = ema
                .iter()
                .map(|e| e.as_f64().unwrap_or(f64::MAX))
                .collect();
        }
    }

    /// Batch indices to train on this epoch, highest-loss first.
    pub fn plan(&mut self) -> Vec<usize> {
        let n = self.ema.len();
        let keep = target_count(self.keep_frac, n);
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| self.ema[b].partial_cmp(&self.ema[a]).unwrap());
        let mut chosen: Vec<usize> = idx[..keep].to_vec();
        // exploration: swap a fraction for random non-chosen batches
        let n_explore = ((keep as f64 * self.explore).floor() as usize).min(n - keep);
        for e in 0..n_explore {
            let j = keep + self.rng.below((n - keep) as u64) as usize;
            chosen[keep - 1 - e] = idx[j];
        }
        chosen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn clients(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("t{i:02}")).collect()
    }

    #[test]
    fn select_all_returns_everyone() {
        let mut s = SelectAll;
        assert_eq!(s.select(0, &clients(5)).len(), 5);
        assert!(s.select(0, &[]).is_empty());
    }

    #[test]
    fn random_respects_fraction_and_distinct() {
        let mut s = RandomSelect::new(0.4, 1);
        let c = clients(10);
        for round in 0..20 {
            let sel = s.select(round, &c);
            assert_eq!(sel.len(), 4);
            let mut d = sel.clone();
            d.dedup();
            assert_eq!(d.len(), 4);
        }
    }

    #[test]
    fn random_minimum_one() {
        let mut s = RandomSelect::new(0.01, 2);
        assert_eq!(s.select(0, &clients(10)).len(), 1);
    }

    #[test]
    fn oort_prefers_high_loss_clients() {
        let mut s = OortSelect::new(0.3, 3);
        s.epsilon = 0.0; // pure exploitation for the test
        let c = clients(10);
        for (i, id) in c.iter().enumerate() {
            s.report(
                id,
                ClientStats {
                    loss: if i < 3 { 5.0 } else { 0.1 },
                    round_time: 1000,
                    participation: 1,
                },
            );
        }
        let sel = s.select(1, &c);
        assert_eq!(sel, vec!["t00", "t01", "t02"]);
    }

    #[test]
    fn oort_penalizes_stragglers() {
        let mut s = OortSelect::new(0.2, 4);
        s.epsilon = 0.0;
        let c = clients(10);
        for (i, id) in c.iter().enumerate() {
            s.report(
                id,
                ClientStats {
                    loss: 1.0,
                    // t00 is 100x slower than the rest
                    round_time: if i == 0 { 100_000_000 } else { 1_000_000 },
                    participation: 1,
                },
            );
        }
        let sel = s.select(1, &c);
        assert!(!sel.contains(&"t00".to_string()), "straggler selected: {sel:?}");
    }

    #[test]
    fn oort_explores_unseen_clients() {
        let mut s = OortSelect::new(0.5, 5);
        let c = clients(10);
        // only first 2 have stats; cohort of 5 must include unseen ones
        for id in &c[..2] {
            s.report(id, ClientStats { loss: 1.0, round_time: 1000, participation: 1 });
        }
        let sel = s.select(1, &c);
        assert_eq!(sel.len(), 5);
        assert!(sel.iter().any(|x| !["t00", "t01"].contains(&x.as_str())));
    }

    #[test]
    fn make_selector_dispatch() {
        let mut s = make_selector("all", 0.1, 0);
        assert_eq!(s.select(0, &clients(4)).len(), 4);
        let mut s = make_selector("random", 0.5, 0);
        assert_eq!(s.select(0, &clients(4)).len(), 2);
        let mut s = make_selector("oort", 0.5, 0);
        assert_eq!(s.select(0, &clients(4)).len(), 2);
    }

    #[test]
    fn fedbalancer_prefers_high_loss_batches() {
        let mut fb = FedBalancer::new(10, 0.3, 6);
        fb.explore = 0.0;
        for b in 0..10 {
            fb.record(b, if b >= 7 { 9.0 } else { 0.1 });
        }
        let mut plan = fb.plan();
        plan.sort();
        assert_eq!(plan, vec![7, 8, 9]);
    }

    #[test]
    fn fedbalancer_unseen_batches_first() {
        let mut fb = FedBalancer::new(5, 0.4, 7);
        fb.explore = 0.0;
        fb.record(0, 100.0);
        fb.record(1, 100.0);
        fb.record(2, 100.0);
        // batches 3,4 never seen -> max priority
        let plan = fb.plan();
        assert!(plan.contains(&3) && plan.contains(&4), "{plan:?}");
    }

    #[test]
    fn fedbalancer_ema_updates() {
        let mut fb = FedBalancer::new(2, 1.0, 8);
        fb.record(0, 1.0);
        fb.record(0, 0.0);
        assert!((fb.ema[0] - 0.7).abs() < 1e-9);
    }
}
