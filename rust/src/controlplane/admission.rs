//! Admission control: capacity accounting over registered computes.
//!
//! The [`crate::registry::Registry`] advertises an advisory worker
//! capacity per compute cluster; the [`CapacityLedger`] turns that into a
//! reservation book the [`super::JobManager`] admits against. A job's
//! **demand** is its expanded worker count per compute (placement — realm
//! matching and least-loaded spreading — already happened in
//! [`crate::tag::expand`]); admission reserves the demand, job completion
//! releases it, and a job whose demand cannot currently be reserved waits
//! in the FIFO admission queue.

use std::collections::BTreeMap;

use crate::registry::Registry;
use crate::tag::WorkerConfig;

/// Per-compute demand of one job: `compute name -> workers placed there`.
pub type Demand = BTreeMap<String, usize>;

/// Reservation book over the registered computes' advisory capacities.
pub struct CapacityLedger {
    caps: BTreeMap<String, usize>,
    in_use: BTreeMap<String, usize>,
}

impl CapacityLedger {
    /// A ledger over `registry`'s computes, nothing reserved.
    pub fn from_registry(registry: &Registry) -> Self {
        Self {
            caps: registry
                .computes()
                .iter()
                .map(|c| (c.name.clone(), c.capacity))
                .collect(),
            in_use: BTreeMap::new(),
        }
    }

    /// Register (or update) a compute's capacity after construction.
    pub fn set_capacity(&mut self, compute: &str, capacity: usize) {
        self.caps.insert(compute.to_string(), capacity);
    }

    /// A job's per-compute demand, read off its expanded worker list.
    pub fn demand_of(workers: &[WorkerConfig]) -> Demand {
        let mut d = Demand::new();
        for w in workers {
            *d.entry(w.compute.clone()).or_insert(0) += 1;
        }
        d
    }

    /// Can `demand` be reserved *right now* (per compute, free >= asked)?
    pub fn fits(&self, demand: &Demand) -> bool {
        demand.iter().all(|(c, n)| self.free(c) >= *n)
    }

    /// Could `demand` ever be reserved on an idle fleet? `false` means the
    /// job is unschedulable and must be rejected at submit, not queued
    /// forever.
    pub fn can_ever_fit(&self, demand: &Demand) -> bool {
        demand
            .iter()
            .all(|(c, n)| self.caps.get(c).copied().unwrap_or(0) >= *n)
    }

    /// Reserve `demand` (admission). Callers check [`Self::fits`] first;
    /// over-reservation is allowed but leaves `free` at zero.
    pub fn reserve(&mut self, demand: &Demand) {
        for (c, n) in demand {
            *self.in_use.entry(c.clone()).or_insert(0) += n;
        }
    }

    /// Release `demand` (job finished).
    pub fn release(&mut self, demand: &Demand) {
        for (c, n) in demand {
            let e = self.in_use.entry(c.clone()).or_insert(0);
            *e = e.saturating_sub(*n);
        }
    }

    /// Unreserved capacity on `compute` (0 for unknown computes).
    pub fn free(&self, compute: &str) -> usize {
        let cap = self.caps.get(compute).copied().unwrap_or(0);
        cap.saturating_sub(self.used(compute))
    }

    /// Reserved capacity on `compute`.
    pub fn used(&self, compute: &str) -> usize {
        self.in_use.get(compute).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::Backend;
    use crate::registry::ComputeSpec;
    use crate::tag::expand;
    use crate::topo;

    fn two_box_registry(cap: usize) -> Registry {
        let mut r = Registry::new();
        r.register_compute(ComputeSpec::new("a", "*", cap));
        r.register_compute(ComputeSpec::new("b", "*", cap));
        r
    }

    #[test]
    fn demand_counts_workers_per_compute() {
        let reg = two_box_registry(100);
        let spec = topo::classical(4, Backend::P2p).build();
        let workers = expand(&spec, &reg).unwrap();
        let d = CapacityLedger::demand_of(&workers);
        // 4 trainers least-loaded across a/b + 1 global round-robin
        assert_eq!(d.values().sum::<usize>(), 5);
        assert!(d.keys().all(|k| k == "a" || k == "b"));
    }

    #[test]
    fn reserve_release_roundtrip_at_exact_capacity() {
        let reg = two_box_registry(3);
        let mut l = CapacityLedger::from_registry(&reg);
        let d: Demand = [("a".to_string(), 3usize)].into_iter().collect();
        assert!(l.fits(&d), "exact capacity must fit");
        l.reserve(&d);
        assert_eq!(l.free("a"), 0);
        assert_eq!(l.free("b"), 3);
        // the admission-queueing edge the JobManager relies on: a second
        // identical job does NOT fit until the first releases
        assert!(!l.fits(&d));
        assert!(l.can_ever_fit(&d), "queued, not rejected");
        l.release(&d);
        assert!(l.fits(&d));
        assert_eq!(l.used("a"), 0);
    }

    #[test]
    fn oversized_demand_is_unschedulable_not_queued() {
        let reg = two_box_registry(4);
        let l = CapacityLedger::from_registry(&reg);
        let d: Demand = [("a".to_string(), 5usize)].into_iter().collect();
        assert!(!l.fits(&d));
        assert!(!l.can_ever_fit(&d), "demand beyond capacity can never fit");
        // spread across computes, each within its own cap, is fine
        let spread: Demand = [("a".to_string(), 4usize), ("b".to_string(), 4usize)]
            .into_iter()
            .collect();
        assert!(l.can_ever_fit(&spread));
    }

    #[test]
    fn unknown_compute_has_zero_capacity() {
        let reg = two_box_registry(4);
        let l = CapacityLedger::from_registry(&reg);
        let d: Demand = [("ghost".to_string(), 1usize)].into_iter().collect();
        assert!(!l.fits(&d));
        assert!(!l.can_ever_fit(&d));
        assert_eq!(l.free("ghost"), 0);
    }

    #[test]
    fn release_never_underflows() {
        let reg = two_box_registry(4);
        let mut l = CapacityLedger::from_registry(&reg);
        let d: Demand = [("a".to_string(), 2usize)].into_iter().collect();
        l.release(&d); // release without reserve
        assert_eq!(l.used("a"), 0);
        assert_eq!(l.free("a"), 4);
    }

    #[test]
    fn single_box_infinite_capacity_always_fits() {
        let l = CapacityLedger::from_registry(&Registry::single_box());
        let d: Demand = [("box".to_string(), 1_000_000usize)].into_iter().collect();
        assert!(l.fits(&d));
        assert!(l.can_ever_fit(&d));
    }
}
