//! Round-boundary job checkpoints — the crash-resilience substrate.
//!
//! Flame's control plane snapshots each job's runtime state at round
//! boundaries into the [`Store`]'s `job_ckpt` collection, so a controller
//! killed at *any* boundary can resume the job and produce a final report
//! byte-identical to an unkilled run (see DESIGN.md "Crash resilience &
//! failover").
//!
//! The moving parts:
//!
//! * [`CkptPolicy`] — per-job knobs carried on `JobOptions`: checkpoint
//!   cadence, an injectable controller kill point, and whether mid-tier
//!   aggregator failover is armed.
//! * [`CkptSink`] — the per-job collection point shared through
//!   [`crate::roles::JobRuntime`]. Uploading workers *publish* their
//!   boundary snapshot into the sink's hub immediately **before** their
//!   upload send; because a synchronous quorum-1.0 collect only returns
//!   once every child's upload arrived, the send gives a happens-before
//!   edge: when the global aggregator reaches the next round boundary,
//!   every worker's published snapshot is current. The global's
//!   checkpoint tasklet then *commits* hub + its own state as one atomic
//!   `put_batch`.
//! * [`JobCheckpoint`] — the decoded checkpoint a resumed job rehydrates
//!   from ([`load_latest`]).
//!
//! Torn-write safety: each epoch's records go into one `put_batch` with
//! the `<job>/head` pointer written **last in the batch** — the head is
//! both commit marker and latest-epoch pointer. Old-epoch GC runs only
//! *after* the new head is durable, as separate tombstones plus a
//! [`Store::compact`]. A crash between the two batches therefore leaves
//! either the previous head (its parts still intact — GC had not run) or
//! the new head (its parts committed atomically): never a torn state.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Context, Result};

use crate::json::{self, Json};
use crate::store::Store;
use crate::tag::WorkerConfig;

/// Store collection holding checkpoint records.
pub const CKPT_COLLECTION: &str = "job_ckpt";

/// Per-job crash-resilience policy (set through `JobOptions::with_ckpt`).
#[derive(Clone, Debug, Default)]
pub struct CkptPolicy {
    /// Checkpoint every `every` round boundaries (1 = every boundary,
    /// 0 = never write checkpoints).
    pub every: u64,
    /// Injected controller kill: the global's checkpoint tasklet fails its
    /// pod immediately **after** committing the boundary-`round`
    /// checkpoint, taking the whole job down (parked workers are culled by
    /// the scheduler's stall detection). The store keeps the checkpoint;
    /// `JobManager::resume` picks it up.
    pub kill_at: Option<u64>,
    /// Arm mid-tier aggregator failover: when an aggregator pod dies
    /// mid-run, the control plane evicts it and schedules a replacement
    /// pod under the same worker id (see `controlplane` JobTracker).
    pub failover: bool,
}

impl CkptPolicy {
    /// Checkpoint at every round boundary.
    pub fn every_round() -> Self {
        Self {
            every: 1,
            kill_at: None,
            failover: false,
        }
    }

    /// Checkpoint every boundary and kill the controller right after the
    /// boundary-`round` commit.
    pub fn kill_at(round: u64) -> Self {
        Self {
            every: 1,
            kill_at: Some(round),
            failover: false,
        }
    }

    /// Arm aggregator failover (no checkpoint cadence needed).
    pub fn with_failover(mut self) -> Self {
        self.failover = true;
        self
    }
}

/// One decoded job checkpoint: everything a resumed job needs beyond its
/// spec to restart at a round boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct JobCheckpoint {
    /// The boundary this checkpoint captures: rounds `1..=round` are done.
    pub round: u64,
    /// Timeline entries the dead run had already drained — the resumed
    /// job replays these against the initial expansion to rebuild its
    /// boundary membership, and skips them in the rebuilt timeline.
    pub cursor: u64,
    /// Global-aggregator state (model, server optimizer, selector, rounds,
    /// clock — encoded by `roles::global`).
    pub global: Json,
    /// Per-worker boundary snapshots keyed by worker id.
    pub workers: BTreeMap<String, Json>,
    /// Metrics-hub dump ([`crate::metrics::MetricsHub::snapshot`]).
    pub metrics: Json,
    /// Trace-hub dump ([`crate::trace::TraceHub::snapshot`]); `Null` for
    /// untraced jobs and checkpoints written before tracing existed.
    pub trace: Json,
}

fn epoch_prefix(job: &str, epoch: u64) -> String {
    format!("{job}/{epoch:016x}")
}

fn head_key(job: &str) -> String {
    format!("{job}/head")
}

/// Per-job checkpoint collection point, shared via `JobRuntime::ckpt`.
pub struct CkptSink {
    job: String,
    policy: CkptPolicy,
    /// Does this job actually write checkpoints? Live checkpointing is
    /// gated by the controller to topologies where the boundary is a true
    /// barrier (synchronous aggregation, quorum 1.0, no coordinator, no
    /// ring channels); other jobs resume by restarting from round 0.
    live: bool,
    /// Latest published per-worker snapshots.
    hub: Mutex<HashMap<String, Json>>,
    /// Bound by the control plane once the job's store is known (the
    /// role layer that builds sinks has no store access). Never bound →
    /// commits are hub-only, which still seeds failover.
    store: OnceLock<Arc<Store>>,
    /// Worker configs by id, registered at env build — the failover desk
    /// redeploys a dead aggregator from this.
    cfgs: Mutex<HashMap<String, WorkerConfig>>,
    /// Failover seeds: snapshots staged for a replacement pod to consume
    /// at context build (keyed by worker id).
    seeds: Mutex<HashMap<String, Json>>,
    /// Pods recovered by failover; the fleet's finish path offsets its
    /// failed-pod count by this so a failed-over job still completes.
    recovered: AtomicU64,
}

impl CkptSink {
    pub fn new(job: impl Into<String>, policy: CkptPolicy, live: bool) -> Arc<Self> {
        Arc::new(Self {
            job: job.into(),
            policy,
            live,
            hub: Mutex::new(HashMap::new()),
            store: OnceLock::new(),
            cfgs: Mutex::new(HashMap::new()),
            seeds: Mutex::new(HashMap::new()),
            recovered: AtomicU64::new(0),
        })
    }

    pub fn policy(&self) -> &CkptPolicy {
        &self.policy
    }

    /// Does this sink write durable round-boundary checkpoints?
    pub fn is_live(&self) -> bool {
        self.live
    }

    /// Bind the job's store (idempotent; called by the control plane).
    pub fn bind_store(&self, store: Arc<Store>) {
        let _ = self.store.set(store);
    }

    /// Should the global's checkpoint tasklet commit at this boundary?
    pub fn due(&self, round: u64) -> bool {
        self.policy.every > 0 && round > 0 && round % self.policy.every == 0
    }

    /// A worker publishes its boundary snapshot (called immediately before
    /// its upload send — see module docs for why the ordering matters).
    pub fn publish(&self, worker: &str, snap: Json) {
        self.hub.lock().unwrap().insert(worker.to_string(), snap);
    }

    /// Record a worker config for possible failover redeployment.
    pub fn register_cfg(&self, cfg: WorkerConfig) {
        self.cfgs.lock().unwrap().insert(cfg.id.clone(), cfg);
    }

    /// The registered config of a worker (failover redeploy source).
    pub fn cfg_of(&self, worker: &str) -> Option<WorkerConfig> {
        self.cfgs.lock().unwrap().get(worker).cloned()
    }

    /// Stage the last published snapshot of `worker` as a failover seed
    /// for its replacement pod.
    pub fn stage_seed(&self, worker: &str) {
        if let Some(snap) = self.hub.lock().unwrap().get(worker).cloned() {
            self.seeds.lock().unwrap().insert(worker.to_string(), snap);
        }
    }

    /// Consume a staged failover seed at replacement-context build.
    pub fn take_seed(&self, worker: &str) -> Option<Json> {
        self.seeds.lock().unwrap().remove(worker)
    }

    /// Count one failover recovery.
    pub fn note_recovered(&self) {
        self.recovered.fetch_add(1, Ordering::SeqCst);
    }

    pub fn recovered(&self) -> u64 {
        self.recovered.load(Ordering::SeqCst)
    }

    /// Commit the boundary-`round` checkpoint: hub snapshots + the
    /// global's own state, one atomic `put_batch` with the head pointer
    /// last, then GC of superseded epochs. No-op (hub retained) when the
    /// sink is not live or no store is bound.
    pub fn commit(
        &self,
        round: u64,
        cursor: u64,
        global: Json,
        metrics: Json,
        trace: Json,
    ) -> Result<()> {
        if !self.live {
            return Ok(());
        }
        let Some(store) = self.store.get() else {
            return Ok(());
        };
        let epoch = round;
        let prefix = epoch_prefix(&self.job, epoch);
        // deterministic record order: meta, global, metrics, trace,
        // workers by id
        let workers: BTreeMap<String, Json> = self
            .hub
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        let mut meta = Json::obj();
        meta.insert("round", json::from_u64_hex(round));
        meta.insert("cursor", json::from_u64_hex(cursor));
        meta.insert(
            "workers",
            Json::Arr(workers.keys().map(|k| Json::Str(k.clone())).collect()),
        );
        let mut batch: Vec<(String, Json)> = Vec::with_capacity(workers.len() + 4);
        batch.push((format!("{prefix}/meta"), Json::Obj(meta)));
        batch.push((format!("{prefix}/global"), global));
        batch.push((format!("{prefix}/metrics"), metrics));
        if !matches!(trace, Json::Null) {
            batch.push((format!("{prefix}/trace"), trace));
        }
        for (id, snap) in &workers {
            batch.push((format!("{prefix}/w/{id}"), snap.clone()));
        }
        // the head record goes LAST: it is the commit marker — a torn
        // batch leaves the previous head pointing at intact records
        let mut head = Json::obj();
        head.insert("epoch", json::from_u64_hex(epoch));
        batch.push((head_key(&self.job), Json::Obj(head)));
        store.put_batch(CKPT_COLLECTION, batch)?;
        self.gc(store, epoch)?;
        Ok(())
    }

    /// Drop every record of epochs other than `keep` (tombstones), then
    /// compact the journal so superseded snapshots stop occupying disk.
    /// Runs only after the new head is durable; a crash mid-GC leaves
    /// stale-but-unreferenced records the next GC sweep removes.
    fn gc(&self, store: &Arc<Store>, keep: u64) -> Result<()> {
        let keep_prefix = format!("{}/", epoch_prefix(&self.job, keep));
        let job_prefix = format!("{}/", self.job);
        let head = head_key(&self.job);
        let mut dropped = false;
        for key in store.keys(CKPT_COLLECTION) {
            if key.starts_with(&job_prefix) && !key.starts_with(&keep_prefix) && key != head {
                store.delete(CKPT_COLLECTION, &key)?;
                dropped = true;
            }
        }
        if dropped {
            store.compact()?;
        }
        Ok(())
    }
}

/// Load the latest *committed* checkpoint of `job`, trusting only the
/// epoch the head pointer names (torn tails past the head are invisible
/// by construction). `Ok(None)` when the job never checkpointed.
pub fn load_latest(store: &Arc<Store>, job: &str) -> Result<Option<JobCheckpoint>> {
    let Some(head) = store.get(CKPT_COLLECTION, &head_key(job)) else {
        return Ok(None);
    };
    let epoch = json::as_u64_hex(head.get("epoch"))
        .with_context(|| format!("job '{job}': malformed checkpoint head"))?;
    let prefix = epoch_prefix(job, epoch);
    let meta = store
        .get(CKPT_COLLECTION, &format!("{prefix}/meta"))
        .with_context(|| format!("job '{job}': checkpoint epoch {epoch} missing meta"))?;
    let round = json::as_u64_hex(meta.get("round")).context("checkpoint meta missing round")?;
    let cursor = json::as_u64_hex(meta.get("cursor")).context("checkpoint meta missing cursor")?;
    let global = store
        .get(CKPT_COLLECTION, &format!("{prefix}/global"))
        .with_context(|| format!("job '{job}': checkpoint epoch {epoch} missing global state"))?;
    let metrics = store
        .get(CKPT_COLLECTION, &format!("{prefix}/metrics"))
        .unwrap_or(Json::Null);
    let trace = store
        .get(CKPT_COLLECTION, &format!("{prefix}/trace"))
        .unwrap_or(Json::Null);
    let mut workers = BTreeMap::new();
    let Some(ids) = meta.get("workers").as_arr() else {
        bail!("job '{job}': checkpoint meta missing worker list");
    };
    for id in ids {
        let Some(id) = id.as_str() else {
            bail!("job '{job}': malformed checkpoint worker list");
        };
        let snap = store
            .get(CKPT_COLLECTION, &format!("{prefix}/w/{id}"))
            .with_context(|| {
                format!("job '{job}': checkpoint epoch {epoch} missing worker '{id}'")
            })?;
        workers.insert(id.to_string(), snap);
    }
    Ok(Some(JobCheckpoint {
        round,
        cursor,
        global,
        workers,
        metrics,
        trace,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sink_with_store() -> (Arc<CkptSink>, Arc<Store>) {
        let store = Arc::new(Store::in_memory());
        let sink = CkptSink::new("j0", CkptPolicy::every_round(), true);
        sink.bind_store(store.clone());
        (sink, store)
    }

    #[test]
    fn commit_and_load_roundtrip() {
        let (sink, store) = sink_with_store();
        sink.publish("w0", Json::Str("s0".into()));
        sink.publish("w1", Json::Str("s1".into()));
        sink.commit(3, 2, Json::Str("g".into()), Json::Null, Json::Null).unwrap();
        let ck = load_latest(&store, "j0").unwrap().unwrap();
        assert_eq!(ck.round, 3);
        assert_eq!(ck.cursor, 2);
        assert_eq!(ck.global, Json::Str("g".into()));
        assert_eq!(ck.workers.len(), 2);
        assert_eq!(ck.workers["w1"], Json::Str("s1".into()));
        assert!(load_latest(&store, "nope").unwrap().is_none());
    }

    #[test]
    fn newer_epoch_supersedes_and_gcs_older() {
        let (sink, store) = sink_with_store();
        sink.publish("w0", Json::Str("r1".into()));
        sink.commit(1, 0, Json::Str("g1".into()), Json::Null, Json::Null).unwrap();
        sink.publish("w0", Json::Str("r2".into()));
        sink.commit(2, 0, Json::Str("g2".into()), Json::Null, Json::Null).unwrap();
        let ck = load_latest(&store, "j0").unwrap().unwrap();
        assert_eq!(ck.round, 2);
        assert_eq!(ck.workers["w0"], Json::Str("r2".into()));
        // every epoch-1 record tombstoned
        for key in store.keys(CKPT_COLLECTION) {
            assert!(
                !key.contains(&format!("{:016x}", 1u64)),
                "stale epoch record survived GC: {key}"
            );
        }
    }

    #[test]
    fn non_live_sink_keeps_hub_but_writes_nothing() {
        let store = Arc::new(Store::in_memory());
        let sink = CkptSink::new("j0", CkptPolicy::every_round(), false);
        sink.bind_store(store.clone());
        sink.publish("agg", Json::Str("s".into()));
        sink.commit(1, 0, Json::Null, Json::Null, Json::Null).unwrap();
        assert!(store.get(CKPT_COLLECTION, "j0/head").is_none());
        // hub still seeds failover
        sink.stage_seed("agg");
        assert_eq!(sink.take_seed("agg"), Some(Json::Str("s".into())));
        assert_eq!(sink.take_seed("agg"), None);
    }

    #[test]
    fn due_respects_cadence() {
        let sink = CkptSink::new(
            "j",
            CkptPolicy {
                every: 2,
                kill_at: None,
                failover: false,
            },
            true,
        );
        assert!(!sink.due(0));
        assert!(!sink.due(1));
        assert!(sink.due(2));
        assert!(sink.due(4));
        let off = CkptSink::new("j", CkptPolicy::default(), true);
        assert!(!off.due(5));
    }
}
