//! Round-boundary job checkpoints — the crash-resilience substrate.
//!
//! Flame's control plane snapshots each job's runtime state at round (or,
//! for asynchronous jobs, version) boundaries into the [`Store`]'s
//! `job_ckpt` collection, so a controller killed at *any* boundary can
//! resume the job and produce a final report byte-identical to an unkilled
//! run (see DESIGN.md "Crash resilience & failover").
//!
//! The moving parts:
//!
//! * [`CkptPolicy`] — per-job knobs carried on `JobOptions`: checkpoint
//!   cadence, a scriptable [`FaultPlan`], whether mid-tier aggregator
//!   failover is armed, and the incremental-chain bound (`full_every`).
//! * [`CkptSink`] — the per-job collection point shared through
//!   [`crate::roles::JobRuntime`]. Uploading workers *publish* their
//!   boundary snapshot into the sink's hub immediately **before** their
//!   upload send; the committing worker (global aggregator or ring
//!   delegate) only commits once every peer's boundary message has
//!   arrived, so the send gives a happens-before edge: at commit time
//!   every worker's published snapshot is current. How each flavor
//!   establishes that barrier differs — synchronous quorum < 1.0 collects
//!   drain stragglers at the boundary, async/FedBuff holds a
//!   version-boundary barrier, ring members emit collective-op epoch
//!   markers — but the commit contract is the same.
//! * [`JobCheckpoint`] — the decoded checkpoint a resumed job rehydrates
//!   from ([`load_latest`]).
//!
//! Torn-write safety: each epoch's records go into one `put_batch` with
//! the `<job>/head` pointer written **last in the batch** — the head is
//! both commit marker and latest-epoch pointer. Old-epoch GC runs only
//! *after* the new head is durable, as separate tombstones plus a
//! [`Store::compact`]. A crash between the two batches therefore leaves
//! either the previous head (its parts still intact — GC had not run) or
//! the new head (its parts committed atomically): never a torn state.
//!
//! Incremental epochs: model state is O(d), so journaling a full snapshot
//! every round dominates checkpoint cost at `flame scale` sizes. Commits
//! therefore delta-encode each record against the *previous* epoch
//! (`meta.base` names it): float arrays become XOR-of-f32-bits token
//! strings with zero runs run-length collapsed, grown arrays (metric
//! series) store only their appended tail, unchanged subtrees collapse to
//! a same marker. Every `full_every`-th commit writes a full epoch to
//! bound the chain, and GC never collects an epoch that a live chain's
//! head still reaches through base pointers — [`load_latest`] rebuilds
//! state by replaying the chain from its full root forward.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Context, Result};

use crate::json::{self, Json, Obj};
use crate::store::Store;
use crate::tag::WorkerConfig;

/// Store collection holding checkpoint records.
pub const CKPT_COLLECTION: &str = "job_ckpt";

/// Wrapper key marking a record as delta-encoded against its base epoch.
const DELTA_KEY: &str = "__delta";

/// Default incremental-chain bound: every 8th commit is a full snapshot.
const DEFAULT_FULL_EVERY: u64 = 8;

// ------------------------------------------------------------ fault plans

/// Who a scripted fault takes down.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultVictim {
    /// The job's committing worker (global aggregator / ring delegate):
    /// its pod bails right **after** the boundary commit, taking the whole
    /// job down (parked peers are culled by stall detection). The store
    /// keeps the checkpoint; `JobManager::resume` picks it up.
    Controller,
    /// A named worker pod: it bails at its own boundary upload. With
    /// failover armed the control plane redeploys it; otherwise the job
    /// fails and resumes from the last committed epoch.
    Worker(String),
}

/// One scripted fault: kill `victim` at round/version boundary `boundary`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub boundary: u64,
    pub victim: FaultVictim,
}

/// A deterministic, per-job fault script — the generalization of the old
/// single `kill_at` knob. Faults are data on the job's [`CkptPolicy`]
/// (like topology events on the spec), so a kill matrix is a set of plans,
/// not env-var plumbing; `FLAME_KILL_POINT` survives only as a CI filter
/// choosing which plans a test shard runs.
///
/// The text form round-trips through [`FaultPlan::parse`] /
/// [`FaultPlan::dump`]: comma- or space-separated `victim@boundary`
/// entries where the victim is `controller` or a worker id, e.g.
/// `"controller@3"` or `"rsm-trainer-1@2,controller@4"`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Plan with a single controller kill after the boundary-`round` commit.
    pub fn kill_controller_at(boundary: u64) -> Self {
        Self {
            events: vec![FaultEvent {
                boundary,
                victim: FaultVictim::Controller,
            }],
        }
    }

    /// Add a worker kill at `boundary` (builder).
    pub fn and_kill_worker(mut self, worker: impl Into<String>, boundary: u64) -> Self {
        self.events.push(FaultEvent {
            boundary,
            victim: FaultVictim::Worker(worker.into()),
        });
        self
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Does the plan kill the controller at this boundary?
    pub fn kills_controller_at(&self, boundary: u64) -> bool {
        self.events
            .iter()
            .any(|e| e.boundary == boundary && e.victim == FaultVictim::Controller)
    }

    /// Does a scripted controller kill land in `(prev, boundary]`? Commits
    /// don't visit every integer boundary (cadence > 1; async versions can
    /// skip when the drain buffers past the due version), so the kill
    /// check fires at the first *committed* boundary at or after the
    /// scripted one.
    pub fn controller_kill_between(&self, prev: u64, boundary: u64) -> bool {
        self.events.iter().any(|e| {
            e.victim == FaultVictim::Controller && e.boundary > prev && e.boundary <= boundary
        })
    }

    /// Does the plan kill worker `id` at this boundary?
    pub fn kills_worker_at(&self, id: &str, boundary: u64) -> bool {
        self.events
            .iter()
            .any(|e| e.boundary == boundary && matches!(&e.victim, FaultVictim::Worker(w) if w == id))
    }

    /// Parse the `victim@boundary[,victim@boundary...]` text form.
    pub fn parse(s: &str) -> Result<Self> {
        let mut events = Vec::new();
        for part in s.split([',', ' ']).filter(|p| !p.is_empty()) {
            let (victim, boundary) = part
                .rsplit_once('@')
                .with_context(|| format!("fault '{part}': expected victim@boundary"))?;
            let boundary: u64 = boundary
                .parse()
                .with_context(|| format!("fault '{part}': boundary must be a round/version"))?;
            let victim = if victim == "controller" {
                FaultVictim::Controller
            } else if victim.is_empty() {
                bail!("fault '{part}': empty victim");
            } else {
                FaultVictim::Worker(victim.to_string())
            };
            events.push(FaultEvent { boundary, victim });
        }
        Ok(Self { events })
    }

    /// Inverse of [`FaultPlan::parse`].
    pub fn dump(&self) -> String {
        self.events
            .iter()
            .map(|e| match &e.victim {
                FaultVictim::Controller => format!("controller@{}", e.boundary),
                FaultVictim::Worker(w) => format!("{w}@{}", e.boundary),
            })
            .collect::<Vec<_>>()
            .join(",")
    }
}

// ---------------------------------------------------------------- policy

/// Per-job crash-resilience policy (set through `JobOptions::with_ckpt`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CkptPolicy {
    /// Checkpoint every `every` round boundaries (1 = every boundary,
    /// 0 = never write checkpoints).
    pub every: u64,
    /// Scripted deterministic faults (controller/worker kills at chosen
    /// boundaries) — see [`FaultPlan`].
    pub faults: FaultPlan,
    /// Arm mid-tier aggregator failover: when an aggregator pod dies
    /// mid-run, the control plane evicts it and schedules a replacement
    /// pod under the same worker id (see `controlplane` JobTracker).
    pub failover: bool,
    /// Incremental-chain bound: every `full_every`-th commit writes a full
    /// snapshot; the ones between are deltas against their predecessor.
    /// 0 disables incremental encoding (every epoch full).
    pub full_every: u64,
}

impl CkptPolicy {
    /// Checkpoint at every round boundary.
    pub fn every_round() -> Self {
        Self {
            every: 1,
            faults: FaultPlan::default(),
            failover: false,
            full_every: DEFAULT_FULL_EVERY,
        }
    }

    /// Checkpoint every boundary and kill the controller right after the
    /// boundary-`round` commit (shorthand for a one-event [`FaultPlan`]).
    pub fn kill_at(round: u64) -> Self {
        Self {
            faults: FaultPlan::kill_controller_at(round),
            ..Self::every_round()
        }
    }

    /// Checkpoint every boundary and run the given fault script.
    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        self.faults = plan;
        self
    }

    /// Arm aggregator failover (no checkpoint cadence needed).
    pub fn with_failover(mut self) -> Self {
        self.failover = true;
        self
    }

    /// Override the incremental-chain bound (0 = always full snapshots).
    pub fn with_full_every(mut self, n: u64) -> Self {
        self.full_every = n;
        self
    }
}

// ------------------------------------------------------------ checkpoint

/// One decoded job checkpoint: everything a resumed job needs beyond its
/// spec to restart at a round boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct JobCheckpoint {
    /// The boundary this checkpoint captures: rounds `1..=round` are done
    /// (for async jobs, versions `1..=round`).
    pub round: u64,
    /// Timeline entries the dead run had already drained — the resumed
    /// job replays these against the initial expansion to rebuild its
    /// boundary membership, and skips them in the rebuilt timeline.
    pub cursor: u64,
    /// Mechanism flavor that wrote the checkpoint (`sync`, `async`,
    /// `ring`, ... — reported by `flame resume --list`).
    pub flavor: String,
    /// Senders whose updates landed in the committed boundary window —
    /// the quorum < 1.0 census (every selected trainer for full-quorum
    /// sync, the drained membership for async versions). Sorted.
    pub landed: Vec<String>,
    /// Global-aggregator state (model, server optimizer, selector, rounds,
    /// clock — encoded by `roles::global`).
    pub global: Json,
    /// Per-worker boundary snapshots keyed by worker id.
    pub workers: BTreeMap<String, Json>,
    /// Metrics-hub dump ([`crate::metrics::MetricsHub::snapshot`]).
    pub metrics: Json,
    /// Trace-hub dump ([`crate::trace::TraceHub::snapshot`]); `Null` for
    /// untraced jobs and checkpoints written before tracing existed.
    pub trace: Json,
}

fn epoch_prefix(job: &str, epoch: u64) -> String {
    format!("{job}/{epoch:016x}")
}

fn head_key(job: &str) -> String {
    format!("{job}/head")
}

/// Previous committed epoch, cached so the next commit can delta against
/// it without a store read: plain (decoded) records by suffix plus the
/// base-first list of epochs in the live delta chain.
struct PrevEpoch {
    epoch: u64,
    records: BTreeMap<String, Json>,
    chain: Vec<u64>,
}

/// Per-job checkpoint collection point, shared via `JobRuntime::ckpt`.
pub struct CkptSink {
    job: String,
    policy: CkptPolicy,
    /// Does this job actually write checkpoints? Live checkpointing is
    /// gated by the controller to the flavors whose boundary barrier is
    /// implemented (sync at any quorum, async/FedBuff, ring/hybrid);
    /// coordinated jobs resume by restarting from round 0.
    live: bool,
    /// Mechanism flavor recorded in every epoch's meta (set by the
    /// control plane at sink construction; defaults to `sync`).
    flavor: OnceLock<String>,
    /// Latest published per-worker snapshots.
    hub: Mutex<HashMap<String, Json>>,
    /// Bound by the control plane once the job's store is known (the
    /// role layer that builds sinks has no store access). Never bound →
    /// commits are hub-only, which still seeds failover.
    store: OnceLock<Arc<Store>>,
    /// Worker configs by id, registered at env build — the failover desk
    /// redeploys a dead aggregator from this.
    cfgs: Mutex<HashMap<String, WorkerConfig>>,
    /// Failover seeds: snapshots staged for a replacement pod to consume
    /// at context build (keyed by worker id).
    seeds: Mutex<HashMap<String, Json>>,
    /// Pods recovered by failover; the fleet's finish path offsets its
    /// failed-pod count by this so a failed-over job still completes.
    recovered: AtomicU64,
    /// Cache of the previous committed epoch (incremental encoding).
    prev: Mutex<Option<PrevEpoch>>,
    /// Journal bytes written by commits (keys + serialized values) — the
    /// store-level measure `rust/benches/resume.rs` compares full vs
    /// incremental encoding with.
    written: AtomicU64,
}

impl CkptSink {
    pub fn new(job: impl Into<String>, policy: CkptPolicy, live: bool) -> Arc<Self> {
        Arc::new(Self {
            job: job.into(),
            policy,
            live,
            flavor: OnceLock::new(),
            hub: Mutex::new(HashMap::new()),
            store: OnceLock::new(),
            cfgs: Mutex::new(HashMap::new()),
            seeds: Mutex::new(HashMap::new()),
            recovered: AtomicU64::new(0),
            prev: Mutex::new(None),
            written: AtomicU64::new(0),
        })
    }

    pub fn policy(&self) -> &CkptPolicy {
        &self.policy
    }

    /// Does this sink write durable round-boundary checkpoints?
    pub fn is_live(&self) -> bool {
        self.live
    }

    /// Record the job's mechanism flavor (idempotent).
    pub fn set_flavor(&self, flavor: &str) {
        let _ = self.flavor.set(flavor.to_string());
    }

    pub fn flavor(&self) -> &str {
        self.flavor.get().map(|s| s.as_str()).unwrap_or("sync")
    }

    /// Bind the job's store (idempotent; called by the control plane).
    pub fn bind_store(&self, store: Arc<Store>) {
        let _ = self.store.set(store);
    }

    /// Should the committing worker's checkpoint tasklet commit at this
    /// boundary?
    pub fn due(&self, round: u64) -> bool {
        self.policy.every > 0 && round > 0 && round % self.policy.every == 0
    }

    /// A worker publishes its boundary snapshot (called immediately before
    /// its upload send — see module docs for why the ordering matters).
    pub fn publish(&self, worker: &str, snap: Json) {
        self.hub.lock().unwrap().insert(worker.to_string(), snap);
    }

    /// Record a worker config for possible failover redeployment.
    pub fn register_cfg(&self, cfg: WorkerConfig) {
        self.cfgs.lock().unwrap().insert(cfg.id.clone(), cfg);
    }

    /// The registered config of a worker (failover redeploy source).
    pub fn cfg_of(&self, worker: &str) -> Option<WorkerConfig> {
        self.cfgs.lock().unwrap().get(worker).cloned()
    }

    /// Stage the last published snapshot of `worker` as a failover seed
    /// for its replacement pod.
    pub fn stage_seed(&self, worker: &str) {
        if let Some(snap) = self.hub.lock().unwrap().get(worker).cloned() {
            self.seeds.lock().unwrap().insert(worker.to_string(), snap);
        }
    }

    /// Consume a staged failover seed at replacement-context build.
    pub fn take_seed(&self, worker: &str) -> Option<Json> {
        self.seeds.lock().unwrap().remove(worker)
    }

    /// Count one failover recovery.
    pub fn note_recovered(&self) {
        self.recovered.fetch_add(1, Ordering::SeqCst);
    }

    pub fn recovered(&self) -> u64 {
        self.recovered.load(Ordering::SeqCst)
    }

    /// Total journal bytes commits have written (keys + values).
    pub fn bytes_written(&self) -> u64 {
        self.written.load(Ordering::SeqCst)
    }

    /// Commit the boundary-`round` checkpoint: hub snapshots + the
    /// committing worker's own state, one atomic `put_batch` with the head
    /// pointer last, then GC of epochs no live chain reaches. `landed` is
    /// the boundary's landed-sender census (see [`JobCheckpoint::landed`]).
    /// No-op (hub retained) when the sink is not live or no store is
    /// bound.
    pub fn commit(
        &self,
        round: u64,
        cursor: u64,
        global: Json,
        metrics: Json,
        trace: Json,
        landed: &[String],
    ) -> Result<()> {
        if !self.live {
            return Ok(());
        }
        let Some(store) = self.store.get() else {
            return Ok(());
        };
        let epoch = round;
        let prefix = epoch_prefix(&self.job, epoch);
        // deterministic record order: meta, global, metrics, trace,
        // workers by id
        let workers: BTreeMap<String, Json> = self
            .hub
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        let mut records: Vec<(String, Json)> =
            vec![("global".into(), global), ("metrics".into(), metrics)];
        if !matches!(trace, Json::Null) {
            records.push(("trace".into(), trace));
        }
        for (id, snap) in &workers {
            records.push((format!("w/{id}"), snap.clone()));
        }

        let mut prev = self.prev.lock().unwrap();
        let full = self.policy.full_every == 0
            || prev
                .as_ref()
                .map_or(true, |p| p.chain.len() as u64 >= self.policy.full_every);
        let base = if full { None } else { prev.as_ref().map(|p| p.epoch) };

        let mut meta = Json::obj();
        meta.insert("round", json::from_u64_hex(round));
        meta.insert("cursor", json::from_u64_hex(cursor));
        meta.insert("flavor", self.flavor());
        if let Some(b) = base {
            meta.insert("base", json::from_u64_hex(b));
        }
        if !landed.is_empty() {
            let mut census: Vec<&String> = landed.iter().collect();
            census.sort();
            meta.insert(
                "landed",
                Json::Arr(census.into_iter().map(|s| Json::Str(s.clone())).collect()),
            );
        }
        meta.insert(
            "workers",
            Json::Arr(workers.keys().map(|k| Json::Str(k.clone())).collect()),
        );

        let mut batch: Vec<(String, Json)> = Vec::with_capacity(records.len() + 2);
        batch.push((format!("{prefix}/meta"), Json::Obj(meta)));
        for (suffix, value) in &records {
            let stored = match (base, prev.as_ref().and_then(|p| p.records.get(suffix))) {
                (Some(_), Some(prev_v)) => delta_record(prev_v, value),
                _ => value.clone(),
            };
            batch.push((format!("{prefix}/{suffix}"), stored));
        }
        // the head record goes LAST: it is the commit marker — a torn
        // batch leaves the previous head pointing at intact records
        let mut head = Json::obj();
        head.insert("epoch", json::from_u64_hex(epoch));
        batch.push((head_key(&self.job), Json::Obj(head)));
        let bytes: u64 = batch
            .iter()
            .map(|(k, v)| (k.len() + v.dump().len()) as u64)
            .sum();
        store.put_batch(CKPT_COLLECTION, batch)?;
        self.written.fetch_add(bytes, Ordering::SeqCst);

        let mut chain = if full {
            Vec::new()
        } else {
            prev.take().map(|p| p.chain).unwrap_or_default()
        };
        chain.push(epoch);
        let keep = chain.clone();
        *prev = Some(PrevEpoch {
            epoch,
            records: records.into_iter().collect(),
            chain,
        });
        drop(prev);
        self.gc(store, &keep)?;
        Ok(())
    }

    /// Drop every record of epochs outside the live chain `keep`
    /// (tombstones), then compact the journal so superseded snapshots stop
    /// occupying disk. Runs only after the new head is durable; a crash
    /// mid-GC leaves stale-but-unreferenced records the next GC sweep
    /// removes. An epoch that is the base of a live delta chain is in
    /// `keep` by construction and therefore never collected.
    fn gc(&self, store: &Arc<Store>, keep: &[u64]) -> Result<()> {
        let keep_prefixes: Vec<String> = keep
            .iter()
            .map(|e| format!("{}/", epoch_prefix(&self.job, *e)))
            .collect();
        let job_prefix = format!("{}/", self.job);
        let head = head_key(&self.job);
        let mut dropped = false;
        for key in store.keys(CKPT_COLLECTION) {
            if key.starts_with(&job_prefix)
                && key != head
                && !keep_prefixes.iter().any(|p| key.starts_with(p))
            {
                store.delete(CKPT_COLLECTION, &key)?;
                dropped = true;
            }
        }
        if dropped {
            store.compact()?;
        }
        Ok(())
    }
}

// ------------------------------------------------------------------ load

/// Load the latest *committed* checkpoint of `job`, trusting only the
/// epoch the head pointer names (torn tails past the head are invisible
/// by construction). Delta epochs are rebuilt by walking `meta.base`
/// pointers down to the chain's full root and replaying the deltas
/// forward. `Ok(None)` when the job never checkpointed.
pub fn load_latest(store: &Arc<Store>, job: &str) -> Result<Option<JobCheckpoint>> {
    let Some(head) = store.get(CKPT_COLLECTION, &head_key(job)) else {
        return Ok(None);
    };
    let epoch = json::as_u64_hex(head.get("epoch"))
        .with_context(|| format!("job '{job}': malformed checkpoint head"))?;
    // walk base pointers to the full root (base epochs strictly decrease,
    // so a malformed pointer cannot loop)
    let mut chain: Vec<(u64, Json)> = Vec::new();
    let mut at = epoch;
    loop {
        let meta = store
            .get(CKPT_COLLECTION, &format!("{}/meta", epoch_prefix(job, at)))
            .with_context(|| format!("job '{job}': checkpoint epoch {at} missing meta"))?;
        let base = json::as_u64_hex(meta.get("base"));
        chain.push((at, meta));
        match base {
            Some(b) if b < at => at = b,
            Some(b) => bail!("job '{job}': epoch {at} has non-decreasing base {b}"),
            None => break,
        }
    }
    chain.reverse();

    // replay the chain forward, decoding deltas against accumulated state
    let mut records: BTreeMap<String, Json> = BTreeMap::new();
    for (e, meta) in &chain {
        let prefix = epoch_prefix(job, *e);
        let mut suffixes: Vec<(String, bool)> =
            vec![("global".into(), true), ("metrics".into(), false), ("trace".into(), false)];
        let Some(ids) = meta.get("workers").as_arr() else {
            bail!("job '{job}': checkpoint meta missing worker list");
        };
        for id in ids {
            let Some(id) = id.as_str() else {
                bail!("job '{job}': malformed checkpoint worker list");
            };
            suffixes.push((format!("w/{id}"), true));
        }
        for (suffix, required) in suffixes {
            let raw = store.get(CKPT_COLLECTION, &format!("{prefix}/{suffix}"));
            let Some(raw) = raw else {
                if required {
                    bail!("job '{job}': checkpoint epoch {e} missing record '{suffix}'");
                }
                continue;
            };
            let decoded = decode_record(records.get(&suffix), raw).with_context(|| {
                format!("job '{job}': checkpoint epoch {e} record '{suffix}'")
            })?;
            records.insert(suffix, decoded);
        }
    }

    let (_, meta) = chain.last().expect("chain has the head epoch");
    let round = json::as_u64_hex(meta.get("round")).context("checkpoint meta missing round")?;
    let cursor = json::as_u64_hex(meta.get("cursor")).context("checkpoint meta missing cursor")?;
    let flavor = meta.get("flavor").as_str().unwrap_or("sync").to_string();
    let landed = meta
        .get("landed")
        .as_arr()
        .map(|a| {
            a.iter()
                .filter_map(|s| s.as_str().map(str::to_string))
                .collect()
        })
        .unwrap_or_default();
    let global = records
        .remove("global")
        .with_context(|| format!("job '{job}': checkpoint epoch {epoch} missing global state"))?;
    let metrics = records.remove("metrics").unwrap_or(Json::Null);
    let trace = records.remove("trace").unwrap_or(Json::Null);
    let mut workers = BTreeMap::new();
    let Some(ids) = meta.get("workers").as_arr() else {
        bail!("job '{job}': checkpoint meta missing worker list");
    };
    for id in ids {
        let id = id.as_str().unwrap_or_default();
        let snap = records.remove(&format!("w/{id}")).with_context(|| {
            format!("job '{job}': checkpoint epoch {epoch} missing worker '{id}'")
        })?;
        workers.insert(id.to_string(), snap);
    }
    Ok(Some(JobCheckpoint {
        round,
        cursor,
        flavor,
        landed,
        global,
        workers,
        metrics,
        trace,
    }))
}

// -------------------------------------------------------- delta encoding

/// Wrap `cur` as a delta record against `prev` (its decoded predecessor).
fn delta_record(prev: &Json, cur: &Json) -> Json {
    let mut w = Json::obj();
    w.insert(DELTA_KEY, Json::Obj(delta_value(prev, cur)));
    Json::Obj(w)
}

/// Decode a stored record: plain values pass through, delta wrappers are
/// applied against the accumulated predecessor state.
fn decode_record(prev: Option<&Json>, raw: Json) -> Result<Json> {
    let is_delta = raw
        .as_obj()
        .map(|o| o.len() == 1 && o.contains(DELTA_KEY))
        .unwrap_or(false);
    if !is_delta {
        return Ok(raw);
    }
    let prev = prev.context("delta record without a base predecessor")?;
    let tag = raw
        .get(DELTA_KEY)
        .as_obj()
        .context("malformed delta wrapper")?;
    apply_delta(prev, tag)
}

/// Encode `cur` against `prev` as a one-of tag object:
/// `s` same · `x` XOR float tokens · `a` appended array tail ·
/// `o` per-key object delta · `f` full replacement.
fn delta_value(prev: &Json, cur: &Json) -> Obj {
    let mut t = Obj::new();
    if prev == cur {
        t.insert("s", true);
        return t;
    }
    if let (Json::Arr(p), Json::Arr(c)) = (prev, cur) {
        if p.len() == c.len() {
            if let (Some(pb), Some(cb)) = (f32_bits(p), f32_bits(c)) {
                t.insert("x", xor_tokens(&pb, &cb));
                return t;
            }
        }
        if c.len() > p.len() && c[..p.len()] == p[..] {
            t.insert("a", Json::Arr(c[p.len()..].to_vec()));
            return t;
        }
    }
    if let (Json::Obj(po), Json::Obj(co)) = (prev, cur) {
        // the current key set is authoritative: keys absent here are
        // dropped on decode, keys without a predecessor store full
        let mut d = Obj::new();
        for (k, cv) in co.iter() {
            let enc = match po.get(k) {
                Some(pv) => delta_value(pv, cv),
                None => {
                    let mut f = Obj::new();
                    f.insert("f", cv.clone());
                    f
                }
            };
            d.insert(k.clone(), Json::Obj(enc));
        }
        t.insert("o", Json::Obj(d));
        return t;
    }
    t.insert("f", cur.clone());
    t
}

/// Invert [`delta_value`].
fn apply_delta(prev: &Json, tag: &Obj) -> Result<Json> {
    if tag.contains("s") {
        return Ok(prev.clone());
    }
    if let Some(tokens) = tag.get("x") {
        let tokens = tokens.as_str().context("delta 'x' must be a string")?;
        let base = prev.as_arr().context("delta 'x' against a non-array")?;
        let bits = f32_bits(base).context("delta 'x' against non-f32 floats")?;
        return xor_apply(&bits, tokens);
    }
    if let Some(tail) = tag.get("a") {
        let tail = tail.as_arr().context("delta 'a' must be an array")?;
        let mut out = prev.as_arr().context("delta 'a' against a non-array")?.to_vec();
        out.extend(tail.iter().cloned());
        return Ok(Json::Arr(out));
    }
    if let Some(inner) = tag.get("o") {
        let inner = inner.as_obj().context("delta 'o' must be an object")?;
        let po = prev.as_obj().context("delta 'o' against a non-object")?;
        let mut out = Obj::new();
        for (k, enc) in inner.iter() {
            let enc = enc.as_obj().context("malformed nested delta")?;
            let decoded = if enc.contains("f") {
                enc.get("f").cloned().unwrap()
            } else {
                let pv = po
                    .get(k)
                    .with_context(|| format!("delta key '{k}' has no predecessor"))?;
                apply_delta(pv, enc)?
            };
            out.insert(k.clone(), decoded);
        }
        return Ok(Json::Obj(out));
    }
    if let Some(full) = tag.get("f") {
        return Ok(full.clone());
    }
    bail!("unknown delta tag: {:?}", tag.iter().map(|(k, _)| k.clone()).collect::<Vec<_>>())
}

/// All-numeric array whose every element is exactly representable as f32
/// (model/optimizer state written by `floats_to_json` qualifies; native
/// f64 series and NaNs do not, and fall back to full encoding).
fn f32_bits(arr: &[Json]) -> Option<Vec<u32>> {
    let mut out = Vec::with_capacity(arr.len());
    for v in arr {
        let n = v.as_f64()?;
        let f = n as f32;
        if f as f64 != n {
            return None;
        }
        out.push(f.to_bits());
    }
    Some(out)
}

/// XOR token string: per-element XOR of f32 bit patterns, zero runs
/// collapsed to `z<count>` tokens, non-zero words as bare lowercase hex
/// (≤ 8 chars each vs ~10–19 for a shortest-roundtrip f64 decimal).
fn xor_tokens(prev: &[u32], cur: &[u32]) -> String {
    let mut out = String::new();
    let mut zrun = 0usize;
    let mut push = |s: &str, out: &mut String| {
        if !out.is_empty() {
            out.push(',');
        }
        out.push_str(s);
    };
    for (p, c) in prev.iter().zip(cur) {
        let x = p ^ c;
        if x == 0 {
            zrun += 1;
            continue;
        }
        if zrun > 0 {
            push(&format!("z{zrun}"), &mut out);
            zrun = 0;
        }
        push(&format!("{x:x}"), &mut out);
    }
    if zrun > 0 {
        push(&format!("z{zrun}"), &mut out);
    }
    out
}

/// Invert [`xor_tokens`] against the predecessor bits.
fn xor_apply(prev: &[u32], tokens: &str) -> Result<Json> {
    let mut out = Vec::with_capacity(prev.len());
    let mut i = 0usize;
    for tok in tokens.split(',').filter(|t| !t.is_empty()) {
        if let Some(n) = tok.strip_prefix('z') {
            let n: usize = n.parse().context("bad zero-run token")?;
            for _ in 0..n {
                let p = *prev.get(i).context("zero run past array end")?;
                out.push(Json::Num(f32::from_bits(p) as f64));
                i += 1;
            }
        } else {
            let x = u32::from_str_radix(tok, 16).context("bad xor token")?;
            let p = *prev.get(i).context("xor token past array end")?;
            out.push(Json::Num(f32::from_bits(p ^ x) as f64));
            i += 1;
        }
    }
    anyhow::ensure!(i == prev.len(), "xor tokens cover {i} of {} elements", prev.len());
    Ok(Json::Arr(out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sink_with_store() -> (Arc<CkptSink>, Arc<Store>) {
        let store = Arc::new(Store::in_memory());
        let sink = CkptSink::new("j0", CkptPolicy::every_round(), true);
        sink.bind_store(store.clone());
        (sink, store)
    }

    /// Sink writing full snapshots only (the pre-incremental behavior).
    fn full_sink_with_store() -> (Arc<CkptSink>, Arc<Store>) {
        let store = Arc::new(Store::in_memory());
        let sink = CkptSink::new("j0", CkptPolicy::every_round().with_full_every(0), true);
        sink.bind_store(store.clone());
        (sink, store)
    }

    fn floats(vals: &[f32]) -> Json {
        Json::Arr(vals.iter().map(|v| Json::Num(*v as f64)).collect())
    }

    #[test]
    fn commit_and_load_roundtrip() {
        let (sink, store) = sink_with_store();
        sink.set_flavor("sync");
        sink.publish("w0", Json::Str("s0".into()));
        sink.publish("w1", Json::Str("s1".into()));
        let landed = vec!["w1".to_string(), "w0".to_string()];
        sink.commit(3, 2, Json::Str("g".into()), Json::Null, Json::Null, &landed)
            .unwrap();
        let ck = load_latest(&store, "j0").unwrap().unwrap();
        assert_eq!(ck.round, 3);
        assert_eq!(ck.cursor, 2);
        assert_eq!(ck.flavor, "sync");
        assert_eq!(ck.landed, vec!["w0".to_string(), "w1".to_string()]);
        assert_eq!(ck.global, Json::Str("g".into()));
        assert_eq!(ck.workers.len(), 2);
        assert_eq!(ck.workers["w1"], Json::Str("s1".into()));
        assert!(load_latest(&store, "nope").unwrap().is_none());
    }

    #[test]
    fn newer_epoch_supersedes_and_gcs_older() {
        let (sink, store) = full_sink_with_store();
        sink.publish("w0", Json::Str("r1".into()));
        sink.commit(1, 0, Json::Str("g1".into()), Json::Null, Json::Null, &[])
            .unwrap();
        sink.publish("w0", Json::Str("r2".into()));
        sink.commit(2, 0, Json::Str("g2".into()), Json::Null, Json::Null, &[])
            .unwrap();
        let ck = load_latest(&store, "j0").unwrap().unwrap();
        assert_eq!(ck.round, 2);
        assert_eq!(ck.workers["w0"], Json::Str("r2".into()));
        // every epoch-1 record tombstoned (full snapshots → no live chain
        // reaches epoch 1)
        for key in store.keys(CKPT_COLLECTION) {
            assert!(
                !key.contains(&format!("{:016x}", 1u64)),
                "stale epoch record survived GC: {key}"
            );
        }
    }

    #[test]
    fn non_live_sink_keeps_hub_but_writes_nothing() {
        let store = Arc::new(Store::in_memory());
        let sink = CkptSink::new("j0", CkptPolicy::every_round(), false);
        sink.bind_store(store.clone());
        sink.publish("agg", Json::Str("s".into()));
        sink.commit(1, 0, Json::Null, Json::Null, Json::Null, &[]).unwrap();
        assert!(store.get(CKPT_COLLECTION, "j0/head").is_none());
        // hub still seeds failover
        sink.stage_seed("agg");
        assert_eq!(sink.take_seed("agg"), Some(Json::Str("s".into())));
        assert_eq!(sink.take_seed("agg"), None);
    }

    #[test]
    fn due_respects_cadence() {
        let sink = CkptSink::new(
            "j",
            CkptPolicy {
                every: 2,
                ..CkptPolicy::every_round()
            },
            true,
        );
        assert!(!sink.due(0));
        assert!(!sink.due(1));
        assert!(sink.due(2));
        assert!(sink.due(4));
        let off = CkptSink::new("j", CkptPolicy::default(), true);
        assert!(!off.due(5));
    }

    /// A worker snapshot shaped like the real ones: a model array that
    /// drifts a little each epoch plus scalar round state.
    fn worker_snap(round: u64, model: &[f32]) -> Json {
        let mut o = Json::obj();
        o.insert("round", json::from_u64_hex(round));
        o.insert("flat", floats(model));
        Json::Obj(o)
    }

    #[test]
    fn delta_chain_roundtrips_and_keeps_its_base() {
        let (sink, store) = sink_with_store();
        let mut model: Vec<f32> = (0..64).map(|i| i as f32 * 0.5).collect();
        for round in 1..=5u64 {
            model[3] += 0.25 * round as f32;
            model[40] -= 1.0;
            sink.publish("w0", worker_snap(round, &model));
            let mut g = Json::obj();
            g.insert("round", json::from_u64_hex(round));
            g.insert("flat", floats(&model));
            sink.commit(round, 0, Json::Obj(g), Json::Null, Json::Null, &[])
                .unwrap();
        }
        // epochs 2..=5 are deltas: their global record carries the wrapper
        let raw = store
            .get(CKPT_COLLECTION, &format!("{}/global", epoch_prefix("j0", 4)))
            .unwrap();
        assert!(raw.get(DELTA_KEY).as_obj().is_some(), "epoch 4 not delta-encoded");
        // the chain's full root (epoch 1) must survive GC
        assert!(
            store
                .get(CKPT_COLLECTION, &format!("{}/meta", epoch_prefix("j0", 1)))
                .is_some(),
            "live chain base collected"
        );
        // decoded state equals the newest plain state
        let ck = load_latest(&store, "j0").unwrap().unwrap();
        assert_eq!(ck.round, 5);
        assert_eq!(ck.global.get("flat"), &floats(&model));
        assert_eq!(ck.workers["w0"], worker_snap(5, &model));
    }

    #[test]
    fn full_epoch_resets_the_chain_and_gc_collects_the_old_one() {
        let store = Arc::new(Store::in_memory());
        let sink = CkptSink::new("j0", CkptPolicy::every_round().with_full_every(2), true);
        sink.bind_store(store.clone());
        for round in 1..=3u64 {
            sink.publish("w0", worker_snap(round, &[round as f32]));
            sink.commit(round, 0, Json::Str(format!("g{round}")), Json::Null, Json::Null, &[])
                .unwrap();
        }
        // epoch 3 started a fresh full chain → epochs 1 and 2 collected
        for old in [1u64, 2] {
            assert!(
                store
                    .get(CKPT_COLLECTION, &format!("{}/meta", epoch_prefix("j0", old)))
                    .is_none(),
                "superseded epoch {old} survived GC"
            );
        }
        let ck = load_latest(&store, "j0").unwrap().unwrap();
        assert_eq!(ck.round, 3);
        assert_eq!(ck.global, Json::Str("g3".into()));
    }

    #[test]
    fn incremental_chain_shrinks_journal_bytes() {
        // same commit sequence, once all-full and once incremental: a
        // model where most elements hold still between boundaries (opt
        // state, converged coordinates) plus a growing metrics series
        let run = |full_every: u64| -> u64 {
            let store = Arc::new(Store::in_memory());
            let sink = CkptSink::new(
                "j0",
                CkptPolicy::every_round().with_full_every(full_every),
                true,
            );
            sink.bind_store(store);
            let mut model: Vec<f32> = (0..512).map(|i| (i as f32).sin()).collect();
            let mut series: Vec<Json> = Vec::new();
            for round in 1..=6u64 {
                for i in (0..512).step_by(16) {
                    model[i] += 1e-3 * round as f32;
                }
                series.push(Json::Num(round as f64));
                sink.publish("w0", worker_snap(round, &model));
                let mut g = Json::obj();
                g.insert("round", json::from_u64_hex(round));
                g.insert("flat", floats(&model));
                let mut m = Json::obj();
                m.insert("loss", Json::Arr(series.clone()));
                sink.commit(round, 0, Json::Obj(g), Json::Obj(m), Json::Null, &[])
                    .unwrap();
            }
            sink.bytes_written()
        };
        let full = run(0);
        let incremental = run(8);
        assert!(
            incremental * 2 < full,
            "incremental chain did not shrink journal bytes: {incremental} vs {full}"
        );
    }

    #[test]
    fn xor_tokens_roundtrip_exactly() {
        let prev: Vec<f32> = vec![0.0, 1.5, -2.25, 1e-8, 3.0, 3.0, f32::MAX];
        let cur: Vec<f32> = vec![0.0, 1.5000001, -2.25, 2e-8, 3.0, 3.0, f32::MIN_POSITIVE];
        let pb: Vec<u32> = prev.iter().map(|f| f.to_bits()).collect();
        let cb: Vec<u32> = cur.iter().map(|f| f.to_bits()).collect();
        let toks = xor_tokens(&pb, &cb);
        let out = xor_apply(&pb, &toks).unwrap();
        let want = Json::Arr(cur.iter().map(|f| Json::Num(*f as f64)).collect());
        assert_eq!(out, want);
        // unchanged tail collapses into a zero-run token
        let same = xor_tokens(&pb, &pb);
        assert_eq!(same, format!("z{}", prev.len()));
    }

    #[test]
    fn delta_value_handles_append_drop_and_nan() {
        // append: a grown series stores only its tail
        let p = Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]);
        let c = Json::Arr(vec![Json::Num(1.0), Json::Num(2.0), Json::Num(3.0)]);
        let enc = delta_value(&p, &c);
        assert_eq!(enc.get("a").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(apply_delta(&p, &enc).unwrap(), c);
        // dropped keys vanish on decode; new keys land full
        let p = Json::Obj(Obj::from([("old", Json::Num(1.0)), ("keep", Json::Num(2.0))]));
        let c = Json::Obj(Obj::from([("keep", Json::Num(2.0)), ("new", Json::Str("x".into()))]));
        let enc = delta_value(&p, &c);
        assert_eq!(apply_delta(&p, &enc).unwrap(), c);
        // NaN never matches the f32-exact fast path and falls back to full
        let p = Json::Arr(vec![Json::Num(1.0)]);
        let c = Json::Arr(vec![Json::Num(f64::NAN)]);
        let enc = delta_value(&p, &c);
        assert!(enc.contains("f"));
    }

    #[test]
    fn fault_plan_parses_and_dumps() {
        let plan = FaultPlan::parse("controller@3,rsm-trainer-1@2").unwrap();
        assert!(plan.kills_controller_at(3));
        assert!(!plan.kills_controller_at(2));
        assert!(plan.kills_worker_at("rsm-trainer-1", 2));
        assert!(!plan.kills_worker_at("rsm-trainer-1", 3));
        assert!(plan.controller_kill_between(2, 4));
        assert!(!plan.controller_kill_between(3, 5));
        assert_eq!(FaultPlan::parse(&plan.dump()).unwrap(), plan);
        assert!(FaultPlan::parse("nope").is_err());
        assert!(FaultPlan::parse("w@x").is_err());
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::default());
    }
}
