//! Multi-job control plane: concurrent job admission, placement, and
//! fair-share execution on one shared worker fabric (paper §4/§5 —
//! Flame's apiserver/controller manage *many* FL jobs over shared
//! infrastructure; the single-job [`crate::control::Controller`] is the
//! degenerate case).
//!
//! The [`JobManager`] accepts any number of [`JobSpec`] submissions and
//! drives each through the lifecycle
//!
//! ```text
//! submit ─▶ Queued ──admit──▶ Deploying ─▶ Running ─▶ Completed
//!             │   (capacity)                   │
//!             └───── FIFO wait ◀── release ────┴─────▶ Failed
//! ```
//!
//! * **Admission** checks the job's expanded per-compute demand against a
//!   [`CapacityLedger`] over the registry's advisory capacities. A job
//!   that fits is deployed immediately; one that doesn't waits in a FIFO
//!   queue (head-of-line order — deliberately simple and deterministic).
//!   A job whose demand exceeds total capacity is rejected at submit.
//! * **Execution** multiplexes every admitted job onto **one** shared
//!   virtual-time [`Scheduler`]: each job gets its own fair-share group
//!   (so a 10k-trainer job cannot starve a 5-worker job — see
//!   [`crate::sched`]) and its own scoped [`ChannelManager`] view over
//!   the shared channel fabric (so identically named workers/channels of
//!   concurrent jobs can never collide — see [`crate::channel`]).
//! * **Release** happens on the running fabric: a control-plane *pump*
//!   task wakes whenever a job's last pod terminates, releases its
//!   capacity, persists the terminal state, and admits whatever now fits
//!   — jobs queue and drain without ever pausing the fabric.
//!
//! Every lifecycle transition is persisted to the [`Store`] (collection
//! `job_state`) and streamed through the [`Notifier`] as
//! [`EventKind::JobState`] events.
//!
//! Per-job results are **deterministic**: a job's virtual execution
//! depends only on its own spec, options and seed — never on when the
//! pump admitted it — so a fleet of seeded jobs yields byte-identical
//! per-job reports across runs and runner-pool sizes
//! (`rust/tests/fleet.rs`).

pub mod admission;
pub mod checkpoint;

use std::collections::VecDeque;
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::channel::ChannelManager;
use crate::control::{prepare_expanded, JobOptions, PreparedJob};
use crate::deploy::{FleetDeployer, PodTracker};
use crate::json::Json;
use crate::net::{VTime, VirtualNet};
use crate::notify::{EventKind, Notifier};
use crate::registry::Registry;
use crate::roles::{JobRuntime, ProgramFactory, RoleRegistry};
use crate::sched::{PollOutcome, RunnableTask, Scheduler, Waker};
use crate::store::Store;
use crate::tag::{expand, validate, JobSpec, WorkerConfig};

pub use admission::{CapacityLedger, Demand};
pub use checkpoint::{CkptPolicy, JobCheckpoint};

/// Control-plane job identifier (`<spec name>-<submission counter>`).
pub type JobId = String;

/// Control-plane lifecycle states (persisted in the `job_state`
/// collection and streamed as [`EventKind::JobState`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobPhase {
    /// Accepted, waiting for capacity (FIFO).
    Queued,
    /// Admitted: capacity reserved, workers being staged on the fabric.
    Deploying,
    /// All workers launched.
    Running,
    /// Every pod completed cleanly.
    Completed,
    /// Rejected at admission, failed to deploy, or >= 1 pod failed.
    Failed(String),
}

impl JobPhase {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobPhase::Queued => "queued",
            JobPhase::Deploying => "deploying",
            JobPhase::Running => "running",
            JobPhase::Completed => "completed",
            JobPhase::Failed(_) => "failed",
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self, JobPhase::Completed | JobPhase::Failed(_))
    }
}

/// Per-job bookkeeping inside the fleet.
struct JobSlot {
    id: JobId,
    phase: JobPhase,
    demand: Demand,
    /// Spec + options + the submit-time expansion, parked until
    /// admission (consumed by deploy — Algorithm 1 runs once per job).
    pending: Option<(JobSpec, JobOptions, Vec<WorkerConfig>)>,
    runtime: Option<Arc<JobRuntime>>,
    /// Pods not yet terminal (includes live-extension joiners).
    active_pods: usize,
    /// Every pod ever staged for this job.
    spawned_pods: usize,
    /// Pods a dead predecessor run spawned before this (resumed) run took
    /// over but that never reach this fabric — evicted-before-boundary
    /// workers. Added to `spawned_pods` in the report so a resumed job's
    /// worker count matches the unkilled run's.
    prior_pods: usize,
    failed_pods: usize,
    /// Error recorded while staging workers (pods may still drain).
    deploy_error: Option<String>,
    /// Largest virtual time reached by any of the job's pods.
    finish_at: VTime,
    /// Pump cycle that admitted this job (1 = never waited for capacity).
    admitted_cycle: Option<u64>,
}

impl JobSlot {
    fn new(
        id: JobId,
        demand: Demand,
        pending: Option<(JobSpec, JobOptions, Vec<WorkerConfig>)>,
    ) -> Self {
        Self {
            id,
            phase: JobPhase::Queued,
            demand,
            pending,
            runtime: None,
            active_pods: 0,
            spawned_pods: 0,
            prior_pods: 0,
            failed_pods: 0,
            deploy_error: None,
            finish_at: 0,
            admitted_cycle: None,
        }
    }
}

struct FleetState {
    ledger: CapacityLedger,
    slots: Vec<JobSlot>,
    /// FIFO admission queue of slot indices.
    queue: VecDeque<usize>,
    /// Jobs whose last pod terminated, awaiting pump processing.
    completions: Vec<usize>,
    /// Jobs admitted and not yet processed as complete.
    running_jobs: usize,
    /// Pump cycles so far (cycle 1 is the initial admission pass).
    cycle: u64,
}

/// State shared between the [`JobManager`] and the pump task running on
/// the fleet fabric.
struct FleetCore {
    store: Arc<Store>,
    notifier: Arc<Notifier>,
    registry: RwLock<Registry>,
    /// Role SDK: the fleet's base program registry (per-job overlays
    /// come from each submission's `JobOptions::with_program`).
    programs: RwLock<Arc<RoleRegistry>>,
    sched: Scheduler,
    /// Root of the shared channel fabric; jobs get scoped views.
    chan_root: Arc<ChannelManager>,
    state: Mutex<FleetState>,
    pump_waker: Mutex<Option<Waker>>,
}

impl FleetCore {
    /// Record and broadcast a lifecycle transition.
    fn set_phase(&self, idx: usize, phase: JobPhase) -> Result<()> {
        let id = {
            let mut g = self.state.lock().unwrap();
            g.slots[idx].phase = phase.clone();
            g.slots[idx].id.clone()
        };
        self.store.put("job_state", &id, Json::from(phase.as_str()))?;
        self.notifier.emit(EventKind::JobState, &id, Json::from(phase.as_str()));
        Ok(())
    }

    /// A job that never made it onto the fabric: release its reservation
    /// and record the terminal failure.
    fn release_and_fail(&self, idx: usize, msg: String) {
        {
            let mut g = self.state.lock().unwrap();
            let demand = g.slots[idx].demand.clone();
            g.ledger.release(&demand);
            g.running_jobs -= 1;
        }
        let _ = self.set_phase(idx, JobPhase::Failed(msg));
    }

    /// Process one finished job: terminal phase + capacity release.
    fn finish_job(&self, idx: usize) {
        let phase = {
            let mut g = self.state.lock().unwrap();
            let demand = g.slots[idx].demand.clone();
            g.ledger.release(&demand);
            g.running_jobs -= 1;
            let s = &g.slots[idx];
            // pods the failover desk replaced count as recovered, not
            // failed: the job completed on its replacement topology
            let recovered = s
                .runtime
                .as_ref()
                .and_then(|rt| rt.ckpt.as_ref())
                .map_or(0, |c| c.recovered() as usize);
            if let Some(e) = &s.deploy_error {
                JobPhase::Failed(e.clone())
            } else if s.failed_pods > recovered {
                JobPhase::Failed(format!(
                    "{} worker pod(s) failed",
                    s.failed_pods - recovered
                ))
            } else {
                JobPhase::Completed
            }
        };
        let _ = self.set_phase(idx, phase);
    }

    /// Admit and deploy one queued job onto the running (or about-to-run)
    /// fabric. Capacity was already reserved by the caller.
    fn deploy_job(self: &Arc<Self>, idx: usize) {
        let (id, spec, opts, expanded) = {
            let mut g = self.state.lock().unwrap();
            let cycle = g.cycle;
            let s = &mut g.slots[idx];
            s.admitted_cycle = Some(cycle);
            let (spec, opts, expanded) = s.pending.take().expect("queued job has pending spec");
            (s.id.clone(), spec, opts, expanded)
        };
        let _ = self.set_phase(idx, JobPhase::Deploying);
        let prepared = {
            let reg = self.registry.read().unwrap();
            let programs = self.programs.read().unwrap().clone();
            prepare_expanded(
                &id,
                spec,
                opts,
                &reg,
                &programs,
                self.chan_root.scoped(&id),
                expanded,
            )
        };
        let prepared = match prepared {
            Ok(p) => p,
            Err(e) => {
                self.release_and_fail(idx, format!("deploy failed: {e:#}"));
                return;
            }
        };
        let PreparedJob {
            job,
            workers,
            timeline,
            prior_pods,
            ..
        } = prepared;
        // crash resilience: give the job's checkpoint sink the fleet
        // store so round-boundary commits are durable
        if let Some(sink) = &job.ckpt {
            sink.bind_store(self.store.clone());
        }
        // traced jobs stream round-boundary Trace events on this notifier
        job.trace.bind_notifier(self.notifier.clone());
        let tracker: Arc<dyn PodTracker> = Arc::new(JobTracker {
            core: self.clone(),
            idx,
        });
        // fair-share group: job slot + 1 (group 0 is the pump's)
        let deployer = Arc::new(FleetDeployer::new(self.sched.clone(), idx + 1, tracker));
        if timeline.is_elastic() {
            timeline.bind(deployer.clone(), self.notifier.clone());
        }
        {
            let mut g = self.state.lock().unwrap();
            g.slots[idx].runtime = Some(job.clone());
            g.slots[idx].prior_pods = prior_pods;
        }
        // deploy payload names each channel's requested substrate, same
        // shape as the single-job controller's deploy event
        let mut substrates = Json::obj();
        for c in &job.spec.channels {
            substrates.insert(c.name.as_str(), c.substrate.as_str());
        }
        let mut deploy_payload = Json::obj();
        deploy_payload.insert("workers", workers.len());
        deploy_payload.insert("substrates", substrates);
        self.notifier
            .emit(EventKind::Deploy, &id, Json::Obj(deploy_payload));
        let mut stage_error = None;
        for w in &workers {
            if let Err(e) = deployer.deploy(w.clone(), &job, self.notifier.clone()) {
                stage_error = Some(format!("staging worker '{}': {e:#}", w.id));
                break;
            }
        }
        let staged_any = {
            let g = self.state.lock().unwrap();
            g.slots[idx].spawned_pods > 0
        };
        if let Some(msg) = stage_error {
            if !staged_any {
                // nothing on the fabric: fail and release right here
                self.release_and_fail(idx, msg);
                return;
            }
            // pods already staged must drain; the completion path turns
            // the recorded error into the terminal Failed phase
            self.state.lock().unwrap().slots[idx].deploy_error = Some(msg);
        }
        if !staged_any {
            // a zero-worker job is trivially complete
            self.finish_job(idx);
            return;
        }
        // launch every staged pod (two-phase: all channels joined first)
        let _ = deployer.start();
        let _ = self.set_phase(idx, JobPhase::Running);
    }

    /// One control-plane pump cycle: process completions (in a canonical
    /// order), then admit whatever now fits, FIFO.
    fn pump_cycle(self: &Arc<Self>) -> PollOutcome {
        let done: Vec<usize> = {
            let mut g = self.state.lock().unwrap();
            g.cycle += 1;
            let mut d = std::mem::take(&mut g.completions);
            let finish = |i: &usize| (g.slots[*i].finish_at, *i);
            d.sort_by_key(finish);
            d
        };
        for idx in done {
            self.finish_job(idx);
        }
        loop {
            let next = {
                let mut g = self.state.lock().unwrap();
                let head = g.queue.front().copied();
                match head {
                    Some(idx) if g.ledger.fits(&g.slots[idx].demand) => {
                        g.queue.pop_front();
                        let demand = g.slots[idx].demand.clone();
                        g.ledger.reserve(&demand);
                        g.running_jobs += 1;
                        Some(idx)
                    }
                    _ => None,
                }
            };
            match next {
                Some(idx) => self.deploy_job(idx),
                None => break,
            }
        }
        let g = self.state.lock().unwrap();
        if g.queue.is_empty() && g.running_jobs == 0 && g.completions.is_empty() {
            PollOutcome::Done
        } else {
            PollOutcome::Parked
        }
    }

    /// Mid-tier aggregator failover (armed by `CkptPolicy::failover`):
    /// when an aggregator pod dies mid-run, evict it from the fabric —
    /// which wakes the global's parked quorum collect so the round
    /// completes over the survivors — and schedule a replacement pod
    /// under the **same worker id** through the job's live-extension
    /// timeline (the global drains it at the next round boundary, and
    /// its `assign_dirty` re-partition plus the next weight broadcast
    /// rehydrate the newcomer). The sink stages the dead pod's last
    /// published snapshot as a seed for the replacement's context.
    /// Returns whether a replacement was scheduled.
    fn try_failover(&self, idx: usize, worker: &str, at: VTime) -> bool {
        let (job_id, runtime, running) = {
            let g = self.state.lock().unwrap();
            let s = &g.slots[idx];
            (
                s.id.clone(),
                s.runtime.clone(),
                s.phase == JobPhase::Running,
            )
        };
        let Some(rt) = runtime else { return false };
        let Some(sink) = rt.ckpt.clone() else { return false };
        if !running || !sink.policy().failover {
            return false;
        }
        let Some(cfg) = sink.cfg_of(worker) else { return false };
        // only mid-tier aggregators fail over: they sit on the global's
        // collect path (their death would deadlock the round) yet hold no
        // irreplaceable state (the next broadcast rehydrates them)
        let mid_tier = cfg.role != "global-aggregator"
            && cfg.dataset.is_none()
            && cfg.channels.contains_key("agg-channel")
            && cfg.channels.contains_key("param-channel");
        if !mid_tier {
            return false;
        }
        sink.stage_seed(worker);
        // evict NOW: parked collects recompute their quorum target over
        // the surviving membership instead of waiting forever
        rt.chan_mgr.evict(worker, at);
        // replacement rides the elastic timeline, due immediately at the
        // global's next apply_events drain
        rt.timeline
            .push_entry(0, crate::deploy::ScheduledAction::Deploy(vec![cfg]));
        sink.note_recovered();
        self.notifier.emit(
            EventKind::WorkerStatus,
            &job_id,
            Json::from(format!("failover:{worker}")),
        );
        true
    }

    /// Wake the pump at virtual time 0: job clocks are mutually
    /// incomparable, so waking at a finished job's (possibly huge) final
    /// vtime would sort the pump behind every other job's pending work
    /// and delay capacity release. Vtime 0 gives admission the earliest
    /// possible poll; per-job results cannot depend on it (admitted jobs
    /// start their own clocks at 0 regardless).
    fn wake_pump(&self) {
        if let Some(w) = self.pump_waker.lock().unwrap().as_ref() {
            w.wake(0);
        }
    }
}

/// Observes one job's pods on the shared fabric.
struct JobTracker {
    core: Arc<FleetCore>,
    idx: usize,
}

impl PodTracker for JobTracker {
    fn pod_spawned(&self) {
        let mut g = self.core.state.lock().unwrap();
        let s = &mut g.slots[self.idx];
        s.active_pods += 1;
        s.spawned_pods += 1;
    }

    fn pod_done(&self, worker: &str, at: VTime, failed: bool) {
        if failed {
            // a recovered (failed-over) pod still counts below;
            // finish_job offsets the failed count by the sink's
            // recovered tally
            let _ = self.core.try_failover(self.idx, worker, at);
        }
        let job_finished = {
            let mut g = self.core.state.lock().unwrap();
            let idx = self.idx;
            let s = &mut g.slots[idx];
            s.active_pods -= 1;
            if failed {
                s.failed_pods += 1;
            }
            s.finish_at = s.finish_at.max(at);
            let finished = s.active_pods == 0;
            if finished {
                g.completions.push(idx);
            }
            finished
        };
        if job_finished {
            // the completing pod's poll is still counted as running, so
            // this wake can never race the deadlock detector
            self.core.wake_pump();
        }
    }
}

/// The control-plane pump: a tasklet on the fleet fabric that releases
/// capacity and admits queued jobs the moment any job finishes.
struct Pump {
    core: Arc<FleetCore>,
}

impl RunnableTask for Pump {
    fn name(&self) -> &str {
        "control-plane-pump"
    }

    fn poll(&mut self) -> PollOutcome {
        self.core.pump_cycle()
    }

    fn fail(&mut self, _reason: &str) {
        // the fleet stalled with the pump parked (some job deadlocked and
        // the detector culled every waiter); run_fleet's post-run pass
        // marks the remaining jobs
    }
}

// ------------------------------------------------------------- reports

/// Terminal per-job summary. [`Self::line`] is a stable, fully-precise
/// rendering used by the determinism tests (byte-identical across runs).
#[derive(Debug, Clone)]
pub struct FleetJobReport {
    pub job: JobId,
    pub phase: JobPhase,
    /// Pods that ran (including live-extension joiners).
    pub workers: usize,
    /// Rounds (or async versions) that recorded an evaluation.
    pub rounds: u64,
    pub final_loss: Option<f64>,
    pub final_acc: Option<f64>,
    pub total_bytes: u64,
    /// The job's own final virtual time, seconds.
    pub vtime_s: f64,
}

impl FleetJobReport {
    /// Canonical one-line rendering (full float precision — any
    /// nondeterminism shows up as a byte diff).
    pub fn line(&self) -> String {
        format!(
            "{} phase={} workers={} rounds={} loss={:?} acc={:?} bytes={} vtime_s={:?}",
            self.job,
            self.phase.as_str(),
            self.workers,
            self.rounds,
            self.final_loss,
            self.final_acc,
            self.total_bytes,
            self.vtime_s,
        )
    }
}

/// What a drained fleet returns.
#[derive(Debug)]
pub struct FleetReport {
    pub jobs: Vec<FleetJobReport>,
    pub completed: usize,
    pub failed: usize,
    /// Jobs that waited in the admission queue (not admitted on the
    /// initial pass).
    pub waited: usize,
    /// Largest single-job virtual time, seconds (fleet virtual makespan
    /// under full concurrency).
    pub max_job_vs: f64,
    /// Sum of all jobs' virtual times, seconds (total virtual work).
    pub total_job_vs: f64,
    pub total_rounds: u64,
    /// Fleet throughput: completed jobs per virtual second of makespan.
    pub jobs_per_vs: f64,
    /// Fleet throughput: evaluated rounds per virtual second of makespan.
    pub rounds_per_vs: f64,
    pub wall_s: f64,
}

impl FleetReport {
    /// Stable summary line (excludes wall-clock, so it is deterministic).
    pub fn summary(&self) -> String {
        format!(
            "fleet: jobs={} completed={} failed={} waited={} max_job_vs={:.4} \
             total_job_vs={:.4} rounds={} jobs_per_vs={:.4} rounds_per_vs={:.4}",
            self.jobs.len(),
            self.completed,
            self.failed,
            self.waited,
            self.max_job_vs,
            self.total_job_vs,
            self.total_rounds,
            self.jobs_per_vs,
            self.rounds_per_vs,
        )
    }
}

/// One orphaned job in a [`JobManager::resumable`] listing: persisted id
/// and last journaled phase, plus the flavor tag and round (async: buffer
/// version) of its latest committed checkpoint epoch, when one exists.
#[derive(Debug, Clone)]
pub struct ResumableJob {
    pub id: JobId,
    pub phase: String,
    pub flavor: Option<String>,
    pub round: Option<u64>,
}

impl ResumableJob {
    /// Stable one-line rendering for `flame resume --list`.
    pub fn line(&self) -> String {
        match (&self.flavor, self.round) {
            (Some(f), Some(r)) => {
                format!("{} phase={} flavor={f} epoch={r}", self.id, self.phase)
            }
            _ => format!("{} phase={} (no checkpoint: restarts at round 0)", self.id, self.phase),
        }
    }
}

// ---------------------------------------------------------- JobManager

/// The multi-job control plane (see module docs).
pub struct JobManager {
    core: Arc<FleetCore>,
    counter: u64,
}

impl JobManager {
    /// A manager over the fiab-style single-box registry (unbounded
    /// capacity: every job admits immediately).
    pub fn new(store: Arc<Store>) -> Self {
        Self::with_registry(store, Registry::single_box())
    }

    /// A manager over an explicit registry — admission control enforces
    /// the registered computes' capacities.
    pub fn with_registry(store: Arc<Store>, registry: Registry) -> Self {
        let ledger = CapacityLedger::from_registry(&registry);
        Self {
            core: Arc::new(FleetCore {
                store,
                notifier: Arc::new(Notifier::new()),
                registry: RwLock::new(registry),
                programs: RwLock::new(Arc::new(RoleRegistry::builtin())),
                sched: Scheduler::new(),
                chan_root: ChannelManager::new(Arc::new(VirtualNet::default())),
                state: Mutex::new(FleetState {
                    ledger,
                    slots: Vec::new(),
                    queue: VecDeque::new(),
                    completions: Vec::new(),
                    running_jobs: 0,
                    cycle: 0,
                }),
                pump_waker: Mutex::new(None),
            }),
            counter: 0,
        }
    }

    pub fn notifier(&self) -> Arc<Notifier> {
        self.core.notifier.clone()
    }

    /// The journaling store the control plane persists through.
    pub fn store(&self) -> Arc<Store> {
        self.core.store.clone()
    }

    /// Ids of every submitted job, in submission order.
    pub fn job_ids(&self) -> Vec<JobId> {
        let g = self.core.state.lock().unwrap();
        g.slots.iter().map(|s| s.id.clone()).collect()
    }

    /// Register a role program for every subsequent submission (Role
    /// SDK). Jobs already deployed keep the registry view they bound
    /// against.
    pub fn register_program(&mut self, name: impl Into<String>, factory: ProgramFactory) {
        let mut g = self.core.programs.write().unwrap();
        let mut next = (**g).clone();
        next.register(name, factory);
        *g = Arc::new(next);
    }

    /// Register a compute cluster (journaled, capacity fed to admission).
    pub fn register_compute(&mut self, c: crate::registry::ComputeSpec) -> Result<()> {
        self.core.store.put("computes", &c.name, c.to_json())?;
        let mut g = self.core.state.lock().unwrap();
        g.ledger.set_capacity(&c.name, c.capacity);
        drop(g);
        self.core.registry.write().unwrap().register_compute(c);
        Ok(())
    }

    /// Current lifecycle phase of a submitted job.
    pub fn job_phase(&self, id: &str) -> Option<JobPhase> {
        let g = self.core.state.lock().unwrap();
        g.slots.iter().find(|s| s.id == id).map(|s| s.phase.clone())
    }

    /// Accept a job: persist its spec and expansion, run admission
    /// pre-checks, and queue it for the next [`Self::run_fleet`]. Returns
    /// the job id; fails (with a persisted `Failed` state) when the spec
    /// cannot expand or its demand exceeds total fleet capacity.
    ///
    /// Demand accounts the **peak** worker population across the job's
    /// live-extension timeline, not just the initial expansion — a job
    /// whose `Extend` event grows a tier mid-run reserves the grown
    /// size up front, so live joiners can never overcommit the ledger.
    pub fn submit(&mut self, spec: JobSpec, opts: JobOptions) -> Result<JobId> {
        self.counter += 1;
        let job_id: JobId = format!("{}-{}", spec.name, self.counter);
        self.core.store.put("jobs", &job_id, spec.to_json())?;
        self.enqueue(job_id, spec, opts)
    }

    /// Resume a job from its last round-boundary checkpoint (crash
    /// recovery): the spec comes back from the `jobs` collection, the
    /// latest committed [`JobCheckpoint`] (if any) rides in on the
    /// options, and the job re-enters the admission queue **under its
    /// original id** — per-job determinism then makes the resumed run's
    /// report byte-identical to an unkilled one. A job that never
    /// committed a checkpoint restarts from round 0, which reaches the
    /// same bytes by the same determinism.
    pub fn resume(&mut self, job_id: &str, mut opts: JobOptions) -> Result<JobId> {
        let spec_json = self
            .core
            .store
            .get("jobs", job_id)
            .with_context(|| format!("resume: job '{job_id}' has no persisted spec"))?;
        let spec = JobSpec::from_json(&spec_json).context("resume: decoding persisted spec")?;
        opts.restore = checkpoint::load_latest(&self.core.store, job_id)?.map(Arc::new);
        self.enqueue(job_id.to_string(), spec, opts)
    }

    /// The jobs a restarted manager can pick back up: every persisted job
    /// whose last journaled phase is non-terminal (queued / deploying /
    /// running at the crash), annotated with the flavor and round (buffer
    /// version for async jobs) of its latest committed checkpoint epoch —
    /// `None` round means the job never reached a commit and restarts
    /// from round 0. Sorted by job id so listings and [`Self::resume_all`]
    /// admission order are deterministic. Jobs already slotted in *this*
    /// manager instance are excluded (they are live, not orphaned).
    pub fn resumable(&self) -> Result<Vec<ResumableJob>> {
        let live = self.job_ids();
        let mut ids = self.core.store.keys("job_state");
        ids.sort();
        let mut out = Vec::new();
        for id in ids {
            if live.contains(&id) {
                continue;
            }
            let phase = self
                .core
                .store
                .get("job_state", &id)
                .and_then(|v| v.as_str().map(str::to_string))
                .unwrap_or_default();
            if matches!(phase.as_str(), "completed" | "failed") {
                continue;
            }
            // no persisted spec -> nothing to re-admit (reject() journals
            // a phase even for specs that never stored)
            if self.core.store.get("jobs", &id).is_none() {
                continue;
            }
            let ck = checkpoint::load_latest(&self.core.store, &id)?;
            out.push(ResumableJob {
                flavor: ck.as_ref().map(|c| c.flavor.clone()),
                round: ck.as_ref().map(|c| c.round),
                id,
                phase,
            });
        }
        Ok(out)
    }

    /// Fleet-wide crash recovery: re-admit every [`Self::resumable`] job
    /// through [`Self::resume`] — original ids, latest checkpoints, the
    /// normal admission/capacity path — in deterministic (sorted-id)
    /// order. `opts_for` supplies each job's runtime options (options are
    /// not journaled: they carry live objects — programs, compute, data
    /// plans). Returns the re-admitted ids; the next
    /// [`Self::run_fleet`] drives them to completion.
    pub fn resume_all<F>(&mut self, mut opts_for: F) -> Result<Vec<JobId>>
    where
        F: FnMut(&ResumableJob) -> JobOptions,
    {
        let jobs = self.resumable()?;
        let mut ids = Vec::with_capacity(jobs.len());
        for job in &jobs {
            let opts = opts_for(job);
            ids.push(self.resume(&job.id, opts)?);
        }
        Ok(ids)
    }

    /// Shared tail of [`Self::submit`] / [`Self::resume`]: admission
    /// pre-checks, expansion persistence, slot + queue registration.
    fn enqueue(&mut self, job_id: JobId, spec: JobSpec, opts: JobOptions) -> Result<JobId> {
        // spec lints stream as events; they never fail the submission
        for warning in validate::lint(&spec) {
            self.core
                .notifier
                .emit(EventKind::SpecLint, &job_id, Json::from(warning));
        }
        let expanded = {
            let reg = self.core.registry.read().unwrap();
            expand(&spec, &reg)
        };
        let workers = match expanded {
            Ok(w) => w,
            Err(e) => {
                let msg = format!("admission: TAG expansion failed: {e:#}");
                return Err(self.reject(&job_id, Demand::new(), msg));
            }
        };
        // Role SDK: resolve the spec's bindings now (base registry plus
        // this submission's `with_program` overlays), so an unknown
        // program rejects the submission synchronously — matching
        // `Controller::submit`. Roles introduced later by extend deltas
        // are re-resolved against the union spec at deploy (a clean
        // job-level failure; never a pod).
        if let Err(e) = self.resolve_bindings(&spec, &opts) {
            let msg = format!("admission: {e:#}");
            return Err(self.reject(&job_id, Demand::new(), msg));
        }
        let demand = match self.peak_demand(&spec, &opts, &workers) {
            Ok(d) => d,
            Err(e) => {
                let msg = format!("admission: resolving event timeline: {e:#}");
                return Err(self.reject(&job_id, Demand::new(), msg));
            }
        };
        let schedulable = {
            let g = self.core.state.lock().unwrap();
            g.ledger.can_ever_fit(&demand)
        };
        if !schedulable {
            let msg = format!(
                "admission: demand {demand:?} exceeds registered compute capacity \
                 (job can never be placed)"
            );
            return Err(self.reject(&job_id, demand, msg));
        }
        self.core
            .store
            .put_batch(
                "workers",
                workers
                    .iter()
                    .map(|w| (format!("{job_id}/{}", w.id), w.to_json())),
            )
            .context("persisting expansion")?;
        let idx = self.push_slot(JobSlot::new(
            job_id.clone(),
            demand,
            Some((spec, opts, workers)),
        ));
        self.core.set_phase(idx, JobPhase::Queued)?;
        self.core.state.lock().unwrap().queue.push_back(idx);
        Ok(job_id)
    }

    /// Submit-time binding resolution (see [`Self::submit`]): every role
    /// of the spec — including roles introduced by `Extend` deltas, whose
    /// workers the timeline deploys mid-run — must resolve against the
    /// fleet registry overlaid with the submission's per-job programs.
    /// Same [`RoleRegistry::overlaid`] + [`RoleRegistry::resolve_all`]
    /// pair `prepare_expanded` applies at deploy, so acceptance and
    /// deploy can never diverge.
    fn resolve_bindings(&self, spec: &JobSpec, opts: &JobOptions) -> Result<()> {
        let base = self.core.programs.read().unwrap().clone();
        let effective = RoleRegistry::overlaid(&base, &opts.programs);
        let flavor = spec.resolved_flavor();
        effective.resolve_all(spec, flavor)?;
        let mut events: Vec<&crate::tag::TopologyEvent> =
            spec.events.iter().chain(opts.events.iter()).collect();
        events.sort_by_key(|e| e.at_us());
        let mut cur = spec.clone();
        cur.events.clear();
        for ev in events {
            if let crate::tag::TopologyEvent::Extend { delta, .. } = ev {
                cur = delta.apply(&cur).context("applying topology delta")?;
                effective.resolve_all(&cur, flavor)?;
            }
        }
        Ok(())
    }

    /// Per-compute demand at the job's busiest phase: the maximum over
    /// the initial expansion and every `Extend`ed topology in the event
    /// timeline (evictions never release ledger capacity mid-job, so the
    /// running maximum is exactly what the fabric can be asked to hold).
    fn peak_demand(
        &self,
        spec: &JobSpec,
        opts: &JobOptions,
        workers: &[WorkerConfig],
    ) -> Result<Demand> {
        let mut demand = CapacityLedger::demand_of(workers);
        let mut events: Vec<&crate::tag::TopologyEvent> =
            spec.events.iter().chain(opts.events.iter()).collect();
        if events.iter().all(|e| !matches!(e, crate::tag::TopologyEvent::Extend { .. })) {
            return Ok(demand);
        }
        events.sort_by_key(|e| e.at_us());
        let reg = self.core.registry.read().unwrap();
        let mut cur = spec.clone();
        cur.events.clear();
        for ev in events {
            if let crate::tag::TopologyEvent::Extend { delta, .. } = ev {
                cur = delta.apply(&cur).context("applying topology delta")?;
                let ws = expand(&cur, &reg).context("expanding extended TAG")?;
                for (c, n) in CapacityLedger::demand_of(&ws) {
                    let slot = demand.entry(c).or_insert(0);
                    *slot = (*slot).max(n);
                }
            }
        }
        Ok(demand)
    }

    /// Record a submit-time rejection: a slot with a persisted terminal
    /// `Failed` state, and the error to hand back to the caller.
    fn reject(&self, job_id: &str, demand: Demand, msg: String) -> anyhow::Error {
        let idx = self.push_slot(JobSlot::new(job_id.to_string(), demand, None));
        let _ = self.core.set_phase(idx, JobPhase::Failed(msg.clone()));
        anyhow::anyhow!("job {job_id}: {msg}")
    }

    fn push_slot(&self, slot: JobSlot) -> usize {
        let mut g = self.core.state.lock().unwrap();
        let idx = g.slots.len();
        g.slots.push(slot);
        idx
    }

    /// Drive every queued job to a terminal state on one shared fabric.
    /// `runners` bounds the pool (0 = one per CPU core). Returns when the
    /// fabric drains; every submitted job is then `Completed` or `Failed`
    /// in the store.
    pub fn run_fleet(&mut self, runners: usize) -> Result<FleetReport> {
        let wall0 = Instant::now();
        let core = self.core.clone();
        let pump_id = core.sched.spawn_in(0, Box::new(Pump { core: core.clone() }));
        *core.pump_waker.lock().unwrap() = Some(core.sched.waker(pump_id));
        let n = if runners == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        } else {
            runners
        };
        core.sched.run(n);
        *core.pump_waker.lock().unwrap() = None;

        // post-run: settle anything the pump could not (a stalled fleet)
        let leftovers: Vec<usize> = {
            let mut g = core.state.lock().unwrap();
            let mut d = std::mem::take(&mut g.completions);
            let finish = |i: &usize| (g.slots[*i].finish_at, *i);
            d.sort_by_key(finish);
            d
        };
        for idx in leftovers {
            core.finish_job(idx);
        }
        let unsettled: Vec<(usize, JobPhase)> = {
            let g = core.state.lock().unwrap();
            g.slots
                .iter()
                .enumerate()
                .filter(|(_, s)| !s.phase.is_terminal())
                .map(|(i, s)| {
                    let why = match s.phase {
                        JobPhase::Queued => "starved in the admission queue (fleet stalled)",
                        _ => "fabric drained before the job finished (deadlocked pods)",
                    };
                    (i, JobPhase::Failed(why.to_string()))
                })
                .collect()
        };
        for (idx, phase) in unsettled {
            let _ = core.set_phase(idx, phase);
        }
        self.core.store.flush()?;

        // assemble the report
        let g = core.state.lock().unwrap();
        let mut jobs = Vec::with_capacity(g.slots.len());
        let mut completed = 0;
        let mut failed = 0;
        let mut waited = 0;
        let mut max_vs = 0f64;
        let mut total_vs = 0f64;
        let mut total_rounds = 0u64;
        for s in &g.slots {
            let (rounds, loss, acc, bytes, vtime_s) = match &s.runtime {
                Some(rt) => (
                    rt.metrics.series("acc").len() as u64,
                    rt.metrics.last("loss"),
                    rt.metrics.last("acc"),
                    rt.metrics.total_bytes(),
                    rt.metrics.last("vtime_s").unwrap_or(0.0),
                ),
                None => (0, None, None, 0, 0.0),
            };
            match s.phase {
                JobPhase::Completed => completed += 1,
                JobPhase::Failed(_) => failed += 1,
                _ => {}
            }
            if s.admitted_cycle.map_or(false, |c| c > 1) {
                waited += 1;
            }
            max_vs = max_vs.max(vtime_s);
            total_vs += vtime_s;
            total_rounds += rounds;
            jobs.push(FleetJobReport {
                job: s.id.clone(),
                phase: s.phase.clone(),
                workers: s.spawned_pods + s.prior_pods,
                rounds,
                final_loss: loss,
                final_acc: acc,
                total_bytes: bytes,
                vtime_s,
            });
        }
        let denom = if max_vs > 0.0 { max_vs } else { 1.0 };
        Ok(FleetReport {
            completed,
            failed,
            waited,
            max_job_vs: max_vs,
            total_job_vs: total_vs,
            total_rounds,
            jobs_per_vs: completed as f64 / denom,
            rounds_per_vs: total_rounds as f64 / denom,
            wall_s: wall0.elapsed().as_secs_f64(),
            jobs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::Backend;
    use crate::registry::ComputeSpec;
    use crate::topo;

    fn small_job(name: &str, trainers: usize) -> JobSpec {
        topo::classical(trainers, Backend::P2p)
            .name(name)
            .rounds(2)
            .set("lr", Json::Num(0.5))
            .set("local_steps", 1usize)
            .build()
    }

    fn small_opts() -> JobOptions {
        JobOptions::mock().with_data(24, 48, crate::data::Partition::Iid, 7)
    }

    fn bounded_manager(cap_a: usize, cap_b: usize) -> JobManager {
        let mut reg = Registry::new();
        reg.register_compute(ComputeSpec::new("a", "*", cap_a));
        reg.register_compute(ComputeSpec::new("b", "*", cap_b));
        JobManager::with_registry(Arc::new(Store::in_memory()), reg)
    }

    #[test]
    fn two_concurrent_jobs_complete_on_one_fabric() {
        let mut m = JobManager::new(Arc::new(Store::in_memory()));
        let a = m.submit(small_job("cfl", 3), small_opts()).unwrap();
        let b = m.submit(small_job("cfl", 4), small_opts()).unwrap();
        assert_ne!(a, b, "submission counter disambiguates equal names");
        let report = m.run_fleet(2).unwrap();
        assert_eq!(report.completed, 2);
        assert_eq!(report.failed, 0);
        for j in &report.jobs {
            assert_eq!(j.phase, JobPhase::Completed);
            assert!(j.final_acc.is_some(), "{}", j.line());
            assert!(j.vtime_s > 0.0);
        }
        assert_eq!(report.jobs[0].workers, 4);
        assert_eq!(report.jobs[1].workers, 5);
    }

    #[test]
    fn lifecycle_transitions_persist_and_stream() {
        let store = Arc::new(Store::in_memory());
        let mut m = JobManager::new(store.clone());
        let rx = m.notifier().subscribe(Some(EventKind::JobState), None);
        let id = m.submit(small_job("cfl", 2), small_opts()).unwrap();
        assert_eq!(m.job_phase(&id), Some(JobPhase::Queued));
        m.run_fleet(1).unwrap();
        assert_eq!(m.job_phase(&id), Some(JobPhase::Completed));
        assert_eq!(
            store.get("job_state", &id).unwrap().as_str(),
            Some("completed")
        );
        let states: Vec<String> = rx
            .try_iter()
            .map(|e| e.payload.as_str().unwrap().to_string())
            .collect();
        assert_eq!(states, vec!["queued", "deploying", "running", "completed"]);
    }

    #[test]
    fn capacity_exhaustion_queues_then_admits_fifo() {
        // each cfl job expands to 4 workers placed a,b,a + global on a
        // (least-loaded + round-robin), i.e. demand {a: 3, b: 1};
        // capacity 4+2 holds exactly one job at a time
        let mut m = bounded_manager(4, 2);
        let a = m.submit(small_job("cfl", 3), small_opts()).unwrap();
        let b = m.submit(small_job("cfl", 3), small_opts()).unwrap();
        let c = m.submit(small_job("cfl", 3), small_opts()).unwrap();
        let report = m.run_fleet(2).unwrap();
        assert_eq!(report.completed, 3, "{}", report.summary());
        // FIFO: first job never waited; the rest did
        assert!(report.waited >= 2, "{}", report.summary());
        for id in [&a, &b, &c] {
            assert_eq!(m.job_phase(id), Some(JobPhase::Completed), "{id}");
        }
    }

    #[test]
    fn oversized_job_is_rejected_at_submit_with_persisted_failure() {
        let store = Arc::new(Store::in_memory());
        let mut reg = Registry::new();
        reg.register_compute(ComputeSpec::new("tiny", "*", 2));
        let mut m = JobManager::with_registry(store.clone(), reg);
        let err = m.submit(small_job("cfl", 8), small_opts()).unwrap_err();
        assert!(format!("{err:#}").contains("capacity"), "{err:#}");
        assert_eq!(store.get("job_state", "cfl-1").unwrap().as_str(), Some("failed"));
        // the fleet still runs (empty) and reports the rejection
        let report = m.run_fleet(1).unwrap();
        assert_eq!(report.failed, 1);
        assert_eq!(report.completed, 0);
    }

    #[test]
    fn extend_events_reserve_peak_demand_at_submit() {
        let extend_spec = |rounds: u64| {
            topo::classical(2, Backend::P2p)
                .name("ext")
                .rounds(rounds)
                .set("lr", Json::Num(0.5))
                .set("local_steps", 1usize)
                .build()
        };
        let mk_events = |spec: &JobSpec| {
            let delta = crate::tag::delta::add_tier_delta(spec, 1).unwrap();
            vec![crate::tag::TopologyEvent::Extend { at_us: 1, delta }]
        };
        // classical(2) = 3 initial workers; the extend grows a 1-aggregator
        // middle tier -> peak 4. Capacity 3 must reject at submit rather
        // than let the live joiner overcommit the ledger mid-run.
        let mut reg = Registry::new();
        reg.register_compute(ComputeSpec::new("solo", "*", 3));
        let mut m = JobManager::with_registry(Arc::new(Store::in_memory()), reg);
        let spec = extend_spec(3);
        let events = mk_events(&spec);
        let err = m
            .submit(spec, small_opts().with_events(events))
            .unwrap_err();
        assert!(format!("{err:#}").contains("capacity"), "{err:#}");
        // with room for the peak, the job admits AND its live extension
        // deploys on the shared fabric
        let mut reg = Registry::new();
        reg.register_compute(ComputeSpec::new("solo", "*", 4));
        let mut m = JobManager::with_registry(Arc::new(Store::in_memory()), reg);
        let spec = extend_spec(3);
        let events = mk_events(&spec);
        let id = m.submit(spec, small_opts().with_events(events)).unwrap();
        let report = m.run_fleet(2).unwrap();
        assert_eq!(m.job_phase(&id), Some(JobPhase::Completed), "{}", report.summary());
        // 3 initial pods + the live-deployed aggregator
        assert_eq!(report.jobs[0].workers, 4);
    }

    #[test]
    fn realm_mismatch_fails_admission_cleanly() {
        let mut reg = Registry::new();
        reg.register_compute(ComputeSpec::new("eu", "eu", 16));
        let mut m = JobManager::with_registry(Arc::new(Store::in_memory()), reg);
        let mut spec = small_job("cfl", 2);
        spec.datasets[0].realm = "us/east".into();
        assert!(m.submit(spec, small_opts()).is_err());
    }

    #[test]
    fn empty_fleet_run_returns_immediately() {
        let mut m = JobManager::new(Arc::new(Store::in_memory()));
        let report = m.run_fleet(1).unwrap();
        assert!(report.jobs.is_empty());
        assert_eq!(report.completed, 0);
    }

    #[test]
    fn failing_job_does_not_poison_its_neighbours() {
        let mut m = JobManager::new(Arc::new(Store::in_memory()));
        let good = m.submit(small_job("cfl", 3), small_opts()).unwrap();
        // an unknown hyper algorithm fails at deploy (prepare), after
        // admission — the slot must turn Failed without touching the
        // healthy job
        let mut bad = small_job("cfl", 2);
        bad.hyper = {
            let mut o = Json::obj();
            o.insert("algorithm", "no-such-algo");
            Json::Obj(o)
        };
        let bad_id = m.submit(bad, small_opts()).unwrap();
        let report = m.run_fleet(2).unwrap();
        assert_eq!(m.job_phase(&good), Some(JobPhase::Completed));
        match m.job_phase(&bad_id) {
            Some(JobPhase::Failed(msg)) => {
                assert!(msg.contains("deploy failed"), "{msg}")
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        assert_eq!(report.completed, 1);
        assert_eq!(report.failed, 1);
    }
}
