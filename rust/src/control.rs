//! Controller — the core of the management plane (paper §5.1, §5.2).
//!
//! Responsibilities, exactly as the paper lists them: (i) process
//! submissions and manage state via the journaling store; (ii) expand the
//! TAG into a physical topology and drive worker deployment through the
//! per-orchestrator deployers; (iii) monitor progress (worker status
//! events) and finish the job, revoking deployments.
//!
//! `submit` is the full §5.2 workflow in one call: store spec → expand →
//! store workers → deploy-event → pods/agents → run → collect → revoke.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::algos::TrainingConfig;
use crate::channel::{ChannelManager, RECV_TIMEOUT};
use crate::controlplane::checkpoint::{CkptPolicy, CkptSink, JobCheckpoint};
use crate::data::{make_federated, Partition};
use crate::deploy::{
    Deployer, DeployerSet, PodStatus, ScheduledAction, SimDeployer, ThreadDeployer,
    TimelineEntry, TopologyTimeline,
};
use crate::json::Json;
use crate::metrics::MetricsHub;
use crate::net::VirtualNet;
use crate::notify::{EventKind, Notifier};
use crate::registry::Registry;
use crate::roles::{JobRuntime, ProgramFactory, RoleRegistry};
use crate::runtime::{Compute, ComputeTimeModel};
use crate::store::Store;
use crate::tag::delta::diff_workers;
use crate::tag::{expand, validate, Flavor, JobSpec, TopologyEvent, WorkerConfig};

/// How the sim orchestrator executes a job's workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Executor {
    /// The cooperative worker fabric: all workers multiplexed over a
    /// bounded runner pool (`runners == 0` means one per CPU core). The
    /// default — scales to tens of thousands of workers.
    Cooperative { runners: usize },
    /// One OS thread per worker (the seed's execution model). Kept for
    /// parity testing and preemptive isolation; capped by the OS thread
    /// limit.
    ThreadPerWorker,
}

impl Default for Executor {
    fn default() -> Self {
        Executor::Cooperative { runners: 0 }
    }
}

/// Fold one extension phase's TAG into the runtime union spec: latest
/// definition of each role/channel/dataset name wins, names the new phase
/// dropped are retained. Initially deployed workers resolve their
/// channels against this union even after an event removes or replaces
/// them, and late joiners find everything their phase introduced.
fn merge_spec_union(union: &mut JobSpec, next: &JobSpec) {
    for r in &next.roles {
        match union.roles.iter_mut().find(|x| x.name == r.name) {
            Some(slot) => *slot = r.clone(),
            None => union.roles.push(r.clone()),
        }
    }
    for c in &next.channels {
        match union.channels.iter_mut().find(|x| x.name == c.name) {
            Some(slot) => *slot = c.clone(),
            None => union.channels.push(c.clone()),
        }
    }
    for d in &next.datasets {
        match union.datasets.iter_mut().find(|x| x.name == d.name) {
            Some(slot) => *slot = d.clone(),
            None => union.datasets.push(d.clone()),
        }
    }
}

/// Blocking-receive stall guard scaled with deployment size: big fan-ins
/// legitimately wait a long wall-clock time for their slowest peer, and a
/// 10k-worker run must not false-stall on the seed's fixed 60 s.
/// (Cooperative execution ignores this — stalls there are detected
/// instantly as virtual-time deadlocks.)
fn auto_recv_timeout(workers: usize) -> Duration {
    RECV_TIMEOUT.max(Duration::from_millis(10 * workers as u64))
}

/// Per-job execution options (what the paper's job configuration carries
/// beyond the TAG itself).
pub struct JobOptions {
    pub compute: Arc<dyn Compute>,
    /// He-init seed for the global model (None = zeros, fine for the mock).
    pub init_flat: Option<Vec<f32>>,
    pub time_model: ComputeTimeModel,
    /// Samples per trainer shard / held-out test size.
    pub per_shard: usize,
    pub test_n: usize,
    pub partition: Partition,
    pub noise_sigma: f32,
    pub data_seed: u64,
    /// Hook to shape the virtual network before workers start (straggler
    /// links etc. — the `tc` stand-in).
    pub configure_net: Option<Box<dyn FnOnce(&VirtualNet) + Send>>,
    /// Worker execution model for the sim orchestrator.
    pub executor: Executor,
    /// Blocking-receive stall guard; `None` auto-scales with worker count.
    pub recv_timeout: Option<Duration>,
    /// Scripted live-extension timeline (join/leave/extend-tier events at
    /// virtual timestamps), merged with any events the spec itself
    /// declares. Requires the cooperative executor.
    pub events: Vec<TopologyEvent>,
    /// Role SDK: per-job program registrations, overlaid on the
    /// controller's base [`RoleRegistry`] at prepare. This is how a
    /// custom mechanism (e.g. `sim::run_fedprox`) binds spec-declared
    /// `program:` names without touching global state.
    pub programs: Vec<(String, ProgramFactory)>,
    /// Crash-resilience policy: round-boundary checkpoints through the
    /// store, injectable controller kills, aggregator failover. `None`
    /// leaves resilience off (no sink is built).
    pub ckpt: Option<CkptPolicy>,
    /// Checkpoint to rehydrate from (set by `JobManager::resume`; role
    /// contexts pull their saved state out at build time).
    pub restore: Option<Arc<JobCheckpoint>>,
}

impl JobOptions {
    pub fn mock() -> Self {
        let compute: Arc<dyn Compute> = Arc::new(crate::runtime::MockCompute::default_mlp());
        Self {
            compute,
            init_flat: None,
            time_model: ComputeTimeModel::FixedPerStep(2_000),
            per_shard: 128,
            test_n: 256,
            partition: Partition::Iid,
            noise_sigma: 0.5,
            data_seed: 0,
            configure_net: None,
            executor: Executor::default(),
            recv_timeout: None,
            events: Vec::new(),
            programs: Vec::new(),
            ckpt: None,
            restore: None,
        }
    }

    /// Arm crash resilience for this job (round-boundary checkpoints,
    /// injected kills, aggregator failover — see [`CkptPolicy`]).
    pub fn with_ckpt(mut self, policy: CkptPolicy) -> Self {
        self.ckpt = Some(policy);
        self
    }

    /// Register a program for this job only (Role SDK): the factory is
    /// overlaid on the controller's base registry at prepare, so the
    /// spec's `program:` fields (or custom `bind_default` rules) can
    /// reach it.
    pub fn with_program(mut self, name: impl Into<String>, factory: ProgramFactory) -> Self {
        self.programs.push((name.into(), factory));
        self
    }

    pub fn with_executor(mut self, e: Executor) -> Self {
        self.executor = e;
        self
    }

    pub fn with_events(mut self, events: Vec<TopologyEvent>) -> Self {
        self.events = events;
        self
    }

    pub fn with_recv_timeout(mut self, t: Duration) -> Self {
        self.recv_timeout = Some(t);
        self
    }

    pub fn with_compute(mut self, c: Arc<dyn Compute>) -> Self {
        self.compute = c;
        self
    }

    pub fn with_net(mut self, f: impl FnOnce(&VirtualNet) + Send + 'static) -> Self {
        self.configure_net = Some(Box::new(f));
        self
    }

    pub fn with_time(mut self, tm: ComputeTimeModel) -> Self {
        self.time_model = tm;
        self
    }

    pub fn with_data(
        mut self,
        per_shard: usize,
        test_n: usize,
        partition: Partition,
        seed: u64,
    ) -> Self {
        self.per_shard = per_shard;
        self.test_n = test_n;
        self.partition = partition;
        self.data_seed = seed;
        self
    }

    pub fn with_init(mut self, flat: Vec<f32>) -> Self {
        self.init_flat = Some(flat);
        self
    }

    pub fn with_sigma(mut self, sigma: f32) -> Self {
        self.noise_sigma = sigma;
        self
    }
}

/// What a finished job returns to the caller.
#[derive(Debug)]
pub struct JobReport {
    pub job: String,
    pub workers: usize,
    pub metrics: Arc<MetricsHub>,
    pub final_loss: Option<f64>,
    pub final_acc: Option<f64>,
    pub total_bytes: u64,
    /// Largest virtual time reached by any recorded series.
    pub vtime_s: f64,
    pub wall_s: f64,
    /// Timing breakdown of the submission path (Table 6's measurements).
    pub expansion_s: f64,
    pub db_write_s: f64,
    /// The job's trace hub (disabled for untraced jobs): spans, phase
    /// tables and the Chrome trace-event export.
    pub trace: Arc<crate::trace::TraceHub>,
}

/// Everything a deployer needs to run one prepared job: the shared
/// runtime (channels joined lazily per worker), the initial worker set,
/// the resolved live-extension timeline, and the submission-path timing.
pub(crate) struct PreparedJob {
    pub job: Arc<JobRuntime>,
    pub workers: Vec<WorkerConfig>,
    pub timeline: Arc<TopologyTimeline>,
    pub recv_timeout: Duration,
    pub expansion_s: f64,
    /// Resume bookkeeping: pods the dead predecessor run spawned that
    /// this deployment will never stage (evicted before the checkpoint
    /// boundary) — the fleet report adds them back so a resumed job's
    /// worker count matches the unkilled run's.
    pub prior_pods: usize,
}

/// The submission pipeline up to (but excluding) deployment: expand the
/// TAG, validate the training configuration, resolve the live-extension
/// timeline into precomputed work lists, materialise data shards, and
/// build the shared [`JobRuntime`]. Shared by [`Controller::submit`]
/// (one job, its own channel fabric) and the multi-job
/// [`crate::controlplane::JobManager`] (many jobs, per-job scoped views
/// over one shared fabric — `chan_mgr` carries the scope).
pub(crate) fn prepare_job(
    job_label: &str,
    spec: JobSpec,
    opts: JobOptions,
    registry: &Registry,
    programs: &Arc<RoleRegistry>,
    chan_mgr: Arc<ChannelManager>,
) -> Result<PreparedJob> {
    let t_exp = Instant::now();
    let workers = expand(&spec, registry).context("TAG expansion failed")?;
    let expansion_s = t_exp.elapsed().as_secs_f64();
    let mut prepared =
        prepare_expanded(job_label, spec, opts, registry, programs, chan_mgr, workers)?;
    prepared.expansion_s = expansion_s;
    Ok(prepared)
}

/// [`prepare_job`] for a caller that already ran the expansion (the
/// multi-job control plane expands at submit for admission accounting and
/// must not pay Algorithm 1 twice). `workers` must be `expand(&spec,
/// registry)`'s output for this exact spec; `expansion_s` is reported as
/// zero.
pub(crate) fn prepare_expanded(
    job_label: &str,
    spec: JobSpec,
    mut opts: JobOptions,
    registry: &Registry,
    programs: &Arc<RoleRegistry>,
    chan_mgr: Arc<ChannelManager>,
    workers: Vec<WorkerConfig>,
) -> Result<PreparedJob> {
    let expansion_s = 0.0;
    let tcfg = TrainingConfig::from_hyper(&spec.hyper)?;

    // Role SDK: fix the job's flavour (declared tag.flavor, or the
    // validate-time inference) and the effective registry (base plus
    // per-job `with_program` overlays). Bindings are resolved further
    // down, once the runtime union spec exists — so roles introduced by
    // live-extension deltas are covered too.
    let flavor = spec.resolved_flavor();
    let programs = RoleRegistry::overlaid(programs, &opts.programs);

    if flavor == Flavor::Coordinated
        && matches!(
            tcfg.aggregation,
            crate::algos::AggregationPolicy::Asynchronous { .. }
        )
    {
        bail!(
            "asynchronous aggregation with a coordinator role is not supported: \
             the coordinator's per-round assignment protocol is synchronous \
             (use async on C-FL/H-FL, or sync CO-FL)"
        );
    }
    if flavor == Flavor::Coordinated && tcfg.quorum < 1.0 {
        bail!(
            "quorum fractions are not supported with a coordinator role: CO-FL's \
             ack/report round-trip is a full barrier (an unacked straggler would \
             strand in report); use quorum on C-FL/H-FL"
        );
    }

    // Live topology extension: merge spec-declared and option-supplied
    // events, then resolve each into a concrete worker patch *now* —
    // the running fabric only executes precomputed work lists. The
    // runtime spec becomes the final (union) TAG so late-joining
    // channels and roles resolve, while the initial deployment stays
    // the pre-extension expansion.
    let mut events: Vec<TopologyEvent> = spec.events.clone();
    events.append(&mut opts.events);
    events.sort_by_key(|e| e.at_us());
    // The runtime spec is the *union across phases*: every event folds
    // its roles/channels/datasets in by name (latest definition wins,
    // dropped names are retained), so both the initial expansion's
    // workers and late joiners resolve their channels and shards.
    let mut runtime_spec = spec.clone();
    runtime_spec.events.clear();
    let mut entries: Vec<TimelineEntry> = Vec::new();
    // Per-event marks for checkpoint resume: after each event, how many
    // timeline entries exist and what the live worker set looks like
    // (including in-place sequencer mutations, which never appear as
    // entries). A resumed job replays the first `cursor` entries by
    // jumping to the matching mark — boundaries never split an event, so
    // the cursor always aligns with one.
    let mut phase_marks: Vec<(usize, Vec<WorkerConfig>)> = Vec::new();
    let mut live_set: Vec<WorkerConfig> = workers.clone();
    if !events.is_empty() {
        if flavor == Flavor::Coordinated {
            bail!(
                "live topology events are not supported with a coordinator role \
                 (CO-FL runs its own membership protocol)"
            );
        }
        if matches!(
            tcfg.aggregation,
            crate::algos::AggregationPolicy::Asynchronous { .. }
        ) {
            bail!("live topology events require synchronous aggregation");
        }
        if matches!(opts.executor, Executor::ThreadPerWorker) {
            bail!(
                "live topology events require the cooperative executor \
                 (thread-per-worker cannot spawn or retire pods mid-run)"
            );
        }
        if spec.role("global-aggregator").is_none() {
            bail!(
                "live topology events need a 'global-aggregator' round sequencer \
                 to drain the timeline (distributed/all-reduce topologies have none)"
            );
        }
        if spec.channels.iter().any(|c| c.pair.0 == c.pair.1) {
            bail!(
                "live topology events are not supported on ring/all-reduce \
                 topologies (ring membership is frozen at build)"
            );
        }
        let mut cur = spec.clone();
        let mut cur_workers = workers.clone();
        for ev in &events {
            match ev {
                TopologyEvent::Extend { at_us, delta } => {
                    let next = delta.apply(&cur).context("applying topology delta")?;
                    merge_spec_union(&mut runtime_spec, &next);
                    let next_workers = expand(&next, registry)
                        .context("expanding extended TAG")?;
                    let wd = diff_workers(&cur_workers, &next_workers);
                    // a worker re-expanded under the same id merely
                    // *mutates* (e.g. the global gaining the new tier's
                    // uplink): the live worker adapts by joining the
                    // channel — it is neither evicted nor re-deployed.
                    // Only the round sequencer knows how to adapt, so
                    // mutations of any other worker are rejected here
                    // rather than silently diverging from the spec.
                    let mutated: Vec<&String> = wd
                        .remove
                        .iter()
                        .filter(|id| wd.add.iter().any(|(_, w)| w.id == **id))
                        .collect();
                    for id in &mutated {
                        let role = cur_workers
                            .iter()
                            .find(|w| w.id == ***id)
                            .map(|w| w.role.as_str())
                            .unwrap_or("");
                        if role != "global-aggregator" {
                            bail!(
                                "extend event changes worker '{id}' ({role}) in \
                                 place, which only the sequencer supports; express \
                                 the change as distinct remove+add worker ids"
                            );
                        }
                    }
                    let deploys: Vec<WorkerConfig> = wd
                        .add
                        .iter()
                        .filter(|(_, w)| !mutated.contains(&&w.id))
                        .map(|(_, w)| w.clone())
                        .collect();
                    let evicts: Vec<String> = wd
                        .remove
                        .iter()
                        .filter(|id| !mutated.contains(id))
                        .cloned()
                        .collect();
                    live_set.retain(|w| !evicts.contains(&w.id));
                    live_set.extend(deploys.iter().cloned());
                    for id in &mutated {
                        if let (Some(slot), Some(nw)) = (
                            live_set.iter_mut().find(|w| w.id == ***id),
                            next_workers.iter().find(|w| w.id == ***id),
                        ) {
                            *slot = nw.clone();
                        }
                    }
                    if !evicts.is_empty() {
                        entries.push(TimelineEntry {
                            at: *at_us,
                            action: ScheduledAction::Evict(evicts),
                        });
                    }
                    if !deploys.is_empty() {
                        entries.push(TimelineEntry {
                            at: *at_us,
                            action: ScheduledAction::Deploy(deploys),
                        });
                    }
                    cur = next;
                    cur_workers = next_workers;
                }
                TopologyEvent::Leave { at_us, workers: leavers } => {
                    for id in leavers {
                        if !cur_workers.iter().any(|w| w.id == *id) {
                            bail!("leave event names unknown worker '{id}'");
                        }
                    }
                    live_set.retain(|w| !leavers.contains(&w.id));
                    entries.push(TimelineEntry {
                        at: *at_us,
                        action: ScheduledAction::Evict(leavers.clone()),
                    });
                }
            }
            phase_marks.push((entries.len(), live_set.clone()));
        }
    }

    // ---- crash resilience: sink gating, failover arming, resume replay
    let sync_agg = !matches!(
        tcfg.aggregation,
        crate::algos::AggregationPolicy::Asynchronous { .. }
    );
    let has_ring = spec.channels.iter().any(|c| c.pair.0 == c.pair.1);
    let arm_failover = opts.ckpt.as_ref().is_some_and(|p| p.failover);
    if arm_failover {
        // failover rides the live-extension machinery (evict + deploy_at
        // on the running fabric), so it needs the same substrate
        if flavor == Flavor::Coordinated {
            bail!("aggregator failover is not supported with a coordinator role");
        }
        if !sync_agg {
            bail!("aggregator failover requires synchronous aggregation");
        }
        if matches!(opts.executor, Executor::ThreadPerWorker) {
            bail!("aggregator failover requires the cooperative executor");
        }
        if spec.role("global-aggregator").is_none() {
            bail!("aggregator failover needs a 'global-aggregator' round sequencer");
        }
        if has_ring {
            bail!("aggregator failover is not supported on ring/all-reduce topologies");
        }
    }
    // Live (durable) checkpointing needs the boundary the committing
    // worker snapshots at to be a true barrier. Every flavor now
    // establishes one: full-quorum sync collects block until all uploads
    // land; partial-quorum sync drains its stragglers at the boundary;
    // async/FedBuff holds a version-boundary barrier (replies withheld
    // until every outstanding update lands); ring and hybrid topologies
    // emit collective-op epoch markers to the committing delegate. Only
    // coordinated jobs stay excluded — the coordinator owns its own
    // membership/termination protocol, and its jobs resume by restarting
    // from round 0 (byte-identical by per-job determinism).
    let live_ckpt = flavor != Flavor::Coordinated
        && (flavor == Flavor::Distributed || spec.role("global-aggregator").is_some());
    let ckpt_sink = opts
        .ckpt
        .as_ref()
        .map(|policy| CkptSink::new(job_label, policy.clone(), live_ckpt));
    if let Some(sink) = &ckpt_sink {
        sink.set_flavor(if has_ring && flavor != Flavor::Distributed {
            "hybrid"
        } else if !sync_agg {
            "async"
        } else if flavor == Flavor::Distributed {
            "ring"
        } else {
            flavor.name()
        });
    }

    // Resume: jump the worker set to the checkpoint boundary (replaying
    // the first `cursor` timeline entries' deploys/evicts/mutations via
    // the phase marks) and hand the rebuilt timeline only the remainder.
    let elastic = !entries.is_empty() || arm_failover;
    let mut workers = workers;
    let mut prior_pods = 0usize;
    if let Some(ck) = &opts.restore {
        if ck.cursor > 0 {
            let boundary = phase_marks
                .iter()
                .find(|(n, _)| *n as u64 == ck.cursor)
                .map(|(_, ws)| ws.clone())
                .with_context(|| {
                    format!(
                        "resume: checkpoint cursor {} does not align with the \
                         event timeline",
                        ck.cursor
                    )
                })?;
            let spawned_before: usize = workers.len()
                + entries[..ck.cursor as usize]
                    .iter()
                    .map(|e| match &e.action {
                        ScheduledAction::Deploy(ws) => ws.len(),
                        ScheduledAction::Evict(_) => 0,
                    })
                    .sum::<usize>();
            workers = boundary;
            prior_pods = spawned_before - workers.len();
            entries.drain(..ck.cursor as usize);
        }
    }
    let timeline = TopologyTimeline::with_elastic(entries, elastic);
    if let Some(ck) = &opts.restore {
        timeline.skip_cursor(ck.cursor);
    }

    // Resolve every role's program binding NOW, against the union spec —
    // initial roles AND roles introduced by live-extension deltas — so an
    // unknown program fails the submission, never a pod mid-run.
    programs.resolve_all(&runtime_spec, flavor)?;

    let net = chan_mgr.net().clone();
    if let Some(f) = opts.configure_net.take() {
        if !chan_mgr.scope().is_empty() {
            bail!(
                "per-job network shaping (JobOptions::with_net) is not supported \
                 on a shared fleet fabric: worker node names are not namespaced, \
                 so shaping one job's links would leak into identically-named \
                 workers of concurrent jobs"
            );
        }
        f(&net);
    }
    // data shards cover the union of every phase's datasets, so late
    // joiners and not-yet-retired leavers both find theirs materialised
    let n_shards = runtime_spec.datasets.len();
    let (shards, test) = make_federated(
        opts.data_seed,
        n_shards.max(1),
        opts.per_shard,
        opts.test_n,
        opts.partition,
        opts.noise_sigma,
    );
    let mut shard_map = HashMap::new();
    for (d, s) in runtime_spec.datasets.iter().zip(shards) {
        shard_map.insert(d.name.clone(), Arc::new(s));
    }
    // SIMD fold selection: `hyper.simd` picks the policy; the `FLAME_SIMD`
    // env var overrides it (CI's force-scalar cell runs the dispatch path
    // with the bit-exact scalar kernel under every job). "off" leaves the
    // backend untouched.
    let simd_policy = std::env::var("FLAME_SIMD")
        .ok()
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| tcfg.simd.clone());
    let compute: Arc<dyn Compute> = if simd_policy == "off" {
        opts.compute
    } else {
        Arc::new(crate::runtime::SimdCompute::with_kernel(
            opts.compute,
            crate::runtime::simd::kernel_from_policy(&simd_policy),
        ))
    };
    // Upload codec (`hyper.codec`): built once, shared via the runtime;
    // uploading roles encode, aggregation points decode. Ring all-reduce
    // topologies have no upload path to compress.
    let codec = match tcfg.codec.as_deref() {
        Some(name) => {
            if flavor == Flavor::Distributed {
                bail!(
                    "update codecs are not supported on distributed (all-reduce) \
                     topologies: there is no client upload to compress"
                );
            }
            Some(crate::runtime::codec::build_codec(name, tcfg.topk_frac)?)
        }
        None => None,
    };
    let init_flat = Arc::new(
        opts.init_flat
            .take()
            .unwrap_or_else(|| vec![0f32; compute.d_pad()]),
    );
    let pool = crate::runtime::TensorPool::new(compute.d_pad());
    // Virtual-time tracing: `hyper.trace` turns the per-job span recorder
    // on; the `FLAME_TRACE` env var overrides it either way (mirrors
    // FLAME_SIMD). Untraced jobs carry the disabled hub — every record
    // call is one branch — and the channel fabric's delivery hook is only
    // installed for traced jobs, keeping that hot path allocation-free.
    let trace_policy = std::env::var("FLAME_TRACE")
        .ok()
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| tcfg.trace.clone());
    let trace = if trace_policy == "on" {
        crate::trace::TraceHub::for_job(job_label)
    } else {
        crate::trace::TraceHub::disabled()
    };
    if trace.enabled() {
        chan_mgr.set_trace(trace.clone());
    }
    let job = Arc::new(JobRuntime {
        spec: runtime_spec,
        chan_mgr,
        compute,
        tcfg,
        metrics: Arc::new(MetricsHub::for_job(job_label)),
        shards: shard_map,
        test_set: Arc::new(test),
        time_model: opts.time_model,
        init_flat,
        pool,
        timeline: timeline.clone(),
        programs,
        flavor,
        codec,
        ckpt: ckpt_sink,
        restore: opts.restore.clone(),
        trace,
    });
    // rounds recorded before the kill point come back verbatim, so the
    // resumed run's report series continue where the dead run stopped —
    // and so do trace spans, making a resumed trace replay the dead run's
    // prefix byte-for-byte
    if let Some(ck) = &opts.restore {
        if !matches!(ck.metrics, Json::Null) {
            job.metrics.restore(&ck.metrics);
        }
        job.trace.restore(&ck.trace);
    }
    let recv_timeout = opts
        .recv_timeout
        .unwrap_or_else(|| auto_recv_timeout(workers.len()));
    Ok(PreparedJob {
        job,
        workers,
        timeline,
        recv_timeout,
        expansion_s,
        prior_pods,
    })
}

/// The management-plane controller.
pub struct Controller {
    store: Arc<Store>,
    notifier: Arc<Notifier>,
    registry: Registry,
    deployers: DeployerSet,
    /// Role SDK: the base program registry every submission binds
    /// through (extended via [`Self::register_program`] or per job via
    /// [`JobOptions::with_program`]).
    programs: Arc<RoleRegistry>,
    job_counter: u64,
}

impl Controller {
    pub fn new(store: Arc<Store>) -> Self {
        Self {
            store,
            notifier: Arc::new(Notifier::new()),
            registry: Registry::single_box(),
            deployers: DeployerSet::with_sim(),
            programs: Arc::new(RoleRegistry::builtin()),
            job_counter: 0,
        }
    }

    pub fn notifier(&self) -> Arc<Notifier> {
        self.notifier.clone()
    }

    pub fn registry_mut(&mut self) -> &mut Registry {
        &mut self.registry
    }

    /// The controller's base program registry (Role SDK).
    pub fn programs(&self) -> &Arc<RoleRegistry> {
        &self.programs
    }

    /// Register a program for every subsequent submission (Role SDK).
    /// Jobs already prepared keep the registry view they bound against.
    pub fn register_program(&mut self, name: impl Into<String>, factory: ProgramFactory) {
        Arc::make_mut(&mut self.programs).register(name, factory);
    }

    /// Install a default `(role, flavor)` binding on the base registry
    /// (Role SDK); the program must already be registered.
    pub fn bind_default_program(
        &mut self,
        role: &str,
        flavor: Option<Flavor>,
        program: &str,
    ) -> Result<()> {
        Arc::make_mut(&mut self.programs).bind_default(role, flavor, program)
    }

    /// Replace the default single-box registry (compute registration,
    /// §5.2 step 1). Also journals the registration.
    pub fn register_compute(&mut self, c: crate::registry::ComputeSpec) -> Result<()> {
        self.store.put("computes", &c.name, c.to_json())?;
        self.registry.register_compute(c);
        Ok(())
    }

    /// Dataset metadata registration (§4.3): the system stores metadata
    /// only, never raw data.
    pub fn register_dataset(&mut self, d: crate::tag::DatasetRef) -> Result<()> {
        let mut o = Json::obj();
        o.insert("name", d.name.as_str());
        o.insert("group", d.group.as_str());
        o.insert("realm", d.realm.as_str());
        o.insert("url", d.url.as_str());
        self.store.put("datasets", &d.name, Json::Obj(o))?;
        self.registry.register_dataset(d);
        Ok(())
    }

    /// Submit a job and run it to completion (the §5.2 workflow).
    pub fn submit(&mut self, spec: JobSpec, opts: JobOptions) -> Result<JobReport> {
        let wall0 = Instant::now();
        self.job_counter += 1;
        let job_id = format!("{}-{}", spec.name, self.job_counter);

        // (step 3/4) record the job configuration
        self.store.put("jobs", &job_id, spec.to_json())?;

        // spec lints (e.g. missing tag.flavor → inferred binding) stream
        // as events; they never fail the submission
        for warning in validate::lint(&spec) {
            self.notifier
                .emit(EventKind::SpecLint, &job_id, Json::from(warning));
        }

        let executor = opts.executor;
        let chan_mgr = ChannelManager::new(Arc::new(VirtualNet::default()));
        let PreparedJob {
            job,
            workers,
            timeline,
            recv_timeout,
            expansion_s,
            ..
        } = prepare_job(&job_id, spec, opts, &self.registry, &self.programs, chan_mgr)?;
        // crash resilience: commits go through the controller's store
        if let Some(sink) = &job.ckpt {
            sink.bind_store(self.store.clone());
        }
        // traced jobs stream round-boundary Trace events on this notifier
        job.trace.bind_notifier(self.notifier.clone());

        let t_db = Instant::now();
        self.store.put_batch(
            "workers",
            workers
                .iter()
                .map(|w| (format!("{job_id}/{}", w.id), w.to_json())),
        )?;
        let db_write_s = t_db.elapsed().as_secs_f64();
        // (step 5/6) deploy-event -> deployers create pods. The payload
        // reports each channel's *requested* substrate (which may alias
        // onto an implemented transport, e.g. "mqtt" on the broker).
        let mut substrates = Json::obj();
        for c in &job.spec.channels {
            substrates.insert(c.name.as_str(), c.substrate.as_str());
        }
        let mut deploy_payload = Json::obj();
        deploy_payload.insert("workers", workers.len());
        deploy_payload.insert("substrates", substrates);
        self.notifier
            .emit(EventKind::Deploy, &job_id, Json::Obj(deploy_payload));
        // Two-phase deployment: `deploy` builds every worker environment
        // (joining channels) BEFORE `start` launches anything, so roles
        // observe complete channel membership — the equivalent of the
        // paper's agents fetching full task configuration before starting
        // the worker process.
        let sim: Arc<dyn Deployer> = match executor {
            Executor::Cooperative { runners } => Arc::new(SimDeployer::new(runners)),
            Executor::ThreadPerWorker => Arc::new(ThreadDeployer::new(recv_timeout)),
        };
        if timeline.is_elastic() {
            // arm the incremental deploy path: scheduled Deploy actions
            // spawn through this deployer while the fabric runs
            timeline.bind(sim.clone(), self.notifier.clone());
        }
        let mut pods = Vec::with_capacity(workers.len());
        let mut custom_orchestrators: Vec<String> = Vec::new();
        for w in &workers {
            let orchestrator = self
                .registry
                .computes()
                .iter()
                .find(|c| c.name == w.compute)
                .map(|c| c.orchestrator.clone())
                .unwrap_or_else(|| "sim".into());
            let deployer: Arc<dyn Deployer> = if orchestrator == "sim" {
                sim.clone()
            } else {
                if !custom_orchestrators.contains(&orchestrator) {
                    custom_orchestrators.push(orchestrator.clone());
                }
                self.deployers.get(&orchestrator)?.clone()
            };
            pods.push(deployer.deploy(w.clone(), &job, self.notifier.clone())?);
        }
        // Launch. For the cooperative fabric this drives the whole
        // deployment to completion on the runner pool.
        for orch in &custom_orchestrators {
            self.deployers.get(orch)?.start()?;
        }
        sim.start()?;
        // pods deployed live by timeline events are terminal too once the
        // fabric drains; fold them into monitoring
        pods.extend(timeline.take_pods());

        // (monitoring) wait for completion; fail the job on any failed pod
        let mut failures = Vec::new();
        for pod in &pods {
            if let PodStatus::Failed(e) = pod.wait() {
                failures.push(format!("{}: {e}", pod.worker_id));
            }
        }

        // (teardown) revoke-deploy event + final state
        self.notifier
            .emit(EventKind::Revoke, &job_id, Json::from(pods.len()));
        let status = if failures.is_empty() { "done" } else { "failed" };
        self.store.put("job_status", &job_id, Json::from(status))?;
        self.store.flush()?;
        self.notifier
            .emit(EventKind::JobDone, &job_id, Json::from(status));

        if !failures.is_empty() {
            bail!("job {job_id} failed:\n  {}", failures.join("\n  "));
        }

        let metrics = job.metrics.clone();
        let vtime_s = metrics.last("vtime_s").unwrap_or(0.0);
        Ok(JobReport {
            job: job_id,
            // count every pod that ran, including live-extension joiners
            workers: pods.len(),
            final_loss: metrics.last("loss"),
            final_acc: metrics.last("acc"),
            total_bytes: metrics.total_bytes(),
            vtime_s,
            wall_s: wall0.elapsed().as_secs_f64(),
            expansion_s,
            db_write_s,
            metrics,
            trace: job.trace.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::Backend;
    use crate::topo;

    fn controller() -> Controller {
        Controller::new(Arc::new(Store::in_memory()))
    }

    #[test]
    fn cfl_job_runs_to_completion_and_learns() {
        let mut c = controller();
        let spec = topo::classical(4, Backend::P2p)
            .rounds(8)
            .set("lr", Json::Num(0.5))
            .set("local_steps", 2usize)
            .build();
        let report = c.submit(spec, JobOptions::mock()).unwrap();
        assert_eq!(report.workers, 5);
        let acc = report.final_acc.unwrap();
        let loss = report.final_loss.unwrap();
        assert!(acc > 0.5, "acc={acc}");
        assert!(loss < 1.5, "loss={loss}");
        assert!(report.total_bytes > 0);
        assert!(report.vtime_s > 0.0);
    }

    #[test]
    fn hfl_job_runs_with_two_tiers() {
        let mut c = controller();
        let spec = topo::hierarchical(6, 2, Backend::Broker)
            .rounds(5)
            .set("lr", Json::Num(0.5))
            .build();
        let report = c.submit(spec, JobOptions::mock()).unwrap();
        assert_eq!(report.workers, 9);
        assert!(report.final_acc.unwrap() > 0.4);
    }

    #[test]
    fn store_records_job_and_workers() {
        let store = Arc::new(Store::in_memory());
        let mut c = Controller::new(store.clone());
        let spec = topo::classical(3, Backend::P2p).rounds(2).build();
        let report = c.submit(spec, JobOptions::mock()).unwrap();
        assert!(store.get("jobs", &report.job).is_some());
        assert_eq!(store.count("workers"), 4);
        assert_eq!(
            store.get("job_status", &report.job).unwrap().as_str(),
            Some("done")
        );
    }

    #[test]
    fn notifier_sees_lifecycle_events() {
        let mut c = controller();
        let deploy_rx = c.notifier().subscribe(Some(EventKind::Deploy), None);
        let done_rx = c.notifier().subscribe(Some(EventKind::JobDone), None);
        let spec = topo::classical(2, Backend::P2p).rounds(2).build();
        c.submit(spec, JobOptions::mock()).unwrap();
        assert_eq!(deploy_rx.try_iter().count(), 1);
        assert_eq!(done_rx.try_iter().count(), 1);
    }

    #[test]
    fn thread_per_worker_executor_still_supported() {
        let mut c = controller();
        let spec = topo::classical(3, Backend::P2p)
            .rounds(3)
            .set("lr", Json::Num(0.5))
            .build();
        let report = c
            .submit(
                spec,
                JobOptions::mock().with_executor(Executor::ThreadPerWorker),
            )
            .unwrap();
        assert_eq!(report.workers, 4);
        assert!(report.final_acc.unwrap() > 0.4);
    }

    #[test]
    fn single_runner_cooperative_executor_works() {
        let mut c = controller();
        let spec = topo::hierarchical(4, 2, Backend::P2p)
            .rounds(2)
            .set("lr", Json::Num(0.5))
            .build();
        let report = c
            .submit(
                spec,
                JobOptions::mock().with_executor(Executor::Cooperative { runners: 1 }),
            )
            .unwrap();
        assert_eq!(report.workers, 7);
        assert!(report.final_acc.is_some());
    }

    #[test]
    fn realm_mismatch_fails_expansion_cleanly() {
        let store = Arc::new(Store::in_memory());
        let mut c = Controller::new(store);
        // replace the single-box registry with a constrained one
        *c.registry_mut() = Registry::new();
        c.register_compute(crate::registry::ComputeSpec::new("eu", "eu", 10))
            .unwrap();
        let mut spec = topo::classical(1, Backend::P2p).rounds(1).build();
        spec.datasets[0].realm = "us/east".into();
        assert!(c.submit(spec, JobOptions::mock()).is_err());
    }
}
