//! Virtual-time tracing & runtime telemetry — the observability layer.
//!
//! A [`TraceHub`] is a per-job span recorder stamped entirely in *virtual*
//! time: role chains record round phases (`train`, `encode`,
//! `collect-wait`, `aggregate`, `distribute`, `checkpoint`, `eval`), the
//! channel fabric records one `upload-xfer` span per delivered message
//! (charged by the net model), and the scheduler's runtime counters
//! ([`crate::sched::SchedStats`]) are sampled at round boundaries into
//! [`MetricsHub`] series. Because every span derives from worker vclocks
//! and message arrival times — never the wall clock — the emitted trace is
//! **byte-identical across runner-pool sizes and executors**: the spans
//! exist in an interleaving-dependent insertion order, but emission sorts
//! them canonically, and the values themselves are deterministic.
//!
//! Three surfaces:
//!
//! * [`TraceHub::chrome_json`] — Chrome trace-event JSON
//!   (`chrome://tracing` / Perfetto loadable), one virtual thread per
//!   worker (`flame trace` writes `bench_out/trace.json`).
//! * [`TraceHub::round_boundary`] — per-round phase breakdown recorded as
//!   `phase.*_us` metrics series (the round-phase CSV), plus cumulative
//!   scheduler stats as `sched.*` series and a [`EventKind::Trace`]
//!   notifier event for span-boundary subscribers.
//! * [`TraceHub::phase_table`] — the human-readable per-round table the
//!   CLI prints. The sequencer-lane phases (`distribute` + `collect-wait`
//!   + `aggregate` + `eval` + `checkpoint`) tile the round exactly — the
//!   sequencer's clock only advances inside those stages — so their sum
//!   *is* the round's virtual duration.
//!
//! Gating: per job via `hyper.trace` (`"on"`/`"off"`, default off) with a
//! `FLAME_TRACE` env override, mirroring `hyper.simd`/`FLAME_SIMD`. A
//! disabled hub ([`TraceHub::disabled`]) rejects every record before
//! touching a lock or the interner, so the PR-5 allocation-free hot path
//! stays allocation-free (`rust/tests/alloc_regression.rs` pins this).
//! Workers and phases are interned [`Arc<str>`] atoms, so an *enabled*
//! hub's steady-state recording cost is one `Vec::push` per span.
//!
//! Checkpointing: [`TraceHub::snapshot`] / [`TraceHub::restore`] ride the
//! round-boundary job checkpoints, so a killed-and-resumed job's final
//! trace replays the pre-kill prefix verbatim (`rust/tests/trace.rs`).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::{Arc, Mutex, OnceLock};

use crate::intern::atom;
use crate::json::{self, Json};
use crate::metrics::MetricsHub;
use crate::net::VTime;
use crate::notify::{EventKind, Notifier};
use crate::sched::SchedStats;

/// Canonical round-phase names. Role chains record these; everything else
/// (tables, CSV series, the Chrome trace) keys off them.
pub mod phase {
    pub const TRAIN: &str = "train";
    pub const ENCODE: &str = "encode";
    pub const XFER: &str = "upload-xfer";
    pub const WAIT: &str = "collect-wait";
    pub const AGGREGATE: &str = "aggregate";
    pub const DISTRIBUTE: &str = "distribute";
    pub const CHECKPOINT: &str = "checkpoint";
    pub const EVAL: &str = "eval";
}

/// One virtual-time span: `worker` spent `[vstart, vend]` in `phase`
/// during `round`. Transfer spans carry the receiving `peer` and the
/// message's wire `bytes`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Span {
    pub worker: Arc<str>,
    pub phase: Arc<str>,
    pub peer: Option<Arc<str>>,
    pub round: u64,
    pub vstart: VTime,
    pub vend: VTime,
    pub bytes: u64,
}

impl Span {
    fn dur(&self) -> u64 {
        self.vend.saturating_sub(self.vstart)
    }

    /// Canonical ordering key: virtual-time first, then worker/phase —
    /// independent of insertion (i.e. thread-interleaving) order.
    fn key(&self) -> (VTime, &Arc<str>, VTime, &Arc<str>, u64, &Option<Arc<str>>, u64) {
        (
            self.vstart,
            &self.worker,
            self.vend,
            &self.phase,
            self.round,
            &self.peer,
            self.bytes,
        )
    }
}

/// One counter sample (`ph: "C"` in the Chrome trace): a named value at a
/// virtual instant, e.g. the quorum fill of a collect.
#[derive(Debug, Clone, PartialEq)]
pub struct CounterEvent {
    pub worker: Arc<str>,
    pub name: Arc<str>,
    pub at: VTime,
    pub value: f64,
}

/// Per-round phase durations (µs), summed over every worker's spans.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PhaseRow {
    pub train_us: u64,
    pub encode_us: u64,
    pub xfer_us: u64,
    pub wait_us: u64,
    pub aggregate_us: u64,
    pub distribute_us: u64,
    pub checkpoint_us: u64,
    pub eval_us: u64,
}

impl PhaseRow {
    /// The sequencer-lane sum — the round's virtual duration (see module
    /// docs: these phases tile the sequencer's clock exactly).
    pub fn round_us(&self) -> u64 {
        self.distribute_us + self.wait_us + self.aggregate_us + self.eval_us + self.checkpoint_us
    }
}

/// The per-job span recorder. Shared through
/// [`crate::roles::JobRuntime::trace`]; a disabled hub is a zero-cost
/// no-op on every recording path.
pub struct TraceHub {
    enabled: bool,
    job: String,
    spans: Mutex<Vec<Span>>,
    counters: Mutex<Vec<CounterEvent>>,
    /// Scheduler runtime counters, bound by the deployer that owns the
    /// cooperative fabric (absent under thread-per-worker execution).
    sched: OnceLock<Arc<SchedStats>>,
    /// Bound by the controller so round boundaries can emit
    /// [`EventKind::Trace`] events.
    notifier: OnceLock<Arc<Notifier>>,
}

impl TraceHub {
    /// An enabled hub recording for `job`.
    pub fn for_job(job: impl Into<String>) -> Arc<Self> {
        Arc::new(Self {
            enabled: true,
            job: job.into(),
            spans: Mutex::new(Vec::new()),
            counters: Mutex::new(Vec::new()),
            sched: OnceLock::new(),
            notifier: OnceLock::new(),
        })
    }

    /// The disabled hub every untraced job carries: rejects all records
    /// up front — no lock, no interning, no allocation.
    pub fn disabled() -> Arc<Self> {
        Arc::new(Self {
            enabled: false,
            job: String::new(),
            spans: Mutex::new(Vec::new()),
            counters: Mutex::new(Vec::new()),
            sched: OnceLock::new(),
            notifier: OnceLock::new(),
        })
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn job_id(&self) -> &str {
        &self.job
    }

    /// Bind the scheduler's runtime counters (idempotent; cooperative
    /// deployers call this at pod staging).
    pub fn bind_sched(&self, stats: Arc<SchedStats>) {
        if self.enabled {
            let _ = self.sched.set(stats);
        }
    }

    /// Bind the notifier for round-boundary [`EventKind::Trace`] events
    /// (idempotent; the controller calls this at submit).
    pub fn bind_notifier(&self, notifier: Arc<Notifier>) {
        if self.enabled {
            let _ = self.notifier.set(notifier);
        }
    }

    /// Record a phase span for `worker`. No-op when disabled.
    pub fn span(&self, worker: &str, phase: &str, round: u64, vstart: VTime, vend: VTime) {
        if !self.enabled {
            return;
        }
        self.spans.lock().unwrap().push(Span {
            worker: atom(worker),
            phase: atom(phase),
            peer: None,
            round,
            vstart,
            vend,
            bytes: 0,
        });
    }

    /// Record one message-transfer span, charged by the net model:
    /// `from`'s send clock to the computed arrival at `to`.
    #[allow(clippy::too_many_arguments)]
    pub fn transfer(
        &self,
        from: &str,
        to: &str,
        round: u64,
        vstart: VTime,
        vend: VTime,
        bytes: u64,
    ) {
        if !self.enabled {
            return;
        }
        self.spans.lock().unwrap().push(Span {
            worker: atom(from),
            phase: atom(phase::XFER),
            peer: Some(atom(to)),
            round,
            vstart,
            vend,
            bytes,
        });
    }

    /// Record a counter sample. No-op when disabled.
    pub fn counter(&self, worker: &str, name: &str, at: VTime, value: f64) {
        if !self.enabled {
            return;
        }
        self.counters.lock().unwrap().push(CounterEvent {
            worker: atom(worker),
            name: atom(name),
            at,
            value,
        });
    }

    /// How many spans have been recorded.
    pub fn span_count(&self) -> usize {
        self.spans.lock().unwrap().len()
    }

    /// The latest span (by virtual end time) of `worker`, formatted for
    /// diagnostics — the "what was it doing last" line of a deadlock
    /// post-mortem. `None` when disabled or the worker never recorded.
    pub fn last_span_of(&self, worker: &str) -> Option<String> {
        if !self.enabled {
            return None;
        }
        let spans = self.spans.lock().unwrap();
        spans
            .iter()
            .filter(|s| &*s.worker == worker)
            .max_by_key(|s| (s.vend, s.vstart, s.round))
            .map(|s| format!("{}@[{}..{}]us round {}", s.phase, s.vstart, s.vend, s.round))
    }

    // ------------------------------------------------- round boundaries

    /// Round-boundary hook, called by the round sequencer's `eval`: fold
    /// the round's spans into `phase.*_us` metrics series, sample the
    /// scheduler's cumulative runtime counters into `sched.*` series, and
    /// emit one [`EventKind::Trace`] event at virtual time `now`.
    ///
    /// The `phase.*` series are deterministic (pure functions of vclock
    /// values); the `sched.*` series are *executor-dependent* runtime
    /// stats and are deliberately kept out of [`Self::chrome_json`].
    pub fn round_boundary(
        &self,
        metrics: &MetricsHub,
        worker: &str,
        round: u64,
        round_start: VTime,
        now: VTime,
    ) {
        if !self.enabled {
            return;
        }
        let row = self.phase_row(round);
        for (series, v) in [
            ("phase.train_us", row.train_us),
            ("phase.encode_us", row.encode_us),
            ("phase.xfer_us", row.xfer_us),
            ("phase.wait_us", row.wait_us),
            ("phase.aggregate_us", row.aggregate_us),
            ("phase.distribute_us", row.distribute_us),
            ("phase.checkpoint_us", row.checkpoint_us),
            ("phase.eval_us", row.eval_us),
            ("phase.round_us", now.saturating_sub(round_start)),
        ] {
            metrics.record(worker, series, round, v as f64);
        }
        if let Some(st) = self.sched.get() {
            for (series, v) in st.samples() {
                metrics.record(worker, series, round, v as f64);
            }
        }
        if let Some(n) = self.notifier.get() {
            let mut p = Json::obj();
            p.insert("round", Json::Num(round as f64));
            p.insert("train_us", Json::Num(row.train_us as f64));
            p.insert("xfer_us", Json::Num(row.xfer_us as f64));
            p.insert("wait_us", Json::Num(row.wait_us as f64));
            p.insert("aggregate_us", Json::Num(row.aggregate_us as f64));
            p.insert("round_us", Json::Num(now.saturating_sub(round_start) as f64));
            n.emit_at(EventKind::Trace, &self.job, now, Json::Obj(p));
        }
    }

    /// Per-phase duration sums for one round.
    pub fn phase_row(&self, round: u64) -> PhaseRow {
        let mut row = PhaseRow::default();
        for s in self.spans.lock().unwrap().iter() {
            if s.round != round {
                continue;
            }
            Self::fold_phase(&mut row, s);
        }
        row
    }

    /// Per-round phase rows for every round any span named.
    pub fn phase_rounds(&self) -> BTreeMap<u64, PhaseRow> {
        let mut out: BTreeMap<u64, PhaseRow> = BTreeMap::new();
        for s in self.spans.lock().unwrap().iter() {
            Self::fold_phase(out.entry(s.round).or_default(), s);
        }
        out
    }

    /// Whole-job per-phase totals (µs) — the cross-mechanism comparison
    /// number (e.g. sync quorum vs FedBuff in EXPERIMENTS.md).
    pub fn phase_totals(&self) -> BTreeMap<&'static str, u64> {
        let mut out: BTreeMap<&'static str, u64> = BTreeMap::new();
        for s in self.spans.lock().unwrap().iter() {
            let slot = match &*s.phase {
                p if p == phase::TRAIN => phase::TRAIN,
                p if p == phase::ENCODE => phase::ENCODE,
                p if p == phase::XFER => phase::XFER,
                p if p == phase::WAIT => phase::WAIT,
                p if p == phase::AGGREGATE => phase::AGGREGATE,
                p if p == phase::DISTRIBUTE => phase::DISTRIBUTE,
                p if p == phase::CHECKPOINT => phase::CHECKPOINT,
                p if p == phase::EVAL => phase::EVAL,
                _ => continue,
            };
            *out.entry(slot).or_default() += s.dur();
        }
        out
    }

    fn fold_phase(row: &mut PhaseRow, s: &Span) {
        let d = s.dur();
        match &*s.phase {
            p if p == phase::TRAIN => row.train_us += d,
            p if p == phase::ENCODE => row.encode_us += d,
            p if p == phase::XFER => row.xfer_us += d,
            p if p == phase::WAIT => row.wait_us += d,
            p if p == phase::AGGREGATE => row.aggregate_us += d,
            p if p == phase::DISTRIBUTE => row.distribute_us += d,
            p if p == phase::CHECKPOINT => row.checkpoint_us += d,
            p if p == phase::EVAL => row.eval_us += d,
            _ => {}
        }
    }

    /// The per-round phase-breakdown table `flame trace` prints. The
    /// `round_us` column is the sequencer-lane sum — the round's virtual
    /// duration by construction.
    pub fn phase_table(&self) -> String {
        let mut s = format!(
            "{:<6} {:>10} {:>10} {:>10} {:>10} {:>9} {:>9} {:>9} {:>10}\n",
            "round", "train_us", "xfer_us", "wait_us", "agg_us", "dist_us", "eval_us", "ckpt_us",
            "round_us"
        );
        for (round, row) in self.phase_rounds() {
            let _ = writeln!(
                s,
                "{:<6} {:>10} {:>10} {:>10} {:>10} {:>9} {:>9} {:>9} {:>10}",
                round,
                row.train_us,
                row.xfer_us,
                row.wait_us,
                row.aggregate_us,
                row.distribute_us,
                row.eval_us,
                row.checkpoint_us,
                row.round_us()
            );
        }
        s
    }

    // ---------------------------------------------------- Chrome trace

    /// Emit the Chrome trace-event JSON (`chrome://tracing` / Perfetto
    /// loadable). Output is canonical: workers map to virtual thread ids
    /// in sorted-name order, spans and counters sort by virtual time with
    /// deterministic tie-breaks — so the bytes are identical across
    /// runner-pool sizes and executors for the same job.
    pub fn chrome_json(&self) -> String {
        let mut spans = self.spans.lock().unwrap().clone();
        spans.sort_by(|a, b| a.key().cmp(&b.key()));
        let mut counters = self.counters.lock().unwrap().clone();
        counters.sort_by(|a, b| {
            (a.at, &a.worker, &a.name)
                .cmp(&(b.at, &b.worker, &b.name))
                .then(a.value.total_cmp(&b.value))
        });

        // virtual thread ids in sorted worker-name order
        let mut workers: Vec<&str> = spans
            .iter()
            .map(|s| &*s.worker)
            .chain(counters.iter().map(|c| &*c.worker))
            .collect();
        workers.sort_unstable();
        workers.dedup();
        let tid_of = |w: &str| workers.binary_search(&w).map(|i| i + 1).unwrap_or(0);

        let mut out = String::from("{\"traceEvents\":[");
        let mut first = true;
        let mut push = |out: &mut String, first: &mut bool, ev: String| {
            if !*first {
                out.push(',');
            }
            *first = false;
            out.push('\n');
            out.push_str(&ev);
        };
        for w in &workers {
            push(
                &mut out,
                &mut first,
                format!(
                    "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{},\"args\":{{\"name\":{}}}}}",
                    tid_of(w),
                    esc(w)
                ),
            );
        }
        for s in &spans {
            let peer = match &s.peer {
                Some(p) => format!(",\"peer\":{}", esc(p)),
                None => String::new(),
            };
            push(
                &mut out,
                &mut first,
                format!(
                    "{{\"name\":{},\"cat\":\"phase\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":1,\
                     \"tid\":{},\"args\":{{\"round\":{},\"bytes\":{}{}}}}}",
                    esc(&s.phase),
                    s.vstart,
                    s.dur(),
                    tid_of(&s.worker),
                    s.round,
                    s.bytes,
                    peer
                ),
            );
        }
        for c in &counters {
            push(
                &mut out,
                &mut first,
                format!(
                    "{{\"name\":{},\"ph\":\"C\",\"ts\":{},\"pid\":1,\"tid\":{},\"args\":{{\"value\":{}}}}}",
                    esc(&c.name),
                    c.at,
                    tid_of(&c.worker),
                    c.value
                ),
            );
        }
        out.push_str("\n]}\n");
        out
    }

    // ----------------------------------------------------- checkpointing

    /// Checkpoint encoding: spans and counters in canonical order, so the
    /// snapshot bytes are interleaving-independent like the trace itself.
    pub fn snapshot(&self) -> Json {
        if !self.enabled {
            return Json::Null;
        }
        let mut spans = self.spans.lock().unwrap().clone();
        spans.sort_by(|a, b| a.key().cmp(&b.key()));
        let rows: Vec<Json> = spans
            .iter()
            .map(|s| {
                Json::Arr(vec![
                    Json::Str(s.worker.to_string()),
                    Json::Str(s.phase.to_string()),
                    Json::Str(s.peer.as_deref().unwrap_or("").to_string()),
                    json::from_u64_hex(s.round),
                    json::from_u64_hex(s.vstart),
                    json::from_u64_hex(s.vend),
                    json::from_u64_hex(s.bytes),
                ])
            })
            .collect();
        let mut counters = self.counters.lock().unwrap().clone();
        counters.sort_by(|a, b| {
            (a.at, &a.worker, &a.name)
                .cmp(&(b.at, &b.worker, &b.name))
                .then(a.value.total_cmp(&b.value))
        });
        let crows: Vec<Json> = counters
            .iter()
            .map(|c| {
                Json::Arr(vec![
                    Json::Str(c.worker.to_string()),
                    Json::Str(c.name.to_string()),
                    json::from_u64_hex(c.at),
                    Json::Num(c.value),
                ])
            })
            .collect();
        let mut o = Json::obj();
        o.insert("spans", Json::Arr(rows));
        o.insert("counters", Json::Arr(crows));
        Json::Obj(o)
    }

    /// Replace this hub's contents with a [`Self::snapshot`] — resume
    /// from checkpoint: the killed run's spans come back verbatim, and
    /// the resumed half appends after them. No-op when disabled or the
    /// snapshot is absent (pre-tracing checkpoints).
    pub fn restore(&self, snap: &Json) {
        if !self.enabled || matches!(snap, Json::Null) {
            return;
        }
        let mut spans = self.spans.lock().unwrap();
        spans.clear();
        if let Some(rows) = snap.get("spans").as_arr() {
            for row in rows {
                let peer = row.idx(2).as_str().unwrap_or("");
                spans.push(Span {
                    worker: atom(row.idx(0).as_str().unwrap_or("")),
                    phase: atom(row.idx(1).as_str().unwrap_or("")),
                    peer: if peer.is_empty() { None } else { Some(atom(peer)) },
                    round: json::as_u64_hex(row.idx(3)).unwrap_or(0),
                    vstart: json::as_u64_hex(row.idx(4)).unwrap_or(0),
                    vend: json::as_u64_hex(row.idx(5)).unwrap_or(0),
                    bytes: json::as_u64_hex(row.idx(6)).unwrap_or(0),
                });
            }
        }
        drop(spans);
        let mut counters = self.counters.lock().unwrap();
        counters.clear();
        if let Some(rows) = snap.get("counters").as_arr() {
            for row in rows {
                counters.push(CounterEvent {
                    worker: atom(row.idx(0).as_str().unwrap_or("")),
                    name: atom(row.idx(1).as_str().unwrap_or("")),
                    at: json::as_u64_hex(row.idx(2)).unwrap_or(0),
                    value: row.idx(3).as_f64().unwrap_or(0.0),
                });
            }
        }
    }
}

impl std::fmt::Debug for TraceHub {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceHub")
            .field("enabled", &self.enabled)
            .field("job", &self.job)
            .field("spans", &self.span_count())
            .finish()
    }
}

/// Minimal JSON string escaping (worker/phase names are plain
/// identifiers; this keeps the emitter safe for arbitrary ids anyway).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_hub_records_nothing() {
        let t = TraceHub::disabled();
        t.span("w0", phase::TRAIN, 0, 0, 100);
        t.transfer("w0", "agg", 0, 100, 200, 64);
        t.counter("w0", "x", 0, 1.0);
        assert_eq!(t.span_count(), 0);
        assert!(t.last_span_of("w0").is_none());
        assert!(matches!(t.snapshot(), Json::Null));
        assert_eq!(t.phase_row(0), PhaseRow::default());
    }

    #[test]
    fn chrome_json_is_insertion_order_independent() {
        let mk = |order_flip: bool| {
            let t = TraceHub::for_job("j");
            let a = || t.span("w0", phase::TRAIN, 0, 0, 100);
            let b = || t.transfer("w1", "agg", 0, 100, 250, 64);
            if order_flip {
                b();
                a();
            } else {
                a();
                b();
            }
            t.counter("agg", "quorum", 250, 2.0);
            t.chrome_json()
        };
        let x = mk(false);
        let y = mk(true);
        assert_eq!(x, y);
        // well-formed trace-event JSON with one thread per worker
        let parsed = Json::parse(&x).unwrap();
        let events = parsed.get("traceEvents").as_arr().unwrap();
        // 3 metadata + 2 spans + 1 counter
        assert_eq!(events.len(), 6);
        assert!(events.iter().any(|e| e.get("ph").as_str() == Some("X")));
        assert!(events.iter().any(|e| e.get("ph").as_str() == Some("C")));
    }

    #[test]
    fn phase_rows_sum_and_tile() {
        let t = TraceHub::for_job("j");
        t.span("agg", phase::DISTRIBUTE, 1, 1_000, 1_000);
        t.span("agg", phase::WAIT, 1, 1_000, 5_000);
        t.span("agg", phase::AGGREGATE, 1, 5_000, 6_000);
        t.span("agg", phase::EVAL, 1, 6_000, 6_500);
        t.span("t0", phase::TRAIN, 1, 1_200, 3_200);
        t.transfer("t0", "agg", 1, 3_200, 4_900, 4096);
        let row = t.phase_row(1);
        assert_eq!(row.wait_us, 4_000);
        assert_eq!(row.train_us, 2_000);
        assert_eq!(row.xfer_us, 1_700);
        assert_eq!(row.round_us(), 5_500);
        let table = t.phase_table();
        assert!(table.contains("round_us"), "{table}");
        assert_eq!(table.lines().count(), 2);
        assert_eq!(t.phase_totals()[phase::WAIT], 4_000);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let t = TraceHub::for_job("j");
        t.span("w0", phase::TRAIN, 3, 10, 20);
        t.transfer("w0", "agg", 3, 20, 45, 128);
        t.counter("agg", "quorum", 45, 1.0);
        let snap = t.snapshot();
        let r = TraceHub::for_job("j");
        r.restore(&snap);
        assert_eq!(r.chrome_json(), t.chrome_json());
        // restoring nothing is a no-op, not a clear
        r.restore(&Json::Null);
        assert_eq!(r.span_count(), 2);
    }

    #[test]
    fn last_span_context_picks_latest_virtual_time() {
        let t = TraceHub::for_job("j");
        t.span("w0", phase::TRAIN, 0, 0, 100);
        t.span("w0", phase::WAIT, 1, 100, 900);
        t.span("w1", phase::TRAIN, 0, 0, 50);
        let s = t.last_span_of("w0").unwrap();
        assert!(s.contains("collect-wait"), "{s}");
        assert!(s.contains("round 1"), "{s}");
        assert!(t.last_span_of("nope").is_none());
    }
}
