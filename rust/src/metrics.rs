//! Metrics collection: per-round series, traffic counters and CSV emission.
//!
//! The controller hands every worker a [`MetricsHub`] handle; roles record
//! round events (loss, accuracy, per-round virtual time, bytes moved) and
//! the bench harnesses dump the series as the CSV rows behind each paper
//! figure (`bench_out/figNN.csv`).

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::intern::atom;
use crate::json::{self, Json};
use crate::net::VTime;

/// One recorded sample: `(series, round, value)` plus the emitting worker
/// and the job it belongs to. The job id is what keeps concurrent jobs'
/// series apart when a fleet run aggregates many hubs into one CSV.
///
/// The string fields are interned [`Arc<str>`] atoms ([`crate::intern`]):
/// recording a sample clones three pointers instead of three heap
/// strings, which keeps per-round telemetry (including the `phase.*`
/// trace series) off the steady-state allocation budget.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub job: Arc<str>,
    pub worker: Arc<str>,
    pub series: Arc<str>,
    pub round: u64,
    pub value: f64,
}

/// Thread-safe metrics sink shared by all workers of a job. Every sample
/// is stamped with the hub's job id ([`MetricsHub::for_job`]; standalone
/// hubs use the empty id), so rows from concurrent jobs never collapse
/// into one anonymous series.
#[derive(Debug)]
pub struct MetricsHub {
    job: Arc<str>,
    samples: Mutex<Vec<Sample>>,
    bytes_sent: AtomicU64,
    messages: AtomicU64,
}

impl Default for MetricsHub {
    fn default() -> Self {
        Self {
            job: atom(""),
            samples: Mutex::new(Vec::new()),
            bytes_sent: AtomicU64::new(0),
            messages: AtomicU64::new(0),
        }
    }
}

impl MetricsHub {
    pub fn new() -> Self {
        Self::default()
    }

    /// A hub whose samples carry `job` as their job id.
    pub fn for_job(job: impl AsRef<str>) -> Self {
        Self {
            job: atom(job.as_ref()),
            ..Self::default()
        }
    }

    /// The job id stamped on this hub's samples (empty for standalone
    /// hubs).
    pub fn job_id(&self) -> &str {
        &self.job
    }

    /// Record one sample. Steady-state cost after the first sighting of a
    /// `worker`/`series` name is three `Arc` clones and a `Vec::push` —
    /// no string allocation.
    pub fn record(&self, worker: &str, series: &str, round: u64, value: f64) {
        self.samples.lock().unwrap().push(Sample {
            job: self.job.clone(),
            worker: atom(worker),
            series: atom(series),
            round,
            value,
        });
    }

    pub fn add_traffic(&self, bytes: u64) {
        self.bytes_sent.fetch_add(bytes, Ordering::Relaxed);
        self.messages.fetch_add(1, Ordering::Relaxed);
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_sent.load(Ordering::Relaxed)
    }

    pub fn total_messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }

    /// All samples of one series, sorted by round.
    pub fn series(&self, name: &str) -> Vec<(u64, f64)> {
        let mut out: Vec<(u64, f64)> = self
            .samples
            .lock()
            .unwrap()
            .iter()
            .filter(|s| &*s.series == name)
            .map(|s| (s.round, s.value))
            .collect();
        out.sort_by_key(|(r, _)| *r);
        out
    }

    /// Last value of a series, if any.
    pub fn last(&self, name: &str) -> Option<f64> {
        self.series(name).last().map(|(_, v)| *v)
    }

    pub fn all(&self) -> Vec<Sample> {
        self.samples.lock().unwrap().clone()
    }

    /// Checkpoint encoding of everything recorded so far: samples in
    /// insertion order (series extraction is a stable sort, so order
    /// within a round is observable) plus the traffic counters. Each row
    /// carries its own job id as the fifth element so a cross-hub restore
    /// keeps sample provenance (empty = "stamp with the restoring hub").
    pub fn snapshot(&self) -> Json {
        let mut o = Json::obj();
        let samples: Vec<Json> = self
            .samples
            .lock()
            .unwrap()
            .iter()
            .map(|s| {
                Json::Arr(vec![
                    Json::Str(s.worker.to_string()),
                    Json::Str(s.series.to_string()),
                    Json::from(s.round),
                    Json::Num(s.value),
                    Json::Str(s.job.to_string()),
                ])
            })
            .collect();
        o.insert("samples", Json::Arr(samples));
        o.insert("bytes", json::from_u64_hex(self.bytes_sent.load(Ordering::Relaxed)));
        o.insert("messages", json::from_u64_hex(self.messages.load(Ordering::Relaxed)));
        Json::Obj(o)
    }

    /// Replace this hub's contents with a snapshot taken by
    /// [`MetricsHub::snapshot`] (resume-from-checkpoint: rounds recorded
    /// before the kill point come back verbatim). Rows that recorded a
    /// job id keep it — a cross-hub restore no longer re-stamps foreign
    /// samples with the restoring hub's id; only legacy four-element rows
    /// (and rows from anonymous hubs) fall back to it.
    pub fn restore(&self, snap: &Json) {
        let mut samples = self.samples.lock().unwrap();
        samples.clear();
        if let Some(rows) = snap.get("samples").as_arr() {
            for row in rows {
                let job = match row.idx(4).as_str() {
                    Some(j) if !j.is_empty() => atom(j),
                    _ => self.job.clone(),
                };
                samples.push(Sample {
                    job,
                    worker: atom(row.idx(0).as_str().unwrap_or("")),
                    series: atom(row.idx(1).as_str().unwrap_or("")),
                    round: row.idx(2).as_f64().unwrap_or(0.0) as u64,
                    value: row.idx(3).as_f64().unwrap_or(0.0),
                });
            }
        }
        drop(samples);
        self.bytes_sent
            .store(json::as_u64_hex(snap.get("bytes")).unwrap_or(0), Ordering::Relaxed);
        self.messages
            .store(json::as_u64_hex(snap.get("messages")).unwrap_or(0), Ordering::Relaxed);
    }

    /// Merge several series into one CSV: `round,<series...>` (missing cells
    /// empty). Returns the CSV text.
    pub fn to_csv(&self, series: &[&str]) -> String {
        let mut rows: BTreeMap<u64, BTreeMap<&str, f64>> = BTreeMap::new();
        for name in series {
            for (round, v) in self.series(name) {
                rows.entry(round).or_default().insert(name, v);
            }
        }
        let mut out = String::from("round");
        for name in series {
            out.push(',');
            out.push_str(name);
        }
        out.push('\n');
        for (round, cells) in rows {
            out.push_str(&round.to_string());
            for name in series {
                out.push(',');
                if let Some(v) = cells.get(name) {
                    out.push_str(&format!("{v}"));
                }
            }
            out.push('\n');
        }
        out
    }

    pub fn write_csv(&self, path: impl AsRef<Path>, series: &[&str]) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        fs::write(path, self.to_csv(series))?;
        Ok(())
    }

    /// Like [`Self::to_csv`] but with a leading `job` column, so rows from
    /// many concurrent jobs' hubs can be concatenated into one fleet CSV
    /// without interleaving into an anonymous series. `header` controls
    /// whether the `job,round,<series...>` header line is emitted (pass
    /// `true` for the first hub only when concatenating).
    pub fn to_csv_with_job(&self, series: &[&str], header: bool) -> String {
        let mut out = String::new();
        if header {
            out.push_str("job,round");
            for name in series {
                out.push(',');
                out.push_str(name);
            }
            out.push('\n');
        }
        let body = self.to_csv(series);
        for line in body.lines().skip(1) {
            out.push_str(&self.job);
            out.push(',');
            out.push_str(line);
            out.push('\n');
        }
        out
    }
}

/// Format a virtual duration for logs.
pub fn fmt_vtime(us: VTime) -> String {
    if us >= 1_000_000 {
        format!("{:.2}s", us as f64 / 1e6)
    } else if us >= 1000 {
        format!("{:.1}ms", us as f64 / 1e3)
    } else {
        format!("{us}us")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_series() {
        let m = MetricsHub::new();
        m.record("w0", "loss", 2, 0.5);
        m.record("w0", "loss", 1, 0.9);
        m.record("w1", "acc", 1, 0.4);
        assert_eq!(m.series("loss"), vec![(1, 0.9), (2, 0.5)]);
        assert_eq!(m.last("loss"), Some(0.5));
        assert_eq!(m.last("nope"), None);
    }

    #[test]
    fn traffic_counters() {
        let m = MetricsHub::new();
        m.add_traffic(100);
        m.add_traffic(250);
        assert_eq!(m.total_bytes(), 350);
        assert_eq!(m.total_messages(), 2);
    }

    #[test]
    fn csv_layout() {
        let m = MetricsHub::new();
        m.record("g", "loss", 1, 0.5);
        m.record("g", "acc", 1, 0.9);
        m.record("g", "loss", 2, 0.25);
        let csv = m.to_csv(&["loss", "acc"]);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "round,loss,acc");
        assert_eq!(lines[1], "1,0.5,0.9");
        assert_eq!(lines[2], "2,0.25,");
    }

    #[test]
    fn samples_carry_the_job_id() {
        let m = MetricsHub::for_job("fleet-cfl-3");
        m.record("w0", "loss", 1, 0.5);
        let all = m.all();
        assert_eq!(all.len(), 1);
        assert_eq!(&*all[0].job, "fleet-cfl-3");
        assert_eq!(m.job_id(), "fleet-cfl-3");
        // standalone hubs stamp the empty id
        let anon = MetricsHub::new();
        anon.record("w0", "loss", 1, 0.5);
        assert_eq!(&*anon.all()[0].job, "");
    }

    #[test]
    fn record_interns_names() {
        use std::sync::Arc;
        let m = MetricsHub::for_job("intern-job");
        m.record("w0", "loss", 1, 0.5);
        m.record("w0", "loss", 2, 0.25);
        let all = m.all();
        // repeated names share one allocation — the recording fast path
        // clones pointers, it does not re-allocate strings
        assert!(Arc::ptr_eq(&all[0].worker, &all[1].worker));
        assert!(Arc::ptr_eq(&all[0].series, &all[1].series));
        assert!(Arc::ptr_eq(&all[0].job, &all[1].job));
    }

    #[test]
    fn restore_preserves_recorded_job_ids() {
        // a fleet aggregator hub holding samples from two jobs
        let a = MetricsHub::for_job("job-a");
        a.record("g", "loss", 1, 0.5);
        let b = MetricsHub::for_job("job-b");
        b.record("g", "loss", 1, 0.25);
        let merged = MetricsHub::for_job("fleet");
        for s in a.all().into_iter().chain(b.all()) {
            merged.samples.lock().unwrap().push(s);
        }
        let snap = merged.snapshot();
        // restoring into a differently-named hub must keep each row's
        // recorded job id, not re-stamp everything with "other"
        let other = MetricsHub::for_job("other");
        other.restore(&snap);
        let jobs: Vec<String> = other.all().iter().map(|s| s.job.to_string()).collect();
        assert_eq!(jobs, vec!["job-a", "job-b"]);
        // legacy four-element rows (no job column) fall back to the
        // restoring hub's id
        let legacy = Json::parse(
            r#"{"samples":[["w0","loss",1,0.5]],"bytes":"0000000000000000","messages":"0000000000000000"}"#,
        )
        .unwrap();
        other.restore(&legacy);
        assert_eq!(&*other.all()[0].job, "other");
    }

    #[test]
    fn job_csv_prefixes_every_row_and_concatenates() {
        let a = MetricsHub::for_job("job-a");
        a.record("g", "loss", 1, 0.5);
        a.record("g", "acc", 1, 0.9);
        let b = MetricsHub::for_job("job-b");
        b.record("g", "loss", 1, 0.25);
        let mut csv = a.to_csv_with_job(&["loss", "acc"], true);
        csv.push_str(&b.to_csv_with_job(&["loss", "acc"], false));
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "job,round,loss,acc");
        assert_eq!(lines[1], "job-a,1,0.5,0.9");
        assert_eq!(lines[2], "job-b,1,0.25,");
    }

    #[test]
    fn vtime_formatting() {
        assert_eq!(fmt_vtime(10), "10us");
        assert_eq!(fmt_vtime(1_500), "1.5ms");
        assert_eq!(fmt_vtime(2_500_000), "2.50s");
    }

    #[test]
    fn concurrent_recording() {
        use std::sync::Arc;
        let m = Arc::new(MetricsHub::new());
        let hs: Vec<_> = (0..4)
            .map(|t| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for i in 0..100 {
                        m.record(&format!("w{t}"), "x", i, i as f64);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.all().len(), 400);
    }
}
