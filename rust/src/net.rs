//! Virtual-time network model (the paper's `tc`-shaped testbed stand-in).
//!
//! The paper's §6.1/§6.2 experiments emulate heterogeneous links (a 1 Mbps
//! straggler uplink vs 100 Mbps peer links) with Linux `tc` and measure
//! wall-clock effects. Re-running that in real time would cost hours of
//! sleeping, so Flame's channels instead account *virtual* time: every
//! message transfer costs `latency + bytes * 8 / bandwidth` on each hop, and
//! each worker carries a [`VClock`] that advances on compute and merges on
//! receive (`recv_clock = max(recv_clock, send_clock + transfer)`). Round
//! times reported by the benches are therefore critical-path times over the
//! communication DAG — exactly what `tc` + wall clock measures, but
//! deterministic and fast.
//!
//! Topology knobs mirror `tc` usage: a default link, per-node uplink /
//! downlink shaping, and per-pair overrides. Broker-backed channels route
//! via a hub node (two hops); p2p channels use the direct link.

use std::collections::HashMap;
use std::sync::RwLock;

/// Virtual time in microseconds.
pub type VTime = u64;

/// A monotone per-worker virtual clock.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord)]
pub struct VClock(pub VTime);

impl VClock {
    pub fn advance(&mut self, dt: VTime) -> VTime {
        self.0 += dt;
        self.0
    }

    /// Merge an incoming event timestamp (message arrival): clocks never go
    /// backwards, which is the causality invariant property-tested below.
    pub fn merge(&mut self, t: VTime) -> VTime {
        self.0 = self.0.max(t);
        self.0
    }

    pub fn now(&self) -> VTime {
        self.0
    }
}

/// Directed link shape: bits/second + one-way latency.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    pub bandwidth_bps: f64,
    pub latency_us: VTime,
}

impl LinkSpec {
    pub fn new(bandwidth_bps: f64, latency_us: VTime) -> Self {
        assert!(bandwidth_bps > 0.0, "bandwidth must be positive");
        Self {
            bandwidth_bps,
            latency_us,
        }
    }

    pub fn mbps(mbps: f64, latency_us: VTime) -> Self {
        Self::new(mbps * 1e6, latency_us)
    }

    /// Transfer cost of `bytes` over this link, in virtual microseconds.
    pub fn transfer_us(&self, bytes: u64) -> VTime {
        let secs = (bytes as f64 * 8.0) / self.bandwidth_bps;
        self.latency_us + (secs * 1e6).round() as VTime
    }
}

impl Default for LinkSpec {
    /// 1 Gbps, 200 µs one-way — a LAN-ish default.
    fn default() -> Self {
        Self::new(1e9, 200)
    }
}

/// A shaping rule active during a virtual-time window (`tc` scripts change
/// shaping over the course of an experiment; this is the virtual-time
/// equivalent — e.g. Fig 10's congestion that starts at round 6).
#[derive(Debug, Clone, Copy)]
struct TimedSpec {
    spec: LinkSpec,
    from: VTime,
    until: VTime,
}

impl TimedSpec {
    fn active_at(&self, t: VTime) -> bool {
        t >= self.from && t < self.until
    }
}

fn lookup(rules: &[TimedSpec], t: VTime) -> Option<LinkSpec> {
    // latest-added active rule wins
    rules.iter().rev().find(|r| r.active_at(t)).map(|r| r.spec)
}

#[derive(Default)]
struct Shaping {
    default: LinkSpec,
    /// tc-style per-node egress shaping (applies to the sending side).
    uplink: HashMap<String, Vec<TimedSpec>>,
    /// per-node ingress shaping (applies to the receiving side).
    downlink: HashMap<String, Vec<TimedSpec>>,
    /// exact (from -> to) overrides — strongest precedence. Nested so the
    /// hot path can look up by `&str` without allocating a key pair.
    pair: HashMap<String, HashMap<String, Vec<TimedSpec>>>,
}

impl Shaping {
    /// No rules at all: every hop is the default link. This is the common
    /// case for large-scale runs, where per-message allocation-free lookup
    /// matters (a 10k-worker round is hundreds of thousands of hops).
    fn is_trivial(&self) -> bool {
        self.uplink.is_empty() && self.downlink.is_empty() && self.pair.is_empty()
    }
}

/// The shared virtual network. Cheap to clone handles around via `Arc`.
pub struct VirtualNet {
    shaping: RwLock<Shaping>,
}

impl Default for VirtualNet {
    fn default() -> Self {
        Self::new(LinkSpec::default())
    }
}

impl VirtualNet {
    pub fn new(default: LinkSpec) -> Self {
        Self {
            shaping: RwLock::new(Shaping {
                default,
                ..Default::default()
            }),
        }
    }

    /// Replace the default (unshaped) link of the whole fabric.
    pub fn set_default(&self, spec: LinkSpec) {
        self.shaping.write().unwrap().default = spec;
    }

    /// Shape a node's egress (like `tc qdisc ... dev eth0 egress`).
    pub fn set_uplink(&self, node: &str, spec: LinkSpec) {
        self.set_uplink_window(node, spec, 0, VTime::MAX);
    }

    /// Egress shaping active only during `[from, until)` virtual time.
    pub fn set_uplink_window(&self, node: &str, spec: LinkSpec, from: VTime, until: VTime) {
        self.shaping
            .write()
            .unwrap()
            .uplink
            .entry(node.to_string())
            .or_default()
            .push(TimedSpec { spec, from, until });
    }

    pub fn clear_uplink(&self, node: &str) {
        self.shaping.write().unwrap().uplink.remove(node);
    }

    pub fn set_downlink(&self, node: &str, spec: LinkSpec) {
        self.shaping
            .write()
            .unwrap()
            .downlink
            .entry(node.to_string())
            .or_default()
            .push(TimedSpec {
                spec,
                from: 0,
                until: VTime::MAX,
            });
    }

    /// Exact-pair override (highest precedence).
    pub fn set_pair(&self, from: &str, to: &str, spec: LinkSpec) {
        self.set_pair_window(from, to, spec, 0, VTime::MAX);
    }

    /// Pair override active only during `[from_t, until_t)` virtual time.
    pub fn set_pair_window(
        &self,
        from: &str,
        to: &str,
        spec: LinkSpec,
        from_t: VTime,
        until_t: VTime,
    ) {
        self.shaping
            .write()
            .unwrap()
            .pair
            .entry(from.to_string())
            .or_default()
            .entry(to.to_string())
            .or_default()
            .push(TimedSpec {
                spec,
                from: from_t,
                until: until_t,
            });
    }

    /// Effective link for one hop at virtual time `at`: pair override, else
    /// the *slowest* of (sender uplink, receiver downlink, default) —
    /// matching how serial `tc` shapers compose on a path (bottleneck
    /// bandwidth; latency approximated by the max of the shapers').
    fn hop(&self, from: &str, to: &str, at: VTime) -> LinkSpec {
        let g = self.shaping.read().unwrap();
        if g.is_trivial() {
            return g.default;
        }
        if let Some(s) = g
            .pair
            .get(from)
            .and_then(|m| m.get(to))
            .and_then(|r| lookup(r, at))
        {
            return s;
        }
        let mut bw = g.default.bandwidth_bps;
        let mut lat = g.default.latency_us;
        if let Some(u) = g.uplink.get(from).and_then(|r| lookup(r, at)) {
            bw = bw.min(u.bandwidth_bps);
            lat = lat.max(u.latency_us);
        }
        if let Some(d) = g.downlink.get(to).and_then(|r| lookup(r, at)) {
            bw = bw.min(d.bandwidth_bps);
            lat = lat.max(d.latency_us);
        }
        LinkSpec::new(bw, lat)
    }

    /// Direct (p2p) transfer cost for a send occurring at virtual time `at`.
    pub fn transfer_at_us(&self, from: &str, to: &str, bytes: u64, at: VTime) -> VTime {
        self.hop(from, to, at).transfer_us(bytes)
    }

    /// Direct (p2p) transfer cost (time-independent shaping).
    pub fn transfer_us(&self, from: &str, to: &str, bytes: u64) -> VTime {
        self.transfer_at_us(from, to, bytes, 0)
    }

    /// Broker-routed transfer cost: `from -> hub` + `hub -> to`.
    pub fn transfer_via_at_us(
        &self,
        from: &str,
        hub: &str,
        to: &str,
        bytes: u64,
        at: VTime,
    ) -> VTime {
        let first = self.hop(from, hub, at).transfer_us(bytes);
        self.hop(hub, to, at + first).transfer_us(bytes) + first
    }

    /// Broker-routed transfer cost (time-independent shaping).
    pub fn transfer_via_us(&self, from: &str, hub: &str, to: &str, bytes: u64) -> VTime {
        self.transfer_via_at_us(from, hub, to, bytes, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proputil::{check, ensure};

    #[test]
    fn transfer_math() {
        // 1 MB over 8 Mbps = 1 s = 1e6 us, plus 100 us latency.
        let l = LinkSpec::new(8e6, 100);
        assert_eq!(l.transfer_us(1_000_000), 1_000_100);
        // zero bytes costs just latency
        assert_eq!(l.transfer_us(0), 100);
    }

    #[test]
    fn default_is_symmetric() {
        let net = VirtualNet::default();
        assert_eq!(net.transfer_us("a", "b", 1000), net.transfer_us("b", "a", 1000));
    }

    #[test]
    fn uplink_shaping_slows_sender_only() {
        let net = VirtualNet::new(LinkSpec::mbps(100.0, 0));
        net.set_uplink("straggler", LinkSpec::mbps(1.0, 0));
        let fast = net.transfer_us("peer", "agg", 1_000_000);
        let slow = net.transfer_us("straggler", "agg", 1_000_000);
        assert_eq!(fast, 80_000); // 8 Mbit over 100 Mbps = 80 ms
        assert_eq!(slow, 8_000_000); // 8 Mbit over 1 Mbps = 8 s
        // Receiving at the straggler is NOT shaped by its uplink.
        assert_eq!(net.transfer_us("agg", "straggler", 1_000_000), 80_000);
    }

    #[test]
    fn pair_override_wins() {
        let net = VirtualNet::new(LinkSpec::mbps(100.0, 10));
        net.set_uplink("a", LinkSpec::mbps(1.0, 10));
        net.set_pair("a", "b", LinkSpec::mbps(50.0, 5));
        assert_eq!(
            net.transfer_us("a", "b", 1_000_000),
            LinkSpec::mbps(50.0, 5).transfer_us(1_000_000)
        );
        // other destinations still see the uplink shaping
        assert!(net.transfer_us("a", "c", 1_000_000) > 1_000_000);
    }

    #[test]
    fn broker_route_is_two_hops() {
        let net = VirtualNet::new(LinkSpec::mbps(10.0, 100));
        let direct = net.transfer_us("a", "b", 500_000);
        let via = net.transfer_via_us("a", "hub", "b", 500_000);
        assert_eq!(via, 2 * direct);
    }

    #[test]
    fn bottleneck_composition() {
        let net = VirtualNet::new(LinkSpec::mbps(1000.0, 1));
        net.set_uplink("a", LinkSpec::mbps(10.0, 1));
        net.set_downlink("b", LinkSpec::mbps(5.0, 1));
        // path bottleneck = 5 Mbps
        assert_eq!(
            net.transfer_us("a", "b", 1_000_000),
            LinkSpec::mbps(5.0, 1).transfer_us(1_000_000)
        );
    }

    #[test]
    fn windowed_shaping_applies_only_in_window() {
        let net = VirtualNet::new(LinkSpec::mbps(100.0, 0));
        net.set_uplink_window("s", LinkSpec::mbps(1.0, 0), 1_000_000, 2_000_000);
        let fast = LinkSpec::mbps(100.0, 0).transfer_us(1_000_000);
        let slow = LinkSpec::mbps(1.0, 0).transfer_us(1_000_000);
        assert_eq!(net.transfer_at_us("s", "a", 1_000_000, 0), fast);
        assert_eq!(net.transfer_at_us("s", "a", 1_000_000, 1_500_000), slow);
        assert_eq!(net.transfer_at_us("s", "a", 1_000_000, 2_000_000), fast);
    }

    #[test]
    fn later_rules_override_earlier() {
        let net = VirtualNet::new(LinkSpec::mbps(100.0, 0));
        net.set_uplink("s", LinkSpec::mbps(10.0, 0));
        net.set_uplink("s", LinkSpec::mbps(1.0, 0));
        assert_eq!(
            net.transfer_us("s", "a", 1_000_000),
            LinkSpec::mbps(1.0, 0).transfer_us(1_000_000)
        );
    }

    #[test]
    fn broker_second_hop_evaluated_after_first_hop_elapses() {
        // a window that opens between the two hops of a broker route must
        // affect only the second hop
        let net = VirtualNet::new(LinkSpec::mbps(8.0, 0));
        // 1 MB at 8 Mbps = 1s per hop; congest hub->b from t=1s on
        net.set_pair_window("hub", "b", LinkSpec::mbps(0.8, 0), 1_000_000, VTime::MAX);
        let t = net.transfer_via_at_us("a", "hub", "b", 1_000_000, 0);
        // first hop 1s (uncongested), second hop starts at t=1s -> 10s
        assert_eq!(t, 11_000_000);
        // sending before the window with a fast second hop
        let t0 = net.transfer_via_at_us("a", "hub", "b", 100, 0);
        assert!(t0 < 1_000);
    }

    #[test]
    fn clock_merge_is_monotone_property() {
        check(
            "vclock-monotone",
            42,
            500,
            |r| {
                let ops: Vec<(bool, u64)> = (0..20)
                    .map(|_| (r.f64() < 0.5, r.below(1_000_000)))
                    .collect();
                ops
            },
            |ops| {
                let mut c = VClock::default();
                let mut last = 0;
                for (is_advance, v) in ops {
                    let now = if *is_advance { c.advance(*v) } else { c.merge(*v) };
                    ensure(now >= last, format!("clock went backwards: {now} < {last}"))?;
                    last = now;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn arrival_never_precedes_send_property() {
        check(
            "causality",
            43,
            500,
            |r| (r.below(1 << 30), r.below(10_000_000) as u64, r.below(1 << 20)),
            |(send_t, _bw_sel, bytes)| {
                let net = VirtualNet::default();
                let arrival = send_t + net.transfer_us("a", "b", *bytes);
                ensure(arrival >= *send_t, "arrival precedes send")
            },
        );
    }
}
