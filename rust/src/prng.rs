//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! [`Pcg64`]-class generator built from SplitMix64 seeding + xoshiro256**,
//! with the distribution helpers the rest of the crate needs: uniform,
//! normal (Box–Muller), gamma (Marsaglia–Tsang) and Dirichlet (for non-IID
//! data partitioning), plus Fisher–Yates shuffling and sampling.
//!
//! All simulation results in this repo are reproducible from a single `u64`
//! seed threaded through job configs.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

/// FNV-1a over raw bytes — the crate's string→tag mixer (worker ids to
/// [`Rng::fork`] tags). Unlike a plain `h = h*131 + b` polynomial fold,
/// every byte is XOR-folded *and* multiplied through the full 64-bit
/// state, so short byte patterns cannot cancel each other out (the fold
/// is linear: `[1, 0]` and `[0, 131]` collide under it).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// The full generator state — everything needed to resume the stream
    /// at exactly this point (round-boundary checkpoints).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator mid-stream from [`Rng::state`].
    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s }
    }

    /// Overwrite this generator's stream position with a saved state.
    pub fn set_state(&mut self, s: [u64; 4]) {
        self.s = s;
    }

    /// Checkpoint encoding: the four state words as hex strings (a
    /// `Json::Num` is an f64 and would truncate them).
    pub fn to_json(&self) -> crate::json::Json {
        crate::json::Json::Arr(self.s.iter().map(|w| crate::json::from_u64_hex(*w)).collect())
    }

    /// Decode a stream position written by [`Rng::to_json`].
    pub fn from_json(j: &crate::json::Json) -> Option<Rng> {
        let a = j.as_arr()?;
        if a.len() != 4 {
            return None;
        }
        let mut s = [0u64; 4];
        for (i, w) in a.iter().enumerate() {
            s[i] = crate::json::as_u64_hex(w)?;
        }
        Some(Rng::from_state(s))
    }

    /// Derive an independent stream (e.g. per worker) from this seed space.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`. Uses Lemire rejection for lack of bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= (n.wrapping_neg() % n) {
                return hi;
            }
        }
    }

    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (with Johnk boost for shape < 1).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0);
        if shape < 1.0 {
            // Gamma(a) = Gamma(a + 1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = loop {
                let u = self.f64();
                if u > 0.0 {
                    break u;
                }
            };
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1_k) sample — the standard non-IID label split.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = g.iter().sum();
        if sum <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for x in &mut g {
            *x /= sum;
        }
        g
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_and_in_range() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(4);
        for shape in [0.3, 1.0, 2.5, 9.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.1 * shape.max(1.0),
                "shape={shape} mean={mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(5);
        for alpha in [0.1, 0.5, 10.0] {
            let p = r.dirichlet(alpha, 10);
            assert_eq!(p.len(), 10);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_concentration_behaviour() {
        // small alpha -> sparse; large alpha -> near-uniform
        let mut r = Rng::new(6);
        let sparse = r.dirichlet(0.05, 10);
        let uniform = r.dirichlet(100.0, 10);
        let max_sparse = sparse.iter().cloned().fold(0.0, f64::max);
        let max_uniform = uniform.iter().cloned().fold(0.0, f64::max);
        assert!(max_sparse > 0.5, "{sparse:?}");
        assert!(max_uniform < 0.2, "{uniform:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(7);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(8);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 30);
    }

    #[test]
    fn fnv_tag_separates_fold_collisions() {
        // The legacy worker-tag derivation (h = h*131 + b) is linear, so
        // distinct byte strings cancel: [1, 0] and [0, 131] both fold to
        // 131 — two workers whose ids folded equal would share an RNG
        // stream. FNV-1a keeps them apart.
        let fold = |bs: &[u8]| {
            bs.iter()
                .fold(0u64, |a, &b| a.wrapping_mul(131).wrapping_add(b as u64))
        };
        let (a, b): (&[u8], &[u8]) = (&[1, 0], &[0, 131]);
        assert_eq!(fold(a), fold(b), "legacy fold should collide here");
        assert_ne!(fnv1a64(a), fnv1a64(b));
        // realistic worker-id families yield pairwise-distinct tags (and
        // therefore distinct forked streams)
        let mut seen = std::collections::HashSet::new();
        for role in ["trainer", "aggregator", "global-aggregator"] {
            for i in 0..10_000 {
                let tag = fnv1a64(format!("job-{role}-{i}").as_bytes());
                assert!(seen.insert(tag), "tag collision for job-{role}-{i}");
            }
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xa: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }
}
