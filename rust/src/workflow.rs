//! Tasklet / Composer workflow engine — the paper's developer programming
//! model (§4.4, Fig 6, Table 1).
//!
//! A role's work is structured as a chain of [`Tasklet`]s plus a [`Loop`]
//! primitive that repeats a sub-chain until an exit condition holds. The
//! paper's Python SDK overloads `>>` to chain tasklets; here the same shape
//! is a builder API (`seq`, `task`, `loop_until`). Crucially, the **surgery
//! API of Table 1** is reproduced verbatim so that derived roles (e.g. the
//! CO-FL global aggregator of Fig 9) can extend an inherited chain without
//! touching the base implementation:
//!
//! | paper (Table 1)          | here                                 |
//! |--------------------------|--------------------------------------|
//! | `get_tasklet(alias)`     | [`Composer::get_tasklet`]            |
//! | `t.insert_before(x)`     | [`Composer::insert_before`]          |
//! | `t.insert_after(x)`      | [`Composer::insert_after`]           |
//! | `t.replace_with(x)`      | [`Composer::replace_with`]           |
//! | `t.remove()`             | [`Composer::remove`]                 |
//!
//! The chain is generic over a context type `C` (the role's state), so the
//! same engine drives trainers, aggregators and coordinators.
//!
//! ## Cooperative execution
//!
//! Chains are *step-structured*, which is what lets the worker fabric
//! ([`crate::sched`]) multiplex thousands of workers over a few runner
//! threads: when a tasklet's blocking receive finds no mail it raises the
//! [`crate::sched::Pending`] signal, and [`Composer::step_from`] suspends
//! the chain at that tasklet, returning a resume path (the index path into
//! the possibly-nested node tree). The next step re-enters exactly there.
//!
//! **Re-entrancy contract:** a suspended tasklet is *re-run from its
//! start* on resume, so role tasklets must be idempotent up to their first
//! blocking receive — do not send or mutate non-idempotent state before a
//! receive that can yield. Multi-message receives either use the atomic
//! `recv_fifo` barrier (nothing is consumed until everything arrived) or
//! persist partial progress in the role context (see the global
//! aggregator's collect and the ring all-reduce state machine).

use anyhow::{bail, Result};

use crate::sched::is_pending;

/// Result of driving a chain one step: ran to completion, or suspended at
/// a yielding tasklet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepStatus {
    Done,
    Pending,
}

/// A named unit of work over role state `C`.
pub struct Tasklet<C> {
    pub alias: String,
    f: Box<dyn FnMut(&mut C) -> Result<()> + Send>,
}

impl<C> Tasklet<C> {
    pub fn new(alias: impl Into<String>, f: impl FnMut(&mut C) -> Result<()> + Send + 'static) -> Self {
        Self {
            alias: alias.into(),
            f: Box::new(f),
        }
    }
}

/// Chain node: a tasklet or a loop over a sub-chain.
pub enum Node<C> {
    Task(Tasklet<C>),
    Loop {
        /// Exit condition — the loop repeats its body **until** this returns
        /// true (the paper's `loop_check_fn`).
        check: Box<dyn FnMut(&C) -> bool + Send>,
        body: Vec<Node<C>>,
    },
}

/// An ordered tasklet chain with loop structure and surgery operations.
pub struct Composer<C> {
    nodes: Vec<Node<C>>,
}

impl<C> Default for Composer<C> {
    fn default() -> Self {
        Self { nodes: Vec::new() }
    }
}

impl<C> Composer<C> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a tasklet (the `>>` of the Python SDK).
    pub fn task(
        mut self,
        alias: impl Into<String>,
        f: impl FnMut(&mut C) -> Result<()> + Send + 'static,
    ) -> Self {
        self.nodes.push(Node::Task(Tasklet::new(alias, f)));
        self
    }

    /// Append a loop that repeats `body` until `check` returns true.
    pub fn loop_until(
        mut self,
        check: impl FnMut(&C) -> bool + Send + 'static,
        body: Composer<C>,
    ) -> Self {
        self.nodes.push(Node::Loop {
            check: Box::new(check),
            body: body.nodes,
        });
        self
    }

    /// Execute the chain to completion (blocking mode: receives wait, so
    /// the chain never suspends).
    pub fn run(&mut self, ctx: &mut C) -> Result<()> {
        match self.step_from(&[], ctx)? {
            (StepStatus::Done, _) => Ok(()),
            (StepStatus::Pending, _) => {
                bail!("tasklet chain yielded outside a cooperative scheduler")
            }
        }
    }

    /// Drive the chain from `resume` (empty = from the top) until it
    /// completes or a tasklet yields [`crate::sched::Pending`]. On
    /// `Pending`, the returned path locates the suspended tasklet; pass it
    /// back as `resume` to continue. Loop iterations that were in flight
    /// when the chain suspended are finished before their exit condition is
    /// re-checked, exactly as uninterrupted execution would.
    pub fn step_from(
        &mut self,
        resume: &[usize],
        ctx: &mut C,
    ) -> Result<(StepStatus, Vec<usize>)> {
        let mut pend = Vec::new();
        let status = Self::exec_nodes(&mut self.nodes, ctx, resume, &mut pend)?;
        Ok((status, pend))
    }

    fn exec_nodes(
        nodes: &mut [Node<C>],
        ctx: &mut C,
        resume: &[usize],
        pend: &mut Vec<usize>,
    ) -> Result<StepStatus> {
        let (start, deeper): (usize, &[usize]) = match resume.split_first() {
            Some((&s, rest)) => (s, rest),
            None => (0, &[]),
        };
        let mut at_resume_node = !resume.is_empty();
        let mut i = start;
        while i < nodes.len() {
            let node_resume: &[usize] = if at_resume_node { deeper } else { &[] };
            at_resume_node = false;
            match &mut nodes[i] {
                Node::Task(t) => {
                    if let Err(e) = (t.f)(ctx) {
                        if is_pending(&e) {
                            pend.push(i);
                            return Ok(StepStatus::Pending);
                        }
                        return Err(e);
                    }
                }
                Node::Loop { check, body } => {
                    // Finish the iteration that was suspended inside this
                    // loop's body (resume paths always end at a Task, so a
                    // non-empty node_resume means "we were inside").
                    if !node_resume.is_empty() {
                        pend.push(i);
                        match Self::exec_nodes(body, ctx, node_resume, pend)? {
                            StepStatus::Pending => return Ok(StepStatus::Pending),
                            StepStatus::Done => {
                                pend.pop();
                            }
                        }
                    }
                    while !(check)(ctx) {
                        pend.push(i);
                        match Self::exec_nodes(body, ctx, &[], pend)? {
                            StepStatus::Pending => return Ok(StepStatus::Pending),
                            StepStatus::Done => {
                                pend.pop();
                            }
                        }
                    }
                }
            }
            i += 1;
        }
        Ok(StepStatus::Done)
    }

    // ------------------------------------------------------------ surgery

    /// Aliases in execution order (loops flattened), for inspection/tests.
    pub fn aliases(&self) -> Vec<String> {
        fn walk<C>(nodes: &[Node<C>], out: &mut Vec<String>) {
            for n in nodes {
                match n {
                    Node::Task(t) => out.push(t.alias.clone()),
                    Node::Loop { body, .. } => walk(body, out),
                }
            }
        }
        let mut out = Vec::new();
        walk(&self.nodes, &mut out);
        out
    }

    /// Does a tasklet with this alias exist anywhere in the chain?
    pub fn get_tasklet(&self, alias: &str) -> bool {
        self.aliases().iter().any(|a| a == alias)
    }

    /// Insert `t` immediately before the tasklet with `alias`.
    pub fn insert_before(&mut self, alias: &str, t: Tasklet<C>) -> Result<()> {
        if !Self::edit(&mut self.nodes, alias, Edit::Before(t)) {
            bail!("tasklet alias '{alias}' not found");
        }
        Ok(())
    }

    /// Insert `t` immediately after the tasklet with `alias`.
    pub fn insert_after(&mut self, alias: &str, t: Tasklet<C>) -> Result<()> {
        if !Self::edit(&mut self.nodes, alias, Edit::After(t)) {
            bail!("tasklet alias '{alias}' not found");
        }
        Ok(())
    }

    /// Replace the tasklet with `alias` by `t`.
    pub fn replace_with(&mut self, alias: &str, t: Tasklet<C>) -> Result<()> {
        if !Self::edit(&mut self.nodes, alias, Edit::Replace(t)) {
            bail!("tasklet alias '{alias}' not found");
        }
        Ok(())
    }

    /// Remove the tasklet with `alias` from the chain.
    pub fn remove(&mut self, alias: &str) -> Result<()> {
        if !Self::edit(&mut self.nodes, alias, Edit::Remove) {
            bail!("tasklet alias '{alias}' not found");
        }
        Ok(())
    }

    fn edit(nodes: &mut Vec<Node<C>>, alias: &str, op: Edit<C>) -> bool {
        let mut op = Some(op);
        Self::edit_inner(nodes, alias, &mut op)
    }

    fn edit_inner(nodes: &mut Vec<Node<C>>, alias: &str, op: &mut Option<Edit<C>>) -> bool {
        let mut i = 0;
        while i < nodes.len() {
            let hit = match &nodes[i] {
                Node::Task(t) => t.alias == alias,
                Node::Loop { .. } => false,
            };
            if hit {
                match op.take().unwrap() {
                    Edit::Before(t) => nodes.insert(i, Node::Task(t)),
                    Edit::After(t) => nodes.insert(i + 1, Node::Task(t)),
                    Edit::Replace(t) => nodes[i] = Node::Task(t),
                    Edit::Remove => {
                        nodes.remove(i);
                    }
                }
                return true;
            }
            if let Node::Loop { body, .. } = &mut nodes[i] {
                if Self::edit_inner(body, alias, op) {
                    return true;
                }
            }
            i += 1;
        }
        false
    }
}

enum Edit<C> {
    Before(Tasklet<C>),
    After(Tasklet<C>),
    Replace(Tasklet<C>),
    Remove,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Default)]
    struct Ctx {
        log: Vec<&'static str>,
        rounds: usize,
    }

    fn rec(name: &'static str) -> impl FnMut(&mut Ctx) -> Result<()> {
        move |c: &mut Ctx| {
            c.log.push(name);
            Ok(())
        }
    }

    fn trainer_like_chain() -> Composer<Ctx> {
        Composer::new()
            .task("load", rec("load"))
            .task("init", rec("init"))
            .loop_until(
                |c: &Ctx| c.rounds >= 3,
                Composer::new()
                    .task("get", rec("get"))
                    .task("train", rec("train"))
                    .task("put", |c: &mut Ctx| {
                        c.log.push("put");
                        c.rounds += 1;
                        Ok(())
                    }),
            )
            .task("end_of_train", rec("end_of_train"))
    }

    #[test]
    fn chain_executes_in_order() {
        let mut ch = Composer::new().task("a", rec("a")).task("b", rec("b"));
        let mut ctx = Ctx::default();
        ch.run(&mut ctx).unwrap();
        assert_eq!(ctx.log, vec!["a", "b"]);
    }

    #[test]
    fn loop_repeats_until_exit_condition() {
        let mut ch = trainer_like_chain();
        let mut ctx = Ctx::default();
        ch.run(&mut ctx).unwrap();
        assert_eq!(
            ctx.log,
            vec![
                "load", "init", "get", "train", "put", "get", "train", "put", "get",
                "train", "put", "end_of_train"
            ]
        );
    }

    #[test]
    fn loop_skipped_if_condition_initially_true() {
        let mut ch = Composer::new().loop_until(|_: &Ctx| true, Composer::new().task("x", rec("x")));
        let mut ctx = Ctx::default();
        ch.run(&mut ctx).unwrap();
        assert!(ctx.log.is_empty());
    }

    #[test]
    fn insert_before_inside_loop() {
        // Fig 9: insert get_coord_ends before 'distribute' — here before 'put'.
        let mut ch = trainer_like_chain();
        ch.insert_before("put", Tasklet::new("coord", rec("coord"))).unwrap();
        let mut ctx = Ctx::default();
        ch.run(&mut ctx).unwrap();
        let first_cycle: Vec<_> = ctx.log[2..6].to_vec();
        assert_eq!(first_cycle, vec!["get", "train", "coord", "put"]);
    }

    #[test]
    fn insert_after_top_level() {
        let mut ch = trainer_like_chain();
        ch.insert_after("init", Tasklet::new("snapshot", rec("snapshot"))).unwrap();
        assert_eq!(
            ch.aliases()[..3],
            ["load".to_string(), "init".into(), "snapshot".into()]
        );
    }

    #[test]
    fn remove_tasklet() {
        // Fig 9: remove 'end_of_train' because the coordinator owns termination.
        let mut ch = trainer_like_chain();
        ch.remove("end_of_train").unwrap();
        let mut ctx = Ctx::default();
        ch.run(&mut ctx).unwrap();
        assert!(!ctx.log.contains(&"end_of_train"));
    }

    #[test]
    fn replace_with_swaps_behaviour() {
        let mut ch = trainer_like_chain();
        ch.replace_with("train", Tasklet::new("train2", rec("train2"))).unwrap();
        let mut ctx = Ctx::default();
        ch.run(&mut ctx).unwrap();
        assert!(ctx.log.contains(&"train2"));
        assert!(!ctx.log.contains(&"train"));
    }

    #[test]
    fn surgery_on_missing_alias_errors() {
        let mut ch = trainer_like_chain();
        assert!(ch.remove("nope").is_err());
        assert!(ch
            .insert_before("nope", Tasklet::new("x", rec("x")))
            .is_err());
        assert!(ch.get_tasklet("train"));
        assert!(!ch.get_tasklet("nope"));
    }

    #[test]
    fn tasklet_error_aborts_run() {
        let mut ch = Composer::new()
            .task("ok", rec("ok"))
            .task("boom", |_: &mut Ctx| anyhow::bail!("boom"))
            .task("unreached", rec("unreached"));
        let mut ctx = Ctx::default();
        assert!(ch.run(&mut ctx).is_err());
        assert_eq!(ctx.log, vec!["ok"]);
    }

    #[test]
    fn nested_loops_execute_inner_per_outer_iteration() {
        // epochs x batches — the shape of a local-training loop
        #[derive(Default)]
        struct C {
            epochs: usize,
            batches: usize,
            log: Vec<(usize, usize)>,
        }
        let mut ch: Composer<C> = Composer::new().loop_until(
            |c: &C| c.epochs >= 3,
            Composer::new()
                .task("reset", |c: &mut C| {
                    c.batches = 0;
                    Ok(())
                })
                .loop_until(
                    |c: &C| c.batches >= 2,
                    Composer::new().task("batch", |c: &mut C| {
                        c.log.push((c.epochs, c.batches));
                        c.batches += 1;
                        Ok(())
                    }),
                )
                .task("end_epoch", |c: &mut C| {
                    c.epochs += 1;
                    Ok(())
                }),
        );
        let mut ctx = C::default();
        ch.run(&mut ctx).unwrap();
        assert_eq!(
            ctx.log,
            vec![(0, 0), (0, 1), (1, 0), (1, 1), (2, 0), (2, 1)]
        );
    }

    #[test]
    fn surgery_inside_nested_loop() {
        #[derive(Default)]
        struct C {
            n: usize,
            hits: usize,
        }
        let mut ch: Composer<C> = Composer::new().loop_until(
            |c: &C| c.n >= 2,
            Composer::new().loop_until(
                |c: &C| c.n >= 2,
                Composer::new().task("tick", |c: &mut C| {
                    c.n += 1;
                    Ok(())
                }),
            ),
        );
        ch.insert_after(
            "tick",
            Tasklet::new("count", |c: &mut C| {
                c.hits += 1;
                Ok(())
            }),
        )
        .unwrap();
        let mut ctx = C::default();
        ch.run(&mut ctx).unwrap();
        assert_eq!(ctx.hits, 2);
        assert_eq!(ch.aliases(), vec!["tick", "count"]);
    }

    /// Epochs × batches — the nested-loop shape of a local-training body.
    /// The target tasklet (`batch`) sits inside the INNER `Node::Loop`.
    #[derive(Default)]
    struct NestedCtx {
        epochs: usize,
        batches: usize,
        log: Vec<&'static str>,
    }

    fn nested_chain() -> Composer<NestedCtx> {
        Composer::new().loop_until(
            |c: &NestedCtx| c.epochs >= 2,
            Composer::new()
                .task("reset", |c: &mut NestedCtx| {
                    c.batches = 0;
                    c.log.push("reset");
                    Ok(())
                })
                .loop_until(
                    |c: &NestedCtx| c.batches >= 2,
                    Composer::new().task("batch", |c: &mut NestedCtx| {
                        c.batches += 1;
                        c.log.push("batch");
                        Ok(())
                    }),
                )
                .task("end_epoch", |c: &mut NestedCtx| {
                    c.epochs += 1;
                    c.log.push("end_epoch");
                    Ok(())
                }),
        )
    }

    #[test]
    fn insert_before_targets_tasklet_inside_nested_loop() {
        let mut ch = nested_chain();
        ch.insert_before("batch", Tasklet::new("pre", |c: &mut NestedCtx| {
            c.log.push("pre");
            Ok(())
        }))
        .unwrap();
        assert_eq!(ch.aliases(), vec!["reset", "pre", "batch", "end_epoch"]);
        let mut ctx = NestedCtx::default();
        ch.run(&mut ctx).unwrap();
        // 2 epochs x 2 batches: every batch is preceded by pre, in place
        assert_eq!(
            ctx.log,
            vec![
                "reset", "pre", "batch", "pre", "batch", "end_epoch", "reset", "pre",
                "batch", "pre", "batch", "end_epoch"
            ]
        );
    }

    #[test]
    fn replace_with_targets_tasklet_inside_nested_loop() {
        let mut ch = nested_chain();
        ch.replace_with("batch", Tasklet::new("batch2", |c: &mut NestedCtx| {
            c.batches += 1;
            c.log.push("batch2");
            Ok(())
        }))
        .unwrap();
        assert_eq!(ch.aliases(), vec!["reset", "batch2", "end_epoch"]);
        let mut ctx = NestedCtx::default();
        ch.run(&mut ctx).unwrap();
        assert!(ctx.log.contains(&"batch2"));
        assert!(!ctx.log.contains(&"batch"));
        assert_eq!(ctx.epochs, 2);
    }

    #[test]
    fn remove_targets_tasklet_inside_nested_loop() {
        // the inner loop's body keeps a second tasklet (`tick`) so the
        // loop still executes — and terminates — after `doomed` is
        // removed, making the "never ran" assertion real coverage
        #[derive(Default)]
        struct C {
            outer: usize,
            inner_ticks: usize,
            doomed_ran: bool,
        }
        let mut ch: Composer<C> = Composer::new().loop_until(
            |c: &C| c.outer >= 2,
            Composer::new()
                .task("advance", |c: &mut C| {
                    c.outer += 1;
                    Ok(())
                })
                .loop_until(
                    |c: &C| c.inner_ticks >= c.outer, // one pass per outer turn
                    Composer::new()
                        .task("doomed", |c: &mut C| {
                            c.doomed_ran = true;
                            Ok(())
                        })
                        .task("tick", |c: &mut C| {
                            c.inner_ticks += 1;
                            Ok(())
                        }),
                ),
        );
        assert_eq!(ch.aliases(), vec!["advance", "doomed", "tick"]);
        ch.remove("doomed").unwrap();
        assert_eq!(ch.aliases(), vec!["advance", "tick"]);
        let mut ctx = C::default();
        ch.run(&mut ctx).unwrap();
        assert!(!ctx.doomed_ran, "removed tasklet still executed");
        assert_eq!(ctx.inner_ticks, 2, "inner loop body really ran");
    }

    #[test]
    fn step_from_resumes_at_yielding_tasklet_inside_loop() {
        // A "recv"-like tasklet that yields Pending twice per round before
        // succeeding; stepping the chain must interleave exactly like an
        // uninterrupted run, re-running only the yielding tasklet.
        #[derive(Default)]
        struct C {
            rounds: usize,
            tries: usize,
            log: Vec<String>,
        }
        let mut ch: Composer<C> = Composer::new()
            .task("init", |c: &mut C| {
                c.log.push("init".into());
                Ok(())
            })
            .loop_until(
                |c: &C| c.rounds >= 2,
                Composer::new()
                    .task("recv", |c: &mut C| {
                        c.tries += 1;
                        if c.tries % 3 != 0 {
                            return Err(crate::sched::pending_err());
                        }
                        c.log.push(format!("recv{}", c.rounds));
                        Ok(())
                    })
                    .task("put", |c: &mut C| {
                        c.log.push(format!("put{}", c.rounds));
                        c.rounds += 1;
                        Ok(())
                    }),
            )
            .task("end", |c: &mut C| {
                c.log.push("end".into());
                Ok(())
            });
        let mut ctx = C::default();
        let mut resume: Vec<usize> = Vec::new();
        let mut steps = 0;
        loop {
            let (st, pend) = ch.step_from(&resume, &mut ctx).unwrap();
            steps += 1;
            match st {
                StepStatus::Done => break,
                StepStatus::Pending => resume = pend,
            }
        }
        // two yields per round, two rounds -> 4 pending steps + final
        assert_eq!(steps, 5);
        assert_eq!(ctx.log, vec!["init", "recv0", "put0", "recv1", "put1", "end"]);
    }

    #[test]
    fn step_from_finishes_suspended_iteration_before_loop_recheck() {
        // The exit condition flips *during* a suspended iteration; the
        // iteration must still run to completion (put executes) before the
        // loop exits — identical to uninterrupted semantics.
        struct C {
            flip: bool,
            yielded: bool,
            log: Vec<&'static str>,
        }
        let mut ch: Composer<C> = Composer::new().loop_until(
            |c: &C| c.flip,
            Composer::new()
                .task("recv", |c: &mut C| {
                    if !c.yielded {
                        c.yielded = true;
                        return Err(crate::sched::pending_err());
                    }
                    c.log.push("recv");
                    Ok(())
                })
                .task("put", |c: &mut C| {
                    c.log.push("put");
                    Ok(())
                }),
        );
        let mut ctx = C {
            flip: false,
            yielded: false,
            log: vec![],
        };
        let (st, pend) = ch.step_from(&[], &mut ctx).unwrap();
        assert_eq!(st, StepStatus::Pending);
        // condition flips while suspended (e.g. a 'done' flag set by the
        // message the resumed recv will consume)
        ctx.flip = true;
        let (st, _) = ch.step_from(&pend, &mut ctx).unwrap();
        assert_eq!(st, StepStatus::Done);
        assert_eq!(ctx.log, vec!["recv", "put"]);
    }

    #[test]
    fn stateful_tasklets_keep_state_across_loop_iterations() {
        let mut counter = 0usize;
        let mut ch: Composer<Ctx> = Composer::new().loop_until(
            |c: &Ctx| c.rounds >= 5,
            Composer::new().task("tick", move |c: &mut Ctx| {
                counter += 1;
                c.rounds = counter;
                Ok(())
            }),
        );
        let mut ctx = Ctx::default();
        ch.run(&mut ctx).unwrap();
        assert_eq!(ctx.rounds, 5);
    }
}
