//! Synthetic dataset substrate + non-IID partitioning.
//!
//! The paper's §6.2 experiment trains on MNIST; this repo substitutes a
//! learnable synthetic stand-in (see DESIGN.md): 10 Gaussian class
//! prototypes in 784-d, samples drawn as `prototype + noise`. What the
//! figures measure — convergence speed under different topologies and
//! backends — depends on the model/aggregation math and data heterogeneity,
//! both of which are preserved; label skew across shards is controlled by a
//! Dirichlet(α) split exactly as in the FL literature.

use crate::prng::Rng;

pub const INPUT_DIM: usize = 784;
pub const NUM_CLASSES: usize = 10;

/// A flat dataset: `x` is row-major `[n, INPUT_DIM]`, `y` holds class ids.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * INPUT_DIM..(i + 1) * INPUT_DIM]
    }

    /// Assemble one fixed-size batch from sample indices (wrapping if the
    /// index list is shorter than `batch`), matching the static HLO shapes.
    pub fn gather_batch(&self, idx: &[usize], batch: usize) -> (Vec<f32>, Vec<i32>) {
        assert!(!idx.is_empty());
        let mut x = Vec::with_capacity(batch * INPUT_DIM);
        let mut y = Vec::with_capacity(batch);
        for b in 0..batch {
            let i = idx[b % idx.len()];
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        (x, y)
    }

    /// Class histogram (useful for skew assertions).
    pub fn class_counts(&self) -> [usize; NUM_CLASSES] {
        let mut c = [0usize; NUM_CLASSES];
        for &y in &self.y {
            c[y as usize] += 1;
        }
        c
    }
}

/// The generator: fixed class prototypes (drawn once from the seed), then
/// `x = prototype[y] + sigma * noise`.
pub struct SynthSource {
    prototypes: Vec<f32>,
    sigma: f32,
    rng: Rng,
}

impl SynthSource {
    pub fn new(seed: u64, sigma: f32) -> Self {
        let mut rng = Rng::new(seed);
        let mut prototypes = Vec::with_capacity(NUM_CLASSES * INPUT_DIM);
        for _ in 0..NUM_CLASSES * INPUT_DIM {
            prototypes.push(rng.normal() as f32);
        }
        Self {
            prototypes,
            sigma,
            rng,
        }
    }

    /// Draw `n` samples with the given class distribution (must sum ~1).
    ///
    /// Samples are `(proto + sigma * noise) / sqrt(1 + sigma^2)`: per-dim
    /// variance stays ~1 regardless of `sigma`, so `sigma` purely controls
    /// the signal-to-noise ratio (task difficulty) without blowing up
    /// activations at high noise.
    pub fn sample(&mut self, n: usize, class_probs: &[f64]) -> Dataset {
        assert_eq!(class_probs.len(), NUM_CLASSES);
        let inv = 1.0 / (1.0 + self.sigma * self.sigma).sqrt();
        let mut x = Vec::with_capacity(n * INPUT_DIM);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let c = self.draw_class(class_probs);
            let p = &self.prototypes[c * INPUT_DIM..(c + 1) * INPUT_DIM];
            for &pv in p {
                x.push((pv + self.sigma * self.rng.normal() as f32) * inv);
            }
            y.push(c as i32);
        }
        Dataset { x, y }
    }

    fn draw_class(&mut self, probs: &[f64]) -> usize {
        let u = self.rng.f64();
        let mut acc = 0.0;
        for (c, &p) in probs.iter().enumerate() {
            acc += p;
            if u < acc {
                return c;
            }
        }
        NUM_CLASSES - 1
    }

    /// Uniform-class dataset (test/eval splits).
    pub fn sample_uniform(&mut self, n: usize) -> Dataset {
        self.sample(n, &[1.0 / NUM_CLASSES as f64; NUM_CLASSES])
    }
}

/// How to split label mass across shards.
#[derive(Debug, Clone, Copy)]
pub enum Partition {
    /// Same class distribution everywhere.
    Iid,
    /// Per-shard class distribution drawn from Dirichlet(alpha): small alpha
    /// = heavy label skew.
    Dirichlet(f64),
}

/// Generate `shards` trainer datasets of `per_shard` samples each, plus a
/// uniform held-out test set of `test_n` samples. Deterministic in `seed`.
pub fn make_federated(
    seed: u64,
    shards: usize,
    per_shard: usize,
    test_n: usize,
    partition: Partition,
    sigma: f32,
) -> (Vec<Dataset>, Dataset) {
    let mut src = SynthSource::new(seed, sigma);
    let mut shard_rng = Rng::new(seed ^ 0xA5A5_5A5A);
    let mut out = Vec::with_capacity(shards);
    for _ in 0..shards {
        let probs = match partition {
            Partition::Iid => vec![1.0 / NUM_CLASSES as f64; NUM_CLASSES],
            Partition::Dirichlet(alpha) => shard_rng.dirichlet(alpha, NUM_CLASSES),
        };
        out.push(src.sample(per_shard, &probs));
    }
    let test = src.sample_uniform(test_n);
    (out, test)
}

/// Deterministic per-epoch batch index plan: shuffled sample indices chunked
/// into fixed-size batches (last batch wraps).
pub fn batch_plan(rng: &mut Rng, n: usize, batch: usize) -> Vec<Vec<usize>> {
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    idx.chunks(batch).map(|c| c.to_vec()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let (a, _) = make_federated(7, 3, 50, 20, Partition::Iid, 0.5);
        let (b, _) = make_federated(7, 3, 50, 20, Partition::Iid, 0.5);
        assert_eq!(a[1].y, b[1].y);
        assert_eq!(a[2].x[..20], b[2].x[..20]);
        let (c, _) = make_federated(8, 3, 50, 20, Partition::Iid, 0.5);
        assert_ne!(a[0].y, c[0].y);
    }

    #[test]
    fn shapes_and_sizes() {
        let (shards, test) = make_federated(1, 4, 64, 128, Partition::Iid, 0.3);
        assert_eq!(shards.len(), 4);
        for s in &shards {
            assert_eq!(s.len(), 64);
            assert_eq!(s.x.len(), 64 * INPUT_DIM);
        }
        assert_eq!(test.len(), 128);
    }

    #[test]
    fn labels_in_range() {
        let (shards, test) = make_federated(2, 2, 100, 100, Partition::Dirichlet(0.3), 0.3);
        for ds in shards.iter().chain(std::iter::once(&test)) {
            assert!(ds.y.iter().all(|&y| (0..NUM_CLASSES as i32).contains(&y)));
        }
    }

    #[test]
    fn dirichlet_partition_is_skewed_iid_is_not() {
        let (iid, _) = make_federated(3, 5, 400, 10, Partition::Iid, 0.3);
        let (skew, _) = make_federated(3, 5, 400, 10, Partition::Dirichlet(0.1), 0.3);
        let max_frac = |d: &Dataset| {
            let c = d.class_counts();
            *c.iter().max().unwrap() as f64 / d.len() as f64
        };
        let iid_max: f64 = iid.iter().map(|d| max_frac(d)).sum::<f64>() / 5.0;
        let skew_max: f64 = skew.iter().map(|d| max_frac(d)).sum::<f64>() / 5.0;
        assert!(iid_max < 0.25, "iid max class fraction {iid_max}");
        assert!(skew_max > 0.5, "dirichlet max class fraction {skew_max}");
    }

    #[test]
    fn classes_are_separable() {
        // nearest-prototype classification should beat chance by a lot —
        // guarantees the learning problem is non-degenerate.
        let mut src = SynthSource::new(5, 0.5);
        let protos = src.prototypes.clone();
        let ds = src.sample_uniform(200);
        let mut correct = 0;
        for i in 0..ds.len() {
            let row = ds.row(i);
            let mut best = (f32::MAX, 0usize);
            for c in 0..NUM_CLASSES {
                let p = &protos[c * INPUT_DIM..(c + 1) * INPUT_DIM];
                let d: f32 = row.iter().zip(p).map(|(a, b)| (a - b) * (a - b)).sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 == ds.y[i] as usize {
                correct += 1;
            }
        }
        assert!(correct > 190, "only {correct}/200 nearest-prototype correct");
    }

    #[test]
    fn batch_gathering_wraps() {
        let (shards, _) = make_federated(4, 1, 10, 10, Partition::Iid, 0.3);
        let ds = &shards[0];
        let (x, y) = ds.gather_batch(&[0, 1, 2], 8);
        assert_eq!(x.len(), 8 * INPUT_DIM);
        assert_eq!(y.len(), 8);
        assert_eq!(y[3], ds.y[0]); // wrapped
    }

    #[test]
    fn batch_plan_covers_all_samples() {
        let mut rng = Rng::new(9);
        let plan = batch_plan(&mut rng, 100, 32);
        assert_eq!(plan.len(), 4);
        let mut all: Vec<usize> = plan.concat();
        all.sort();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }
}
