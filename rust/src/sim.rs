//! Scenario harness: the exact experiment setups behind the paper's
//! evaluation figures, reproducible from one function call each.
//!
//! * [`run_fig10`] — §6.1: CO-FL's coordinator load-balancing vs plain
//!   H-FL under an aggregator whose uplink to the global aggregator gets
//!   congested from round 6 on (10 trainers, 2 aggregators).
//! * [`run_fig11`] — §6.2: Hybrid FL (fast p2p intra-cluster ring + broker
//!   upload by one delegate per cluster) vs Classical FL (everyone uploads
//!   over the broker), with one 1 Mbps straggler among 50 trainers in 5
//!   groups.
//! * [`run_scale`] — the cooperative worker fabric's headline: a
//!   10,000-trainer, 3-tier hierarchical deployment (trainers →
//!   per-group aggregators → global) that completes on a laptop. The
//!   seed's thread-per-worker execution capped out around 50 trainers;
//!   the [`crate::sched`] fabric multiplexes all 10k workers over one
//!   runner thread per CPU core.
//! * [`run_churn`] — the live-topology-extension headline (the paper's
//!   §6 extension stories, end to end): a job that *starts* 2-tier
//!   (trainers ↔ global) and *finishes* 3-tier H-FL — a middle
//!   aggregator tier deploys mid-run via a scheduled
//!   [`crate::tag::TagDelta`] — while fresh trainers join and a
//!   configurable fraction of the initial trainers churns out, with
//!   quorum-collect keeping every round's aggregation from blocking on
//!   departed workers.
//! * [`run_fleet`] — the multi-job control plane headline: hundreds of
//!   heterogeneous concurrent jobs (2-tier C-FL, 3-tier H-FL,
//!   churn-with-events, async FedBuff) admitted against bounded compute
//!   capacity and multiplexed onto **one** shared scheduler fabric by
//!   the [`crate::controlplane::JobManager`], with fair-share groups
//!   keeping big jobs from starving small ones. Per-job reports are
//!   byte-deterministic for a fixed seed (`rust/tests/fleet.rs`).
//!
//! All use the virtual-time network (the `tc` stand-in — DESIGN.md
//! substitutions) so runs are deterministic and fast, while training is
//! *real* (the configured [`Compute`]). Determinism holds **across
//! executors**: the same scenario produces bit-identical `JobReport`
//! series under cooperative and thread-per-worker execution (see
//! `rust/tests/scheduler_parity.rs`).

use std::sync::Arc;

use anyhow::Result;

use crate::channel::Backend;
use crate::control::{Controller, Executor, JobOptions, JobReport};
use crate::data::Partition;
use crate::json::Json;
use crate::net::LinkSpec;
use crate::runtime::{Compute, ComputeTimeModel, MockCompute};
use crate::store::Store;
use crate::topo;

/// Options shared by the scenario runners.
pub struct SimOptions {
    pub compute: Arc<dyn Compute>,
    pub per_shard: usize,
    pub test_n: usize,
    pub local_steps: usize,
    pub lr: f64,
    /// Fixed virtual compute cost per training step (determinism).
    pub step_cost_us: u64,
    /// Synthetic-data noise level (higher = harder task, slower curves).
    pub sigma: f32,
    pub seed: u64,
    /// Worker execution model (cooperative fabric by default).
    pub executor: Executor,
}

impl SimOptions {
    pub fn mock() -> Self {
        Self {
            compute: Arc::new(MockCompute::default_mlp()),
            per_shard: 128,
            test_n: 320,
            local_steps: 2,
            lr: 0.05,
            step_cost_us: 50_000, // 50 ms/step — edge-device scale
            sigma: 10.0,
            seed: 7,
            executor: Executor::Cooperative { runners: 0 },
        }
    }

    /// Preset for [`run_scale`]: the smallest model the mock supports
    /// (`d_pad` = the logistic head, no padding) and tiny shards, so state
    /// for 10k trainers fits in well under 2 GB — the scenario measures
    /// the *fabric* (scheduling, channels, virtual time), not the numerics.
    pub fn scale() -> Self {
        Self {
            compute: Arc::new(MockCompute::new(7_850, 8, 16)),
            per_shard: 8,
            test_n: 64,
            local_steps: 1,
            lr: 0.1,
            step_cost_us: 1_000,
            sigma: 1.0,
            seed: 7,
            executor: Executor::Cooperative { runners: 0 },
        }
    }

    fn job_options(&self) -> JobOptions {
        JobOptions::mock()
            .with_compute(self.compute.clone())
            .with_time(ComputeTimeModel::FixedPerStep(self.step_cost_us))
            .with_data(self.per_shard, self.test_n, Partition::Dirichlet(0.15), self.seed)
            .with_sigma(self.sigma)
            .with_executor(self.executor)
    }
}

// ---------------------------------------------------------------- Fig 10

/// §6.1: returns `(hfl, cofl)` job reports. Series of interest:
/// `round_time_s` (per-round wall time — the paper's Fig 10 y-axis) and
/// `active_aggregators` (the coordinator's exclusion trace).
///
/// Each topology is first run unshaped for 6 rounds to calibrate the
/// virtual time at which round 6 begins; congestion on the straggling
/// aggregator's link to the global aggregator starts there — matching the
/// paper's "from round #6" timeline.
pub fn run_fig10(rounds: u64, o: &SimOptions) -> Result<(JobReport, JobReport)> {
    let spec_for = |name: &str, r: u64| -> crate::tag::JobSpec {
        let b = match name {
            "hfl" => topo::hierarchical(10, 2, Backend::P2p),
            _ => topo::coordinated(10, 2, Backend::P2p),
        };
        b.rounds(r)
            .set("lr", Json::Num(o.lr))
            .set("local_steps", o.local_steps)
            .set("seed", o.seed)
            .build()
    };

    let run_one = |name: &'static str, r: u64, congestion_start: Option<u64>| -> Result<JobReport> {
        let mut ctl = Controller::new(Arc::new(Store::in_memory()));
        let straggler = format!("{name}-aggregator-1");
        let global = format!("{name}-global-aggregator-0");
        let mut opts = o.job_options();
        if let Some(start) = congestion_start {
            opts = opts.with_net(move |net| {
                // the link between THIS aggregator and the global aggregator
                // becomes the bottleneck from round ~6 onward (paper §6.1)
                net.set_pair_window(
                    &straggler,
                    &global,
                    LinkSpec::mbps(2.0, 200),
                    start,
                    u64::MAX,
                );
            });
        }
        ctl.submit(spec_for(name, r), opts)
    };

    let run_calibrated = |name: &'static str| -> Result<JobReport> {
        // calibration: virtual time at which round 6 starts when healthy
        let cal = run_one(name, 6, None)?;
        let end_r5 = cal.metrics.series("vtime_s").last().map(|(_, v)| *v).unwrap_or(1.0);
        let congestion_start = (end_r5 * 1e6) as u64 + 1;
        run_one(name, rounds, Some(congestion_start))
    };

    let hfl = run_calibrated("hfl")?;
    let cofl = run_calibrated("cofl")?;
    Ok((hfl, cofl))
}

// ---------------------------------------------------------------- Fig 11

/// §6.2: returns `(cfl, hybrid)` job reports. Series: `acc` vs `vtime_s`
/// (the paper's accuracy-over-wall-clock curves) and `upload_bytes`.
///
/// Setup mirrors the paper: 50 trainers, 5 co-location groups, one
/// straggler at 1 Mbps toward the aggregator/broker, 100 Mbps p2p links.
pub fn run_fig11(rounds: u64, o: &SimOptions) -> Result<(JobReport, JobReport)> {
    // The paper limits the bandwidth "between an aggregator and itself" for
    // one trainer: a WAN constraint on the trainer<->broker path. The
    // co-located p2p LAN stays at full speed, so the shaping is the pair
    // link toward the broker hub, not blanket egress.
    let shape = |net: &crate::net::VirtualNet, straggler: String| {
        // WAN-ish 100 Mbps fabric (the paper's P2P cap; the broker shares
        // it store-and-forward), 1 Mbps straggler toward the broker.
        net.set_default(LinkSpec::mbps(100.0, 1_000));
        net.set_pair(&straggler, "hub:param-channel", LinkSpec::mbps(1.0, 5_000));
    };
    // trainer 7 sits in cluster group2 and is not its delegate (the
    // lexically-first member is), matching the paper's setup where the
    // straggler is an ordinary cluster member.
    let straggler_idx = 7;

    // Classical FL: every trainer uploads over the broker channel.
    let cfl = {
        let mut ctl = Controller::new(Arc::new(Store::in_memory()));
        let spec = topo::classical(50, Backend::Broker)
            .rounds(rounds)
            .set("lr", Json::Num(o.lr))
            .set("local_steps", o.local_steps)
            .set("seed", o.seed)
            .build();
        let straggler = format!("cfl-trainer-{straggler_idx}");
        let opts = o
            .job_options()
            .with_net(move |net| shape(net, straggler));
        ctl.submit(spec, opts)?
    };

    // Hybrid FL: p2p ring per group; delegates upload over the broker.
    let hybrid = {
        let mut ctl = Controller::new(Arc::new(Store::in_memory()));
        let spec = topo::hybrid(50, 5, Backend::Broker, Backend::P2p)
            .rounds(rounds)
            .set("lr", Json::Num(o.lr))
            .set("local_steps", o.local_steps)
            .set("seed", o.seed)
            .build();
        let straggler = format!("hybrid-trainer-{straggler_idx}");
        let opts = o
            .job_options()
            .with_net(move |net| shape(net, straggler));
        ctl.submit(spec, opts)?
    };
    Ok((cfl, hybrid))
}

// ---------------------------------------------------------------- scale

/// The worker-fabric headline scenario: a 3-tier hierarchical FL job
/// (trainers → per-group aggregators → one global aggregator) at edge
/// scale. `run_scale(10_000, 100, 3, &SimOptions::scale())` deploys
/// 10,101 workers and completes in well under a minute on a 4-core
/// laptop — the seed's thread-per-worker deployment could not even spawn
/// that many workers.
pub fn run_scale(
    trainers: usize,
    groups: usize,
    rounds: u64,
    o: &SimOptions,
) -> Result<JobReport> {
    let spec = topo::hierarchical(trainers, groups, Backend::P2p)
        .name("scale")
        .rounds(rounds)
        .set("lr", Json::Num(o.lr))
        .set("local_steps", o.local_steps)
        .set("seed", o.seed)
        .build();
    let mut ctl = Controller::new(Arc::new(Store::in_memory()));
    ctl.submit(spec, o.job_options())
}

// ---------------------------------------------------------------- churn

/// Live topology extension under churn. The job starts as a 2-tier
/// classical deployment (`trainers` ↔ 1 global aggregator); one third
/// into the run a scheduled [`crate::tag::TopologyEvent::Extend`] grows a
/// middle tier of `groups` aggregators (plus ~10% fresh trainers — the
/// "join" story), and `churn_frac` of the initial trainers depart at
/// staggered virtual times over the remaining rounds. `quorum` is the
/// aggregation quorum fraction (1.0 keeps the run bit-deterministic; see
/// DESIGN.md).
///
/// Extension/departure timestamps are calibrated from a short unextended
/// run, exactly like [`run_fig10`] calibrates its congestion onset.
/// Reported series of interest beyond the usual `acc`/`round_time_s`:
/// `trainers_alive` and `aggregators_alive`, the per-round population of
/// each tier.
pub fn run_churn(
    trainers: usize,
    groups: usize,
    rounds: u64,
    churn_frac: f64,
    quorum: f64,
    o: &SimOptions,
) -> Result<JobReport> {
    anyhow::ensure!(trainers >= 4, "run_churn needs at least 4 trainers");
    anyhow::ensure!(groups >= 1, "run_churn needs at least 1 group");
    anyhow::ensure!(rounds >= 3, "run_churn needs at least 3 rounds");
    anyhow::ensure!(
        (0.0..1.0).contains(&churn_frac),
        "churn_frac must be in [0, 1)"
    );
    let base = |r: u64| {
        topo::classical(trainers, Backend::P2p)
            .name("churn")
            .rounds(r)
            .set("lr", Json::Num(o.lr))
            .set("local_steps", o.local_steps)
            .set("seed", o.seed)
            .set("quorum", Json::Num(quorum))
            .build()
    };

    // calibrate the per-round virtual duration on the unextended topology
    let cal = {
        let mut ctl = Controller::new(Arc::new(Store::in_memory()));
        ctl.submit(base(2), o.job_options())?
    };
    let round_us = ((cal.vtime_s / 2.0) * 1e6).max(1.0) as u64 + 1;

    // one third in: grow the middle tier + ~10% fresh trainers
    let spec = base(rounds);
    let extend_round = (rounds / 3).max(1);
    let extend_at = round_us * extend_round + round_us / 2;
    let join = (trainers / 10).max(1);
    let mut delta = crate::tag::delta::add_tier_delta(&spec, groups)?;
    for i in 0..join {
        delta.add_datasets.push(crate::tag::DatasetRef {
            name: format!("d{}", trainers + i),
            group: "default".into(),
            realm: "*".into(),
            url: format!("synth://join/{i}"),
        });
    }
    let mut events = vec![crate::tag::TopologyEvent::Extend {
        at_us: extend_at,
        delta,
    }];

    // churn: `churn_frac` of the initial trainers leave, spread across the
    // post-extension rounds (victims strided across the population)
    let departures = ((trainers as f64) * churn_frac).round() as usize;
    let tail_rounds = (rounds - extend_round).max(1);
    for i in 0..departures {
        let victim = format!("churn-trainer-{}", i * trainers / departures.max(1));
        let at = extend_at + round_us * (1 + i as u64 % tail_rounds);
        events.push(crate::tag::TopologyEvent::Leave {
            at_us: at,
            workers: vec![victim],
        });
    }

    let mut ctl = Controller::new(Arc::new(Store::in_memory()));
    ctl.submit(spec, o.job_options().with_events(events))
}

// ---------------------------------------------------------------- fleet

/// Build the heterogeneous fleet scenario: `jobs` submissions, cycling a
/// deterministic mix (by submission index modulo 4) of
///
/// 0. 2-tier classical FL (4 trainers, 3 rounds),
/// 1. 3-tier hierarchical FL (6 trainers / 2 groups, 2 rounds),
/// 2. churn-with-events: classical FL whose first trainer leaves at the
///    first round boundary (the live-extension machinery, per job),
/// 3. asynchronous FedBuff classical FL (3 trainers, 3 versions),
///
/// each with a per-job data/selection seed of `o.seed + index`. The
/// registry bounds capacity (two computes of 48 workers each), so a
/// large fleet genuinely exercises admission queueing: jobs wait FIFO
/// and admit as running jobs release capacity. Returns the manager with
/// everything submitted; call [`crate::controlplane::JobManager::run_fleet`]
/// to drive it.
pub fn build_fleet(jobs: usize, o: &SimOptions) -> Result<crate::controlplane::JobManager> {
    use crate::registry::{ComputeSpec, Registry};
    let mut reg = Registry::new();
    reg.register_compute(ComputeSpec::new("fab-a", "*", 48));
    reg.register_compute(ComputeSpec::new("fab-b", "*", 48));
    let mut m = crate::controlplane::JobManager::with_registry(Arc::new(Store::in_memory()), reg);
    for i in 0..jobs {
        let seed = o.seed + i as u64;
        let common = |b: crate::topo::TopoBuilder, rounds: u64| {
            b.rounds(rounds)
                .set("lr", Json::Num(o.lr))
                .set("local_steps", o.local_steps)
                .set("seed", seed)
        };
        let (spec, events) = match i % 4 {
            0 => (
                common(topo::classical(4, Backend::P2p).name("fcfl"), 3).build(),
                Vec::new(),
            ),
            1 => (
                common(topo::hierarchical(6, 2, Backend::P2p).name("fhfl"), 2).build(),
                Vec::new(),
            ),
            2 => {
                let spec = common(topo::classical(5, Backend::P2p).name("fchurn"), 3).build();
                let events = vec![crate::tag::TopologyEvent::Leave {
                    at_us: 1,
                    workers: vec!["fchurn-trainer-0".into()],
                }];
                (spec, events)
            }
            _ => (
                common(topo::classical(3, Backend::P2p).name("fasync"), 3)
                    .set("aggregation", "fedbuff")
                    .set("buffer_k", 2usize)
                    .build(),
                Vec::new(),
            ),
        };
        let mut opts = o.job_options();
        opts.data_seed = seed;
        let opts = if events.is_empty() {
            opts
        } else {
            opts.with_events(events)
        };
        m.submit(spec, opts)?;
    }
    Ok(m)
}

// --------------------------------------------------------------- resume

/// Outcome of [`run_resume`]: the kill/resume pair plus the unkilled
/// oracle, as canonical report lines for byte comparison.
pub struct ResumeRun {
    pub job: String,
    /// Flavor tag the committing worker stamped on the resumed epoch
    /// (`sync`, `async`, `ring`, ...).
    pub flavor: String,
    pub kill_at: u64,
    /// Round of the checkpoint found in the store after the kill (for
    /// async jobs: the FedBuff buffer version of the barrier).
    pub ckpt_round: u64,
    pub oracle_line: String,
    pub resumed_line: String,
}

impl ResumeRun {
    /// Resume determinism held: the resumed report is byte-identical to
    /// the unkilled run's.
    pub fn matched(&self) -> bool {
        self.oracle_line == self.resumed_line
    }
}

/// Spec for one [`run_resume`] flavor:
///
/// * `sync` — full-quorum classical FL (the original scenario),
/// * `quorum` — classical FL at quorum 0.75, so every round closes with a
///   straggler's update still in flight (the boundary drain's hard case),
/// * `async` / `fedbuff` — asynchronous FedBuff, where checkpoint
///   boundaries are buffer *versions*, not rounds,
/// * `ring` — aggregator-less distributed trainers, where the ring
///   delegate is the committing worker.
fn resume_spec(
    flavor: &str,
    trainers: usize,
    rounds: u64,
    o: &SimOptions,
) -> Result<crate::tag::JobSpec> {
    let builder = match flavor {
        "ring" => topo::distributed(trainers, Backend::P2p),
        _ => topo::classical(trainers, Backend::P2p),
    };
    let mut b = builder
        .name("rsm")
        .rounds(rounds)
        .set("lr", Json::Num(o.lr))
        .set("local_steps", o.local_steps)
        .set("seed", o.seed);
    match flavor {
        "sync" | "ring" => {}
        "quorum" => b = b.set("quorum", Json::Num(0.75)),
        "async" | "fedbuff" => b = b.set("aggregation", "fedbuff").set("buffer_k", 2usize),
        other => anyhow::bail!("unknown resume flavor '{other}' (sync|quorum|async|ring)"),
    }
    Ok(b.build())
}

/// The crash-resilience headline (`flame resume`): run a job of the given
/// `flavor` (see [`resume_spec`]) with round-boundary checkpointing and a
/// scripted controller kill at boundary `kill_at`, then resume it from
/// the journaled checkpoint under its original id — and run the same job
/// unkilled as the oracle. The two final reports must match byte for
/// byte (`rust/tests/resume.rs` sweeps every boundary and flavor; this
/// scenario is the demo-sized single kill).
pub fn run_resume(
    flavor: &str,
    trainers: usize,
    rounds: u64,
    kill_at: u64,
    runners: usize,
    o: &SimOptions,
) -> Result<ResumeRun> {
    use crate::controlplane::{checkpoint, CkptPolicy, JobManager};
    anyhow::ensure!(trainers >= 2, "run_resume needs at least 2 trainers");
    anyhow::ensure!(rounds >= 2, "run_resume needs at least 2 rounds");
    anyhow::ensure!(
        (1..rounds).contains(&kill_at),
        "kill_at must be a round boundary in 1..rounds"
    );
    let spec = || resume_spec(flavor, trainers, rounds, o);

    // oracle: same job, checkpointing armed, never killed
    let mut m = JobManager::new(Arc::new(Store::in_memory()));
    m.submit(spec()?, o.job_options().with_ckpt(CkptPolicy::every_round()))?;
    let r = m.run_fleet(runners)?;
    anyhow::ensure!(r.completed == 1, "oracle run failed: {}", r.summary());
    let oracle_line = r.jobs[0].line();

    // kill at the boundary, then resume over the same store
    let store = Arc::new(Store::in_memory());
    let mut m = JobManager::new(store.clone());
    let id = m.submit(spec()?, o.job_options().with_ckpt(CkptPolicy::kill_at(kill_at)))?;
    let r = m.run_fleet(runners)?;
    anyhow::ensure!(r.failed == 1, "injected kill did not fire: {}", r.summary());
    let ck = checkpoint::load_latest(&store, &id)?
        .ok_or_else(|| anyhow::anyhow!("no checkpoint survived the kill"))?;
    let ckpt_round = ck.round;
    let ckpt_flavor = ck.flavor.clone();
    let mut m = JobManager::new(store);
    m.resume(&id, o.job_options().with_ckpt(CkptPolicy::every_round()))?;
    let r = m.run_fleet(runners)?;
    anyhow::ensure!(r.completed == 1, "resumed run failed: {}", r.summary());
    Ok(ResumeRun {
        job: id,
        flavor: ckpt_flavor,
        kill_at,
        ckpt_round,
        oracle_line,
        resumed_line: r.jobs[0].line(),
    })
}

/// Outcome of [`run_resume_fleet`]: the restarted manager's resumable
/// listing plus oracle / resumed per-job report lines (sorted by job id)
/// for byte comparison.
pub struct ResumeFleet {
    /// `flame resume --list` view of the orphaned fleet
    /// ([`crate::controlplane::ResumableJob::line`] per job).
    pub listing: Vec<String>,
    pub resumed_ids: Vec<String>,
    pub oracle_lines: Vec<String>,
    pub resumed_lines: Vec<String>,
}

impl ResumeFleet {
    /// Fleet-wide resume determinism held: every resumed job's report is
    /// byte-identical to its oracle.
    pub fn matched(&self) -> bool {
        !self.oracle_lines.is_empty() && self.oracle_lines == self.resumed_lines
    }
}

/// Fleet-wide crash recovery (`flame resume --all`): a mixed-flavor fleet
/// — classical sync, 3-tier hierarchical, partial-quorum, async FedBuff
/// and ring jobs, cycling by submission index modulo 5 — dies wholesale
/// (every job's controller killed at its first committed boundary), a
/// fresh manager scans the journal and re-admits everything through
/// [`crate::controlplane::JobManager::resume_all`], and the drained fleet
/// must byte-match the never-killed oracle fleet job for job.
///
/// The synchronous harness journals each scripted kill as a terminal
/// failure; a real manager outage dies *with* its workers, leaving the
/// last journaled phase at `running` — so after the kill run this
/// scenario rewrites the victims' `job_state` to model the outage before
/// handing the store to the restarted manager.
pub fn run_resume_fleet(
    jobs: usize,
    runners: usize,
    o: &SimOptions,
) -> Result<ResumeFleet> {
    use crate::controlplane::{CkptPolicy, JobManager};
    anyhow::ensure!(jobs >= 1, "run_resume_fleet needs at least 1 job");
    let spec_for = |i: usize| -> (crate::tag::JobSpec, u64) {
        let seed = o.seed + i as u64;
        let common = |b: crate::topo::TopoBuilder, rounds: u64| {
            b.rounds(rounds)
                .set("lr", Json::Num(o.lr))
                .set("local_steps", o.local_steps)
                .set("seed", seed)
        };
        let spec = match i % 5 {
            0 => common(topo::classical(4, Backend::P2p).name("rfs"), 3).build(),
            1 => common(topo::hierarchical(6, 2, Backend::P2p).name("rfh"), 2).build(),
            2 => common(topo::classical(4, Backend::P2p).name("rfq"), 3)
                .set("quorum", Json::Num(0.75))
                .build(),
            3 => common(topo::classical(3, Backend::P2p).name("rfa"), 3)
                .set("aggregation", "fedbuff")
                .set("buffer_k", 2usize)
                .build(),
            _ => common(topo::distributed(3, Backend::P2p).name("rfr"), 3).build(),
        };
        (spec, seed)
    };
    let opts_for = |seed: u64| {
        let mut opts = o.job_options();
        opts.data_seed = seed;
        opts
    };
    // job ids are "{name}-{counter}" with a 1-based submission counter, so
    // the per-job seed is recoverable from the id alone — which is all the
    // restarted manager has (options are live objects, never journaled)
    let seed_of = |id: &str| -> u64 {
        id.rsplit_once('-')
            .and_then(|(_, n)| n.parse::<u64>().ok())
            .map(|c| o.seed + c.saturating_sub(1))
            .unwrap_or(o.seed)
    };
    let lines_by_id = |r: &crate::controlplane::FleetReport| -> Vec<String> {
        let mut v: Vec<(String, String)> =
            r.jobs.iter().map(|j| (j.job.clone(), j.line())).collect();
        v.sort();
        v.into_iter().map(|(_, line)| line).collect()
    };

    // oracle fleet: checkpointing armed, nothing killed
    let mut m = JobManager::new(Arc::new(Store::in_memory()));
    for i in 0..jobs {
        let (spec, seed) = spec_for(i);
        m.submit(spec, opts_for(seed).with_ckpt(CkptPolicy::every_round()))?;
    }
    let r = m.run_fleet(runners)?;
    anyhow::ensure!(r.completed == jobs, "oracle fleet failed: {}", r.summary());
    let oracle_lines = lines_by_id(&r);

    // the outage: every job's controller dies at its first committed
    // boundary (async jobs: first committed buffer version)
    let store = Arc::new(Store::in_memory());
    let mut m = JobManager::new(store.clone());
    let mut ids = Vec::with_capacity(jobs);
    for i in 0..jobs {
        let (spec, seed) = spec_for(i);
        ids.push(m.submit(spec, opts_for(seed).with_ckpt(CkptPolicy::kill_at(1)))?);
    }
    let r = m.run_fleet(runners)?;
    anyhow::ensure!(r.failed == jobs, "fleet-wide kill did not fire: {}", r.summary());
    for id in &ids {
        store.put("job_state", id, Json::from("running"))?;
    }

    // restart: list, re-admit everything, drain, compare
    let mut m = JobManager::new(store);
    let listing: Vec<String> = m.resumable()?.iter().map(|j| j.line()).collect();
    let resumed_ids =
        m.resume_all(|j| opts_for(seed_of(&j.id)).with_ckpt(CkptPolicy::every_round()))?;
    anyhow::ensure!(
        resumed_ids.len() == jobs,
        "resume_all re-admitted {} of {jobs} jobs",
        resumed_ids.len()
    );
    let r = m.run_fleet(runners)?;
    anyhow::ensure!(r.completed == jobs, "resumed fleet failed: {}", r.summary());
    Ok(ResumeFleet {
        listing,
        resumed_ids,
        oracle_lines,
        resumed_lines: lines_by_id(&r),
    })
}

/// Build and drain the fleet scenario on `runners` threads (0 = one per
/// core). Every job reaches a terminal state persisted in the manager's
/// store; the report carries per-job outcomes and fleet throughput
/// (jobs / rounds per virtual second of makespan).
pub fn run_fleet(
    jobs: usize,
    runners: usize,
    o: &SimOptions,
) -> Result<crate::controlplane::FleetReport> {
    let mut m = build_fleet(jobs, o)?;
    m.run_fleet(runners)
}

// ---------------------------------------------------------- codec sweep

/// One codec's outcome inside a [`run_codec_sweep`] report.
pub struct CodecRun {
    pub codec: &'static str,
    pub report: JobReport,
    /// Mean upload volume per round (MB) — encoded bytes, since
    /// [`crate::channel::Message`] sizes `Payload::Encoded` by its wire
    /// form and `upload_bytes` records message sizes.
    pub upload_mb_round: f64,
    /// Final accuracy minus the f32 baseline's — the convergence cost of
    /// lossy compression (0 for the baseline by construction).
    pub acc_delta: f64,
}

/// Result of [`run_codec_sweep`]: one run per codec over the same spec.
pub struct CodecSweep {
    pub rounds: u64,
    pub runs: Vec<CodecRun>,
}

impl CodecSweep {
    /// Human-readable table: accuracy, convergence delta vs f32, virtual
    /// completion time, and encoded upload volume per codec.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{:<6} {:>9} {:>9} {:>10} {:>12}\n",
            "codec", "final_acc", "d_acc", "vtime_s", "MB/round"
        );
        for r in &self.runs {
            s.push_str(&format!(
                "{:<6} {:>9.4} {:>+9.4} {:>10.3} {:>12.4}\n",
                r.codec,
                r.report.final_acc.unwrap_or(f64::NAN),
                r.acc_delta,
                r.report.vtime_s,
                r.upload_mb_round
            ));
        }
        s
    }
}

/// Communication-efficiency sweep: the same WAN-shaped classical job run
/// once per update codec — `f32` passthrough (the baseline; bit-identical
/// to running without a codec, including virtual time), `int8` linear
/// quantization (~4x upload compression), and `topk` sparsification with
/// error feedback (~`1/topk_frac`x). Uplink bytes are charged in their
/// *encoded* form, so the lossy codecs finish in strictly less virtual
/// time; the `acc_delta` column reports what that compression costs in
/// final accuracy.
pub fn run_codec_sweep(
    trainers: usize,
    rounds: u64,
    topk_frac: f64,
    o: &SimOptions,
) -> Result<CodecSweep> {
    anyhow::ensure!(trainers >= 1, "run_codec_sweep needs at least 1 trainer");
    let run_one = |codec: &'static str| -> Result<JobReport> {
        let spec = topo::classical(trainers, Backend::Broker)
            .name("codec")
            .rounds(rounds)
            .set("lr", Json::Num(o.lr))
            .set("local_steps", o.local_steps)
            .set("seed", o.seed)
            .set("codec", codec)
            .set("topk_frac", Json::Num(topk_frac))
            .build();
        // fig11-style WAN fabric: the uplink is the bottleneck the codecs
        // attack, so byte savings show up as virtual-time savings
        let opts = o
            .job_options()
            .with_net(|net| net.set_default(LinkSpec::mbps(100.0, 1_000)));
        let mut ctl = Controller::new(Arc::new(Store::in_memory()));
        ctl.submit(spec, opts)
    };
    let mut runs = Vec::new();
    let mut base_acc = 0.0;
    for codec in ["f32", "int8", "topk"] {
        let report = run_one(codec)?;
        let acc = report.final_acc.unwrap_or(0.0);
        if codec == "f32" {
            base_acc = acc;
        }
        runs.push(CodecRun {
            codec,
            upload_mb_round: upload_mb_per_round(&report, rounds),
            acc_delta: acc - base_acc,
            report,
        });
    }
    Ok(CodecSweep { rounds, runs })
}

// ---------------------------------------------------------------- trace

/// The observability scenario behind `flame trace`: a small classical FL
/// job run with virtual-time tracing enabled (`hyper.trace = "on"`) and
/// one deliberately slow uplink, so the sequencer's per-round phase
/// breakdown shows a visible `collect-wait` component and the Chrome
/// trace carries per-message `upload-xfer` spans of varying width.
/// Returns the job report; its `trace` hub renders the phase table
/// ([`crate::trace::TraceHub::phase_table`]) and the trace-event JSON
/// ([`crate::trace::TraceHub::chrome_json`]). Both are byte-deterministic
/// across runner-pool sizes and executors (`rust/tests/trace.rs`).
pub fn run_trace(trainers: usize, rounds: u64, o: &SimOptions) -> Result<JobReport> {
    anyhow::ensure!(trainers >= 2, "run_trace needs at least 2 trainers");
    let spec = topo::classical(trainers, Backend::P2p)
        .name("trace")
        .rounds(rounds)
        .set("lr", Json::Num(o.lr))
        .set("local_steps", o.local_steps)
        .set("seed", o.seed)
        .set("trace", "on")
        .build();
    let straggler = format!("trace-trainer-{}", trainers - 1);
    let opts = o.job_options().with_net(move |net| {
        net.set_default(LinkSpec::mbps(100.0, 1_000));
        net.set_pair(
            &straggler,
            "trace-global-aggregator-0",
            LinkSpec::mbps(4.0, 5_000),
        );
    });
    let mut ctl = Controller::new(Arc::new(Store::in_memory()));
    ctl.submit(spec, opts)
}

// -------------------------------------------------------------- fedprox

/// The FedProx proximal training step, written as a Role-SDK tasklet: the
/// drop-in replacement for the base trainer chain's `train` slot. Pulled
/// out of [`fedprox_trainer_program`] so the surgery site stays readable.
fn train_prox(c: &mut crate::roles::sdk::TrainerCtx) -> Result<()> {
    if !c.training_this_round() {
        return Ok(());
    }
    let tcfg = c.env.job.tcfg.clone();
    let compute = c.env.job.compute.clone();
    let mut loss_sum = 0.0;
    for _ in 0..tcfg.local_steps {
        let (batch_idx, x, y) = c.next_batch();
        let t0 = std::time::Instant::now();
        let (flat, loss) =
            compute.train_step_prox(c.model(), c.anchor(), &x, &y, tcfg.lr, tcfg.mu)?;
        c.env.charge(t0);
        c.set_model(flat);
        c.record_batch_loss(batch_idx, loss as f64);
        loss_sum += loss as f64;
    }
    c.finish_train_step(loss_sum / tcfg.local_steps as f64);
    Ok(())
}

/// The Role SDK's proof-of-extensibility: a **FedProx trainer program**
/// derived entirely through the public SDK — Table-1 surgery on the
/// exported base trainer chain ([`crate::roles::sdk::trainer_chain`]),
/// with `train` replaced by a proximal-term step anchored on the round's
/// received global model. No file under `rust/src/roles/` knows this
/// program exists; the spec binds it by name (`program:
/// "fedprox-trainer"` on the trainer role).
pub fn fedprox_trainer_program() -> crate::roles::sdk::ProgramFactory {
    use crate::roles::sdk::{chain_program, trainer_chain, Tasklet, TrainerCtx};
    Arc::new(|env, _binding| {
        let ctx = TrainerCtx::new(env)?;
        let mut chain = trainer_chain();
        chain.replace_with("train", Tasklet::new("train_prox", train_prox))?;
        Ok(chain_program(chain, ctx))
    })
}

/// FedProx end to end through the Role SDK: a classical topology whose
/// trainer role binds the custom `fedprox-trainer` program (registered
/// per job via [`JobOptions::with_program`], named in the spec's
/// `program:` field). `mu` is the proximal coefficient. For a fixed seed
/// the report is byte-deterministic across runner-pool sizes
/// (`rust/tests/roles_sdk.rs`).
pub fn run_fedprox(trainers: usize, rounds: u64, mu: f64, o: &SimOptions) -> Result<JobReport> {
    anyhow::ensure!(trainers >= 1, "run_fedprox needs at least 1 trainer");
    anyhow::ensure!(mu >= 0.0, "mu must be non-negative");
    let mut spec = topo::classical(trainers, Backend::P2p)
        .name("fedprox")
        .rounds(rounds)
        .set("lr", Json::Num(o.lr))
        .set("local_steps", o.local_steps)
        .set("seed", o.seed)
        .set("mu", Json::Num(mu))
        .build();
    // declare the binding in the spec: the trainer role names the custom
    // program; every other role keeps its default (flavor) binding
    spec.flavor = Some(crate::tag::Flavor::Sync);
    spec.roles
        .iter_mut()
        .find(|r| r.name == "trainer")
        .expect("classical topology has a trainer role")
        .program = Some("fedprox-trainer".into());
    let opts = o
        .job_options()
        .with_program("fedprox-trainer", fedprox_trainer_program());
    let mut ctl = Controller::new(Arc::new(Store::in_memory()));
    ctl.submit(spec, opts)
}

/// Virtual time (seconds) at which a job's `acc` series first reaches
/// `target`; `None` if it never does.
pub fn time_to_accuracy(report: &JobReport, target: f64) -> Option<f64> {
    let acc = report.metrics.series("acc");
    let vt = report.metrics.series("vtime_s");
    for ((round, a), (r2, t)) in acc.iter().zip(vt.iter()) {
        debug_assert_eq!(round, r2);
        if *a >= target {
            return Some(*t);
        }
    }
    None
}

/// Mean upload volume per round in MB.
pub fn upload_mb_per_round(report: &JobReport, rounds: u64) -> f64 {
    let total: f64 = report
        .metrics
        .all()
        .iter()
        .filter(|s| &*s.series == "upload_bytes")
        .map(|s| s.value)
        .sum();
    total / 1e6 / rounds as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_opts() -> SimOptions {
        let mut o = SimOptions::mock();
        o.per_shard = 32;
        o.test_n = 64;
        o.local_steps = 1;
        o
    }

    #[test]
    fn fig10_cofl_beats_hfl_after_congestion() {
        let o = small_opts();
        let (hfl, cofl) = run_fig10(16, &o).unwrap();
        let hfl_rt = hfl.metrics.series("round_time_s");
        let cofl_rt = cofl.metrics.series("round_time_s");
        assert_eq!(hfl_rt.len(), 16);
        assert_eq!(cofl_rt.len(), 16);
        // pre-congestion rounds are comparable
        let pre = |s: &[(u64, f64)]| s[..4].iter().map(|(_, v)| v).sum::<f64>() / 4.0;
        assert!(pre(&hfl_rt) < 2.0 * pre(&cofl_rt) + 0.5);
        // post-congestion: H-FL pays the straggler every round; CO-FL only
        // on probe rounds -> its mean tail round time must be much smaller
        let tail = |s: &[(u64, f64)]| s[10..].iter().map(|(_, v)| v).sum::<f64>() / 6.0;
        assert!(
            tail(&cofl_rt) < 0.5 * tail(&hfl_rt),
            "cofl tail {} vs hfl tail {}",
            tail(&cofl_rt),
            tail(&hfl_rt)
        );
        // the exclusion trace shows the aggregator being dropped
        let active = cofl.metrics.series("active_aggregators");
        assert!(active.iter().any(|(_, v)| *v < 2.0), "{active:?}");
    }

    #[test]
    fn fig11_hybrid_converges_faster_and_cheaper() {
        let mut o = small_opts();
        o.per_shard = 48;
        let rounds = 6;
        let (cfl, hybrid) = run_fig11(rounds, &o).unwrap();
        // both learn
        assert!(cfl.final_acc.unwrap() > 0.5);
        assert!(hybrid.final_acc.unwrap() > 0.5);
        // hybrid reaches the same virtual round count far sooner
        assert!(
            hybrid.vtime_s < 0.5 * cfl.vtime_s,
            "hybrid {}s vs cfl {}s",
            hybrid.vtime_s,
            cfl.vtime_s
        );
        // upload volume per round: ~10x less (5 delegates vs 50 trainers)
        let cfl_mb = upload_mb_per_round(&cfl, rounds);
        let hy_mb = upload_mb_per_round(&hybrid, rounds);
        assert!(
            hy_mb < 0.2 * cfl_mb,
            "hybrid {hy_mb} MB/round vs cfl {cfl_mb} MB/round"
        );
    }

    #[test]
    fn run_scale_midsize_completes_on_the_fabric() {
        // 300 trainers / 10 groups: far beyond what the seed's
        // thread-per-worker execution was exercised at, small enough for a
        // unit test. 311 workers total.
        let o = SimOptions::scale();
        let r = run_scale(300, 10, 2, &o).unwrap();
        assert_eq!(r.workers, 311);
        assert!(r.final_acc.is_some());
        assert_eq!(r.metrics.series("acc").len(), 2);
        assert!(r.vtime_s > 0.0);
    }

    /// The acceptance scenario: 10k trainers, 3 tiers, < 60 s wall and
    /// < 2 GB RSS on a 4-core box. Ignored by default (it is a scale
    /// benchmark, not a unit test): `cargo test -q -- --ignored` or
    /// `flame scale`.
    #[test]
    #[ignore]
    fn run_scale_10k_trainers() {
        let o = SimOptions::scale();
        let t0 = std::time::Instant::now();
        let r = run_scale(10_000, 100, 3, &o).unwrap();
        assert_eq!(r.workers, 10_101);
        assert_eq!(r.metrics.series("acc").len(), 3);
        assert!(
            t0.elapsed().as_secs() < 60,
            "10k-trainer run took {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn run_churn_grows_tier_and_survives_departures() {
        let mut o = small_opts();
        o.per_shard = 24;
        let r = run_churn(10, 2, 6, 0.2, 1.0, &o).unwrap();
        assert_eq!(r.metrics.series("acc").len(), 6);
        assert!(r.final_acc.is_some());
        // the middle tier appears mid-run...
        let aggs = r.metrics.series("aggregators_alive");
        assert_eq!(aggs.first().map(|(_, v)| *v), Some(0.0), "{aggs:?}");
        assert_eq!(aggs.last().map(|(_, v)| *v), Some(2.0), "{aggs:?}");
        // ...the population grows by the joiner, then shrinks under churn
        let t = r.metrics.series("trainers_alive");
        assert_eq!(t.first().map(|(_, v)| *v), Some(10.0), "{t:?}");
        let peak = t.iter().map(|(_, v)| *v).fold(0.0, f64::max);
        assert_eq!(peak, 11.0, "join never materialised: {t:?}");
        let last = t.last().unwrap().1;
        assert!((8.0..=10.0).contains(&last), "churn never materialised: {t:?}");
        // initial 10 + 1 joiner + 2 aggregators + 1 global = 14 pods ran
        assert_eq!(r.workers, 14);
    }

    #[test]
    fn small_fleet_mixes_all_job_kinds_and_completes() {
        let mut o = small_opts();
        o.per_shard = 16;
        o.test_n = 32;
        let report = run_fleet(8, 2, &o).unwrap();
        assert_eq!(report.jobs.len(), 8);
        assert_eq!(report.completed, 8, "{}", report.summary());
        assert_eq!(report.failed, 0);
        // the deterministic mix: two of each kind
        let count = |prefix: &str| {
            report
                .jobs
                .iter()
                .filter(|j| j.job.starts_with(prefix))
                .count()
        };
        assert_eq!(count("fcfl-"), 2);
        assert_eq!(count("fhfl-"), 2);
        assert_eq!(count("fchurn-"), 2);
        assert_eq!(count("fasync-"), 2);
        // the churn jobs really churned: 5 trainers + 1 global ran, and
        // every job made virtual progress
        for j in &report.jobs {
            assert!(j.vtime_s > 0.0, "{}", j.line());
            assert!(j.rounds > 0, "{}", j.line());
        }
        assert!(report.max_job_vs > 0.0);
        assert!(report.jobs_per_vs > 0.0);
    }

    #[test]
    fn codec_sweep_saves_virtual_time_and_reports_convergence_cost() {
        let mut o = small_opts();
        o.per_shard = 48;
        let sweep = run_codec_sweep(4, 4, 0.1, &o).unwrap();
        assert_eq!(sweep.runs.len(), 3);
        let by = |name: &str| sweep.runs.iter().find(|r| r.codec == name).unwrap();
        let (f32r, int8, topk) = (by("f32"), by("int8"), by("topk"));
        // the baseline's delta is zero by construction
        assert_eq!(f32r.acc_delta, 0.0);
        // encoded uploads are strictly smaller...
        assert!(int8.upload_mb_round < f32r.upload_mb_round, "{}", sweep.summary());
        assert!(topk.upload_mb_round < int8.upload_mb_round, "{}", sweep.summary());
        // ...and the virtual clock sees it: compressed jobs finish sooner
        assert!(int8.report.vtime_s < f32r.report.vtime_s, "{}", sweep.summary());
        assert!(topk.report.vtime_s < f32r.report.vtime_s, "{}", sweep.summary());
        // lossy compression still learns on this task
        assert!(int8.report.final_acc.unwrap() > 0.4, "{}", sweep.summary());
        assert!(topk.report.final_acc.unwrap() > 0.4, "{}", sweep.summary());
        // the summary table carries one row per codec
        assert_eq!(sweep.summary().lines().count(), 4);
    }

    #[test]
    fn run_trace_phase_rows_tile_each_round() {
        let o = small_opts();
        let r = run_trace(3, 2, &o).unwrap();
        assert!(r.trace.enabled());
        assert!(r.trace.span_count() > 0);
        let rows = r.trace.phase_rounds();
        assert_eq!(rows.len(), 2, "{rows:?}");
        // the sequencer-lane sum IS the round's virtual duration (the
        // phase.round_us series records now - round_start independently)
        let round_us = r.metrics.series("phase.round_us");
        assert_eq!(round_us.len(), 2);
        for ((round, v), (r2, row)) in round_us.iter().zip(rows.iter()) {
            assert_eq!(round, r2);
            assert_eq!(*v as u64, row.round_us(), "round {round}: {row:?}");
        }
        // the straggler's shaped uplink dominates the wait
        let row0 = rows[&0];
        assert!(row0.wait_us > 0, "{row0:?}");
        assert!(row0.train_us > 0, "{row0:?}");
        assert!(row0.xfer_us > 0, "{row0:?}");
    }

    #[test]
    fn fedprox_sdk_program_runs_and_learns() {
        let mut o = small_opts();
        o.per_shard = 48;
        let r = run_fedprox(4, 6, 0.1, &o).unwrap();
        assert_eq!(r.workers, 5);
        assert_eq!(r.metrics.series("acc").len(), 6);
        assert!(r.final_acc.unwrap() > 0.4, "{:?}", r.final_acc);
        // the proximal term really bites: a large mu pins clients to the
        // anchor, so the loss trajectory must differ from plain FedAvg
        let prox = run_fedprox(4, 3, 5.0, &o).unwrap();
        let avg = {
            let spec = topo::classical(4, Backend::P2p)
                .name("fedprox")
                .rounds(3)
                .set("lr", Json::Num(o.lr))
                .set("local_steps", o.local_steps)
                .set("seed", o.seed)
                .build();
            let mut ctl = Controller::new(Arc::new(Store::in_memory()));
            ctl.submit(spec, o.job_options()).unwrap()
        };
        assert_ne!(
            prox.metrics.series("loss"),
            avg.metrics.series("loss"),
            "mu=5.0 should change the trajectory"
        );
    }

    #[test]
    fn time_to_accuracy_helper() {
        let o = small_opts();
        let (cfl, _) = run_fig11(4, &o).unwrap();
        // target 0 is reached at the first recorded round
        let t = time_to_accuracy(&cfl, 0.0).unwrap();
        assert!(t > 0.0);
        assert!(time_to_accuracy(&cfl, 2.0).is_none());
    }
}
