//! FL algorithms from the paper's feature matrix (Table 7).
//!
//! Client-side variants (FedAvg local SGD, FedProx proximal steps, FedDyn
//! drift correction) execute through the AOT artifacts' dedicated entry
//! points; this module holds the **server-side** machinery:
//!
//! * [`ServerOpt`] — adaptive server optimizers over the aggregated
//!   pseudo-gradient (FedAvg, FedAdam, FedAdagrad, FedYogi per Reddi et al.,
//!   plus the FedDyn server state),
//! * [`FedBuff`] — buffered asynchronous aggregation (Nguyen et al.):
//!   staleness-weighted updates released every `K` arrivals,
//! * [`dp_sanitize`] — differential-privacy clipping + Gaussian noise on
//!   client deltas,
//! * [`TrainingConfig`] — parsing of the job spec's `hyper` block into one
//!   coherent algorithm configuration.

use anyhow::{bail, Result};

use crate::json::Json;
use crate::model::{axpy, l2_norm};
use crate::prng::Rng;

/// Which client-side training step a trainer runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClientAlgo {
    /// Plain local SGD (FedAvg).
    Sgd,
    /// FedProx proximal steps.
    Prox,
    /// FedDyn with per-client drift state.
    Dyn,
}

/// Server optimizer kind (applied to the aggregated pseudo-gradient).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServerOptKind {
    /// Plain replacement: global <- weighted mean of client models.
    Avg,
    FedAdam,
    FedAdagrad,
    FedYogi,
    /// FedDyn server correction state.
    FedDyn,
}

/// Stateful server optimizer. `apply` consumes the round's weighted-mean
/// client model and moves the global model.
pub struct ServerOpt {
    kind: ServerOptKind,
    eta: f32,
    beta1: f32,
    beta2: f32,
    tau: f32,
    /// FedDyn's alpha.
    alpha: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    h: Vec<f32>,
}

impl ServerOpt {
    pub fn new(kind: ServerOptKind, d: usize) -> Self {
        Self {
            kind,
            eta: 0.1,
            beta1: 0.9,
            beta2: 0.99,
            tau: 1e-3,
            alpha: 0.1,
            m: vec![0.0; d],
            v: vec![0.0; d],
            h: vec![0.0; d],
        }
    }

    pub fn with_eta(mut self, eta: f32) -> Self {
        self.eta = eta;
        self
    }

    pub fn with_alpha(mut self, alpha: f32) -> Self {
        self.alpha = alpha;
        self
    }

    pub fn kind(&self) -> ServerOptKind {
        self.kind
    }

    /// Optimizer moment state `(m, v, h)` for round-boundary checkpoints.
    /// Hyper-parameters (`eta`, betas, …) are reconstructed from the job
    /// spec on restore, so only the mutable vectors travel.
    pub fn state(&self) -> (&[f32], &[f32], &[f32]) {
        (&self.m, &self.v, &self.h)
    }

    /// Restore the moment state captured by [`ServerOpt::state`].
    pub fn restore_state(&mut self, m: Vec<f32>, v: Vec<f32>, h: Vec<f32>) {
        self.m = m;
        self.v = v;
        self.h = h;
    }

    /// One server step. `mean_model` is the weighted mean of client models;
    /// the pseudo-gradient is `delta = mean_model - global`.
    pub fn apply(&mut self, global: &mut [f32], mean_model: &[f32]) {
        debug_assert_eq!(global.len(), mean_model.len());
        match self.kind {
            ServerOptKind::Avg => {
                global.copy_from_slice(mean_model);
            }
            ServerOptKind::FedAdam | ServerOptKind::FedAdagrad | ServerOptKind::FedYogi => {
                let (b1, b2) = (self.beta1, self.beta2);
                for i in 0..global.len() {
                    let d = mean_model[i] - global[i];
                    self.m[i] = b1 * self.m[i] + (1.0 - b1) * d;
                    let d2 = d * d;
                    self.v[i] = match self.kind {
                        ServerOptKind::FedAdam => b2 * self.v[i] + (1.0 - b2) * d2,
                        ServerOptKind::FedAdagrad => self.v[i] + d2,
                        ServerOptKind::FedYogi => {
                            let s = if d2 > self.v[i] { 1.0 } else { -1.0 };
                            self.v[i] + (1.0 - b2) * d2 * s
                        }
                        _ => unreachable!(),
                    };
                    global[i] += self.eta * self.m[i] / (self.v[i].max(0.0).sqrt() + self.tau);
                }
            }
            ServerOptKind::FedDyn => {
                // h <- h - alpha * delta;  global <- mean - h / alpha
                for i in 0..global.len() {
                    let d = mean_model[i] - global[i];
                    self.h[i] -= self.alpha * d;
                    global[i] = mean_model[i] - self.h[i] / self.alpha.max(1e-8);
                }
            }
        }
    }
}

/// Buffered asynchronous aggregation (FedBuff). The global aggregator calls
/// [`FedBuff::push`] per client arrival; every `k` arrivals it returns the
/// staleness-weighted mean delta to apply.
///
/// The fold is **streaming**: each delta is weighted into one O(d)
/// accumulator at push time and its buffer is free for the caller to
/// recycle immediately — the old collect-then-drain kept `k` cloned
/// vectors alive per release. The staleness weight is known at push
/// (version only advances on release), and the drain folded in push order
/// too, so the streaming fold is bit-identical to the buffered one.
pub struct FedBuff {
    k: usize,
    /// Server learning rate for the buffered delta.
    pub eta: f32,
    /// Running weighted sum of the current window's deltas.
    acc: Vec<f32>,
    /// Total staleness weight folded into `acc`.
    wsum: f32,
    /// Deltas folded since the last release.
    pending: usize,
    version: u64,
}

impl FedBuff {
    pub fn new(k: usize, eta: f32) -> Self {
        assert!(k >= 1);
        Self {
            k,
            eta,
            acc: Vec::new(),
            wsum: 0.0,
            pending: 0,
            version: 0,
        }
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn buffered(&self) -> usize {
        self.pending
    }

    /// Pending-fold window state `(acc, wsum, pending, version)` for
    /// round-boundary checkpoints.
    pub fn state(&self) -> (&[f32], f32, usize, u64) {
        (&self.acc, self.wsum, self.pending, self.version)
    }

    /// Restore the window captured by [`FedBuff::state`] (mid-window
    /// resume: partially folded deltas keep their weights).
    pub fn restore_state(&mut self, acc: Vec<f32>, wsum: f32, pending: usize, version: u64) {
        self.acc = acc;
        self.wsum = wsum;
        self.pending = pending;
        self.version = version;
    }

    /// Staleness weight `1/sqrt(1+s)` (the FedBuff paper's default).
    pub fn staleness_weight(staleness: u64) -> f32 {
        1.0 / ((1.0 + staleness as f32).sqrt())
    }

    /// Fold one client delta computed against `base_version` into the
    /// window accumulator. Returns the aggregate to apply (and bumps the
    /// model version) on every `k`-th delta.
    pub fn push(&mut self, delta: &[f32], base_version: u64) -> Option<Vec<f32>> {
        let staleness = self.version.saturating_sub(base_version);
        let w = Self::staleness_weight(staleness);
        if self.acc.is_empty() {
            self.acc.resize(delta.len(), 0.0);
        }
        axpy(&mut self.acc, w, delta);
        self.wsum += w;
        self.pending += 1;
        if self.pending < self.k {
            return None;
        }
        let mut out = std::mem::take(&mut self.acc);
        crate::model::scale(&mut out, self.eta / self.wsum.max(1e-8));
        self.wsum = 0.0;
        self.pending = 0;
        self.version += 1;
        Some(out)
    }
}

/// Differential privacy: L2-clip the delta to `clip`, then add
/// `N(0, (sigma*clip)^2)` noise per coordinate (Gaussian mechanism).
pub fn dp_sanitize(delta: &mut [f32], clip: f32, sigma: f32, rng: &mut Rng) {
    let norm = l2_norm(delta) as f32;
    if norm > clip && norm > 0.0 {
        crate::model::scale(delta, clip / norm);
    }
    if sigma > 0.0 {
        let std = (sigma * clip) as f64;
        for v in delta.iter_mut() {
            *v += rng.normal_with(0.0, std) as f32;
        }
    }
}

/// Aggregation policy at the global aggregator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggregationPolicy {
    /// Barrier each round over all selected clients.
    Synchronous,
    /// FedBuff-style buffered async.
    Asynchronous { buffer_k: usize },
}

/// Full algorithm configuration parsed from the job spec's `hyper` block.
#[derive(Debug, Clone)]
pub struct TrainingConfig {
    pub client: ClientAlgo,
    pub server: ServerOptKind,
    pub aggregation: AggregationPolicy,
    pub lr: f32,
    pub local_steps: usize,
    /// FedProx mu.
    pub mu: f32,
    /// FedDyn alpha.
    pub alpha: f32,
    /// Server optimizer eta.
    pub eta: f32,
    /// DP: clip bound (0 = off) and noise multiplier.
    pub dp_clip: f32,
    pub dp_sigma: f32,
    /// Client selection: name + fraction (see `select`).
    pub selection: String,
    pub select_frac: f64,
    /// FedBalancer-style sample selection on/off.
    pub fedbalancer: bool,
    /// Aggregation quorum fraction in `(0, 1]`: a collect proceeds once
    /// `ceil(quorum * alive_children)` updates for the current round have
    /// arrived, against *current* channel membership. 1.0 (default) is the
    /// classic full barrier; fractions tolerate stragglers and churn.
    pub quorum: f64,
    /// Upload codec (`f32` passthrough, `int8` quantization, `topk`
    /// sparsification with error feedback); `None` sends raw floats.
    pub codec: Option<String>,
    /// Kept-coordinate fraction for the `topk` codec, in `(0, 1]`.
    pub topk_frac: f64,
    /// SIMD fold policy: `off` (default), `auto`, `scalar`, `portable`,
    /// `avx2` — see `runtime::simd::kernel_from_policy`.
    pub simd: String,
    /// Virtual-time tracing: `off` (default) or `on`. When on, the job
    /// carries an enabled [`crate::trace::TraceHub`] recording round-phase
    /// spans, transfer spans and scheduler stats; `FLAME_TRACE` overrides
    /// per process.
    pub trace: String,
    pub seed: u64,
}

impl Default for TrainingConfig {
    fn default() -> Self {
        Self {
            client: ClientAlgo::Sgd,
            server: ServerOptKind::Avg,
            aggregation: AggregationPolicy::Synchronous,
            lr: 0.1,
            local_steps: 4,
            mu: 0.01,
            alpha: 0.1,
            eta: 0.1,
            dp_clip: 0.0,
            dp_sigma: 0.0,
            selection: "all".into(),
            select_frac: 1.0,
            fedbalancer: false,
            quorum: 1.0,
            codec: None,
            topk_frac: 0.05,
            simd: "off".into(),
            trace: "off".into(),
            seed: 0,
        }
    }
}

impl TrainingConfig {
    /// Parse the job spec's `hyper` object; missing keys take defaults.
    pub fn from_hyper(hyper: &Json) -> Result<Self> {
        let mut cfg = Self::default();
        if hyper.is_null() {
            return Ok(cfg);
        }
        if let Some(a) = hyper.get("algorithm").as_str() {
            cfg.client = match a {
                "fedavg" | "sgd" => ClientAlgo::Sgd,
                "fedprox" => ClientAlgo::Prox,
                "feddyn" => ClientAlgo::Dyn,
                other => bail!("unknown client algorithm '{other}'"),
            };
        }
        if let Some(s) = hyper.get("server_opt").as_str() {
            cfg.server = match s {
                "avg" | "none" => ServerOptKind::Avg,
                "fedadam" | "adam" => ServerOptKind::FedAdam,
                "fedadagrad" | "adagrad" => ServerOptKind::FedAdagrad,
                "fedyogi" | "yogi" => ServerOptKind::FedYogi,
                "feddyn" => ServerOptKind::FedDyn,
                other => bail!("unknown server optimizer '{other}'"),
            };
        }
        if let Some(a) = hyper.get("aggregation").as_str() {
            cfg.aggregation = match a {
                "sync" => AggregationPolicy::Synchronous,
                "fedbuff" | "async" => AggregationPolicy::Asynchronous {
                    buffer_k: hyper.get("buffer_k").as_usize().unwrap_or(3),
                },
                other => bail!("unknown aggregation policy '{other}'"),
            };
        }
        if let Some(v) = hyper.get("lr").as_f64() {
            cfg.lr = v as f32;
        }
        if let Some(v) = hyper.get("local_steps").as_usize() {
            cfg.local_steps = v.max(1);
        }
        if let Some(v) = hyper.get("mu").as_f64() {
            cfg.mu = v as f32;
        }
        if let Some(v) = hyper.get("alpha").as_f64() {
            cfg.alpha = v as f32;
        }
        if let Some(v) = hyper.get("eta").as_f64() {
            cfg.eta = v as f32;
        }
        if let Some(v) = hyper.get("dp_clip").as_f64() {
            cfg.dp_clip = v as f32;
        }
        if let Some(v) = hyper.get("dp_sigma").as_f64() {
            cfg.dp_sigma = v as f32;
        }
        if let Some(s) = hyper.get("selection").as_str() {
            cfg.selection = s.to_string();
        }
        if let Some(v) = hyper.get("select_frac").as_f64() {
            cfg.select_frac = v.clamp(0.0, 1.0);
        }
        if let Some(b) = hyper.get("fedbalancer").as_bool() {
            cfg.fedbalancer = b;
        }
        if let Some(v) = hyper.get("quorum").as_f64() {
            if !(v > 0.0 && v <= 1.0) {
                bail!("quorum must be in (0, 1], got {v}");
            }
            cfg.quorum = v;
        }
        if let Some(s) = hyper.get("codec").as_str() {
            match s {
                "none" | "" => cfg.codec = None,
                "f32" | "int8" | "topk" => cfg.codec = Some(s.to_string()),
                other => bail!("unknown codec '{other}' (expected f32 | int8 | topk)"),
            }
        }
        if let Some(v) = hyper.get("topk_frac").as_f64() {
            if !(v > 0.0 && v <= 1.0) {
                bail!("topk_frac must be in (0, 1], got {v}");
            }
            cfg.topk_frac = v;
        }
        if let Some(s) = hyper.get("simd").as_str() {
            match s {
                "off" | "auto" | "scalar" | "portable" | "avx2" => cfg.simd = s.to_string(),
                other => bail!(
                    "unknown simd policy '{other}' (expected off | auto | scalar | portable | avx2)"
                ),
            }
        }
        if let Some(s) = hyper.get("trace").as_str() {
            match s {
                "off" | "on" => cfg.trace = s.to_string(),
                other => bail!("unknown trace setting '{other}' (expected off | on)"),
            }
        }
        if let Some(v) = hyper.get("seed").as_i64() {
            cfg.seed = v as u64;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn avg_replaces_global() {
        let mut opt = ServerOpt::new(ServerOptKind::Avg, 4);
        let mut g = vec![0.0; 4];
        opt.apply(&mut g, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(g, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn adaptive_opts_move_toward_mean() {
        for kind in [
            ServerOptKind::FedAdam,
            ServerOptKind::FedAdagrad,
            ServerOptKind::FedYogi,
        ] {
            let mut opt = ServerOpt::new(kind, 3).with_eta(0.1);
            let mut g = vec![0.0f32; 3];
            let target = [1.0f32, -1.0, 0.5];
            for _ in 0..200 {
                opt.apply(&mut g, &target);
            }
            for (gi, ti) in g.iter().zip(&target) {
                assert!(
                    (gi - ti).abs() < 0.3,
                    "{kind:?} did not converge: {g:?} vs {target:?}"
                );
            }
        }
    }

    #[test]
    fn adam_step_bounded_by_eta_scale() {
        // First step magnitude ~ eta * (1-b1)*d / (sqrt((1-b2) d^2) + tau)
        let mut opt = ServerOpt::new(ServerOptKind::FedAdam, 1).with_eta(1.0);
        let mut g = vec![0.0f32];
        opt.apply(&mut g, &[100.0]);
        assert!(g[0] > 0.0 && g[0] < 100.0, "step {g:?} not damped");
    }

    #[test]
    fn feddyn_server_tracks_mean_when_stationary() {
        let mut opt = ServerOpt::new(ServerOptKind::FedDyn, 2).with_alpha(0.1);
        let mut g = vec![0.0f32, 0.0];
        for _ in 0..50 {
            let mean = g.clone(); // clients agree with global: delta = 0
            opt.apply(&mut g, &mean);
        }
        assert!(g.iter().all(|v| v.abs() < 1e-4), "{g:?}");
    }

    #[test]
    fn fedbuff_releases_every_k() {
        let mut fb = FedBuff::new(3, 1.0);
        assert!(fb.push(&[1.0, 0.0], 0).is_none());
        assert!(fb.push(&[0.0, 1.0], 0).is_none());
        let agg = fb.push(&[1.0, 1.0], 0).unwrap();
        // all staleness 0 -> plain mean
        assert!((agg[0] - 2.0 / 3.0).abs() < 1e-6);
        assert!((agg[1] - 2.0 / 3.0).abs() < 1e-6);
        assert_eq!(fb.version(), 1);
        assert_eq!(fb.buffered(), 0);
    }

    #[test]
    fn fedbuff_downweights_stale_updates() {
        let mut fb = FedBuff::new(2, 1.0);
        fb.push(&[0.0], 0);
        fb.push(&[0.0], 0); // version -> 1
        fb.push(&[1.0], 1); // fresh
        let agg = fb.push(&[1.0], 0).unwrap(); // staleness 1
        let w_fresh = FedBuff::staleness_weight(0);
        let w_stale = FedBuff::staleness_weight(1);
        let want = (w_fresh * 1.0 + w_stale * 1.0) / (w_fresh + w_stale);
        assert!((agg[0] - want).abs() < 1e-6);
        assert!(w_stale < w_fresh);
    }

    #[test]
    fn fedbuff_streaming_fold_matches_buffered_drain() {
        // oracle: the pre-streaming implementation (collect k, then drain
        // in push order) — the in-place fold must reproduce it bit for bit
        let deltas: Vec<(Vec<f32>, u64)> = (0..6)
            .map(|i| {
                let mut rng = Rng::new(40 + i);
                ((0..33).map(|_| rng.normal() as f32).collect(), i % 3)
            })
            .collect();
        let mut fb = FedBuff::new(3, 0.7);
        let mut got = Vec::new();
        for (d, base) in &deltas {
            if let Some(a) = fb.push(d, *base) {
                got.push(a);
            }
        }
        // buffered oracle
        let mut want = Vec::new();
        let mut version = 0u64;
        let mut window: Vec<(Vec<f32>, u64)> = Vec::new();
        for (d, base) in &deltas {
            window.push((d.clone(), version.saturating_sub(*base)));
            if window.len() == 3 {
                let mut out = vec![0f32; d.len()];
                let mut wsum = 0f32;
                for (delta, s) in window.drain(..) {
                    let w = FedBuff::staleness_weight(s);
                    axpy(&mut out, w, &delta);
                    wsum += w;
                }
                crate::model::scale(&mut out, 0.7 / wsum.max(1e-8));
                version += 1;
                want.push(out);
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn dp_clips_and_noises() {
        let mut rng = Rng::new(0);
        let mut d = vec![3.0f32, 4.0]; // norm 5
        dp_sanitize(&mut d, 1.0, 0.0, &mut rng);
        assert!((l2_norm(&d) - 1.0).abs() < 1e-6);
        // below clip: untouched without noise
        let mut d = vec![0.3f32, 0.4];
        dp_sanitize(&mut d, 1.0, 0.0, &mut rng);
        assert_eq!(d, vec![0.3, 0.4]);
        // noise actually perturbs
        let mut a = vec![0.0f32; 100];
        dp_sanitize(&mut a, 1.0, 0.5, &mut rng);
        assert!(l2_norm(&a) > 0.0);
    }

    #[test]
    fn parses_hyper_block() {
        let hyper = Json::parse(
            r#"{
            "algorithm": "fedprox", "server_opt": "yogi",
            "aggregation": "fedbuff", "buffer_k": 5,
            "lr": 0.05, "local_steps": 8, "mu": 0.1,
            "dp_clip": 1.0, "dp_sigma": 0.01,
            "selection": "oort", "select_frac": 0.5, "seed": 42
        }"#,
        )
        .unwrap();
        let cfg = TrainingConfig::from_hyper(&hyper).unwrap();
        assert_eq!(cfg.client, ClientAlgo::Prox);
        assert_eq!(cfg.server, ServerOptKind::FedYogi);
        assert_eq!(
            cfg.aggregation,
            AggregationPolicy::Asynchronous { buffer_k: 5 }
        );
        assert_eq!(cfg.local_steps, 8);
        assert_eq!(cfg.selection, "oort");
        assert_eq!(cfg.seed, 42);
    }

    #[test]
    fn defaults_on_null_hyper() {
        let cfg = TrainingConfig::from_hyper(&Json::Null).unwrap();
        assert_eq!(cfg.client, ClientAlgo::Sgd);
        assert_eq!(cfg.server, ServerOptKind::Avg);
        assert_eq!(cfg.aggregation, AggregationPolicy::Synchronous);
    }

    #[test]
    fn rejects_unknown_names() {
        for bad in [
            r#"{"algorithm": "alchemy"}"#,
            r#"{"server_opt": "sgdm"}"#,
            r#"{"aggregation": "psychic"}"#,
        ] {
            assert!(TrainingConfig::from_hyper(&Json::parse(bad).unwrap()).is_err());
        }
    }

    #[test]
    fn codec_and_simd_parse_and_validate() {
        let cfg = TrainingConfig::from_hyper(
            &Json::parse(r#"{"codec": "topk", "topk_frac": 0.02, "simd": "auto"}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.codec.as_deref(), Some("topk"));
        assert_eq!(cfg.topk_frac, 0.02);
        assert_eq!(cfg.simd, "auto");
        let d = TrainingConfig::default();
        assert_eq!(d.codec, None);
        assert_eq!(d.simd, "off");
        let off = TrainingConfig::from_hyper(&Json::parse(r#"{"codec": "none"}"#).unwrap());
        assert_eq!(off.unwrap().codec, None);
        let traced =
            TrainingConfig::from_hyper(&Json::parse(r#"{"trace": "on"}"#).unwrap()).unwrap();
        assert_eq!(traced.trace, "on");
        assert_eq!(d.trace, "off");
        for bad in [
            r#"{"codec": "gzip"}"#,
            r#"{"topk_frac": 0.0}"#,
            r#"{"topk_frac": 2}"#,
            r#"{"simd": "gpu"}"#,
            r#"{"trace": "verbose"}"#,
        ] {
            assert!(TrainingConfig::from_hyper(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn quorum_parses_and_validates() {
        let cfg =
            TrainingConfig::from_hyper(&Json::parse(r#"{"quorum": 0.75}"#).unwrap()).unwrap();
        assert_eq!(cfg.quorum, 0.75);
        assert_eq!(TrainingConfig::default().quorum, 1.0);
        for bad in [r#"{"quorum": 0.0}"#, r#"{"quorum": 1.5}"#, r#"{"quorum": -1}"#] {
            assert!(TrainingConfig::from_hyper(&Json::parse(bad).unwrap()).is_err());
        }
    }
}
