//! Global symbol interner — the fabric's answer to per-message string tax.
//!
//! Every name the channel fabric routes by (worker, channel, group, scope,
//! message kind) is interned once into an `Arc<str>` **atom**; after the
//! first sighting, handing the name around is a pointer clone, map lookups
//! hash a `&str` borrow, and equality checks compare short strings that are
//! usually pointer-equal. Channel identity — the `(scope, channel, group)`
//! triple the old `ChannelManager::key` built as three fresh `String`s per
//! call — packs into a single [`Route`]: each component resolves to a
//! `u32` [`Symbol`] and the three symbols pack into one `u64`, so the
//! membership shard map is keyed by a machine word instead of a
//! heap-allocated tuple.
//!
//! The interner is process-global and append-only. That is deliberate:
//! names are tiny, bounded by the deployment's vocabulary (worker ids,
//! channel names, the closed set of message kinds), and a stable global id
//! space means scoped views of one shared fabric agree on symbols without
//! coordination. Nothing orders by symbol id — all user-visible ordering
//! stays lexicographic on the underlying strings — so interning order
//! (test interleaving, job admission order) can never leak into results.

use std::collections::HashMap;
use std::sync::{Arc, OnceLock, RwLock};

/// Interned name id. Dense, starting at 0, never recycled.
pub type Symbol = u32;

/// Bits per route component. 2^21 ≈ 2M distinct names — two orders of
/// magnitude above the 10k-worker design point; exceeding it makes
/// [`Route::pack`] return `None`, which the channel layer surfaces as a
/// clean join error (a long-lived control plane rejects the job instead
/// of aborting).
const SYM_BITS: u32 = 21;
const SYM_MASK: u64 = (1 << SYM_BITS) - 1;

/// A channel's packed identity: `(scope, channel, group)` in one `u64`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Route(u64);

impl Route {
    /// Pack three symbols into one route word. `None` when any component
    /// is past the 21-bit budget — callers (channel `join`) surface that
    /// as a clean error instead of aborting a worker thread.
    pub fn pack(scope: Symbol, channel: Symbol, group: Symbol) -> Option<Self> {
        if [scope, channel, group].iter().any(|&s| (s as u64) > SYM_MASK) {
            return None;
        }
        Some(Route(
            ((scope as u64) << (2 * SYM_BITS)) | ((channel as u64) << SYM_BITS) | group as u64,
        ))
    }

    pub fn scope_sym(&self) -> Symbol {
        ((self.0 >> (2 * SYM_BITS)) & SYM_MASK) as Symbol
    }

    pub fn channel_sym(&self) -> Symbol {
        ((self.0 >> SYM_BITS) & SYM_MASK) as Symbol
    }

    pub fn group_sym(&self) -> Symbol {
        (self.0 & SYM_MASK) as Symbol
    }

    /// The packed word itself — the wire key of a framed message. Only
    /// meaningful to a process that replayed the same name table
    /// ([`apply_names`]); everyone else must treat it as opaque.
    pub fn raw(&self) -> u64 {
        self.0
    }

    /// Rebuild a route from a wire key. No validation happens here — a
    /// key from a process with a diverged name table simply fails the
    /// receiver's membership lookup.
    pub fn from_raw(raw: u64) -> Self {
        Route(raw)
    }

    /// A well-mixed hash of the packed word (the raw packing is too
    /// structured for direct modulo sharding: common groups share low
    /// bits).
    pub fn mix(&self) -> u64 {
        // splitmix64 finalizer
        let mut z = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

struct Interner {
    map: HashMap<Arc<str>, Symbol>,
    names: Vec<Arc<str>>,
}

fn table() -> &'static RwLock<Interner> {
    static TABLE: OnceLock<RwLock<Interner>> = OnceLock::new();
    TABLE.get_or_init(|| {
        RwLock::new(Interner {
            map: HashMap::new(),
            names: Vec::new(),
        })
    })
}

/// Intern `s`, returning its dense id. Read-locked fast path; the write
/// lock is only taken the first time a name is seen.
pub fn sym(s: &str) -> Symbol {
    if let Some(&id) = table().read().unwrap().map.get(s) {
        return id;
    }
    let mut g = table().write().unwrap();
    if let Some(&id) = g.map.get(s) {
        return id;
    }
    let atom: Arc<str> = Arc::from(s);
    let id = g.names.len() as Symbol;
    g.names.push(atom.clone());
    g.map.insert(atom, id);
    id
}

/// Intern `s`, returning the shared atom. After the first call for a given
/// name this allocates nothing: the stored `Arc<str>` is cloned.
pub fn atom(s: &str) -> Arc<str> {
    if let Some((k, _)) = table().read().unwrap().map.get_key_value(s) {
        return k.clone();
    }
    let mut g = table().write().unwrap();
    if let Some((k, _)) = g.map.get_key_value(s) {
        return k.clone();
    }
    let atom: Arc<str> = Arc::from(s);
    let id = g.names.len() as Symbol;
    g.names.push(atom.clone());
    g.map.insert(atom.clone(), id);
    atom
}

/// The name behind a symbol (diagnostics; panics on a foreign id).
pub fn name(id: Symbol) -> Arc<str> {
    table().read().unwrap().names[id as usize].clone()
}

/// Pack a `(scope, channel, group)` channel identity into a [`Route`];
/// `None` once the symbol space is exhausted (> 2^21 distinct names).
pub fn route(scope: &str, channel: &str, group: &str) -> Option<Route> {
    Route::pack(sym(scope), sym(channel), sym(group))
}

/// The full name table in symbol order — the cross-process interning
/// handshake payload. A multi-process deployment ships this to every
/// joining worker process, which replays it via [`apply_names`] before
/// interning anything else, so a packed `u64` [`Route`] means the same
/// `(scope, channel, group)` triple on every process.
pub fn export_names() -> Vec<String> {
    table().read().unwrap().names.iter().map(|n| n.to_string()).collect()
}

/// Replay a peer's exported name table ([`export_names`]) into this
/// process's interner. Must run before this process interns any name of
/// its own: each replayed name must land on the symbol equal to its
/// position, otherwise the two processes' route words have already
/// diverged and the join is rejected.
pub fn apply_names(names: &[String]) -> anyhow::Result<()> {
    for (i, n) in names.iter().enumerate() {
        let got = sym(n);
        if got as usize != i {
            anyhow::bail!(
                "interning handshake diverged: '{n}' resolved to symbol {got}, \
                 expected {i} (this process interned names before the handshake)"
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sym_is_stable_and_dense() {
        let a = sym("intern-test-alpha");
        let b = sym("intern-test-beta");
        assert_ne!(a, b);
        assert_eq!(a, sym("intern-test-alpha"));
        assert_eq!(b, sym("intern-test-beta"));
    }

    #[test]
    fn atom_returns_the_shared_allocation() {
        let a1 = atom("intern-test-atom");
        let a2 = atom("intern-test-atom");
        assert!(Arc::ptr_eq(&a1, &a2), "atoms must share one allocation");
        assert_eq!(&*a1, "intern-test-atom");
        assert_eq!(&*name(sym("intern-test-atom")), "intern-test-atom");
    }

    #[test]
    fn route_roundtrips_components() {
        let r = route("intern-scope", "intern-chan", "intern-group").unwrap();
        assert_eq!(r.scope_sym(), sym("intern-scope"));
        assert_eq!(r.channel_sym(), sym("intern-chan"));
        assert_eq!(r.group_sym(), sym("intern-group"));
        // identical triple -> identical route; any differing component
        // changes it
        assert_eq!(r, route("intern-scope", "intern-chan", "intern-group").unwrap());
        assert_ne!(r, route("intern-scope", "intern-chan", "intern-group2").unwrap());
        assert_ne!(r, route("", "intern-chan", "intern-group").unwrap());
    }

    #[test]
    fn separators_cannot_alias_routes() {
        // structured packing, not string joining: a name containing the
        // old separator cannot collide with a scoped triple
        let a = route("a", "b::c", "g").unwrap();
        let b = route("a::b", "c", "g").unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn mix_spreads_structured_routes() {
        // many channels sharing one group must not collapse onto a few
        // shards under the mixed hash
        let mut shards = std::collections::HashSet::new();
        for i in 0..64 {
            let r = route("", &format!("intern-mix-{i}"), "default").unwrap();
            shards.insert((r.mix() % 64) as u8);
        }
        assert!(shards.len() > 16, "only {} shards hit", shards.len());
    }

    #[test]
    fn export_apply_replays_to_identical_symbols() {
        // replaying a table this process already agrees with is the
        // fixed-point case: every name lands on its own index
        sym("intern-export-probe");
        let names = export_names();
        assert!(names.iter().any(|n| n == "intern-export-probe"));
        apply_names(&names).unwrap();
        assert_eq!(names, export_names(), "replay must not grow the table");
    }

    #[test]
    fn concurrent_interning_agrees() {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                std::thread::spawn(|| {
                    (0..100)
                        .map(|i| sym(&format!("intern-race-{i}")))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        let results: Vec<Vec<Symbol>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for w in results.windows(2) {
            assert_eq!(w[0], w[1]);
        }
    }
}
