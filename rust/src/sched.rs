//! Discrete-event, virtual-time cooperative scheduler — the worker fabric.
//!
//! The seed deployed one OS thread per expanded worker and blocked each on
//! a `Condvar` mailbox, which caps every topology at the OS-thread limit
//! (~50 trainers in practice). This module replaces that with the
//! timely-dataflow idiom: a *small* set of runner threads drives *many*
//! logical workers cooperatively. A worker runs until its next blocking
//! receive; if the mail is not there yet, the receive registers a wait
//! condition on the mailbox and yields a [`Pending`] signal back through
//! the tasklet chain. The scheduler parks the worker and resumes it — in
//! **virtual-arrival order** — once a matching message is delivered.
//!
//! Pieces:
//!
//! * [`Pending`] — the yield signal. It travels as an `anyhow` error so
//!   role tasklets need no new plumbing; the chain executor
//!   ([`crate::workflow::Composer`]) recognises it and suspends the chain
//!   at the yielding tasklet (tasklets are re-entrant up to their first
//!   blocking receive — see the workflow docs).
//! * [`WorkerPark`] — per-worker execution mode shared by all of the
//!   worker's channel handles: `blocking` (legacy Condvar waits, used by
//!   direct channel tests and the thread-per-worker deployer) or
//!   `cooperative` (yield to the scheduler).
//! * [`Waker`] — handed to mailboxes; delivery calls `wake(arrival)` when
//!   the parked worker's wait condition is satisfied.
//! * [`Scheduler`] — per-group ready heaps (each ordered by
//!   `(virtual time, task id)`) plus an M:N pool of runner threads
//!   ([`Scheduler::run`]). When no task is ready and none is running but
//!   live tasks remain, the fabric has a *virtual-time deadlock*; the
//!   scheduler fails the stuck workers immediately instead of burning a
//!   wall-clock timeout.
//!
//! ## Fair-share groups
//!
//! Tasks belong to a **share group** (default group 0; the multi-job
//! control plane puts each job in its own group via
//! [`Scheduler::spawn_in`] / [`Scheduler::spawn_parked_in`]). Runners pick
//! the next task by `(head virtual time, group pass, group id)`: the
//! earliest virtual time always wins — virtual-time semantics are
//! untouched — but among groups whose heads are *tied* on virtual time,
//! the group that has been polled least (lowest `pass` count) goes first.
//! That is a stride scheduler with equal weights: a 10,000-task job and a
//! 5-task job tied at the same virtual instant alternate polls instead of
//! the big job draining first, so small jobs cannot be starved by large
//! ones. Fairness only reorders polls, never results: message selection
//! stays deterministic by `(arrival, sender, seq)` regardless of poll
//! order (see [`crate::channel`]).
//!
//! Deadlock detection assumes every message producer for cooperative
//! workers is itself a task on this scheduler. A job that mixes
//! cooperative workers with workers on external threads (a custom
//! orchestrator) could trip the detector while an external producer is
//! still about to send; such mixed deployments should run the sim side
//! with `Executor::ThreadPerWorker`.
//!
//! The scheduler knows nothing about channels or roles: it drives
//! [`RunnableTask`] objects. The worker-side task lives in
//! [`crate::agent::WorkerTask`]; mail delivery lives in
//! [`crate::channel::ChannelManager`].

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Duration;

use crate::net::VTime;

// ------------------------------------------------------------ runtime stats

/// Always-on scheduler runtime counters (relaxed atomics — a handful of
/// uncontended increments per poll, noise next to a tasklet step). The
/// trace layer samples them at round boundaries into `sched.*` metrics
/// series; they are *runtime* stats (executor- and pool-size-dependent),
/// so they never enter the deterministic trace output itself.
#[derive(Debug, Default)]
pub struct SchedStats {
    /// Tasks ever registered.
    pub spawns: AtomicU64,
    /// Task polls executed by runners.
    pub polls: AtomicU64,
    /// Polls that ended in a cooperative park.
    pub parks: AtomicU64,
    /// Wakes that moved a Waiting task to Ready.
    pub wakes: AtomicU64,
    /// Current ready-queue depth across all groups.
    ready_now: AtomicU64,
    /// High-water mark of the ready-queue depth.
    pub ready_peak: AtomicU64,
    /// High-water mark of concurrently running tasks (runner occupancy).
    pub running_peak: AtomicU64,
}

impl SchedStats {
    fn on_push_ready(&self) {
        let now = self.ready_now.fetch_add(1, Ordering::Relaxed) + 1;
        self.ready_peak.fetch_max(now, Ordering::Relaxed);
    }

    fn on_pop_ready(&self) {
        self.ready_now.fetch_sub(1, Ordering::Relaxed);
    }

    /// Current ready-queue depth.
    pub fn ready_depth(&self) -> u64 {
        self.ready_now.load(Ordering::Relaxed)
    }

    /// The cumulative counters as `(series, value)` pairs, named for
    /// direct recording into a metrics hub.
    pub fn samples(&self) -> [(&'static str, u64); 6] {
        [
            ("sched.spawns", self.spawns.load(Ordering::Relaxed)),
            ("sched.polls", self.polls.load(Ordering::Relaxed)),
            ("sched.parks", self.parks.load(Ordering::Relaxed)),
            ("sched.wakes", self.wakes.load(Ordering::Relaxed)),
            ("sched.ready_peak", self.ready_peak.load(Ordering::Relaxed)),
            ("sched.runners_busy_peak", self.running_peak.load(Ordering::Relaxed)),
        ]
    }
}

// ------------------------------------------------------------ yield signal

/// Marker error: the worker cannot progress until new mail arrives.
///
/// Raised by channel receives in cooperative mode; recognised by the chain
/// executor, which suspends the chain instead of failing the worker.
#[derive(Debug, Clone, Copy)]
pub struct Pending;

impl fmt::Display for Pending {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "worker is pending on mail (cooperative yield)")
    }
}

impl std::error::Error for Pending {}

/// Build the yield signal as an `anyhow` error.
pub fn pending_err() -> anyhow::Error {
    anyhow::Error::new(Pending)
}

/// Is this error the cooperative yield signal (possibly wrapped in
/// context)?
pub fn is_pending(err: &anyhow::Error) -> bool {
    err.downcast_ref::<Pending>().is_some()
}

// ------------------------------------------------------------- worker park

/// Per-worker execution mode, shared by every channel handle of the worker.
pub struct WorkerPark {
    cooperative: bool,
    timeout: Duration,
    /// Written once at spawn, read on every cooperative park: an RwLock
    /// keeps the read path (one per yielding receive, across all of a
    /// worker's channels) uncontended.
    waker: RwLock<Option<Waker>>,
}

impl WorkerPark {
    /// Legacy blocking mode: receives wait on the mailbox Condvar up to
    /// `timeout` (the configurable `RECV_TIMEOUT`).
    pub fn blocking(timeout: Duration) -> Arc<Self> {
        Arc::new(Self {
            cooperative: false,
            timeout,
            waker: RwLock::new(None),
        })
    }

    /// Cooperative mode: receives yield [`Pending`] to the scheduler. No
    /// wall-clock timeout is needed — a stuck deployment is detected as a
    /// virtual-time deadlock the moment the fabric goes idle.
    pub fn cooperative() -> Arc<Self> {
        Arc::new(Self {
            cooperative: true,
            timeout: Duration::ZERO,
            waker: RwLock::new(None),
        })
    }

    pub fn is_cooperative(&self) -> bool {
        self.cooperative
    }

    /// Blocking-mode receive timeout.
    pub fn timeout(&self) -> Duration {
        self.timeout
    }

    /// Bind the scheduler-side waker (after the task is spawned).
    pub fn set_waker(&self, w: Waker) {
        *self.waker.write().unwrap() = Some(w);
    }

    pub fn waker(&self) -> Option<Waker> {
        self.waker.read().unwrap().clone()
    }
}

// --------------------------------------------------------------- the tasks

/// Outcome of driving a task once.
pub enum PollOutcome {
    /// The task finished (successfully or not — the task records its own
    /// terminal status).
    Done,
    /// The task yielded; it parked a wait condition on some mailbox and
    /// will be woken through its [`Waker`].
    Parked,
}

/// A cooperatively scheduled unit (one worker).
pub trait RunnableTask: Send {
    /// Stable name for diagnostics (the worker id).
    fn name(&self) -> &str;

    /// Drive the task until it completes or yields.
    fn poll(&mut self) -> PollOutcome;

    /// Terminate a parked task that can never resume (virtual-time
    /// deadlock). The task records the failure as its terminal status.
    fn fail(&mut self, reason: &str);

    /// What this parked task is waiting for — channel, wait-spec, peer
    /// set, last trace span — for the deadlock post-mortem. Called only
    /// on stalled tasks, *outside* the scheduler lock (implementations
    /// may take channel locks). Default: no context.
    fn stall_context(&self) -> Option<String> {
        None
    }
}

// --------------------------------------------------------------- scheduler

pub type TaskId = usize;

#[derive(Clone, Copy)]
enum TaskState {
    Ready,
    Running { wake_pending: Option<VTime> },
    Waiting,
    Done,
}

struct TaskSlot {
    state: TaskState,
    task: Option<Box<dyn RunnableTask>>,
    /// Fair-share group this task is polled under.
    group: usize,
}

/// One fair-share group's slice of the ready set.
struct GroupQueue {
    /// Min-heap of `(virtual wake time, task id)` — virtual-arrival order
    /// within the group.
    ready: BinaryHeap<Reverse<(VTime, TaskId)>>,
    /// Polls charged to this group so far (the stride scheduler's pass).
    pass: u64,
}

impl GroupQueue {
    fn new() -> Self {
        Self {
            ready: BinaryHeap::new(),
            pass: 0,
        }
    }
}

struct SchedState {
    tasks: Vec<TaskSlot>,
    /// Ready tasks, sliced per fair-share group.
    groups: Vec<GroupQueue>,
    /// Groups whose ready heap is currently non-empty — the only ones a
    /// pop must consider. Keeps selection proportional to *concurrent*
    /// work, not to every group ever created (a fleet makes one group
    /// per job and jobs outlive their tasks).
    nonempty: std::collections::BTreeSet<usize>,
    /// Tasks not yet Done.
    live: usize,
    /// Tasks currently being polled by a runner.
    running: usize,
    /// Wakes can arrive from *outside* the runner pool (another OS
    /// process delivering over a wire transport). While set, an idle pool
    /// with parked tasks is not a virtual-time deadlock — it waits for
    /// external mail instead of failing the tasks.
    external: bool,
    /// Runtime counters (shared out through [`Scheduler::stats`]).
    stats: Arc<SchedStats>,
}

impl SchedState {
    fn ensure_group(&mut self, group: usize) {
        while self.groups.len() <= group {
            self.groups.push(GroupQueue::new());
        }
    }

    fn push_ready(&mut self, id: TaskId, at: VTime) {
        let g = self.tasks[id].group;
        self.groups[g].ready.push(Reverse((at, id)));
        self.nonempty.insert(g);
        self.stats.on_push_ready();
    }

    /// Pop the next task to poll: earliest head virtual time wins; virtual
    /// -time ties go to the group with the fewest polls so far (then the
    /// lower group id — fully deterministic given the same ready set).
    ///
    /// The selection scans the heads of the *non-empty* groups only:
    /// O(concurrent groups with ready work) per poll — drained groups
    /// (completed jobs) cost nothing. A poll runs a whole tasklet step
    /// (training, aggregation), so this scan is noise; if profiles ever
    /// disagree, the fix is a secondary heap over groups keyed by
    /// `(head vtime, pass, id)` with lazy invalidation.
    fn pop_ready(&mut self) -> Option<TaskId> {
        let mut best: Option<(VTime, u64, usize)> = None;
        for &gi in &self.nonempty {
            if let Some(Reverse((vt, _))) = self.groups[gi].ready.peek() {
                let key = (*vt, self.groups[gi].pass, gi);
                if best.map_or(true, |b| key < b) {
                    best = Some(key);
                }
            }
        }
        let (_, _, gi) = best?;
        let Reverse((_, id)) = self.groups[gi].ready.pop().expect("peeked non-empty");
        self.groups[gi].pass += 1;
        if self.groups[gi].ready.is_empty() {
            self.nonempty.remove(&gi);
        }
        self.stats.on_pop_ready();
        Some(id)
    }
}

/// Shared scheduler core (referenced by [`Waker`]s inside mailboxes).
pub struct SchedShared {
    state: Mutex<SchedState>,
    cv: Condvar,
}

/// Wakes one parked task; cheap to clone into mailbox wait slots.
#[derive(Clone)]
pub struct Waker {
    shared: Arc<SchedShared>,
    task: TaskId,
}

impl Waker {
    /// Mark the task runnable at virtual time `at` (the matching message's
    /// arrival). Safe to call at any time: a wake racing the task's own
    /// park is latched and applied when the poll returns.
    pub fn wake(&self, at: VTime) {
        let mut g = self.shared.state.lock().unwrap();
        let push = {
            let slot = &mut g.tasks[self.task];
            match slot.state {
                TaskState::Running { wake_pending } => {
                    let at = wake_pending.map_or(at, |p| p.min(at));
                    slot.state = TaskState::Running {
                        wake_pending: Some(at),
                    };
                    false
                }
                TaskState::Waiting => {
                    slot.state = TaskState::Ready;
                    true
                }
                TaskState::Ready | TaskState::Done => false,
            }
        };
        if push {
            g.stats.wakes.fetch_add(1, Ordering::Relaxed);
            g.push_ready(self.task, at);
            drop(g);
            self.shared.cv.notify_all();
        }
    }
}

/// The worker fabric: spawn tasks, then [`run`](Self::run) the pool.
///
/// Clones share the same fabric, which is what lets a *running* task
/// trigger further spawns: live topology extension deploys new workers
/// through a clone held by the deployer while the pool is mid-run.
pub struct Scheduler {
    shared: Arc<SchedShared>,
}

impl Clone for Scheduler {
    fn clone(&self) -> Self {
        Self {
            shared: self.shared.clone(),
        }
    }
}

impl Default for Scheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler {
    pub fn new() -> Self {
        Self {
            shared: Arc::new(SchedShared {
                state: Mutex::new(SchedState {
                    tasks: Vec::new(),
                    groups: vec![GroupQueue::new()],
                    nonempty: std::collections::BTreeSet::new(),
                    live: 0,
                    running: 0,
                    external: false,
                    stats: Arc::new(SchedStats::default()),
                }),
                cv: Condvar::new(),
            }),
        }
    }

    /// Register a task in share group 0; it becomes ready at virtual
    /// time 0. Tasks do not run until [`run`](Self::run).
    pub fn spawn(&self, task: Box<dyn RunnableTask>) -> TaskId {
        self.spawn_in(0, task)
    }

    /// Register a task in the given fair-share group; it becomes ready at
    /// virtual time 0. The multi-job control plane gives every job its own
    /// group so no job can monopolise the runner pool.
    pub fn spawn_in(&self, group: usize, task: Box<dyn RunnableTask>) -> TaskId {
        let mut g = self.shared.state.lock().unwrap();
        g.ensure_group(group);
        let id = g.tasks.len();
        g.tasks.push(TaskSlot {
            state: TaskState::Ready,
            task: Some(task),
            group,
        });
        g.live += 1;
        g.stats.spawns.fetch_add(1, Ordering::Relaxed);
        g.push_ready(id, 0);
        id
    }

    /// Register a task in the parked (Waiting) state: it will not run
    /// until its waker fires. This is the spawn used for **live** (mid-run)
    /// deployment — bind the waker first, then wake at the worker's join
    /// time — and it is safe while the runner pool is active: the wake's
    /// notify hands the fresh task to an idle runner. The spawn must
    /// originate from a running task (or happen before [`Self::run`]),
    /// otherwise the deadlock detector could fire between spawn and wake.
    pub fn spawn_parked(&self, task: Box<dyn RunnableTask>) -> TaskId {
        self.spawn_parked_in(0, task)
    }

    /// [`Self::spawn_parked`] into a specific fair-share group.
    pub fn spawn_parked_in(&self, group: usize, task: Box<dyn RunnableTask>) -> TaskId {
        let mut g = self.shared.state.lock().unwrap();
        g.ensure_group(group);
        let id = g.tasks.len();
        g.tasks.push(TaskSlot {
            state: TaskState::Waiting,
            task: Some(task),
            group,
        });
        g.live += 1;
        g.stats.spawns.fetch_add(1, Ordering::Relaxed);
        id
    }

    /// A waker for `id`, to be bound into the task's [`WorkerPark`].
    pub fn waker(&self, id: TaskId) -> Waker {
        Waker {
            shared: self.shared.clone(),
            task: id,
        }
    }

    /// Tasks not yet finished.
    pub fn live(&self) -> usize {
        self.shared.state.lock().unwrap().live
    }

    /// Declare (or retract) an external wake source: deliveries arriving
    /// from outside the runner pool, e.g. a wire transport fed by another
    /// OS process. While on, an idle pool with parked tasks waits instead
    /// of declaring a virtual-time deadlock — a multi-process worker host
    /// is routinely quiescent between remote messages. The pool still
    /// exits normally once every task is Done.
    pub fn set_external_source(&self, on: bool) {
        let mut g = self.shared.state.lock().unwrap();
        g.external = on;
        drop(g);
        // retracting the source can re-arm the deadlock check on an
        // already-idle pool
        self.shared.cv.notify_all();
    }

    /// This fabric's runtime counters (shared; clones see live updates).
    pub fn stats(&self) -> Arc<SchedStats> {
        self.shared.state.lock().unwrap().stats.clone()
    }

    /// Drive all tasks to completion on `runners` threads (the calling
    /// thread counts as one). Returns when every task is Done; stalled
    /// tasks are failed via [`RunnableTask::fail`] rather than hanging.
    pub fn run(&self, runners: usize) {
        let n = runners.max(1);
        if n == 1 {
            Self::runner(&self.shared);
            return;
        }
        std::thread::scope(|s| {
            for _ in 1..n {
                let shared = &self.shared;
                s.spawn(move || Self::runner(shared));
            }
            Self::runner(&self.shared);
        });
    }

    fn runner(shared: &SchedShared) {
        enum Next {
            Poll(TaskId, Box<dyn RunnableTask>),
            /// Virtual-time deadlock: these tasks can never resume.
            Stalled(Vec<Box<dyn RunnableTask>>, String),
            Exit,
        }
        loop {
            let next = {
                let mut g = shared.state.lock().unwrap();
                loop {
                    if g.live == 0 {
                        break Next::Exit;
                    }
                    if let Some(id) = g.pop_ready() {
                        let slot = &mut g.tasks[id];
                        slot.state = TaskState::Running { wake_pending: None };
                        let task = slot.task.take().expect("ready task has a runnable");
                        g.running += 1;
                        g.stats.polls.fetch_add(1, Ordering::Relaxed);
                        g.stats.running_peak.fetch_max(g.running as u64, Ordering::Relaxed);
                        break Next::Poll(id, task);
                    }
                    if g.running == 0 && !g.external {
                        // Nothing ready, nothing running, live tasks remain:
                        // no delivery can ever wake them again. (With an
                        // external wake source — a wire transport fed by
                        // another OS process — this is just quiescence
                        // between remote deliveries, so wait instead.)
                        let (tasks, reason) = Self::collect_stalled(&mut g);
                        break Next::Stalled(tasks, reason);
                    }
                    g = shared.cv.wait(g).unwrap();
                }
            };
            let (id, mut task) = match next {
                Next::Exit => {
                    shared.cv.notify_all();
                    return;
                }
                Next::Stalled(tasks, reason) => {
                    // fail() AND the post-mortem gathering run OUTSIDE the
                    // scheduler lock: a failing task may fan out through
                    // observers that take this lock again (e.g. the
                    // control plane's pod tracker waking its pump), and
                    // stall_context() takes channel locks whose ordering
                    // puts the scheduler lock *after* them on the delivery
                    // path.
                    let reason = Self::post_mortem(reason, &tasks);
                    eprintln!("{reason}");
                    for mut t in tasks {
                        t.fail(&reason);
                    }
                    shared.cv.notify_all();
                    continue;
                }
                Next::Poll(id, task) => (id, task),
            };

            let outcome = task.poll();

            let mut g = shared.state.lock().unwrap();
            g.running -= 1;
            match outcome {
                PollOutcome::Done => {
                    g.tasks[id].state = TaskState::Done;
                    // drop the runnable now so finished workers release
                    // their model state immediately (peak-RSS matters at
                    // 10k workers)
                    drop(task);
                    g.live -= 1;
                }
                PollOutcome::Parked => {
                    g.stats.parks.fetch_add(1, Ordering::Relaxed);
                    let wake = match g.tasks[id].state {
                        TaskState::Running { wake_pending } => wake_pending,
                        _ => None,
                    };
                    g.tasks[id].task = Some(task);
                    if let Some(at) = wake {
                        g.tasks[id].state = TaskState::Ready;
                        g.push_ready(id, at);
                    } else {
                        g.tasks[id].state = TaskState::Waiting;
                    }
                }
            }
            drop(g);
            shared.cv.notify_all();
        }
    }

    /// Remove every Waiting task from the state (marking it Done and
    /// adjusting `live`) and hand the runnables back with the deadlock
    /// diagnostic. The caller invokes [`RunnableTask::fail`] on each
    /// *after* releasing the state lock — failure observers are allowed
    /// to take scheduler locks (wake other tasks) again.
    fn collect_stalled(
        g: &mut std::sync::MutexGuard<'_, SchedState>,
    ) -> (Vec<Box<dyn RunnableTask>>, String) {
        let st: &mut SchedState = g;
        let names: Vec<String> = st
            .tasks
            .iter()
            .filter(|t| matches!(t.state, TaskState::Waiting))
            .filter_map(|t| t.task.as_ref().map(|x| x.name().to_string()))
            .collect();
        let shown: Vec<String> = names.iter().take(5).cloned().collect();
        let reason = format!(
            "virtual-time deadlock: {} worker(s) waiting on mail that can never arrive ({}{})",
            names.len(),
            shown.join(", "),
            if names.len() > 5 { ", ..." } else { "" }
        );
        let mut stalled = Vec::new();
        for slot in st.tasks.iter_mut() {
            if matches!(slot.state, TaskState::Waiting) {
                if let Some(task) = slot.task.take() {
                    stalled.push(task);
                }
                slot.state = TaskState::Done;
            }
        }
        st.live -= stalled.len();
        (stalled, reason)
    }

    /// Append each stalled task's wait context to the deadlock diagnostic:
    /// what it was parked on (channel, wait-spec, peers) and, when tracing
    /// is on, the last span it recorded. Capped so a 10k-worker stall
    /// stays one screen.
    fn post_mortem(reason: String, tasks: &[Box<dyn RunnableTask>]) -> String {
        const SHOWN: usize = 8;
        let mut out = reason;
        for t in tasks.iter().take(SHOWN) {
            let ctx = t
                .stall_context()
                .unwrap_or_else(|| "no wait registered".to_string());
            out.push_str(&format!("\n  - {}: {}", t.name(), ctx));
        }
        if tasks.len() > SHOWN {
            out.push_str(&format!("\n  ... and {} more", tasks.len() - SHOWN));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// A task that yields `yields` times (waking itself eagerly via the
    /// waker it is given after spawn), then completes.
    struct YieldTask {
        name: String,
        yields: usize,
        park: Arc<WorkerPark>,
        polls: Arc<AtomicUsize>,
        failed: Arc<Mutex<Option<String>>>,
        wake_self: bool,
    }

    impl RunnableTask for YieldTask {
        fn name(&self) -> &str {
            &self.name
        }

        fn poll(&mut self) -> PollOutcome {
            self.polls.fetch_add(1, Ordering::SeqCst);
            if self.yields == 0 {
                return PollOutcome::Done;
            }
            self.yields -= 1;
            if self.wake_self {
                // simulate a delivery that races the park
                self.park.waker().unwrap().wake(self.yields as u64);
            }
            PollOutcome::Parked
        }

        fn fail(&mut self, reason: &str) {
            *self.failed.lock().unwrap() = Some(reason.to_string());
        }
    }

    fn task(
        name: &str,
        yields: usize,
        wake_self: bool,
    ) -> (YieldTask, Arc<WorkerPark>, Arc<AtomicUsize>, Arc<Mutex<Option<String>>>) {
        let park = WorkerPark::cooperative();
        let polls = Arc::new(AtomicUsize::new(0));
        let failed = Arc::new(Mutex::new(None));
        (
            YieldTask {
                name: name.into(),
                yields,
                park: park.clone(),
                polls: polls.clone(),
                failed: failed.clone(),
                wake_self,
            },
            park,
            polls,
            failed,
        )
    }

    #[test]
    fn runs_tasks_to_completion() {
        let sched = Scheduler::new();
        let (t, park, polls, _) = task("w0", 3, true);
        let id = sched.spawn(Box::new(t));
        park.set_waker(sched.waker(id));
        sched.run(2);
        assert_eq!(polls.load(Ordering::SeqCst), 4);
        assert_eq!(sched.live(), 0);
    }

    #[test]
    fn stalled_task_is_failed_not_hung() {
        let sched = Scheduler::new();
        // parks once and is never woken
        let (t, park, polls, failed) = task("stuck", 1, false);
        let id = sched.spawn(Box::new(t));
        park.set_waker(sched.waker(id));
        sched.run(1);
        assert_eq!(polls.load(Ordering::SeqCst), 1);
        let msg = failed.lock().unwrap().clone().expect("task must be failed");
        assert!(msg.contains("deadlock"), "{msg}");
        assert!(msg.contains("stuck"), "{msg}");
    }

    #[test]
    fn deadlock_post_mortem_includes_stall_context() {
        struct StallTask {
            failed: Arc<Mutex<Option<String>>>,
        }
        impl RunnableTask for StallTask {
            fn name(&self) -> &str {
                "ctx-task"
            }
            fn poll(&mut self) -> PollOutcome {
                PollOutcome::Parked
            }
            fn fail(&mut self, reason: &str) {
                *self.failed.lock().unwrap() = Some(reason.to_string());
            }
            fn stall_context(&self) -> Option<String> {
                Some("waiting on channel 'param' for a message from 'agg' (peers: [agg])".into())
            }
        }
        let sched = Scheduler::new();
        let failed = Arc::new(Mutex::new(None));
        sched.spawn(Box::new(StallTask {
            failed: failed.clone(),
        }));
        sched.run(1);
        let msg = failed.lock().unwrap().clone().expect("task must be failed");
        assert!(msg.contains("deadlock"), "{msg}");
        // the post-mortem names the task and dumps its wait context
        assert!(msg.contains("ctx-task:"), "{msg}");
        assert!(msg.contains("channel 'param'"), "{msg}");
        assert!(msg.contains("peers: [agg]"), "{msg}");
    }

    #[test]
    fn stats_count_polls_parks_and_wakes() {
        let sched = Scheduler::new();
        let (t, park, _, _) = task("w0", 3, true);
        let id = sched.spawn(Box::new(t));
        park.set_waker(sched.waker(id));
        sched.run(2);
        let st = sched.stats();
        assert_eq!(st.spawns.load(Ordering::SeqCst), 1);
        assert_eq!(st.polls.load(Ordering::SeqCst), 4);
        assert_eq!(st.parks.load(Ordering::SeqCst), 3);
        assert!(st.ready_peak.load(Ordering::SeqCst) >= 1);
        assert_eq!(st.ready_depth(), 0);
        assert!(st.samples().iter().any(|(n, v)| *n == "sched.polls" && *v == 4));
        // a wake on a Waiting task is what counts as a wake
        let (t2, park2, _, _) = task("w1", 0, false);
        let id2 = sched.spawn_parked(Box::new(t2));
        park2.set_waker(sched.waker(id2));
        sched.waker(id2).wake(3);
        sched.run(1);
        assert_eq!(st.wakes.load(Ordering::SeqCst), 1);
        assert_eq!(st.spawns.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn many_tasks_on_few_runners() {
        let sched = Scheduler::new();
        let mut handles = Vec::new();
        for i in 0..200 {
            let (t, park, polls, _) = task(&format!("w{i}"), 2, true);
            let id = sched.spawn(Box::new(t));
            park.set_waker(sched.waker(id));
            handles.push(polls);
        }
        sched.run(4);
        for polls in handles {
            assert_eq!(polls.load(Ordering::SeqCst), 3);
        }
    }

    #[test]
    fn spawn_parked_waits_for_its_wake() {
        let sched = Scheduler::new();
        let (t, park, polls, _) = task("late", 0, false);
        let id = sched.spawn_parked(Box::new(t));
        park.set_waker(sched.waker(id));
        sched.waker(id).wake(7);
        sched.run(1);
        assert_eq!(polls.load(Ordering::SeqCst), 1);
        assert_eq!(sched.live(), 0);
    }

    #[test]
    fn live_spawn_from_a_running_task() {
        // a polled task deploys a new task onto the running fabric — the
        // mechanism behind mid-job topology extension
        struct Spawner {
            sched: Scheduler,
            child_polls: Arc<AtomicUsize>,
        }
        impl RunnableTask for Spawner {
            fn name(&self) -> &str {
                "spawner"
            }
            fn poll(&mut self) -> PollOutcome {
                let park = WorkerPark::cooperative();
                let child = YieldTask {
                    name: "child".into(),
                    yields: 0,
                    park: park.clone(),
                    polls: self.child_polls.clone(),
                    failed: Arc::new(Mutex::new(None)),
                    wake_self: false,
                };
                let id = self.sched.spawn_parked(Box::new(child));
                park.set_waker(self.sched.waker(id));
                self.sched.waker(id).wake(3);
                PollOutcome::Done
            }
            fn fail(&mut self, _reason: &str) {}
        }
        let sched = Scheduler::new();
        let child_polls = Arc::new(AtomicUsize::new(0));
        sched.spawn(Box::new(Spawner {
            sched: sched.clone(),
            child_polls: child_polls.clone(),
        }));
        sched.run(2);
        assert_eq!(child_polls.load(Ordering::SeqCst), 1);
        assert_eq!(sched.live(), 0);
    }

    #[test]
    fn pending_signal_roundtrip() {
        let err = pending_err();
        assert!(is_pending(&err));
        let wrapped = err.context("while receiving");
        assert!(is_pending(&wrapped));
        assert!(!is_pending(&anyhow::anyhow!("boom")));
    }

    #[test]
    fn empty_scheduler_returns_immediately() {
        let sched = Scheduler::new();
        sched.run(3);
        assert_eq!(sched.live(), 0);
    }

    /// One-shot task that appends its name to a shared poll log.
    struct LogTask {
        name: String,
        log: Arc<Mutex<Vec<String>>>,
    }

    impl RunnableTask for LogTask {
        fn name(&self) -> &str {
            &self.name
        }
        fn poll(&mut self) -> PollOutcome {
            self.log.lock().unwrap().push(self.name.clone());
            PollOutcome::Done
        }
        fn fail(&mut self, _reason: &str) {}
    }

    #[test]
    fn fair_share_interleaves_groups_at_equal_vtime() {
        // a "big job" (group 1, spawned first) and a "small job" (group 2),
        // all ready at virtual time 0 on one runner: the stride tie-break
        // must alternate groups instead of draining the big job first
        let sched = Scheduler::new();
        let log = Arc::new(Mutex::new(Vec::new()));
        for i in 0..4 {
            sched.spawn_in(
                1,
                Box::new(LogTask {
                    name: format!("big-{i}"),
                    log: log.clone(),
                }),
            );
        }
        for i in 0..2 {
            sched.spawn_in(
                2,
                Box::new(LogTask {
                    name: format!("small-{i}"),
                    log: log.clone(),
                }),
            );
        }
        sched.run(1);
        let order = log.lock().unwrap().clone();
        assert_eq!(
            order,
            vec!["big-0", "small-0", "big-1", "small-1", "big-2", "big-3"],
            "expected stride alternation between tied groups"
        );
    }

    #[test]
    fn earlier_vtime_beats_fair_share() {
        // virtual time stays the primary key: a group-2 task ready at
        // vtime 5 must NOT run before a group-1 task ready at vtime 3,
        // whatever the pass counters say
        let sched = Scheduler::new();
        let log = Arc::new(Mutex::new(Vec::new()));
        let early = sched.spawn_parked_in(
            1,
            Box::new(LogTask {
                name: "early".into(),
                log: log.clone(),
            }),
        );
        let late = sched.spawn_parked_in(
            2,
            Box::new(LogTask {
                name: "late".into(),
                log: log.clone(),
            }),
        );
        sched.waker(late).wake(5);
        sched.waker(early).wake(3);
        sched.run(1);
        let order = log.lock().unwrap().clone();
        assert_eq!(order, vec!["early", "late"]);
    }
}
