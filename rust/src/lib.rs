//! # Flame — Federated Learning Operations Made Simple (reproduction)
//!
//! A from-scratch reproduction of the Flame FLOps system (Daga et al., 2023)
//! as the Layer-3 Rust coordinator of a three-layer Rust + JAX + Pallas
//! stack. The crate contains:
//!
//! * the **TAG** abstraction — roles, channels, `groupBy` /
//!   `groupAssociation` / `replica` / `isDataConsumer` attributes — and the
//!   paper's Algorithm 1 expansion ([`tag`]),
//! * **live topology extension** — [`tag::delta`] TAG deltas resolved into
//!   incremental worker patches, a scheduled event timeline
//!   ([`deploy::TopologyTimeline`]) that deploys joiners and retires
//!   leavers on the running fabric, and churn-safe quorum aggregation
//!   (the title's *extension* claim, exercised by `sim::run_churn` /
//!   `flame churn`),
//! * the **management plane** — controller, notifier, deployer, agent,
//!   journaling store, compute/dataset registries with realms
//!   ([`control`], [`notify`], [`deploy`], [`agent`], [`store`],
//!   [`registry`]),
//! * the **multi-job control plane** — concurrent job admission against
//!   registered compute capacity, FIFO queueing, persisted
//!   `Queued → Deploying → Running → Completed/Failed` lifecycles, and
//!   fair-share execution of every admitted job on **one** shared
//!   virtual-time fabric with per-job channel namespacing
//!   ([`controlplane`]; scenario: `sim::run_fleet` / `flame fleet`),
//! * the **channel** primitive with the paper's Table-2 API and pluggable
//!   communication backends over a virtual-time network model ([`channel`],
//!   [`net`]),
//! * the **cooperative worker fabric** — a discrete-event, virtual-time
//!   scheduler that multiplexes thousands of logical workers over a
//!   bounded runner pool ([`sched`]), replacing thread-per-worker
//!   deployment and unlocking the 10,000-trainer `sim::run_scale`
//!   scenario,
//! * the **tasklet/composer** developer programming model (Table 1 surgery
//!   API) and the built-in role workflows ([`workflow`], [`roles`]),
//! * the **Role SDK** — the public, registry-based role↔program binding
//!   of §4.1 ([`roles::registry`], [`roles::sdk`]): named
//!   `ProgramFactory` closures, spec-declared `program:`/`flavor`
//!   bindings (validate-time inference for legacy specs), exported base
//!   chains so new mechanisms are derived by surgery without touching
//!   `roles/` (proof: FedProx via `sim::run_fedprox` / `flame fedprox`),
//! * FL **algorithms** and **selection** policies from the paper's feature
//!   matrix (Table 7) ([`algos`], [`select`]),
//! * the PJRT **runtime** that loads the AOT-lowered JAX/Pallas artifacts
//!   and executes them on the request path with no Python ([`runtime`],
//!   [`model`]),
//! * synthetic **data** with non-IID partitioning, **metrics**, and the
//!   **sim**ulation harness that regenerates the paper's figures ([`data`],
//!   [`metrics`], [`sim`]).
//!
//! See `DESIGN.md` for the paper-to-module map and `EXPERIMENTS.md` for the
//! reproduced tables/figures.

pub mod agent;
pub mod algos;
pub mod alloc_track;
pub mod channel;
pub mod control;
pub mod controlplane;
pub mod data;
pub mod deploy;
pub mod intern;
pub mod json;
pub mod metrics;
pub mod model;
pub mod net;
pub mod notify;
pub mod prng;
pub mod proputil;
pub mod registry;
pub mod roles;
pub mod runtime;
pub mod sched;
pub mod select;
pub mod sim;
pub mod store;
pub mod tag;
pub mod topo;
pub mod trace;
pub mod wire;
pub mod workflow;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
