//! Pure-Rust [`Compute`] stand-in: multinomial logistic regression.
//!
//! Implements the same trait as the PJRT pool so every coordination test,
//! property test and bench that doesn't care about the exact model can run
//! without artifacts and in microseconds. The model *really learns*: the
//! first `784*10 + 10` coordinates of the flat vector are a softmax
//! classifier over the synthetic data; the rest of the vector is carried
//! through untouched (mirroring padding semantics of the real layout).

use anyhow::Result;

use super::Compute;
use crate::model::weighted_sum;

const IN: usize = crate::data::INPUT_DIM;
const C: usize = crate::data::NUM_CLASSES;
const USED: usize = IN * C + C;

/// Logistic-regression mock with the real flat-vector calling convention.
pub struct MockCompute {
    d_pad: usize,
    batch: usize,
    agg_k: usize,
}

impl MockCompute {
    pub fn new(d_pad: usize, batch: usize, agg_k: usize) -> Self {
        Self {
            d_pad,
            batch,
            agg_k,
        }
    }

    /// Same envelope as the real MLP artifacts (d_pad, batch 32, K 16) so a
    /// mock can be swapped for a PjrtPool in any test.
    pub fn default_mlp() -> Self {
        Self::new(235_520, 32, 16)
    }

    /// Forward pass: logits for each batch row.
    fn logits(&self, flat: &[f32], x: &[f32]) -> Vec<f32> {
        let b = x.len() / IN;
        let w = &flat[..IN * C];
        let bias = &flat[IN * C..USED.min(flat.len())];
        let mut out = vec![0f32; b * C];
        for r in 0..b {
            let row = &x[r * IN..(r + 1) * IN];
            for c in 0..C {
                let mut acc = if bias.len() == C { bias[c] } else { 0.0 };
                // column-major-ish access kept simple; mock is not perf-critical
                for i in 0..IN {
                    acc += row[i] * w[i * C + c];
                }
                out[r * C + c] = acc;
            }
        }
        out
    }

    /// Returns (grad over flat, mean loss).
    fn grad_loss(&self, flat: &[f32], x: &[f32], y: &[i32]) -> (Vec<f32>, f32) {
        let b = y.len();
        let logits = self.logits(flat, x);
        let mut grad = vec![0f32; self.d_pad];
        let mut loss = 0f64;
        for r in 0..b {
            let lg = &logits[r * C..(r + 1) * C];
            let mx = lg.iter().cloned().fold(f32::MIN, f32::max);
            let exps: Vec<f32> = lg.iter().map(|v| (v - mx).exp()).collect();
            let z: f32 = exps.iter().sum();
            let target = y[r] as usize;
            loss -= ((exps[target] / z).max(1e-12) as f64).ln();
            let row = &x[r * IN..(r + 1) * IN];
            for c in 0..C {
                let p = exps[c] / z;
                let g = p - if c == target { 1.0 } else { 0.0 };
                for i in 0..IN {
                    grad[i * C + c] += row[i] * g / b as f32;
                }
                grad[IN * C + c] += g / b as f32;
            }
        }
        (grad, (loss / b as f64) as f32)
    }
}

impl Compute for MockCompute {
    fn d_pad(&self) -> usize {
        self.d_pad
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn agg_k(&self) -> usize {
        self.agg_k
    }

    fn train_step(&self, flat: &[f32], x: &[f32], y: &[i32], lr: f32) -> Result<(Vec<f32>, f32)> {
        let (grad, loss) = self.grad_loss(flat, x, y);
        let mut new = flat.to_vec();
        crate::model::axpy(&mut new, -lr, &grad);
        Ok((new, loss))
    }

    fn train_step_prox(
        &self,
        flat: &[f32],
        gflat: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        mu: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let (mut grad, loss) = self.grad_loss(flat, x, y);
        for i in 0..self.d_pad {
            grad[i] += mu * (flat[i] - gflat[i]);
        }
        let mut new = flat.to_vec();
        crate::model::axpy(&mut new, -lr, &grad);
        Ok((new, loss))
    }

    fn train_step_dyn(
        &self,
        flat: &[f32],
        gflat: &[f32],
        h: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        alpha: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let (mut grad, loss) = self.grad_loss(flat, x, y);
        for i in 0..self.d_pad {
            grad[i] = grad[i] - h[i] + alpha * (flat[i] - gflat[i]);
        }
        let mut new = flat.to_vec();
        crate::model::axpy(&mut new, -lr, &grad);
        let mut new_h = h.to_vec();
        for i in 0..self.d_pad {
            new_h[i] -= alpha * (new[i] - gflat[i]);
        }
        Ok((new, new_h, loss))
    }

    fn grad_step(&self, flat: &[f32], x: &[f32], y: &[i32]) -> Result<(Vec<f32>, f32)> {
        let (g, l) = self.grad_loss(flat, x, y);
        Ok((g, l))
    }

    fn eval_step(&self, flat: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let b = y.len();
        let logits = self.logits(flat, x);
        let mut sum_loss = 0f64;
        let mut correct = 0f32;
        for r in 0..b {
            let lg = &logits[r * C..(r + 1) * C];
            let mx = lg.iter().cloned().fold(f32::MIN, f32::max);
            let exps: Vec<f32> = lg.iter().map(|v| (v - mx).exp()).collect();
            let z: f32 = exps.iter().sum();
            let target = y[r] as usize;
            sum_loss -= ((exps[target] / z).max(1e-12) as f64).ln();
            let argmax = lg
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if argmax == target {
                correct += 1.0;
            }
        }
        Ok((sum_loss as f32, correct))
    }

    fn aggregate_k(&self, updates: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>> {
        assert!(updates.len() <= self.agg_k);
        Ok(weighted_sum(updates, weights))
    }

    /// Chunk-uniform override: fold rows sequentially, so the result is
    /// bit-identical to `model::weighted_sum` over the concatenation of
    /// all chunks — chunk boundaries cannot perturb rounding. This is what
    /// makes the streaming `Accumulator` byte-stable across `agg_k`
    /// configurations (`rust/tests/streaming_parity.rs`).
    fn aggregate_into(&self, acc: &mut [f32], updates: &[&[f32]], weights: &[f32]) -> Result<()> {
        assert!(updates.len() <= self.agg_k);
        assert_eq!(updates.len(), weights.len());
        for (u, &w) in updates.iter().zip(weights) {
            crate::model::axpy(acc, w, u);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{make_federated, Partition};

    fn batch(seed: u64) -> (Vec<f32>, Vec<i32>) {
        let (shards, _) = make_federated(seed, 1, 64, 32, Partition::Iid, 0.5);
        let idx: Vec<usize> = (0..32).collect();
        shards[0].gather_batch(&idx, 32)
    }

    #[test]
    fn learns_on_fixed_batch() {
        let c = MockCompute::default_mlp();
        let mut flat = vec![0f32; c.d_pad()];
        let (x, y) = batch(0);
        let (_, l0) = c.train_step(&flat, &x, &y, 0.0).unwrap();
        assert!((l0 - (10f32).ln()).abs() < 1e-3);
        let mut last = l0;
        for _ in 0..15 {
            let (nf, l) = c.train_step(&flat, &x, &y, 0.5).unwrap();
            flat = nf;
            last = l;
        }
        assert!(last < 0.5 * l0, "{l0} -> {last}");
    }

    #[test]
    fn grad_matches_finite_difference() {
        let c = MockCompute::new(USED, 8, 4);
        let (shards, _) = make_federated(3, 1, 8, 8, Partition::Iid, 0.5);
        let idx: Vec<usize> = (0..8).collect();
        let (x, y) = shards[0].gather_batch(&idx, 8);
        let mut flat = vec![0f32; c.d_pad()];
        // non-trivial point
        for (i, v) in flat.iter_mut().enumerate() {
            *v = ((i % 23) as f32 - 11.0) * 0.001;
        }
        let (g, _) = c.grad_step(&flat, &x, &y).unwrap();
        let eps = 1e-3;
        for &i in &[0usize, 777, 4001, 7845] {
            let mut p = flat.clone();
            p[i] += eps;
            let (_, lp) = c.train_step(&p, &x, &y, 0.0).unwrap();
            p[i] -= 2.0 * eps;
            let (_, lm) = c.train_step(&p, &x, &y, 0.0).unwrap();
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (g[i] - fd).abs() < 0.02 * (1.0 + fd.abs()),
                "coord {i}: grad {} vs fd {}",
                g[i],
                fd
            );
        }
    }

    #[test]
    fn prox_mu_zero_equals_sgd() {
        let c = MockCompute::new(USED, 8, 4);
        let (x, y) = batch(1);
        let flat = vec![0.01f32; c.d_pad()];
        let g = vec![0f32; c.d_pad()];
        let (a, _) = c.train_step(&flat, &x[..8 * IN], &y[..8], 0.1).unwrap();
        let (b, _) = c
            .train_step_prox(&flat, &g, &x[..8 * IN], &y[..8], 0.1, 0.0)
            .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn prox_pulls_toward_global() {
        let c = MockCompute::new(USED, 8, 4);
        let (x, y) = batch(2);
        let flat = vec![0.05f32; c.d_pad()];
        let g = vec![0f32; c.d_pad()];
        let (a, _) = c
            .train_step_prox(&flat, &g, &x[..8 * IN], &y[..8], 0.1, 0.0)
            .unwrap();
        let (b, _) = c
            .train_step_prox(&flat, &g, &x[..8 * IN], &y[..8], 0.1, 10.0)
            .unwrap();
        assert!(crate::model::l2_norm(&b) < crate::model::l2_norm(&a));
    }

    #[test]
    fn dyn_h_update_rule() {
        let c = MockCompute::new(USED, 8, 4);
        let (x, y) = batch(3);
        let flat = vec![0.02f32; c.d_pad()];
        let g = vec![0.01f32; c.d_pad()];
        let h = vec![0.001f32; c.d_pad()];
        let alpha = 0.1f32;
        let (nf, nh, _) = c
            .train_step_dyn(&flat, &g, &h, &x[..8 * IN], &y[..8], 0.05, alpha)
            .unwrap();
        for i in (0..c.d_pad()).step_by(997) {
            let want = h[i] - alpha * (nf[i] - g[i]);
            assert!((nh[i] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn untouched_tail_preserved() {
        let c = MockCompute::default_mlp();
        let (x, y) = batch(4);
        let mut flat = vec![0f32; c.d_pad()];
        flat[USED + 5] = 42.0;
        let (nf, _) = c.train_step(&flat, &x, &y, 0.1).unwrap();
        assert_eq!(nf[USED + 5], 42.0);
    }
}
