//! Update codecs — communication-efficient encodings for the upload path.
//!
//! Trainers upload model *deltas* (update − distributed model). A codec
//! compresses that delta on the uploading role's chain and is decoded at
//! the aggregation point, where the carried delta is re-added onto the
//! round's distributed base before entering the streaming fold. Because
//! [`crate::channel::Payload::Encoded`] reports the **encoded** wire size
//! through `Message::size_bytes`, `VirtualNet::transfer_us` charges the
//! compressed bytes — compression visibly shortens virtual-time rounds,
//! which `rust/tests/codecs.rs` asserts.
//!
//! Three schemes:
//!
//! * [`F32Codec`] (`"f32"`) — passthrough parity oracle. Carries the raw
//!   delta; wire size equals the `Payload::Floats` size it replaces, so a
//!   job with `codec: "f32"` is bit-identical (metrics *and* virtual
//!   time) to one with no codec at all on the classical trainer path,
//!   whose raw upload computes the same `base + delta` sum. (The hybrid
//!   delegate's raw upload ships its model directly, so there f32 parity
//!   is virtual-time-exact but numerically only f32-add-exact.)
//! * [`Int8Codec`] (`"int8"`) — linear quantization: `scale = max|δ|/127`,
//!   each coordinate rounds to a signed byte. ~4× fewer bytes, bounded
//!   per-coordinate error `≤ scale/2`.
//! * [`TopKCodec`] (`"topk"`) — magnitude sparsification with per-client
//!   **error feedback**: the codec adds the client's residual to the
//!   delta, keeps the `ceil(frac·d)` largest-magnitude coordinates
//!   (deterministic tie-break: larger |value| first, then lower index),
//!   and leaves everything it dropped in the residual for the next round.
//!   `decode(encode(u)) + residual == u + residual_in` holds exactly —
//!   the selected values are copied verbatim, never re-rounded.
//!
//! Codecs are stateless and shared per job (`JobRuntime::codec`); the
//! error-feedback residual lives with the *client* (trainer/hybrid role
//! context), which keeps encoding a pure function of `(delta, residual)`
//! and therefore deterministic across executors and runner pools.

use std::sync::Arc;

use anyhow::{bail, Result};

/// One encoded update as it travels the wire.
#[derive(Debug, Clone, PartialEq)]
pub enum EncodedUpdate {
    /// Raw delta — the passthrough oracle.
    F32 { data: Vec<f32> },
    /// Linear int8 quantization: `delta[i] ≈ q[i] · scale`.
    Int8 { d: usize, scale: f32, q: Vec<i8> },
    /// Sparse top-k coordinates of the (residual-corrected) delta.
    TopK { d: usize, idx: Vec<u32>, val: Vec<f32> },
}

impl EncodedUpdate {
    /// Bytes this update occupies on the wire — what `VirtualNet` charges.
    /// `F32` matches `Payload::Floats` exactly (4 bytes per coordinate, no
    /// extra header) so passthrough keeps virtual time unchanged; the
    /// compressed forms carry their small side-channel (scale / length)
    /// explicitly.
    pub fn wire_bytes(&self) -> usize {
        match self {
            EncodedUpdate::F32 { data } => 4 * data.len(),
            EncodedUpdate::Int8 { q, .. } => 8 + q.len(),
            EncodedUpdate::TopK { idx, .. } => 8 + 8 * idx.len(),
        }
    }

    /// Decoded (dense) length.
    pub fn d(&self) -> usize {
        match self {
            EncodedUpdate::F32 { data } => data.len(),
            EncodedUpdate::Int8 { d, .. } | EncodedUpdate::TopK { d, .. } => *d,
        }
    }

    pub fn scheme(&self) -> &'static str {
        match self {
            EncodedUpdate::F32 { .. } => "f32",
            EncodedUpdate::Int8 { .. } => "int8",
            EncodedUpdate::TopK { .. } => "topk",
        }
    }
}

/// An upload-path encode / aggregation-point decode pair.
pub trait Codec: Send + Sync {
    fn name(&self) -> &'static str;

    /// Encode one delta. `residual` is the caller-owned per-client
    /// error-feedback state — empty means "no residual yet"; codecs
    /// without error feedback leave it untouched.
    fn encode(&self, delta: &[f32], residual: &mut Vec<f32>) -> EncodedUpdate;

    /// Decode the carried delta and **add** it into `out` (`out += δ`),
    /// mirroring how the raw-float path axpy's the delta onto the base
    /// model. `out` must have the encoded dense length.
    fn decode_add(&self, enc: &EncodedUpdate, out: &mut [f32]) -> Result<()>;
}

fn check_len(enc: &EncodedUpdate, out: &[f32]) -> Result<()> {
    if enc.d() != out.len() {
        bail!(
            "encoded update carries {} parameters, decode target holds {}",
            enc.d(),
            out.len()
        );
    }
    Ok(())
}

/// Passthrough parity oracle: carries the raw delta.
pub struct F32Codec;

impl Codec for F32Codec {
    fn name(&self) -> &'static str {
        "f32"
    }

    fn encode(&self, delta: &[f32], _residual: &mut Vec<f32>) -> EncodedUpdate {
        EncodedUpdate::F32 { data: delta.to_vec() }
    }

    fn decode_add(&self, enc: &EncodedUpdate, out: &mut [f32]) -> Result<()> {
        check_len(enc, out)?;
        match enc {
            EncodedUpdate::F32 { data } => {
                crate::model::axpy(out, 1.0, data);
                Ok(())
            }
            other => bail!("f32 codec cannot decode a '{}' update", other.scheme()),
        }
    }
}

/// Linear int8 quantization: `scale = max|δ|/127`, symmetric range.
pub struct Int8Codec;

impl Codec for Int8Codec {
    fn name(&self) -> &'static str {
        "int8"
    }

    fn encode(&self, delta: &[f32], _residual: &mut Vec<f32>) -> EncodedUpdate {
        let max_abs = delta.iter().fold(0f32, |m, &v| m.max(v.abs()));
        let scale = max_abs / 127.0;
        let q = if scale == 0.0 {
            vec![0i8; delta.len()]
        } else {
            delta
                .iter()
                .map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8)
                .collect()
        };
        EncodedUpdate::Int8 { d: delta.len(), scale, q }
    }

    fn decode_add(&self, enc: &EncodedUpdate, out: &mut [f32]) -> Result<()> {
        check_len(enc, out)?;
        match enc {
            EncodedUpdate::Int8 { scale, q, .. } => {
                for (o, &qi) in out.iter_mut().zip(q) {
                    *o += qi as f32 * scale;
                }
                Ok(())
            }
            other => bail!("int8 codec cannot decode a '{}' update", other.scheme()),
        }
    }
}

/// Top-k magnitude sparsification with error feedback.
pub struct TopKCodec {
    frac: f64,
}

impl TopKCodec {
    /// `frac` is the kept fraction of coordinates, in `(0, 1]`.
    pub fn new(frac: f64) -> Result<Self> {
        if !(frac > 0.0 && frac <= 1.0) {
            bail!("topk_frac must be in (0, 1], got {frac}");
        }
        Ok(Self { frac })
    }

    pub fn k_for(&self, d: usize) -> usize {
        ((self.frac * d as f64).ceil() as usize).clamp(1, d.max(1))
    }
}

impl Codec for TopKCodec {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn encode(&self, delta: &[f32], residual: &mut Vec<f32>) -> EncodedUpdate {
        let d = delta.len();
        if residual.len() != d {
            residual.clear();
            residual.resize(d, 0.0);
        }
        // error-feedback correction: compress (delta + residual)
        let u: Vec<f32> = delta.iter().zip(residual.iter()).map(|(&a, &b)| a + b).collect();
        let k = self.k_for(d);
        let mut order: Vec<u32> = (0..d as u32).collect();
        // deterministic selection: |value| descending, index ascending
        order.sort_by(|&a, &b| {
            u[b as usize]
                .abs()
                .total_cmp(&u[a as usize].abs())
                .then(a.cmp(&b))
        });
        let mut idx: Vec<u32> = order[..k].to_vec();
        idx.sort_unstable();
        let val: Vec<f32> = idx.iter().map(|&i| u[i as usize]).collect();
        // what was dropped carries over; what was sent leaves the residual
        residual.copy_from_slice(&u);
        for &i in &idx {
            residual[i as usize] = 0.0;
        }
        EncodedUpdate::TopK { d, idx, val }
    }

    fn decode_add(&self, enc: &EncodedUpdate, out: &mut [f32]) -> Result<()> {
        check_len(enc, out)?;
        match enc {
            EncodedUpdate::TopK { idx, val, .. } => {
                for (&i, &v) in idx.iter().zip(val) {
                    out[i as usize] += v;
                }
                Ok(())
            }
            other => bail!("topk codec cannot decode a '{}' update", other.scheme()),
        }
    }
}

/// Build a codec from its TAG spec name (`hyper.codec`). `topk_frac`
/// parameterizes `"topk"` and is ignored otherwise.
pub fn build_codec(name: &str, topk_frac: f64) -> Result<Arc<dyn Codec>> {
    Ok(match name {
        "f32" => Arc::new(F32Codec),
        "int8" => Arc::new(Int8Codec),
        "topk" => Arc::new(TopKCodec::new(topk_frac)?),
        other => bail!("unknown codec '{other}' (expected f32 | int8 | topk)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::prng::Rng::new(seed);
        (0..d).map(|_| rng.normal() as f32 * 0.1).collect()
    }

    #[test]
    fn f32_roundtrip_is_exact_and_wire_matches_floats() {
        let u = delta(97, 1);
        let mut res = Vec::new();
        let enc = F32Codec.encode(&u, &mut res);
        assert!(res.is_empty(), "passthrough must not touch the residual");
        assert_eq!(enc.wire_bytes(), 4 * 97);
        let mut out = vec![0f32; 97];
        F32Codec.decode_add(&enc, &mut out).unwrap();
        assert_eq!(out, u);
    }

    #[test]
    fn int8_error_bounded_by_half_scale() {
        let u = delta(256, 2);
        let mut res = Vec::new();
        let enc = Int8Codec.encode(&u, &mut res);
        let scale = match &enc {
            EncodedUpdate::Int8 { scale, .. } => *scale,
            _ => unreachable!(),
        };
        assert!(enc.wire_bytes() < 4 * 256 / 3, "int8 must compress ≥3×");
        let mut out = vec![0f32; 256];
        Int8Codec.decode_add(&enc, &mut out).unwrap();
        for (a, b) in u.iter().zip(&out) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-7, "{a} vs {b} (scale {scale})");
        }
    }

    #[test]
    fn int8_zero_delta_encodes_cleanly() {
        let u = vec![0f32; 16];
        let enc = Int8Codec.encode(&u, &mut Vec::new());
        let mut out = vec![0f32; 16];
        Int8Codec.decode_add(&enc, &mut out).unwrap();
        assert_eq!(out, u);
    }

    #[test]
    fn topk_keeps_largest_and_banks_the_rest() {
        let u = vec![0.1, -5.0, 0.2, 3.0, -0.05, 0.0];
        let codec = TopKCodec::new(0.34).unwrap(); // k = ceil(2.04) = 3
        let mut res = Vec::new();
        let enc = codec.encode(&u, &mut res);
        match &enc {
            EncodedUpdate::TopK { idx, val, .. } => {
                assert_eq!(idx, &[1, 2, 3], "sorted index layout");
                assert_eq!(val, &[-5.0, 0.2, 3.0]);
            }
            _ => unreachable!(),
        }
        // residual holds exactly the dropped mass
        assert_eq!(res, vec![0.1, 0.0, 0.0, 0.0, -0.05, 0.0]);
        let mut out = vec![0f32; 6];
        codec.decode_add(&enc, &mut out).unwrap();
        for i in 0..6 {
            assert_eq!(out[i] + res[i], u[i], "EF conservation at {i}");
        }
    }

    #[test]
    fn topk_error_feedback_flushes_over_rounds() {
        // a coordinate too small to ever win a round on its own still gets
        // through once its banked residual outgrows the competition
        let codec = TopKCodec::new(0.25).unwrap(); // k=1 of d=4
        let mut res = Vec::new();
        let mut delivered = vec![0f32; 4];
        for _ in 0..8 {
            let u = vec![0.4, 0.3, 0.2, 0.1];
            let enc = codec.encode(&u, &mut res);
            codec.decode_add(&enc, &mut delivered).unwrap();
        }
        // total mass conservation: delivered + residual == Σ rounds
        for i in 0..4 {
            let sent = 8.0 * [0.4f32, 0.3, 0.2, 0.1][i];
            assert!((delivered[i] + res[i] - sent).abs() < 1e-5);
        }
        // every coordinate was eventually delivered at least once
        assert!(delivered.iter().all(|&v| v > 0.0), "{delivered:?}");
    }

    #[test]
    fn topk_tie_break_is_deterministic() {
        let u = vec![1.0f32, -1.0, 1.0, 0.5];
        let codec = TopKCodec::new(0.5).unwrap(); // k=2
        let enc = codec.encode(&u, &mut Vec::new());
        match enc {
            EncodedUpdate::TopK { idx, .. } => assert_eq!(idx, vec![0, 1]),
            _ => unreachable!(),
        }
    }

    #[test]
    fn build_codec_validates() {
        assert!(build_codec("f32", 0.0).is_ok());
        assert!(build_codec("int8", 0.0).is_ok());
        assert!(build_codec("topk", 0.01).is_ok());
        assert!(build_codec("topk", 0.0).is_err());
        assert!(build_codec("topk", 1.5).is_err());
        assert!(build_codec("gzip", 0.1).is_err());
    }

    #[test]
    fn wire_bytes_reflect_compression() {
        let d = 4096;
        let u = delta(d, 3);
        let f32b = F32Codec.encode(&u, &mut Vec::new()).wire_bytes();
        let i8b = Int8Codec.encode(&u, &mut Vec::new()).wire_bytes();
        let tkb = TopKCodec::new(0.01)
            .unwrap()
            .encode(&u, &mut Vec::new())
            .wire_bytes();
        assert_eq!(f32b, 4 * d);
        assert!(i8b * 3 < f32b, "int8 {i8b} vs {f32b}");
        assert!(tkb * 10 < f32b, "topk {tkb} vs {f32b}");
    }

    #[test]
    fn cross_scheme_decode_is_rejected() {
        let enc = Int8Codec.encode(&[1.0, 2.0], &mut Vec::new());
        assert!(F32Codec.decode_add(&enc, &mut [0.0, 0.0]).is_err());
        let mut short = [0f32; 1];
        assert!(Int8Codec.decode_add(&enc, &mut short).is_err());
    }
}
