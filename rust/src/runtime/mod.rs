//! Runtime layer: executing the AOT-lowered JAX/Pallas artifacts from Rust.
//!
//! Python runs once (`make artifacts`); afterwards this module is the only
//! place numerics happen. It exposes the [`Compute`] trait — the exact set
//! of entry points lowered by `python/compile/aot.py` — with two
//! implementations:
//!
//! * [`pjrt::PjrtPool`] — the real thing: a pool of service threads, each
//!   owning a `PjRtClient` (the `xla` crate's client is `Rc`-based and not
//!   `Send`, so executables cannot cross threads) and the compiled
//!   executables for every entry point; worker threads submit requests over
//!   an mpsc queue.
//! * [`mock::MockCompute`] — a pure-Rust logistic-regression stand-in with
//!   the same trait, so the entire coordination stack is testable without
//!   artifacts (and so coordinator tests stay fast).
//!
//! [`aggregate_any`] folds arbitrarily many client updates through the
//! fixed-`K` Pallas aggregation entry point (weighted sums are associative).

pub mod mock;
pub mod pjrt;
pub mod spec;

use anyhow::Result;

pub use mock::MockCompute;
pub use pjrt::PjrtPool;
pub use spec::ArtifactSpec;

use crate::net::VTime;

/// The L2 entry points, as seen from the coordinator.
///
/// All vectors are flat `f32` model parameters of length `d_pad()`;
/// `x`/`y` are one fixed-size batch (`batch()` rows).
pub trait Compute: Send + Sync {
    fn d_pad(&self) -> usize;
    fn batch(&self) -> usize;
    /// Max rows per aggregation call (the Pallas kernel's K).
    fn agg_k(&self) -> usize;

    /// One SGD step: returns `(new_flat, mean_loss)`.
    fn train_step(&self, flat: &[f32], x: &[f32], y: &[i32], lr: f32)
        -> Result<(Vec<f32>, f32)>;

    /// FedProx step with proximal pull toward `gflat`.
    fn train_step_prox(
        &self,
        flat: &[f32],
        gflat: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        mu: f32,
    ) -> Result<(Vec<f32>, f32)>;

    /// FedDyn step with drift state `h`; returns `(new_flat, new_h, loss)`.
    fn train_step_dyn(
        &self,
        flat: &[f32],
        gflat: &[f32],
        h: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        alpha: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)>;

    /// Bare batch gradient: `(grad, loss)`.
    fn grad_step(&self, flat: &[f32], x: &[f32], y: &[i32]) -> Result<(Vec<f32>, f32)>;

    /// Eval over one batch: `(sum_loss, num_correct)`.
    fn eval_step(&self, flat: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, f32)>;

    /// Weighted sum of up to `agg_k()` updates (the Pallas kernel).
    fn aggregate_k(&self, updates: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>>;
}

/// Aggregate arbitrarily many updates by folding through `aggregate_k` in
/// chunks (weighted sums are associative; callers pass final weights).
pub fn aggregate_any(c: &dyn Compute, updates: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>> {
    assert_eq!(updates.len(), weights.len());
    assert!(!updates.is_empty());
    let k = c.agg_k();
    let mut total: Option<Vec<f32>> = None;
    for (chunk_u, chunk_w) in updates.chunks(k).zip(weights.chunks(k)) {
        let part = c.aggregate_k(chunk_u, chunk_w)?;
        total = Some(match total {
            None => part,
            Some(mut acc) => {
                crate::model::axpy(&mut acc, 1.0, &part);
                acc
            }
        });
    }
    Ok(total.unwrap())
}

/// Evaluate `flat` over a whole dataset (looping fixed-size batches);
/// returns `(mean_loss, accuracy)`.
pub fn evaluate(
    c: &dyn Compute,
    flat: &[f32],
    ds: &crate::data::Dataset,
) -> Result<(f64, f64)> {
    let b = c.batch();
    let n_batches = ds.len() / b;
    assert!(n_batches > 0, "eval set smaller than one batch");
    let mut loss = 0.0;
    let mut correct = 0.0;
    for i in 0..n_batches {
        let idx: Vec<usize> = (i * b..(i + 1) * b).collect();
        let (x, y) = ds.gather_batch(&idx, b);
        let (l, cr) = c.eval_step(flat, &x, &y)?;
        loss += l as f64;
        correct += cr as f64;
    }
    let n = (n_batches * b) as f64;
    Ok((loss / n, correct / n))
}

/// How a worker charges local compute against its virtual clock.
#[derive(Debug, Clone, Copy)]
pub enum ComputeTimeModel {
    /// Charge measured wall time of the runtime call.
    Measured,
    /// Charge a fixed virtual cost per training step (deterministic sims).
    FixedPerStep(VTime),
    /// Charge nothing (pure-communication studies).
    Free,
}

impl ComputeTimeModel {
    pub fn charge(&self, measured_us: u128) -> VTime {
        match self {
            ComputeTimeModel::Measured => measured_us as VTime,
            ComputeTimeModel::FixedPerStep(v) => *v,
            ComputeTimeModel::Free => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{make_federated, Partition};

    #[test]
    fn aggregate_any_chunks_match_direct_sum() {
        let c = MockCompute::new(64, 8, 4); // d_pad 64, batch 8, agg_k 4
        let rows: Vec<Vec<f32>> = (0..10)
            .map(|i| (0..64).map(|j| (i * j) as f32 * 0.01).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let w: Vec<f32> = (0..10).map(|i| (i + 1) as f32 * 0.1).collect();
        let got = aggregate_any(&c, &refs, &w).unwrap();
        let want = crate::model::weighted_sum(&refs, &w);
        for (g, w_) in got.iter().zip(&want) {
            assert!((g - w_).abs() < 1e-4);
        }
    }

    #[test]
    fn evaluate_over_dataset() {
        let c = MockCompute::default_mlp();
        let (_, test) = make_federated(1, 1, 32, 96, Partition::Iid, 0.3);
        let flat = vec![0f32; c.d_pad()];
        let (loss, acc) = evaluate(&c, &flat, &test).unwrap();
        // zero weights -> uniform prediction: loss = ln 10, acc ~ 10%
        assert!((loss - (10f64).ln()).abs() < 1e-3, "loss={loss}");
        assert!((0.0..=0.35).contains(&acc));
    }

    #[test]
    fn compute_time_models() {
        assert_eq!(ComputeTimeModel::Measured.charge(123), 123);
        assert_eq!(ComputeTimeModel::FixedPerStep(500).charge(123), 500);
        assert_eq!(ComputeTimeModel::Free.charge(123), 0);
    }
}
