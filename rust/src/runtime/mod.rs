//! Runtime layer: executing the AOT-lowered JAX/Pallas artifacts from Rust.
//!
//! Python runs once (`make artifacts`); afterwards this module is the only
//! place numerics happen. It exposes the [`Compute`] trait — the exact set
//! of entry points lowered by `python/compile/aot.py` — with two
//! implementations:
//!
//! * [`pjrt::PjrtPool`] — the real thing: a pool of service threads, each
//!   owning a `PjRtClient` (the `xla` crate's client is `Rc`-based and not
//!   `Send`, so executables cannot cross threads) and the compiled
//!   executables for every entry point; worker threads submit requests over
//!   an mpsc queue.
//! * [`mock::MockCompute`] — a pure-Rust logistic-regression stand-in with
//!   the same trait, so the entire coordination stack is testable without
//!   artifacts (and so coordinator tests stay fast).
//!
//! [`aggregate_any`] folds arbitrarily many client updates through the
//! fixed-`K` Pallas aggregation entry point (weighted sums are associative).
//!
//! ## Streaming aggregation ([`Accumulator`])
//!
//! The collect-then-aggregate pattern retained every child's update until
//! round end — unconditionally O(children · d) peak memory at the
//! aggregation points. The [`Accumulator`] replaces it: updates fold into
//! a single O(d) buffer *as they arrive* and their buffers return to the
//! job's [`TensorPool`] immediately after folding. (Out-of-order arrivals
//! stage as `Arc` clones until their fold slot is reached, so worst-case
//! retention — a straggling lexicographically-early sender — matches the
//! old buffered collect; the steady state folds eagerly.)
//!
//! Determinism is the hard part. Arrival *consumption* order depends on
//! runner-pool interleaving, so folding in consumption order would break
//! the byte-identical executor-parity guarantee. The accumulator therefore
//! folds in **sorted expected-sender order** via a cursor: an update whose
//! sender is next in sorted order folds (and frees its buffer) on arrival;
//! out-of-order arrivals stage as pointer-sized `Arc` clones until the gap
//! fills. The fold sequence — and the fold-order total weight — is thus a
//! pure function of the round's update *set*, never of scheduling. The
//! result equals `scale(model::weighted_sum(rows, raw_weights), 1/Σw)`
//! bit-for-bit on any chunk-uniform [`Compute::aggregate_into`]
//! implementation (the mock's sequential fold; verified in
//! `rust/tests/streaming_parity.rs` against `model::weighted_sum` as the
//! oracle).

pub mod codec;
pub mod mock;
pub mod pjrt;
pub mod pool;
pub mod simd;
pub mod spec;

use std::sync::Arc;

use anyhow::{bail, Result};

pub use codec::{Codec, EncodedUpdate};
pub use mock::MockCompute;
pub use pjrt::PjrtPool;
pub use pool::TensorPool;
pub use simd::{SimdCompute, SimdKernel};
pub use spec::ArtifactSpec;

use crate::net::VTime;

/// The L2 entry points, as seen from the coordinator.
///
/// All vectors are flat `f32` model parameters of length `d_pad()`;
/// `x`/`y` are one fixed-size batch (`batch()` rows).
pub trait Compute: Send + Sync {
    fn d_pad(&self) -> usize;
    fn batch(&self) -> usize;
    /// Max rows per aggregation call (the Pallas kernel's K).
    fn agg_k(&self) -> usize;

    /// One SGD step: returns `(new_flat, mean_loss)`.
    fn train_step(&self, flat: &[f32], x: &[f32], y: &[i32], lr: f32)
        -> Result<(Vec<f32>, f32)>;

    /// FedProx step with proximal pull toward `gflat`.
    fn train_step_prox(
        &self,
        flat: &[f32],
        gflat: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        mu: f32,
    ) -> Result<(Vec<f32>, f32)>;

    /// FedDyn step with drift state `h`; returns `(new_flat, new_h, loss)`.
    fn train_step_dyn(
        &self,
        flat: &[f32],
        gflat: &[f32],
        h: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        alpha: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)>;

    /// Bare batch gradient: `(grad, loss)`.
    fn grad_step(&self, flat: &[f32], x: &[f32], y: &[i32]) -> Result<(Vec<f32>, f32)>;

    /// Eval over one batch: `(sum_loss, num_correct)`.
    fn eval_step(&self, flat: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, f32)>;

    /// Weighted sum of up to `agg_k()` updates (the Pallas kernel).
    fn aggregate_k(&self, updates: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>>;

    /// Fold up to `agg_k()` updates **into** `acc`: `acc += Σ wᵢ·uᵢ`.
    ///
    /// The default routes through [`Self::aggregate_k`] and adds the
    /// partial (one temporary per chunk — what a fixed-K kernel can do).
    /// Implementations that can fold row-sequentially (the mock) override
    /// this so the result is bit-identical to [`crate::model::weighted_sum`]
    /// regardless of chunk boundaries — the property the streaming
    /// [`Accumulator`] parity tests pin down.
    fn aggregate_into(&self, acc: &mut [f32], updates: &[&[f32]], weights: &[f32]) -> Result<()> {
        let part = self.aggregate_k(updates, weights)?;
        crate::model::axpy(acc, 1.0, &part);
        Ok(())
    }
}

/// Aggregate arbitrarily many updates by folding `agg_k`-sized chunks into
/// one O(d) output buffer (weighted sums are associative; callers pass
/// final weights). No per-chunk partial vector is allocated on
/// chunk-uniform [`Compute::aggregate_into`] implementations.
pub fn aggregate_any(c: &dyn Compute, updates: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>> {
    assert_eq!(updates.len(), weights.len());
    assert!(!updates.is_empty());
    let k = c.agg_k();
    let mut total = vec![0f32; updates[0].len()];
    for (chunk_u, chunk_w) in updates.chunks(k).zip(weights.chunks(k)) {
        c.aggregate_into(&mut total, chunk_u, chunk_w)?;
    }
    Ok(total)
}

// ------------------------------------------------------- streaming fold

/// Result of draining an [`Accumulator`].
pub struct Aggregate {
    /// The weighted mean `Σ wᵢ·uᵢ / Σ wᵢ`, uniquely owned (taken from the
    /// pool). `None` when nothing was folded or the total weight is zero —
    /// the caller keeps its current model.
    pub mean: Option<Arc<Vec<f32>>>,
    /// Total weight, summed in deterministic fold order.
    pub total_weight: f64,
    /// Number of updates folded.
    pub count: usize,
}

/// Streaming, order-deterministic weighted-mean accumulator (see the
/// module docs for the design and its determinism argument).
///
/// Usage: create at round start with the round's expected sender set,
/// [`push`](Self::push) each `(sender, update, weight)` as it is received
/// (re-entrant across cooperative yields when held in the role context),
/// then [`finish`](Self::finish) once the quorum target is met.
pub struct Accumulator {
    compute: Arc<dyn Compute>,
    pool: Arc<TensorPool>,
    /// The O(d) fold target, uniquely owned.
    acc: Arc<Vec<f32>>,
    /// Sorted, deduplicated expected senders; slot i belongs to
    /// `expected[i]`.
    expected: Vec<String>,
    /// Out-of-order arrivals parked until the cursor reaches their slot.
    staged: Vec<Option<(Arc<Vec<f32>>, f64)>>,
    /// Next expected slot to fold.
    cursor: usize,
    /// Updates from senders outside the expected set (late churn races);
    /// folded after the expected ones, in sorted sender order.
    spill: Vec<(String, Arc<Vec<f32>>, f64)>,
    /// Pending chunk for the next `aggregate_into` call (≤ agg_k rows).
    chunk_u: Vec<Arc<Vec<f32>>>,
    chunk_w: Vec<f32>,
    /// Total weight in fold order (deterministic).
    total: f64,
    /// Updates accepted so far (staged + folded + spilled).
    count: usize,
}

impl Accumulator {
    pub fn new(
        compute: Arc<dyn Compute>,
        pool: Arc<TensorPool>,
        mut expected: Vec<String>,
    ) -> Self {
        expected.sort();
        expected.dedup();
        let n = expected.len();
        Self {
            acc: pool.take_zeroed(),
            compute,
            pool,
            expected,
            staged: (0..n).map(|_| None).collect(),
            cursor: 0,
            spill: Vec::new(),
            chunk_u: Vec::new(),
            chunk_w: Vec::new(),
            total: 0.0,
            count: 0,
        }
    }

    /// Updates accepted so far — the quorum-target comparand.
    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Accept one update. In-order arrivals fold immediately (their buffer
    /// returns to the pool); out-of-order ones stage as `Arc` clones.
    pub fn push(&mut self, sender: &str, update: Arc<Vec<f32>>, weight: f64) -> Result<()> {
        if update.len() != self.acc.len() {
            bail!(
                "update from '{sender}' has {} parameters, accumulator holds {}",
                update.len(),
                self.acc.len()
            );
        }
        match self.expected.binary_search_by(|e| e.as_str().cmp(sender)) {
            Ok(i) => {
                if self.staged[i].is_some() || i < self.cursor {
                    bail!("duplicate update from '{sender}' within one round");
                }
                self.staged[i] = Some((update, weight));
                self.advance()?;
            }
            Err(_) => self.spill.push((sender.to_string(), update, weight)),
        }
        self.count += 1;
        Ok(())
    }

    /// Fold the contiguous staged prefix at the cursor.
    fn advance(&mut self) -> Result<()> {
        while self.cursor < self.staged.len() {
            match self.staged[self.cursor].take() {
                Some(pair) => {
                    self.cursor += 1;
                    self.stage_fold(pair)?;
                }
                None => break,
            }
        }
        Ok(())
    }

    fn stage_fold(&mut self, (update, weight): (Arc<Vec<f32>>, f64)) -> Result<()> {
        self.total += weight;
        self.chunk_u.push(update);
        self.chunk_w.push(weight as f32);
        if self.chunk_u.len() >= self.compute.agg_k() {
            self.flush()?;
        }
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        if self.chunk_u.is_empty() {
            return Ok(());
        }
        let acc = Arc::get_mut(&mut self.acc).expect("accumulator buffer is uniquely owned");
        {
            let refs: Vec<&[f32]> = self.chunk_u.iter().map(|u| u.as_slice()).collect();
            self.compute.aggregate_into(acc, &refs, &self.chunk_w)?;
        }
        for u in self.chunk_u.drain(..) {
            self.pool.reclaim(u);
        }
        self.chunk_w.clear();
        Ok(())
    }

    /// Fold whatever is still staged (gaps left by departed senders are
    /// skipped), then the spillover in sorted sender order, scale by the
    /// inverse total weight, and hand the mean back.
    pub fn finish(mut self) -> Result<Aggregate> {
        for i in self.cursor..self.staged.len() {
            if let Some(pair) = self.staged[i].take() {
                self.stage_fold(pair)?;
            }
        }
        self.spill.sort_by(|a, b| a.0.cmp(&b.0));
        for (_, u, w) in std::mem::take(&mut self.spill) {
            self.stage_fold((u, w))?;
        }
        self.flush()?;
        if self.count == 0 || self.total <= 0.0 {
            self.pool.reclaim(self.acc);
            return Ok(Aggregate {
                mean: None,
                total_weight: self.total,
                count: self.count,
            });
        }
        let inv = (1.0 / self.total) as f32;
        crate::model::scale(
            Arc::get_mut(&mut self.acc).expect("accumulator buffer is uniquely owned"),
            inv,
        );
        Ok(Aggregate {
            mean: Some(self.acc),
            total_weight: self.total,
            count: self.count,
        })
    }
}

/// Evaluate `flat` over a whole dataset (looping fixed-size batches);
/// returns `(mean_loss, accuracy)`.
pub fn evaluate(
    c: &dyn Compute,
    flat: &[f32],
    ds: &crate::data::Dataset,
) -> Result<(f64, f64)> {
    let b = c.batch();
    let n_batches = ds.len() / b;
    assert!(n_batches > 0, "eval set smaller than one batch");
    let mut loss = 0.0;
    let mut correct = 0.0;
    for i in 0..n_batches {
        let idx: Vec<usize> = (i * b..(i + 1) * b).collect();
        let (x, y) = ds.gather_batch(&idx, b);
        let (l, cr) = c.eval_step(flat, &x, &y)?;
        loss += l as f64;
        correct += cr as f64;
    }
    let n = (n_batches * b) as f64;
    Ok((loss / n, correct / n))
}

/// How a worker charges local compute against its virtual clock.
#[derive(Debug, Clone, Copy)]
pub enum ComputeTimeModel {
    /// Charge measured wall time of the runtime call.
    Measured,
    /// Charge a fixed virtual cost per training step (deterministic sims).
    FixedPerStep(VTime),
    /// Charge nothing (pure-communication studies).
    Free,
}

impl ComputeTimeModel {
    pub fn charge(&self, measured_us: u128) -> VTime {
        match self {
            ComputeTimeModel::Measured => measured_us as VTime,
            ComputeTimeModel::FixedPerStep(v) => *v,
            ComputeTimeModel::Free => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{make_federated, Partition};
    use crate::model::weighted_sum;

    #[test]
    fn aggregate_any_chunks_match_direct_sum() {
        let c = MockCompute::new(64, 8, 4); // d_pad 64, batch 8, agg_k 4
        let rows: Vec<Vec<f32>> = (0..10)
            .map(|i| (0..64).map(|j| (i * j) as f32 * 0.01).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let w: Vec<f32> = (0..10).map(|i| (i + 1) as f32 * 0.1).collect();
        let got = aggregate_any(&c, &refs, &w).unwrap();
        let want = weighted_sum(&refs, &w);
        // the mock's sequential fold makes chunking invisible: exact match
        assert_eq!(got, want);
    }

    fn rows(k: usize, d: usize) -> Vec<Vec<f32>> {
        (0..k)
            .map(|i| (0..d).map(|j| ((i * 31 + j * 7) % 13) as f32 * 0.125 - 0.75).collect())
            .collect()
    }

    #[test]
    fn accumulator_matches_oracle_any_push_order() {
        let d = 48;
        let k = 7;
        let rows = rows(k, d);
        let weights: Vec<f64> = (0..k).map(|i| (i + 1) as f64).collect();
        let senders: Vec<String> = (0..k).map(|i| format!("t{i}")).collect();
        // oracle: weighted_sum in sorted sender order, then scale
        let order: Vec<usize> = {
            let mut idx: Vec<usize> = (0..k).collect();
            idx.sort_by(|&a, &b| senders[a].cmp(&senders[b]));
            idx
        };
        let refs: Vec<&[f32]> = order.iter().map(|&i| rows[i].as_slice()).collect();
        let ws: Vec<f32> = order.iter().map(|&i| weights[i] as f32).collect();
        let total: f64 = order.iter().map(|&i| weights[i]).sum();
        let mut want = weighted_sum(&refs, &ws);
        crate::model::scale(&mut want, (1.0 / total) as f32);

        let compute: Arc<dyn Compute> = Arc::new(MockCompute::new(d, 8, 3));
        let pool = TensorPool::new(d);
        // several adversarial push orders must all give the oracle, byte
        // for byte
        let orders: Vec<Vec<usize>> = vec![
            (0..k).collect(),
            (0..k).rev().collect(),
            vec![3, 0, 6, 1, 5, 2, 4],
        ];
        for ord in orders {
            let mut acc =
                Accumulator::new(compute.clone(), pool.clone(), senders.clone());
            for &i in &ord {
                acc.push(&senders[i], Arc::new(rows[i].clone()), weights[i]).unwrap();
            }
            let out = acc.finish().unwrap();
            assert_eq!(out.count, k);
            assert_eq!(out.total_weight, total);
            assert_eq!(**out.mean.unwrap(), want, "order {ord:?} diverged");
        }
    }

    #[test]
    fn accumulator_handles_gaps_and_spill() {
        let d = 16;
        let compute: Arc<dyn Compute> = Arc::new(MockCompute::new(d, 8, 4));
        let pool = TensorPool::new(d);
        let expected = vec!["a".to_string(), "b".into(), "c".into()];
        let mut acc = Accumulator::new(compute.clone(), pool.clone(), expected);
        // "b" never arrives (departed); "z" is an unexpected late joiner
        acc.push("c", Arc::new(vec![1.0; d]), 1.0).unwrap();
        acc.push("z", Arc::new(vec![3.0; d]), 1.0).unwrap();
        acc.push("a", Arc::new(vec![2.0; d]), 2.0).unwrap();
        let out = acc.finish().unwrap();
        assert_eq!(out.count, 3);
        assert_eq!(out.total_weight, 4.0);
        // (2*2 + 1*1 + 1*3) / 4 = 2.0 per coordinate
        assert_eq!(**out.mean.unwrap(), vec![2.0; d]);
    }

    #[test]
    fn accumulator_zero_weight_keeps_no_mean() {
        let d = 8;
        let compute: Arc<dyn Compute> = Arc::new(MockCompute::new(d, 8, 4));
        let pool = TensorPool::new(d);
        let empty = Accumulator::new(compute.clone(), pool.clone(), vec!["a".into()]);
        let out = empty.finish().unwrap();
        assert!(out.mean.is_none());
        assert_eq!(out.count, 0);
        let mut zero = Accumulator::new(compute, pool, vec!["a".into()]);
        zero.push("a", Arc::new(vec![1.0; d]), 0.0).unwrap();
        assert!(zero.finish().unwrap().mean.is_none());
    }

    #[test]
    fn accumulator_rejects_duplicates_and_bad_dims() {
        let d = 8;
        let compute: Arc<dyn Compute> = Arc::new(MockCompute::new(d, 8, 4));
        let pool = TensorPool::new(d);
        let mut acc = Accumulator::new(compute, pool, vec!["a".into(), "b".into()]);
        acc.push("a", Arc::new(vec![0.0; d]), 1.0).unwrap();
        assert!(acc.push("a", Arc::new(vec![0.0; d]), 1.0).is_err());
        assert!(acc.push("b", Arc::new(vec![0.0; d + 1]), 1.0).is_err());
    }

    #[test]
    fn accumulator_recycles_buffers_through_the_pool() {
        let d = 8;
        let compute: Arc<dyn Compute> = Arc::new(MockCompute::new(d, 8, 2));
        let pool = TensorPool::new(d);
        let senders = vec!["a".to_string(), "b".into(), "c".into(), "d".into()];
        let mut acc = Accumulator::new(compute, pool.clone(), senders.clone());
        for s in &senders {
            acc.push(s, Arc::new(vec![1.0; d]), 1.0).unwrap();
        }
        let out = acc.finish().unwrap();
        pool.reclaim(out.mean.unwrap());
        let (_, _, recycled) = pool.stats();
        // 4 update buffers + the mean came back
        assert_eq!(recycled, 5);
    }

    #[test]
    fn evaluate_over_dataset() {
        let c = MockCompute::default_mlp();
        let (_, test) = make_federated(1, 1, 32, 96, Partition::Iid, 0.3);
        let flat = vec![0f32; c.d_pad()];
        let (loss, acc) = evaluate(&c, &flat, &test).unwrap();
        // zero weights -> uniform prediction: loss = ln 10, acc ~ 10%
        assert!((loss - (10f64).ln()).abs() < 1e-3, "loss={loss}");
        assert!((0.0..=0.35).contains(&acc));
    }

    #[test]
    fn compute_time_models() {
        assert_eq!(ComputeTimeModel::Measured.charge(123), 123);
        assert_eq!(ComputeTimeModel::FixedPerStep(500).charge(123), 500);
        assert_eq!(ComputeTimeModel::Free.charge(123), 0);
    }
}
