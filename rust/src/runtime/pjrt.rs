//! PJRT-backed [`Compute`]: a pool of service threads, each owning a CPU
//! PJRT client and the compiled executables for one model's entry points.
//!
//! The `xla` crate's `PjRtClient` wraps an `Rc`, so clients and executables
//! cannot move between threads. Worker threads therefore submit requests to
//! a shared mpsc queue; each service thread loops `recv -> execute -> reply`
//! on its own client. Compilation happens once per service thread at pool
//! construction (the executable cache), never on the request path.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`), matching
//! `aot.py` — see /opt/xla-example/README.md for why serialized protos fail
//! against xla_extension 0.5.1.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::spec::{ArtifactSpec, EntryInfo};
use super::Compute;

/// One request argument (host-side).
enum Arg {
    F32s(Vec<f32>),
    I32s(Vec<i32>),
    Scalar(f32),
}

/// One result value (host-side).
#[derive(Debug)]
enum Out {
    F32s(Vec<f32>),
    Scalar(f32),
}

struct Req {
    entry: String,
    args: Vec<Arg>,
    reply: SyncSender<Result<Vec<Out>>>,
}

/// Pool of PJRT service threads implementing [`Compute`] for one model.
pub struct PjrtPool {
    tx: Sender<Req>,
    d_pad: usize,
    batch: usize,
    agg_k: usize,
    calls: AtomicU64,
    exec_us: AtomicU64,
}

impl PjrtPool {
    /// Load `model` from the artifact directory with `threads` service
    /// threads. Each thread compiles every entry point on its own client.
    pub fn load(spec: &ArtifactSpec, model: &str, threads: usize) -> Result<Arc<Self>> {
        assert!(threads >= 1);
        let m = spec.model(model)?;
        let entries: Vec<(String, String, EntryInfo)> = m
            .entries
            .iter()
            .map(|(name, e)| {
                (
                    name.clone(),
                    spec.dir.join(&e.file).to_string_lossy().into_owned(),
                    e.clone(),
                )
            })
            .collect();

        let (tx, rx) = mpsc::channel::<Req>();
        let rx = Arc::new(Mutex::new(rx));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for t in 0..threads {
            let rx = rx.clone();
            let entries = entries.clone();
            let ready = ready_tx.clone();
            std::thread::Builder::new()
                .name(format!("pjrt-{t}"))
                .spawn(move || service_thread(rx, entries, ready))
                .expect("spawn pjrt service thread");
        }
        drop(ready_tx);
        for _ in 0..threads {
            ready_rx
                .recv()
                .context("pjrt service thread died during startup")??;
        }
        Ok(Arc::new(Self {
            tx,
            d_pad: m.spec.d_pad,
            batch: spec.batch,
            agg_k: spec.agg_k,
            calls: AtomicU64::new(0),
            exec_us: AtomicU64::new(0),
        }))
    }

    /// Convenience: load from the default artifacts dir.
    pub fn load_default(model: &str, threads: usize) -> Result<Arc<Self>> {
        let spec = ArtifactSpec::load(ArtifactSpec::default_dir())?;
        Self::load(&spec, model, threads)
    }

    fn call(&self, entry: &str, args: Vec<Arg>) -> Result<Vec<Out>> {
        let t0 = Instant::now();
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.tx
            .send(Req {
                entry: entry.to_string(),
                args,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("pjrt pool is shut down"))?;
        let out = reply_rx
            .recv()
            .map_err(|_| anyhow!("pjrt service thread dropped the request"))??;
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.exec_us
            .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        Ok(out)
    }

    /// (total calls, total microseconds) spent in runtime execution.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.calls.load(Ordering::Relaxed),
            self.exec_us.load(Ordering::Relaxed),
        )
    }

    fn floats(out: Out) -> Result<Vec<f32>> {
        match out {
            Out::F32s(v) => Ok(v),
            Out::Scalar(s) => Ok(vec![s]),
        }
    }

    fn scalar(out: Out) -> Result<f32> {
        match out {
            Out::Scalar(s) => Ok(s),
            Out::F32s(v) if v.len() == 1 => Ok(v[0]),
            Out::F32s(v) => bail!("expected scalar, got vector of {}", v.len()),
        }
    }
}

fn service_thread(
    rx: Arc<Mutex<Receiver<Req>>>,
    entries: Vec<(String, String, EntryInfo)>,
    ready: Sender<Result<()>>,
) {
    // Build client + compile all entries; report readiness.
    let built = (|| -> Result<_> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut exes = std::collections::HashMap::new();
        for (name, path, info) in &entries {
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parse HLO text {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compile entry '{name}'"))?;
            exes.insert(name.clone(), (exe, info.clone()));
        }
        Ok(exes)
    })();
    let exes = match built {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    loop {
        let req = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(r) => r,
                Err(_) => return, // pool dropped
            }
        };
        let result = execute_one(&exes, &req);
        let _ = req.reply.send(result);
    }
}

fn execute_one(
    exes: &std::collections::HashMap<String, (xla::PjRtLoadedExecutable, EntryInfo)>,
    req: &Req,
) -> Result<Vec<Out>> {
    let (exe, info) = exes
        .get(&req.entry)
        .with_context(|| format!("unknown entry '{}'", req.entry))?;
    if req.args.len() != info.input_shapes.len() {
        bail!(
            "entry '{}' expects {} inputs, got {}",
            req.entry,
            info.input_shapes.len(),
            req.args.len()
        );
    }
    let mut literals = Vec::with_capacity(req.args.len());
    for (arg, shape) in req.args.iter().zip(&info.input_shapes) {
        let lit = match arg {
            Arg::Scalar(s) => xla::Literal::scalar(*s),
            Arg::F32s(v) => {
                let expected: usize = shape.iter().product();
                if v.len() != expected {
                    bail!(
                        "entry '{}': f32 input length {} != shape {:?}",
                        req.entry,
                        v.len(),
                        shape
                    );
                }
                if shape.len() > 1 {
                    // one host copy straight into the shaped literal —
                    // `vec1(..).reshape(..)` would copy twice (§Perf L3 #1)
                    let bytes = unsafe {
                        std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
                    };
                    xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::F32,
                        shape,
                        bytes,
                    )?
                } else {
                    xla::Literal::vec1(v)
                }
            }
            Arg::I32s(v) => xla::Literal::vec1(v),
        };
        literals.push(lit);
    }
    let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
    // aot.py lowers with return_tuple=True: the single output is a tuple.
    let parts = result.to_tuple()?;
    let mut outs = Vec::with_capacity(parts.len());
    for p in parts {
        let n = p.element_count();
        if n == 1 {
            outs.push(Out::Scalar(p.get_first_element::<f32>()?));
        } else {
            outs.push(Out::F32s(p.to_vec::<f32>()?));
        }
    }
    Ok(outs)
}

impl Compute for PjrtPool {
    fn d_pad(&self) -> usize {
        self.d_pad
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn agg_k(&self) -> usize {
        self.agg_k
    }

    fn train_step(&self, flat: &[f32], x: &[f32], y: &[i32], lr: f32) -> Result<(Vec<f32>, f32)> {
        let mut out = self.call(
            "train_step",
            vec![
                Arg::F32s(flat.to_vec()),
                Arg::F32s(x.to_vec()),
                Arg::I32s(y.to_vec()),
                Arg::Scalar(lr),
            ],
        )?;
        let loss = Self::scalar(out.pop().unwrap())?;
        let new_flat = Self::floats(out.pop().unwrap())?;
        Ok((new_flat, loss))
    }

    fn train_step_prox(
        &self,
        flat: &[f32],
        gflat: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        mu: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let mut out = self.call(
            "train_step_prox",
            vec![
                Arg::F32s(flat.to_vec()),
                Arg::F32s(gflat.to_vec()),
                Arg::F32s(x.to_vec()),
                Arg::I32s(y.to_vec()),
                Arg::Scalar(lr),
                Arg::Scalar(mu),
            ],
        )?;
        let loss = Self::scalar(out.pop().unwrap())?;
        let new_flat = Self::floats(out.pop().unwrap())?;
        Ok((new_flat, loss))
    }

    fn train_step_dyn(
        &self,
        flat: &[f32],
        gflat: &[f32],
        h: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        alpha: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let mut out = self.call(
            "train_step_dyn",
            vec![
                Arg::F32s(flat.to_vec()),
                Arg::F32s(gflat.to_vec()),
                Arg::F32s(h.to_vec()),
                Arg::F32s(x.to_vec()),
                Arg::I32s(y.to_vec()),
                Arg::Scalar(lr),
                Arg::Scalar(alpha),
            ],
        )?;
        let loss = Self::scalar(out.pop().unwrap())?;
        let new_h = Self::floats(out.pop().unwrap())?;
        let new_flat = Self::floats(out.pop().unwrap())?;
        Ok((new_flat, new_h, loss))
    }

    fn grad_step(&self, flat: &[f32], x: &[f32], y: &[i32]) -> Result<(Vec<f32>, f32)> {
        let mut out = self.call(
            "grad_step",
            vec![
                Arg::F32s(flat.to_vec()),
                Arg::F32s(x.to_vec()),
                Arg::I32s(y.to_vec()),
            ],
        )?;
        let loss = Self::scalar(out.pop().unwrap())?;
        let grad = Self::floats(out.pop().unwrap())?;
        Ok((grad, loss))
    }

    fn eval_step(&self, flat: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let mut out = self.call(
            "eval_step",
            vec![
                Arg::F32s(flat.to_vec()),
                Arg::F32s(x.to_vec()),
                Arg::I32s(y.to_vec()),
            ],
        )?;
        let correct = Self::scalar(out.pop().unwrap())?;
        let sum_loss = Self::scalar(out.pop().unwrap())?;
        Ok((sum_loss, correct))
    }

    fn aggregate_k(&self, updates: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(updates.len(), weights.len());
        assert!(!updates.is_empty() && updates.len() <= self.agg_k);
        // Pack [K, D] with zero-weight padding rows (free: w=0). Built by
        // appending (no zero-init pass over 15 MB — §Perf L3 #2).
        let mut stacked = Vec::with_capacity(self.agg_k * self.d_pad);
        let mut w = vec![0f32; self.agg_k];
        for (i, (u, wi)) in updates.iter().zip(weights).enumerate() {
            assert_eq!(u.len(), self.d_pad);
            stacked.extend_from_slice(u);
            w[i] = *wi;
        }
        stacked.resize(self.agg_k * self.d_pad, 0.0);
        let mut out = self.call("aggregate", vec![Arg::F32s(stacked), Arg::F32s(w)])?;
        Self::floats(out.pop().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{make_federated, Partition};

    fn pool() -> Option<Arc<PjrtPool>> {
        if !ArtifactSpec::available() {
            eprintln!("skipping: artifacts/ not built");
            return None;
        }
        Some(PjrtPool::load_default("mlp", 1).unwrap())
    }

    #[test]
    fn train_step_reduces_loss_on_fixed_batch() {
        let Some(p) = pool() else { return };
        let spec = ArtifactSpec::load(ArtifactSpec::default_dir()).unwrap();
        let mut flat = spec.model("mlp").unwrap().spec.init(0);
        let (shards, _) = make_federated(0, 1, 64, 32, Partition::Iid, 0.5);
        let idx: Vec<usize> = (0..32).collect();
        let (x, y) = shards[0].gather_batch(&idx, 32);
        let (_, first_loss) = p.train_step(&flat, &x, &y, 0.0).unwrap();
        let mut last = first_loss;
        for _ in 0..10 {
            let (nf, l) = p.train_step(&flat, &x, &y, 0.1).unwrap();
            flat = nf;
            last = l;
        }
        assert!(
            last < first_loss * 0.8,
            "loss did not decrease: {first_loss} -> {last}"
        );
    }

    #[test]
    fn aggregate_matches_rust_oracle() {
        let Some(p) = pool() else { return };
        let d = p.d_pad();
        let rows: Vec<Vec<f32>> = (0..4)
            .map(|i| (0..d).map(|j| ((i + j) % 13) as f32 * 0.1).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let w = [0.1f32, 0.2, 0.3, 0.4];
        let got = p.aggregate_k(&refs, &w).unwrap();
        let want = crate::model::weighted_sum(&refs, &w);
        let mut max_err = 0f32;
        for (g, ww) in got.iter().zip(&want) {
            max_err = max_err.max((g - ww).abs());
        }
        assert!(max_err < 1e-3, "max_err={max_err}");
    }

    #[test]
    fn eval_step_counts_sensibly() {
        let Some(p) = pool() else { return };
        let spec = ArtifactSpec::load(ArtifactSpec::default_dir()).unwrap();
        let flat = spec.model("mlp").unwrap().spec.init(1);
        let (shards, _) = make_federated(1, 1, 32, 32, Partition::Iid, 0.5);
        let idx: Vec<usize> = (0..32).collect();
        let (x, y) = shards[0].gather_batch(&idx, 32);
        let (sum_loss, correct) = p.eval_step(&flat, &x, &y).unwrap();
        assert!(sum_loss > 0.0);
        assert!((0.0..=32.0).contains(&correct));
    }

    #[test]
    fn concurrent_callers_share_the_pool() {
        let Some(p) = pool() else { return };
        let spec = ArtifactSpec::load(ArtifactSpec::default_dir()).unwrap();
        let flat = Arc::new(spec.model("mlp").unwrap().spec.init(2));
        let (shards, _) = make_federated(2, 4, 32, 32, Partition::Iid, 0.5);
        let mut handles = vec![];
        for (t, shard) in shards.into_iter().enumerate() {
            let p = p.clone();
            let flat = flat.clone();
            handles.push(std::thread::spawn(move || {
                let idx: Vec<usize> = (0..32).collect();
                let (x, y) = shard.gather_batch(&idx, 32);
                let (nf, loss) = p.train_step(&flat, &x, &y, 0.05).unwrap();
                assert_eq!(nf.len(), flat.len());
                assert!(loss.is_finite(), "thread {t} got bad loss");
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let (calls, _) = p.stats();
        assert_eq!(calls, 4);
    }
}
