//! `SimdCompute` — explicitly vectorized aggregation folds.
//!
//! The aggregation fold is the fabric's arithmetic hot path: every round,
//! every aggregation point folds O(children) flat `f32` rows into one
//! O(d) accumulator. [`MockCompute`](super::MockCompute) folds row by row
//! (`acc += w·u` as a full pass per row), which re-streams the
//! accumulator from memory once per child. `SimdCompute` wraps any inner
//! [`Compute`] and replaces only the fold entry points
//! ([`Compute::aggregate_into`] / [`Compute::aggregate_k`]) with
//! register-blocked kernels: each 8-lane block of the accumulator is
//! loaded once, folded across *all* rows of the chunk, and stored once —
//! O(d) accumulator traffic per chunk instead of O(rows·d).
//!
//! Three kernels, selected at construction ([`SimdKernel`]):
//!
//! * **Scalar** — row-sequential [`crate::model::axpy`], byte-identical
//!   to the mock oracle. The CI force-scalar cell (`FLAME_SIMD=scalar`)
//!   pins this path.
//! * **Portable** — the blocked loop written over fixed 8-wide arrays so
//!   LLVM auto-vectorizes it on any target. Per element it performs the
//!   same `mul` then `add` sequence in the same order as Scalar, so it is
//!   **bit-identical** to the oracle (blocking reorders memory traffic,
//!   never arithmetic).
//! * **Avx2Fma** — `std::arch` AVX2 intrinsics with `_mm256_fmadd_ps`,
//!   runtime-dispatched via `is_x86_feature_detected!`. Fusing the
//!   multiply-add skips one rounding per fold step, so results may differ
//!   from the scalar oracle — see the ULP policy below.
//!
//! ## ULP-parity policy
//!
//! Each fused `fma(u, w, acc)` differs from the scalar
//! `round(round(w·u) + acc)` by at most one unit in the last place of the
//! running accumulator. A k-row fold therefore diverges from the scalar
//! oracle by **at most k ULP** per element; in practice the error is far
//! smaller because the two roundings usually agree. Tests here and in
//! `rust/tests/codecs.rs` assert `ulp_distance ≤ rows` for every kernel
//! (Scalar and Portable must be exactly 0). Chunk boundaries never
//! perturb any kernel: the per-element fold order is row order regardless
//! of how the `Accumulator` batches `agg_k`-sized calls, so streaming
//! determinism across runner pools is preserved.

use std::sync::Arc;

use anyhow::Result;

use super::Compute;

/// Which fold kernel a [`SimdCompute`] runs. Fixed per instance (hence
/// per job) so every fold in a run uses the same arithmetic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdKernel {
    /// Row-sequential scalar fold — the `MockCompute` oracle.
    Scalar,
    /// Register-blocked 8-wide fold, auto-vectorized; bit-identical to
    /// `Scalar`.
    Portable,
    /// AVX2 + FMA intrinsics; ULP-bounded divergence from `Scalar`.
    Avx2Fma,
}

impl SimdKernel {
    pub fn name(&self) -> &'static str {
        match self {
            SimdKernel::Scalar => "scalar",
            SimdKernel::Portable => "portable",
            SimdKernel::Avx2Fma => "avx2",
        }
    }
}

/// Pick the fastest kernel the host supports.
pub fn detect_kernel() -> SimdKernel {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return SimdKernel::Avx2Fma;
        }
    }
    SimdKernel::Portable
}

/// Resolve a kernel from a policy string (TAG `hyper.simd`, `JobOptions`,
/// or the `FLAME_SIMD` env override used by the CI force-scalar cell).
/// `auto`/`on` detect; unknown or unsupported requests fall back to the
/// best supported kernel rather than failing the job.
pub fn kernel_from_policy(policy: &str) -> SimdKernel {
    match policy {
        "scalar" => SimdKernel::Scalar,
        "portable" => SimdKernel::Portable,
        "avx2" | "fma" => {
            if detect_kernel() == SimdKernel::Avx2Fma {
                SimdKernel::Avx2Fma
            } else {
                SimdKernel::Portable
            }
        }
        _ => detect_kernel(),
    }
}

/// The env-resolved kernel: `FLAME_SIMD` wins (CI's force-scalar cell),
/// otherwise hardware detection.
pub fn env_kernel() -> SimdKernel {
    match std::env::var("FLAME_SIMD") {
        Ok(v) if !v.is_empty() => kernel_from_policy(&v),
        _ => detect_kernel(),
    }
}

/// A [`Compute`] decorator that vectorizes the aggregation fold and
/// forwards every other entry point to the wrapped backend.
pub struct SimdCompute {
    inner: Arc<dyn Compute>,
    kernel: SimdKernel,
}

impl SimdCompute {
    /// Wrap `inner` with the env/hardware-selected kernel.
    pub fn wrap(inner: Arc<dyn Compute>) -> Self {
        Self::with_kernel(inner, env_kernel())
    }

    /// Wrap `inner` with an explicit kernel (parity tests and benches).
    pub fn with_kernel(inner: Arc<dyn Compute>, kernel: SimdKernel) -> Self {
        Self { inner, kernel }
    }

    pub fn kernel(&self) -> SimdKernel {
        self.kernel
    }
}

/// Fold `acc += Σ wᵢ·uᵢ` with the given kernel. Public so the fabric
/// bench can time kernels directly without a `Compute` round-trip.
pub fn fold_rows(kernel: SimdKernel, acc: &mut [f32], updates: &[&[f32]], weights: &[f32]) {
    assert_eq!(updates.len(), weights.len());
    for u in updates {
        assert_eq!(u.len(), acc.len(), "row length mismatch in fold");
    }
    match kernel {
        SimdKernel::Scalar => {
            for (u, &w) in updates.iter().zip(weights) {
                crate::model::axpy(acc, w, u);
            }
        }
        SimdKernel::Portable => fold_portable(acc, updates, weights),
        SimdKernel::Avx2Fma => {
            #[cfg(target_arch = "x86_64")]
            // Safety: Avx2Fma is only ever selected when
            // `is_x86_feature_detected!` confirmed avx2+fma (see
            // `kernel_from_policy`/`detect_kernel`), or by tests that
            // check support first.
            unsafe {
                fold_avx2(acc, updates, weights)
            }
            #[cfg(not(target_arch = "x86_64"))]
            fold_portable(acc, updates, weights)
        }
    }
}

const LANES: usize = 8;

/// Register-blocked portable fold: one pass over `acc`, all rows folded
/// per 8-lane block. Per element this is the same mul-then-add sequence
/// as the scalar row loop, so the result is bit-identical.
fn fold_portable(acc: &mut [f32], updates: &[&[f32]], weights: &[f32]) {
    let d = acc.len();
    let blocks = d / LANES * LANES;
    let mut i = 0;
    while i < blocks {
        let mut a = [0f32; LANES];
        a.copy_from_slice(&acc[i..i + LANES]);
        for (u, &w) in updates.iter().zip(weights) {
            let row = &u[i..i + LANES];
            for l in 0..LANES {
                a[l] += w * row[l];
            }
        }
        acc[i..i + LANES].copy_from_slice(&a);
        i += LANES;
    }
    for j in blocks..d {
        let mut a = acc[j];
        for (u, &w) in updates.iter().zip(weights) {
            a += w * u[j];
        }
        acc[j] = a;
    }
}

/// AVX2/FMA fold. The scalar tail uses `mul_add` so the whole vector sees
/// one arithmetic (fused) regardless of lane position.
///
/// # Safety
/// Caller must ensure the host supports `avx2` and `fma`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn fold_avx2(acc: &mut [f32], updates: &[&[f32]], weights: &[f32]) {
    use std::arch::x86_64::*;
    let d = acc.len();
    let blocks = d / LANES * LANES;
    let mut i = 0;
    while i < blocks {
        let mut a = _mm256_loadu_ps(acc.as_ptr().add(i));
        for (u, &w) in updates.iter().zip(weights) {
            let wv = _mm256_set1_ps(w);
            let row = _mm256_loadu_ps(u.as_ptr().add(i));
            a = _mm256_fmadd_ps(row, wv, a);
        }
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), a);
        i += LANES;
    }
    for j in blocks..d {
        let mut a = acc[j];
        for (u, &w) in updates.iter().zip(weights) {
            a = u[j].mul_add(w, a);
        }
        acc[j] = a;
    }
}

/// ULP distance between two finite `f32`s: how many representable values
/// lie between them. The parity tests' comparison metric.
pub fn ulp_distance(a: f32, b: f32) -> u32 {
    // map IEEE sign-magnitude onto a monotone integer line (±0 coincide)
    fn ordered(x: f32) -> i64 {
        let bits = x.to_bits();
        if bits & 0x8000_0000 != 0 {
            -((bits & 0x7fff_ffff) as i64)
        } else {
            bits as i64
        }
    }
    debug_assert!(a.is_finite() && b.is_finite());
    (ordered(a) - ordered(b)).unsigned_abs() as u32
}

/// Max ULP distance across two equal-length slices.
pub fn max_ulp(a: &[f32], b: &[f32]) -> u32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| ulp_distance(x, y)).max().unwrap_or(0)
}

impl Compute for SimdCompute {
    fn d_pad(&self) -> usize {
        self.inner.d_pad()
    }

    fn batch(&self) -> usize {
        self.inner.batch()
    }

    fn agg_k(&self) -> usize {
        self.inner.agg_k()
    }

    fn train_step(
        &self,
        flat: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        self.inner.train_step(flat, x, y, lr)
    }

    fn train_step_prox(
        &self,
        flat: &[f32],
        gflat: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        mu: f32,
    ) -> Result<(Vec<f32>, f32)> {
        self.inner.train_step_prox(flat, gflat, x, y, lr, mu)
    }

    fn train_step_dyn(
        &self,
        flat: &[f32],
        gflat: &[f32],
        h: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        alpha: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        self.inner.train_step_dyn(flat, gflat, h, x, y, lr, alpha)
    }

    fn grad_step(&self, flat: &[f32], x: &[f32], y: &[i32]) -> Result<(Vec<f32>, f32)> {
        self.inner.grad_step(flat, x, y)
    }

    fn eval_step(&self, flat: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        self.inner.eval_step(flat, x, y)
    }

    /// Vectorized weighted sum of one chunk: zeroed buffer + blocked fold.
    fn aggregate_k(&self, updates: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>> {
        assert!(updates.len() <= self.agg_k());
        let d = updates.first().map(|u| u.len()).unwrap_or(0);
        let mut out = vec![0f32; d];
        fold_rows(self.kernel, &mut out, updates, weights);
        Ok(out)
    }

    /// Chunk-uniform like the mock: per-element fold order is row order,
    /// so `agg_k` batching is invisible to the result for every kernel.
    fn aggregate_into(&self, acc: &mut [f32], updates: &[&[f32]], weights: &[f32]) -> Result<()> {
        assert!(updates.len() <= self.agg_k());
        fold_rows(self.kernel, acc, updates, weights);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weighted_sum;
    use crate::prng::Rng;
    use crate::runtime::MockCompute;

    fn rows(seed: u64, k: usize, d: usize) -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let rows = (0..k)
            .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
            .collect();
        let w = (0..k).map(|_| 0.25 + rng.below(40) as f32 * 0.125).collect();
        (rows, w)
    }

    fn kernels() -> Vec<SimdKernel> {
        let mut ks = vec![SimdKernel::Scalar, SimdKernel::Portable];
        if detect_kernel() == SimdKernel::Avx2Fma {
            ks.push(SimdKernel::Avx2Fma);
        }
        ks
    }

    #[test]
    fn portable_is_bit_identical_to_scalar_oracle() {
        for &(k, d) in &[(1usize, 7usize), (5, 64), (13, 257), (64, 1000)] {
            let (rows, w) = rows(k as u64 * 31 + d as u64, k, d);
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            let want = weighted_sum(&refs, &w);
            let mut got = vec![0f32; d];
            fold_rows(SimdKernel::Portable, &mut got, &refs, &w);
            assert_eq!(got, want, "portable diverged at k={k} d={d}");
        }
    }

    #[test]
    fn avx2_stays_within_documented_ulp_bound() {
        if detect_kernel() != SimdKernel::Avx2Fma {
            return; // host cannot run the fused kernel
        }
        for &(k, d) in &[(3usize, 61usize), (16, 512), (64, 4096)] {
            let (rows, w) = rows(k as u64 * 7 + d as u64, k, d);
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            let want = weighted_sum(&refs, &w);
            let mut got = vec![0f32; d];
            fold_rows(SimdKernel::Avx2Fma, &mut got, &refs, &w);
            let ulp = max_ulp(&got, &want);
            assert!(ulp <= k as u32, "k={k} d={d}: ulp {ulp} exceeds fold depth");
        }
    }

    #[test]
    fn chunk_boundaries_are_invisible_for_every_kernel() {
        let (rows, w) = rows(99, 11, 130);
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        for kern in kernels() {
            let mut whole = vec![0f32; 130];
            fold_rows(kern, &mut whole, &refs, &w);
            for chunk in [1usize, 2, 3, 5, 11] {
                let mut acc = vec![0f32; 130];
                for (cu, cw) in refs.chunks(chunk).zip(w.chunks(chunk)) {
                    fold_rows(kern, &mut acc, cu, cw);
                }
                assert_eq!(acc, whole, "{kern:?} chunk={chunk} changed the fold");
            }
        }
    }

    #[test]
    fn simd_compute_matches_mock_fold_within_ulp_policy() {
        let d = 200;
        let mock = MockCompute::new(d, 8, 16);
        let (rows, w) = rows(7, 9, d);
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut want = vec![0f32; d];
        mock.aggregate_into(&mut want, &refs, &w).unwrap();
        for kern in kernels() {
            let sc = SimdCompute::with_kernel(Arc::new(MockCompute::new(d, 8, 16)), kern);
            let mut got = vec![0f32; d];
            sc.aggregate_into(&mut got, &refs, &w).unwrap();
            let ulp = max_ulp(&got, &want);
            match kern {
                SimdKernel::Avx2Fma => {
                    assert!(ulp <= refs.len() as u32, "{kern:?}: ulp {ulp}")
                }
                _ => assert_eq!(got, want, "{kern:?} must be bit-identical"),
            }
            // aggregate_k is the same fold over a zeroed buffer
            let agg = sc.aggregate_k(&refs, &w).unwrap();
            assert_eq!(agg, got);
        }
    }

    #[test]
    fn delegates_everything_but_the_fold() {
        let inner: Arc<dyn Compute> = Arc::new(MockCompute::new(64, 4, 8));
        let sc = SimdCompute::wrap(inner.clone());
        assert_eq!(sc.d_pad(), 64);
        assert_eq!(sc.batch(), 4);
        assert_eq!(sc.agg_k(), 8);
        let flat = vec![0.01f32; 64];
        let x = vec![0.1f32; 4 * crate::data::INPUT_DIM];
        let y = vec![1i32; 4];
        let (a, la) = inner.train_step(&flat, &x, &y, 0.1).unwrap();
        let (b, lb) = sc.train_step(&flat, &x, &y, 0.1).unwrap();
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }

    #[test]
    fn policy_resolution() {
        assert_eq!(kernel_from_policy("scalar"), SimdKernel::Scalar);
        assert_eq!(kernel_from_policy("portable"), SimdKernel::Portable);
        // avx2 request degrades gracefully on hosts without it
        let got = kernel_from_policy("avx2");
        assert!(got == SimdKernel::Avx2Fma || got == SimdKernel::Portable);
        assert_eq!(kernel_from_policy("auto"), detect_kernel());
    }

    #[test]
    fn ulp_distance_metric() {
        assert_eq!(ulp_distance(1.0, 1.0), 0);
        assert_eq!(ulp_distance(1.0, f32::from_bits(1.0f32.to_bits() + 1)), 1);
        assert_eq!(ulp_distance(-1.0, f32::from_bits((-1.0f32).to_bits() + 1)), 1);
        // straddling zero counts the values between -0.0 and +0.0 as one step
        assert_eq!(ulp_distance(f32::from_bits(1), -f32::from_bits(1)), 2);
        assert!(ulp_distance(1.0, 2.0) > 1_000_000);
    }
}
