//! `TensorPool` — model-buffer recycling for the steady-state round loop.
//!
//! Every round the fabric moves O(workers) flat `f32` vectors of length
//! `d_pad` (distributed weights, trainer updates, aggregated means). The
//! collect-then-allocate style hit the global allocator once per buffer
//! per round; at 10k trainers that is tens of thousands of ~1 MB
//! allocations a round. The pool closes the cycle instead: buffers travel
//! as `Arc<Vec<f32>>`, and whoever drops the **last** reference offers the
//! buffer back via [`TensorPool::reclaim`] — uniqueness is checked with
//! `Arc::get_mut`, so a buffer still shared (an in-flight broadcast, a
//! retained model) is simply left to the normal `Drop` path. Takers
//! receive a uniquely-owned `Arc` whose allocation (vector *and* Arc
//! control block) is reused, which is what drives steady-state fabric
//! allocations to zero (`rust/tests/alloc_regression.rs`).
//!
//! One pool per job (`JobRuntime::pool`), sized to the job's `d_pad`;
//! buffers of any other length are rejected by `reclaim` so ring-allreduce
//! chunks and other small payloads never pollute it.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Upper bound on pooled buffers — a guard against pathological retention,
/// not a tuning knob: a job's pool never outgrows the job's own peak
/// concurrent buffer count.
const POOL_CAP: usize = 1024;

pub struct TensorPool {
    d: usize,
    bufs: Mutex<Vec<Arc<Vec<f32>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    recycled: AtomicU64,
}

impl TensorPool {
    pub fn new(d: usize) -> Arc<Self> {
        Arc::new(Self {
            d,
            bufs: Mutex::new(Vec::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            recycled: AtomicU64::new(0),
        })
    }

    /// Buffer length this pool serves.
    pub fn d(&self) -> usize {
        self.d
    }

    fn pop(&self) -> Option<Arc<Vec<f32>>> {
        let got = self.bufs.lock().unwrap().pop();
        match &got {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        got
    }

    /// A uniquely-owned zeroed buffer of length `d`.
    pub fn take_zeroed(&self) -> Arc<Vec<f32>> {
        match self.pop() {
            Some(mut a) => {
                Arc::get_mut(&mut a)
                    .expect("pooled buffers are uniquely owned")
                    .fill(0.0);
                a
            }
            None => Arc::new(vec![0f32; self.d]),
        }
    }

    /// A uniquely-owned copy of `src`. Falls back to a plain allocation
    /// when `src` is not pool-sized (callers need not special-case).
    pub fn take_copy(&self, src: &[f32]) -> Arc<Vec<f32>> {
        if src.len() != self.d {
            return Arc::new(src.to_vec());
        }
        match self.pop() {
            Some(mut a) => {
                Arc::get_mut(&mut a)
                    .expect("pooled buffers are uniquely owned")
                    .copy_from_slice(src);
                a
            }
            None => Arc::new(src.to_vec()),
        }
    }

    /// Offer a buffer back. Kept only when it is the right length and this
    /// was the last reference; otherwise the `Arc` drops normally. Returns
    /// whether the buffer was pooled.
    pub fn reclaim(&self, mut buf: Arc<Vec<f32>>) -> bool {
        if buf.len() != self.d || Arc::get_mut(&mut buf).is_none() {
            return false;
        }
        let mut g = self.bufs.lock().unwrap();
        if g.len() >= POOL_CAP {
            return false;
        }
        g.push(buf);
        self.recycled.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// `(hits, misses, recycled)` counters — bench observability.
    pub fn stats(&self) -> (u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.recycled.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycles_unique_buffers() {
        let pool = TensorPool::new(8);
        let a = pool.take_zeroed();
        let ptr = a.as_ptr();
        assert!(pool.reclaim(a));
        let b = pool.take_copy(&[1.0; 8]);
        assert_eq!(b.as_ptr(), ptr, "reused the same allocation");
        assert_eq!(**b, vec![1.0; 8]);
        let (hits, misses, recycled) = pool.stats();
        assert_eq!((hits, misses, recycled), (1, 1, 1));
    }

    #[test]
    fn shared_buffers_are_not_pooled() {
        let pool = TensorPool::new(4);
        let a = pool.take_zeroed();
        let b = a.clone();
        assert!(!pool.reclaim(a), "still referenced elsewhere");
        drop(b);
    }

    #[test]
    fn wrong_length_is_rejected() {
        let pool = TensorPool::new(4);
        assert!(!pool.reclaim(Arc::new(vec![0.0; 3])));
        // take_copy of a foreign length still works, just unpooled
        let c = pool.take_copy(&[1.0, 2.0]);
        assert_eq!(**c, vec![1.0, 2.0]);
    }

    #[test]
    fn take_zeroed_clears_previous_contents() {
        let pool = TensorPool::new(4);
        let a = pool.take_copy(&[9.0; 4]);
        pool.reclaim(a);
        assert_eq!(**pool.take_zeroed(), vec![0.0; 4]);
    }
}
