//! Parsing of `artifacts/spec.json` — the contract emitted by `aot.py`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::json::Json;
use crate::model::ModelSpec;

/// Shapes of one lowered entry point's inputs.
#[derive(Debug, Clone)]
pub struct EntryInfo {
    pub file: String,
    pub input_shapes: Vec<Vec<usize>>,
    pub input_dtypes: Vec<String>,
}

/// One model's artifacts: layout + entry table.
#[derive(Debug, Clone)]
pub struct ModelArtifacts {
    pub spec: ModelSpec,
    pub entries: BTreeMap<String, EntryInfo>,
}

/// The whole `artifacts/` directory, parsed.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub dir: PathBuf,
    pub batch: usize,
    pub input_dim: usize,
    pub num_classes: usize,
    pub agg_k: usize,
    pub agg_block_d: usize,
    pub models: BTreeMap<String, ModelArtifacts>,
}

impl ArtifactSpec {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("spec.json"))
            .with_context(|| format!("reading {}/spec.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).context("spec.json is not valid JSON")?;
        let mut models = BTreeMap::new();
        let model_obj = j.get("models").as_obj().context("spec missing models")?;
        for (name, m) in model_obj.iter() {
            let spec = ModelSpec::from_json(name, m)?;
            let mut entries = BTreeMap::new();
            for (entry, e) in m.get("entries").as_obj().context("missing entries")?.iter() {
                let mut input_shapes = Vec::new();
                let mut input_dtypes = Vec::new();
                for inp in e.get("inputs").as_arr().context("entry missing inputs")? {
                    input_shapes.push(
                        inp.get("shape")
                            .as_arr()
                            .context("input missing shape")?
                            .iter()
                            .filter_map(Json::as_usize)
                            .collect(),
                    );
                    input_dtypes.push(
                        inp.get("dtype").as_str().unwrap_or("float32").to_string(),
                    );
                }
                entries.insert(
                    entry.clone(),
                    EntryInfo {
                        file: e
                            .get("file")
                            .as_str()
                            .context("entry missing file")?
                            .to_string(),
                        input_shapes,
                        input_dtypes,
                    },
                );
            }
            models.insert(name.clone(), ModelArtifacts { spec, entries });
        }
        Ok(Self {
            dir,
            batch: j.get("batch").as_usize().context("spec missing batch")?,
            input_dim: j.get("input_dim").as_usize().context("missing input_dim")?,
            num_classes: j
                .get("num_classes")
                .as_usize()
                .context("missing num_classes")?,
            agg_k: j.get("agg_k").as_usize().context("missing agg_k")?,
            agg_block_d: j
                .get("agg_block_d")
                .as_usize()
                .context("missing agg_block_d")?,
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelArtifacts> {
        self.models
            .get(name)
            .with_context(|| format!("model '{name}' not in artifacts (rebuild with --models)"))
    }

    /// Default artifact dir: `$FLAME_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("FLAME_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Are artifacts present (so PJRT-dependent tests can self-skip)?
    pub fn available() -> bool {
        Self::default_dir().join("spec.json").exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_present() -> bool {
        ArtifactSpec::available()
    }

    #[test]
    fn loads_real_spec_when_present() {
        if !artifacts_present() {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let spec = ArtifactSpec::load(ArtifactSpec::default_dir()).unwrap();
        assert_eq!(spec.batch, 32);
        assert_eq!(spec.input_dim, 784);
        let mlp = spec.model("mlp").unwrap();
        assert_eq!(mlp.spec.d, 235146);
        assert_eq!(mlp.spec.d_pad % spec.agg_block_d, 0);
        for entry in ["train_step", "train_step_prox", "train_step_dyn", "grad_step", "eval_step", "aggregate"] {
            let e = mlp.entries.get(entry).unwrap_or_else(|| panic!("missing {entry}"));
            assert!(spec.dir.join(&e.file).exists(), "{} missing", e.file);
        }
        // shape sanity: train_step inputs are [flat, x, y, lr]
        let ts = &mlp.entries["train_step"];
        assert_eq!(ts.input_shapes[0], vec![mlp.spec.d_pad]);
        assert_eq!(ts.input_shapes[1], vec![spec.batch, spec.input_dim]);
        assert_eq!(ts.input_dtypes[2], "int32");
        assert!(ts.input_shapes[3].is_empty()); // scalar lr
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = ArtifactSpec::load("/nonexistent/artifacts").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
