//! `flame` — the leader entrypoint / CLI.
//!
//! Subcommands (hand-rolled parser; the offline vendor set has no clap):
//!
//! ```text
//! flame expand  --topo hfl --trainers 12 --groups 3       # print workers
//! flame run     --topo cfl --trainers 8 --rounds 10 \
//!               [--runtime mock|pjrt] [--algorithm fedavg|fedprox|feddyn]
//!               [--server-opt avg|adam|yogi|adagrad] [--selection all|random|oort]
//! flame fig10   [--rounds 36]                             # §6.1 scenario
//! flame fig11   [--rounds 20]                             # §6.2 scenario
//! flame scale   [--trainers 10000 --groups 100 --rounds 3] \
//!               [--executor coop|threads] [--runners N]   # 10k-worker fabric demo
//! flame churn   [--trainers 20 --groups 2 --rounds 9] \
//!               [--churn 0.2] [--quorum 1.0] [--runners N] # live topology extension
//! flame fleet   [--jobs 100 --runners N]                  # multi-job control plane
//! flame fedprox [--trainers 8 --rounds 6 --mu 0.1]        # Role-SDK custom program
//! flame codec-sweep [--trainers 8 --rounds 8 --topk-frac 0.05] # update-codec comparison
//! flame resume  [--flavor sync|quorum|async|ring --kill-at N]  # kill/resume vs oracle
//! flame resume  --list | --all [--jobs 10]                     # fleet-wide crash recovery
//! flame trace   [--trainers 6 --rounds 4 --out bench_out/trace.json] # virtual-time tracing
//! flame roles                                             # list registered programs
//! flame spec    --topo hybrid --trainers 50 --groups 5    # print TAG JSON
//! ```
//!
//! Unknown `--flags` are rejected with the command's valid option list —
//! a typo can never be silently ignored.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use flame::channel::Backend;
use flame::control::{Controller, JobOptions};
use flame::json::Json;
use flame::registry::Registry;
use flame::runtime::{ArtifactSpec, Compute, MockCompute, PjrtPool};
use flame::store::Store;
use flame::{sim, tag, topo};

struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = argv.get(i + 1).cloned().unwrap_or_default();
                if val.starts_with("--") || val.is_empty() {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                } else {
                    flags.insert(key.to_string(), val);
                    i += 2;
                }
            } else {
                bail!("unexpected argument '{a}'");
            }
        }
        Ok(Self { flags })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        self.get(key, &default.to_string())
            .parse()
            .with_context(|| format!("--{key} must be an integer"))
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.get_u64(key, default as u64)? as usize)
    }

    /// Reject flags the command does not understand, listing what it does.
    fn expect_flags(&self, cmd: &str, valid: &[&str]) -> Result<()> {
        let mut unknown: Vec<&str> = self
            .flags
            .keys()
            .map(String::as_str)
            .filter(|k| !valid.contains(k))
            .collect();
        unknown.sort_unstable();
        if let Some(first) = unknown.first() {
            let mut opts: Vec<String> = valid.iter().map(|v| format!("--{v}")).collect();
            opts.sort();
            bail!(
                "unknown flag '--{first}' for '{cmd}' (valid options: {})",
                opts.join(", ")
            );
        }
        Ok(())
    }
}

/// Flags understood by `build_spec` (shared by expand/spec/run).
const SPEC_FLAGS: &[&str] = &[
    "topo",
    "trainers",
    "groups",
    "rounds",
    "backend",
    "lr",
    "local-steps",
    "algorithm",
    "server-opt",
    "selection",
    "seed",
    "select-frac",
    "aggregation",
    "buffer-k",
    "model",
    "codec",
    "topk-frac",
    "simd",
];

/// `run`'s full flag set: spec + runtime + data shaping.
fn run_flags() -> Vec<&'static str> {
    let mut v = SPEC_FLAGS.to_vec();
    v.extend_from_slice(&["runtime", "runtime-threads", "per-shard", "test-n", "dirichlet"]);
    v
}

fn build_spec(args: &Args) -> Result<tag::JobSpec> {
    let trainers = args.get_usize("trainers", 8)?;
    let groups = args.get_usize("groups", 2)?;
    let rounds = args.get_u64("rounds", 10)?;
    let backend = Backend::parse(&args.get("backend", "p2p"))?;
    let builder = match args.get("topo", "cfl").as_str() {
        "cfl" | "classical" => topo::classical(trainers, backend),
        "hfl" | "hierarchical" => topo::hierarchical(trainers, groups, backend),
        "cofl" | "coordinated" => topo::coordinated(trainers, groups.max(2), backend),
        "hybrid" => topo::hybrid(trainers, groups, backend, Backend::P2p),
        "distributed" => topo::distributed(trainers, Backend::P2p),
        other => bail!("unknown topology '{other}'"),
    };
    let mut builder = builder
        .rounds(rounds)
        .set("lr", Json::Num(args.get("lr", "0.5").parse()?))
        .set("local_steps", args.get_usize("local-steps", 2)?)
        .set("algorithm", args.get("algorithm", "fedavg").as_str())
        .set("server_opt", args.get("server-opt", "avg").as_str())
        .set("selection", args.get("selection", "all").as_str())
        .set("seed", args.get_u64("seed", 7)?);
    if args.flags.contains_key("select-frac") {
        builder = builder.set(
            "select_frac",
            Json::Num(args.get("select-frac", "1.0").parse()?),
        );
    }
    if args.get("aggregation", "sync") != "sync" {
        builder = builder
            .set("aggregation", args.get("aggregation", "sync").as_str())
            .set("buffer_k", args.get_usize("buffer-k", 3)?);
    }
    if args.flags.contains_key("codec") {
        builder = builder
            .set("codec", args.get("codec", "f32").as_str())
            .set("topk_frac", Json::Num(args.get("topk-frac", "0.05").parse()?));
    }
    if args.flags.contains_key("simd") {
        builder = builder.set("simd", args.get("simd", "auto").as_str());
    }
    Ok(builder.model(&args.get("model", "mlp")).build())
}

fn make_compute(args: &Args) -> Result<(Arc<dyn Compute>, Option<Vec<f32>>)> {
    match args.get("runtime", "mock").as_str() {
        "mock" => Ok((Arc::new(MockCompute::default_mlp()), None)),
        "pjrt" => {
            let spec = ArtifactSpec::load(ArtifactSpec::default_dir())?;
            let model = args.get("model", "mlp");
            let threads = args.get_usize("runtime-threads", 2)?;
            let pool = PjrtPool::load(&spec, &model, threads)?;
            let init = spec.model(&model)?.spec.init(args.get_u64("seed", 7)?);
            Ok((pool, Some(init)))
        }
        other => bail!("unknown runtime '{other}' (mock|pjrt)"),
    }
}

fn cmd_expand(args: &Args) -> Result<()> {
    args.expect_flags("expand", SPEC_FLAGS)?;
    let spec = build_spec(args)?;
    let workers = tag::expand(&spec, &Registry::single_box())?;
    println!("# {} workers", workers.len());
    for w in &workers {
        println!("{}", w.to_json().dump());
    }
    Ok(())
}

fn cmd_spec(args: &Args) -> Result<()> {
    args.expect_flags("spec", SPEC_FLAGS)?;
    println!("{}", build_spec(args)?.to_json().pretty());
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    args.expect_flags("run", &run_flags())?;
    let spec = build_spec(args)?;
    let (compute, init) = make_compute(args)?;
    let mut opts = JobOptions::mock().with_compute(compute).with_data(
        args.get_usize("per-shard", 128)?,
        args.get_usize("test-n", 256)?,
        if args.flags.contains_key("dirichlet") {
            flame::data::Partition::Dirichlet(args.get("dirichlet", "0.5").parse()?)
        } else {
            flame::data::Partition::Iid
        },
        args.get_u64("seed", 7)?,
    );
    if let Some(init) = init {
        opts = opts.with_init(init);
    }
    let mut ctl = Controller::new(Arc::new(Store::in_memory()));
    let report = ctl.submit(spec, opts)?;
    println!(
        "job {} done: workers={} wall={:.2}s vtime={:.2}s bytes={}",
        report.job, report.workers, report.wall_s, report.vtime_s, report.total_bytes
    );
    for (series, label) in [
        ("loss", "loss"),
        ("acc", "accuracy"),
        ("round_time_s", "round time (s)"),
    ] {
        let s = report.metrics.series(series);
        if !s.is_empty() {
            let line: Vec<String> = s.iter().map(|(r, v)| format!("{r}:{v:.4}")).collect();
            println!("{label}: {}", line.join(" "));
        }
    }
    Ok(())
}

fn cmd_fig10(args: &Args) -> Result<()> {
    args.expect_flags("fig10", &["rounds"])?;
    let rounds = args.get_u64("rounds", 36)?;
    let o = sim::SimOptions::mock();
    let (hfl, cofl) = sim::run_fig10(rounds, &o)?;
    println!("round,hfl_round_time_s,cofl_round_time_s,cofl_active_aggs");
    let h = hfl.metrics.series("round_time_s");
    let c = cofl.metrics.series("round_time_s");
    let a = cofl.metrics.series("active_aggregators");
    for i in 0..h.len().min(c.len()) {
        println!(
            "{},{:.3},{:.3},{}",
            i,
            h[i].1,
            c[i].1,
            a.get(i).map(|x| x.1).unwrap_or(f64::NAN)
        );
    }
    Ok(())
}

fn cmd_fig11(args: &Args) -> Result<()> {
    args.expect_flags("fig11", &["rounds"])?;
    let rounds = args.get_u64("rounds", 20)?;
    let o = sim::SimOptions::mock();
    let (cfl, hybrid) = sim::run_fig11(rounds, &o)?;
    println!(
        "# C-FL:    final acc {:.3} at vtime {:.1}s, {:.1} MB/round uploaded",
        cfl.final_acc.unwrap_or(0.0),
        cfl.vtime_s,
        sim::upload_mb_per_round(&cfl, rounds)
    );
    println!(
        "# Hybrid:  final acc {:.3} at vtime {:.1}s, {:.1} MB/round uploaded",
        hybrid.final_acc.unwrap_or(0.0),
        hybrid.vtime_s,
        sim::upload_mb_per_round(&hybrid, rounds)
    );
    println!("round,cfl_vtime_s,cfl_acc,hybrid_vtime_s,hybrid_acc");
    let (cv, ca) = (cfl.metrics.series("vtime_s"), cfl.metrics.series("acc"));
    let (hv, ha) = (hybrid.metrics.series("vtime_s"), hybrid.metrics.series("acc"));
    for i in 0..cv.len().max(hv.len()) {
        let f = |s: &[(u64, f64)], i: usize| {
            s.get(i).map(|x| format!("{:.4}", x.1)).unwrap_or_default()
        };
        println!("{},{},{},{},{}", i, f(&cv, i), f(&ca, i), f(&hv, i), f(&ha, i));
    }
    Ok(())
}

fn cmd_scale(args: &Args) -> Result<()> {
    args.expect_flags("scale", &["trainers", "groups", "rounds", "executor", "runners"])?;
    let trainers = args.get_usize("trainers", 10_000)?;
    let groups = args.get_usize("groups", 100)?;
    let rounds = args.get_u64("rounds", 3)?;
    let mut o = sim::SimOptions::scale();
    o.executor = match args.get("executor", "coop").as_str() {
        "coop" | "cooperative" => flame::control::Executor::Cooperative {
            runners: args.get_usize("runners", 0)?,
        },
        "threads" | "thread-per-worker" => flame::control::Executor::ThreadPerWorker,
        other => bail!("unknown executor '{other}' (coop|threads)"),
    };
    let t0 = std::time::Instant::now();
    let report = sim::run_scale(trainers, groups, rounds, &o)?;
    println!(
        "scale: workers={} rounds={rounds} wall={:.2}s vtime={:.2}s acc={:.3} bytes={}",
        report.workers,
        t0.elapsed().as_secs_f64(),
        report.vtime_s,
        report.final_acc.unwrap_or(f64::NAN),
        report.total_bytes
    );
    Ok(())
}

/// Live topology extension demo: 2-tier job grows a middle aggregator
/// tier mid-run while trainers churn (see `sim::run_churn`).
fn cmd_churn(args: &Args) -> Result<()> {
    args.expect_flags(
        "churn",
        &["trainers", "groups", "rounds", "churn", "quorum", "runners", "per-shard", "test-n"],
    )?;
    let trainers = args.get_usize("trainers", 20)?;
    let groups = args.get_usize("groups", 2)?;
    let rounds = args.get_u64("rounds", 9)?;
    let churn: f64 = args
        .get("churn", "0.2")
        .parse()
        .context("--churn must be a fraction in [0, 1)")?;
    let quorum: f64 = args
        .get("quorum", "1.0")
        .parse()
        .context("--quorum must be a fraction in (0, 1]")?;
    let mut o = sim::SimOptions::mock();
    o.per_shard = args.get_usize("per-shard", 64)?;
    o.test_n = args.get_usize("test-n", 128)?;
    o.executor = flame::control::Executor::Cooperative {
        runners: args.get_usize("runners", 0)?,
    };
    let t0 = std::time::Instant::now();
    let report = sim::run_churn(trainers, groups, rounds, churn, quorum, &o)?;
    println!(
        "churn: workers={} (initial {}) rounds={rounds} churn={churn} quorum={quorum} \
         wall={:.2}s vtime={:.2}s acc={:.3}",
        report.workers,
        trainers + 1,
        t0.elapsed().as_secs_f64(),
        report.vtime_s,
        report.final_acc.unwrap_or(f64::NAN),
    );
    println!("round,acc,round_time_s,trainers_alive,aggregators_alive");
    let acc = report.metrics.series("acc");
    let rt = report.metrics.series("round_time_s");
    let ta = report.metrics.series("trainers_alive");
    let aa = report.metrics.series("aggregators_alive");
    let f = |s: &[(u64, f64)], i: usize| {
        s.get(i).map(|x| format!("{:.4}", x.1)).unwrap_or_default()
    };
    for i in 0..acc.len() {
        println!(
            "{},{},{},{},{}",
            i,
            f(&acc, i),
            f(&rt, i),
            f(&ta, i),
            f(&aa, i)
        );
    }
    Ok(())
}

/// Multi-job control plane demo: a heterogeneous fleet (C-FL, H-FL,
/// churn-with-events, async FedBuff) admitted against bounded capacity
/// and drained on one shared fabric (see `sim::run_fleet`).
fn cmd_fleet(args: &Args) -> Result<()> {
    args.expect_flags("fleet", &["jobs", "runners", "per-shard", "test-n", "seed"])?;
    let jobs = args.get_usize("jobs", 100)?;
    let runners = args.get_usize("runners", 0)?;
    let mut o = sim::SimOptions::mock();
    // logistic-head mock: the fleet demo exercises the control plane and
    // the shared fabric, not large-model numerics
    o.compute = Arc::new(MockCompute::new(7_850, 8, 16));
    o.per_shard = args.get_usize("per-shard", 16)?;
    o.test_n = args.get_usize("test-n", 32)?;
    o.local_steps = 1;
    o.seed = args.get_u64("seed", 7)?;
    let t0 = std::time::Instant::now();
    let report = sim::run_fleet(jobs, runners, &o)?;
    println!("{}", report.summary());
    println!("# wall: {:.2}s", t0.elapsed().as_secs_f64());
    for j in &report.jobs {
        println!("{}", j.line());
    }
    Ok(())
}

/// Role-SDK catalog: every registered program with its default-binding
/// role and flavour. Custom programs registered at runtime
/// (`Controller::register_program` / `JobOptions::with_program`) appear
/// the same way; from the CLI only the built-ins exist.
fn cmd_roles(args: &Args) -> Result<()> {
    args.expect_flags("roles", &[])?;
    let reg = flame::roles::RoleRegistry::builtin();
    println!("# {} registered programs", reg.names().len());
    println!("program,role,flavor");
    for info in reg.catalog() {
        if info.bindings.is_empty() {
            // reachable only via an explicit spec `program:` field
            println!("{},-,-", info.name);
        }
        for (role, flavor) in &info.bindings {
            println!(
                "{},{},{}",
                info.name,
                role,
                flavor.map(|f| f.name()).unwrap_or("any"),
            );
        }
    }
    println!();
    println!("# {} communication substrates", Backend::SUBSTRATES.len());
    println!("substrate,transport");
    for (name, backend) in Backend::SUBSTRATES {
        println!("{name},{}", backend.name());
    }
    Ok(())
}

/// Host one process's worker partition of a multi-process job. Not meant
/// for interactive use: a [`flame::wire::ProcDeployer`] parent drives it
/// over stdin/stdout (see the wire protocol in `flame::wire::proc`).
fn cmd_worker(args: &Args) -> Result<()> {
    args.expect_flags("worker", &["listen"])?;
    let listen = args.get("listen", "127.0.0.1:0");
    flame::wire::worker_main(&listen)
}

/// FedProx via the Role SDK: the trainer role bound to a custom program
/// derived from the exported base chain (see `sim::run_fedprox`).
fn cmd_fedprox(args: &Args) -> Result<()> {
    args.expect_flags(
        "fedprox",
        &["trainers", "rounds", "mu", "runners", "per-shard", "test-n", "seed"],
    )?;
    let trainers = args.get_usize("trainers", 8)?;
    let rounds = args.get_u64("rounds", 6)?;
    let mu: f64 = args
        .get("mu", "0.1")
        .parse()
        .context("--mu must be a non-negative number")?;
    let mut o = sim::SimOptions::mock();
    o.per_shard = args.get_usize("per-shard", 64)?;
    o.test_n = args.get_usize("test-n", 128)?;
    o.seed = args.get_u64("seed", 7)?;
    o.executor = flame::control::Executor::Cooperative {
        runners: args.get_usize("runners", 0)?,
    };
    let report = sim::run_fedprox(trainers, rounds, mu, &o)?;
    println!(
        "fedprox: workers={} rounds={rounds} mu={mu} wall={:.2}s vtime={:.2}s acc={:.3}",
        report.workers,
        report.wall_s,
        report.vtime_s,
        report.final_acc.unwrap_or(f64::NAN),
    );
    for (series, label) in [("loss", "loss"), ("acc", "accuracy")] {
        let s = report.metrics.series(series);
        if !s.is_empty() {
            let line: Vec<String> = s.iter().map(|(r, v)| format!("{r}:{v:.4}")).collect();
            println!("{label}: {}", line.join(" "));
        }
    }
    Ok(())
}

/// Update-codec comparison: the same WAN-shaped job per codec (f32
/// baseline, int8 quantization, top-k + error feedback), reporting final
/// accuracy, convergence delta, virtual completion time, and encoded
/// upload volume (see `sim::run_codec_sweep`).
fn cmd_codec_sweep(args: &Args) -> Result<()> {
    args.expect_flags(
        "codec-sweep",
        &["trainers", "rounds", "topk-frac", "per-shard", "test-n", "seed", "runners"],
    )?;
    let trainers = args.get_usize("trainers", 8)?;
    let rounds = args.get_u64("rounds", 8)?;
    let topk_frac: f64 = args
        .get("topk-frac", "0.05")
        .parse()
        .context("--topk-frac must be a fraction in (0, 1]")?;
    let mut o = sim::SimOptions::mock();
    o.per_shard = args.get_usize("per-shard", 64)?;
    o.test_n = args.get_usize("test-n", 128)?;
    o.seed = args.get_u64("seed", 7)?;
    o.executor = flame::control::Executor::Cooperative {
        runners: args.get_usize("runners", 0)?,
    };
    let t0 = std::time::Instant::now();
    let sweep = sim::run_codec_sweep(trainers, rounds, topk_frac, &o)?;
    println!(
        "# codec sweep: {trainers} trainers, {rounds} rounds, topk_frac={topk_frac}, wall={:.2}s",
        t0.elapsed().as_secs_f64()
    );
    print!("{}", sweep.summary());
    Ok(())
}

/// Crash-resilience demo: checkpoint every round boundary, kill the
/// controller at --kill-at, resume from the journaled checkpoint under
/// the original job id, and byte-compare the resumed report against an
/// unkilled oracle run (see `sim::run_resume`). `--flavor` picks what
/// gets checkpointed: `sync` (full quorum), `quorum` (0.75 — stragglers
/// in flight at every boundary), `async` (FedBuff version barriers) or
/// `ring` (delegate-committed distributed trainers).
///
/// `--list` / `--all` switch to the fleet-wide variant
/// (`sim::run_resume_fleet`): a mixed-flavor fleet dies wholesale, a
/// restarted manager scans the journal and either lists every orphaned
/// job (`--list`) or re-admits the lot via `resume_all` and
/// byte-compares the drained fleet against its oracle (`--all`).
fn cmd_resume(args: &Args) -> Result<()> {
    args.expect_flags(
        "resume",
        &[
            "trainers", "rounds", "kill-at", "flavor", "list", "all", "jobs", "per-shard",
            "test-n", "seed", "runners",
        ],
    )?;
    let trainers = args.get_usize("trainers", 8)?;
    let rounds = args.get_u64("rounds", 6)?;
    let kill_at = args.get_u64("kill-at", rounds / 2)?;
    let mut o = sim::SimOptions::mock();
    o.per_shard = args.get_usize("per-shard", 64)?;
    o.test_n = args.get_usize("test-n", 128)?;
    o.seed = args.get_u64("seed", 7)?;
    let runners = args.get_usize("runners", 0)?;
    if args.get("list", "false") == "true" || args.get("all", "false") == "true" {
        let jobs = args.get_usize("jobs", 10)?;
        let f = sim::run_resume_fleet(jobs, runners, &o)?;
        println!("# {} resumable jobs after the outage", f.listing.len());
        for line in &f.listing {
            println!("{line}");
        }
        if args.get("all", "false") == "true" {
            println!("# resumed {} jobs via resume_all", f.resumed_ids.len());
            for (oracle, resumed) in f.oracle_lines.iter().zip(&f.resumed_lines) {
                println!("oracle:  {oracle}");
                println!("resumed: {resumed}");
            }
            println!("byte-identical: {}", if f.matched() { "yes" } else { "NO" });
            if !f.matched() {
                bail!("resumed fleet diverged from the oracle");
            }
        }
        return Ok(());
    }
    let flavor = args.get("flavor", "sync");
    let r = sim::run_resume(&flavor, trainers, rounds, kill_at, runners, &o)?;
    println!(
        "killed '{}' at round boundary {} (flavor {}, checkpoint epoch {})",
        r.job, r.kill_at, r.flavor, r.ckpt_round
    );
    println!("oracle:  {}", r.oracle_line);
    println!("resumed: {}", r.resumed_line);
    println!("byte-identical: {}", if r.matched() { "yes" } else { "NO" });
    if !r.matched() {
        bail!("resumed run diverged from the oracle");
    }
    Ok(())
}

/// Virtual-time tracing demo: run the traced scenario (`hyper.trace =
/// "on"`, one shaped uplink), print the per-round phase breakdown, and
/// write the Chrome trace-event JSON plus a round-phase CSV (see
/// `sim::run_trace` and the `trace` module docs).
fn cmd_trace(args: &Args) -> Result<()> {
    args.expect_flags(
        "trace",
        &["trainers", "rounds", "out", "per-shard", "test-n", "seed", "runners"],
    )?;
    let trainers = args.get_usize("trainers", 6)?;
    let rounds = args.get_u64("rounds", 4)?;
    let out = args.get("out", "bench_out/trace.json");
    let mut o = sim::SimOptions::mock();
    o.per_shard = args.get_usize("per-shard", 64)?;
    o.test_n = args.get_usize("test-n", 128)?;
    o.seed = args.get_u64("seed", 7)?;
    o.executor = flame::control::Executor::Cooperative {
        runners: args.get_usize("runners", 0)?,
    };
    let report = sim::run_trace(trainers, rounds, &o)?;
    println!(
        "trace: job {} workers={} rounds={rounds} vtime={:.2}s spans={}",
        report.job,
        report.workers,
        report.vtime_s,
        report.trace.span_count()
    );
    print!("{}", report.trace.phase_table());
    if let Some(dir) = std::path::Path::new(&out).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(&out, report.trace.chrome_json())?;
    println!("# chrome trace: {out} (load in chrome://tracing or Perfetto)");
    let csv = out.replace(".json", "_phases.csv");
    let mut s = String::from(
        "round,train_us,encode_us,xfer_us,wait_us,aggregate_us,distribute_us,eval_us,checkpoint_us,round_us\n",
    );
    for (round, row) in report.trace.phase_rounds() {
        s.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{}\n",
            round,
            row.train_us,
            row.encode_us,
            row.xfer_us,
            row.wait_us,
            row.aggregate_us,
            row.distribute_us,
            row.eval_us,
            row.checkpoint_us,
            row.round_us()
        ));
    }
    std::fs::write(&csv, s)?;
    println!("# phase csv:    {csv}");
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.clone(), r.to_vec()),
        None => {
            eprintln!(
                "usage: flame <expand|spec|run|fig10|fig11|scale|churn|fleet|fedprox|codec-sweep|resume|trace|roles|worker> [--flags]"
            );
            std::process::exit(2);
        }
    };
    let result = Args::parse(&rest).and_then(|args| match cmd.as_str() {
        "expand" => cmd_expand(&args),
        "spec" => cmd_spec(&args),
        "run" => cmd_run(&args),
        "fig10" => cmd_fig10(&args),
        "fig11" => cmd_fig11(&args),
        "scale" => cmd_scale(&args),
        "churn" => cmd_churn(&args),
        "fleet" => cmd_fleet(&args),
        "fedprox" => cmd_fedprox(&args),
        "codec-sweep" => cmd_codec_sweep(&args),
        "resume" => cmd_resume(&args),
        "trace" => cmd_trace(&args),
        "roles" => cmd_roles(&args),
        "worker" => cmd_worker(&args),
        other => bail!("unknown command '{other}'"),
    });
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
