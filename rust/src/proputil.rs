//! Minimal property-testing helper (substrate; no `proptest` offline).
//!
//! [`check`] runs a property over `n` generated cases from a seeded [`Rng`]
//! and, on failure, reports the case index and the seed that reproduces it.
//! Generators are plain closures over the RNG, which keeps shrinking out of
//! scope but makes every failure a one-line repro (`seed`, `case`).

use crate::prng::Rng;

/// Run `prop` over `cases` generated inputs. Panics with a reproducible
/// seed/case report on the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = rng.fork(case as u64);
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Convenience assertion helpers usable inside properties.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    let denom = 1.0_f64.max(a.abs()).max(b.abs());
    if (a - b).abs() / denom <= tol {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        check(
            "square-nonneg",
            1,
            200,
            |r| r.normal(),
            |x| ensure(x * x >= 0.0, "square must be non-negative"),
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failing_case() {
        check("always-fails", 2, 10, |r| r.f64(), |_| Err("nope".into()));
    }

    #[test]
    fn ensure_close_tolerates() {
        assert!(ensure_close(1.0, 1.0 + 1e-9, 1e-6, "x").is_ok());
        assert!(ensure_close(1.0, 2.0, 1e-6, "x").is_err());
    }
}
