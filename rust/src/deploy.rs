//! Deployer — the integration interface to resource orchestrators (§5.1).
//!
//! The paper's deployer abstracts Kubernetes / Docker Swarm / Mesos behind
//! one interface; any orchestrator that can create and destroy worker
//! instances plugs in. Here the interface is the [`Deployer`] trait with a
//! **two-phase** contract: `deploy` prepares one worker instance (building
//! its environment joins its channels), `start` launches everything that
//! was deployed. The split guarantees every role observes complete channel
//! membership before any worker runs — the paper's step-7/8 ordering
//! (agents fetch their full task configuration before the worker process
//! starts).
//!
//! Two single-box orchestrators ship:
//!
//! * [`SimDeployer`] — the default **cooperative worker fabric**: every
//!   pod is a task on a [`crate::sched::Scheduler`], multiplexed over a
//!   bounded M:N runner pool (default: one runner per CPU core). This is
//!   what lets a laptop hold a 10,000-trainer hierarchical deployment.
//! * [`ThreadDeployer`] — the legacy fiab-style emulation: one named OS
//!   thread per pod. Kept for parity testing (cooperative execution must
//!   reproduce its results bit-for-bit) and for workloads that want
//!   preemptive isolation; it does not scale past a few thousand workers.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, Result};

use crate::agent::{self, WorkerTask};
use crate::notify::Notifier;
use crate::roles::{JobRuntime, WorkerEnv};
use crate::sched::{Scheduler, WorkerPark};
use crate::tag::WorkerConfig;

/// Pod lifecycle states.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PodStatus {
    Creating,
    Running,
    Completed,
    Failed(String),
}

impl PodStatus {
    pub fn is_terminal(&self) -> bool {
        matches!(self, PodStatus::Completed | PodStatus::Failed(_))
    }
}

/// Shared pod status slot: written by the executing agent (thread or
/// scheduler task), waited on by the controller.
pub struct StatusCell {
    state: Mutex<PodStatus>,
    cv: Condvar,
}

impl StatusCell {
    pub fn new() -> Arc<Self> {
        Arc::new(Self {
            state: Mutex::new(PodStatus::Creating),
            cv: Condvar::new(),
        })
    }

    pub fn set(&self, s: PodStatus) {
        *self.state.lock().unwrap() = s;
        self.cv.notify_all();
    }

    pub fn get(&self) -> PodStatus {
        self.state.lock().unwrap().clone()
    }

    /// Block until the pod reaches a terminal state.
    pub fn wait_terminal(&self) -> PodStatus {
        let mut g = self.state.lock().unwrap();
        while !g.is_terminal() {
            g = self.cv.wait(g).unwrap();
        }
        g.clone()
    }
}

/// Handle to one deployed worker instance.
pub struct PodHandle {
    pub worker_id: String,
    pub compute: String,
    status: Arc<StatusCell>,
}

impl PodHandle {
    pub fn status(&self) -> PodStatus {
        self.status.get()
    }

    /// Block until the pod's worker exits; returns the terminal status.
    /// Call the deployer's [`Deployer::start`] first — before `start`, pods
    /// are deployed but not launched.
    pub fn wait(&self) -> PodStatus {
        self.status.wait_terminal()
    }
}

/// The resource-orchestrator integration interface (two-phase).
pub trait Deployer: Send + Sync {
    /// Orchestrator kind this deployer backs ("sim", "sim-threads",
    /// "k8s", ...).
    fn orchestrator(&self) -> &str;

    /// Prepare a worker instance (pod): build its environment — joining
    /// its channels — and register it for launch. The worker does not run
    /// until [`start`](Self::start).
    fn deploy(
        &self,
        cfg: WorkerConfig,
        job: &Arc<JobRuntime>,
        notifier: Arc<Notifier>,
    ) -> Result<PodHandle>;

    /// Launch every deployed-but-not-started worker. For the cooperative
    /// fabric this call *drives the whole deployment to completion* on the
    /// runner pool and returns when all pods are terminal.
    fn start(&self) -> Result<()> {
        Ok(())
    }
}

// ------------------------------------------------- cooperative (default)

/// Cooperative orchestrator: each pod is a task on the virtual-time
/// scheduler; `start` runs the M:N pool to completion.
pub struct SimDeployer {
    /// Runner threads; 0 = one per available CPU core.
    runners: usize,
    sched: Mutex<Option<Scheduler>>,
}

impl SimDeployer {
    pub fn new(runners: usize) -> Self {
        Self {
            runners,
            sched: Mutex::new(None),
        }
    }
}

impl Default for SimDeployer {
    fn default() -> Self {
        Self::new(0)
    }
}

impl Deployer for SimDeployer {
    fn orchestrator(&self) -> &str {
        "sim"
    }

    fn deploy(
        &self,
        cfg: WorkerConfig,
        job: &Arc<JobRuntime>,
        notifier: Arc<Notifier>,
    ) -> Result<PodHandle> {
        let park = WorkerPark::cooperative();
        let env = WorkerEnv::with_park(cfg, job.clone(), park.clone())?;
        let worker_id = env.cfg.id.clone();
        let compute = env.cfg.compute.clone();
        let status = StatusCell::new();
        let task = WorkerTask::new(env, notifier, status.clone());
        let mut g = self.sched.lock().unwrap();
        let sched = g.get_or_insert_with(Scheduler::new);
        let id = sched.spawn(Box::new(task));
        park.set_waker(sched.waker(id));
        Ok(PodHandle {
            worker_id,
            compute,
            status,
        })
    }

    fn start(&self) -> Result<()> {
        let sched = self.sched.lock().unwrap().take();
        if let Some(sched) = sched {
            let runners = if self.runners == 0 {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(4)
            } else {
                self.runners
            };
            sched.run(runners);
        }
        Ok(())
    }
}

// ------------------------------------------------ thread-per-worker (legacy)

/// Thread-backed orchestrator: each pod is a named OS thread running the
/// blocking Flame agent (fiab-style single-box emulation).
pub struct ThreadDeployer {
    recv_timeout: std::time::Duration,
    pending: Mutex<Vec<(WorkerEnv, Arc<Notifier>, Arc<StatusCell>)>>,
}

impl ThreadDeployer {
    pub fn new(recv_timeout: std::time::Duration) -> Self {
        Self {
            recv_timeout,
            pending: Mutex::new(Vec::new()),
        }
    }
}

impl Default for ThreadDeployer {
    fn default() -> Self {
        Self::new(crate::channel::RECV_TIMEOUT)
    }
}

impl Deployer for ThreadDeployer {
    fn orchestrator(&self) -> &str {
        "sim-threads"
    }

    fn deploy(
        &self,
        cfg: WorkerConfig,
        job: &Arc<JobRuntime>,
        notifier: Arc<Notifier>,
    ) -> Result<PodHandle> {
        let park = WorkerPark::blocking(self.recv_timeout);
        let env = WorkerEnv::with_park(cfg, job.clone(), park)?;
        let worker_id = env.cfg.id.clone();
        let compute = env.cfg.compute.clone();
        let status = StatusCell::new();
        self.pending
            .lock()
            .unwrap()
            .push((env, notifier, status.clone()));
        Ok(PodHandle {
            worker_id,
            compute,
            status,
        })
    }

    fn start(&self) -> Result<()> {
        let pending = std::mem::take(&mut *self.pending.lock().unwrap());
        for (env, notifier, status) in pending {
            let worker_id = env.cfg.id.clone();
            std::thread::Builder::new()
                .name(format!("pod-{worker_id}"))
                .spawn(move || {
                    status.set(PodStatus::Running);
                    let outcome = agent::run_worker(env, notifier);
                    status.set(match outcome {
                        Ok(()) => PodStatus::Completed,
                        Err(e) => PodStatus::Failed(format!("{e:#}")),
                    });
                })?;
        }
        Ok(())
    }
}

/// Per-orchestrator deployer registry held by the controller.
#[derive(Default)]
pub struct DeployerSet {
    deployers: HashMap<String, Arc<dyn Deployer>>,
}

impl DeployerSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// A set with the sim orchestrator (cooperative fabric) pre-registered.
    /// Note: `Controller::submit` routes "sim" pods through a fresh
    /// per-job deployer configured from `JobOptions::executor`; this entry
    /// marks the orchestrator as known (lookups, custom-orchestrator
    /// error paths) rather than executing jobs itself.
    pub fn with_sim() -> Self {
        let mut s = Self::new();
        s.register(Arc::new(SimDeployer::default()));
        s
    }

    pub fn register(&mut self, d: Arc<dyn Deployer>) {
        self.deployers.insert(d.orchestrator().to_string(), d);
    }

    pub fn get(&self, orchestrator: &str) -> Result<&Arc<dyn Deployer>> {
        match self.deployers.get(orchestrator) {
            Some(d) => Ok(d),
            None => bail!("no deployer registered for orchestrator '{orchestrator}'"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::notify::EventKind;

    #[test]
    fn deployer_set_lookup() {
        let s = DeployerSet::with_sim();
        assert!(s.get("sim").is_ok());
        assert!(s.get("k8s").is_err());
    }

    // Pod lifecycle end-to-end is covered by controller integration tests;
    // here we check the failure path surfaces through the status for both
    // orchestrators.
    #[test]
    fn failed_worker_reports_failed_status_cooperative() {
        use crate::roles::tests_support::tiny_job_runtime;
        let (job, cfgs) = tiny_job_runtime();
        let mut bad = cfgs[0].clone();
        bad.role = "no-such-role".into();
        let d = SimDeployer::new(1);
        let notifier = Arc::new(Notifier::new());
        let rx = notifier.subscribe(Some(EventKind::WorkerStatus), None);
        let pod = d.deploy(bad, &job, notifier).unwrap();
        d.start().unwrap();
        let status = pod.wait();
        assert!(matches!(status, PodStatus::Failed(_)), "{status:?}");
        assert!(rx.try_iter().count() >= 1);
    }

    #[test]
    fn failed_worker_reports_failed_status_threaded() {
        use crate::roles::tests_support::tiny_job_runtime;
        let (job, cfgs) = tiny_job_runtime();
        let mut bad = cfgs[0].clone();
        bad.role = "no-such-role".into();
        let d = ThreadDeployer::default();
        let notifier = Arc::new(Notifier::new());
        let pod = d.deploy(bad, &job, notifier).unwrap();
        d.start().unwrap();
        let status = pod.wait();
        assert!(matches!(status, PodStatus::Failed(_)), "{status:?}");
    }
}
